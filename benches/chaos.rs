//! Deterministic chaos harness: the fault-tolerance counterpart to the
//! serving bench. Every fault is *injected from a seeded plan* — executor
//! panics via `FaultPlan`, torn frames / reply stalls via `WireFaults` —
//! so each scenario is reproducible and gates on hard invariants instead
//! of luck:
//!
//! 1. panic soak — a poisoned plane keeps serving: every admitted request
//!    gets exactly one typed outcome, completions stay bit-exact vs
//!    `sim::eval_batch`, workers survive all panics;
//! 2. deadline storm — a saturated single worker sheds expired requests
//!    with typed `Expired` replies, generous deadlines still complete;
//! 3. quarantine lifecycle — a repeatedly panicking tenant trips its
//!    breaker, co-tenants are untouched, the window half-opens and a
//!    clean probe recovers the tenant;
//! 4. wire chaos — loadgen drives a server injecting executor panics,
//!    torn frames and reply stalls, and finishes every request through
//!    reconnects and typed-failure retries.
//!
//!     cargo bench --bench chaos
//!     KANELE_BENCH_QUICK=1 cargo bench --bench chaos   # CI smoke mode
//!
//! Acceptance bar (ISSUE 8): zero hangs (every reply is collected under a
//! timeout and a watchdog aborts the whole run past its wall budget),
//! `completed + failed + shed_expired + dropped == admitted` on every
//! scenario, and rows land under `section: "chaos"` in `BENCH_serving.json`
//! (merged, not overwritten — the serving bench owns the rest of the file).

mod common;

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use kanele::checkpoint::testutil;
use kanele::coordinator::{FaultPlan, ModelRegistry, Service, ServiceCfg, SubmitError};
use kanele::json::{obj, Value};
use kanele::net::{self, LoadGenCfg, NetCfg, NetServer, WireFaults};
use kanele::netlist::Netlist;
use kanele::{data, lut, sim};

/// Hard wall budget for the whole bench: a hang anywhere (stuck reply,
/// unjoinable thread, wedged socket) turns into a loud process abort
/// instead of a silent CI timeout.
const WALL_BUDGET: Duration = Duration::from_secs(300);

/// Per-reply collection timeout: no typed outcome within this window is a
/// hang, full stop.
const REPLY_TIMEOUT: Duration = Duration::from_secs(30);

/// Typed-outcome tally for one scenario. The invariant every scenario
/// gates on: `ok + failed + expired + dropped == admitted`.
#[derive(Default)]
struct Tally {
    admitted: u64,
    ok: u64,
    failed: u64,
    expired: u64,
    dropped: u64,
}

impl Tally {
    fn assert_conserved(&self, scenario: &str) {
        assert_eq!(
            self.ok + self.failed + self.expired + self.dropped,
            self.admitted,
            "{scenario}: typed outcomes do not partition admissions \
             (ok {} + failed {} + expired {} + dropped {} != admitted {})",
            self.ok,
            self.failed,
            self.expired,
            self.dropped,
            self.admitted
        );
    }

    fn row(&self, scenario: &str, extra: Vec<(&str, Value)>) -> Value {
        let mut fields: Vec<(&str, Value)> = vec![
            ("section", "chaos".into()),
            ("scenario", scenario.into()),
            ("admitted", (self.admitted as i64).into()),
            ("completed", (self.ok as i64).into()),
            ("failed", (self.failed as i64).into()),
            ("expired", (self.expired as i64).into()),
            ("dropped", (self.dropped as i64).into()),
            ("conserved", true.into()),
        ];
        fields.extend(extra);
        obj(fields)
    }
}

/// Collect one reply into the tally; `oracle` is the bit-exact expectation
/// for a completion (panicked and shed requests never reach an executor,
/// so only `Ok` outcomes are comparable).
fn collect(
    tally: &mut Tally,
    rx: std::sync::mpsc::Receiver<kanele::coordinator::Reply>,
    oracle: Option<&Vec<i64>>,
    scenario: &str,
) {
    match rx.recv_timeout(REPLY_TIMEOUT) {
        Ok(Ok(resp)) => {
            tally.ok += 1;
            if let Some(want) = oracle {
                assert_eq!(&resp.sums, want, "{scenario}: completed row diverges from sim");
            }
        }
        Ok(Err(SubmitError::Failed)) => tally.failed += 1,
        Ok(Err(SubmitError::Expired)) => tally.expired += 1,
        Ok(Err(e)) => panic!("{scenario}: unexpected typed reply {e}"),
        Err(RecvTimeoutError::Disconnected) => tally.dropped += 1,
        Err(RecvTimeoutError::Timeout) => {
            panic!("{scenario}: reply channel hung past {REPLY_TIMEOUT:?}")
        }
    }
}

fn main() {
    let quick = std::env::var("KANELE_BENCH_QUICK").is_ok();
    println!("=== chaos bench: seeded faults, typed outcomes, hard invariants ===");

    // watchdog: the whole point of this bench is "no hangs", so a hang in
    // the bench itself must fail loudly rather than stall CI
    let done = Arc::new(AtomicBool::new(false));
    {
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            let t0 = Instant::now();
            while t0.elapsed() < WALL_BUDGET {
                std::thread::sleep(Duration::from_millis(200));
                if done.load(Ordering::Relaxed) {
                    return;
                }
            }
            eprintln!("CHAOS HANG: wall budget {WALL_BUDGET:?} exceeded");
            std::process::exit(2);
        });
    }

    let ck = common::checkpoint_or_synthetic("jsc_openml");
    let tables = lut::from_checkpoint(&ck);
    let net = Arc::new(Netlist::build(&ck, &tables, 2));
    let n_stream = if quick { 2_000 } else { 10_000 };
    let stream = data::random_code_stream(&ck, n_stream, 17);
    let oracle = sim::eval_batch(&net, &stream);
    let mut rows: Vec<Value> = Vec::new();

    // -- 1. panic soak: a poisoned plane keeps serving ----------------------
    // every 5th executed batch panics (never two in a row, so the default
    // breaker stays closed); the closed loop below must see exactly one
    // typed outcome per admission and bit-exact completions
    {
        let svc = Service::start(
            Arc::clone(&net),
            ServiceCfg {
                workers: 4,
                shards: 2,
                steal: true,
                max_batch: 16,
                max_wait: Duration::from_micros(50),
                queue_depth: 1 << 12,
                faults: FaultPlan { seed: 0xC4A05, panic_every: 5, ..Default::default() },
                ..Default::default()
            },
        );
        let mut tally = Tally::default();
        let mut pending: VecDeque<(usize, _)> = VecDeque::with_capacity(512);
        let t0 = Instant::now();
        for (i, codes) in stream.iter().enumerate() {
            let mut codes = codes.clone();
            loop {
                match svc.try_submit(codes) {
                    Ok(rx) => {
                        tally.admitted += 1;
                        pending.push_back((i, rx));
                        break;
                    }
                    Err((SubmitError::Backpressure, back)) => {
                        codes = back.expect("codes back on backpressure");
                        if let Some((j, rx)) = pending.pop_front() {
                            collect(&mut tally, rx, Some(&oracle[j]), "panic_soak");
                        }
                    }
                    Err((e, _)) => panic!("panic_soak: submit failed: {e}"),
                }
            }
            if pending.len() >= 512 {
                if let Some((j, rx)) = pending.pop_front() {
                    collect(&mut tally, rx, Some(&oracle[j]), "panic_soak");
                }
            }
        }
        for (j, rx) in pending {
            collect(&mut tally, rx, Some(&oracle[j]), "panic_soak");
        }
        let wall = t0.elapsed().as_secs_f64();
        svc.shutdown(); // must return: a leaked/wedged worker would hang here
        let st = svc.stats();
        tally.assert_conserved("panic_soak");
        assert!(st.exec_panics > 0, "fault plan injected nothing");
        assert!(st.faults_injected > 0);
        assert!(st.respawns >= 1, "no supervised restart recorded");
        assert_eq!(st.failed, tally.failed, "service failed-counter disagrees with replies");
        assert_eq!(st.completed, tally.ok);
        println!(
            "   panic soak: {} admitted -> {} ok / {} failed / {} dropped | {} panics, {} respawns, {:.0} req/s",
            tally.admitted,
            tally.ok,
            tally.failed,
            tally.dropped,
            st.exec_panics,
            st.respawns,
            tally.admitted as f64 / wall
        );
        rows.push(tally.row(
            "panic_soak",
            vec![
                ("exec_panics", (st.exec_panics as i64).into()),
                ("respawns", (st.respawns as i64).into()),
                ("faults_injected", (st.faults_injected as i64).into()),
                ("rps", (tally.admitted as f64 / wall).into()),
            ],
        ));
    }

    // -- 2. deadline storm: expiry shedding under a saturated worker --------
    // one worker stretched 2 ms per batch; a burst with 200 us deadlines
    // mostly expires at batch formation (typed, cheap — shed batches never
    // execute), then a generous pass completes bit-exact
    {
        let svc = Service::start(
            Arc::clone(&net),
            ServiceCfg {
                workers: 1,
                shards: 1,
                max_batch: 8,
                max_wait: Duration::from_micros(100),
                queue_depth: 1 << 12,
                exec_delay: Duration::from_millis(2),
                exec_delay_every: 0,
                ..Default::default()
            },
        );
        let n_burst = if quick { 100 } else { 300 };
        let mut tally = Tally::default();
        let mut pending = Vec::with_capacity(n_burst);
        for (i, codes) in stream.iter().take(n_burst).enumerate() {
            let rx = svc.submit_deadline(codes.clone(), Some(200)).expect("burst admit");
            tally.admitted += 1;
            pending.push((i, rx));
        }
        for (j, rx) in pending.drain(..) {
            collect(&mut tally, rx, Some(&oracle[j]), "deadline_storm");
        }
        assert!(tally.expired > 0, "saturated plane shed nothing");
        // generous deadlines ride the same stretched plane and still land
        let n_generous = 50usize;
        for (i, codes) in stream.iter().take(n_generous).enumerate() {
            let rx = svc.submit_deadline(codes.clone(), Some(10_000_000)).expect("generous admit");
            tally.admitted += 1;
            pending.push((i, rx));
        }
        let before_generous = tally.ok;
        for (j, rx) in pending {
            collect(&mut tally, rx, Some(&oracle[j]), "deadline_storm");
        }
        assert_eq!(
            tally.ok - before_generous,
            n_generous as u64,
            "a generous deadline was shed or failed"
        );
        svc.shutdown();
        let st = svc.stats();
        tally.assert_conserved("deadline_storm");
        assert_eq!(st.shed_expired, tally.expired, "shed counter disagrees with typed replies");
        assert_eq!(st.per_shard.iter().map(|s| s.shed_expired).sum::<u64>(), st.shed_expired);
        println!(
            "   deadline storm: {} admitted -> {} ok / {} expired (typed, shed at formation)",
            tally.admitted, tally.ok, tally.expired
        );
        rows.push(tally.row(
            "deadline_storm",
            vec![
                ("deadline_us", 200.into()),
                ("shed_expired", (st.shed_expired as i64).into()),
                ("generous_completed", (n_generous as i64).into()),
            ],
        ));
    }

    // -- 3. quarantine lifecycle: trip -> isolate -> half-open -> recover ---
    // tenant a panics on its first two batches (seeded, budgeted), trips a
    // 2-strike breaker, is refused with a typed error while tenant b keeps
    // serving bit-exact, then the window elapses and a clean probe closes
    // the breaker
    {
        let ck_a = testutil::synthetic(&[4, 3, 2], &[4, 5, 6], 2024);
        let ck_b = testutil::synthetic(&[6, 4, 3], &[3, 5, 6], 777);
        let net_a = Arc::new(Netlist::build(&ck_a, &lut::from_checkpoint(&ck_a), 2));
        let net_b = Arc::new(Netlist::build(&ck_b, &lut::from_checkpoint(&ck_b), 2));
        let reg = Arc::new(ModelRegistry::new(kanele::engine::OptLevel::default()));
        let a = reg.load("a", Arc::clone(&net_a)).expect("load tenant a");
        let b = reg.load("b", Arc::clone(&net_b)).expect("load tenant b");
        let svc = Service::start_registry(
            Arc::clone(&reg),
            ServiceCfg {
                workers: 1,
                max_batch: 1,
                max_wait: Duration::from_micros(10),
                faults: FaultPlan {
                    panic_every: 1,
                    panic_budget: 2,
                    panic_model: Some(a),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let window = Duration::from_millis(60);
        reg.resolve(a).expect("tenant a").quarantine_policy(2, window);
        let codes_a = vec![1u32, 2, 3, 0];
        let codes_b = vec![1u32, 2, 3, 0, 1, 2];
        for _ in 0..2 {
            let rx = svc.submit_model(a, codes_a.clone()).expect("poisoned admit");
            let reply = rx.recv_timeout(REPLY_TIMEOUT).expect("poisoned reply");
            assert_eq!(reply.unwrap_err(), SubmitError::Failed);
        }
        let refusal = svc.submit_model(a, codes_a.clone()).expect_err("breaker should be open");
        assert!(matches!(refusal, SubmitError::Quarantined(_)), "untyped refusal: {refusal}");
        let got = svc.submit_blocking_model(b, codes_b.clone()).expect("co-tenant");
        assert_eq!(got.sums, sim::eval(&net_b, &codes_b), "co-tenant b disturbed by a's breaker");
        std::thread::sleep(2 * window);
        // half-open probe: the fault budget is spent, so it runs clean
        let probe = svc.submit_blocking_model(a, codes_a.clone()).expect("half-open probe");
        assert_eq!(probe.sums, sim::eval(&net_a, &codes_a));
        svc.shutdown();
        let st = svc.stats();
        let sa = st.per_tenant.iter().find(|t| t.name == "a").expect("tenant a stats");
        assert_eq!((sa.panics, sa.failed), (2, 2));
        assert!(sa.quarantine_drops >= 1);
        assert!(!sa.quarantined, "breaker still open after clean probe");
        assert_eq!(st.quarantine_drops, sa.quarantine_drops);
        let admitted: u64 = st.per_shard.iter().map(|s| s.admitted).sum();
        assert_eq!(st.completed + st.failed + st.shed_expired + st.dropped, admitted);
        println!(
            "   quarantine: tripped after 2 panics, {} refusal(s), co-tenant clean, recovered",
            sa.quarantine_drops
        );
        rows.push(obj(vec![
            ("section", "chaos".into()),
            ("scenario", "quarantine".into()),
            ("panics", (sa.panics as i64).into()),
            ("quarantine_drops", (sa.quarantine_drops as i64).into()),
            ("recovered", (!sa.quarantined).into()),
            ("conserved", true.into()),
        ]));
    }

    // -- 4. wire chaos: panics + torn frames + stalls through loadgen -------
    // the server injects an executor panic every 9th batch, tears every
    // 17th reply frame mid-write and stalls every 13th; loadgen must land
    // every request through reconnects and typed-failure retries
    {
        let svc = Arc::new(Service::start(
            Arc::clone(&net),
            ServiceCfg {
                workers: 2,
                shards: 2,
                steal: true,
                max_batch: 16,
                max_wait: Duration::from_micros(100),
                queue_depth: 1 << 12,
                faults: FaultPlan { seed: 0xFACADE, panic_every: 9, ..Default::default() },
                ..Default::default()
            },
        ));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let mut server = NetServer::start(
            Arc::clone(&svc),
            listener,
            NetCfg {
                levels: ck.quantizer(0).levels(),
                faults: WireFaults {
                    torn_every: 17,
                    stall_every: 13,
                    stall: Duration::from_micros(200),
                    ..Default::default()
                },
                ..NetCfg::default()
            },
        )
        .expect("start chaos server");
        let addr = server.local_addr().to_string();
        let requests: u64 = if quick { 400 } else { 2_000 };
        let r = net::loadgen(
            &addr,
            LoadGenCfg {
                connections: 2,
                requests,
                seed: 29,
                deadline_us: 50_000,
                ..Default::default()
            },
        )
        .expect("chaos loadgen");
        assert_eq!(r.errors, 0, "wire chaos produced terminal client errors");
        assert_eq!(
            r.completed + r.expired,
            requests,
            "requests lost on the wire (completed {} + expired {} != {requests})",
            r.completed,
            r.expired
        );
        assert!(r.reconnects >= 1, "torn frames never forced a reconnect");
        assert!(r.failed_retries >= 1, "injected panics never surfaced as typed retries");
        let ns = server.stats();
        assert!(ns.faults_injected >= 1, "server injected no wire faults");
        let st = svc.stats();
        assert!(st.exec_panics >= 1, "server injected no executor panics");
        server.shutdown(); // must return with faults armed: no wedged conns
        svc.shutdown();
        println!(
            "   wire chaos: {requests} reqs -> {} ok / {} expired | {} reconnects, {} failed retries, {} wire faults, {} panics",
            r.completed,
            r.expired,
            r.reconnects,
            r.failed_retries,
            ns.faults_injected,
            st.exec_panics
        );
        rows.push(obj(vec![
            ("section", "chaos".into()),
            ("scenario", "wire_chaos".into()),
            ("requests", (requests as i64).into()),
            ("completed", (r.completed as i64).into()),
            ("expired", (r.expired as i64).into()),
            ("reconnects", (r.reconnects as i64).into()),
            ("failed_retries", (r.failed_retries as i64).into()),
            ("wire_faults_injected", (ns.faults_injected as i64).into()),
            ("exec_panics", (st.exec_panics as i64).into()),
            ("conserved", true.into()),
        ]));
    }

    done.store(true, Ordering::Relaxed);

    // merge (not overwrite) into the serving trajectory file: replace any
    // previous chaos rows, leave the serving bench's own rows alone
    let path = std::path::Path::new("BENCH_serving.json");
    let mut doc: BTreeMap<String, Value> = match kanele::json::from_file(path) {
        Ok(Value::Object(o)) => o,
        _ => {
            let mut o = BTreeMap::new();
            o.insert("bench".to_string(), Value::Str("serving".to_string()));
            o
        }
    };
    let mut all_rows = match doc.remove("rows") {
        Some(Value::Array(a)) => a,
        _ => Vec::new(),
    };
    all_rows.retain(|r| r.get("section").and_then(|s| s.as_str()) != Some("chaos"));
    all_rows.extend(rows);
    doc.insert("rows".to_string(), Value::Array(all_rows));
    std::fs::write(path, kanele::json::to_string(&Value::Object(doc)))
        .expect("write BENCH_serving.json");
    println!("merged chaos rows into BENCH_serving.json");
}
