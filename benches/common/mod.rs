//! Minimal bench harness (criterion is unavailable offline): warmup +
//! timed iterations, median / MAD / throughput reporting, environment knobs
//! via KANELE_BENCH_{WARMUP,ITERS}.

// shared by several bench binaries; each uses a subset of the helpers
#![allow(dead_code)]

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub iters: usize,
}

/// Run `f` repeatedly; each call should perform one logical operation of
/// the benchmark (batching inside `f` is the caller's business).
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    let warmup: usize = std::env::var("KANELE_BENCH_WARMUP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let iters: usize = std::env::var("KANELE_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(15);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    let r = BenchResult { name: name.to_string(), median_ns: median, mad_ns: mad, iters };
    println!(
        "bench {:<44} median {:>12.0} ns  (+- {:>10.0} ns MAD, {} iters)",
        r.name, r.median_ns, r.mad_ns, r.iters
    );
    r
}

/// Report an ops/sec figure for a bench whose `f` performed `n_ops`.
pub fn report_throughput(r: &BenchResult, n_ops: usize) {
    println!(
        "      {:<44} {:>14.0} ops/s",
        format!("{} throughput", r.name),
        n_ops as f64 / (r.median_ns / 1e9)
    );
}

/// Load a checkpoint if its artifact exists, else None (benches skip).
pub fn try_checkpoint(name: &str) -> Option<kanele::checkpoint::Checkpoint> {
    let p = kanele::config::ckpt_path(name);
    if !p.exists() {
        println!("bench {name}: missing checkpoint {} (run make artifacts-all) — skipped", p.display());
        return None;
    }
    kanele::checkpoint::Checkpoint::load(&p).ok()
}

/// Real checkpoint when the artifact exists, otherwise a synthetic twin
/// with the experiment's dims/bits — lets structural benches (e.g. the
/// interpreted-vs-compiled comparison) run in artifact-less environments.
pub fn checkpoint_or_synthetic(name: &str) -> kanele::checkpoint::Checkpoint {
    if let Some(ck) = try_checkpoint(name) {
        return ck;
    }
    let exp = kanele::config::experiment(name).expect("unknown experiment");
    println!("bench {name}: using a synthetic twin (dims {:?}, bits {:?})", exp.dims, exp.bits);
    kanele::checkpoint::testutil::synthetic(exp.dims, exp.bits, 0xB5EED)
}
