//! Coordinator serving bench: throughput/latency across worker counts and
//! batching policies (the L3 hot path + the batching-policy ablation that
//! DESIGN.md calls out).
//!
//!     cargo bench --bench serving

mod common;

use std::sync::Arc;
use std::time::Duration;

use kanele::coordinator::{Service, ServiceCfg};
use kanele::netlist::Netlist;
use kanele::{data, lut};

fn main() {
    println!("=== serving bench: coordinator throughput/latency ===");
    let Some(ck) = common::try_checkpoint("jsc_openml")
        .or_else(|| common::try_checkpoint("moons"))
    else {
        return;
    };
    let tables = lut::from_checkpoint(&ck);
    let net = Arc::new(Netlist::build(&ck, &tables, 2));
    let stream = data::random_code_stream(&ck, 20_000, 11);

    for workers in [1usize, 2, 4] {
        for (batch, wait_us) in [(1usize, 0u64), (16, 50), (64, 100), (256, 200)] {
            let svc = Service::start(
                Arc::clone(&net),
                ServiceCfg {
                    workers,
                    max_batch: batch,
                    max_wait: Duration::from_micros(wait_us),
                    queue_depth: 1 << 14,
                },
            );
            let t = std::time::Instant::now();
            let mut pending = Vec::with_capacity(4096);
            for codes in &stream {
                loop {
                    match svc.submit(codes.clone()) {
                        Ok(rx) => {
                            pending.push(rx);
                            break;
                        }
                        Err(_) => {
                            for rx in pending.drain(..) {
                                let _ = rx.recv();
                            }
                        }
                    }
                }
            }
            for rx in pending.drain(..) {
                let _ = rx.recv();
            }
            let wall = t.elapsed().as_secs_f64();
            let st = svc.stats();
            println!(
                "workers {workers} batch {batch:>3} wait {wait_us:>3} us -> {:>9.0} req/s | p50 {:>7.1} us p99 {:>8.1} us | mean batch {:>6.1}",
                20_000.0 / wall,
                st.latency_p50_us,
                st.latency_p99_us,
                st.mean_batch
            );
            svc.shutdown();
        }
    }
}
