//! Coordinator serving bench: the interpreted-vs-compiled backend
//! comparison plus throughput/latency across worker counts and batching
//! policies (the L3 hot path + the batching-policy ablation that
//! DESIGN.md calls out).
//!
//!     cargo bench --bench serving
//!
//! Runs on the real jet-tagging checkpoint when `make artifacts-all` has
//! produced it, and on a synthetic twin with the same dims/bits otherwise
//! (backend *speedups* are structural, so the twin is representative even
//! though absolute accuracy is meaningless there).

mod common;

use std::sync::Arc;
use std::time::Duration;

use kanele::coordinator::{Backend, Service, ServiceCfg};
use kanele::netlist::Netlist;
use kanele::{data, engine, lut, sim};

fn main() {
    println!("=== serving bench: interpreted vs compiled + coordinator grid ===");
    let ck = common::checkpoint_or_synthetic("jsc_openml");
    let tables = lut::from_checkpoint(&ck);
    let net = Arc::new(Netlist::build(&ck, &tables, 2));
    let stream = data::random_code_stream(&ck, 20_000, 11);

    // -- 1. direct backend comparison (no threads, no batcher) -------------
    // chunked execution of the same 20k-request stream through both
    // executors; the acceptance bar is >= 2x at batch 64
    let prog = engine::compile(&net);
    println!(
        "netlist {}: {} L-LUTs -> {} fused ops, {} packed table words",
        ck.name,
        net.n_luts(),
        prog.n_ops(),
        prog.table_words()
    );
    for batch in [1usize, 16, 64, 256] {
        let r_interp = common::bench(&format!("interpreted eval_batch (batch {batch})"), || {
            for chunk in stream.chunks(batch) {
                std::hint::black_box(sim::eval_batch(&net, chunk));
            }
        });
        let mut exec = engine::Executor::with_capacity(&prog, batch);
        let r_comp = common::bench(&format!("compiled run_batch    (batch {batch})"), || {
            for chunk in stream.chunks(batch) {
                std::hint::black_box(exec.run_batch(&prog, chunk));
            }
        });
        common::report_throughput(&r_comp, stream.len());
        println!(
            "      batch {batch:>3}: compiled is {:.2}x interpreted",
            r_interp.median_ns / r_comp.median_ns
        );
    }

    // -- 2. end-to-end coordinator grid -------------------------------------
    for backend in [Backend::Interpreted, Backend::Compiled] {
        for workers in [1usize, 2, 4] {
            for (batch, wait_us) in [(1usize, 0u64), (16, 50), (64, 100), (256, 200)] {
                let svc = Service::start(
                    Arc::clone(&net),
                    ServiceCfg {
                        workers,
                        max_batch: batch,
                        max_wait: Duration::from_micros(wait_us),
                        queue_depth: 1 << 14,
                        backend,
                    },
                );
                let t = std::time::Instant::now();
                let mut pending = Vec::with_capacity(4096);
                for codes in &stream {
                    loop {
                        match svc.submit(codes.clone()) {
                            Ok(rx) => {
                                pending.push(rx);
                                break;
                            }
                            Err(_) => {
                                for rx in pending.drain(..) {
                                    let _ = rx.recv();
                                }
                            }
                        }
                    }
                }
                for rx in pending.drain(..) {
                    let _ = rx.recv();
                }
                let wall = t.elapsed().as_secs_f64();
                let st = svc.stats();
                println!(
                    "{:<11} workers {workers} batch {batch:>3} wait {wait_us:>3} us -> {:>9.0} req/s | p50 {:>7.1} us p99 {:>8.1} us | mean batch {:>6.1}",
                    format!("{backend:?}"),
                    20_000.0 / wall,
                    st.latency_p50_us,
                    st.latency_p99_us,
                    st.mean_batch
                );
                svc.shutdown();
            }
        }
    }
}
