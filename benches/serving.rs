//! Coordinator serving bench: the interpreted-vs-compiled backend
//! comparison plus throughput/latency across worker counts and batching
//! policies (the L3 hot path + the batching-policy ablation that
//! DESIGN.md calls out).
//!
//!     cargo bench --bench serving
//!
//! Runs on the real jet-tagging checkpoint when `make artifacts-all` has
//! produced it, and on a synthetic twin with the same dims/bits otherwise
//! (backend *speedups* are structural, so the twin is representative even
//! though absolute accuracy is meaningless there).

mod common;

use std::sync::Arc;
use std::time::Duration;

use kanele::coordinator::{Backend, Service, ServiceCfg, SubmitError};
use kanele::netlist::Netlist;
use kanele::{data, engine, lut, sim};

fn main() {
    println!("=== serving bench: interpreted vs compiled + coordinator grid ===");
    let ck = common::checkpoint_or_synthetic("jsc_openml");
    let tables = lut::from_checkpoint(&ck);
    let net = Arc::new(Netlist::build(&ck, &tables, 2));
    let stream = data::random_code_stream(&ck, 20_000, 11);

    // -- 1. direct backend comparison (no threads, no batcher) -------------
    // chunked execution of the same 20k-request stream through both
    // executors; the acceptance bar is >= 2x at batch 64
    let prog = engine::compile(&net);
    println!(
        "netlist {}: {} L-LUTs -> {} fused ops, {} packed table words",
        ck.name,
        net.n_luts(),
        prog.n_ops(),
        prog.table_words()
    );
    for batch in [1usize, 16, 64, 256] {
        let r_interp = common::bench(&format!("interpreted eval_batch (batch {batch})"), || {
            for chunk in stream.chunks(batch) {
                std::hint::black_box(sim::eval_batch(&net, chunk));
            }
        });
        // reused flat output plane — exactly what the coordinator's
        // executor workers run
        let mut exec = engine::Executor::with_capacity(&prog, batch);
        let mut flat: Vec<i64> = Vec::new();
        let r_comp = common::bench(&format!("compiled run_batch_into (batch {batch})"), || {
            for chunk in stream.chunks(batch) {
                exec.run_batch_into(&prog, chunk, &mut flat);
                std::hint::black_box(&flat);
            }
        });
        common::report_throughput(&r_comp, stream.len());
        let samples_per_s = stream.len() as f64 / (r_comp.median_ns / 1e9);
        println!(
            "      batch {batch:>3}: compiled is {:.2}x interpreted | {:.3e} fused ops/s ({:.0} samples/s)",
            r_interp.median_ns / r_comp.median_ns,
            samples_per_s * prog.n_ops() as f64,
            samples_per_s
        );
    }

    // -- 2. end-to-end coordinator grid -------------------------------------
    // backend x batching-policy x workers through the dispatcher/executor
    // pipeline; workers is the innermost loop so each row reports its
    // throughput scaling against the same config at workers = 1 (the
    // pipelined coordinator's whole point is that this scales)
    for backend in [Backend::Interpreted, Backend::Compiled] {
        for (batch, wait_us) in [(1usize, 0u64), (16, 50), (64, 100), (256, 200)] {
            let mut base_rps = None;
            for workers in [1usize, 2, 4] {
                let svc = Service::start(
                    Arc::clone(&net),
                    ServiceCfg {
                        workers,
                        max_batch: batch,
                        max_wait: Duration::from_micros(wait_us),
                        queue_depth: 1 << 14,
                        backend,
                        ..Default::default()
                    },
                );
                let t = std::time::Instant::now();
                let mut pending = Vec::with_capacity(4096);
                for codes in &stream {
                    loop {
                        match svc.submit(codes.clone()) {
                            Ok(rx) => {
                                pending.push(rx);
                                break;
                            }
                            Err(SubmitError::Backpressure) => {
                                for rx in pending.drain(..) {
                                    let _ = rx.recv();
                                }
                            }
                            Err(e) => panic!("serving bench submit failed: {e}"),
                        }
                    }
                }
                for rx in pending.drain(..) {
                    let _ = rx.recv();
                }
                let wall = t.elapsed().as_secs_f64();
                let rps = stream.len() as f64 / wall;
                let scaling = rps / *base_rps.get_or_insert(rps);
                let st = svc.stats();
                println!(
                    "{:<11} batch {batch:>3} wait {wait_us:>3} us workers {workers} -> {rps:>9.0} req/s ({scaling:>4.2}x vs 1 worker) | {:.3e} ops/s | p50 {:>7.1} us p99 {:>8.1} us | mean batch {:>6.1} ({} batches)",
                    format!("{backend:?}"),
                    st.throughput_ops,
                    st.latency_p50_us,
                    st.latency_p99_us,
                    st.mean_batch,
                    st.batches
                );
                svc.shutdown();
            }
        }
    }
}
