//! Coordinator serving bench: the interpreted-vs-compiled backend
//! comparison, throughput/latency across worker counts and batching
//! policies, the shards x workers scaling grid, the headline A/B —
//! the sharded admission + work-stealing executor pool against the PR-3
//! single-dispatcher topology frozen in-bench as `mod baseline` — and the
//! wire: loopback TCP loadgen sweeps plus a CheetahLite control loop whose
//! policy is evaluated over the network under a per-step deadline.
//!
//!     cargo bench --bench serving
//!     KANELE_BENCH_QUICK=1 cargo bench --bench serving   # CI smoke mode
//!
//! Acceptance bar (ISSUE 4): with 4+ executors under a heavy-tailed
//! synthetic load (every Nth executed batch is stretched by a fixed delay),
//! the sharded/stealing plane reaches >= 1.3x the frozen baseline's
//! throughput, with bit-exact responses (asserted against `sim::eval`
//! before any timing) and `shards=1, steal=off` matching the baseline
//! within noise. Results also land in `BENCH_serving.json` so the perf
//! trajectory is recorded instead of lost in logs.
//!
//! Runs on the real jet-tagging checkpoint when `make artifacts-all` has
//! produced it, and on a synthetic twin with the same dims/bits otherwise
//! (backend *speedups* are structural, so the twin is representative even
//! though absolute accuracy is meaningless there).

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use kanele::checkpoint::testutil;
use kanele::coordinator::{Backend, ModelId, ModelRegistry, Service, ServiceCfg, SubmitError};
use kanele::json::{obj, Value};
use kanele::net::{self, Client, LoadGenCfg, NetCfg, NetServer};
use kanele::netlist::hotswap::NetlistCell;
use kanele::netlist::Netlist;
use kanele::util::{Rng, Summary};
use kanele::{data, engine, lut, rl, sim};

/// The PR-3 serving plane, frozen as the A/B baseline: ONE bounded
/// admission channel drained by ONE dispatcher thread, a bounded work
/// channel (depth = workers) behind a shared `Mutex<Receiver>`, N
/// executors on the compiled engine. Mirrors `rust/src/coordinator` as of
/// PR 3 so future serving-plane changes keep an honest comparison point;
/// the same heavy-tail instrumentation (every Nth executed batch sleeps)
/// is reproduced so both topologies run the identical synthetic load.
mod baseline {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
    use std::sync::{Arc, Mutex};
    use std::time::{Duration, Instant};

    use kanele::coordinator::batcher::{collect, Batch, Policy, Timestamped};
    use kanele::engine;
    use kanele::netlist::Netlist;

    pub struct Pending {
        codes: Vec<u32>,
        submitted: Instant,
        reply: SyncSender<Vec<i64>>,
    }

    impl Timestamped for Pending {
        fn submitted(&self) -> Instant {
            self.submitted
        }
    }

    #[derive(Clone, Copy)]
    pub struct Cfg {
        pub workers: usize,
        pub max_batch: usize,
        pub max_wait: Duration,
        pub queue_depth: usize,
        pub exec_delay: Duration,
        pub exec_delay_every: usize,
    }

    pub struct Service {
        tx: Option<SyncSender<Pending>>,
        threads: Vec<std::thread::JoinHandle<()>>,
        completed: Arc<AtomicU64>,
    }

    pub fn start(net: &Arc<Netlist>, cfg: Cfg) -> Service {
        let prog = Arc::new(engine::compile(net));
        let (tx, rx) = sync_channel::<Pending>(cfg.queue_depth);
        // handoff depth = workers, exactly the PR-3 pipeline
        let (work_tx, work_rx) = sync_channel::<Batch<Pending>>(cfg.workers);
        let work_rx = Arc::new(Mutex::new(work_rx));
        let completed = Arc::new(AtomicU64::new(0));
        let exec_seq = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::with_capacity(cfg.workers + 1);
        for _ in 0..cfg.workers {
            let work_rx = Arc::clone(&work_rx);
            let prog = Arc::clone(&prog);
            let completed = Arc::clone(&completed);
            let exec_seq = Arc::clone(&exec_seq);
            threads.push(std::thread::spawn(move || {
                let mut exec = engine::Executor::with_capacity(&prog, cfg.max_batch);
                let mut flat: Vec<i64> = Vec::new();
                loop {
                    let batch = match work_rx.lock().unwrap().recv() {
                        Ok(b) => b,
                        Err(_) => return, // dispatcher hung up, queue drained
                    };
                    let rows: Vec<&[u32]> =
                        batch.items.iter().map(|p| p.codes.as_slice()).collect();
                    exec.run_batch_into(&prog, &rows, &mut flat);
                    if !cfg.exec_delay.is_zero() {
                        let hit = cfg.exec_delay_every <= 1
                            || exec_seq.fetch_add(1, Ordering::Relaxed)
                                % cfg.exec_delay_every as u64
                                == 0;
                        if hit {
                            std::thread::sleep(cfg.exec_delay);
                        }
                    }
                    let d_out = prog.d_out();
                    completed.fetch_add(batch.items.len() as u64, Ordering::Relaxed);
                    for (i, p) in batch.items.into_iter().enumerate() {
                        let _ = p.reply.send(flat[i * d_out..(i + 1) * d_out].to_vec());
                    }
                }
            }));
        }
        let policy =
            Policy { max_batch: cfg.max_batch, max_wait: cfg.max_wait, ..Default::default() };
        threads.push(std::thread::spawn(move || {
            while let Some(batch) = collect(&rx, &policy) {
                if work_tx.send(batch).is_err() {
                    return;
                }
            }
        }));
        Service { tx: Some(tx), threads, completed }
    }

    impl Service {
        /// PR-3 `try_send` admission: `Ok(receiver)` or the codes handed
        /// back on backpressure.
        pub fn submit(&self, codes: Vec<u32>) -> Result<Receiver<Vec<i64>>, Vec<u32>> {
            let (reply, rx) = sync_channel(1);
            let p = Pending { codes, submitted: Instant::now(), reply };
            match self.tx.as_ref().unwrap().try_send(p) {
                Ok(()) => Ok(rx),
                Err(TrySendError::Full(p)) | Err(TrySendError::Disconnected(p)) => Err(p.codes),
            }
        }

        pub fn completed(&self) -> u64 {
            self.completed.load(Ordering::Relaxed)
        }

        pub fn shutdown(mut self) {
            self.tx.take();
            for t in self.threads.drain(..) {
                let _ = t.join();
            }
        }
    }
}

/// Closed-loop multi-client driver: `clients` threads split the stream,
/// each submitting with an unbounded in-flight window that drains fully on
/// backpressure; returns wall seconds for the whole stream. `submit` hands
/// the codes back on backpressure so the retry loop never clones.
fn drive<R, F>(stream: &[Vec<u32>], clients: usize, submit: F) -> f64
where
    R: Send,
    F: Fn(Vec<u32>) -> Result<std::sync::mpsc::Receiver<R>, Vec<u32>> + Sync,
{
    let submit = &submit;
    let chunk = stream.len().max(1).div_ceil(clients);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for slice in stream.chunks(chunk) {
            s.spawn(move || {
                let mut pending = Vec::with_capacity(1024);
                for codes in slice {
                    let mut codes = codes.clone();
                    loop {
                        match submit(codes) {
                            Ok(rx) => {
                                pending.push(rx);
                                break;
                            }
                            Err(back) => {
                                codes = back;
                                for rx in pending.drain(..) {
                                    let _ = rx.recv();
                                }
                            }
                        }
                    }
                }
                for rx in pending {
                    let _ = rx.recv();
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

fn main() {
    let quick = std::env::var("KANELE_BENCH_QUICK").is_ok();
    println!("=== serving bench: backends, coordinator grid, sharded A/B ===");
    let ck = common::checkpoint_or_synthetic("jsc_openml");
    let tables = lut::from_checkpoint(&ck);
    let net = Arc::new(Netlist::build(&ck, &tables, 2));
    let n_stream = if quick { 2_000 } else { 20_000 };
    let stream = data::random_code_stream(&ck, n_stream, 11);
    let mut rows: Vec<Value> = Vec::new();

    // -- 1. direct backend comparison (no threads, no batcher) -------------
    // chunked execution of the same request stream through both executors;
    // the acceptance bar is >= 2x at batch 64
    let prog = engine::compile(&net);
    println!(
        "netlist {}: {} L-LUTs -> {} fused ops, {} packed table words",
        ck.name,
        net.n_luts(),
        prog.n_ops(),
        prog.table_words()
    );
    let direct_batches: &[usize] = if quick { &[64] } else { &[1, 16, 64, 256] };
    for &batch in direct_batches {
        let r_interp = common::bench(&format!("interpreted eval_batch (batch {batch})"), || {
            for chunk in stream.chunks(batch) {
                std::hint::black_box(sim::eval_batch(&net, chunk));
            }
        });
        // reused flat output plane — exactly what the coordinator's
        // executor workers run
        let mut exec = engine::Executor::with_capacity(&prog, batch);
        let mut flat: Vec<i64> = Vec::new();
        let r_comp = common::bench(&format!("compiled run_batch_into (batch {batch})"), || {
            for chunk in stream.chunks(batch) {
                exec.run_batch_into(&prog, chunk, &mut flat);
                std::hint::black_box(&flat);
            }
        });
        common::report_throughput(&r_comp, stream.len());
        let samples_per_s = stream.len() as f64 / (r_comp.median_ns / 1e9);
        let speedup = r_interp.median_ns / r_comp.median_ns;
        println!(
            "      batch {batch:>3}: compiled is {speedup:.2}x interpreted | {:.3e} fused ops/s ({samples_per_s:.0} samples/s)",
            samples_per_s * prog.n_ops() as f64,
        );
        rows.push(obj(vec![
            ("section", "direct".into()),
            ("batch", (batch as i64).into()),
            ("interpreted_ns", r_interp.median_ns.into()),
            ("compiled_ns", r_comp.median_ns.into()),
            ("speedup", speedup.into()),
        ]));
    }

    // -- 2. end-to-end coordinator grid (single shard: worker scaling) ------
    // backend x batching-policy x workers through the dispatcher/executor
    // plane; workers is the innermost loop so each row reports its
    // throughput scaling against the same config at workers = 1
    let grid_policies: &[(usize, u64)] =
        if quick { &[(64, 100)] } else { &[(1, 0), (16, 50), (64, 100), (256, 200)] };
    let grid_workers: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    for backend in [Backend::Interpreted, Backend::Compiled] {
        for &(batch, wait_us) in grid_policies {
            let mut base_rps = None;
            for &workers in grid_workers {
                let svc = Service::start(
                    Arc::clone(&net),
                    ServiceCfg {
                        workers,
                        max_batch: batch,
                        max_wait: Duration::from_micros(wait_us),
                        queue_depth: 1 << 14,
                        backend,
                        ..Default::default()
                    },
                );
                let wall = drive(&stream, 1, |codes| {
                    svc.try_submit(codes).map_err(|(e, back)| match e {
                        SubmitError::Backpressure => back.expect("codes back"),
                        e => panic!("serving bench submit failed: {e}"),
                    })
                });
                let rps = stream.len() as f64 / wall;
                let scaling = rps / *base_rps.get_or_insert(rps);
                let st = svc.stats();
                println!(
                    "{:<11} batch {batch:>3} wait {wait_us:>3} us workers {workers} -> {rps:>9.0} req/s ({scaling:>4.2}x vs 1 worker) | {:.3e} ops/s | p50 {:>7.1} us p99 {:>8.1} us | mean batch {:>6.1} ({} batches)",
                    format!("{backend:?}"),
                    st.throughput_ops,
                    st.latency_p50_us,
                    st.latency_p99_us,
                    st.mean_batch,
                    st.batches
                );
                rows.push(obj(vec![
                    ("section", "grid".into()),
                    ("backend", format!("{backend:?}").as_str().into()),
                    ("batch", (batch as i64).into()),
                    ("wait_us", (wait_us as i64).into()),
                    ("workers", (workers as i64).into()),
                    ("rps", rps.into()),
                    ("scaling_vs_1_worker", scaling.into()),
                    ("p50_us", st.latency_p50_us.into()),
                    ("p99_us", st.latency_p99_us.into()),
                    ("mean_batch", st.mean_batch.into()),
                ]));
                svc.shutdown();
            }
        }
    }

    // -- 3. shards x workers grid (compiled, stealing on) -------------------
    // the tentpole's scaling surface: multiple admission shards feeding the
    // work-stealing executor pool, multi-client closed loop
    let shard_grid: &[(usize, usize)] = if quick {
        &[(1, 2), (2, 2)]
    } else {
        &[(1, 2), (2, 2), (1, 4), (2, 4), (4, 4)]
    };
    for &(shards, workers) in shard_grid {
        let svc = Service::start(
            Arc::clone(&net),
            ServiceCfg {
                workers,
                shards,
                steal: true,
                max_batch: 64,
                max_wait: Duration::from_micros(100),
                queue_depth: 1 << 14,
                ..Default::default()
            },
        );
        let clients = 2 * workers;
        let wall = drive(&stream, clients, |codes| {
            svc.try_submit(codes).map_err(|(e, back)| match e {
                SubmitError::Backpressure => back.expect("codes back"),
                e => panic!("serving bench submit failed: {e}"),
            })
        });
        let rps = stream.len() as f64 / wall;
        let st = svc.stats();
        println!(
            "shards {shards} x workers {workers} ({clients} clients) -> {rps:>9.0} req/s | {:.3e} ops/s | {} local pops, {} steals | mean batch {:.1}",
            st.throughput_ops, st.local_pops, st.steals, st.mean_batch
        );
        rows.push(obj(vec![
            ("section", "shard_grid".into()),
            ("shards", (shards as i64).into()),
            ("workers", (workers as i64).into()),
            ("clients", (clients as i64).into()),
            ("rps", rps.into()),
            ("local_pops", (st.local_pops as i64).into()),
            ("steals", (st.steals as i64).into()),
            ("mean_batch", st.mean_batch.into()),
        ]));
        svc.shutdown();
    }

    // -- 4. A/B gate: sharded/stealing plane vs frozen PR-3 baseline --------
    // heavy-tailed synthetic load: every TAIL_EVERY-th executed batch is
    // stretched by TAIL_US, on both topologies, same stream, same clients.
    // Acceptance: >= 1.3x with 4+ executors; shards=1+steal=off ~ 1.0x.
    let workers = 4usize;
    let shards = if quick { 2 } else { 4 };
    let clients = 8usize;
    let (max_batch, max_wait) = (16usize, Duration::from_micros(50));
    let tail_us: u64 = std::env::var("KANELE_BENCH_TAIL_US")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    let tail_every: usize = std::env::var("KANELE_BENCH_TAIL_EVERY")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let exec_delay = Duration::from_micros(tail_us);
    println!(
        "-- sharded plane vs frozen PR-3 baseline: {workers} executors, {clients} clients, tail {tail_us} us every {tail_every} batches --"
    );

    // bit-exact gate before any timing: both topologies vs sim::eval
    {
        let probe = &stream[..stream.len().min(128)];
        let oracle = sim::eval_batch(&net, probe);
        let base = baseline::start(
            &net,
            baseline::Cfg {
                workers,
                max_batch,
                max_wait,
                queue_depth: 1 << 14,
                exec_delay: Duration::ZERO,
                exec_delay_every: 0,
            },
        );
        let rxs: Vec<_> = probe.iter().map(|c| base.submit(c.clone()).expect("probe")).collect();
        for (rx, want) in rxs.into_iter().zip(&oracle) {
            assert_eq!(&rx.recv().unwrap(), want, "baseline diverges from sim");
        }
        base.shutdown();
        let svc = Service::start(
            Arc::clone(&net),
            ServiceCfg { workers, shards, steal: true, max_batch, max_wait, ..Default::default() },
        );
        let rxs: Vec<_> = probe
            .iter()
            .enumerate()
            .map(|(i, c)| svc.submit_to(i % shards, c.clone()).expect("probe"))
            .collect();
        for (rx, want) in rxs.into_iter().zip(&oracle) {
            assert_eq!(&rx.recv().unwrap().unwrap().sums, want, "sharded plane diverges from sim");
        }
        svc.shutdown();
        println!("   bit-exactness gate: baseline == sharded == sim on {} probes", probe.len());
    }

    let reps = if quick { 1 } else { 2 };
    let run_baseline = || {
        let svc = baseline::start(
            &net,
            baseline::Cfg {
                workers,
                max_batch,
                max_wait,
                queue_depth: 1 << 14,
                exec_delay,
                exec_delay_every: tail_every,
            },
        );
        let wall = drive(&stream, clients, |codes| svc.submit(codes));
        assert_eq!(svc.completed(), stream.len() as u64);
        svc.shutdown();
        stream.len() as f64 / wall
    };
    let run_sharded = |shards: usize, steal: bool| {
        let svc = Service::start(
            Arc::clone(&net),
            ServiceCfg {
                workers,
                shards,
                steal,
                max_batch,
                max_wait,
                queue_depth: 1 << 14,
                exec_delay,
                exec_delay_every: tail_every,
                ..Default::default()
            },
        );
        let wall = drive(&stream, clients, |codes| {
            svc.try_submit(codes).map_err(|(e, back)| match e {
                SubmitError::Backpressure => back.expect("codes back"),
                e => panic!("serving bench submit failed: {e}"),
            })
        });
        let st = svc.stats();
        assert_eq!(st.completed, stream.len() as u64);
        svc.shutdown();
        (stream.len() as f64 / wall, st.steals)
    };
    // best-of-reps: single full-stream passes are noisy on shared runners
    let rps_base = (0..reps).map(|_| run_baseline()).fold(f64::MIN, f64::max);
    let (mut rps_sharded, mut steals_sharded) = (f64::MIN, 0);
    for _ in 0..reps {
        let (r, s) = run_sharded(shards, true);
        if r > rps_sharded {
            (rps_sharded, steals_sharded) = (r, s);
        }
    }
    let (rps_nosteal, _) = run_sharded(shards, false);
    let (rps_eq, _) = run_sharded(1, false);
    let ratio = rps_sharded / rps_base;
    let ratio_nosteal = rps_nosteal / rps_base;
    let ratio_eq = rps_eq / rps_base;
    println!("   frozen PR-3 baseline        : {rps_base:>9.0} req/s (1.00x)");
    println!(
        "   shards={shards} steal=on  ({steals_sharded:>5} steals): {rps_sharded:>9.0} req/s ({ratio:.2}x) {}",
        if ratio >= 1.3 { "PASS >= 1.3x" } else { "MISS < 1.3x (record + investigate)" }
    );
    println!("   shards={shards} steal=off            : {rps_nosteal:>9.0} req/s ({ratio_nosteal:.2}x)");
    println!("   shards=1 steal=off (equivalence) : {rps_eq:>9.0} req/s ({ratio_eq:.2}x, expect ~1.0x)");
    rows.push(obj(vec![
        ("section", "heavy_tail_ab".into()),
        ("workers", (workers as i64).into()),
        ("clients", (clients as i64).into()),
        ("tail_us", (tail_us as i64).into()),
        ("tail_every", (tail_every as i64).into()),
        ("baseline_rps", rps_base.into()),
        ("sharded_shards", (shards as i64).into()),
        ("sharded_rps", rps_sharded.into()),
        ("sharded_steals", (steals_sharded as i64).into()),
        ("ratio_vs_baseline", ratio.into()),
        ("nosteal_rps", rps_nosteal.into()),
        ("nosteal_ratio", ratio_nosteal.into()),
        ("equivalence_rps", rps_eq.into()),
        ("equivalence_ratio", ratio_eq.into()),
        ("gate_1_3x", (ratio >= 1.3).into()),
    ]));

    // -- 5. the wire: loopback TCP front end + over-the-wire control loop ---
    // same serving plane, now behind `net::NetServer` on 127.0.0.1. The
    // bit-exact gate runs first (wire responses vs sim::eval), then loadgen
    // sweeps closed-loop wire throughput/latency, then a CheetahLite control
    // loop runs its policy remotely with a per-step deadline.
    println!("-- wire loopback: framed TCP front end over the sharded plane --");
    {
        let svc = Arc::new(Service::start(
            Arc::clone(&net),
            ServiceCfg {
                workers: 2,
                shards: 2,
                steal: true,
                max_batch: 64,
                max_wait: Duration::from_micros(100),
                queue_depth: 1 << 14,
                ..Default::default()
            },
        ));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let levels = ck.quantizer(0).levels();
        let mut server = NetServer::start(
            Arc::clone(&svc),
            listener,
            NetCfg { levels, ..NetCfg::default() },
        )
        .expect("start wire server");
        let addr = server.local_addr().to_string();

        // bit-exact gate before any timing: wire == sim on a probe slice
        {
            let probe = &stream[..stream.len().min(128)];
            let oracle = sim::eval_batch(&net, probe);
            let mut client = Client::connect(&addr).expect("connect probe client");
            for (codes, want) in probe.iter().zip(&oracle) {
                let (sums, _) = client.infer(codes.clone()).expect("probe infer");
                assert_eq!(&sums, want, "wire response diverges from sim");
            }
            let rows = client
                .infer_batch(probe.to_vec())
                .expect("probe infer_batch");
            assert_eq!(rows, oracle, "wire batch diverges from sim");
            println!("   bit-exactness gate: wire == sim on {} probes (+1 batch frame)", probe.len());
        }

        let wire_requests: u64 = if quick { 1_000 } else { 10_000 };
        let wire_cfgs: &[(usize, u64, usize)] =
            if quick { &[(2, 0, 0)] } else { &[(1, 0, 0), (4, 0, 0), (4, 8, 32)] };
        for &(conns, tail_every, tail_batch) in wire_cfgs {
            let r = net::loadgen(
                &addr,
                LoadGenCfg {
                    connections: conns,
                    requests: wire_requests,
                    rate_rps: 0.0,
                    tail_every,
                    tail_batch,
                    seed: 13,
                    ..Default::default()
                },
            )
            .expect("loadgen");
            assert!(r.completed > 0, "wire loadgen completed nothing");
            assert_eq!(r.errors, 0, "wire loadgen hit terminal errors");
            println!(
                "   {conns} conns (tail every {tail_every} -> {tail_batch}): {:>8.0} samples/s | wire p50/p90/p99 {:>7.1} / {:>7.1} / {:>8.1} us | {} bp retries",
                r.rps, r.p50_us, r.p90_us, r.p99_us, r.backpressure_retries
            );
            rows.push(obj(vec![
                ("section", "wire_loopback".into()),
                ("connections", (conns as i64).into()),
                ("requests", (wire_requests as i64).into()),
                ("tail_every", (tail_every as i64).into()),
                ("tail_batch", (tail_batch as i64).into()),
                ("completed", (r.completed as i64).into()),
                ("rps", r.rps.into()),
                ("p50_us", r.p50_us.into()),
                ("p90_us", r.p90_us.into()),
                ("p99_us", r.p99_us.into()),
                ("backpressure_retries", (r.backpressure_retries as i64).into()),
            ]));
        }
        server.shutdown();
        svc.shutdown();
    }

    // CheetahLite with its policy net served over TCP: encode observations
    // locally, evaluate remotely, decode actions — the §5.7 control loop
    // with the network in the loop, under a per-step latency deadline
    {
        let pol_ck = testutil::synthetic(&[rl::OBS_DIM, 10, rl::ACT_DIM], &[6, 6, 6], 0xCA7);
        let pol_tables = lut::from_checkpoint(&pol_ck);
        let pol_net = Arc::new(Netlist::build(&pol_ck, &pol_tables, 2));
        let svc = Arc::new(Service::start(
            Arc::clone(&pol_net),
            ServiceCfg {
                workers: 2,
                // a control loop is one client: single shard, tiny batch
                // window so each step flushes immediately
                shards: 1,
                max_batch: 1,
                max_wait: Duration::from_micros(0),
                queue_depth: 256,
                ..Default::default()
            },
        ));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let mut server = NetServer::start(
            Arc::clone(&svc),
            listener,
            NetCfg { levels: pol_ck.quantizer(0).levels(), ..NetCfg::default() },
        )
        .expect("start policy server");
        let mut client = Client::connect(server.local_addr()).expect("connect control loop");

        let local = rl::NetlistPolicy { ck: &pol_ck, net: &pol_net };
        let steps = if quick { 200 } else { 1_000 };
        let deadline_us = 2_000.0;
        let mut env = rl::CheetahLite::new(5);
        let mut obs = env.reset();
        let mut lat = Summary::new();
        let mut hits = 0usize;
        let mut reward = 0.0;
        for step in 0..steps {
            let t0 = Instant::now();
            let codes = rl::encode_obs(&pol_ck, &obs);
            let (sums, _) = client.infer(codes).expect("control-loop infer");
            let act = rl::decode_action(&pol_ck, &sums);
            let us = t0.elapsed().as_secs_f64() * 1e6;
            lat.push(us);
            if us <= deadline_us {
                hits += 1;
            }
            // the wire policy must be the local policy, bit for bit
            if step < 32 {
                assert_eq!(act, local.act(&obs), "wire policy diverges from local");
            }
            let (o, r, done) = env.step(&act);
            obs = o;
            reward += r;
            if done {
                obs = env.reset();
            }
        }
        let hit_rate = hits as f64 / steps as f64;
        println!(
            "   cheetah over the wire: {steps} steps, deadline {deadline_us:.0} us -> {:.1}% hit | step p50/p99 {:.1} / {:.1} us | reward {reward:.1}",
            100.0 * hit_rate,
            lat.quantile(0.5),
            lat.quantile(0.99)
        );
        rows.push(obj(vec![
            ("section", "wire_control_loop".into()),
            ("steps", (steps as i64).into()),
            ("deadline_us", deadline_us.into()),
            ("hit_rate", hit_rate.into()),
            ("p50_us", lat.quantile(0.5).into()),
            ("p99_us", lat.quantile(0.99).into()),
            ("reward", reward.into()),
        ]));
        drop(client);
        server.shutdown();
        svc.shutdown();
    }

    // -- 6. multi-tenant registry: arena sharing, Zipf routing, fairness, canary
    // N fine-tuned variants of the same checkpoint behind one registry:
    // tenant t0 is the base netlist, every other tenant differs by one
    // hot-swapped edge table, so cross-tenant interning shares all but that
    // table. Gates: per-tenant bit-exactness vs sim (hard), interned arena
    // strictly smaller than N flat arenas (hard), exact deterministic
    // canary counts (hard), and the DRR fairness bar — light-tenant p99
    // under a saturating heavy neighbor <= 1.5x its isolated p99
    // (report-only PASS/MISS; recorded in the JSON either way).
    println!("-- multi-tenant registry: shared arena, Zipf routing, DRR fairness, canary --");
    {
        let n_tenants = if quick { 8 } else { 24 };
        let variant_cell = |i: usize| -> Arc<NetlistCell> {
            let cell = Arc::new(NetlistCell::new(Arc::clone(&net)));
            if i > 0 {
                let p = net.layers[0].neurons[0].luts[0].input;
                let n_codes = 1usize << net.layers[0].in_bits;
                cell.swap_edge(0, 0, p, vec![i as i64 * 17 + 1; n_codes]).expect("variant swap");
            }
            cell
        };
        let reg = Arc::new(ModelRegistry::new(engine::OptLevel::default()));
        let mut tenant_nets: Vec<Arc<Netlist>> = Vec::with_capacity(n_tenants);
        let mut ids: Vec<ModelId> = Vec::with_capacity(n_tenants);
        for i in 0..n_tenants {
            let cell = variant_cell(i);
            tenant_nets.push(cell.load());
            ids.push(reg.load_cell(&format!("t{i}"), cell, 0).expect("load tenant"));
        }

        // arena gate: the interned arena must be strictly smaller than N
        // independently materialized ones, with real cross-tenant sharing
        let arena = reg.reintern();
        assert!(
            arena.bytes_interned < arena.bytes_flat,
            "interned arena ({} B) not smaller than flat ({} B)",
            arena.bytes_interned,
            arena.bytes_flat
        );
        assert!(arena.bytes_shared > 0, "no cross-tenant table sharing");
        println!(
            "   arena: {} programs, {} unique tables | {} B interned ({} B shared) vs {} B flat ({:.1}x smaller)",
            arena.programs,
            arena.unique_tables,
            arena.bytes_interned,
            arena.bytes_shared,
            arena.bytes_flat,
            arena.bytes_flat as f64 / arena.bytes_interned.max(1) as f64
        );
        rows.push(obj(vec![
            ("section", "multi_tenant".into()),
            ("kind", "arena".into()),
            ("tenants", (n_tenants as i64).into()),
            ("programs", (arena.programs as i64).into()),
            ("unique_tables", (arena.unique_tables as i64).into()),
            ("bytes_flat", (arena.bytes_flat as i64).into()),
            ("bytes_interned", (arena.bytes_interned as i64).into()),
            ("bytes_shared", (arena.bytes_shared as i64).into()),
            ("gate_interned_lt_flat", true.into()),
        ]));

        let svc = Arc::new(Service::start_registry(
            Arc::clone(&reg),
            ServiceCfg {
                workers: 4,
                shards: 2,
                steal: true,
                max_batch: 32,
                max_wait: Duration::from_micros(100),
                queue_depth: 1 << 14,
                ..Default::default()
            },
        ));

        // bit-exact gate before any timing: every tenant vs its own sim
        let n_probes = 4usize;
        for (i, tnet) in tenant_nets.iter().enumerate() {
            for codes in stream.iter().take(n_probes) {
                let got = svc.submit_blocking_model(ids[i], codes.clone()).expect("probe");
                assert_eq!(got.sums, sim::eval(tnet, codes), "tenant t{i} diverges from sim");
            }
        }
        println!("   bit-exactness gate: {n_tenants} tenants x {n_probes} probes == per-tenant sim");

        // Zipf-skewed closed loop: tenant i draws with weight ~ 1/(i+1)
        let zipf_requests: usize = if quick { 4_000 } else { 40_000 };
        let weights: Vec<u64> =
            (0..n_tenants).map(|i| (1_000.0 / (i + 1) as f64).ceil() as u64).collect();
        let total_w: u64 = weights.iter().sum();
        let mut rng = Rng::new(0x21BF);
        let picks: Vec<usize> = (0..zipf_requests)
            .map(|_| {
                let mut x = rng.below(total_w);
                let mut t = 0usize;
                for (i, w) in weights.iter().enumerate() {
                    if x < *w {
                        t = i;
                        break;
                    }
                    x -= *w;
                }
                t
            })
            .collect();
        let clients = 8usize;
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for (c, chunk) in picks.chunks(zipf_requests.div_ceil(clients)).enumerate() {
                let svc = &svc;
                let ids = &ids;
                let stream = &stream;
                s.spawn(move || {
                    let mut pending = Vec::with_capacity(1024);
                    for (k, &t) in chunk.iter().enumerate() {
                        let mut codes = stream[(c * 31 + k) % stream.len()].clone();
                        loop {
                            match svc.try_submit_model(ids[t], codes) {
                                Ok(rx) => {
                                    pending.push(rx);
                                    break;
                                }
                                Err((SubmitError::Backpressure, back)) => {
                                    codes = back.expect("codes back");
                                    for rx in pending.drain(..) {
                                        let _ = rx.recv();
                                    }
                                }
                                Err((e, _)) => panic!("zipf submit failed: {e}"),
                            }
                        }
                    }
                    for rx in pending {
                        let _ = rx.recv();
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let rps = zipf_requests as f64 / wall;
        let st = svc.stats();
        assert_eq!(st.completed, (zipf_requests + n_tenants * n_probes) as u64);
        let per: u64 = st.per_tenant.iter().map(|t| t.completed).sum();
        assert_eq!(per, st.completed, "per-tenant completions do not sum to the total");
        let heavy = st.per_tenant.iter().find(|t| t.name == "t0").expect("t0 stats");
        let heavy_share = heavy.completed as f64 / st.completed as f64;
        println!(
            "   zipf {zipf_requests} reqs over {n_tenants} tenants: {rps:>9.0} req/s | t0 share {heavy_share:.2} | mean batch {:.1} ({} batches)",
            st.mean_batch, st.batches
        );
        rows.push(obj(vec![
            ("section", "multi_tenant".into()),
            ("kind", "zipf".into()),
            ("tenants", (n_tenants as i64).into()),
            ("requests", (zipf_requests as i64).into()),
            ("rps", rps.into()),
            ("heavy_share", heavy_share.into()),
            ("mean_batch", st.mean_batch.into()),
        ]));
        svc.shutdown();

        // fairness: the light tenant's p99 with a saturating heavy
        // neighbor vs alone — same plane shape, same artificial per-batch
        // execution cost, fresh service per phase so reservoirs are clean
        let fresh_pair = || {
            let reg = Arc::new(ModelRegistry::new(engine::OptLevel::default()));
            let heavy = reg.load_cell("heavy", variant_cell(1), 0).expect("heavy tenant");
            let light = reg.load_cell("light", variant_cell(2), 0).expect("light tenant");
            let svc = Arc::new(Service::start_registry(
                reg,
                ServiceCfg {
                    workers: 2,
                    shards: 1,
                    max_batch: 32,
                    max_wait: Duration::from_micros(100),
                    queue_depth: 1 << 12,
                    exec_delay: Duration::from_micros(100),
                    exec_delay_every: 0,
                    ..Default::default()
                },
            ));
            (svc, heavy, light)
        };
        let n_light = if quick { 200 } else { 1_000 };
        let light_row = stream[0].clone();
        let light_p99 = |svc: &Arc<Service>, light: ModelId| -> f64 {
            for _ in 0..n_light {
                svc.submit_blocking_model(light, light_row.clone()).expect("light request");
            }
            let st = svc.stats();
            st.per_tenant
                .iter()
                .find(|t| t.name == "light")
                .expect("light stats")
                .latency_p99_us
        };
        let (svc_a, _, light_a) = fresh_pair();
        let p99_isolated = light_p99(&svc_a, light_a);
        svc_a.shutdown();
        let (svc_b, heavy_b, light_b) = fresh_pair();
        let stop = std::sync::atomic::AtomicBool::new(false);
        let p99_contended = std::thread::scope(|s| {
            for c in 0..2usize {
                let svc = &svc_b;
                let stop = &stop;
                let row = &stream[(c + 1) % stream.len()];
                s.spawn(move || {
                    // deep async window: keeps a heavy backlog queued so
                    // DRR (not arrival order) decides batch formation
                    let mut pending = std::collections::VecDeque::with_capacity(64);
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        match svc.try_submit_model(heavy_b, row.clone()) {
                            Ok(rx) => pending.push_back(rx),
                            Err((SubmitError::Backpressure, _)) => {
                                match pending.pop_front() {
                                    Some(rx) => {
                                        let _ = rx.recv();
                                    }
                                    None => std::thread::sleep(Duration::from_micros(50)),
                                }
                            }
                            Err((SubmitError::Stopped, _)) => break,
                            Err((e, _)) => panic!("heavy submit failed: {e}"),
                        }
                        if pending.len() >= 64 {
                            if let Some(rx) = pending.pop_front() {
                                let _ = rx.recv();
                            }
                        }
                    }
                    for rx in pending {
                        let _ = rx.recv();
                    }
                });
            }
            // let the heavy backlog build before measuring
            std::thread::sleep(Duration::from_millis(20));
            let p = light_p99(&svc_b, light_b);
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            p
        });
        svc_b.shutdown();
        let fairness_ratio = p99_contended / p99_isolated.max(1e-9);
        let fairness_pass = fairness_ratio <= 1.5;
        println!(
            "   fairness: light p99 isolated {p99_isolated:>8.1} us vs contended {p99_contended:>8.1} us ({fairness_ratio:.2}x) {}",
            if fairness_pass { "PASS <= 1.5x" } else { "MISS > 1.5x (record + investigate)" }
        );
        rows.push(obj(vec![
            ("section", "multi_tenant".into()),
            ("kind", "fairness".into()),
            ("light_requests", (n_light as i64).into()),
            ("light_p99_isolated_us", p99_isolated.into()),
            ("light_p99_contended_us", p99_contended.into()),
            ("ratio", fairness_ratio.into()),
            ("gate_1_5x", fairness_pass.into()),
        ]));

        // canary: 25% of one tenant's rows shadowed by a second variant;
        // the routing counter is global and deterministic, so 400 valid
        // rows canary exactly 100 — and every response is bit-exact
        // against one of the two sims
        let reg = Arc::new(ModelRegistry::new(engine::OptLevel::default()));
        let cid = reg.load_cell("c", variant_cell(0), 0).expect("canary tenant");
        let canary_net = variant_cell(3).load();
        reg.set_canary("c", Arc::clone(&canary_net), 25).expect("set canary");
        let svc = Arc::new(Service::start_registry(
            Arc::clone(&reg),
            ServiceCfg { workers: 2, shards: 1, ..Default::default() },
        ));
        let n_rows = 400usize;
        for k in 0..n_rows {
            let codes = stream[k % stream.len()].clone();
            let got = svc.submit_blocking_model(cid, codes.clone()).expect("canary row");
            let base = sim::eval(&net, &codes);
            let shadow = sim::eval(&canary_net, &codes);
            assert!(
                got.sums == base || got.sums == shadow,
                "canary response matches neither primary nor canary sim"
            );
        }
        let ts = reg.tenant_stats();
        let ct = ts.iter().find(|t| t.name == "c").expect("canary stats");
        assert_eq!(ct.canary_rows, (n_rows / 4) as u64, "canary routing is deterministic");
        assert!(ct.canary_agree <= ct.canary_rows);
        println!(
            "   canary: {} of {n_rows} rows shadowed (exact 25%), live argmax agreement {:.3}",
            ct.canary_rows, ct.canary_agreement
        );
        rows.push(obj(vec![
            ("section", "multi_tenant".into()),
            ("kind", "canary".into()),
            ("rows", (n_rows as i64).into()),
            ("canary_rows", (ct.canary_rows as i64).into()),
            ("agreement", ct.canary_agreement.into()),
        ]));
        svc.shutdown();
    }

    // machine-readable trajectory: stdout grids rot in logs, this does not
    let doc = obj(vec![
        ("bench", "serving".into()),
        ("quick", quick.into()),
        ("model", ck.name.as_str().into()),
        ("n_requests", (stream.len() as i64).into()),
        ("rows", Value::Array(rows)),
    ]);
    std::fs::write("BENCH_serving.json", kanele::json::to_string(&doc))
        .expect("write BENCH_serving.json");
    println!("wrote BENCH_serving.json");
}
