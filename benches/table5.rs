//! Bench for paper Table 5 (ToyADMOS on xc7a100t): KANELE AE row
//! (throughput, latency, energy) vs the hls4ml MLPerf-Tiny baseline model,
//! plus the AUC evaluation wall time over the exported test windows.
//!
//!     cargo bench --bench table5

mod common;

use kanele::baselines::hls4ml::Hls4mlCfg;
use kanele::checkpoint::TestSet;
use kanele::netlist::Netlist;
use kanele::{config, lut, sim, synth};

fn main() {
    println!("=== Table 5 bench: MLPerf-Tiny ToyADMOS ===");
    let Some(ck) = common::try_checkpoint("toyadmos") else { return };
    let tables = lut::from_checkpoint(&ck);
    let net = Netlist::build(&ck, &tables, 2);
    let dev = synth::device_by_name("xc7a100t").unwrap();
    let r = synth::synthesize(&net, &dev);
    println!(
        "row  KANELE   LUT {:>7} FF {:>7} | II=1 {:.2e} inf/s | {:.2} us | {:.3} uJ/inf",
        r.luts,
        r.ffs,
        r.throughput_inf_s,
        r.latency_ns / 1000.0,
        r.energy_per_inf_uj
    );
    let ae = Hls4mlCfg {
        name: "hls4ml AE".into(),
        dims: vec![64, 128, 128, 128, 8, 128, 128, 128, 64],
        bits: 16,
        reuse: 16,
        resource_strategy: true,
    }
    .estimate();
    println!(
        "row  hls4ml   LUT {:>7} FF {:>7} DSP {:>4} BRAM {:>4} | II=16 | {:.2} us",
        ae.luts,
        ae.ffs,
        ae.dsps,
        ae.brams,
        ae.latency_ns / 1000.0
    );

    if let Ok(ts) = TestSet::load(&config::testset_path("toyadmos")) {
        let rb = common::bench("toyadmos: full-testset reconstruction", || {
            for codes in &ts.input_codes {
                std::hint::black_box(sim::eval(&net, codes));
            }
        });
        common::report_throughput(&rb, ts.input_codes.len());
    }
}
