//! Ablation benches (paper Fig. 6 + design choices in DESIGN.md):
//!   * n_add (adder-tree arity): latency cycles vs Fmax vs LUTs
//!   * bitwidth: LUT growth (Fig. 6d, on fig6_bits_* checkpoints)
//!   * edges vs resources (Fig. 6b, on fig6_prune_* checkpoints)
//!   * hot-path micro: lut extraction + sim eval per layer
//!
//!     cargo bench --bench ablation

mod common;

use kanele::netlist::Netlist;
use kanele::{lut, synth};

fn main() {
    println!("=== ablation bench ===");
    let Some(ck) = common::try_checkpoint("jsc_openml").or_else(|| common::try_checkpoint("moons"))
    else {
        return;
    };
    let tables = lut::from_checkpoint(&ck);
    let dev = synth::device_by_name("xcvu9p").unwrap();

    println!("-- adder-tree arity (n_add) sweep on {} --", ck.name);
    for n_add in [2usize, 3, 4, 6] {
        let net = Netlist::build(&ck, &tables, n_add);
        let r = synth::synthesize(&net, &dev);
        println!(
            "n_add {n_add}: {:>3} cycles | Fmax {:>5.0} MHz | {:>6.1} ns | {:>7} LUT | AxD {:>9.2e}",
            r.latency_cycles, r.fmax_mhz, r.latency_ns, r.luts, r.area_delay
        );
    }

    println!("-- Fig. 6d: bitwidth vs LUTs (fig6_bits_* checkpoints) --");
    for b in [3, 4, 5, 6, 7, 8] {
        if let Some(ckb) = common::try_checkpoint(&format!("fig6_bits_{b}")) {
            let t = lut::from_checkpoint(&ckb);
            let net = Netlist::build(&ckb, &t, 2);
            let r = synth::synthesize(&net, &dev);
            println!("bits {b}: LUT {:>7} FF {:>7}", r.luts, r.ffs);
        }
    }

    println!("-- Fig. 6b: edges vs resources (fig6_prune_* checkpoints) --");
    for t in ["0.0", "0.3", "0.6", "0.9", "1.4", "2.0"] {
        if let Some(ckp) = common::try_checkpoint(&format!("fig6_prune_{t}")) {
            let tb = lut::from_checkpoint(&ckp);
            let net = Netlist::build(&ckp, &tb, 2);
            let r = synth::synthesize(&net, &dev);
            println!(
                "T {t}: edges {:>4} -> LUT {:>7} FF {:>7}",
                ckp.active_edges(),
                r.luts,
                r.ffs
            );
        }
    }

    println!("-- toolflow hot-path micro --");
    common::bench("lut::extract_all", || {
        std::hint::black_box(lut::extract_all(&ck));
    });
    let net = Netlist::build(&ck, &tables, 2);
    let codes: Vec<u32> = vec![1; ck.dims[0]];
    let rb = common::bench("sim::eval x10000 (alloc per call)", || {
        for _ in 0..10_000 {
            std::hint::black_box(kanele::sim::eval(&net, &codes));
        }
    });
    common::report_throughput(&rb, 10_000);
    let rb2 = common::bench("sim::Evaluator x10000 (reused scratch)", || {
        let mut ev = kanele::sim::Evaluator::new(&net);
        for _ in 0..10_000 {
            std::hint::black_box(ev.eval(&codes));
        }
    });
    common::report_throughput(&rb2, 10_000);
}
