//! Bench for paper Table 4 (Moons / Wine / Dry Bean on xczu7ev): KANELE
//! rows vs our Tran-et-al direct-spline cost model, reproducing the §5.4
//! headline ratios (~2700x latency, ~4000x LUTs on Dry Bean).
//!
//!     cargo bench --bench table4

mod common;

use kanele::baselines::tran::TranKanCfg;
use kanele::netlist::Netlist;
use kanele::{config, lut, sim, synth};

fn main() {
    println!("=== Table 4 bench: prior KAN-FPGA comparison ===");
    for name in ["moons", "wine", "dry_bean"] {
        let Some(ck) = common::try_checkpoint(name) else { continue };
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        let dev = synth::device_by_name("xczu7ev").unwrap();
        let ours = synth::synthesize(&net, &dev);
        // Tran et al. modelled on *their* (unpruned, wide) KAN for this task
        let exp = config::experiment(name).unwrap();
        let dims: Vec<usize> = exp.dims.iter().map(|&d| d.max(2) * 4).collect();
        let tran = TranKanCfg::for_dims(name, &dims, 5, 3).estimate();
        println!(
            "row  {name:<10} ours: {:>6} LUT {:>5.1} ns | tran-model: {:>8} LUT {:>9.0} ns | speedup {:>6.0}x  lut-ratio {:>6.0}x",
            ours.luts,
            ours.latency_ns,
            tran.luts,
            tran.latency_ns,
            tran.latency_ns / ours.latency_ns,
            tran.luts as f64 / ours.luts as f64,
        );
        // single-sample latency through the cycle-accurate simulator
        let codes: Vec<u32> = vec![0; ck.dims[0]];
        let rb = common::bench(&format!("{name}: cycle-accurate single inference"), || {
            let mut cs = sim::CycleSim::new(&net);
            cs.step(Some((0, &codes)));
            loop {
                if cs.step(None).is_some() {
                    break;
                }
            }
        });
        let _ = rb;
    }
}
