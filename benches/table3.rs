//! Bench for paper Table 3 (JSC CERNBox / JSC OpenML / MNIST on xcvu9p):
//! regenerates every KANELE row (LUT/FF/Fmax/latency/AreaxDelay) and times
//! the toolflow stages (L-LUT extraction, netlist build, synthesis) plus
//! the simulated-core inference throughput for each model.
//!
//!     cargo bench --bench table3

mod common;

use kanele::netlist::Netlist;
use kanele::{data, lut, sim, synth};

fn main() {
    println!("=== Table 3 bench: LUT-NN comparison datasets ===");
    for name in ["jsc_cernbox", "jsc_openml", "mnist"] {
        let Some(ck) = common::try_checkpoint(name) else { continue };
        // toolflow timing
        let r_extract = common::bench(&format!("{name}: L-LUT extraction"), || {
            std::hint::black_box(lut::extract_all(&ck));
        });
        let tables = lut::from_checkpoint(&ck);
        common::bench(&format!("{name}: netlist build"), || {
            std::hint::black_box(Netlist::build(&ck, &tables, 2));
        });
        let net = Netlist::build(&ck, &tables, 2);
        let dev = synth::device_by_name("xcvu9p").unwrap();
        common::bench(&format!("{name}: synthesis estimate"), || {
            std::hint::black_box(synth::synthesize(&net, &dev));
        });
        // the row itself
        let r = synth::synthesize(&net, &dev);
        println!(
            "row  {name:<14} LUT {:>7} FF {:>7} Fmax {:>5.0} MHz lat {:>6.1} ns AxD {:>9.2e}",
            r.luts, r.ffs, r.fmax_mhz, r.latency_ns, r.area_delay
        );
        let _ = r_extract;
        // simulated-core inference throughput (functional hot path)
        let stream = data::random_code_stream(&ck, 1024, 5);
        let rb = common::bench(&format!("{name}: sim eval x1024"), || {
            for codes in &stream {
                std::hint::black_box(sim::eval(&net, codes));
            }
        });
        common::report_throughput(&rb, 1024);
    }
}
