//! Engine hot-path bench: the feature-major, integer-requant, narrowed-arena
//! executor against the PR-2 compiled executor (sample-major planes, one i64
//! arena, f64 requant), which is frozen below as `mod baseline` so the A/B
//! stays honest across future refactors. Also microbenches the requant plan
//! against the float oracle, the flat-output path against the
//! `Vec<Vec<i64>>` convenience, (section 4) the optimizing pass pipeline
//! (constant folding, dead-input elimination, table hash-consing, CSE)
//! against the 1:1 `OptLevel::None` lowering on a pruned synthetic net,
//! (section 5) the CHUNK-wide lane kernels against the frozen PR-3 scalar
//! reference (bit-exact gate on tail shapes first, `gate_1_3x` at batch
//! 64), (section 6) intra-batch data-parallelism: one large batch
//! sliced across 4 executors vs 1 (`gate_2x`), with the sub-threshold
//! unsliced path proven on the same config, and (section 7) error-budgeted
//! lossy compilation: `OptLevel::Lossy(16)` against `Full` on a nearified
//! jet twin — argmax agreement >= 0.99 and measured-delta-within-bound are
//! hard gates asserted BEFORE timing, and the nearified pruned net must
//! give up >= 25% arena bytes vs Full (`lossy_agreement` /
//! `lossy_byte_reduction` land as headline fields in BENCH_engine.json).
//!
//!     cargo bench --bench engine
//!     KANELE_BENCH_QUICK=1 cargo bench --bench engine    # CI smoke mode
//!
//! Acceptance bars: transposed integer executor >= 1.5x baseline at batch 64
//! on the jet-tagging twin (ISSUE 3); on the pruned synthetic net the
//! optimizer must report >= 25% fused-op and >= 30% table-byte reduction
//! (ISSUE 5, asserted below — the `opt_*` fields land in BENCH_engine.json).
//! Bit-exactness vs `sim::eval_batch` is asserted here before any timing
//! (and enforced by the crate's tests), for both OptLevels.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use kanele::coordinator::{Service, ServiceCfg, GRAIN_OFF};
use kanele::engine::exec::scalar_ref::ScalarExecutor;
use kanele::engine::{self, OptLevel, RequantPlan};
use kanele::fixed::Quantizer;
use kanele::json::{obj, Value};
use kanele::netlist::Netlist;
use kanele::{data, lut, sim};

/// The PR-2 compiled executor, reproduced verbatim as the A/B baseline:
/// batch-major (sample-major) scratch planes indexed `[s * width + f]`, a
/// single packed i64 table arena, and the float `encode(from_fixed(..))`
/// requant on every inter-layer flip.
mod baseline {
    use kanele::fixed::{from_fixed, Quantizer};
    use kanele::netlist::Netlist;
    use std::ops::Range;

    pub struct Op {
        pub table_off: u32,
        pub addr_mask: u32,
        pub input: u32,
        pub neuron: u32,
    }

    pub struct Layer {
        pub d_in: usize,
        pub d_out: usize,
        pub ops: Range<usize>,
        pub bias_off: usize,
        pub requant: Option<Quantizer>,
    }

    pub struct Program {
        pub frac_bits: u32,
        pub tables: Vec<i64>,
        pub ops: Vec<Op>,
        pub biases: Vec<i64>,
        pub layers: Vec<Layer>,
        pub d_in: usize,
        pub max_width: usize,
    }

    pub fn compile(net: &Netlist) -> Program {
        let mut tables = Vec::new();
        let mut ops = Vec::new();
        let mut biases = Vec::new();
        let mut layers = Vec::with_capacity(net.layers.len());
        let mut max_width = 1usize;
        for layer in &net.layers {
            let ops_start = ops.len();
            let bias_off = biases.len();
            for (q, neuron) in layer.neurons.iter().enumerate() {
                biases.push(neuron.bias);
                for lut in &neuron.luts {
                    let off = tables.len();
                    tables.extend_from_slice(&lut.table);
                    ops.push(Op {
                        table_off: off as u32,
                        addr_mask: (lut.table.len() - 1) as u32,
                        input: lut.input as u32,
                        neuron: q as u32,
                    });
                }
            }
            max_width = max_width.max(layer.d_in).max(layer.d_out);
            layers.push(Layer {
                d_in: layer.d_in,
                d_out: layer.d_out,
                ops: ops_start..ops.len(),
                bias_off,
                requant: layer.requant,
            });
        }
        Program {
            frac_bits: net.frac_bits,
            tables,
            ops,
            biases,
            d_in: net.input_width(),
            max_width,
            layers,
        }
    }

    #[derive(Default)]
    pub struct Executor {
        codes: Vec<u32>,
        sums: Vec<i64>,
    }

    impl Executor {
        pub fn with_capacity(prog: &Program, batch: usize) -> Executor {
            Executor {
                codes: Vec::with_capacity(batch * prog.max_width),
                sums: Vec::with_capacity(batch * prog.max_width),
            }
        }

        pub fn run_batch<S: AsRef<[u32]>>(&mut self, prog: &Program, batch: &[S]) -> Vec<Vec<i64>> {
            let n = batch.len();
            if n == 0 || prog.layers.is_empty() {
                return vec![Vec::new(); n];
            }
            let d0 = prog.d_in;
            self.codes.clear();
            self.codes.reserve(n * prog.max_width);
            for row in batch {
                let row = row.as_ref();
                assert_eq!(row.len(), d0, "batch row width != program d_in");
                self.codes.extend_from_slice(row);
            }
            for plan in &prog.layers {
                let (d_in, d_out) = (plan.d_in, plan.d_out);
                let biases = &prog.biases[plan.bias_off..plan.bias_off + d_out];
                self.sums.clear();
                self.sums.reserve(n * prog.max_width);
                for _ in 0..n {
                    self.sums.extend_from_slice(biases);
                }
                let codes = &self.codes[..n * d_in];
                let sums = &mut self.sums[..n * d_out];
                for op in &prog.ops[plan.ops.clone()] {
                    let off = op.table_off as usize;
                    let mask = op.addr_mask as usize;
                    let table = &prog.tables[off..off + mask + 1];
                    let (input, neuron) = (op.input as usize, op.neuron as usize);
                    for s in 0..n {
                        let addr = codes[s * d_in + input] as usize & mask;
                        sums[s * d_out + neuron] += table[addr];
                    }
                }
                if let Some(q) = &plan.requant {
                    self.codes.clear();
                    for &sum in self.sums[..n * d_out].iter() {
                        self.codes.push(q.encode(from_fixed(sum, prog.frac_bits)));
                    }
                }
            }
            let d_out = prog.layers.last().unwrap().d_out;
            (0..n)
                .map(|s| self.sums[s * d_out..(s + 1) * d_out].to_vec())
                .collect()
        }
    }
}

/// Synthetic checkpoint rewritten by the shared
/// `checkpoint::testutil::prunify` the way KANELE's prune-aware training
/// leaves real ones: 40% of active edges collapse to constant tables and
/// ~30% duplicate the first surviving table of their input column (same
/// input + same content, so hash-consing AND CSE fire). Same construction
/// — and the same >= 30% constant / >= 20% duplicate regime — as the
/// optimizer's `pruned_synthetic_hits_the_reduction_bars` unit test, so
/// the acceptance bars are stated against one pruning scheme.
fn pruned_synthetic() -> kanele::checkpoint::Checkpoint {
    let mut ck =
        kanele::checkpoint::testutil::synthetic(&[32, 16, 16, 5], &[6, 5, 5, 6], 0xB0A5);
    kanele::checkpoint::testutil::prunify(&mut ck, 40, 30, 7);
    ck.name = "pruned-synthetic".into();
    ck
}

fn main() {
    let quick = std::env::var("KANELE_BENCH_QUICK").is_ok();
    println!("=== engine bench: feature-major integer hot path vs PR-2 baseline ===");
    let ck = common::checkpoint_or_synthetic("jsc_openml");
    let tables = lut::from_checkpoint(&ck);
    let net = Netlist::build(&ck, &tables, 2);
    // OptLevel::None here keeps the PR-3 executor A/B honest: sections 1-3
    // measure the feature-major integer executor against the PR-2 baseline
    // on the SAME 1:1 op stream; section 4 below isolates the optimizer
    let prog = engine::compile_with(&net, OptLevel::None);
    let base_prog = baseline::compile(&net);
    println!(
        "netlist {}: {} fused ops, {} table words ({} B narrowed vs {} B all-i64)",
        ck.name,
        prog.n_ops(),
        prog.table_words(),
        prog.table_bytes(),
        prog.table_words() * std::mem::size_of::<i64>()
    );
    for (l, plan) in prog.layers().iter().enumerate() {
        println!(
            "  layer {l}: {}x{} lane {:?}, requant {}",
            plan.d_in,
            plan.d_out,
            plan.lane,
            plan.requant.as_ref().map(|r| r.kind_name()).unwrap_or("none")
        );
    }

    let n_stream = if quick { 2_000 } else { 20_000 };
    let stream = data::random_code_stream(&ck, n_stream, 11);

    // bit-exactness gate before timing anything: engine == baseline == sim
    let probe = &stream[..stream.len().min(256)];
    let oracle = sim::eval_batch(&net, probe);
    assert_eq!(engine::run_batch(&prog, probe), oracle, "engine diverges from sim");
    {
        let mut bex = baseline::Executor::with_capacity(&base_prog, probe.len());
        assert_eq!(bex.run_batch(&base_prog, probe), oracle, "baseline diverges from sim");
    }

    let mut rows: Vec<Value> = Vec::new();

    // -- 1. executor A/B across batch sizes ---------------------------------
    println!("-- transposed integer executor vs PR-2 sample-major baseline --");
    for batch in [1usize, 16, 64, 256] {
        let mut bex = baseline::Executor::with_capacity(&base_prog, batch);
        let r_base = common::bench(&format!("baseline sample-major f64 (batch {batch})"), || {
            for chunk in stream.chunks(batch) {
                std::hint::black_box(bex.run_batch(&base_prog, chunk));
            }
        });
        let mut ex = engine::Executor::with_capacity(&prog, batch);
        let mut flat: Vec<i64> = Vec::new();
        let r_new = common::bench(&format!("feature-major int into-flat (batch {batch})"), || {
            for chunk in stream.chunks(batch) {
                ex.run_batch_into(&prog, chunk, &mut flat);
                std::hint::black_box(&flat);
            }
        });
        common::report_throughput(&r_new, stream.len());
        let samples_per_s = stream.len() as f64 / (r_new.median_ns / 1e9);
        println!(
            "      batch {batch:>3}: transposed integer engine is {:.2}x baseline | {:.3e} fused ops/s ({:.0} samples/s) | scratch {} B",
            r_base.median_ns / r_new.median_ns,
            samples_per_s * prog.n_ops() as f64,
            samples_per_s,
            ex.scratch_bytes()
        );
        rows.push(obj(vec![
            ("section", "executor_ab".into()),
            ("batch", (batch as i64).into()),
            ("baseline_ns", r_base.median_ns.into()),
            ("new_ns", r_new.median_ns.into()),
            ("speedup", (r_base.median_ns / r_new.median_ns).into()),
            ("fused_ops_per_s", (samples_per_s * prog.n_ops() as f64).into()),
            ("scratch_bytes", (ex.scratch_bytes() as i64).into()),
        ]));
    }

    // -- 2. requant plan vs float oracle ------------------------------------
    println!("-- integer requant plan vs float encode(from_fixed(..)) oracle --");
    let q = Quantizer::new(6, ck.domain.0, ck.domain.1);
    let plan = RequantPlan::build(q, ck.frac_bits);
    println!("  plan lowering: {} (bits {})", plan.kind_name(), q.bits);
    let sums: Vec<i64> = (0..65_536i64).map(|i| (i * 2_654_435_761) % (1 << 20) - (1 << 19)).collect();
    let r_float = common::bench("requant float oracle (64k sums)", || {
        let mut acc = 0u32;
        for &s in &sums {
            acc = acc.wrapping_add(q.encode_fixed(s, ck.frac_bits));
        }
        std::hint::black_box(acc);
    });
    let r_plan = common::bench("requant integer plan (64k sums)", || {
        let mut acc = 0u32;
        for &s in &sums {
            acc = acc.wrapping_add(plan.encode_sum(s));
        }
        std::hint::black_box(acc);
    });
    println!("      integer plan is {:.2}x the float oracle", r_float.median_ns / r_plan.median_ns);
    rows.push(obj(vec![
        ("section", "requant".into()),
        ("kind", plan.kind_name().into()),
        ("float_ns", r_float.median_ns.into()),
        ("plan_ns", r_plan.median_ns.into()),
        ("speedup", (r_float.median_ns / r_plan.median_ns).into()),
    ]));

    // -- 3. flat outputs vs per-sample Vec<Vec<i64>> -------------------------
    println!("-- run_batch_into (zero-alloc) vs run_batch (nested vecs) --");
    let batch = 64usize;
    let mut ex = engine::Executor::with_capacity(&prog, batch);
    let r_nested = common::bench("run_batch nested vecs (batch 64)", || {
        for chunk in stream.chunks(batch) {
            std::hint::black_box(ex.run_batch(&prog, chunk));
        }
    });
    let mut flat: Vec<i64> = Vec::new();
    let r_flat = common::bench("run_batch_into flat plane (batch 64)", || {
        for chunk in stream.chunks(batch) {
            ex.run_batch_into(&prog, chunk, &mut flat);
            std::hint::black_box(&flat);
        }
    });
    println!("      flat outputs are {:.2}x nested vecs", r_nested.median_ns / r_flat.median_ns);
    rows.push(obj(vec![
        ("section", "flat_outputs".into()),
        ("nested_ns", r_nested.median_ns.into()),
        ("flat_ns", r_flat.median_ns.into()),
        ("speedup", (r_nested.median_ns / r_flat.median_ns).into()),
    ]));

    // -- 4. optimizer A/B: pass pipeline vs OptLevel::None -------------------
    // a synthetic checkpoint shaped like pruning-aware training left it:
    // >= 30% constant edges (pruned-to-constant splines) and >= 20%
    // duplicate tables (shared segments), the regime the ISSUE's acceptance
    // bars are stated for
    println!("-- optimizing pass pipeline (fold + DCE + dedup + CSE) vs OptLevel::None --");
    let pck = pruned_synthetic();
    let ptables = lut::from_checkpoint(&pck);
    let pnet = Netlist::build(&pck, &ptables, 2);
    let p_none = engine::compile_with(&pnet, OptLevel::None);
    let p_full = engine::compile_with(&pnet, OptLevel::Full);
    let report = p_full.opt_report().expect("full lowering reports").clone();
    println!("  {}", report.summary());

    // bit-exactness gate FIRST: optimized == OptLevel::None == sim
    let pstream = data::random_code_stream(&pck, n_stream, 13);
    let pprobe = &pstream[..pstream.len().min(256)];
    let poracle = sim::eval_batch(&pnet, pprobe);
    assert_eq!(engine::run_batch(&p_none, pprobe), poracle, "OptLevel::None diverges from sim");
    assert_eq!(engine::run_batch(&p_full, pprobe), poracle, "optimized program diverges from sim");

    // structural acceptance bars (deterministic, so they gate the bench)
    assert!(
        report.op_reduction() >= 0.25,
        "fused-op reduction {:.3} < 0.25 on the pruned net: {report:?}",
        report.op_reduction()
    );
    assert!(
        report.byte_reduction() >= 0.30,
        "table-byte reduction {:.3} < 0.30 on the pruned net: {report:?}",
        report.byte_reduction()
    );

    let batch = 64usize;
    let mut ex_none = engine::Executor::with_capacity(&p_none, batch);
    let mut flat_none: Vec<i64> = Vec::new();
    let r_unopt = common::bench("pruned net, OptLevel::None (batch 64)", || {
        for chunk in pstream.chunks(batch) {
            ex_none.run_batch_into(&p_none, chunk, &mut flat_none);
            std::hint::black_box(&flat_none);
        }
    });
    let mut ex_full = engine::Executor::with_capacity(&p_full, batch);
    let mut flat_full: Vec<i64> = Vec::new();
    let r_opt = common::bench("pruned net, OptLevel::Full (batch 64)", || {
        for chunk in pstream.chunks(batch) {
            ex_full.run_batch_into(&p_full, chunk, &mut flat_full);
            std::hint::black_box(&flat_full);
        }
    });
    println!(
        "      optimized program is {:.2}x OptLevel::None | ops {} -> {} (-{:.1}%) | table bytes {} -> {} (-{:.1}%)",
        r_unopt.median_ns / r_opt.median_ns,
        report.ops_before,
        report.ops_after,
        100.0 * report.op_reduction(),
        report.table_bytes_before,
        report.table_bytes_after,
        100.0 * report.byte_reduction(),
    );
    rows.push(obj(vec![
        ("section", "opt_ab".into()),
        ("batch", (batch as i64).into()),
        ("unopt_ns", r_unopt.median_ns.into()),
        ("opt_ns", r_opt.median_ns.into()),
        ("opt_speedup", (r_unopt.median_ns / r_opt.median_ns).into()),
        ("opt_ops_before", (report.ops_before as i64).into()),
        ("opt_ops_after", (report.ops_after as i64).into()),
        ("opt_ops_reduction", report.op_reduction().into()),
        ("opt_table_bytes_before", (report.table_bytes_before as i64).into()),
        ("opt_table_bytes_after", (report.table_bytes_after as i64).into()),
        ("opt_byte_reduction", report.byte_reduction().into()),
        ("opt_folded_edges", (report.folded_edges as i64).into()),
        ("opt_dead_inputs", (report.dead_inputs as i64).into()),
        ("opt_cse_fanouts", (report.cse_fanouts as i64).into()),
        ("opt_tables_total", (report.tables_total as i64).into()),
        ("opt_tables_unique", (report.tables_unique as i64).into()),
    ]));

    // -- 5. chunked (SIMD-width) lane kernels vs frozen PR-3 scalar ref ------
    // scalar_ref is the one-element-per-iteration executor frozen inside
    // engine::exec; the live executor runs the same passes through
    // CHUNK-wide kernels (std::simd bodies under --features simd)
    println!("-- chunked lane kernels vs frozen scalar reference --");
    let chunk = engine::CHUNK;
    println!(
        "  chunk width {} samples, simd feature {}",
        chunk,
        if cfg!(feature = "simd") { "ON (std::simd)" } else { "off (autovectorized)" }
    );
    // bit-exactness gate first, on the tail shapes the chunked path must
    // get right (n = 1, CHUNK-1, CHUNK+1) plus the full probe
    {
        let mut sex = ScalarExecutor::new();
        let mut sflat: Vec<i64> = Vec::new();
        let mut ex = engine::Executor::with_capacity(&prog, chunk + 1);
        let mut cflat: Vec<i64> = Vec::new();
        for n in [1usize, chunk - 1, chunk + 1, probe.len()] {
            let sub = &probe[..n.min(probe.len())];
            sex.run_batch_into(&prog, sub, &mut sflat);
            ex.run_batch_into(&prog, sub, &mut cflat);
            assert_eq!(sflat, cflat, "chunked kernels diverge from scalar_ref at n={n}");
        }
    }
    for batch in [1usize, chunk - 1, 64, 256] {
        let mut sex = ScalarExecutor::new();
        let mut sflat: Vec<i64> = Vec::new();
        let r_scalar = common::bench(&format!("scalar_ref kernels (batch {batch})"), || {
            for c in stream.chunks(batch) {
                sex.run_batch_into(&prog, c, &mut sflat);
                std::hint::black_box(&sflat);
            }
        });
        let mut ex = engine::Executor::with_capacity(&prog, batch);
        let mut cflat: Vec<i64> = Vec::new();
        let r_chunked = common::bench(&format!("chunked kernels (batch {batch})"), || {
            for c in stream.chunks(batch) {
                ex.run_batch_into(&prog, c, &mut cflat);
                std::hint::black_box(&cflat);
            }
        });
        let speedup = r_scalar.median_ns / r_chunked.median_ns;
        let gate = speedup >= 1.3;
        println!(
            "      batch {batch:>3}: chunked kernels are {speedup:.2}x scalar_ref{}",
            if batch == 64 {
                if gate {
                    " | gate >= 1.30x: PASS"
                } else {
                    " | gate >= 1.30x: MISS"
                }
            } else {
                ""
            }
        );
        rows.push(obj(vec![
            ("section", "simd".into()),
            ("batch", (batch as i64).into()),
            ("chunk", (chunk as i64).into()),
            ("simd_feature", cfg!(feature = "simd").into()),
            ("bit_exact", true.into()),
            ("scalar_ns", r_scalar.median_ns.into()),
            ("chunked_ns", r_chunked.median_ns.into()),
            ("speedup", speedup.into()),
            ("gate_1_3x", gate.into()),
        ]));
    }

    // -- 6. intra-batch data-parallelism: one big batch across the pool ------
    // a batch large enough that one executor is the bottleneck: the
    // coordinator slices its sample dimension across 4 executors
    // (ServiceCfg::parallel_grain) and must reproduce the engine's flat
    // plane bit-for-bit while cutting wall clock
    println!("-- intra-batch slicing: one large batch across the executor pool --");
    let big_ck = {
        let mut c =
            kanele::checkpoint::testutil::synthetic(&[64, 48, 32, 8], &[6, 6, 6, 6], 0x51CE);
        c.name = "intra-batch-synthetic".into();
        c
    };
    let big_tables = lut::from_checkpoint(&big_ck);
    let big_net = Netlist::build(&big_ck, &big_tables, 2);
    let n_big = if quick { 2_000 } else { 10_000 };
    let big_stream = data::random_code_stream(&big_ck, n_big, 17);
    // the reference plane comes straight off the engine: both service
    // configurations below must reproduce it exactly
    let big_prog = engine::compile_with(&big_net, OptLevel::Full);
    let mut want_flat: Vec<i64> = Vec::new();
    engine::run_batch_flat(&big_prog, &big_stream, &mut want_flat);
    let d_out = big_prog.d_out();
    let drive = |workers: usize, grain: usize, max_batch: usize, rows_in: &[Vec<u32>]| {
        let svc = Service::start(
            Arc::new(big_net.clone()),
            ServiceCfg {
                workers,
                shards: 1,
                max_batch,
                max_wait: Duration::from_millis(500),
                queue_depth: 1 << 15,
                parallel_grain: grain,
                ..Default::default()
            },
        );
        let t0 = Instant::now();
        let pending: Vec<_> = rows_in
            .iter()
            .map(|c| svc.submit(c.clone()).expect("queue sized for the whole batch"))
            .collect();
        let mut got: Vec<i64> = Vec::with_capacity(rows_in.len() * d_out);
        for rx in pending {
            got.extend(rx.recv().unwrap().unwrap().sums);
        }
        let dt = t0.elapsed();
        let st = svc.stats();
        svc.shutdown();
        (dt, got, st)
    };
    let (dt_single, got_single, st_single) = drive(1, GRAIN_OFF, n_big, &big_stream);
    assert_eq!(got_single, want_flat, "single-executor service diverges from engine");
    assert_eq!(st_single.sliced_batches, 0, "workers=1/GRAIN_OFF must never slice");
    let (dt_sliced, got_sliced, st_sliced) = drive(4, 512, n_big, &big_stream);
    assert_eq!(got_sliced, want_flat, "sliced service diverges from engine");
    assert!(st_sliced.sliced_batches >= 1, "one {n_big}-row batch at grain 512 must slice");
    // small batches provably keep the unsliced path on the very same config
    let small = &big_stream[..256.min(n_big)];
    let (_, got_small, st_small) = drive(4, 512, 64, small);
    assert_eq!(
        got_small.as_slice(),
        &want_flat[..got_small.len()],
        "small-batch run diverges from engine"
    );
    assert_eq!(st_small.sliced_batches, 0, "sub-threshold batches must not slice");
    let core_speedup = dt_single.as_secs_f64() / dt_sliced.as_secs_f64();
    let gate_2x = core_speedup >= 2.0;
    println!(
        "      one {n_big}-sample batch: 1 executor {:.1} ms -> 4 executors sliced {:.1} ms ({core_speedup:.2}x) | gate >= 2.00x: {}",
        dt_single.as_secs_f64() * 1e3,
        dt_sliced.as_secs_f64() * 1e3,
        if gate_2x { "PASS" } else { "MISS" }
    );
    println!(
        "      sliced_batches {} | slice_tasks {} | small-batch run sliced_batches {} (unsliced path proven)",
        st_sliced.sliced_batches, st_sliced.slice_tasks, st_small.sliced_batches
    );
    rows.push(obj(vec![
        ("section", "intra_batch".into()),
        ("batch", (n_big as i64).into()),
        ("workers", 4i64.into()),
        ("grain", 512i64.into()),
        ("bit_exact", true.into()),
        ("single_ms", (dt_single.as_secs_f64() * 1e3).into()),
        ("sliced_ms", (dt_sliced.as_secs_f64() * 1e3).into()),
        ("speedup", core_speedup.into()),
        ("gate_2x", gate_2x.into()),
        ("sliced_batches", (st_sliced.sliced_batches as i64).into()),
        ("slice_tasks", (st_sliced.slice_tasks as i64).into()),
        ("small_batch_unsliced", (st_small.sliced_batches == 0).into()),
    ]));

    // -- 7. error-budgeted lossy compilation: bytes bought vs exactness ------
    // (a) end-to-end fidelity on the jet-tagging twin: nearify the
    // checkpoint so ε-clustering has near-duplicate (not identical) tables
    // to share — jitter amplitude 8 <= budget 16, so the merges provably
    // fire — then compare Lossy(16) against the bit-exact Full program
    // over a fresh stream. Both gates are HARD and run before anything is
    // timed or recorded: the measured worst delta must stay within the
    // compiled-in composed bound, and argmax agreement must hold 99%.
    println!("-- lossy compilation: error-budgeted sharing/folding vs Full --");
    let lbudget = 16u32;
    let lck = {
        let mut c = common::checkpoint_or_synthetic("jsc_openml");
        kanele::checkpoint::testutil::nearify(&mut c, 50, 8, 0x10E5);
        c.name = "lossy-jet-twin".into();
        c
    };
    let ltables = lut::from_checkpoint(&lck);
    let lnet = Netlist::build(&lck, &ltables, 2);
    let l_full = engine::compile_with(&lnet, OptLevel::Full);
    let l_lossy = engine::compile_with(&lnet, OptLevel::Lossy(lbudget));
    let lreport = l_lossy.opt_report().expect("lossy lowering reports").clone();
    let lossy = lreport.lossy.as_ref().expect("lossy level carries its block");
    println!("  {}", lreport.summary());
    let lstream = data::random_code_stream(&lck, n_stream, 23);
    let mut full_flat: Vec<i64> = Vec::new();
    let mut lossy_flat: Vec<i64> = Vec::new();
    engine::run_batch_flat(&l_full, &lstream, &mut full_flat);
    engine::run_batch_flat(&l_lossy, &lstream, &mut lossy_flat);
    let d_out = l_full.d_out();
    let argmax = |s: &[i64]| {
        let mut best = 0;
        for (i, v) in s.iter().enumerate().skip(1) {
            if *v > s[best] {
                best = i;
            }
        }
        best
    };
    let mut agree = 0usize;
    let mut worst = 0i64;
    for (f, l) in full_flat.chunks(d_out).zip(lossy_flat.chunks(d_out)) {
        if argmax(f) == argmax(l) {
            agree += 1;
        }
        for (a, b) in f.iter().zip(l) {
            worst = worst.max((a - b).abs());
        }
    }
    let agreement = agree as f64 / lstream.len() as f64;
    assert!(
        worst <= lossy.worst_case_bound,
        "measured lossy delta {worst} lsb exceeds the composed bound {} lsb",
        lossy.worst_case_bound
    );
    assert!(
        agreement >= 0.99,
        "lossy argmax agreement {agreement:.4} < 0.99 at budget {lbudget} (worst delta {worst} lsb)"
    );

    // (b) the bytes the budget buys: section 4's pruned synthetic,
    // nearified so the duplicate tables pruning leaves behind become
    // NEAR-duplicates — exact dedup/CSE can no longer merge them (Full
    // pays for every jittered copy), ε-clustering can
    let bck = {
        let mut c = pruned_synthetic();
        kanele::checkpoint::testutil::nearify(&mut c, 50, 8, 0x0DD5);
        c.name = "lossy-pruned-synthetic".into();
        c
    };
    let btables = lut::from_checkpoint(&bck);
    let bnet = Netlist::build(&bck, &btables, 2);
    let b_full = engine::compile_with(&bnet, OptLevel::Full);
    let b_lossy = engine::compile_with(&bnet, OptLevel::Lossy(lbudget));
    let byte_reduction = 1.0 - b_lossy.table_bytes() as f64 / b_full.table_bytes() as f64;
    assert!(
        byte_reduction >= 0.25,
        "lossy table-byte reduction {byte_reduction:.3} vs Full < 0.25 (Full {} B, lossy {} B)",
        b_full.table_bytes(),
        b_lossy.table_bytes()
    );

    // timing A/B on the fidelity model (batch 64): smaller shared arenas
    // should never cost throughput; no gate, the numbers are recorded
    let batch = 64usize;
    let mut ex_lfull = engine::Executor::with_capacity(&l_full, batch);
    let mut flat_lfull: Vec<i64> = Vec::new();
    let r_lfull = common::bench("nearified jet twin, OptLevel::Full (batch 64)", || {
        for chunk in lstream.chunks(batch) {
            ex_lfull.run_batch_into(&l_full, chunk, &mut flat_lfull);
            std::hint::black_box(&flat_lfull);
        }
    });
    let mut ex_llossy = engine::Executor::with_capacity(&l_lossy, batch);
    let mut flat_llossy: Vec<i64> = Vec::new();
    let r_llossy = common::bench("nearified jet twin, OptLevel::Lossy(16) (batch 64)", || {
        for chunk in lstream.chunks(batch) {
            ex_llossy.run_batch_into(&l_lossy, chunk, &mut flat_llossy);
            std::hint::black_box(&flat_llossy);
        }
    });
    println!(
        "      budget {lbudget} lsb: agreement {:.4} (worst delta {worst} <= bound {} lsb) | arena bytes -{:.1}% vs Full on the pruned net | {:.2}x Full wall clock",
        agreement,
        lossy.worst_case_bound,
        100.0 * byte_reduction,
        r_lfull.median_ns / r_llossy.median_ns
    );
    rows.push(obj(vec![
        ("section", "lossy".into()),
        ("budget", (lbudget as i64).into()),
        ("agreement", agreement.into()),
        ("gate_agreement_99", (agreement >= 0.99).into()),
        ("worst_delta", worst.into()),
        ("bound", lossy.worst_case_bound.into()),
        ("shared_tables", (lossy.shared_tables as i64).into()),
        ("affine_folds", (lossy.affine_folds as i64).into()),
        ("tightened_layers", (lossy.tightened_layers as i64).into()),
        ("byte_reduction_vs_full", byte_reduction.into()),
        ("full_ns", r_lfull.median_ns.into()),
        ("lossy_ns", r_llossy.median_ns.into()),
        ("speedup", (r_lfull.median_ns / r_llossy.median_ns).into()),
    ]));

    // machine-readable trajectory: stdout grids rot in logs, this does not
    let doc = obj(vec![
        ("bench", "engine".into()),
        ("quick", quick.into()),
        ("model", ck.name.as_str().into()),
        ("n_ops", (prog.n_ops() as i64).into()),
        ("table_bytes", (prog.table_bytes() as i64).into()),
        // headline optimizer numbers are measured on the pruned synthetic
        // net of section 4, NOT on `model` above — opt_model labels them
        ("opt_model", pck.name.as_str().into()),
        ("opt_ops_reduction", report.op_reduction().into()),
        ("opt_byte_reduction", report.byte_reduction().into()),
        // headline lossy numbers (section 7): agreement on the nearified
        // jet twin at budget 16, bytes bought on the nearified pruned net
        ("lossy_budget", (lbudget as i64).into()),
        ("lossy_agreement", agreement.into()),
        ("lossy_byte_reduction", byte_reduction.into()),
        ("rows", Value::Array(rows)),
    ]);
    std::fs::write("BENCH_engine.json", kanele::json::to_string(&doc))
        .expect("write BENCH_engine.json");
    println!("wrote BENCH_engine.json");
}
