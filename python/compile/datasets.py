"""Deterministic dataset generators for every benchmark in the paper (§5.1).

The container is offline, so all non-synthetic datasets are replaced by
seeded surrogates matched in dimensionality, class count, size class and
task structure (DESIGN.md §3). Moons is synthetic in the paper too and is
generated exactly. Each generator returns
``(x_train, y_train, x_test, y_test)`` float32/int64 numpy arrays.
"""

from __future__ import annotations

import numpy as np

DATASETS = [
    "moons",
    "wine",
    "dry_bean",
    "jsc_openml",
    "jsc_cernbox",
    "mnist",
    "toyadmos",
]


def _split(x, y, test_frac, rng):
    n = x.shape[0]
    idx = rng.permutation(n)
    n_test = int(n * test_frac)
    te, tr = idx[:n_test], idx[n_test:]
    return (
        x[tr].astype(np.float32),
        y[tr],
        x[te].astype(np.float32),
        y[te],
    )


def moons(n: int = 4000, noise: float = 0.15, seed: int = 0):
    """Two interleaving half-moons (paper: scikit-learn make_moons; hand-rolled)."""
    rng = np.random.default_rng(seed)
    n_half = n // 2
    t = rng.uniform(0, np.pi, n_half)
    outer = np.stack([np.cos(t), np.sin(t)], axis=1)
    inner = np.stack([1 - np.cos(t), 1 - np.sin(t) - 0.5], axis=1)
    x = np.concatenate([outer, inner], axis=0)
    x += rng.normal(0, noise, x.shape)
    y = np.concatenate([np.zeros(n_half, np.int64), np.ones(n_half, np.int64)])
    return _split(x, y, 0.25, rng)


def wine(n: int = 1800, seed: int = 1):
    """UCI Wine surrogate: 13 physico-chemical features, 3 cultivars.

    Class-conditional Gaussians with correlated chemistry-style features
    (alcohol/phenols/color-intensity clusters), separability tuned so an MLP
    lands in the mid-90s like the real set.
    """
    rng = np.random.default_rng(seed)
    d, k = 13, 3
    # class means spread along a few latent chemistry axes
    axes = rng.normal(size=(4, d))
    means = np.stack([2.2 * (axes[0] * (c - 1) + 0.8 * axes[1 + c]) for c in range(k)])
    # shared correlated covariance
    m = rng.normal(size=(d, d)) * 0.25
    cov = np.eye(d) + m @ m.T * 0.5
    chol = np.linalg.cholesky(cov)
    ys = rng.integers(0, k, n)
    x = means[ys] + rng.normal(size=(n, d)) @ chol.T
    return _split(x, ys.astype(np.int64), 0.25, rng)


def dry_bean(n: int = 9000, seed: int = 2):
    """UCI Dry Bean surrogate: 16 geometric features of 7 bean varieties.

    Physically structured: sample per-variety ellipse axes, then compute the
    real Dry-Bean feature set (area, perimeter, axis lengths, aspect ratio,
    eccentricity, convex-ish area, equivalent diameter, extent, solidity,
    roundness, compactness, 4 shape factors) with measurement noise.
    """
    rng = np.random.default_rng(seed)
    k = 7
    # per-variety (major, minor) axis distributions (log-space)
    base = np.array(
        [[4.8, 4.2], [5.1, 4.3], [5.3, 4.6], [5.6, 4.7], [5.9, 4.9], [6.1, 5.3], [5.4, 5.1]]
    )
    ys = rng.integers(0, k, n)
    la = base[ys, 0] + rng.normal(0, 0.13, n)
    lb = base[ys, 1] + rng.normal(0, 0.11, n)
    a = np.exp(la)  # major semi-axis
    b = np.minimum(np.exp(lb), a * 0.98)  # minor
    area = np.pi * a * b
    # Ramanujan perimeter approximation
    h = ((a - b) / (a + b)) ** 2
    perim = np.pi * (a + b) * (1 + 3 * h / (10 + np.sqrt(4 - 3 * h)))
    ecc = np.sqrt(1 - (b / a) ** 2)
    conv_area = area * (1 + np.abs(rng.normal(0, 0.01, n)))
    eq_diam = np.sqrt(4 * area / np.pi)
    extent = (np.pi / 4) * (1 + rng.normal(0, 0.02, n))
    solidity = area / conv_area
    roundness = 4 * np.pi * area / perim**2
    compact = eq_diam / (2 * a)
    sf1 = 2 * a / eq_diam
    sf2 = 2 * b / eq_diam
    sf3 = area / (np.pi * a * a)
    sf4 = area / (np.pi * a * b * (1 + rng.normal(0, 0.01, n)))
    x = np.stack(
        [area, perim, 2 * a, 2 * b, 2 * a / (2 * b), ecc, conv_area, eq_diam,
         extent, solidity, roundness, compact, sf1, sf2, sf3, sf4],
        axis=1,
    )
    x += rng.normal(0, 0.01, x.shape) * x.std(axis=0, keepdims=True)
    return _split(x, ys.astype(np.int64), 0.2, rng)


def _jets(n: int, seed: int, overlap: float):
    """Shared JSC surrogate: 16 high-level jet-substructure features, 5 classes.

    Classes (q, g, W, Z, t) are given distinct prong multiplicities and mass
    scales; features are physics-formula functions of sampled constituents
    (generalized angularities, N-subjettiness-like ratios, masses, p_T
    dispersion) so the input->label map has the symbolic structure the paper
    argues favours KANs. ``overlap`` widens intra-class spread (CERNBox is
    the harder variant).
    """
    rng = np.random.default_rng(seed)
    k = 5
    prongs = np.array([1, 1, 2, 2, 3])  # q, g, W, Z, t
    mass = np.array([5.0, 12.0, 80.4, 91.2, 172.8])
    softness = np.array([0.4, 1.0, 0.45, 0.5, 0.6])  # gluon radiates more
    ys = rng.integers(0, k, n)
    feats = np.zeros((n, 16))
    npart = rng.poisson(18 + 14 * softness[ys]) + prongs[ys] + 2
    for i in range(n):
        c = ys[i]
        m = npart[i]
        # constituent kinematics: prong cores + soft radiation
        core = rng.dirichlet(np.ones(prongs[c]) * 6)
        z_core = core * rng.uniform(0.55, 0.8)
        z_soft = rng.dirichlet(np.ones(m - prongs[c]) * softness[c] * 2 + 0.1) * (
            1 - z_core.sum()
        )
        z = np.concatenate([z_core, z_soft])
        r_core = rng.uniform(0.02, 0.1, prongs[c]) * (mass[c] / 100 + overlap * rng.normal(0, 0.2) + 0.3)
        r_soft = rng.uniform(0.05, 0.4, m - prongs[c])
        r = np.abs(np.concatenate([r_core, r_soft]))
        # generalized angularities lambda_beta = sum z * r^beta
        ang = [np.sum(z * r**beta) for beta in (0.5, 1.0, 2.0)]
        # N-subjettiness proxies tau_N: residual spread after removing N cores
        order_idx = np.argsort(-z)
        tauN = []
        for nsub in (1, 2, 3):
            rest = order_idx[nsub:]
            tauN.append(np.sum(z[rest] * r[rest]))
        msd = mass[c] * (1 + overlap * rng.normal(0, 0.12)) * (1 + 0.05 * rng.normal())
        ptd = np.sqrt(np.sum(z * z))
        ecf2 = np.sum(np.outer(z, z) * np.add.outer(r, r)) / 2
        feats[i] = [
            np.log(msd + 1e-3),
            ang[0], ang[1], ang[2],
            tauN[0], tauN[1], tauN[2],
            tauN[1] / (tauN[0] + 1e-6), tauN[2] / (tauN[1] + 1e-6),
            ptd, ecf2, np.log(m),
            z.max(), np.sort(z)[-2] if m > 1 else 0.0,
            r.mean(), r.std(),
        ]
    feats += rng.normal(0, 0.02 + 0.06 * overlap, feats.shape) * (
        feats.std(axis=0, keepdims=True) + 1e-9
    )
    return feats, ys.astype(np.int64), rng


def jsc_openml(n: int = 20000, seed: int = 3):
    """JSC OpenML surrogate (easier: cleaner curation -> less overlap)."""
    x, y, rng = _jets(n, seed, overlap=0.35)
    return _split(x, y, 0.2, rng)


def jsc_cernbox(n: int = 20000, seed: int = 4):
    """JSC CERNBox surrogate (harder: more spread/overlap)."""
    x, y, rng = _jets(n, seed, overlap=1.0)
    return _split(x, y, 0.2, rng)


# ----------------------------------------------------------------------------
# MNIST surrogate: procedurally rendered digit glyphs
# ----------------------------------------------------------------------------

# 7-segment-plus-diagonals stroke descriptions per digit on a 20x20 box,
# each stroke = (x0, y0, x1, y1) in unit coords.
_DIGIT_STROKES = {
    0: [(0.2, 0.1, 0.8, 0.1), (0.8, 0.1, 0.8, 0.9), (0.8, 0.9, 0.2, 0.9), (0.2, 0.9, 0.2, 0.1)],
    1: [(0.5, 0.1, 0.5, 0.9), (0.35, 0.25, 0.5, 0.1)],
    2: [(0.2, 0.2, 0.8, 0.1), (0.8, 0.1, 0.8, 0.5), (0.8, 0.5, 0.2, 0.9), (0.2, 0.9, 0.8, 0.9)],
    3: [(0.2, 0.1, 0.8, 0.1), (0.8, 0.1, 0.8, 0.9), (0.8, 0.9, 0.2, 0.9), (0.35, 0.5, 0.8, 0.5)],
    4: [(0.7, 0.1, 0.7, 0.9), (0.2, 0.1, 0.2, 0.55), (0.2, 0.55, 0.85, 0.55)],
    5: [(0.8, 0.1, 0.2, 0.1), (0.2, 0.1, 0.2, 0.5), (0.2, 0.5, 0.8, 0.5), (0.8, 0.5, 0.8, 0.9), (0.8, 0.9, 0.2, 0.9)],
    6: [(0.75, 0.1, 0.3, 0.3), (0.3, 0.3, 0.2, 0.75), (0.2, 0.75, 0.5, 0.9), (0.5, 0.9, 0.8, 0.7), (0.8, 0.7, 0.25, 0.55)],
    7: [(0.2, 0.1, 0.8, 0.1), (0.8, 0.1, 0.4, 0.9)],
    8: [(0.5, 0.1, 0.25, 0.3), (0.25, 0.3, 0.5, 0.5), (0.5, 0.5, 0.75, 0.3), (0.75, 0.3, 0.5, 0.1),
        (0.5, 0.5, 0.2, 0.72), (0.2, 0.72, 0.5, 0.9), (0.5, 0.9, 0.8, 0.72), (0.8, 0.72, 0.5, 0.5)],
    9: [(0.75, 0.45, 0.3, 0.5), (0.3, 0.5, 0.25, 0.2), (0.25, 0.2, 0.6, 0.1), (0.6, 0.1, 0.78, 0.3),
        (0.78, 0.3, 0.75, 0.45), (0.75, 0.45, 0.6, 0.9)],
}


def _render_digit(digit: int, rng: np.random.Generator, size: int = 28) -> np.ndarray:
    """Rasterize one jittered glyph with a gaussian pen, random affine warp."""
    strokes = _DIGIT_STROKES[digit]
    # random affine: rotation, shear, scale, translation
    ang = rng.normal(0, 0.18)
    shear = rng.normal(0, 0.12)
    sc = rng.uniform(0.75, 1.0)
    ca, sa = np.cos(ang), np.sin(ang)
    A = np.array([[ca, -sa + shear], [sa, ca]]) * sc
    off = rng.normal(0, 0.03, 2) + 0.5
    img = np.zeros((size, size))
    yy, xx = np.mgrid[0:size, 0:size]
    pts_x = (xx + 0.5) / size
    pts_y = (yy + 0.5) / size
    width = rng.uniform(0.045, 0.075)
    for (x0, y0, x1, y1) in strokes:
        p0 = A @ (np.array([x0, y0]) - 0.5) + off
        p1 = A @ (np.array([x1, y1]) - 0.5) + off
        d = p1 - p0
        L2 = d @ d + 1e-12
        # distance from every pixel to the segment
        t = ((pts_x - p0[0]) * d[0] + (pts_y - p0[1]) * d[1]) / L2
        t = np.clip(t, 0, 1)
        dx = pts_x - (p0[0] + t * d[0])
        dy = pts_y - (p0[1] + t * d[1])
        dist2 = dx * dx + dy * dy
        img = np.maximum(img, np.exp(-dist2 / (2 * width * width)))
    img += rng.normal(0, 0.02, img.shape)
    return np.clip(img, 0, 1)


def mnist(n_train: int = 12000, n_test: int = 2000, seed: int = 5):
    """MNIST surrogate: 28x28 procedurally rendered digits, 10 classes."""
    rng = np.random.default_rng(seed)
    n = n_train + n_test
    ys = rng.integers(0, 10, n).astype(np.int64)
    xs = np.zeros((n, 28 * 28), dtype=np.float32)
    for i in range(n):
        xs[i] = _render_digit(int(ys[i]), rng).reshape(-1)
    return xs[:n_train], ys[:n_train], xs[n_train:], ys[n_train:]


def toyadmos(n_machines: int = 60, windows_per_machine: int = 40, seed: int = 6):
    """ToyADMOS surrogate: 64-dim log-mel-like windows of machine hum.

    Normal sound = harmonic stack of a per-machine fundamental + pink-ish
    noise; anomalies inject rattle (inter-harmonics + impulsive bursts).
    Returns (x_train, x_train, x_test, y_test): the model is an autoencoder
    trained on NORMAL windows only; y_test is 0/1 anomaly per test window.
    """
    rng = np.random.default_rng(seed)
    n_mels = 64
    sr, nfft = 16000, 1024
    freqs = np.linspace(0, sr / 2, nfft // 2 + 1)
    # triangular mel-ish filterbank on a log-frequency axis
    mel_pts = 700 * (np.expm1(np.linspace(np.log1p(60 / 700), np.log1p(7800 / 700), n_mels + 2)))
    fb = np.zeros((n_mels, freqs.size))
    for m in range(n_mels):
        l_, c, r_ = mel_pts[m], mel_pts[m + 1], mel_pts[m + 2]
        fb[m] = np.clip(np.minimum((freqs - l_) / (c - l_ + 1e-9), (r_ - freqs) / (r_ - c + 1e-9)), 0, None)

    def spectrum(fund, anomalous):
        spec = np.zeros(freqs.size)
        for hnum in range(1, 24):
            f = fund * hnum
            if f > sr / 2:
                break
            amp = 1.0 / hnum * rng.uniform(0.7, 1.3)
            spec += amp * np.exp(-((freqs - f) ** 2) / (2 * (12 + 0.01 * f) ** 2))
        spec += 0.02 / (1 + freqs / 300)  # pink-ish floor
        if anomalous:
            for _ in range(rng.integers(2, 5)):
                f = rng.uniform(0.5, 8) * fund + rng.uniform(-40, 40)
                spec += rng.uniform(0.25, 0.8) * np.exp(-((freqs - f) ** 2) / (2 * 25.0**2))
            spec += rng.uniform(0.05, 0.15)  # broadband rattle
        spec *= rng.uniform(0.85, 1.15)
        return spec

    xs, ys, machine_normal = [], [], []
    for mi in range(n_machines):
        fund = rng.uniform(90, 220)
        anomalous_machine = mi >= n_machines // 2
        for _ in range(windows_per_machine):
            anom = anomalous_machine
            spec = spectrum(fund, anom)
            mel = np.log(fb @ spec + 1e-6)
            xs.append(mel)
            ys.append(int(anom))
            machine_normal.append(not anomalous_machine)
    xs = np.asarray(xs, dtype=np.float32)
    ys = np.asarray(ys, dtype=np.int64)
    normal_idx = np.where(ys == 0)[0]
    rng.shuffle(normal_idx)
    n_tr = int(0.7 * normal_idx.size)
    tr = normal_idx[:n_tr]
    te = np.concatenate([normal_idx[n_tr:], np.where(ys == 1)[0]])
    rng.shuffle(te)
    return xs[tr], xs[tr].copy(), xs[te], ys[te]


def load(name: str, **kw):
    """Dispatch by dataset name (DATASETS)."""
    fns = {
        "moons": moons,
        "wine": wine,
        "dry_bean": dry_bean,
        "jsc_openml": jsc_openml,
        "jsc_cernbox": jsc_cernbox,
        "mnist": mnist,
        "toyadmos": toyadmos,
    }
    if name not in fns:
        raise KeyError(f"unknown dataset {name!r}; choose from {DATASETS}")
    return fns[name](**kw)
