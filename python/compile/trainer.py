"""Training driver: dataset -> QAT+pruned KAN -> checkpoint + testset + HLO.

Usage (from ``python/``)::

    python -m compile.trainer moons jsc_openml          # named datasets
    python -m compile.trainer --all                     # every Table 2 row
    python -m compile.trainer moons --with-mlp          # also MLP FP baseline

Artifacts land in ``../artifacts/``:
    <name>.ckpt.json      full checkpoint (params, masks, L-LUTs, oracle vecs)
    <name>.testset.json   eval set as input codes + labels
    <name>.hlo.txt        AOT-lowered quantized inference fn (PJRT runtime)
    <name>.train.json     per-epoch history + float baselines (Table 2 row)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from . import datasets
from .aot import export_kan_inference
from .configs import TABLE2, ExperimentCfg
from .export import export_checkpoint, export_testset, input_codes_from_raw, quantized_int_forward
from .kan.quant import fit_input_preproc
from .kan.train import train_kan, train_mlp

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _norm_inputs(cfg: ExperimentCfg, x_train, x_test):
    """Fit the folded BN+ScalarBiasScale preproc on train, apply to both."""
    preproc = fit_input_preproc(x_train, cfg.kan.input_quant, coverage=cfg.coverage)
    return preproc, preproc.apply_np(x_train).astype(np.float32), preproc.apply_np(x_test).astype(np.float32)


def run_experiment(name: str, with_mlp: bool = False, with_float_kan: bool = False, seed: int = 0,
                   epochs: int | None = None, log=print) -> dict:
    cfg = TABLE2[name]
    x_tr, y_tr, x_te, y_te = datasets.load(name, **cfg.dataset_kwargs)
    preproc, xn_tr, xn_te = _norm_inputs(cfg, x_tr, x_te)
    task = cfg.task if cfg.task != "binary" else "binary"
    train_task = {"classify": "classify", "binary": "binary", "regress": "regress"}[cfg.task]
    # autoencoder targets are the *quantizer-domain-clipped* inputs: the
    # hardware reconstruction can only be compared against decodable values
    lo, hi = cfg.kan.domain
    y_tr_t = np.clip(xn_tr, lo, hi) if cfg.task == "regress" else y_tr
    y_te_t = np.clip(xn_te, lo, hi) if cfg.task == "regress" else y_te
    ep = epochs if epochs is not None else cfg.epochs

    log(f"[{name}] training KAN (quantized+pruned), dims={cfg.kan.dims} bits={cfg.kan.bits} T={cfg.kan.prune_threshold}")
    res = train_kan(
        cfg.kan, xn_tr, y_tr_t, xn_te, y_te_t,
        epochs=ep, batch_size=cfg.batch_size, lr=cfg.lr, seed=seed,
        quantized=True, task=train_task, log=lambda s: log(f"  {s}"),
    )
    metrics = {"kan_qp_val": res.history[-1]["val"], "edges": res.history[-1]["edges"],
               "train_seconds": res.seconds}

    extras = {}
    if with_float_kan:
        log(f"[{name}] training KAN (float)")
        res_fp = train_kan(
            cfg.kan, xn_tr, y_tr_t, xn_te, y_te_t,
            epochs=ep, batch_size=cfg.batch_size, lr=cfg.lr, seed=seed,
            quantized=False, task=train_task,
        )
        extras["kan_fp_val"] = res_fp.history[-1]["val"]
    if with_mlp:
        log(f"[{name}] training MLP FP baseline dims={cfg.mlp_dims}")
        _, hist = train_mlp(
            cfg.mlp_dims, xn_tr, y_tr_t, xn_te, y_te_t,
            epochs=ep, batch_size=cfg.batch_size, lr=cfg.lr, seed=seed, task=train_task,
        )
        extras["mlp_fp_val"] = hist[-1]["val"]
    metrics.update(extras)

    # identity preproc for export: inputs were already normalised above, so
    # the exported affine is the fitted one (raw -> normalised happens in rust)
    os.makedirs(ART, exist_ok=True)
    ckpt_path = os.path.join(ART, f"{name}.ckpt.json")
    model = export_checkpoint(
        ckpt_path, name, cfg.task, cfg.kan, res.params, res.masks, preproc,
        x_te, y_te, metrics,
    )
    export_testset(os.path.join(ART, f"{name}.testset.json"), model, x_te, y_te)

    # hardware-accuracy of the integer pipeline on the full (exported) set
    codes = input_codes_from_raw(model, x_te[:4096])
    sums = quantized_int_forward(model, codes)
    if cfg.task == "classify":
        hw_acc = float((np.argmax(sums, axis=1) == y_te[: sums.shape[0]]).mean())
    elif cfg.task == "binary":
        hw_acc = float(((sums[:, 0] > 0).astype(np.int64) == y_te[: sums.shape[0]]).mean())
    else:
        rec = sums.astype(np.float64) / (1 << model.frac_bits)
        errs = np.mean((rec - y_te_t[: sums.shape[0]]) ** 2, axis=1)
        # AUC of reconstruction error vs anomaly label
        lab = y_te[: sums.shape[0]]
        order = np.argsort(errs)
        ranks = np.empty_like(order, dtype=np.float64)
        ranks[order] = np.arange(errs.size)
        pos, neg = ranks[lab == 1], ranks[lab == 0]
        hw_acc = float((pos.mean() - neg.mean()) / errs.size + 0.5) if pos.size and neg.size else 0.0
    metrics["hw_int_metric"] = hw_acc
    log(f"[{name}] hardware integer-pipeline metric: {hw_acc:.4f}")

    log(f"[{name}] lowering quantized inference to HLO (Pallas kernel path)")
    t0 = time.time()
    try:
        export_kan_inference(ckpt_path, os.path.join(ART, f"{name}.hlo.txt"), batch=256)
        metrics["hlo_seconds"] = time.time() - t0
    except Exception as e:  # pragma: no cover - large models may exceed lowering budget
        log(f"[{name}] HLO export failed ({e}); falling back to jnp path")
        export_kan_inference(ckpt_path, os.path.join(ART, f"{name}.hlo.txt"), batch=256, use_kernel=False)

    with open(os.path.join(ART, f"{name}.train.json"), "w") as f:
        json.dump({"name": name, "metrics": metrics, "history": res.history}, f)
    log(f"[{name}] done: {metrics}")
    return metrics


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="*", help=f"datasets: {list(TABLE2)}")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--with-mlp", action="store_true")
    ap.add_argument("--with-float-kan", action="store_true")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    names = list(TABLE2) if args.all else args.names
    if not names:
        ap.error("give dataset names or --all")
    for n in names:
        run_experiment(n, with_mlp=args.with_mlp, with_float_kan=args.with_float_kan,
                       seed=args.seed, epochs=args.epochs)


if __name__ == "__main__":
    main()
