"""AOT lowering: JAX (L2, calling the L1 Pallas kernel) -> HLO text.

HLO **text** is the interchange format (NOT ``.serialize()``): jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts produced here are loaded by ``rust/src/runtime`` via
``PjRtClient::cpu() -> HloModuleProto::from_text_file -> compile -> execute``
and serve as the float-reference path that the bit-exact netlist simulator
is cross-checked against.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kan.layers import KanCfg, kan_forward
from .kernels.kan_spline import kan_layer_pallas


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is essential: the default printer elides
    dense constants as ``{...}``, which XLA 0.5.1's text parser silently
    reads back as zeros (weights vanish, outputs go NaN).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def demo_fn(x, y):
    """Tiny smoke computation for the runtime loader test (quickstart)."""
    return (jnp.matmul(x, y) + 2.0,)


def export_demo(out_path: str, use_pallas: bool = False) -> str:
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    if use_pallas:
        from jax.experimental import pallas as pl

        def fn(x, y):
            def kernel(x_ref, y_ref, o_ref):
                o_ref[...] = x_ref[...] @ y_ref[...] + 2.0

            return (
                pl.pallas_call(
                    kernel,
                    out_shape=jax.ShapeDtypeStruct((2, 2), jnp.float32),
                    interpret=True,
                )(x, y),
            )

        lowered = jax.jit(fn).lower(spec, spec)
    else:
        lowered = jax.jit(demo_fn).lower(spec, spec)
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        f.write(text)
    return text


def _kan_infer_fn(cfg: KanCfg, params, masks, preproc_shift, preproc_span, use_kernel: bool):
    """Quantized inference closure: raw float input -> final-layer values.

    Matches the integer pipeline semantics up to fake-quant rounding (the
    Rust runtime cross-check asserts argmax/value agreement, not bit
    equality — bits are the netlist simulator's job).
    """
    shift = jnp.asarray(preproc_shift, jnp.float32)
    span = jnp.asarray(preproc_span, jnp.float32)

    def kernel_adapter(layer_params, x, lcfg, mask):
        ws = layer_params["w_spline"]
        wb = layer_params["w_base"]
        if mask is not None:
            ws = ws * mask[..., None]
            wb = wb * mask
        return kan_layer_pallas(
            x, ws, wb, lcfg.grid_size, lcfg.domain, lcfg.order,
            block_b=min(128, max(8, x.shape[0])),
        )

    def fn(x):
        h = (x - shift) / span
        h = kan_forward(
            params,
            h,
            cfg,
            masks=masks,
            quantized=True,
            kernel=kernel_adapter if use_kernel else None,
        )
        return (h,)

    return fn


def load_ckpt_jax(ckpt_path: str):
    """Checkpoint JSON -> (cfg, params, masks, preproc arrays)."""
    with open(ckpt_path) as f:
        doc = json.load(f)
    cfg = KanCfg(
        dims=tuple(doc["dims"]),
        grid_size=doc["grid_size"],
        order=doc["order"],
        domain=tuple(doc["domain"]),
        bits=tuple(doc["bits"]),
        prune_threshold=doc.get("prune_threshold", 0.0),
    )
    params = [
        {
            "w_spline": jnp.asarray(l["w_spline"], jnp.float32),
            "w_base": jnp.asarray(l["w_base"], jnp.float32),
        }
        for l in doc["layers"]
    ]
    masks = [jnp.asarray(l["mask"], jnp.float32) for l in doc["layers"]]
    return cfg, params, masks, doc["preproc"]["shift"], doc["preproc"]["span"]


def export_kan_inference(
    ckpt_path: str, out_path: str, batch: int = 256, use_kernel: bool = True
) -> str:
    """Lower the quantized KAN inference function of a checkpoint to HLO text."""
    cfg, params, masks, shift, span = load_ckpt_jax(ckpt_path)
    fn = _kan_infer_fn(cfg, params, masks, shift, span, use_kernel)
    spec = jax.ShapeDtypeStruct((batch, cfg.dims[0]), jnp.float32)
    lowered = jax.jit(fn).lower(spec)
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        f.write(text)
    return text


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    ap.add_argument("--ckpt", default=None, help="checkpoint JSON to lower instead of the demo")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--pallas-demo", action="store_true")
    ap.add_argument(
        "--no-kernel", action="store_true",
        help="lower with the jnp path instead of the Pallas kernel",
    )
    args = ap.parse_args()
    if args.ckpt:
        text = export_kan_inference(
            args.ckpt, args.out, batch=args.batch, use_kernel=not args.no_kernel
        )
    else:
        text = export_demo(args.out, use_pallas=args.pallas_demo)
    print(f"wrote {len(text)} chars to {args.out}")


if __name__ == "__main__":
    main()
