# L1: Pallas kernel(s) for the paper's compute hot-spot.
from . import kan_spline, ref  # noqa: F401
