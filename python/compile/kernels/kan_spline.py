"""L1 Pallas kernel: fused KAN-layer forward (basis expansion + contraction).

Hardware adaptation (DESIGN.md §3 / §8): the paper's hot-spot on FPGA is the
LUT + adder-tree evaluation; on TPU-class hardware the same computation is a
*feature expansion followed by a dense contraction*. The kernel therefore:

* expands each input scalar into its ``nb = G + S`` B-spline basis values
  **inside VMEM** (Cox-de Boor, unrolled over the order — pure VPU work),
* appends the silu base-activation channel, and
* performs ONE ``(Bblk, d_in*(nb+1)) @ (d_in*(nb+1), d_out)`` matmul so the
  contraction lands on the MXU instead of ``nb+1`` skinny matmuls.

The batch is tiled by ``block_b`` via ``BlockSpec``; the flattened weight
matrix stays resident in VMEM across grid steps. ``interpret=True`` is
mandatory on this CPU container (real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute); the same code lowers to
Mosaic unchanged on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from compile.kan import bspline


def _basis_in_kernel(x, t, n_knots: int, order: int, lo: float, hi: float):
    """Cox-de Boor inside the kernel; identical op order to bspline.bspline_basis.

    ``t`` is the knot vector read from a kernel input ref (Pallas forbids
    captured array constants); ``lo``/``hi`` are the scalar domain bounds.
    """
    x = jnp.clip(x, lo, hi)
    xe = x[..., None]

    left = t[:-1]
    right = t[1:]
    basis = jnp.where((xe >= left) & (xe < right), 1.0, 0.0)
    domain_last = n_knots - 2 - order
    at_end = xe[..., 0] >= hi
    # x == hi belongs to the closed last domain interval; zero the extension
    # interval the half-open rule would pick. (jnp.where-based column
    # updates keep the op graph branch-free.)
    col = jnp.where(at_end, 1.0, basis[..., domain_last])
    col_next = jnp.where(at_end, 0.0, basis[..., domain_last + 1])
    basis = jnp.concatenate(
        [basis[..., :domain_last], col[..., None], col_next[..., None], basis[..., domain_last + 2 :]],
        axis=-1,
    )

    for k in range(1, order + 1):
        ti = t[: n_knots - k - 1]
        tik = t[k : n_knots - 1]
        ti1 = t[1 : n_knots - k]
        tik1 = t[k + 1 : n_knots]
        d0 = jnp.where(tik - ti > 0, tik - ti, 1.0)
        d1 = jnp.where(tik1 - ti1 > 0, tik1 - ti1, 1.0)
        basis = (xe - ti) / d0 * basis[..., : n_knots - k - 1] + (tik1 - xe) / d1 * basis[
            ..., 1 : n_knots - k
        ]
    return basis


def _kan_layer_kernel(x_ref, w_ref, t_ref, o_ref, *, order: int, nb: int, lo: float, hi: float):
    """One grid step: (block_b, d_in) inputs -> (block_b, d_out) outputs."""
    x = x_ref[...]  # (Bblk, d_in)
    t = t_ref[...]
    basis = _basis_in_kernel(x, t, t.shape[0], order, lo, hi)  # (Bblk, d_in, nb)
    base = x * jax.nn.sigmoid(x)  # silu, VPU
    feats = jnp.concatenate([basis, base[..., None]], axis=-1)  # (Bblk, d_in, nb+1)
    bblk, d_in = x.shape
    flat = feats.reshape(bblk, d_in * (nb + 1))
    # single MXU contraction; accumulate in f32
    o_ref[...] = jax.lax.dot_general(
        flat,
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def pack_weights(w_spline: jnp.ndarray, w_base: jnp.ndarray) -> jnp.ndarray:
    """Flatten (d_out, d_in, nb) + (d_out, d_in) -> (d_in*(nb+1), d_out).

    Feature order must match the kernel's reshape: for each input p the nb
    spline bases come first, then the base-activation channel.
    """
    d_out, d_in, nb = w_spline.shape
    w = jnp.concatenate([w_spline, w_base[..., None]], axis=-1)  # (d_out, d_in, nb+1)
    return w.transpose(1, 2, 0).reshape(d_in * (nb + 1), d_out)


@functools.partial(jax.jit, static_argnames=("order", "block_b", "grid_size", "domain"))
def _run(x, w_packed, *, order, grid_size, domain, block_b):
    knots = bspline.make_knots(grid_size, domain, order)
    nb = bspline.num_bases(grid_size, order)
    b, d_in = x.shape
    d_out = w_packed.shape[1]
    # pad batch up to a block multiple
    pad = (-b) % block_b
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d_in), x.dtype)], axis=0)
    bp = x.shape[0]
    lo, hi = float(domain[0]), float(domain[1])
    t = jnp.asarray(knots, jnp.float32)
    out = pl.pallas_call(
        functools.partial(_kan_layer_kernel, order=order, nb=nb, lo=lo, hi=hi),
        out_shape=jax.ShapeDtypeStruct((bp, d_out), jnp.float32),
        grid=(bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in * (nb + 1), d_out), lambda i: (0, 0)),
            pl.BlockSpec((t.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, d_out), lambda i: (i, 0)),
        interpret=True,  # CPU container: Mosaic custom-calls are TPU-only
    )(x, w_packed, t)
    return out[:b]


def kan_layer_pallas(
    x: jnp.ndarray,
    w_spline: jnp.ndarray,
    w_base: jnp.ndarray,
    grid_size: int,
    domain: tuple[float, float],
    order: int,
    block_b: int = 128,
) -> jnp.ndarray:
    """Public kernel entry point; same contract as ``ref.kan_layer_ref``."""
    if order < 1:
        raise ValueError("kan_layer_pallas requires spline order >= 1")
    w_packed = pack_weights(jnp.asarray(w_spline, jnp.float32), jnp.asarray(w_base, jnp.float32))
    return _run(
        jnp.asarray(x, jnp.float32),
        w_packed,
        order=order,
        grid_size=grid_size,
        domain=domain,
        block_b=block_b,
    )


def vmem_footprint_bytes(
    d_in: int, d_out: int, grid_size: int, order: int, block_b: int = 128
) -> dict:
    """Analytic VMEM/MXU model for DESIGN.md §8 (interpret-mode wallclock is
    not a TPU proxy; structure is what we optimize).

    Returns the per-grid-step VMEM residency and the MXU utilization bound
    from the contraction shape.
    """
    nb = grid_size + order
    f = nb + 1
    bytes_x = block_b * d_in * 4
    bytes_feats = block_b * d_in * f * 4
    bytes_w = d_in * f * d_out * 4
    bytes_out = block_b * d_out * 4
    total = bytes_x + bytes_feats + bytes_w + bytes_out
    # MXU 128x128: utilization bound = how well (block_b, d_in*f, d_out)
    # fills the systolic array tiles.
    def eff(n, t=128):
        import math

        return n / (math.ceil(n / t) * t)

    mxu = eff(block_b) * eff(d_in * f) * eff(d_out)
    return {
        "vmem_bytes": total,
        "vmem_mib": total / (1 << 20),
        "fits_16mib_vmem": total < 16 * (1 << 20),
        "mxu_tile_efficiency": mxu,
        "flops_per_step": 2 * block_b * d_in * f * d_out,
    }
