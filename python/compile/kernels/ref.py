"""Pure-jnp oracle for the L1 Pallas KAN-layer kernel.

This is the CORE correctness reference: ``kan_spline.kan_layer_pallas`` must
match this function to float tolerance for every shape/dtype hypothesis
sweeps throw at it (``python/tests/test_kernel.py``).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from compile.kan import bspline


def kan_layer_ref(
    x: jnp.ndarray,
    w_spline: jnp.ndarray,
    w_base: jnp.ndarray,
    knots: np.ndarray,
    order: int,
) -> jnp.ndarray:
    """Reference KAN layer forward.

    x: (B, d_in); w_spline: (d_out, d_in, nb); w_base: (d_out, d_in).
    Returns (B, d_out) with
    y[b, q] = sum_p [ w_base[q,p] * silu(x[b,p])
                      + sum_k w_spline[q,p,k] * B_k(x[b,p]) ].
    """
    basis = bspline.bspline_basis(x, knots, order)  # (B, d_in, nb)
    spline_out = jnp.einsum("bpk,qpk->bq", basis, w_spline)
    base_out = bspline.silu(x) @ w_base.T
    return spline_out + base_out
