"""Clipped PPO with GAE, from scratch (paper §5.7 / Fig. 7).

Four scenarios (Table 6/7): MLP FP, MLP 8-bit, KAN FP, KAN 8-bit actors —
the critic is always a float MLP. The update is jitted; environment
stepping is numpy-vectorized.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..kan.train import adamw_init, adamw_update
from . import actors
from .cheetah import CheetahLite

SCENARIOS = ["mlp_fp", "mlp_q8", "kan_fp", "kan_q8"]


@dataclass
class PpoCfg:
    n_envs: int = 16
    rollout: int = 128
    total_steps: int = 150_000
    epochs: int = 4
    minibatches: int = 4
    gamma: float = 0.98
    lam: float = 0.95
    clip: float = 0.2
    lr: float = 3e-4
    vf_coef: float = 0.5
    ent_coef: float = 1e-3
    max_grad_norm: float = 0.5


def _gaussian_logp(mean, log_std, act):
    var = jnp.exp(2 * log_std)
    return -0.5 * jnp.sum((act - mean) ** 2 / var + 2 * log_std + jnp.log(2 * np.pi), axis=-1)


def _clip_grads(grads, max_norm):
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-8))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def train(kind: str, seed: int = 0, cfg: PpoCfg | None = None, log=None) -> dict:
    """Train one scenario; returns {steps, returns} learning-curve arrays."""
    cfg = cfg or PpoCfg()
    key = jax.random.PRNGKey(seed)
    k_actor, k_critic, key = jax.random.split(key, 3)
    actor = actors.init_actor(kind, k_actor)
    critic = actors.init_critic(k_critic)
    opt_a = adamw_init(actor)
    opt_c = adamw_init(critic)

    env = CheetahLite(cfg.n_envs, seed=seed + 1000)
    obs = env.reset()

    @jax.jit
    def policy_step(actor, obs, key):
        mean = actors.actor_mean(kind, actor, obs)
        std = jnp.exp(actor["log_std"])
        eps = jax.random.normal(key, mean.shape)
        act = mean + std * eps
        logp = _gaussian_logp(mean, actor["log_std"], act)
        return act, logp

    @jax.jit
    def values(critic, obs):
        return actors.critic_value(critic, obs)

    @jax.jit
    def update(actor, critic, opt_a, opt_c, batch):
        obs_b, act_b, logp_b, adv_b, ret_b = batch

        def actor_loss(a):
            mean = actors.actor_mean(kind, a, obs_b)
            logp = _gaussian_logp(mean, a["log_std"], act_b)
            ratio = jnp.exp(logp - logp_b)
            unclipped = ratio * adv_b
            clipped = jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip) * adv_b
            ent = jnp.sum(a["log_std"] + 0.5 * jnp.log(2 * np.pi * np.e))
            return -jnp.mean(jnp.minimum(unclipped, clipped)) - cfg.ent_coef * ent

        def critic_loss(c):
            v = actors.critic_value(c, obs_b)
            return cfg.vf_coef * jnp.mean((v - ret_b) ** 2)

        la, ga = jax.value_and_grad(actor_loss)(actor)
        lc, gc = jax.value_and_grad(critic_loss)(critic)
        ga = _clip_grads(ga, cfg.max_grad_norm)
        gc = _clip_grads(gc, cfg.max_grad_norm)
        actor, opt_a = adamw_update(actor, ga, opt_a, cfg.lr, weight_decay=0.0)
        critic, opt_c = adamw_update(critic, gc, opt_c, cfg.lr, weight_decay=0.0)
        return actor, critic, opt_a, opt_c, la + lc

    steps_done = 0
    curve_steps, curve_returns = [], []
    ep_return = np.zeros(cfg.n_envs)
    finished_returns: list[float] = []
    t0 = time.time()

    while steps_done < cfg.total_steps:
        # rollout
        obs_buf = np.zeros((cfg.rollout, cfg.n_envs, actors.OBS_DIM), np.float32)
        act_buf = np.zeros((cfg.rollout, cfg.n_envs, actors.ACT_DIM), np.float32)
        logp_buf = np.zeros((cfg.rollout, cfg.n_envs), np.float32)
        rew_buf = np.zeros((cfg.rollout, cfg.n_envs), np.float32)
        done_buf = np.zeros((cfg.rollout, cfg.n_envs), np.float32)
        val_buf = np.zeros((cfg.rollout + 1, cfg.n_envs), np.float32)

        for t in range(cfg.rollout):
            key, sk = jax.random.split(key)
            act, logp = policy_step(actor, jnp.asarray(obs), sk)
            act_np = np.asarray(act)
            val_buf[t] = np.asarray(values(critic, jnp.asarray(obs)))
            obs_buf[t] = obs
            act_buf[t] = act_np
            logp_buf[t] = np.asarray(logp)
            obs, rew, done = env.step(np.tanh(act_np))
            rew_buf[t] = rew
            done_buf[t] = done
            ep_return += rew
            if done.any():
                for i in np.where(done)[0]:
                    finished_returns.append(float(ep_return[i]))
                    ep_return[i] = 0.0
        val_buf[cfg.rollout] = np.asarray(values(critic, jnp.asarray(obs)))
        steps_done += cfg.rollout * cfg.n_envs

        # GAE
        adv = np.zeros_like(rew_buf)
        last = np.zeros(cfg.n_envs, np.float32)
        for t in reversed(range(cfg.rollout)):
            nonterminal = 1.0 - done_buf[t]
            delta = rew_buf[t] + cfg.gamma * val_buf[t + 1] * nonterminal - val_buf[t]
            last = delta + cfg.gamma * cfg.lam * nonterminal * last
            adv[t] = last
        ret = adv + val_buf[: cfg.rollout]
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)

        flat = lambda x: x.reshape(-1, *x.shape[2:])
        data = (flat(obs_buf), flat(act_buf), flat(logp_buf), flat(adv), flat(ret))
        n = data[0].shape[0]
        rng = np.random.default_rng(steps_done)
        for _ in range(cfg.epochs):
            perm = rng.permutation(n)
            for mb in np.array_split(perm, cfg.minibatches):
                batch = tuple(jnp.asarray(d[mb]) for d in data)
                actor, critic, opt_a, opt_c, _ = update(actor, critic, opt_a, opt_c, batch)

        recent = float(np.mean(finished_returns[-10:])) if finished_returns else float(np.sum(rew_buf) / cfg.n_envs)
        curve_steps.append(steps_done)
        curve_returns.append(recent)
        if log:
            log(f"  [{kind} seed {seed}] steps {steps_done:7d} return {recent:9.1f}")

    return {
        "kind": kind,
        "seed": seed,
        "steps": curve_steps,
        "returns": curve_returns,
        "final_return": float(np.mean(curve_returns[-3:])),
        "actor": actor,
        "seconds": time.time() - t0,
    }


def evaluate(kind: str, actor: dict, n_episodes: int = 4, seed: int = 9999) -> float:
    """Deterministic (mean-action) evaluation return."""
    env = CheetahLite(n_episodes, seed=seed)
    obs = env.reset()
    total = np.zeros(n_episodes)
    fn = jax.jit(lambda p, o: actors.actor_mean(kind, p, o))
    from .cheetah import EPISODE_LEN

    for _ in range(EPISODE_LEN):
        act = np.tanh(np.asarray(fn(actor, jnp.asarray(obs))))
        obs, rew, _ = env.step(act)
        total += rew
    return float(total.mean())
