"""Continuous-control extension (paper §5.7).

MuJoCo/Gym are unavailable offline; ``cheetah.py`` is a planar 6-joint
cheetah-flavoured surrogate with HalfCheetah's exact observation/action
dimensions (17/6) and reward structure (forward velocity - control cost).
``ppo.py`` implements clipped PPO with GAE from scratch; ``actors.py``
holds the four actor/critic configurations of Table 6.
"""

from . import actors, cheetah, ppo  # noqa: F401
