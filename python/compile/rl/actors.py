"""Actor/critic configurations of paper Table 6.

* MLP actor  [17, 64, 64, 6]  (5,638 params with biases; paper prints 5,383)
* MLP critic [17, 64, 64, 1]
* KAN actor  [17, 6] single layer, G=6, S=3 -> 102 edges x 10 params = 1,020

The actor head outputs pre-tanh means; a state-independent learnable
log-std completes the Gaussian policy. Quantized variants fake-quant the
actor activations at 8 bits (paper scenario 2 and 4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..kan.layers import KanCfg, init_kan, init_mlp, kan_forward, kan_param_count, mlp_forward, mlp_param_count
from ..kan.quant import QuantSpec

OBS_DIM = 17
ACT_DIM = 6

MLP_ACTOR_DIMS = (OBS_DIM, 64, 64, ACT_DIM)
MLP_CRITIC_DIMS = (OBS_DIM, 64, 64, 1)

KAN_ACTOR_CFG = KanCfg(
    dims=(OBS_DIM, ACT_DIM),
    grid_size=6,
    order=3,
    domain=(-4.0, 4.0),
    bits=(8, 8),
    prune_threshold=0.0,
)

ACTOR_QUANT = QuantSpec(8, -4.0, 4.0)


def param_counts() -> dict:
    """Table 6 parameter counts."""
    return {
        "mlp_actor": mlp_param_count(MLP_ACTOR_DIMS),
        "mlp_critic": mlp_param_count(MLP_CRITIC_DIMS),
        "kan_actor": kan_param_count(KAN_ACTOR_CFG),
    }


def init_actor(kind: str, key: jax.Array) -> dict:
    """kind in {mlp_fp, mlp_q8, kan_fp, kan_q8}."""
    k1, k2 = jax.random.split(key)
    if kind.startswith("mlp"):
        body = init_mlp(k1, MLP_ACTOR_DIMS)
    else:
        body = init_kan(k1, KAN_ACTOR_CFG)
    return {"body": body, "log_std": jnp.full((ACT_DIM,), -0.5)}


def actor_mean(kind: str, params: dict, obs: jnp.ndarray) -> jnp.ndarray:
    """Pre-tanh mean of the policy Gaussian."""
    quant = kind.endswith("q8")
    if kind.startswith("mlp"):
        return mlp_forward(params["body"], obs, quant=ACTOR_QUANT if quant else None)
    return kan_forward(params["body"], obs, KAN_ACTOR_CFG, quantized=quant)


def init_critic(key: jax.Array) -> list[dict]:
    return init_mlp(key, MLP_CRITIC_DIMS)


def critic_value(params: list[dict], obs: jnp.ndarray) -> jnp.ndarray:
    return mlp_forward(params, obs)[:, 0]
