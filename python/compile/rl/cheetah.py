"""CheetahLite: a planar 6-joint locomotion surrogate for HalfCheetah.

Matched to HalfCheetah-v5's interface: observation dim 17 (8 positions
excluding x, 9 velocities), action dim 6 (joint torques in [-1, 1]),
reward = forward velocity - 0.1 * ||action||^2, episode length 1000.

Dynamics (vectorized over N parallel envs):
  * 6 joints: damped double integrators driven by torques, with soft limits;
  * gait thrust: each leg joint contributes ``qd_i * sin(q_i + phi_i)``
    thrust when swinging "backward through stance" — coordinated phase
    patterns produce sustained velocity, uncoordinated flailing cancels;
  * root: forward velocity relaxes toward total thrust; height and pitch
    oscillate with leg asymmetry and are penalized implicitly through
    thrust loss when pitch diverges.

The MDP is smooth, stationary and solved well by coordinated oscillation,
preserving the §5.7 comparison (can a small KAN policy beat a 5x-larger
MLP?) without a rigid-body simulator.
"""

from __future__ import annotations

import numpy as np

OBS_DIM = 17
ACT_DIM = 6
EPISODE_LEN = 1000

_PHI = np.array([0.0, 2.094, 4.189, 1.047, 3.142, 5.236])  # leg phase offsets
_COUPLE = np.array([1.0, 0.8, 0.6, -1.0, -0.8, -0.6])  # front/back legs oppose


class CheetahLite:
    """N parallel environments, numpy-vectorized."""

    def __init__(self, n_envs: int, seed: int = 0):
        self.n = n_envs
        self.rng = np.random.default_rng(seed)
        self.dt = 0.05
        self.reset()

    def reset(self) -> np.ndarray:
        n = self.n
        self.q = self.rng.normal(0, 0.1, (n, ACT_DIM))
        self.qd = self.rng.normal(0, 0.1, (n, ACT_DIM))
        self.vx = np.zeros(n)
        self.vz = np.zeros(n)
        self.height = np.full(n, 0.7) + self.rng.normal(0, 0.02, n)
        self.pitch = self.rng.normal(0, 0.05, n)
        self.pitch_rate = np.zeros(n)
        self.t = np.zeros(n, dtype=np.int64)
        return self._obs()

    def _obs(self) -> np.ndarray:
        return np.concatenate(
            [
                self.height[:, None],
                self.pitch[:, None],
                self.q,  # 6 joint angles -> 8 "positions"
                self.vx[:, None],
                self.vz[:, None],
                self.pitch_rate[:, None],
                self.qd,  # 6 joint velocities -> 9 "velocities"
            ],
            axis=1,
        ).astype(np.float32)

    def step(self, action: np.ndarray):
        """action: (n, 6) in [-1, 1]. Returns (obs, reward, done)."""
        a = np.clip(action, -1.0, 1.0)
        # joint dynamics: torque - damping - soft spring to range
        qdd = 18.0 * a - 1.2 * self.qd - 4.0 * np.clip(self.q, -1.3, 1.3) ** 3
        self.qd = np.clip(self.qd + self.dt * qdd, -12.0, 12.0)
        self.q = np.clip(self.q + self.dt * self.qd, -2.0, 2.0)

        # gait thrust: phase-aligned swing produces forward force
        swing = np.sin(self.q + _PHI) * _COUPLE
        thrust = np.sum(self.qd * swing, axis=1) * 0.12
        # pitch stability discounts thrust
        stability = np.exp(-2.0 * self.pitch**2)
        self.vx += self.dt * (4.0 * thrust * stability - 0.8 * self.vx)

        # root bobbing driven by leg asymmetry
        asym = np.sum(self.qd[:, :3] - self.qd[:, 3:], axis=1) * 0.01
        self.vz = 0.9 * self.vz + asym
        self.height = np.clip(self.height + self.dt * self.vz, 0.3, 1.1)
        self.pitch_rate = 0.9 * self.pitch_rate + 0.02 * asym + 0.004 * self.rng.normal(0, 1, self.n)
        self.pitch = np.clip(self.pitch + self.dt * self.pitch_rate, -1.0, 1.0)

        reward = self.vx - 0.1 * np.sum(a * a, axis=1)
        self.t += 1
        done = self.t >= EPISODE_LEN
        # auto-reset finished envs
        if done.any():
            idx = np.where(done)[0]
            self._reset_some(idx)
        return self._obs(), reward.astype(np.float32), done

    def _reset_some(self, idx: np.ndarray):
        k = idx.size
        self.q[idx] = self.rng.normal(0, 0.1, (k, ACT_DIM))
        self.qd[idx] = self.rng.normal(0, 0.1, (k, ACT_DIM))
        self.vx[idx] = 0.0
        self.vz[idx] = 0.0
        self.height[idx] = 0.7 + self.rng.normal(0, 0.02, k)
        self.pitch[idx] = self.rng.normal(0, 0.05, k)
        self.pitch_rate[idx] = 0.0
        self.t[idx] = 0
