"""Checkpoint export: trained KAN -> JSON consumed by the Rust toolflow.

This file defines the *hardware contract* shared with ``rust/src/checkpoint``
and ``rust/src/lut``:

* Input codes: ``c0 = clamp(floor((clip((x - shift)/span, a, b) - a)/s_in + 0.5),
  0, 2^n_in - 1)`` per feature.
* Edge L-LUT: ``T[q][p][c] = round_half_away(phi_qp(a + c*s_in) * 2^F)`` as
  i64, where ``phi_qp`` is Eq. 2 (base silu term + spline term, masked edges
  omitted) and ``F = frac_bits``.
* Node sum: exact i64 addition of active-edge table entries.
* Inter-layer requantization: ``c = clamp(floor((clip(S/2^F, a, b) - a)/s + 0.5),
  0, 2^n - 1)``.
* Network output: final-layer i64 sums (value = S / 2^F).

``quantized_int_forward`` is the bit-exact oracle; its outputs are exported
as test vectors so the Rust netlist simulator can assert exact equality.
The float tables themselves are also exported (`layers[l].table`) as the
authoritative source: Rust *regenerates* them from the spline parameters as
the paper's toolflow does, and the cross-language test tolerates <=1 LSB of
libm exp() discrepancy on the silu term.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass

import numpy as np

from .kan.bspline import bspline_basis_np, silu_np
from .kan.layers import KanCfg
from .kan.quant import InputPreproc, QuantSpec, quantize_codes_np


@dataclass
class ExportedModel:
    """In-memory form of the checkpoint, shared by oracle + writer."""

    cfg: KanCfg
    preproc: InputPreproc
    frac_bits: int
    # per layer: mask (d_out, d_in) uint8, tables list[d_out][d_in] -> i64[2^n_in] or None
    masks: list
    tables: list


def edge_phi_np(
    x: np.ndarray,
    w_spline_qp: np.ndarray,
    w_base_qp: float,
    knots: np.ndarray,
    order: int,
) -> np.ndarray:
    """Eq. 2 for one edge, f64, fixed op order (mirrored in rust/src/lut).

    Spline contributions are accumulated in ascending k, then the base term
    is added last.
    """
    basis = bspline_basis_np(x, knots, order)  # (n, nb)
    acc = np.zeros(x.shape, dtype=np.float64)
    for k in range(basis.shape[-1]):
        acc = acc + float(w_spline_qp[k]) * basis[..., k]
    return acc + float(w_base_qp) * silu_np(x)


def round_half_away_np(v: np.ndarray) -> np.ndarray:
    """round-half-away-from-zero (ties away from 0), matching Rust's f64::round."""
    return np.sign(v) * np.floor(np.abs(v) + 0.5)


def build_tables(params: list, masks: list, cfg: KanCfg, frac_bits: int) -> list:
    """Enumerate every surviving edge's input-code space -> integer L-LUTs."""
    tables = []
    for l in range(cfg.n_layers):
        lcfg = cfg.layer_cfg(l)
        in_spec = QuantSpec(cfg.bits[l], cfg.domain[0], cfg.domain[1])
        codes = np.arange(in_spec.levels, dtype=np.int64)
        xs = in_spec.lo + codes.astype(np.float64) * in_spec.scale
        w_spline = np.asarray(params[l]["w_spline"], dtype=np.float64)
        w_base = np.asarray(params[l]["w_base"], dtype=np.float64)
        m = np.asarray(masks[l])
        layer_tables = []
        for q in range(lcfg.d_out):
            row = []
            for p in range(lcfg.d_in):
                if m[q, p] == 0:
                    row.append(None)
                else:
                    phi = edge_phi_np(xs, w_spline[q, p], w_base[q, p], lcfg.knots, lcfg.order)
                    row.append(round_half_away_np(phi * (1 << frac_bits)).astype(np.int64))
            layer_tables.append(row)
        tables.append(layer_tables)
    return tables


def quantized_int_forward(model: ExportedModel, input_codes: np.ndarray) -> np.ndarray:
    """Bit-exact integer pipeline (the netlist's functional semantics).

    input_codes: (B, d_0) int64 codes. Returns final-layer i64 sums
    (B, d_L). All arithmetic is exact-integer once past table generation.
    """
    cfg = model.cfg
    F = model.frac_bits
    codes = np.asarray(input_codes, dtype=np.int64)
    for l in range(cfg.n_layers):
        lcfg = cfg.layer_cfg(l)
        b = codes.shape[0]
        sums = np.zeros((b, lcfg.d_out), dtype=np.int64)
        for q in range(lcfg.d_out):
            for p in range(lcfg.d_in):
                t = model.tables[l][q][p]
                if t is not None:
                    sums[:, q] += t[codes[:, p]]
        if l < cfg.n_layers - 1:
            out_spec = QuantSpec(cfg.bits[l + 1], cfg.domain[0], cfg.domain[1])
            v = sums.astype(np.float64) / (1 << F)
            codes = quantize_codes_np(v, out_spec)
        else:
            return sums
    return codes  # unreachable for n_layers >= 1


def input_codes_from_raw(model: ExportedModel, x_raw: np.ndarray) -> np.ndarray:
    """Raw features -> input codes (preproc affine + input quantizer)."""
    spec = model.cfg.input_quant
    xn = model.preproc.apply_np(x_raw)
    return quantize_codes_np(xn, spec)


def export_checkpoint(
    path: str,
    name: str,
    task: str,
    cfg: KanCfg,
    params: list,
    masks: list,
    preproc: InputPreproc,
    x_test_raw: np.ndarray,
    y_test: np.ndarray,
    metrics: dict,
    frac_bits: int = 14,
    n_test_vectors: int = 256,
) -> ExportedModel:
    """Write the full checkpoint JSON (DESIGN.md §4) and return the model."""
    tables = build_tables(params, masks, cfg, frac_bits)
    model = ExportedModel(cfg=cfg, preproc=preproc, frac_bits=frac_bits, masks=masks, tables=tables)

    nv = min(n_test_vectors, x_test_raw.shape[0])
    tv_codes = input_codes_from_raw(model, x_test_raw[:nv])
    tv_out = quantized_int_forward(model, tv_codes)

    layers_json = []
    for l in range(cfg.n_layers):
        lcfg = cfg.layer_cfg(l)
        m = np.asarray(masks[l]).astype(int)
        layers_json.append(
            {
                "d_in": lcfg.d_in,
                "d_out": lcfg.d_out,
                "in_bits": cfg.bits[l],
                "out_bits": cfg.bits[l + 1],
                "w_spline": np.asarray(params[l]["w_spline"], dtype=np.float64).tolist(),
                "w_base": np.asarray(params[l]["w_base"], dtype=np.float64).tolist(),
                "mask": m.tolist(),
                "table": [
                    [None if t is None else t.tolist() for t in row] for row in tables[l]
                ],
            }
        )

    doc = {
        "format": "kanele-ckpt-v1",
        "name": name,
        "task": task,
        "grid_size": cfg.grid_size,
        "order": cfg.order,
        "domain": [cfg.domain[0], cfg.domain[1]],
        "dims": list(cfg.dims),
        "bits": list(cfg.bits),
        "frac_bits": frac_bits,
        "prune_threshold": cfg.prune_threshold,
        "preproc": {
            "shift": np.asarray(preproc.shift, dtype=np.float64).tolist(),
            "span": np.asarray(preproc.span, dtype=np.float64).tolist(),
        },
        "layers": layers_json,
        "metrics": metrics,
        "test_vectors": {
            "input_codes": tv_codes.tolist(),
            "output_sums": tv_out.tolist(),
        },
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    return model


def export_testset(path: str, model: ExportedModel, x_test_raw: np.ndarray, y_test: np.ndarray, limit: int = 4096):
    """Full evaluation set as input codes + labels for the Rust harness."""
    n = min(limit, x_test_raw.shape[0])
    codes = input_codes_from_raw(model, x_test_raw[:n])
    doc = {
        "format": "kanele-testset-v1",
        "input_codes": codes.tolist(),
        "labels": np.asarray(y_test[:n]).tolist(),
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
