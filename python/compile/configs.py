"""Per-dataset hyperparameters — one entry per Table 2 row of the paper.

(G, [a,b], S, d_l, n_l, T) are the paper's printed values; training budgets
(epochs/batch/lr and surrogate sizes) are scaled to CPU-minutes per
DESIGN.md §5. ``task`` selects the loss: softmax / binary / reconstruction.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .kan.layers import KanCfg


@dataclass(frozen=True)
class ExperimentCfg:
    name: str
    kan: KanCfg
    task: str  # classify | binary | regress
    epochs: int
    batch_size: int
    lr: float
    mlp_dims: tuple  # Table 2 "MLP FP" baseline (same dims)
    dataset_kwargs: dict = field(default_factory=dict)
    coverage: float = 3.0  # input preproc sigma coverage


TABLE2: dict[str, ExperimentCfg] = {}


def _add(cfg: ExperimentCfg):
    TABLE2[cfg.name] = cfg


_add(
    ExperimentCfg(
        name="moons",
        kan=KanCfg(dims=(2, 2, 1), grid_size=6, order=3, domain=(-8.0, 8.0),
                   bits=(6, 5, 8), prune_threshold=0.0, warmup_start=0, warmup_target=10),
        task="binary",
        epochs=40, batch_size=64, lr=5e-3,
        mlp_dims=(2, 2, 1),
    )
)

_add(
    ExperimentCfg(
        name="wine",
        kan=KanCfg(dims=(13, 4, 3), grid_size=6, order=3, domain=(-8.0, 8.0),
                   bits=(6, 7, 8), prune_threshold=0.0, warmup_start=0, warmup_target=10),
        task="classify",
        epochs=40, batch_size=64, lr=5e-3,
        mlp_dims=(13, 4, 3),
    )
)

_add(
    ExperimentCfg(
        name="dry_bean",
        kan=KanCfg(dims=(16, 2, 7), grid_size=6, order=3, domain=(-8.0, 8.0),
                   bits=(6, 6, 8), prune_threshold=0.0, warmup_start=0, warmup_target=10),
        task="classify",
        epochs=30, batch_size=128, lr=5e-3,
        mlp_dims=(16, 2, 7),
    )
)

_add(
    ExperimentCfg(
        name="jsc_cernbox",
        kan=KanCfg(dims=(16, 12, 5), grid_size=30, order=10, domain=(-2.0, 2.0),
                   bits=(8, 8, 6), prune_threshold=0.14, warmup_start=2, warmup_target=14),
        task="classify",
        epochs=24, batch_size=256, lr=3e-3,
        mlp_dims=(16, 12, 5),
    )
)

_add(
    ExperimentCfg(
        name="jsc_openml",
        kan=KanCfg(dims=(16, 8, 5), grid_size=40, order=10, domain=(-2.0, 2.0),
                   bits=(6, 7, 6), prune_threshold=0.9, warmup_start=2, warmup_target=14),
        task="classify",
        epochs=24, batch_size=256, lr=3e-3,
        mlp_dims=(16, 8, 5),
    )
)

_add(
    ExperimentCfg(
        name="mnist",
        kan=KanCfg(dims=(784, 62, 10), grid_size=30, order=3, domain=(-8.0, 8.0),
                   # paper prints T=1.0; our edge-norm scale differs (norms are
                   # computed over the 2-point 1-bit input grid), so the
                   # threshold is rescaled to prune ~90% of edges w/o collapse
                   bits=(1, 6, 6), prune_threshold=0.05, warmup_start=4, warmup_target=10),
        task="classify",
        epochs=12, batch_size=256, lr=2e-3,
        mlp_dims=(784, 62, 10),
        dataset_kwargs={"n_train": 8000, "n_test": 2000},
    )
)

_add(
    ExperimentCfg(
        name="toyadmos",
        kan=KanCfg(dims=(64, 16, 8, 16, 64), grid_size=30, order=10, domain=(-2.0, 2.0),
                   bits=(7, 8, 8, 7, 8), prune_threshold=0.9, warmup_start=2, warmup_target=12),
        task="regress",
        epochs=30, batch_size=128, lr=3e-3,
        mlp_dims=(64, 16, 8, 16, 64),
    )
)
