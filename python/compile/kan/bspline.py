"""B-spline bases on a fixed uniform grid (paper Fig. 2, Eq. 2).

A KAN edge activation is a linear combination of ``G + S`` B-spline basis
functions of order (degree) ``S`` defined on a uniform grid of ``G``
intervals over the fixed domain ``[a, b]``. The knot vector is extended by
``S`` knots on each side so that the basis forms a partition of unity on
``[a, b]``.

The Cox-de Boor recursion here is written iteratively and with a *fixed
operation order* so that the Rust L-LUT extractor (``rust/src/lut``) can
mirror it bit-for-bit in f64 — the truth tables generated on either side of
the language boundary must be identical.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def make_knots(grid_size: int, domain: tuple[float, float], order: int) -> np.ndarray:
    """Uniform extended knot vector.

    ``grid_size`` (G) intervals over ``domain = [a, b]``, extended by
    ``order`` (S) knots on each side. Length is ``G + 2S + 1``.
    """
    a, b = float(domain[0]), float(domain[1])
    if not b > a:
        raise ValueError(f"domain must satisfy b > a, got [{a}, {b}]")
    if grid_size < 1:
        raise ValueError(f"grid_size must be >= 1, got {grid_size}")
    if order < 0:
        raise ValueError(f"order must be >= 0, got {order}")
    h = (b - a) / grid_size
    # knots[i] = a + (i - order) * h, i = 0 .. G + 2S
    idx = np.arange(grid_size + 2 * order + 1, dtype=np.float64)
    return a + (idx - order) * h


def num_bases(grid_size: int, order: int) -> int:
    """Number of B-spline basis functions: G + S."""
    return grid_size + order


def bspline_basis(x: jnp.ndarray, knots: np.ndarray, order: int) -> jnp.ndarray:
    """Evaluate all ``G + S`` basis functions at ``x``.

    Cox-de Boor, iterative in the order. ``x`` has any shape; the result has
    shape ``x.shape + (G + S,)``. Values of ``x`` outside the domain are
    clamped to the domain edge (matching the hardware clip before the LUT).
    """
    t = jnp.asarray(knots, dtype=x.dtype)
    n_knots = t.shape[0]
    a, b = t[order], t[n_knots - 1 - order]
    x = jnp.clip(x, a, b)
    xe = x[..., None]

    # Degree 0: indicator of the half-open knot interval. The last interval
    # of the *domain* is closed so that x == b is covered (standard fix).
    left = t[:-1]
    right = t[1:]
    basis = jnp.where((xe >= left) & (xe < right), 1.0, 0.0)
    # close the right end of the domain interval [t[-order-2], t[-order-1]]:
    # x == b belongs to the last *domain* interval, not the extension
    # interval [b, b + h) the half-open rule would pick.
    domain_last = n_knots - 2 - order
    at_end = xe[..., 0] >= b
    basis = basis.at[..., domain_last].set(
        jnp.where(at_end, 1.0, basis[..., domain_last])
    )
    if order > 0:  # extension interval [b, b+h) exists only for order >= 1
        basis = basis.at[..., domain_last + 1].set(
            jnp.where(at_end, 0.0, basis[..., domain_last + 1])
        )

    for k in range(1, order + 1):
        # B_{i,k}(x) = (x - t_i)/(t_{i+k} - t_i) B_{i,k-1}
        #           + (t_{i+k+1} - x)/(t_{i+k+1} - t_{i+1}) B_{i+1,k-1}
        ti = t[: n_knots - k - 1]
        tik = t[k : n_knots - 1]
        ti1 = t[1 : n_knots - k]
        tik1 = t[k + 1 : n_knots]
        # uniform grid -> denominators are k*h > 0, no 0/0 guards needed,
        # but keep them for robustness with degenerate grids.
        d0 = jnp.where(tik - ti > 0, tik - ti, 1.0)
        d1 = jnp.where(tik1 - ti1 > 0, tik1 - ti1, 1.0)
        left_term = (xe - ti) / d0 * basis[..., : n_knots - k - 1]
        right_term = (tik1 - xe) / d1 * basis[..., 1 : n_knots - k]
        basis = left_term + right_term

    return basis  # (..., G + S)


def bspline_basis_np(x: np.ndarray, knots: np.ndarray, order: int) -> np.ndarray:
    """f64 numpy twin of :func:`bspline_basis`.

    Used by the export oracle: the Rust extractor replays exactly this
    operation order in f64, so table generation agrees bit-for-bit.
    """
    t = np.asarray(knots, dtype=np.float64)
    n_knots = t.shape[0]
    a, b = t[order], t[n_knots - 1 - order]
    x = np.clip(np.asarray(x, dtype=np.float64), a, b)
    xe = x[..., None]

    left = t[:-1]
    right = t[1:]
    basis = ((xe >= left) & (xe < right)).astype(np.float64)
    domain_last = n_knots - 2 - order
    at_end = xe[..., 0] >= b
    basis[..., domain_last] = np.where(at_end, 1.0, basis[..., domain_last])
    if order > 0:  # extension interval [b, b+h) exists only for order >= 1
        basis[..., domain_last + 1] = np.where(at_end, 0.0, basis[..., domain_last + 1])

    for k in range(1, order + 1):
        ti = t[: n_knots - k - 1]
        tik = t[k : n_knots - 1]
        ti1 = t[1 : n_knots - k]
        tik1 = t[k + 1 : n_knots]
        d0 = np.where(tik - ti > 0, tik - ti, 1.0)
        d1 = np.where(tik1 - ti1 > 0, tik1 - ti1, 1.0)
        basis = (xe - ti) / d0 * basis[..., : n_knots - k - 1] + (
            tik1 - xe
        ) / d1 * basis[..., 1 : n_knots - k]

    return basis


def silu(x):
    """Base activation phi(x) = x * sigmoid(x) (paper Eq. 2 default)."""
    return x / (1.0 + jnp.exp(-x))


def silu_np(x: np.ndarray) -> np.ndarray:
    """f64 numpy twin of :func:`silu` for the export oracle."""
    x = np.asarray(x, dtype=np.float64)
    return x / (1.0 + np.exp(-x))
