"""Fourier-basis KAN edges (paper §6 future work: "alternative orthogonal
bases ... Fourier, wavelet, or rational bases ... while remaining
LUT-compatible").

An edge activation becomes a truncated Fourier series on the fixed domain:

    phi(x) = a_0 + sum_{k=1..H} [ a_k cos(k w x) + b_k sin(k w x) ],
    w = 2 pi / (b - a)

The LUT-compatibility claim is trivially true — the hardware conversion
enumerates phi at the quantized input codes, so the downstream toolflow
(tables -> netlist -> VHDL -> synthesis) is *identical*; only training-side
basis evaluation changes. ``test_fourier.py`` demonstrates the full path:
train on moons, tabulate, run the bit-exact integer pipeline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .quant import QuantSpec, fake_quant


def num_features(harmonics: int) -> int:
    """1 (DC) + 2 per harmonic."""
    return 1 + 2 * harmonics


def fourier_basis(x: jnp.ndarray, harmonics: int, domain: tuple[float, float]) -> jnp.ndarray:
    """All Fourier features at x; shape x.shape + (2H+1,)."""
    a, b = domain
    w = 2.0 * jnp.pi / (b - a)
    x = jnp.clip(x, a, b)
    ks = jnp.arange(1, harmonics + 1)
    ang = x[..., None] * (ks * w)
    return jnp.concatenate(
        [jnp.ones_like(x)[..., None], jnp.cos(ang), jnp.sin(ang)], axis=-1
    )


def fourier_basis_np(x: np.ndarray, harmonics: int, domain: tuple[float, float]) -> np.ndarray:
    """f64 numpy twin (table-generation oracle)."""
    a, b = domain
    w = 2.0 * np.pi / (b - a)
    x = np.clip(np.asarray(x, np.float64), a, b)
    ks = np.arange(1, harmonics + 1)
    ang = x[..., None] * (ks * w)
    return np.concatenate(
        [np.ones_like(x)[..., None], np.cos(ang), np.sin(ang)], axis=-1
    )


def init_fourier_kan(key: jax.Array, dims: tuple[int, ...], harmonics: int) -> list[dict]:
    """Coefficients decay with harmonic index (smooth init)."""
    params = []
    keys = jax.random.split(key, len(dims) - 1)
    nf = num_features(harmonics)
    decay = np.concatenate([[1.0], *([1.0 / k] * 2 for k in range(1, harmonics + 1))])
    for l in range(len(dims) - 1):
        w = (
            jax.random.normal(keys[l], (dims[l + 1], dims[l], nf))
            * 0.3
            * jnp.asarray(decay)
            / np.sqrt(dims[l])
        )
        params.append({"w": w})
    return params


def fourier_kan_forward(
    params: list[dict],
    x: jnp.ndarray,
    dims: tuple[int, ...],
    harmonics: int,
    domain: tuple[float, float],
    bits: tuple[int, ...] | None = None,
) -> jnp.ndarray:
    """Layer composition with optional inter-layer quantizers (QAT)."""
    h = x
    if bits is not None:
        h = fake_quant(h, QuantSpec(bits[0], domain[0], domain[1]))
    for l, p in enumerate(params):
        basis = fourier_basis(h, harmonics, domain)
        h = jnp.einsum("bpk,qpk->bq", basis, p["w"])
        if bits is not None and l < len(params) - 1:
            h = fake_quant(h, QuantSpec(bits[l + 1], domain[0], domain[1]))
    return h


def edge_phi_fourier_np(
    x: np.ndarray, w_edge: np.ndarray, harmonics: int, domain: tuple[float, float]
) -> np.ndarray:
    """One edge's phi, f64, fixed op order (feature-ascending accumulation)."""
    basis = fourier_basis_np(x, harmonics, domain)
    acc = np.zeros(np.shape(x), np.float64)
    for k in range(basis.shape[-1]):
        acc = acc + float(w_edge[k]) * basis[..., k]
    return acc


def build_fourier_tables(
    params: list[dict],
    dims: tuple[int, ...],
    harmonics: int,
    domain: tuple[float, float],
    bits: tuple[int, ...],
    frac_bits: int,
) -> list:
    """Same L-LUT enumeration as export.build_tables, Fourier flavour."""
    from ..export import round_half_away_np
    from .quant import QuantSpec

    tables = []
    for l in range(len(dims) - 1):
        spec = QuantSpec(bits[l], domain[0], domain[1])
        xs = spec.lo + np.arange(spec.levels, dtype=np.float64) * spec.scale
        w = np.asarray(params[l]["w"], np.float64)
        layer = []
        for q in range(dims[l + 1]):
            row = []
            for p in range(dims[l]):
                phi = edge_phi_fourier_np(xs, w[q, p], harmonics, domain)
                row.append(round_half_away_np(phi * (1 << frac_bits)).astype(np.int64))
            layer.append(row)
        tables.append(layer)
    return tables
