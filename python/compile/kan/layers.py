"""KAN layers and models (paper Eq. 1-5), plus the MLP baseline.

Parameters are plain pytrees (dicts of jnp arrays) so the hand-rolled AdamW
in :mod:`compile.kan.train` can operate on them without a framework.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import bspline
from .quant import QuantSpec, fake_quant


@dataclass(frozen=True)
class KanLayerCfg:
    """Static configuration of one KAN layer (Table 1 hyperparameters)."""

    d_in: int
    d_out: int
    grid_size: int  # G
    order: int  # S
    domain: tuple[float, float]  # [a, b]
    out_bits: int  # n_l for the post-layer quantizer

    @property
    def n_basis(self) -> int:
        return bspline.num_bases(self.grid_size, self.order)

    @property
    def knots(self) -> np.ndarray:
        return bspline.make_knots(self.grid_size, self.domain, self.order)

    @property
    def out_quant(self) -> QuantSpec:
        return QuantSpec(self.out_bits, self.domain[0], self.domain[1])


@dataclass(frozen=True)
class KanCfg:
    """Full model configuration = one Table 2 row."""

    dims: tuple[int, ...]  # d_l, e.g. (16, 8, 5)
    grid_size: int
    order: int
    domain: tuple[float, float]
    bits: tuple[int, ...]  # (n_input, n_l1, ..., n_lL) length len(dims)
    prune_threshold: float = 0.0  # T
    warmup_start: int = 0  # t0
    warmup_target: int = 1  # tf

    def __post_init__(self):
        if len(self.bits) != len(self.dims):
            raise ValueError(
                f"bits must have one entry per dims entry (input + each layer): "
                f"{len(self.bits)} vs {len(self.dims)}"
            )

    @property
    def n_layers(self) -> int:
        return len(self.dims) - 1

    def layer_cfg(self, l: int) -> KanLayerCfg:
        return KanLayerCfg(
            d_in=self.dims[l],
            d_out=self.dims[l + 1],
            grid_size=self.grid_size,
            order=self.order,
            domain=self.domain,
            out_bits=self.bits[l + 1],
        )

    @property
    def input_quant(self) -> QuantSpec:
        return QuantSpec(self.bits[0], self.domain[0], self.domain[1])


def init_kan_layer(key: jax.Array, cfg: KanLayerCfg, noise_scale: float = 0.1) -> dict:
    """Initialise one layer: small random spline coeffs, Kaiming-ish base weights."""
    k1, k2 = jax.random.split(key)
    nb = cfg.n_basis
    w_spline = noise_scale * jax.random.normal(k1, (cfg.d_out, cfg.d_in, nb)) / np.sqrt(cfg.d_in)
    w_base = jax.random.normal(k2, (cfg.d_out, cfg.d_in)) / np.sqrt(cfg.d_in)
    return {"w_spline": w_spline, "w_base": w_base}


def init_kan(key: jax.Array, cfg: KanCfg) -> list[dict]:
    keys = jax.random.split(key, cfg.n_layers)
    return [init_kan_layer(keys[l], cfg.layer_cfg(l)) for l in range(cfg.n_layers)]


def edge_norms(params: dict, cfg: KanLayerCfg, n_grid_samples: int = 0) -> jnp.ndarray:
    """Eq. 10-11: L2 norm of each edge's *spline component* over the input grid.

    The grid X is sampled consistently with the layer's input quantization:
    callers pass ``n_grid_samples = 2**n_in`` (all codes); 0 means a dense
    default of 64 points.
    """
    n = n_grid_samples if n_grid_samples > 0 else 64
    a, b = cfg.domain
    xs = jnp.linspace(a, b, n)
    basis = bspline.bspline_basis(xs, cfg.knots, cfg.order)  # (n, nb)
    # f_{p->q}(x) over the grid: (d_out, d_in, n)
    f = jnp.einsum("qpk,nk->qpn", params["w_spline"], basis)
    return jnp.sqrt(jnp.sum(f * f, axis=-1))  # (d_out, d_in)


def kan_layer_forward(
    params: dict,
    x: jnp.ndarray,
    cfg: KanLayerCfg,
    mask: jnp.ndarray | None = None,
    kernel: Any = None,
) -> jnp.ndarray:
    """Eq. 2-3: y[b, q] = sum_p mask[q,p] * phi_{q,p}(x[b, p]).

    ``kernel`` optionally injects the Pallas implementation (L1); the default
    is the reference jnp path. Both are verified equal in pytest.
    """
    if kernel is not None:
        return kernel(params, x, cfg, mask)
    basis = bspline.bspline_basis(x, cfg.knots, cfg.order)  # (B, d_in, nb)
    base = bspline.silu(x)  # (B, d_in)
    w_spline = params["w_spline"]
    w_base = params["w_base"]
    if mask is not None:
        w_spline = w_spline * mask[..., None]
        w_base = w_base * mask
    spline_out = jnp.einsum("bpk,qpk->bq", basis, w_spline)
    base_out = base @ w_base.T
    return spline_out + base_out


def kan_forward(
    params: list[dict],
    x: jnp.ndarray,
    cfg: KanCfg,
    masks: list[jnp.ndarray] | None = None,
    quantized: bool = True,
    kernel: Any = None,
) -> jnp.ndarray:
    """Eq. 5 composition with the Eq. 6/7 quantizers between layers.

    When ``quantized`` is False this is the float KAN (the "KAN FP" column
    of Table 2). The final layer output is *not* quantized (logits /
    regression head read full accumulator precision, as in the RTL where the
    last adder-tree sum is the output port).
    """
    h = x
    if quantized:
        h = fake_quant(h, cfg.input_quant)
    for l in range(cfg.n_layers):
        lcfg = cfg.layer_cfg(l)
        m = masks[l] if masks is not None else None
        h = kan_layer_forward(params[l], h, lcfg, mask=m, kernel=kernel)
        if quantized and l < cfg.n_layers - 1:
            h = fake_quant(h, lcfg.out_quant)
    return h


# ----------------------------------------------------------------------------
# MLP baseline (Table 2 "MLP FP" column; §5.7 critic & actor baselines)
# ----------------------------------------------------------------------------


def init_mlp(key: jax.Array, dims: tuple[int, ...]) -> list[dict]:
    """He-initialised ReLU MLP with the same layer dims as the KAN."""
    params = []
    keys = jax.random.split(key, len(dims) - 1)
    for l in range(len(dims) - 1):
        w = jax.random.normal(keys[l], (dims[l + 1], dims[l])) * np.sqrt(2.0 / dims[l])
        b = jnp.zeros((dims[l + 1],))
        params.append({"w": w, "b": b})
    return params


def mlp_forward(params: list[dict], x: jnp.ndarray, quant: QuantSpec | None = None) -> jnp.ndarray:
    """ReLU MLP; optional fake-quant after every hidden activation (8-bit MLP of §5.7)."""
    h = x
    if quant is not None:
        h = fake_quant(h, quant)
    for l, p in enumerate(params):
        h = h @ p["w"].T + p["b"]
        if l < len(params) - 1:
            h = jax.nn.relu(h)
            if quant is not None:
                h = fake_quant(h, quant)
    return h


def mlp_param_count(dims: tuple[int, ...]) -> int:
    return sum(dims[l + 1] * dims[l] + dims[l + 1] for l in range(len(dims) - 1))


def kan_param_count(cfg: KanCfg) -> int:
    total = 0
    for l in range(cfg.n_layers):
        lc = cfg.layer_cfg(l)
        total += lc.d_out * lc.d_in * (lc.n_basis + 1)  # spline coeffs + base weight
    return total
