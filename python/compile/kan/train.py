"""Training loop: hand-rolled AdamW (optax is not installed) + QAT + pruning.

The loop follows the paper's toolflow §4.1.1: choose hyperparameters
(Table 1), train with the quantizers of §3.2 in the graph and the pruning
schedule of §3.3 recomputed every epoch.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import prune as prune_mod
from .layers import KanCfg, init_kan, kan_forward


# ----------------------------------------------------------------------------
# AdamW on pytrees
# ----------------------------------------------------------------------------


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=1e-4):
    """One decoupled-weight-decay Adam step (Loshchilov & Hutter)."""
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - step - lr * weight_decay * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


# ----------------------------------------------------------------------------
# Losses / metrics
# ----------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - ll)


def accuracy(logits: np.ndarray, labels: np.ndarray) -> float:
    return float((np.argmax(logits, axis=-1) == labels).mean())


def mse(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((pred - target) ** 2)


def bce_logits(logit: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Binary cross-entropy on a single-logit head (moons: dims [2,2,1])."""
    z = logit[:, 0]
    y = labels.astype(z.dtype)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


@dataclass
class TrainResult:
    params: list
    masks: list
    history: list  # per-epoch dicts
    cfg: KanCfg
    seconds: float


def _batches(rng: np.random.Generator, n: int, batch_size: int):
    idx = rng.permutation(n)
    for i in range(0, n - batch_size + 1, batch_size):
        yield idx[i : i + batch_size]


def train_kan(
    cfg: KanCfg,
    x_train: np.ndarray,
    y_train: np.ndarray,
    x_val: np.ndarray,
    y_val: np.ndarray,
    *,
    epochs: int = 30,
    batch_size: int = 128,
    lr: float = 3e-3,
    weight_decay: float = 1e-4,
    seed: int = 0,
    quantized: bool = True,
    task: str = "classify",  # or "regress" (autoencoder / policy heads)
    log: Callable[[str], None] | None = None,
) -> TrainResult:
    """QAT + pruning training of a KAN on (x, y).

    For ``task="classify"`` ``y`` is int labels and the loss is softmax
    cross-entropy; for ``task="regress"`` ``y`` is float targets and the
    loss is MSE. Masks are recomputed from the warmup schedule every epoch
    and *applied inside the graph*, so gradients of pruned edges vanish and
    surviving edges adapt (structured QAT-consistent pruning).
    """
    key = jax.random.PRNGKey(seed)
    params = init_kan(key, cfg)
    opt = adamw_init(params)
    masks = prune_mod.full_masks(cfg)

    if task == "classify":
        loss_fn_core = lambda logits, y: softmax_xent(logits, y)
        y_train = y_train.astype(np.int32)
        y_val_np = y_val.astype(np.int32)
    elif task == "binary":
        loss_fn_core = lambda logit, y: bce_logits(logit, y)
        y_train = y_train.astype(np.int32)
        y_val_np = y_val.astype(np.int32)
    else:
        loss_fn_core = lambda pred, y: mse(pred, y)
        y_val_np = y_val

    @jax.jit
    def step(params, opt, xb, yb, masks, lr_now):
        def loss(p):
            out = kan_forward(p, xb, cfg, masks=masks, quantized=quantized)
            return loss_fn_core(out, yb)

        l, g = jax.value_and_grad(loss)(params)
        params, opt = adamw_update(params, g, opt, lr_now, weight_decay=weight_decay)
        return params, opt, l

    @jax.jit
    def infer(params, xb, masks):
        return kan_forward(params, xb, cfg, masks=masks, quantized=quantized)

    rng = np.random.default_rng(seed)
    history = []
    t_start = time.time()
    n = x_train.shape[0]
    bs = min(batch_size, n)
    for epoch in range(epochs):
        masks = prune_mod.compute_masks(params, cfg, epoch)
        lr_now = lr * 0.5 * (1 + np.cos(np.pi * epoch / max(epochs - 1, 1)))
        losses = []
        for bidx in _batches(rng, n, bs):
            xb = jnp.asarray(x_train[bidx])
            yb = jnp.asarray(y_train[bidx])
            params, opt, l = step(params, opt, xb, yb, masks, lr_now)
            losses.append(float(l))
        val_out = np.asarray(infer(params, jnp.asarray(x_val), masks))
        if task == "classify":
            val_metric = accuracy(val_out, y_val_np)
        elif task == "binary":
            val_metric = float(((val_out[:, 0] > 0).astype(np.int32) == y_val_np).mean())
        else:
            val_metric = -float(np.mean((val_out - y_val_np) ** 2))
        rec = {
            "epoch": epoch,
            "loss": float(np.mean(losses)) if losses else float("nan"),
            "val": val_metric,
            "edges": prune_mod.active_edges(masks),
            "tau": prune_mod.tau(epoch, cfg.prune_threshold, cfg.warmup_start, cfg.warmup_target),
        }
        history.append(rec)
        if log:
            log(
                f"epoch {epoch:3d} loss {rec['loss']:.4f} val {rec['val']:.4f} "
                f"edges {rec['edges']} tau {rec['tau']:.3g}"
            )

    # final masks at the fully warmed-up threshold
    masks = prune_mod.compute_masks(params, cfg, cfg.warmup_target)
    return TrainResult(params=params, masks=masks, history=history, cfg=cfg, seconds=time.time() - t_start)


def train_mlp(
    dims: tuple[int, ...],
    x_train: np.ndarray,
    y_train: np.ndarray,
    x_val: np.ndarray,
    y_val: np.ndarray,
    *,
    epochs: int = 30,
    batch_size: int = 128,
    lr: float = 3e-3,
    weight_decay: float = 1e-4,
    seed: int = 0,
    task: str = "classify",
    quant=None,
    log: Callable[[str], None] | None = None,
):
    """Baseline MLP trainer (Table 2 "MLP FP" column)."""
    from .layers import init_mlp, mlp_forward

    key = jax.random.PRNGKey(seed)
    params = init_mlp(key, dims)
    opt = adamw_init(params)
    if task == "classify":
        loss_fn_core = lambda logits, y: softmax_xent(logits, y)
        y_train = y_train.astype(np.int32)
        y_val_np = y_val.astype(np.int32)
    elif task == "binary":
        loss_fn_core = lambda logit, y: bce_logits(logit, y)
        y_train = y_train.astype(np.int32)
        y_val_np = y_val.astype(np.int32)
    else:
        loss_fn_core = lambda pred, y: mse(pred, y)
        y_val_np = y_val

    @jax.jit
    def step(params, opt, xb, yb, lr_now):
        def loss(p):
            return loss_fn_core(mlp_forward(p, xb, quant=quant), yb)

        l, g = jax.value_and_grad(loss)(params)
        params, opt = adamw_update(params, g, opt, lr_now, weight_decay=weight_decay)
        return params, opt, l

    @jax.jit
    def infer(params, xb):
        return mlp_forward(params, xb, quant=quant)

    rng = np.random.default_rng(seed)
    history = []
    n = x_train.shape[0]
    bs = min(batch_size, n)
    for epoch in range(epochs):
        lr_now = lr * 0.5 * (1 + np.cos(np.pi * epoch / max(epochs - 1, 1)))
        losses = []
        for bidx in _batches(rng, n, bs):
            params, opt, l = step(params, opt, jnp.asarray(x_train[bidx]), jnp.asarray(y_train[bidx]), lr_now)
            losses.append(float(l))
        val_out = np.asarray(infer(params, jnp.asarray(x_val)))
        if task == "classify":
            val_metric = accuracy(val_out, y_val_np)
        elif task == "binary":
            val_metric = float(((val_out[:, 0] > 0).astype(np.int32) == y_val_np).mean())
        else:
            val_metric = -float(np.mean((val_out - y_val_np) ** 2))
        history.append({"epoch": epoch, "loss": float(np.mean(losses)), "val": val_metric})
        if log:
            log(f"mlp epoch {epoch:3d} loss {history[-1]['loss']:.4f} val {val_metric:.4f}")
    return params, history
