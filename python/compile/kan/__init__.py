"""KANELE build-time KAN library (JAX).

Everything here runs at *compile time* only: training, quantization-aware
training, pruning, checkpoint export and AOT lowering. Nothing in this
package is imported on the Rust request path.
"""

from . import bspline, layers, prune, quant, train  # noqa: F401
