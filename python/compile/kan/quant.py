"""Quantizers (paper Eq. 6-9) with straight-through estimators.

Two quantizer flavours, both uniform over the fixed spline domain [a, b]:

* **Layer-output quantizer** (Eq. 7): learnable scale ``s_l`` (frozen at
  export), clip to [a, b], round to the n_l-bit code grid.
* **Input quantizer** (Eq. 8): scale + bias for asymmetric inputs. In the
  toolflow this is realised as BN(zero-mean/unit-var) folded with a
  ScalarBiasScale block into a single affine shift-scale + clip + quantize.

Hardware contract (mirrored in ``rust/src/fixed``): an ``n``-bit quantizer
over [a, b] with scale ``s`` exposes *codes* ``c in {0 .. 2^n - 1}`` with
dequantized value ``a + c * s`` and ``s = (b - a) / (2^n - 1)`` at export
time (training may learn s; export renormalizes to the code grid).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class QuantSpec(NamedTuple):
    """Static description of one uniform quantizer."""

    bits: int
    lo: float
    hi: float

    @property
    def levels(self) -> int:
        return 1 << self.bits

    @property
    def scale(self) -> float:
        return (self.hi - self.lo) / (self.levels - 1)


def round_ste(x: jnp.ndarray) -> jnp.ndarray:
    """Round with a straight-through gradient (paper Eq. 9)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def fake_quant(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Eq. 7 with the export-time (frozen) scale: clip -> scale -> round -> descale."""
    s = spec.scale
    xq = jnp.clip(x, spec.lo, spec.hi)
    code = round_ste((xq - spec.lo) / s)
    return spec.lo + code * s


def fake_quant_learned(x: jnp.ndarray, spec: QuantSpec, log_s: jnp.ndarray) -> jnp.ndarray:
    """Eq. 7 with a learnable scale ``s_l = exp(log_s)`` (training only).

    The clip domain stays the fixed [a, b]; the code grid is anchored at
    ``lo`` so the zero-point is shared with the frozen form.
    """
    s = jnp.exp(log_s)
    xq = jnp.clip(x, spec.lo, spec.hi)
    code = round_ste((xq - spec.lo) / s)
    # re-clip codes so a small learned s cannot escape the domain
    code = jnp.clip(code, 0.0, float(spec.levels - 1) * spec.scale / jnp.maximum(s, 1e-8))
    return spec.lo + code * s


def quantize_codes_np(x: np.ndarray, spec: QuantSpec) -> np.ndarray:
    """Integer codes for export / oracle vectors (numpy f64, banker-free).

    Uses round-half-away-from-zero on the non-negative shifted value, which
    equals ``floor(v + 0.5)`` — the same rule the Rust side implements —
    rather than numpy's banker rounding.
    """
    x = np.asarray(x, dtype=np.float64)
    v = (np.clip(x, spec.lo, spec.hi) - spec.lo) / spec.scale
    return np.clip(np.floor(v + 0.5), 0, spec.levels - 1).astype(np.int64)


def dequantize_codes_np(codes: np.ndarray, spec: QuantSpec) -> np.ndarray:
    """Dequantized f64 values for integer codes."""
    return spec.lo + np.asarray(codes, dtype=np.float64) * spec.scale


class InputPreproc(NamedTuple):
    """Folded BN + ScalarBiasScale: y = (x - shift) / span (Eq. 8 affine).

    ``shift``/``span`` are per-feature; at export they are frozen constants.
    The quantizer that follows uses a shared [a, b] domain.
    """

    shift: np.ndarray  # (d_in,)
    span: np.ndarray  # (d_in,)

    def apply_np(self, x: np.ndarray) -> np.ndarray:
        return (np.asarray(x, dtype=np.float64) - self.shift) / self.span

    def apply_jnp(self, x: jnp.ndarray) -> jnp.ndarray:
        return (x - jnp.asarray(self.shift, x.dtype)) / jnp.asarray(self.span, x.dtype)


def fit_input_preproc(x_train: np.ndarray, spec: QuantSpec, coverage: float = 3.0) -> InputPreproc:
    """Fit the folded affine so ``coverage`` std-devs map inside [a, b].

    BN gives zero-mean/unit-variance; the ScalarBiasScale then stretches the
    +-coverage sigma band onto the quantizer domain. Constant features get
    span 1 to avoid division by zero.
    """
    x_train = np.asarray(x_train, dtype=np.float64)
    mu = x_train.mean(axis=0)
    sd = x_train.std(axis=0)
    sd = np.where(sd < 1e-12, 1.0, sd)
    half = (spec.hi - spec.lo) / 2.0
    center = (spec.hi + spec.lo) / 2.0
    # y = ((x - mu)/sd) * (half/coverage) + center  ==  (x - shift)/span
    span = sd * coverage / half
    shift = mu - center * span
    return InputPreproc(shift=shift, span=span)
