"""Structured edge pruning (paper §3.3, Eq. 10-12).

Each edge's importance is the L2 norm of its spline component over an input
grid consistent with the layer's quantization level. Edges below the warmup
threshold tau(t) are masked; backward pruning then removes edges feeding
output neurons that have no surviving fan-out in the next layer.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from .layers import KanCfg, edge_norms


def tau(t: int, threshold: float, t0: int, tf: int) -> float:
    """Exponential warmup: tau rises to ~95% of T at the target epoch tf.

    tau(t) = T * exp(-ln 20 * (tf - max(t, t0)) / (tf - t0)), clamped so that
    tau(t0) = T/20 and tau(>= tf) = T. (The paper's Eq. prints the decaying
    form of the *gap*; operationally pruning starts gently at t0 and reaches
    the full threshold at tf, which is what this implements.)
    """
    if threshold <= 0.0:
        return 0.0
    if tf <= t0:
        return threshold
    tt = min(max(t, t0), tf)
    return threshold * math.exp(-math.log(20.0) * (tf - tt) / (tf - t0))


def compute_masks(
    params: list[dict],
    cfg: KanCfg,
    epoch: int,
) -> list[jnp.ndarray]:
    """Eq. 12 masks for every layer at the given epoch, with backward pruning."""
    thr = tau(epoch, cfg.prune_threshold, cfg.warmup_start, cfg.warmup_target)
    masks: list[np.ndarray] = []
    for l in range(cfg.n_layers):
        lcfg = cfg.layer_cfg(l)
        n_in_bits = cfg.bits[l]
        norms = np.asarray(edge_norms(params[l], lcfg, n_grid_samples=1 << min(n_in_bits, 8)))
        masks.append((norms > thr).astype(np.float32))

    # Backward pruning: if output neuron j of layer l has no active outgoing
    # edge in layer l+1, every incoming edge (j, :) of layer l is dead too.
    for l in range(cfg.n_layers - 2, -1, -1):
        fanout_alive = masks[l + 1].sum(axis=0) > 0  # (d_{l+1},) indexed by input of l+1
        masks[l] = masks[l] * fanout_alive[:, None].astype(np.float32)

    # Never allow a layer to go fully dead (keeps training stable early on):
    # if a mask is all-zero, keep its single strongest edge.
    for l in range(cfg.n_layers):
        if masks[l].sum() == 0:
            lcfg = cfg.layer_cfg(l)
            norms = np.asarray(edge_norms(params[l], lcfg))
            q, p = np.unravel_index(np.argmax(norms), norms.shape)
            masks[l][q, p] = 1.0

    return [jnp.asarray(m) for m in masks]


def active_edges(masks: list[jnp.ndarray]) -> int:
    """Total surviving edges — proportional to LUT/FF cost (paper Fig. 6b)."""
    return int(sum(int(m.sum()) for m in masks))


def full_masks(cfg: KanCfg) -> list[jnp.ndarray]:
    """All-ones masks (unpruned model)."""
    return [jnp.ones((cfg.dims[l + 1], cfg.dims[l]), dtype=jnp.float32) for l in range(cfg.n_layers)]
