"""Re-export a checkpoint at a different accumulator precision (frac_bits).

The fixed-point fraction width F is a pure *export/hardware* parameter:
tables are round(phi * 2^F), so lowering F narrows every LUT output and
adder in the netlist (LUT/FF/AxD down) at the cost of coarser pre-requant
sums. This script rebuilds tables + oracle vectors at the requested F and
reports the accuracy of the integer pipeline so the §Perf sweep can pick
the knee.

    python -m compile.reexport moons --frac-bits 10
    python -m compile.reexport --all --frac-bits 10     # overwrite in place
    python -m compile.reexport moons --sweep            # report-only sweep
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from .export import ExportedModel, build_tables, quantized_int_forward
from .kan.layers import KanCfg
from .kan.quant import InputPreproc
from .trainer import ART


def load_ckpt(path: str):
    with open(path) as f:
        doc = json.load(f)
    cfg = KanCfg(
        dims=tuple(doc["dims"]), grid_size=doc["grid_size"], order=doc["order"],
        domain=tuple(doc["domain"]), bits=tuple(doc["bits"]),
        prune_threshold=doc.get("prune_threshold", 0.0),
    )
    params = [
        {"w_spline": np.asarray(l["w_spline"], np.float64),
         "w_base": np.asarray(l["w_base"], np.float64)}
        for l in doc["layers"]
    ]
    masks = [np.asarray(l["mask"], np.float32) for l in doc["layers"]]
    pre = InputPreproc(
        shift=np.asarray(doc["preproc"]["shift"], np.float64),
        span=np.asarray(doc["preproc"]["span"], np.float64),
    )
    return doc, cfg, params, masks, pre


def metric_at(doc, cfg, params, masks, pre, frac_bits: int, ts_path: str | None):
    tables = build_tables(params, masks, cfg, frac_bits)
    model = ExportedModel(cfg=cfg, preproc=pre, frac_bits=frac_bits, masks=masks, tables=tables)
    if ts_path and os.path.exists(ts_path):
        with open(ts_path) as f:
            ts = json.load(f)
        codes = np.asarray(ts["input_codes"], np.int64)
        labels = np.asarray(ts["labels"], np.int64)
        sums = quantized_int_forward(model, codes)
        task = doc["task"]
        if task == "classify":
            m = float((np.argmax(sums, 1) == labels).mean())
        elif task == "binary":
            m = float(((sums[:, 0] > 0).astype(np.int64) == labels).mean())
        else:
            m = float("nan")  # regress handled by the rust AUC path
        return model, m
    return model, float("nan")


def reexport(name: str, frac_bits: int, write: bool) -> dict:
    path = os.path.join(ART, f"{name}.ckpt.json")
    ts_path = os.path.join(ART, f"{name}.testset.json")
    doc, cfg, params, masks, pre = load_ckpt(path)
    old_f = doc["frac_bits"]
    _, m_old = metric_at(doc, cfg, params, masks, pre, old_f, ts_path)
    model, m_new = metric_at(doc, cfg, params, masks, pre, frac_bits, ts_path)
    rec = {"name": name, "old_frac_bits": old_f, "new_frac_bits": frac_bits,
           "metric_old": m_old, "metric_new": m_new}
    if write:
        nv = len(doc["test_vectors"]["input_codes"])
        tv_codes = np.asarray(doc["test_vectors"]["input_codes"], np.int64)
        doc["frac_bits"] = frac_bits
        for l, layer in enumerate(doc["layers"]):
            layer["table"] = [
                [None if t is None else t.tolist() for t in model.tables[l][q]]
                for q in range(layer["d_out"])
            ]
        doc["test_vectors"]["output_sums"] = quantized_int_forward(model, tv_codes).tolist()
        with open(path, "w") as f:
            json.dump(doc, f)
        rec["written"] = True
        assert nv == len(doc["test_vectors"]["input_codes"])
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="*")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--frac-bits", type=int, default=10)
    ap.add_argument("--sweep", action="store_true", help="report-only sweep over F")
    args = ap.parse_args()
    names = args.names
    if args.all:
        names = [f[: -len(".ckpt.json")] for f in sorted(os.listdir(ART)) if f.endswith(".ckpt.json")]
    for name in names:
        if args.sweep:
            path = os.path.join(ART, f"{name}.ckpt.json")
            ts_path = os.path.join(ART, f"{name}.testset.json")
            doc, cfg, params, masks, pre = load_ckpt(path)
            for f_ in [8, 10, 12, 14, 16]:
                _, m = metric_at(doc, cfg, params, masks, pre, f_, ts_path)
                print(f"{name}: F={f_:2d} metric={m:.4f}")
        else:
            rec = reexport(name, args.frac_bits, write=True)
            print(rec)


if __name__ == "__main__":
    main()
