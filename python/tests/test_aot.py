"""AOT lowering: HLO text artifacts parse, embed constants, and round-trip."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import export_demo, export_kan_inference, to_hlo_text

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_demo_export(tmp_path):
    p = str(tmp_path / "demo.hlo.txt")
    text = export_demo(p)
    assert "ENTRY" in text
    assert os.path.getsize(p) > 100


def test_pallas_demo_export(tmp_path):
    p = str(tmp_path / "demo_pallas.hlo.txt")
    text = export_demo(p, use_pallas=True)
    assert "ENTRY" in text


def test_constants_not_elided():
    """XLA 0.5.1 reads elided `constant({...})` payloads back as ZEROS —
    the bug class that produced NaN end-to-end. Guard against regression."""
    w = jnp.asarray(np.arange(100, dtype=np.float32))
    f = lambda x: (x + w,)
    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((100,), jnp.float32))
    text = to_hlo_text(lowered)
    assert "{...}" not in text
    assert "98, 99" in text.replace(".0", "")  # payload actually present


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "moons.ckpt.json")),
    reason="needs make artifacts",
)
def test_kan_inference_export(tmp_path):
    p = str(tmp_path / "moons.hlo.txt")
    text = export_kan_inference(os.path.join(ART, "moons.ckpt.json"), p, batch=16)
    assert "ENTRY" in text
    assert "{...}" not in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "moons.ckpt.json")),
    reason="needs make artifacts",
)
def test_kernel_and_jnp_paths_agree(tmp_path):
    """The Pallas-kernel lowering and the plain-jnp lowering must compute
    the same function (argmax/threshold agreement on random inputs)."""
    from compile.aot import _kan_infer_fn, load_ckpt_jax

    cfg, params, masks, shift, span = load_ckpt_jax(os.path.join(ART, "moons.ckpt.json"))
    fk = _kan_infer_fn(cfg, params, masks, shift, span, use_kernel=True)
    fj = _kan_infer_fn(cfg, params, masks, shift, span, use_kernel=False)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1.5, (32, cfg.dims[0])), jnp.float32)
    a = np.asarray(fk(x)[0])
    b = np.asarray(fj(x)[0])
    np.testing.assert_allclose(a, b, atol=1e-3, rtol=1e-3)
