"""RL substrate tests: env semantics, actors, tiny PPO smoke."""

import jax
import numpy as np

from compile.rl import actors
from compile.rl.cheetah import ACT_DIM, EPISODE_LEN, OBS_DIM, CheetahLite
from compile.rl.ppo import PpoCfg, train


def test_env_shapes_and_reset():
    env = CheetahLite(4, seed=0)
    obs = env.reset()
    assert obs.shape == (4, OBS_DIM)
    o2, r, d = env.step(np.zeros((4, ACT_DIM)))
    assert o2.shape == (4, OBS_DIM)
    assert r.shape == (4,)
    assert not d.any()


def test_env_deterministic():
    a, b = CheetahLite(2, seed=7), CheetahLite(2, seed=7)
    act = np.full((2, ACT_DIM), 0.3)
    for _ in range(20):
        oa, ra, _ = a.step(act)
        ob, rb, _ = b.step(act)
        np.testing.assert_array_equal(oa, ob)
        np.testing.assert_array_equal(ra, rb)


def test_env_episode_autoreset():
    env = CheetahLite(1, seed=1)
    env.reset()
    for t in range(EPISODE_LEN):
        _, _, d = env.step(np.zeros((1, ACT_DIM)))
    assert d.any()  # final step flagged done
    # after auto-reset the internal clock restarted
    assert env.t[0] == 0


def test_coordinated_gait_beats_idle():
    from compile.rl.cheetah import _COUPLE, _PHI

    def run(policy):
        env = CheetahLite(1, seed=3)
        obs = env.reset()
        total = 0.0
        for _ in range(300):
            act = policy(obs)
            obs, r, _ = env.step(act)
            total += float(r[0])
        return total

    idle = run(lambda o: np.zeros((1, ACT_DIM)))
    gait = run(lambda o: np.clip(np.sin(o[:, 2:8] + _PHI) * _COUPLE, -1, 1))
    assert gait > idle + 10


def test_actor_shapes_all_kinds():
    key = jax.random.PRNGKey(0)
    obs = np.zeros((5, OBS_DIM), np.float32)
    for kind in ["mlp_fp", "mlp_q8", "kan_fp", "kan_q8"]:
        a = actors.init_actor(kind, key)
        out = np.asarray(actors.actor_mean(kind, a, obs))
        assert out.shape == (5, ACT_DIM), kind
        assert np.isfinite(out).all(), kind


def test_param_counts_match_table6():
    pc = actors.param_counts()
    assert pc["kan_actor"] == 1020
    assert pc["mlp_actor"] > 5 * pc["kan_actor"]  # the paper's ~5x claim


def test_ppo_smoke_improves():
    cfg = PpoCfg(total_steps=8192, n_envs=8, rollout=64)
    r = train("kan_q8", seed=0, cfg=cfg)
    assert len(r["steps"]) == len(r["returns"]) > 0
    assert np.isfinite(r["final_return"])
    # learning signal: late returns no worse than the first rollout by a margin
    assert r["returns"][-1] > r["returns"][0] - 50.0
