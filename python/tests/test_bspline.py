"""B-spline basis properties: partition of unity, locality, numpy/jnp parity."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kan import bspline


@pytest.mark.parametrize("grid,order", [(4, 2), (6, 3), (30, 10), (40, 10), (1, 0)])
def test_partition_of_unity(grid, order):
    knots = bspline.make_knots(grid, (-2.0, 2.0), order)
    xs = np.linspace(-2, 2, 201)
    b = bspline.bspline_basis_np(xs, knots, order)
    assert b.shape == (201, grid + order)
    np.testing.assert_allclose(b.sum(-1), 1.0, atol=1e-9)


def test_knot_vector():
    k = bspline.make_knots(6, (-8.0, 8.0), 3)
    assert len(k) == 6 + 2 * 3 + 1
    assert k[3] == -8.0 and k[-4] == 8.0
    np.testing.assert_allclose(np.diff(k), np.diff(k)[0])


def test_invalid_args():
    with pytest.raises(ValueError):
        bspline.make_knots(0, (-1, 1), 2)
    with pytest.raises(ValueError):
        bspline.make_knots(4, (1, -1), 2)
    with pytest.raises(ValueError):
        bspline.make_knots(4, (-1, 1), -1)


def test_clamping_outside_domain():
    knots = bspline.make_knots(6, (-8.0, 8.0), 3)
    inside = bspline.bspline_basis_np(np.array([8.0]), knots, 3)
    outside = bspline.bspline_basis_np(np.array([100.0]), knots, 3)
    np.testing.assert_array_equal(inside, outside)


def test_nonnegativity_and_locality():
    knots = bspline.make_knots(8, (0.0, 8.0), 3)
    b = bspline.bspline_basis_np(np.array([0.5]), knots, 3)[0]
    assert (b >= -1e-12).all()
    assert np.all(b[4:] == 0.0)  # support limited to order+1 intervals


@settings(max_examples=50, deadline=None)
@given(
    grid=st.integers(1, 20),
    order=st.integers(0, 6),
    # f32-representable inputs: jax runs f32 here (x64 disabled), and a
    # float64 denormal that rounds across a knot boundary when cast is a
    # representation artifact, not an algorithm divergence
    x=st.floats(-10, 10, allow_nan=False, allow_subnormal=False, width=32),
)
def test_jnp_matches_np(grid, order, x):
    knots = bspline.make_knots(grid, (-3.0, 3.0), order)
    b_np = bspline.bspline_basis_np(np.array([x]), knots, order)
    b_j = np.asarray(bspline.bspline_basis(jnp.asarray([x], jnp.float32), knots, order))
    np.testing.assert_allclose(b_np, b_j, atol=5e-6)


def test_silu_twins():
    xs = np.linspace(-20, 20, 101)
    np.testing.assert_allclose(
        bspline.silu_np(xs), np.asarray(bspline.silu(jnp.asarray(xs))), atol=5e-6
    )
