"""Fourier-basis KAN (paper §6 extension): trains, tabulates, LUT-compatible."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import datasets
from compile.kan.fourier import (
    build_fourier_tables,
    edge_phi_fourier_np,
    fourier_basis,
    fourier_basis_np,
    fourier_kan_forward,
    init_fourier_kan,
    num_features,
)
from compile.kan.quant import QuantSpec, quantize_codes_np
from compile.kan.train import adamw_init, adamw_update, bce_logits


def test_basis_shapes_and_twins():
    xs = np.linspace(-4, 4, 33)
    H = 3
    b_np = fourier_basis_np(xs, H, (-4.0, 4.0))
    b_j = np.asarray(fourier_basis(jnp.asarray(xs, jnp.float32), H, (-4.0, 4.0)))
    assert b_np.shape == (33, num_features(H))
    np.testing.assert_allclose(b_np, b_j, atol=1e-5)
    # DC feature is 1 everywhere
    np.testing.assert_array_equal(b_np[:, 0], 1.0)


def test_basis_periodic_on_domain():
    H = 4
    a, b = -2.0, 2.0
    ba = fourier_basis_np(np.array([a]), H, (a, b))
    bb = fourier_basis_np(np.array([b]), H, (a, b))
    np.testing.assert_allclose(ba, bb, atol=1e-9)  # full period across domain


def test_fourier_kan_trains_on_moons():
    x_tr, y_tr, x_te, y_te = datasets.moons(n=1200, seed=2)
    dims, H, dom, bits = (2, 4, 1), 4, (-4.0, 4.0), (6, 6, 8)
    params = init_fourier_kan(jax.random.PRNGKey(0), dims, H)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, xb, yb):
        def loss(p):
            out = fourier_kan_forward(p, xb, dims, H, dom, bits=bits)
            return bce_logits(out, yb)

        l, g = jax.value_and_grad(loss)(params)
        params, opt = adamw_update(params, g, opt, 1e-2, weight_decay=0.0)
        return params, opt, l

    xj = jnp.asarray(x_tr * 1.5)
    yj = jnp.asarray(y_tr.astype(np.int32))
    for _ in range(300):
        params, opt, l = step(params, opt, xj, yj)
    out = np.asarray(fourier_kan_forward(params, jnp.asarray(x_te * 1.5), dims, H, dom, bits=bits))
    acc = (((out[:, 0] > 0).astype(np.int64)) == y_te).mean()
    assert acc > 0.9, acc


def test_fourier_tables_lut_compatible():
    """The whole point of §6: a Fourier KAN tabulates exactly like B-splines,
    so the integer pipeline (= the Rust netlist semantics) applies unchanged."""
    dims, H, dom, bits, F = (3, 2), 2, (-2.0, 2.0), (4, 6), 12
    params = init_fourier_kan(jax.random.PRNGKey(1), dims, H)
    tables = build_fourier_tables(
        [{"w": np.asarray(p["w"])} for p in params], dims, H, dom, bits, F
    )
    assert len(tables) == 1
    assert len(tables[0]) == 2 and len(tables[0][0]) == 3
    assert tables[0][0][0].shape == (16,)
    # integer pipeline vs float forward at the quantized points
    spec = QuantSpec(4, -2.0, 2.0)
    rng = np.random.default_rng(0)
    x = rng.uniform(-2, 2, (32, 3))
    codes = quantize_codes_np(x, spec)
    ints = np.zeros((32, 2), np.int64)
    for q in range(2):
        for p in range(3):
            ints[:, q] += tables[0][q][p][codes[:, p]]
    got = ints.astype(np.float64) / (1 << F)
    xq = spec.lo + codes * spec.scale
    want = np.asarray(
        fourier_kan_forward(params, jnp.asarray(xq, jnp.float32), dims, H, dom, bits=None)
    )
    np.testing.assert_allclose(got, want, atol=2e-3)


def test_edge_phi_accumulation_order():
    w = np.array([1.0, 0.5, -0.25, 0.125, 0.0625])
    x = np.array([0.3, -1.1])
    phi = edge_phi_fourier_np(x, w, 2, (-2.0, 2.0))
    basis = fourier_basis_np(x, 2, (-2.0, 2.0))
    np.testing.assert_allclose(phi, basis @ w, atol=1e-12)
