"""Export contract: table generation, integer-pipeline oracle, round-trips."""

import json

import jax
import numpy as np
import pytest

from compile.export import (
    ExportedModel,
    build_tables,
    edge_phi_np,
    export_checkpoint,
    export_testset,
    input_codes_from_raw,
    quantized_int_forward,
    round_half_away_np,
)
from compile.kan.bspline import make_knots
from compile.kan.layers import KanCfg, init_kan, kan_forward
from compile.kan.prune import full_masks
from compile.kan.quant import InputPreproc, QuantSpec, dequantize_codes_np


def _small():
    cfg = KanCfg(dims=(3, 4, 2), grid_size=4, order=2, domain=(-2.0, 2.0),
                 bits=(4, 5, 6), prune_threshold=0.0)
    params = init_kan(jax.random.PRNGKey(0), cfg)
    params = [
        {"w_spline": np.asarray(p["w_spline"], np.float64),
         "w_base": np.asarray(p["w_base"], np.float64)}
        for p in params
    ]
    masks = full_masks(cfg)
    return cfg, params, masks


def test_round_half_away():
    np.testing.assert_array_equal(
        round_half_away_np(np.array([0.5, -0.5, 1.5, -1.5, 2.4, -2.4])),
        [1, -1, 2, -2, 2, -2],
    )


def test_tables_shapes_and_masking():
    cfg, params, masks = _small()
    masks[0] = masks[0].at[1, 2].set(0.0)
    tables = build_tables(params, masks, cfg, frac_bits=12)
    assert len(tables) == 2
    assert tables[0][1][2] is None
    assert tables[0][0][0].shape == (16,)  # 2^4 codes
    assert tables[1][0][0].shape == (32,)  # 2^5 codes
    assert tables[0][0][0].dtype == np.int64


def test_edge_phi_matches_layer_decomposition():
    cfg, params, _ = _small()
    lcfg = cfg.layer_cfg(0)
    knots = make_knots(cfg.grid_size, cfg.domain, cfg.order)
    xs = np.linspace(-2, 2, 9)
    # layer output q = sum_p phi_qp(x_p): check against kan_forward for a
    # one-hot style input where all features carry the same value
    import jax.numpy as jnp

    x = jnp.asarray(np.tile(xs[:, None], (1, 3)), jnp.float32)
    full = np.asarray(kan_forward([{k: jnp.asarray(v) for k, v in params[0].items()}],
                                  x, KanCfg(dims=(3, 4), grid_size=4, order=2,
                                            domain=(-2.0, 2.0), bits=(4, 5)),
                                  quantized=False))
    manual = np.zeros_like(full)
    for q in range(4):
        for p in range(3):
            manual[:, q] += edge_phi_np(xs, params[0]["w_spline"][q, p],
                                        params[0]["w_base"][q, p], knots, cfg.order)
    np.testing.assert_allclose(full, manual, atol=1e-4)


def test_int_forward_deterministic_and_bounded():
    cfg, params, masks = _small()
    tables = build_tables(params, masks, cfg, frac_bits=12)
    model = ExportedModel(cfg=cfg, preproc=InputPreproc(np.zeros(3), np.ones(3)),
                          frac_bits=12, masks=masks, tables=tables)
    rng = np.random.default_rng(1)
    codes = rng.integers(0, 16, (20, 3))
    out1 = quantized_int_forward(model, codes)
    out2 = quantized_int_forward(model, codes)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (20, 2)
    # exact-integer bound: sum of per-table extremes
    for q in range(2):
        hi = sum(t.max() for t in (model.tables[1][q][p] for p in range(4)) if t is not None)
        lo = sum(t.min() for t in (model.tables[1][q][p] for p in range(4)) if t is not None)
        assert out1[:, q].max() <= hi and out1[:, q].min() >= lo


def test_int_forward_agrees_with_fake_quant_model():
    """The integer pipeline must track the QAT fake-quant model closely."""
    import jax.numpy as jnp

    cfg, params, masks = _small()
    tables = build_tables(params, masks, cfg, frac_bits=14)
    model = ExportedModel(cfg=cfg, preproc=InputPreproc(np.zeros(3), np.ones(3)),
                          frac_bits=14, masks=masks, tables=tables)
    rng = np.random.default_rng(2)
    x = rng.uniform(-2, 2, (64, 3))
    codes = input_codes_from_raw(model, x)
    ints = quantized_int_forward(model, codes).astype(np.float64) / (1 << 14)
    jparams = [{k: jnp.asarray(v, jnp.float32) for k, v in p.items()} for p in params]
    # feed the *dequantized* values so both paths see identical inputs
    xq = dequantize_codes_np(codes, cfg.input_quant)
    fq = np.asarray(kan_forward(jparams, jnp.asarray(xq, jnp.float32), cfg,
                                masks=masks, quantized=True))
    np.testing.assert_allclose(ints, fq, atol=2e-3)


def test_checkpoint_file_roundtrip(tmp_path):
    cfg, params, masks = _small()
    x_test = np.random.default_rng(3).uniform(-2, 2, (50, 3))
    y_test = np.zeros(50, np.int64)
    pre = InputPreproc(np.zeros(3), np.ones(3))
    path = str(tmp_path / "t.ckpt.json")
    model = export_checkpoint(path, "t", "classify", cfg, params, masks, pre,
                              x_test, y_test, {"m": 1.0}, frac_bits=12,
                              n_test_vectors=16)
    doc = json.load(open(path))
    assert doc["format"] == "kanele-ckpt-v1"
    assert doc["dims"] == [3, 4, 2]
    assert len(doc["test_vectors"]["input_codes"]) == 16
    # oracle vectors replay exactly
    codes = np.asarray(doc["test_vectors"]["input_codes"])
    sums = np.asarray(doc["test_vectors"]["output_sums"])
    np.testing.assert_array_equal(quantized_int_forward(model, codes), sums)

    ts_path = str(tmp_path / "t.testset.json")
    export_testset(ts_path, model, x_test, y_test, limit=30)
    ts = json.load(open(ts_path))
    assert len(ts["input_codes"]) == 30
    assert len(ts["labels"]) == 30


def test_pruned_edges_do_not_contribute():
    cfg, params, masks = _small()
    masks = [m.at[:].set(1.0) for m in masks]
    t_full = build_tables(params, masks, cfg, 12)
    masks2 = [m.at[0, 0].set(0.0) if i == 0 else m for i, m in enumerate(masks)]
    t_pruned = build_tables(params, masks2, cfg, 12)
    model_f = ExportedModel(cfg, InputPreproc(np.zeros(3), np.ones(3)), 12, masks, t_full)
    model_p = ExportedModel(cfg, InputPreproc(np.zeros(3), np.ones(3)), 12, masks2, t_pruned)
    codes = np.random.default_rng(4).integers(0, 16, (8, 3))
    # outputs must differ exactly by the removed edge's table values
    a = quantized_int_forward(model_f, codes)
    b = quantized_int_forward(model_p, codes)
    assert not np.array_equal(a, b) or np.all(t_full[0][0][0] == 0)
