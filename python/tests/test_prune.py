"""Pruning (Eq. 10-12): tau warmup, norm masking, backward propagation."""

import jax
import numpy as np
import pytest

from compile.kan.layers import KanCfg, init_kan
from compile.kan.prune import active_edges, compute_masks, full_masks, tau


def test_tau_warmup_shape():
    T, t0, tf = 2.0, 5, 20
    assert tau(0, T, t0, tf) == tau(5, T, t0, tf)  # flat before t0
    assert tau(5, T, t0, tf) == pytest.approx(T / 20)  # starts at 5% of T
    assert tau(tf, T, t0, tf) == pytest.approx(T)  # full at tf
    assert tau(tf + 100, T, t0, tf) == pytest.approx(T)  # clamped after
    # monotone nondecreasing
    vals = [tau(t, T, t0, tf) for t in range(0, 30)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))


def test_tau_zero_threshold():
    assert tau(10, 0.0, 0, 5) == 0.0


def _cfg(T=0.5):
    return KanCfg(dims=(4, 3, 2), grid_size=4, order=2, domain=(-2.0, 2.0),
                  bits=(4, 4, 6), prune_threshold=T, warmup_start=0, warmup_target=4)


def test_full_masks_all_ones():
    cfg = _cfg()
    ms = full_masks(cfg)
    assert [m.shape for m in ms] == [(3, 4), (2, 3)]
    assert active_edges(ms) == 12 + 6


def test_masks_prune_under_threshold():
    cfg = _cfg(T=1e9)  # absurd threshold kills everything...
    params = init_kan(jax.random.PRNGKey(0), cfg)
    ms = compute_masks(params, cfg, epoch=100)
    # ...except the keep-strongest-edge protection
    assert all(np.asarray(m).sum() >= 1 for m in ms)
    assert active_edges(ms) <= 4


def test_no_pruning_when_threshold_zero():
    cfg = _cfg(T=0.0)
    params = init_kan(jax.random.PRNGKey(1), cfg)
    ms = compute_masks(params, cfg, epoch=100)
    assert active_edges(ms) == 18


def test_backward_pruning_propagates():
    cfg = _cfg(T=0.0)
    params = init_kan(jax.random.PRNGKey(2), cfg)
    # kill every layer-1 edge reading hidden neuron 0 by zeroing its weights;
    # with a tiny threshold those edges prune, and backward pruning must then
    # kill all of layer 0's edges INTO hidden neuron 0
    cfg2 = _cfg(T=1e-6)
    params[1]["w_spline"] = params[1]["w_spline"].at[:, 0, :].set(0.0)
    ms = compute_masks(params, cfg2, epoch=100)
    m1 = np.asarray(ms[1])
    m0 = np.asarray(ms[0])
    assert m1[:, 0].sum() == 0, "layer-1 edges from hidden 0 should be pruned"
    assert m0[0, :].sum() == 0, "backward pruning should kill edges into hidden 0"


def test_mask_shapes_match_layers():
    cfg = _cfg(T=0.1)
    params = init_kan(jax.random.PRNGKey(3), cfg)
    ms = compute_masks(params, cfg, epoch=2)
    assert ms[0].shape == (3, 4)
    assert ms[1].shape == (2, 3)
