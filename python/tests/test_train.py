"""Training loop smoke + AdamW + datasets + model layers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets
from compile.kan.layers import (
    KanCfg,
    init_kan,
    init_mlp,
    kan_forward,
    kan_param_count,
    mlp_forward,
    mlp_param_count,
)
from compile.kan.train import adamw_init, adamw_update, bce_logits, softmax_xent, train_kan


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = adamw_update(params, g, opt, lr=0.1, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_losses():
    logits = jnp.asarray([[10.0, -10.0], [-10.0, 10.0]])
    labels = jnp.asarray([0, 1])
    assert float(softmax_xent(logits, labels)) < 1e-3
    z = jnp.asarray([[10.0], [-10.0]])
    y = jnp.asarray([1, 0])
    assert float(bce_logits(z, y)) < 1e-3


def test_param_counts_table6():
    # paper Table 6: KAN actor [17, 6], G=6, S=3 -> 1020 params
    cfg = KanCfg(dims=(17, 6), grid_size=6, order=3, domain=(-4.0, 4.0), bits=(8, 8))
    assert kan_param_count(cfg) == 1020
    assert mlp_param_count((17, 64, 64, 6)) == 17 * 64 + 64 + 64 * 64 + 64 + 64 * 6 + 6


def test_kan_forward_shapes():
    cfg = KanCfg(dims=(5, 4, 3), grid_size=4, order=2, domain=(-2.0, 2.0), bits=(4, 5, 6))
    params = init_kan(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((7, 5))
    for quantized in (False, True):
        out = kan_forward(params, x, cfg, quantized=quantized)
        assert out.shape == (7, 3)
        assert np.isfinite(np.asarray(out)).all()


def test_mlp_forward_shapes():
    params = init_mlp(jax.random.PRNGKey(1), (5, 8, 2))
    out = mlp_forward(params, jnp.zeros((3, 5)))
    assert out.shape == (3, 2)


def test_train_kan_learns_moons():
    x_tr, y_tr, x_te, y_te = datasets.moons(n=1200, seed=5)
    cfg = KanCfg(dims=(2, 2, 1), grid_size=6, order=3, domain=(-8.0, 8.0),
                 bits=(6, 5, 8), prune_threshold=0.0)
    res = train_kan(cfg, x_tr * 2, y_tr, x_te * 2, y_te, epochs=25,
                    batch_size=64, lr=1e-2, task="binary")
    assert res.history[-1]["val"] > 0.85, res.history[-1]


def test_train_respects_masks_gradient():
    """Pruned edges receive no gradient (masked inside the graph)."""
    cfg = KanCfg(dims=(2, 2), grid_size=4, order=2, domain=(-2.0, 2.0), bits=(4, 6))
    params = init_kan(jax.random.PRNGKey(2), cfg)
    mask = jnp.asarray([[1.0, 0.0], [1.0, 1.0]])

    def loss(p):
        out = kan_forward(p, jnp.ones((4, 2)), cfg, masks=[mask], quantized=False)
        return jnp.sum(out**2)

    g = jax.grad(loss)(params)
    dead = np.asarray(g[0]["w_spline"])[0, 1]
    np.testing.assert_array_equal(dead, 0.0)
    assert np.abs(np.asarray(g[0]["w_spline"])[0, 0]).sum() > 0


@pytest.mark.parametrize("name,d,k", [
    ("moons", 2, 2), ("wine", 13, 3), ("dry_bean", 16, 7),
    ("jsc_openml", 16, 5), ("jsc_cernbox", 16, 5),
])
def test_dataset_shapes(name, d, k):
    kw = {"n": 400} if name != "moons" else {"n": 400}
    x_tr, y_tr, x_te, y_te = datasets.load(name, **kw, seed=1)
    assert x_tr.shape[1] == d
    assert set(np.unique(np.concatenate([y_tr, y_te]))) <= set(range(k))
    assert x_tr.dtype == np.float32
    assert len(x_te) > 0


def test_dataset_determinism():
    a = datasets.wine(n=100, seed=9)
    b = datasets.wine(n=100, seed=9)
    np.testing.assert_array_equal(a[0], b[0])
    c = datasets.wine(n=100, seed=10)
    assert not np.array_equal(a[0], c[0])


def test_mnist_surrogate_renders():
    x_tr, y_tr, x_te, y_te = datasets.mnist(n_train=40, n_test=10, seed=2)
    assert x_tr.shape == (40, 784)
    assert x_tr.max() <= 1.0 and x_tr.min() >= 0.0
    # glyphs have ink
    assert (x_tr.sum(1) > 5).all()


def test_toyadmos_surrogate_structure():
    x_tr, y_tr_dummy, x_te, y_te = datasets.toyadmos(n_machines=8, windows_per_machine=6, seed=3)
    assert x_tr.shape[1] == 64
    assert set(np.unique(y_te)) <= {0, 1}
    assert (y_te == 1).any() and (y_te == 0).any()
