"""Quantizer semantics (Eq. 7-9) and the hardware code contract."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kan.quant import (
    InputPreproc,
    QuantSpec,
    dequantize_codes_np,
    fake_quant,
    fit_input_preproc,
    quantize_codes_np,
    round_ste,
)


def test_spec_scale():
    s = QuantSpec(6, -8.0, 8.0)
    assert s.levels == 64
    np.testing.assert_allclose(s.scale, 16.0 / 63)


def test_codes_roundtrip():
    s = QuantSpec(5, -2.0, 2.0)
    codes = np.arange(32)
    vals = dequantize_codes_np(codes, s)
    np.testing.assert_array_equal(quantize_codes_np(vals, s), codes)


def test_clipping():
    s = QuantSpec(4, -1.0, 1.0)
    assert quantize_codes_np(np.array([-100.0]), s)[0] == 0
    assert quantize_codes_np(np.array([100.0]), s)[0] == 15


def test_rounding_rule_is_floor_half_up():
    # exactly between codes 0 and 1 -> rounds up (floor(v + .5))
    s = QuantSpec(2, 0.0, 3.0)  # scale = 1
    assert quantize_codes_np(np.array([0.5]), s)[0] == 1
    assert quantize_codes_np(np.array([0.49999]), s)[0] == 0
    assert quantize_codes_np(np.array([1.5]), s)[0] == 2


def test_fake_quant_fixed_points():
    s = QuantSpec(3, -4.0, 4.0)
    vals = dequantize_codes_np(np.arange(8), s)
    out = np.asarray(fake_quant(jnp.asarray(vals), s))
    np.testing.assert_allclose(out, vals, atol=1e-6)


def test_ste_gradient_is_identity():
    g = jax.grad(lambda x: round_ste(x * 3.7).sum())(jnp.asarray([0.3, -1.2]))
    np.testing.assert_allclose(np.asarray(g), [3.7, 3.7], atol=1e-6)


def test_fake_quant_gradient_flows():
    s = QuantSpec(4, -2.0, 2.0)
    g = jax.grad(lambda x: fake_quant(x, s).sum())(jnp.asarray([0.1, 1.0]))
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0], atol=1e-6)
    # clipped region: zero gradient
    g2 = jax.grad(lambda x: fake_quant(x, s).sum())(jnp.asarray([5.0]))
    np.testing.assert_allclose(np.asarray(g2), [0.0], atol=1e-6)


@settings(max_examples=60, deadline=None)
@given(
    bits=st.integers(1, 12),
    x=st.floats(-50, 50, allow_nan=False),
)
def test_quantization_error_bound(bits, x):
    s = QuantSpec(bits, -4.0, 4.0)
    code = quantize_codes_np(np.array([x]), s)[0]
    v = dequantize_codes_np(np.array([code]), s)[0]
    clipped = np.clip(x, -4.0, 4.0)
    assert abs(v - clipped) <= s.scale / 2 + 1e-12


def test_preproc_fit_and_fold():
    rng = np.random.default_rng(0)
    x = rng.normal(3.0, 2.5, (1000, 4))
    x[:, 2] = 7.0  # constant feature
    s = QuantSpec(6, -8.0, 8.0)
    pre = fit_input_preproc(x, s, coverage=3.0)
    xn = pre.apply_np(x)
    # ~99.7% of mass inside the domain
    assert (np.abs(xn) <= 8.0).mean() > 0.99
    np.testing.assert_allclose(xn.mean(0)[:2], 0.0, atol=0.3)
    # numpy and jnp twins agree
    np.testing.assert_allclose(
        xn, np.asarray(pre.apply_jnp(jnp.asarray(x))), atol=1e-5
    )


def test_preproc_identity():
    pre = InputPreproc(shift=np.zeros(3), span=np.ones(3))
    x = np.array([[1.0, -2.0, 0.5]])
    np.testing.assert_array_equal(pre.apply_np(x), x)
