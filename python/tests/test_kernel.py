"""L1 Pallas kernel vs the pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes, grid sizes, orders, domains and value ranges;
assert_allclose against ref.kan_layer_ref.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kan import bspline
from compile.kernels.kan_spline import kan_layer_pallas, pack_weights, vmem_footprint_bytes
from compile.kernels.ref import kan_layer_ref


def _run_case(batch, d_in, d_out, grid, order, domain, scale, seed, block_b):
    rng = np.random.default_rng(seed)
    nb = bspline.num_bases(grid, order)
    knots = bspline.make_knots(grid, domain, order)
    x = (rng.normal(size=(batch, d_in)) * scale).astype(np.float32)
    ws = rng.normal(size=(d_out, d_in, nb)).astype(np.float32)
    wb = rng.normal(size=(d_out, d_in)).astype(np.float32)
    ref = np.asarray(kan_layer_ref(jnp.asarray(x), jnp.asarray(ws), jnp.asarray(wb), knots, order))
    pal = np.asarray(kan_layer_pallas(x, ws, wb, grid, domain, order, block_b=block_b))
    np.testing.assert_allclose(ref, pal, atol=2e-4, rtol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 70),
    d_in=st.integers(1, 9),
    d_out=st.integers(1, 7),
    grid=st.integers(2, 12),
    order=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref_sweep(batch, d_in, d_out, grid, order, seed):
    _run_case(batch, d_in, d_out, grid, order, (-4.0, 4.0), 2.0, seed, block_b=16)


@pytest.mark.parametrize("domain", [(-8.0, 8.0), (-2.0, 2.0), (0.0, 1.0)])
def test_kernel_domains(domain):
    _run_case(33, 4, 3, 6, 3, domain, (domain[1] - domain[0]) / 3, 7, block_b=8)


def test_kernel_paper_configs():
    # the actual Table 2 spline configs
    _run_case(16, 16, 8, 40, 10, (-2.0, 2.0), 1.0, 1, block_b=16)
    _run_case(16, 13, 4, 6, 3, (-8.0, 8.0), 3.0, 2, block_b=16)


def test_kernel_edge_values():
    # inputs exactly at and beyond the domain edges
    rng = np.random.default_rng(3)
    grid, order, domain = 6, 3, (-8.0, 8.0)
    nb = bspline.num_bases(grid, order)
    knots = bspline.make_knots(grid, domain, order)
    x = np.array([[-8.0, 8.0], [100.0, -100.0], [0.0, 7.999]], np.float32)
    ws = rng.normal(size=(2, 2, nb)).astype(np.float32)
    wb = rng.normal(size=(2, 2)).astype(np.float32)
    ref = np.asarray(kan_layer_ref(jnp.asarray(x), jnp.asarray(ws), jnp.asarray(wb), knots, order))
    pal = np.asarray(kan_layer_pallas(x, ws, wb, grid, domain, order, block_b=8))
    np.testing.assert_allclose(ref, pal, atol=2e-4)


def test_pack_weights_layout():
    ws = jnp.arange(2 * 3 * 4, dtype=jnp.float32).reshape(2, 3, 4)
    wb = jnp.arange(2 * 3, dtype=jnp.float32).reshape(2, 3) * 100
    w = np.asarray(pack_weights(ws, wb))
    assert w.shape == (3 * 5, 2)
    # input 0's features: 4 spline coeffs then base weight
    np.testing.assert_array_equal(w[:5, 0], [0, 1, 2, 3, 0])
    np.testing.assert_array_equal(w[:5, 1], [12, 13, 14, 15, 300])


def test_vmem_model():
    m = vmem_footprint_bytes(16, 8, 40, 10, block_b=128)
    assert m["fits_16mib_vmem"]
    assert 0 < m["mxu_tile_efficiency"] <= 1
    assert m["flops_per_step"] > 0
