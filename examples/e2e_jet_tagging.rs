//! End-to-end driver (DESIGN.md §6): the full system on the JSC-OpenML jet
//! tagging workload, proving all layers compose:
//!
//!   1. load the QAT+pruned checkpoint produced by the JAX/Pallas build path,
//!   2. extract L-LUTs and build the netlist,
//!   3. assert three-way equivalence on real data:
//!        bit-exact netlist sim == Python integer oracle, and
//!        netlist argmax == PJRT-executed quantized HLO argmax,
//!   4. evaluate accuracy on the full exported test set,
//!   5. serve 100k batched requests through the coordinator,
//!   6. print the hardware row next to the paper's Table 3 row.
//!
//!     make artifacts-all && cargo run --release --example e2e_jet_tagging

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};
use kanele::checkpoint::{Checkpoint, TestSet};
use kanele::coordinator::{Service, ServiceCfg, SubmitError};
use kanele::netlist::Netlist;
use kanele::runtime::Engine;
use kanele::synth;
use kanele::{config, data, engine, lut, report, sim};

fn main() -> Result<()> {
    let name = "jsc_openml";
    let ck = Checkpoint::load(&config::ckpt_path(name))
        .context("train first: cd python && python -m compile.trainer jsc_openml")?;
    let ts = TestSet::load(&config::testset_path(name))?;
    println!("== end-to-end jet tagging: {} test samples ==", ts.input_codes.len());

    // -- netlist ------------------------------------------------------------
    let tables = lut::from_checkpoint(&ck);
    let net = Netlist::build(&ck, &tables, 2);
    println!(
        "netlist: {} edges -> {} L-LUTs, latency {} cycles",
        ck.active_edges(),
        net.n_luts(),
        net.latency_cycles()
    );

    // -- equivalence 1: vs python integer oracle ----------------------------
    let tv = &ck.test_vectors;
    let exact = tv
        .input_codes
        .iter()
        .zip(&tv.output_sums)
        .filter(|(c, want)| &sim::eval(&net, c) == *want)
        .count();
    println!("oracle equivalence: {exact}/{} bit-exact", tv.input_codes.len());
    if exact != tv.input_codes.len() {
        bail!("netlist deviates from the Python oracle");
    }

    // -- equivalence 1b: the compiled serving engine vs the same oracle -----
    // (flat-plane path: one contiguous buffer, no per-sample allocations)
    let prog = engine::compile(&net);
    let mut flat = Vec::new();
    engine::run_batch_flat(&prog, &tv.input_codes, &mut flat);
    let want: Vec<i64> = tv.output_sums.iter().flatten().copied().collect();
    if flat != want {
        bail!("compiled engine deviates from the Python oracle");
    }
    println!(
        "compiled engine   : {} vectors bit-exact ({} fused ops, {} packed table words)",
        tv.input_codes.len(),
        prog.n_ops(),
        prog.table_words()
    );

    // -- equivalence 2: vs the AOT-compiled HLO through PJRT ----------------
    // (on builds without the `xla` feature Engine::load always fails — that
    // stub failure degrades to a skip, the integer-domain checks above stay
    // the hard gate; on real PJRT builds a broken artifact must still fail)
    let hlo = config::hlo_path(name);
    let eng = if !hlo.exists() {
        println!("(no HLO artifact; skipping PJRT cross-check)");
        None
    } else {
        match Engine::load(&hlo, 256, ck.dims[0]) {
            Ok(e) => Some(e),
            Err(e) if cfg!(feature = "xla") => {
                return Err(e.context("loading HLO artifact"));
            }
            Err(e) => {
                println!("(PJRT disabled in this build: {e}; skipping HLO cross-check)");
                None
            }
        }
    };
    if let Some(eng) = eng {
        println!("PJRT platform: {}", eng.platform());
        let q = ck.quantizer(0);
        let n = 256.min(ts.input_codes.len());
        // HLO consumes raw (pre-preproc) floats; testset stores codes.
        // decode codes -> normalized values -> undo preproc for the engine.
        let mut rows = Vec::with_capacity(n);
        for codes in ts.input_codes.iter().take(n) {
            let row: Vec<f32> = codes
                .iter()
                .enumerate()
                .map(|(j, &c)| {
                    (q.decode(c) * ck.preproc.span[j] + ck.preproc.shift[j]) as f32
                })
                .collect();
            rows.push(row);
        }
        let outs = eng.run_padded(&rows)?;
        let mut agree = 0;
        for (i, codes) in ts.input_codes.iter().take(n).enumerate() {
            let hw = sim::eval(&net, codes);
            let hw_pred = sim::argmax(&hw);
            let hlo_pred = outs[i]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, _)| k)
                .unwrap();
            if hw_pred == hlo_pred {
                agree += 1;
            }
        }
        let rate = agree as f64 / n as f64;
        println!("netlist vs PJRT-HLO argmax agreement: {agree}/{n} ({:.1}%)", rate * 100.0);
        if rate < 0.97 {
            bail!("HLO/netlist agreement below 97% — quantization contract broken");
        }
    }

    // -- accuracy ------------------------------------------------------------
    let acc = report::eval_metric(&ck, &net)?;
    println!("netlist test accuracy: {acc:.1}% (paper: 76.0% on the real JSC OpenML)");

    // -- serving (compiled batch-major backend, the default) ------------------
    let svc = Service::start(
        Arc::new(net.clone()),
        ServiceCfg {
            workers: 2,
            max_batch: 128,
            max_wait: Duration::from_micros(50),
            queue_depth: 1 << 14,
            ..Default::default()
        },
    );
    let n_req = 100_000;
    let stream = data::replay_stream(&ts, n_req);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(4096);
    let mut done = 0usize;
    for codes in stream {
        loop {
            match svc.submit(codes.clone()) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                // only backpressure is retryable; a stopped service or a
                // malformed request must abort instead of spinning
                Err(SubmitError::Backpressure) => {
                    for rx in pending.drain(..) {
                        rx.recv()??;
                        done += 1;
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
    for rx in pending {
        rx.recv()??;
        done += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = svc.stats();
    println!(
        "served {done} requests in {wall:.2} s -> {:.0} req/s | p50 {:.0} us p99 {:.0} us | mean batch {:.1} over {} batches",
        done as f64 / wall,
        st.latency_p50_us,
        st.latency_p99_us,
        st.mean_batch,
        st.batches
    );
    svc.shutdown();

    // -- hardware row ---------------------------------------------------------
    let dev = synth::device_by_name("xcvu9p").unwrap();
    let r = synth::synthesize(&net, &dev);
    println!("\nhardware (ours):  {} LUT {} FF 0 DSP 0 BRAM | Fmax {:.0} MHz | {:.1} ns | AxD {:.1e}",
        r.luts, r.ffs, r.fmax_mhz, r.latency_ns, r.area_delay);
    println!("paper Table 3  :  1232 LUT 900 FF 0 DSP 0 BRAM | Fmax 987 MHz | 7.1 ns | AxD 8.7e3");
    println!("E2E OK");
    Ok(())
}
