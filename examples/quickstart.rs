//! Quickstart: the complete KANELE toolflow on the Moons benchmark.
//!
//! checkpoint -> L-LUT extraction -> netlist -> bit-exact verification ->
//! serving through the coordinator -> synthesis estimate -> VHDL bundle,
//! in one binary.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Without the trained artifact (e.g. in CI) it falls back to a synthetic
//! twin with the Moons dims/bits: accuracy numbers are then meaningless,
//! but every structural stage — netlist, engine equivalence, the
//! dispatcher/executor serving pipeline, synthesis, VHDL — still runs.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};
use kanele::checkpoint::{testutil, Checkpoint};
use kanele::coordinator::{Service, ServiceCfg, SubmitError};
use kanele::netlist::Netlist;
use kanele::synth;
use kanele::{config, data, engine, lut, report, sim, vhdl};

fn main() -> Result<()> {
    let path = config::ckpt_path("moons");
    let (ck, trained) = match Checkpoint::load(&path) {
        Ok(ck) => (ck, true),
        Err(_) => {
            let exp = config::experiment("moons").expect("moons experiment");
            println!(
                "(no trained artifact at {} — using a synthetic twin; run `make artifacts` for the real model)",
                path.display()
            );
            (testutil::synthetic(exp.dims, exp.bits, 0xB5EED), false)
        }
    };
    println!("== KANELE quickstart: {} ==", ck.name);
    println!("dims {:?}, bits {:?}, G={}, S={}", ck.dims, ck.bits, ck.grid_size, ck.order);

    // 1. KAN -> Logical-LUTs (paper §4.1.2): regenerate from splines and
    //    check against the Python-exported authoritative tables.
    if trained {
        let (entries, mismatched, maxdiff) = lut::compare_with_exported(&ck);
        println!("L-LUT regeneration: {entries} entries, {mismatched} off by <= {maxdiff} LSB");
        if maxdiff > 1 {
            bail!("table regeneration drifted");
        }
    } else {
        println!("L-LUT regeneration: skipped (synthetic tables are not spline-derived)");
    }
    let tables = lut::from_checkpoint(&ck);

    // 2. Netlist (paper §4.2): balanced pipelined adder trees, n_add = 2.
    let net = Netlist::build(&ck, &tables, 2);
    println!(
        "netlist: {} L-LUTs, {} adders, latency {} cycles",
        net.n_luts(),
        net.n_adders(),
        net.latency_cycles()
    );

    // 3. Bit-exact check vs the Python integer oracle — through both the
    //    interpreter and the compiled serving engine.
    let tv = &ck.test_vectors;
    let ok = tv
        .input_codes
        .iter()
        .zip(&tv.output_sums)
        .all(|(c, want)| &sim::eval(&net, c) == want);
    println!("oracle equivalence: {} vectors -> {}", tv.input_codes.len(), if ok { "BIT-EXACT" } else { "MISMATCH" });
    if !ok {
        bail!("netlist does not match the training-side oracle");
    }
    // flat-plane path: one contiguous output buffer for the whole batch,
    // no per-sample allocations (what the serving executors run)
    let prog = engine::compile(&net);
    let mut flat = Vec::new();
    engine::run_batch_flat(&prog, &tv.input_codes, &mut flat);
    let want: Vec<i64> = tv.output_sums.iter().flatten().copied().collect();
    if flat != want {
        bail!("compiled engine does not match the training-side oracle");
    }
    println!(
        "compiled engine:  {} fused ops over {} packed table words, same vectors BIT-EXACT",
        prog.n_ops(),
        prog.table_words()
    );

    // 4. Test-set accuracy of the hardware pipeline.
    let tables_metric = report::eval_metric(&ck, &net)?;
    if tables_metric.is_finite() {
        println!("netlist accuracy: {tables_metric:.1}% (paper Table 4: 97%)");
    } else {
        println!("netlist accuracy: n/a (no exported test set)");
    }

    // 5. Serve through the dispatcher/executor coordinator (the L3 hot
    //    path): one dispatcher forms batches while two executors run them.
    let svc = Service::start(
        Arc::new(net.clone()),
        ServiceCfg {
            workers: 2,
            max_batch: 32,
            max_wait: Duration::from_micros(100),
            queue_depth: 4096,
            ..Default::default()
        },
    );
    let stream = data::random_code_stream(&ck, 5_000, 13);
    // bounded in-flight window: deep enough for full batches, shallow
    // enough that reported latency is the service's, not queue residency
    const IN_FLIGHT: usize = 1024;
    let mut pending = std::collections::VecDeque::with_capacity(IN_FLIGHT);
    for codes in &stream {
        loop {
            match svc.submit(codes.clone()) {
                Ok(rx) => {
                    pending.push_back(rx);
                    break;
                }
                Err(SubmitError::Backpressure) => std::thread::sleep(Duration::from_micros(20)),
                Err(e) => return Err(e.into()),
            }
        }
        while pending.len() >= IN_FLIGHT {
            pending.pop_front().unwrap().recv()??;
        }
    }
    while let Some(rx) = pending.pop_front() {
        rx.recv()??;
    }
    let st = svc.stats();
    svc.shutdown();
    println!(
        "serving: {} requests -> {:.0} req/s | p99 {:.0} us | mean batch {:.1} over {} batches",
        st.completed, st.throughput_rps, st.latency_p99_us, st.mean_batch, st.batches
    );
    if st.completed != stream.len() as u64 {
        bail!("coordinator lost requests: {} of {}", st.completed, stream.len());
    }

    // 6. Synthesis estimate on the paper's device for this benchmark.
    let dev = synth::device_by_name("xczu7ev").unwrap();
    let r = synth::synthesize(&net, &dev);
    println!(
        "synthesis ({}): {} LUT, {} FF, 0 BRAM, 0 DSP, Fmax {:.0} MHz, {:.1} ns, AxD {:.1e}",
        r.device, r.luts, r.ffs, r.fmax_mhz, r.latency_ns, r.area_delay
    );
    println!("paper row:          67 LUT, 57 FF, 0 BRAM, 0 DSP, Fmax 1736 MHz, 2.9 ns, AxD 1.9e2");

    // 7. Emit the RTL bundle.
    let dir = config::artifacts_dir().join("vhdl_moons");
    vhdl::write_bundle(
        &net,
        &dir,
        (!tv.input_codes.is_empty()).then_some((tv.input_codes.as_slice(), tv.output_sums.as_slice())),
    )?;
    println!("VHDL bundle written to {}", dir.display());
    println!("quickstart OK");
    Ok(())
}
