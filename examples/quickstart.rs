//! Quickstart: the complete KANELE toolflow on the Moons benchmark.
//!
//! checkpoint -> L-LUT extraction -> netlist -> bit-exact verification ->
//! synthesis estimate -> VHDL bundle, in one binary.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::{bail, Context, Result};
use kanele::checkpoint::Checkpoint;
use kanele::netlist::Netlist;
use kanele::synth;
use kanele::{config, engine, lut, report, sim, vhdl};

fn main() -> Result<()> {
    let path = config::ckpt_path("moons");
    let ck = Checkpoint::load(&path)
        .with_context(|| format!("run `make artifacts` first ({})", path.display()))?;
    println!("== KANELE quickstart: {} ==", ck.name);
    println!("dims {:?}, bits {:?}, G={}, S={}", ck.dims, ck.bits, ck.grid_size, ck.order);

    // 1. KAN -> Logical-LUTs (paper §4.1.2): regenerate from splines and
    //    check against the Python-exported authoritative tables.
    let (entries, mismatched, maxdiff) = lut::compare_with_exported(&ck);
    println!("L-LUT regeneration: {entries} entries, {mismatched} off by <= {maxdiff} LSB");
    if maxdiff > 1 {
        bail!("table regeneration drifted");
    }
    let tables = lut::from_checkpoint(&ck);

    // 2. Netlist (paper §4.2): balanced pipelined adder trees, n_add = 2.
    let net = Netlist::build(&ck, &tables, 2);
    println!(
        "netlist: {} L-LUTs, {} adders, latency {} cycles",
        net.n_luts(),
        net.n_adders(),
        net.latency_cycles()
    );

    // 3. Bit-exact check vs the Python integer oracle — through both the
    //    interpreter and the compiled serving engine.
    let tv = &ck.test_vectors;
    let ok = tv
        .input_codes
        .iter()
        .zip(&tv.output_sums)
        .all(|(c, want)| &sim::eval(&net, c) == want);
    println!("oracle equivalence: {} vectors -> {}", tv.input_codes.len(), if ok { "BIT-EXACT" } else { "MISMATCH" });
    if !ok {
        bail!("netlist does not match the training-side oracle");
    }
    let prog = engine::compile(&net);
    if engine::run_batch(&prog, &tv.input_codes) != tv.output_sums {
        bail!("compiled engine does not match the training-side oracle");
    }
    println!(
        "compiled engine:  {} fused ops over {} packed table words, same vectors BIT-EXACT",
        prog.n_ops(),
        prog.table_words()
    );

    // 4. Test-set accuracy of the hardware pipeline.
    let tables_metric = report::eval_metric(&ck, &net)?;
    println!("netlist accuracy: {tables_metric:.1}% (paper Table 4: 97%)");

    // 5. Synthesis estimate on the paper's device for this benchmark.
    let dev = synth::device_by_name("xczu7ev").unwrap();
    let r = synth::synthesize(&net, &dev);
    println!(
        "synthesis ({}): {} LUT, {} FF, 0 BRAM, 0 DSP, Fmax {:.0} MHz, {:.1} ns, AxD {:.1e}",
        r.device, r.luts, r.ffs, r.fmax_mhz, r.latency_ns, r.area_delay
    );
    println!("paper row:          67 LUT, 57 FF, 0 BRAM, 0 DSP, Fmax 1736 MHz, 2.9 ns, AxD 1.9e2");

    // 6. Emit the RTL bundle.
    let dir = config::artifacts_dir().join("vhdl_moons");
    vhdl::write_bundle(
        &net,
        &dir,
        Some((tv.input_codes.as_slice(), tv.output_sums.as_slice())),
    )?;
    println!("VHDL bundle written to {}", dir.display());
    println!("quickstart OK");
    Ok(())
}
