//! ToyADMOS-surrogate anomaly detection (paper §5.5 / Table 5): the KAN
//! autoencoder runs bit-exactly as a netlist; reconstruction error over the
//! exported test windows gives the AUC; the synthesis estimator prices the
//! design on the paper's xc7a100t next to the hls4ml MLPerf-Tiny baseline.
//!
//! Since PR 6 the windows stream through the network front end: the example
//! starts `net::NetServer` on a loopback port and plays the test set as a
//! continuous pipelined wire client — the same deployment shape as a sensor
//! feeding a remote scoring box — with backpressure frames retried and
//! responses matched by id, not arrival order.
//!
//!     cd python && python -m compile.trainer toyadmos
//!     cargo run --release --example anomaly_detection

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};
use kanele::baselines::published;
use kanele::checkpoint::{Checkpoint, TestSet};
use kanele::coordinator::{Service, ServiceCfg};
use kanele::fixed::from_fixed;
use kanele::net::{Client, ErrorKind, NetCfg, NetServer, WireRequest, WireResponse};
use kanele::netlist::Netlist;
use kanele::synth;
use kanele::util::stats::auc;
use kanele::{config, lut};

fn main() -> Result<()> {
    let ck = Checkpoint::load(&config::ckpt_path("toyadmos"))
        .context("train first: cd python && python -m compile.trainer toyadmos")?;
    let ts = TestSet::load(&config::testset_path("toyadmos"))?;
    println!(
        "== anomaly detection: AE {:?}, {} test windows ({} anomalous) ==",
        ck.dims,
        ts.input_codes.len(),
        ts.labels.iter().filter(|&&l| l != 0).count()
    );

    let tables = lut::from_checkpoint(&ck);
    let net = Netlist::build(&ck, &tables, 2);
    let q_in = ck.quantizer(0);

    // serve every window through the wire and score reconstruction: the
    // coordinator runs behind a loopback TCP front end and this process
    // plays the streaming client
    let svc = Arc::new(Service::start(
        Arc::new(net.clone()),
        ServiceCfg {
            workers: 2,
            max_batch: 64,
            max_wait: Duration::from_micros(100),
            queue_depth: 8192,
            ..Default::default()
        },
    ));
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let mut server = NetServer::start(
        Arc::clone(&svc),
        listener,
        NetCfg { levels: q_in.levels(), ..NetCfg::default() },
    )?;
    let mut client = Client::connect(server.local_addr())?;
    println!("streaming over loopback TCP ({})", server.local_addr());

    // pipelined wire window: deep enough that the dispatcher forms real
    // batches (a blocking round-trip per window would serialize the run
    // into batches of one), shallow enough that reported latencies measure
    // the service, not this client's own queue residency. The frame id is
    // the window index, so responses are matched by id even though the
    // stream interleaves error frames ahead of completions.
    const IN_FLIGHT: usize = 256;
    let n = ts.input_codes.len();
    let mut sums: Vec<Option<Vec<i64>>> = vec![None; n];
    let mut send_idx = 0usize;
    let mut in_flight = 0usize;
    let mut done = 0usize;
    while done < n {
        while send_idx < n && in_flight < IN_FLIGHT {
            let req = WireRequest::Infer {
                id: send_idx as u64,
                model: None,
                codes: ts.input_codes[send_idx].clone(),
            };
            client.send(&req).map_err(|e| anyhow::anyhow!("wire send: {e}"))?;
            send_idx += 1;
            in_flight += 1;
        }
        match client.recv_response().map_err(|e| anyhow::anyhow!("wire recv: {e}"))? {
            WireResponse::Sums { id, sums: s, .. } => {
                sums[id as usize] = Some(s);
                in_flight -= 1;
                done += 1;
            }
            WireResponse::Error { id, kind: ErrorKind::Backpressure, .. } => {
                // retryable: give the plane a moment, resend that window
                std::thread::sleep(Duration::from_micros(50));
                let req = WireRequest::Infer {
                    id,
                    model: None,
                    codes: ts.input_codes[id as usize].clone(),
                };
                client.send(&req).map_err(|e| anyhow::anyhow!("wire resend: {e}"))?;
            }
            WireResponse::Error { id, kind, msg } => {
                bail!("window {id} failed over the wire [{kind}]: {msg}")
            }
            other => bail!("unexpected response frame: {other:?}"),
        }
    }
    let mut scores = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(ts.labels.len());
    for (resp, (codes, &label)) in sums.iter().zip(ts.input_codes.iter().zip(&ts.labels)) {
        let resp = resp.as_ref().expect("every window completed");
        let mut err = 0.0;
        for (s, &c) in resp.iter().zip(codes) {
            let rec = from_fixed(*s, ck.frac_bits);
            let d = rec - q_in.decode(c);
            err += d * d;
        }
        scores.push(err / resp.len() as f64);
        labels.push(label != 0);
    }
    let stats = svc.stats();
    let wire = server.stats();
    drop(client);
    server.shutdown();
    svc.shutdown();

    let a = auc(&scores, &labels);
    println!("AUC (bit-exact netlist reconstruction error): {a:.3} (paper: 0.83)");
    println!(
        "serving: {:.0} req/s over the wire (p50/p90/p99 {:.0}/{:.0}/{:.0} us, mean batch {:.1}, {} frames out)",
        stats.throughput_rps,
        stats.latency_p50_us,
        stats.latency_p90_us,
        stats.latency_p99_us,
        stats.mean_batch,
        wire.frames_out
    );

    // threshold sweep (deployment calibration)
    let mut sorted = scores.clone();
    sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
    for pct in [50, 80, 90, 95] {
        let thr = sorted[sorted.len() * pct / 100];
        let (mut tp, mut fp, mut tn, mut fnn) = (0, 0, 0, 0);
        for (s, &l) in scores.iter().zip(&labels) {
            match (*s > thr, l) {
                (true, true) => tp += 1,
                (true, false) => fp += 1,
                (false, false) => tn += 1,
                (false, true) => fnn += 1,
            }
        }
        println!(
            "  threshold@p{pct}: TPR {:.2} FPR {:.2}",
            tp as f64 / (tp + fnn).max(1) as f64,
            fp as f64 / (fp + tn).max(1) as f64
        );
    }

    // hardware row (paper Table 5)
    let dev = synth::device_by_name("xc7a100t").unwrap();
    let r = synth::synthesize(&net, &dev);
    println!(
        "\nhardware (ours): {} LUT {} FF 0 BRAM 0 DSP | II=1 | {:.2e} inf/s | {:.2} us | {:.3} uJ/inf",
        r.luts,
        r.ffs,
        r.throughput_inf_s,
        r.latency_ns / 1000.0,
        r.energy_per_inf_uj
    );
    for row in published::TABLE5 {
        println!(
            "paper {:<26}: {} LUT {} FF {} BRAM {} DSP | II={} | {:.2e} inf/s | {:.2} us | {:.3} uJ/inf",
            row.model, row.luts, row.ffs, row.brams, row.dsps, row.ii,
            row.throughput_inf_s, row.latency_us, row.energy_uj
        );
    }
    println!("anomaly detection OK");
    Ok(())
}
