//! ToyADMOS-surrogate anomaly detection (paper §5.5 / Table 5): the KAN
//! autoencoder runs bit-exactly as a netlist; reconstruction error over the
//! exported test windows gives the AUC; the synthesis estimator prices the
//! design on the paper's xc7a100t next to the hls4ml MLPerf-Tiny baseline.
//!
//!     cd python && python -m compile.trainer toyadmos
//!     cargo run --release --example anomaly_detection

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};
use kanele::baselines::published;
use kanele::checkpoint::{Checkpoint, TestSet};
use kanele::coordinator::{Service, ServiceCfg, SubmitError};
use kanele::fixed::from_fixed;
use kanele::netlist::Netlist;
use kanele::synth;
use kanele::util::stats::auc;
use kanele::{config, lut};

fn main() -> Result<()> {
    let ck = Checkpoint::load(&config::ckpt_path("toyadmos"))
        .context("train first: cd python && python -m compile.trainer toyadmos")?;
    let ts = TestSet::load(&config::testset_path("toyadmos"))?;
    println!(
        "== anomaly detection: AE {:?}, {} test windows ({} anomalous) ==",
        ck.dims,
        ts.input_codes.len(),
        ts.labels.iter().filter(|&&l| l != 0).count()
    );

    let tables = lut::from_checkpoint(&ck);
    let net = Netlist::build(&ck, &tables, 2);
    let q_in = ck.quantizer(0);

    // serve every window through the coordinator and score reconstruction
    let svc = Service::start(
        Arc::new(net.clone()),
        ServiceCfg {
            workers: 2,
            max_batch: 64,
            max_wait: Duration::from_micros(100),
            queue_depth: 8192,
            ..Default::default()
        },
    );
    // pipelined submission with a bounded in-flight window: deep enough
    // that the dispatcher forms real batches (a blocking round-trip per
    // window would serialize the run into batches of one), shallow enough
    // that the reported latencies measure the service, not this example's
    // own unbounded queue residency
    const IN_FLIGHT: usize = 1024;
    let mut rxs = std::collections::VecDeque::with_capacity(IN_FLIGHT);
    let mut resps = Vec::with_capacity(ts.input_codes.len());
    for codes in &ts.input_codes {
        loop {
            match svc.submit(codes.clone()) {
                Ok(rx) => {
                    rxs.push_back(rx);
                    break;
                }
                Err(SubmitError::Backpressure) => std::thread::sleep(Duration::from_micros(50)),
                Err(e) => return Err(e.into()),
            }
        }
        while rxs.len() >= IN_FLIGHT {
            resps.push(rxs.pop_front().unwrap().recv()?);
        }
    }
    while let Some(rx) = rxs.pop_front() {
        resps.push(rx.recv()?);
    }
    let mut scores = Vec::with_capacity(ts.input_codes.len());
    let mut labels = Vec::with_capacity(ts.labels.len());
    for (resp, (codes, &label)) in resps.iter().zip(ts.input_codes.iter().zip(&ts.labels)) {
        let mut err = 0.0;
        for (s, &c) in resp.sums.iter().zip(codes) {
            let rec = from_fixed(*s, ck.frac_bits);
            let d = rec - q_in.decode(c);
            err += d * d;
        }
        scores.push(err / resp.sums.len() as f64);
        labels.push(label != 0);
    }
    let stats = svc.stats();
    svc.shutdown();

    let a = auc(&scores, &labels);
    println!("AUC (bit-exact netlist reconstruction error): {a:.3} (paper: 0.83)");
    println!(
        "serving: {:.0} req/s through the coordinator (p99 {:.0} us, mean batch {:.1})",
        stats.throughput_rps, stats.latency_p99_us, stats.mean_batch
    );

    // threshold sweep (deployment calibration)
    let mut sorted = scores.clone();
    sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
    for pct in [50, 80, 90, 95] {
        let thr = sorted[sorted.len() * pct / 100];
        let (mut tp, mut fp, mut tn, mut fnn) = (0, 0, 0, 0);
        for (s, &l) in scores.iter().zip(&labels) {
            match (*s > thr, l) {
                (true, true) => tp += 1,
                (true, false) => fp += 1,
                (false, false) => tn += 1,
                (false, true) => fnn += 1,
            }
        }
        println!(
            "  threshold@p{pct}: TPR {:.2} FPR {:.2}",
            tp as f64 / (tp + fnn).max(1) as f64,
            fp as f64 / (fp + tn).max(1) as f64
        );
    }

    // hardware row (paper Table 5)
    let dev = synth::device_by_name("xc7a100t").unwrap();
    let r = synth::synthesize(&net, &dev);
    println!(
        "\nhardware (ours): {} LUT {} FF 0 BRAM 0 DSP | II=1 | {:.2e} inf/s | {:.2} us | {:.3} uJ/inf",
        r.luts,
        r.ffs,
        r.throughput_inf_s,
        r.latency_ns / 1000.0,
        r.energy_per_inf_uj
    );
    for row in published::TABLE5 {
        println!(
            "paper {:<26}: {} LUT {} FF {} BRAM {} DSP | II={} | {:.2e} inf/s | {:.2} us | {:.3} uJ/inf",
            row.model, row.luts, row.ffs, row.brams, row.dsps, row.ii,
            row.throughput_inf_s, row.latency_us, row.energy_uj
        );
    }
    println!("anomaly detection OK");
    Ok(())
}
