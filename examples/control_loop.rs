//! Real-time control demo (paper §5.7): the 8-bit quantized KAN policy runs
//! as a *netlist* — exact hardware semantics, zero Python — inside the
//! CheetahLite control loop, and its per-decision latency is compared with
//! the synthesized FPGA latency and the MLP-actor estimate of Table 7.
//!
//!     python -m compile.experiments fig7 && python -m compile.experiments rl_export
//!     cargo run --release --example control_loop

use anyhow::{Context, Result};
use kanele::baselines::hls4ml::Hls4mlCfg;
use kanele::checkpoint::Checkpoint;
use kanele::netlist::Netlist;
use kanele::rl::{rollout, CheetahLite, NetlistPolicy};
use kanele::synth;
use kanele::util::{Summary, Timer};
use kanele::{config, lut};

fn main() -> Result<()> {
    let path = config::ckpt_path("rl_kan_actor");
    let ck = Checkpoint::load(&path).context(
        "missing RL actor checkpoint — run `python -m compile.experiments fig7` then `rl_export`",
    )?;
    println!("== control loop: KAN 8-bit actor [{:?}] as netlist ==", ck.dims);

    let tables = lut::from_checkpoint(&ck);
    let net = Netlist::build(&ck, &tables, 2);
    let policy = NetlistPolicy { ck: &ck, net: &net };

    // closed-loop rollouts (hardware-in-the-loop semantics)
    let mut rewards = Summary::new();
    for seed in 0..5 {
        let r = rollout(&policy, seed);
        println!("episode seed {seed}: reward {r:9.1}");
        rewards.push(r);
    }
    println!(
        "mean reward {:.1} (training-side stochastic-PPO curve ended near the same level; paper: 2762.2 on MuJoCo HalfCheetah)",
        rewards.mean()
    );

    // decision latency in the software netlist simulator
    let mut env = CheetahLite::new(99);
    let obs = env.reset();
    let t = Timer::start();
    let n = 10_000;
    for _ in 0..n {
        std::hint::black_box(policy.act(&obs));
    }
    let us = t.elapsed_s() / n as f64 * 1e6;
    println!("\nsoftware decision latency : {us:.2} us/action (netlist simulator)");

    // hardware latency (synthesis estimate, paper Table 7)
    let dev = synth::device_by_name("xczu7ev").unwrap();
    let r = synth::synthesize(&net, &dev);
    println!(
        "FPGA decision latency     : {:.1} ns @ {:.0} MHz | {} LUT {} FF 0 DSP 0 BRAM | AxD {:.1e}",
        r.latency_ns, r.fmax_mhz, r.luts, r.ffs, r.area_delay
    );
    println!("paper Table 7 (KAN 8-bit) : 4.5 ns @ 884 MHz | 1136 LUT 2828 FF | AxD 1.3e4");

    let mlp = Hls4mlCfg {
        name: "MLP 8-bit hls4ml".into(),
        dims: vec![17, 64, 64, 6],
        bits: 8,
        reuse: 1,
        resource_strategy: true,
    }
    .estimate();
    println!(
        "MLP actor (our hls4ml mdl): {:.1} ns @ {:.0} MHz | {} LUT {} FF {} DSP -> {}",
        mlp.latency_ns,
        mlp.fmax_mhz,
        mlp.luts,
        mlp.ffs,
        mlp.dsps,
        if mlp.dsps > synth::XCZU7EV.dsps { "DOES NOT FIT xczu7ev (as in the paper)" } else { "fits" }
    );
    println!("control loop OK");
    Ok(())
}
