//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no registry access, so the real `anyhow` cannot be
//! fetched; this shim implements the exact surface the workspace uses:
//!
//! * [`Error`] — a context-chain error (outermost message first), with
//!   `{e}` printing the top message, `{e:#}` the full `a: b: c` chain and
//!   `{e:?}` an anyhow-style "Caused by:" listing,
//! * [`Result`] with the `E = Error` default,
//! * the [`Context`] extension trait on `Result` and `Option`,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros,
//! * a blanket `From<E: std::error::Error>` so `?` converts std errors
//!   (their `source()` chain is preserved as context).
//!
//! Deliberately out of scope (unused here): backtraces, downcasting, and
//! `std::error::Error` for [`Error`] itself (omitting it is what makes the
//! blanket `From` coherent — the same trick the real crate uses via
//! specialization-free trickery).

use std::fmt;

/// Context-chain error. `msg` is the outermost description; `source` the
/// next inner layer.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap `self` in an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: ctx.to_string(), source: Some(Box::new(self)) }
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// Innermost error of the chain.
    pub fn root_cause(&self) -> &Error {
        let mut e = self;
        while let Some(s) = e.source.as_deref() {
            e = s;
        }
        e
    }
}

/// Iterator over an error's context chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next.take()?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut e = self.source.as_deref();
            while let Some(s) = e {
                write!(f, ": {}", s.msg)?;
                e = s.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut e = self.source.as_deref();
            while let Some(s) = e {
                write!(f, "\n    {}", s.msg)?;
                e = s.source.as_deref();
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut cur = e.source();
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut out: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            out = Some(Error { msg, source: out.map(Box::new) });
        }
        out.expect("at least one message")
    }
}

/// `std::result::Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or turn `None` into an error
/// (`Option`), exactly like anyhow's trait of the same name.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Early-return with an [`Error`] when `cond` is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($tt:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($tt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_top_and_alternate_chain() {
        let e = Error::msg("inner").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("inner"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("while reading").unwrap_err();
        assert_eq!(format!("{e:#}"), "while reading: gone");

        let n: Option<u32> = None;
        let e = n.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(3).context("nope").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("got {}", x);
            }
            Err(anyhow!("fell through"))
        }
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "got 7");
        assert_eq!(format!("{}", f(1).unwrap_err()), "fell through");
    }

    #[test]
    fn chain_and_root_cause() {
        let e = Error::msg("a").context("b").context("c");
        let msgs: Vec<String> = e.chain().map(|x| format!("{x}")).collect();
        assert_eq!(msgs, vec!["c", "b", "a"]);
        assert_eq!(format!("{}", e.root_cause()), "a");
    }
}
