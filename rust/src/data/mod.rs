//! Request-path data utilities: synthetic request streams for the
//! coordinator benches and helpers over exported test sets.

use crate::checkpoint::{Checkpoint, TestSet};
use crate::util::Rng;

/// Generate `n` uniform-random input-code vectors valid for a checkpoint.
pub fn random_code_stream(ck: &Checkpoint, n: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    let d = ck.dims[0];
    let levels = 1u64 << ck.bits[0];
    (0..n)
        .map(|_| (0..d).map(|_| rng.below(levels) as u32).collect())
        .collect()
}

/// Cycle a test set into a longer stream (serving benches replay the
/// evaluation distribution rather than uniform noise).
pub fn replay_stream(ts: &TestSet, n: usize) -> Vec<Vec<u32>> {
    (0..n).map(|i| ts.input_codes[i % ts.input_codes.len()].clone()).collect()
}

/// Poisson-ish inter-arrival jitter for open-loop serving benches: returns
/// nanosecond offsets of each request from t0 at the given rate.
pub fn poisson_arrivals(n: usize, rate_rps: f64, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        // exponential inter-arrival
        let u = rng.f64().max(1e-12);
        t += -u.ln() / rate_rps;
        out.push((t * 1e9) as u64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;

    #[test]
    fn stream_codes_in_range() {
        let ck = synthetic(&[5, 3], &[4, 6], 3);
        for codes in random_code_stream(&ck, 100, 7) {
            assert_eq!(codes.len(), 5);
            assert!(codes.iter().all(|&c| c < 16));
        }
    }

    #[test]
    fn replay_cycles() {
        let ts = TestSet {
            input_codes: vec![vec![1, 2], vec![3, 4]],
            labels: vec![0, 1],
        };
        let s = replay_stream(&ts, 5);
        assert_eq!(s.len(), 5);
        assert_eq!(s[4], vec![1, 2]);
    }

    #[test]
    fn arrivals_monotone_and_rate_scaled() {
        let a = poisson_arrivals(1000, 1e6, 1);
        assert!(a.windows(2).all(|w| w[1] >= w[0]));
        let total_s = *a.last().unwrap() as f64 / 1e9;
        // ~1000 arrivals at 1M rps ~ 1 ms
        assert!(total_s > 2e-4 && total_s < 5e-3, "{total_s}");
    }
}
