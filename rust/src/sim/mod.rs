//! Bit- and cycle-accurate netlist simulation — the FPGA-substrate
//! substitute (DESIGN.md §3).
//!
//! Two engines over the same [`Netlist`]:
//!
//! * [`eval`] / [`eval_batch`] — functional, bit-exact, the debugging
//!   reference and the equivalence oracle against the Python integer
//!   oracle. The serving hot path does NOT run this interpreter anymore:
//!   it runs the compiled feature-major, integer-requant program of
//!   [`crate::engine`] (whose `RequantPlan`s are proven bit-exact against
//!   this module's float `encode(from_fixed(..))` path), asserted
//!   bit-identical to [`eval`] by property tests here and in `engine`,
//!   plus a per-batch debug cross-check in the coordinator.
//! * [`CycleSim`] — cycle-accurate pipeline model (LUT stage, one register
//!   per adder stage, requant register), II = 1: a new sample can enter
//!   every cycle and results emerge after `netlist.latency_cycles()`.
//!   Tests assert CycleSim == eval on random streams, plus the latency and
//!   occupancy invariants.

use crate::fixed::from_fixed;
use crate::netlist::{LayerNet, Netlist};

/// Functional evaluation of one sample (input codes -> final i64 sums).
///
/// Convenience wrapper over [`Evaluator`]; allocates per call. The serving
/// hot path uses a reused `Evaluator` instead (§Perf: ~35% faster).
pub fn eval(net: &Netlist, codes: &[u32]) -> Vec<i64> {
    let mut ev = Evaluator::new(net);
    ev.eval(codes).to_vec()
}

/// Reusable evaluator with preallocated scratch buffers — the optimized
/// functional hot path (EXPERIMENTS.md §Perf, L3 iteration 2).
pub struct Evaluator<'a> {
    net: &'a Netlist,
    codes: Vec<u32>,
    sums: Vec<i64>,
}

impl<'a> Evaluator<'a> {
    pub fn new(net: &'a Netlist) -> Self {
        let max_d: usize = net.layers.iter().map(|l| l.d_in.max(l.d_out)).max().unwrap_or(1);
        Evaluator {
            net,
            codes: Vec::with_capacity(max_d),
            sums: Vec::with_capacity(max_d),
        }
    }

    /// Evaluate one sample; the returned slice is valid until the next call.
    pub fn eval(&mut self, codes: &[u32]) -> &[i64] {
        debug_assert_eq!(codes.len(), self.net.layers[0].d_in);
        self.codes.clear();
        self.codes.extend_from_slice(codes);
        for layer in &self.net.layers {
            self.sums.clear();
            for n in &layer.neurons {
                let mut acc = n.bias;
                for lut in &n.luts {
                    // tables are 2^in_bits entries; masking the address is
                    // exactly the RTL's truncation semantics and lets the
                    // compiler elide the bounds check
                    let addr = self.codes[lut.input] as usize & (lut.table.len() - 1);
                    acc += lut.table[addr];
                }
                self.sums.push(acc);
            }
            if let Some(q) = &layer.requant {
                self.codes.clear();
                self.codes.extend(
                    self.sums
                        .iter()
                        .map(|&s| q.encode(from_fixed(s, self.net.frac_bits))),
                );
            }
        }
        &self.sums
    }
}

/// Batch functional evaluation. One [`Evaluator`] is reused across the
/// whole batch (the per-sample `eval()` wrapper would reallocate scratch
/// every call).
pub fn eval_batch(net: &Netlist, batch: &[Vec<u32>]) -> Vec<Vec<i64>> {
    let mut ev = Evaluator::new(net);
    batch.iter().map(|c| ev.eval(c).to_vec()).collect()
}

/// Decision helpers shared with the report harness.
pub fn argmax(sums: &[i64]) -> usize {
    sums.iter()
        .enumerate()
        .max_by_key(|(_, &v)| v)
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Classification/binary accuracy of a netlist on (codes, labels).
pub fn accuracy(net: &Netlist, inputs: &[Vec<u32>], labels: &[i64], binary: bool) -> f64 {
    let mut correct = 0usize;
    for (codes, &label) in inputs.iter().zip(labels) {
        let sums = eval(net, codes);
        let pred = if binary {
            (sums[0] > 0) as i64
        } else {
            argmax(&sums) as i64
        };
        if pred == label {
            correct += 1;
        }
    }
    correct as f64 / inputs.len().max(1) as f64
}

// ---------------------------------------------------------------------------
// Cycle-accurate pipeline simulation
// ---------------------------------------------------------------------------

/// In-flight value at one pipeline register: per-neuron partial sums.
#[derive(Clone, Debug)]
enum Slot {
    Empty,
    /// Codes waiting at a layer's LUT-input register.
    Codes(u64, Vec<u32>),
    /// Partial operand vectors per neuron inside the adder tree.
    Partial(u64, Vec<Vec<i64>>),
    /// Final sums leaving the network.
    Done(u64, Vec<i64>),
}

/// A completed sample: id tag + output sums.
#[derive(Clone, Debug, PartialEq)]
pub struct Completion {
    pub id: u64,
    pub sums: Vec<i64>,
}

/// Cycle-accurate pipelined execution of a netlist.
///
/// Stage layout per layer: `[LUT read] -> depth x [adder stage]` with a
/// register after each stage; requantization happens combinationally with
/// the last register write of a layer (as in the RTL, where the quantize/
/// saturate logic sits before the inter-layer register).
pub struct CycleSim<'a> {
    net: &'a Netlist,
    /// stages[s] = register bank after pipeline stage s.
    stages: Vec<Slot>,
    cycle: u64,
    completed: Vec<Completion>,
}

impl<'a> CycleSim<'a> {
    pub fn new(net: &'a Netlist) -> Self {
        // stage count = latency (each stage has exactly one register)
        let n_stages = net.latency_cycles();
        CycleSim {
            net,
            stages: vec![Slot::Empty; n_stages],
            cycle: 0,
            completed: Vec::new(),
        }
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Pipeline occupancy (non-empty stages).
    pub fn occupancy(&self) -> usize {
        self.stages.iter().filter(|s| !matches!(s, Slot::Empty)).count()
    }

    /// Advance one clock, optionally inserting a new sample (II = 1).
    /// Returns the completion that exited this cycle, if any.
    pub fn step(&mut self, input: Option<(u64, &[u32])>) -> Option<Completion> {
        self.cycle += 1;
        // walk stages from the back so each value moves exactly one stage
        let n = self.stages.len();
        let mut out = None;
        if let Slot::Done(id, sums) = std::mem::replace(&mut self.stages[n - 1], Slot::Empty) {
            let c = Completion { id, sums };
            self.completed.push(c.clone());
            out = Some(c);
        }
        for s in (0..n - 1).rev() {
            let v = std::mem::replace(&mut self.stages[s], Slot::Empty);
            self.stages[s + 1] = self.advance(v, s + 1);
        }
        if let Some((id, codes)) = input {
            debug_assert_eq!(codes.len(), self.net.layers[0].d_in);
            self.stages[0] = Slot::Codes(id, codes.to_vec());
        }
        out
    }

    /// Map a value crossing into stage `stage_idx` through that stage's logic.
    fn advance(&self, v: Slot, stage_idx: usize) -> Slot {
        let v = match v {
            Slot::Empty => return Slot::Empty,
            other => other,
        };
        // decode which (layer, sub-stage) this register index corresponds to
        let (layer_idx, sub) = self.locate(stage_idx);
        let layer = match layer_idx {
            Some(l) => &self.net.layers[l],
            None => return v, // input register: pass through
        };
        match (v, sub) {
            // LUT-read stage: codes -> per-neuron operand vectors (the
            // folded constant bias, when present, rides as an extra operand)
            (Slot::Codes(id, codes), 0) => {
                let partial: Vec<Vec<i64>> = layer
                    .neurons
                    .iter()
                    .map(|n| {
                        let mut ops: Vec<i64> =
                            n.luts.iter().map(|l| l.table[codes[l.input] as usize]).collect();
                        if n.bias != 0 {
                            ops.push(n.bias);
                        }
                        ops
                    })
                    .collect();
                self.finish_layer_if_done(id, partial, layer, sub)
            }
            // adder stage: reduce up to n_add operands per node
            (Slot::Partial(id, ops), s) if s >= 1 => {
                let reduced: Vec<Vec<i64>> = ops
                    .into_iter()
                    .map(|v| {
                        if v.len() <= 1 {
                            v
                        } else {
                            v.chunks(self.net.n_add).map(|c| c.iter().sum()).collect()
                        }
                    })
                    .collect();
                self.finish_layer_if_done(id, reduced, layer, s)
            }
            (Slot::Done(id, s), _) => Slot::Done(id, s),
            (v, s) => unreachable!("slot {v:?} at sub-stage {s}"),
        }
    }

    /// After the layer's final sub-stage, requantize (or mark done).
    fn finish_layer_if_done(
        &self,
        id: u64,
        partial: Vec<Vec<i64>>,
        layer: &LayerNet,
        sub: usize,
    ) -> Slot {
        if sub < layer.depth {
            return Slot::Partial(id, partial);
        }
        // all trees reduced to single operands now
        let sums: Vec<i64> = partial
            .into_iter()
            .map(|v| {
                debug_assert!(v.len() <= 1);
                v.first().copied().unwrap_or(0)
            })
            .collect();
        match &layer.requant {
            Some(q) => Slot::Codes(
                id,
                sums.iter()
                    .map(|&s| q.encode(from_fixed(s, self.net.frac_bits)))
                    .collect(),
            ),
            None => Slot::Done(id, sums),
        }
    }

    /// Register index -> (layer, sub-stage). Stage 0 is the input register
    /// (None); then each layer occupies 1 + depth stages.
    fn locate(&self, stage_idx: usize) -> (Option<usize>, usize) {
        if stage_idx == 0 {
            return (None, 0);
        }
        let mut off = 1;
        for (l, layer) in self.net.layers.iter().enumerate() {
            let span = 1 + layer.depth;
            if stage_idx < off + span {
                return (Some(l), stage_idx - off);
            }
            off += span;
        }
        panic!("stage index {stage_idx} out of range");
    }

    /// Run a full stream with II=1 and drain; returns completions in order.
    pub fn run_stream(&mut self, inputs: &[Vec<u32>]) -> Vec<Completion> {
        let mut out = Vec::with_capacity(inputs.len());
        for (i, codes) in inputs.iter().enumerate() {
            if let Some(c) = self.step(Some((i as u64, codes))) {
                out.push(c);
            }
        }
        while out.len() < inputs.len() {
            match self.step(None) {
                Some(c) => out.push(c),
                None if self.occupancy() == 0 => break,
                None => {}
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::lut;
    use crate::netlist::Netlist;
    use crate::util::{prop, Rng};

    fn net_for(dims: &[usize], bits: &[u32], seed: u64, n_add: usize) -> (crate::checkpoint::Checkpoint, Netlist) {
        let ck = synthetic(dims, bits, seed);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, n_add);
        (ck, net)
    }

    fn random_codes(rng: &mut Rng, d: usize, bits: u32) -> Vec<u32> {
        (0..d).map(|_| rng.below(1 << bits) as u32).collect()
    }

    #[test]
    fn eval_deterministic() {
        let (ck, net) = net_for(&[4, 3, 2], &[4, 5, 6], 17, 2);
        let mut rng = Rng::new(5);
        let codes = random_codes(&mut rng, 4, ck.bits[0]);
        assert_eq!(eval(&net, &codes), eval(&net, &codes));
    }

    #[test]
    fn cycle_sim_matches_eval_single() {
        let (ck, net) = net_for(&[4, 3, 2], &[4, 5, 6], 23, 2);
        let mut rng = Rng::new(6);
        let codes = random_codes(&mut rng, 4, ck.bits[0]);
        let want = eval(&net, &codes);
        let mut sim = CycleSim::new(&net);
        let mut got = None;
        sim.step(Some((7, &codes)));
        for _ in 0..net.latency_cycles() + 2 {
            if let Some(c) = sim.step(None) {
                got = Some(c);
                break;
            }
        }
        let got = got.expect("sample never completed");
        assert_eq!(got.id, 7);
        assert_eq!(got.sums, want);
    }

    #[test]
    fn latency_exact() {
        let (ck, net) = net_for(&[5, 4, 3], &[4, 4, 5], 31, 2);
        let mut rng = Rng::new(9);
        let codes = random_codes(&mut rng, 5, ck.bits[0]);
        let mut sim = CycleSim::new(&net);
        sim.step(Some((0, &codes)));
        let mut cycles = 1;
        loop {
            match sim.step(None) {
                Some(_) => break,
                None => cycles += 1,
            }
            assert!(cycles < 1000, "never completed");
        }
        assert_eq!(cycles + 1, net.latency_cycles() + 1, "latency mismatch");
    }

    #[test]
    fn ii_one_streaming_matches_eval() {
        let (ck, net) = net_for(&[6, 5, 4, 2], &[3, 4, 4, 6], 41, 2);
        let mut rng = Rng::new(10);
        let inputs: Vec<Vec<u32>> = (0..50)
            .map(|_| random_codes(&mut rng, 6, ck.bits[0]))
            .collect();
        let mut sim = CycleSim::new(&net);
        let completions = sim.run_stream(&inputs);
        assert_eq!(completions.len(), inputs.len());
        for c in &completions {
            assert_eq!(c.sums, eval(&net, &inputs[c.id as usize]), "sample {}", c.id);
        }
        // in-order completion (rigid pipeline)
        for (i, c) in completions.iter().enumerate() {
            assert_eq!(c.id, i as u64);
        }
        // II = 1: total cycles = n + latency
        assert_eq!(
            sim.cycle() as usize,
            inputs.len() + net.latency_cycles(),
        );
    }

    #[test]
    fn prop_cycle_sim_equals_eval() {
        prop::check("cyclesim-equals-eval", 25, |g| {
            let n_layers = g.usize_in(1, 3);
            let mut dims = vec![g.usize_in(1, 6)];
            let mut bits = vec![g.usize_in(1, 5) as u32];
            for _ in 0..n_layers {
                dims.push(g.usize_in(1, 6));
                bits.push(g.usize_in(2, 6) as u32);
            }
            let n_add = g.usize_in(2, 4);
            let seed = g.rng().next_u64();
            let (ck, net) = net_for(&dims, &bits, seed, n_add);
            let inputs: Vec<Vec<u32>> = (0..10)
                .map(|_| {
                    (0..dims[0])
                        .map(|_| g.rng().below(1 << ck.bits[0]) as u32)
                        .collect()
                })
                .collect();
            let mut sim = CycleSim::new(&net);
            let completions = sim.run_stream(&inputs);
            if completions.len() != inputs.len() {
                return Err(format!("{} of {} completed", completions.len(), inputs.len()));
            }
            for c in &completions {
                let want = eval(&net, &inputs[c.id as usize]);
                if c.sums != want {
                    return Err(format!("sample {}: {:?} != {:?}", c.id, c.sums, want));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dead_neuron_outputs_zero() {
        // craft a checkpoint where one output has no active edges
        let mut ck = synthetic(&[3, 2], &[4, 6], 55);
        let l = &mut ck.layers[0];
        for p in 0..l.d_in {
            l.mask[0 * l.d_in + p] = false;
            l.table[0 * l.d_in + p] = None;
        }
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        let sums = eval(&net, &[0, 1, 2]);
        assert_eq!(sums[0], 0);
    }
}
