//! LogicNets-style baseline (Umuroglu et al. 2020).
//!
//! LogicNets trains a sparse MLP where every neuron has a bounded fan-in F
//! of beta-bit activations; each neuron (dot-product + BN + quantized
//! activation) is *collapsed into one logical LUT* with F*beta address bits
//! and beta output bits. Because neurons chain LUT->LUT, the cost is
//! exponential in F*beta — and pruning a LUT breaks the indexing of every
//! downstream LUT, which is the contrast the paper draws with KANELE's
//! additive independence (§3.3).

use super::BaselineReport;

use crate::synth::plut_cost;

/// One LogicNets layer: d_out neurons, each reading `fanin` inputs of
/// `bits_in` bits and emitting `bits_out` bits.
#[derive(Clone, Copy, Debug)]
pub struct LogicNetsLayer {
    pub d_out: usize,
    pub fanin: usize,
    pub bits_in: u32,
    pub bits_out: u32,
}

/// Whole-network config.
#[derive(Clone, Debug)]
pub struct LogicNetsCfg {
    pub name: String,
    pub layers: Vec<LogicNetsLayer>,
}

impl LogicNetsCfg {
    /// The JSC-sized config from the LogicNets paper (JSC-M/L flavour).
    pub fn jsc_l() -> Self {
        LogicNetsCfg {
            name: "LogicNets JSC-L".into(),
            layers: vec![
                LogicNetsLayer { d_out: 32, fanin: 4, bits_in: 3, bits_out: 3 },
                LogicNetsLayer { d_out: 64, fanin: 4, bits_in: 3, bits_out: 3 },
                LogicNetsLayer { d_out: 192, fanin: 4, bits_in: 3, bits_out: 3 },
                LogicNetsLayer { d_out: 5, fanin: 4, bits_in: 3, bits_out: 7 },
            ],
        }
    }

    pub fn estimate(&self) -> BaselineReport {
        let mut luts = 0u64;
        let mut ffs = 0u64;
        let mut worst_addr = 0u32;
        for l in &self.layers {
            let addr = l.fanin as u32 * l.bits_in;
            worst_addr = worst_addr.max(addr);
            // one logical LUT per neuron: addr -> bits_out
            luts += l.d_out as u64 * plut_cost(addr, l.bits_out);
            // pipeline register per neuron output
            ffs += l.d_out as u64 * l.bits_out as u64;
        }
        // deep LUT cascades route badly; clock model: base + per-mux-level
        let mux_levels = worst_addr.saturating_sub(6) as f64;
        let period = 0.35 + 0.16 * mux_levels + 0.12;
        let fmax_mhz = (1000.0 / period).min(900.0);
        let cycles = self.layers.len() + 1;
        BaselineReport {
            name: self.name.clone(),
            luts,
            ffs,
            dsps: 0,
            brams: 0,
            fmax_mhz,
            latency_cycles: cycles,
            latency_ns: 0.0,
            area_delay: 0.0,
        }
        .finish()
    }

    /// Demonstration of the pruning-incompatibility argument (§3.3): the
    /// cost of a LogicNets neuron is unchanged when an *input* of its LUT
    /// becomes irrelevant, because the truth table's address space cannot
    /// shrink without retraining every downstream LUT.
    pub fn cost_after_input_pruning(&self, layer: usize) -> (u64, u64) {
        let l = &self.layers[layer];
        let full = plut_cost(l.fanin as u32 * l.bits_in, l.bits_out);
        // pruning one input only helps if the table is re-synthesized with a
        // smaller address space — which changes the network function:
        let ideal = plut_cost((l.fanin as u32 - 1) * l.bits_in, l.bits_out);
        (full, ideal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsc_l_shape() {
        let r = LogicNetsCfg::jsc_l().estimate();
        // order of magnitude of the published JSC LogicNets design (~3e4 LUTs)
        assert!(r.luts > 100, "LUTs {}", r.luts);
        assert!(r.dsps == 0 && r.brams == 0);
        assert!(r.latency_cycles >= 4);
        assert!(r.fmax_mhz > 200.0);
    }

    #[test]
    fn exponential_in_fanin_bits() {
        let small = LogicNetsCfg {
            name: "s".into(),
            layers: vec![LogicNetsLayer { d_out: 10, fanin: 2, bits_in: 2, bits_out: 2 }],
        }
        .estimate();
        let big = LogicNetsCfg {
            name: "b".into(),
            layers: vec![LogicNetsLayer { d_out: 10, fanin: 4, bits_in: 3, bits_out: 2 }],
        }
        .estimate();
        // 4 address bits -> 12 address bits: cost explodes
        assert!(big.luts > small.luts * 16, "{} vs {}", big.luts, small.luts);
    }

    #[test]
    fn pruning_cannot_shrink_tables() {
        let cfg = LogicNetsCfg::jsc_l();
        let (full, ideal) = cfg.cost_after_input_pruning(0);
        assert!(full > ideal, "re-synthesized table would be smaller ({full} vs {ideal}) — but requires retraining");
    }

    #[test]
    fn depth_helper_consistency() {
        // adder_depth is reused by other baselines; sanity-check linkage
        assert_eq!(crate::netlist::adder_depth(4, 2), 2);
    }
}
