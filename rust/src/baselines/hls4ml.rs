//! hls4ml-style dense MLP baseline (Fahim et al. 2021; Tables 3, 5, 7).
//!
//! hls4ml compiles a dense quantized MLP to HLS: every MAC maps to a DSP
//! (or LUT fabric when bits are small / DSPs exhausted), weights live in
//! BRAM/LUTRAM above a size threshold, and a reuse factor R trades DSPs for
//! initiation interval (II = R). The model below follows the hls4ml
//! resource-estimation rules closely enough to reproduce the paper's
//! contrast rows (Table 5's 207 DSP / II 144 AE; Table 7's 14k-DSP MLP
//! actor that does not fit on the xczu7ev).

use super::BaselineReport;

#[derive(Clone, Debug)]
pub struct Hls4mlCfg {
    pub name: String,
    pub dims: Vec<usize>,
    pub bits: u32,
    /// Reuse factor: DSPs per layer = MACs / reuse, II = reuse.
    pub reuse: usize,
    /// `Resource` strategy (weights in BRAM, deeper II) vs `Latency`.
    pub resource_strategy: bool,
}

impl Hls4mlCfg {
    pub fn mults(&self) -> u64 {
        self.dims.windows(2).map(|w| (w[0] * w[1]) as u64).sum()
    }

    pub fn params(&self) -> u64 {
        self.dims.windows(2).map(|w| (w[0] * w[1] + w[1]) as u64).sum()
    }

    pub fn estimate(&self) -> BaselineReport {
        let mults = self.mults();
        let reuse = self.reuse.max(1) as u64;
        // DSP packing: two <=8-bit mults per DSP48 when bits <= 8
        let mult_per_dsp = if self.bits <= 8 { 2 } else { 1 };
        let dsps = mults.div_ceil(reuse * mult_per_dsp);
        // accumulators, control FSM, activation tables
        let acc_width = (2 * self.bits + 8) as u64;
        let neurons: u64 = self.dims[1..].iter().map(|&d| d as u64).sum();
        let luts = neurons * (acc_width * 3 + 40) + mults / reuse * 6;
        let ffs = neurons * acc_width * 2 + dsps * 48;
        // weights: BRAM when resource strategy and layer weights exceed 4Kb
        let brams = if self.resource_strategy {
            self.dims
                .windows(2)
                .map(|w| {
                    let bits = (w[0] * w[1]) as u64 * self.bits as u64;
                    bits.div_ceil(36 * 1024)
                })
                .sum()
        } else {
            0
        };
        let fmax_mhz: f64 = if self.resource_strategy { 200.0 } else { 250.0 };
        // per-layer pipeline: load/mac(II=reuse)/activation
        let cycles = self.dims.len().saturating_sub(1) * (reuse as usize + 4) + 4;
        BaselineReport {
            name: self.name.clone(),
            luts,
            ffs,
            dsps,
            brams,
            fmax_mhz,
            latency_cycles: cycles,
            latency_ns: 0.0,
            area_delay: 0.0,
        }
        .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mult_and_param_counts() {
        let c = Hls4mlCfg {
            name: "t".into(),
            dims: vec![16, 64, 32, 5],
            bits: 8,
            reuse: 1,
            resource_strategy: false,
        };
        assert_eq!(c.mults(), 16 * 64 + 64 * 32 + 32 * 5);
        assert_eq!(c.params(), 16 * 64 + 64 + 64 * 32 + 32 + 32 * 5 + 5);
    }

    #[test]
    fn reuse_trades_dsps_for_latency() {
        let mk = |r| Hls4mlCfg {
            name: "t".into(),
            dims: vec![64, 64, 64],
            bits: 8,
            reuse: r,
            resource_strategy: true,
        };
        let fast = mk(1).estimate();
        let slow = mk(16).estimate();
        assert!(slow.dsps < fast.dsps);
        assert!(slow.latency_cycles > fast.latency_cycles);
    }

    #[test]
    fn resource_strategy_uses_bram() {
        let c = Hls4mlCfg {
            name: "t".into(),
            dims: vec![64, 128, 64],
            bits: 8,
            reuse: 8,
            resource_strategy: true,
        };
        assert!(c.estimate().brams > 0);
    }

    #[test]
    fn table7_mlp_actor_exceeds_zu7ev() {
        // the paper's 8-bit [17,64,64,6] MLP actor at reuse 1 does not fit:
        // hls4ml reports ~14k DSPs vs the device's 1,728
        let c = Hls4mlCfg {
            name: "MLP actor 8-bit".into(),
            dims: vec![17, 64, 64, 6],
            bits: 8,
            reuse: 1,
            resource_strategy: true,
        };
        let r = c.estimate();
        let dev = crate::synth::XCZU7EV;
        // unrolled-by-batch HLS designs replicate MACs; our single-sample
        // model under-counts vs the paper's 14k figure but must still show
        // the qualitative gap class (thousands of DSPs at low reuse)
        assert!(r.dsps as f64 > dev.dsps as f64 / 2.0, "dsps = {}", r.dsps);
    }
}
