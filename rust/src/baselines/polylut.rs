//! PolyLUT-style baseline (Andronic & Constantinides 2023) and the
//! PolyLUT-Add variant (Lou et al. 2024).
//!
//! PolyLUT tabulates degree-D multivariate polynomials of F inputs per
//! neuron in a single logical LUT (F*beta address bits). It represents
//! products natively — at the price of the same exponential address-space
//! growth as LogicNets, with bigger constants because higher accuracy
//! demands higher F. PolyLUT-Add splits each neuron into A sub-LUTs of
//! fan-in F/A combined by an adder, trading address width for adders —
//! exactly the structural trick KANELE gets "for free" from the KAN
//! formulation (every edge is additive, A = fan-in).

use super::BaselineReport;
use crate::netlist::adder_depth;
use crate::synth::plut_cost;

#[derive(Clone, Copy, Debug)]
pub struct PolyLutLayer {
    pub d_out: usize,
    pub fanin: usize,
    pub bits: u32,
    pub degree: u32,
    /// Number of additive sub-LUTs per neuron (1 = plain PolyLUT).
    pub n_sub: usize,
}

#[derive(Clone, Debug)]
pub struct PolyLutCfg {
    pub name: String,
    pub layers: Vec<PolyLutLayer>,
}

/// Binomial coefficient (n choose k) saturating at u64::MAX.
pub fn binom(n: u64, k: u64) -> u64 {
    let k = k.min(n - k.min(n));
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc * (n - i) as u128 / (i + 1) as u128;
        if acc > u64::MAX as u128 {
            return u64::MAX;
        }
    }
    acc as u64
}

impl PolyLutCfg {
    /// JSC-sized plain PolyLUT (fan-in 6, degree 2) per the paper's setup.
    pub fn jsc(degree: u32) -> Self {
        PolyLutCfg {
            name: if degree > 1 { "PolyLUT JSC".into() } else { "LUT-MLP JSC".into() },
            layers: vec![
                PolyLutLayer { d_out: 32, fanin: 6, bits: 3, degree, n_sub: 1 },
                PolyLutLayer { d_out: 16, fanin: 6, bits: 3, degree, n_sub: 1 },
                PolyLutLayer { d_out: 5, fanin: 6, bits: 3, degree, n_sub: 1 },
            ],
        }
    }

    /// PolyLUT-Add: same topology, each neuron split into `a` sub-LUTs.
    pub fn jsc_add(degree: u32, a: usize) -> Self {
        let mut cfg = Self::jsc(degree);
        cfg.name = format!("PolyLUT-Add(A={a}) JSC");
        for l in &mut cfg.layers {
            l.n_sub = a;
        }
        cfg
    }

    /// Number of polynomial features per sub-LUT (monomials up to degree D
    /// in F/A variables) — informational; hardware cost is address-bound.
    pub fn monomials(fanin: usize, degree: u32) -> u64 {
        binom(fanin as u64 + degree as u64, degree as u64)
    }

    pub fn estimate(&self) -> BaselineReport {
        let mut luts = 0u64;
        let mut ffs = 0u64;
        let mut worst_addr = 0u32;
        let mut extra_depth = 0usize;
        for l in &self.layers {
            let sub_fanin = l.fanin.div_ceil(l.n_sub);
            let addr = sub_fanin as u32 * l.bits;
            worst_addr = worst_addr.max(addr);
            let sub_out_bits = l.bits + 2; // sub-sums carry guard bits
            luts += (l.d_out * l.n_sub) as u64 * plut_cost(addr, sub_out_bits);
            ffs += (l.d_out * l.n_sub) as u64 * sub_out_bits as u64;
            if l.n_sub > 1 {
                let d = adder_depth(l.n_sub, 2);
                extra_depth = extra_depth.max(d);
                luts += l.d_out as u64 * (l.n_sub as u64 - 1) * sub_out_bits as u64;
                ffs += l.d_out as u64 * sub_out_bits as u64 * d as u64;
            }
        }
        let mux_levels = worst_addr.saturating_sub(6) as f64;
        let period = 0.35 + 0.16 * mux_levels + 0.12;
        let fmax_mhz = (1000.0 / period).min(900.0);
        let cycles = self.layers.len() * (1 + extra_depth) + 1;
        BaselineReport {
            name: self.name.clone(),
            luts,
            ffs,
            dsps: 0,
            brams: 0,
            fmax_mhz,
            latency_cycles: cycles,
            latency_ns: 0.0,
            area_delay: 0.0,
        }
        .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binom_values() {
        assert_eq!(binom(6, 2), 15);
        assert_eq!(binom(8, 0), 1);
        assert_eq!(binom(8, 8), 1);
        assert_eq!(binom(10, 3), 120);
    }

    #[test]
    fn monomial_count() {
        // degree-2 polynomial in 6 vars: C(8,2) = 28 monomials
        assert_eq!(PolyLutCfg::monomials(6, 2), 28);
    }

    #[test]
    fn add_variant_cheaper_than_plain() {
        // The PolyLUT-Add claim: splitting fan-in across added sub-LUTs
        // shrinks the exponential term more than the adders cost.
        let plain = PolyLutCfg::jsc(2).estimate();
        let added = PolyLutCfg::jsc_add(2, 2).estimate();
        assert!(added.luts < plain.luts, "{} !< {}", added.luts, plain.luts);
    }

    #[test]
    fn polylut_much_bigger_than_logicnets_at_same_task() {
        use crate::baselines::logicnets::LogicNetsCfg;
        let poly = PolyLutCfg::jsc(2).estimate();
        let logic = LogicNetsCfg::jsc_l().estimate();
        // PolyLUT's fan-in 6 x 3 bits = 18 address bits dwarfs LogicNets' 12
        assert!(poly.luts > logic.luts, "{} !> {}", poly.luts, logic.luts);
    }

    #[test]
    fn latency_grows_with_add_depth() {
        let a1 = PolyLutCfg::jsc_add(2, 1).estimate();
        let a4 = PolyLutCfg::jsc_add(2, 4).estimate();
        assert!(a4.latency_cycles > a1.latency_cycles);
    }
}
