//! Baseline architectures from the paper's evaluation (Tables 3-5, 7).
//!
//! Two kinds of baseline live here:
//!
//! * **Structural cost models** — generators that, given the baseline's own
//!   architecture hyperparameters (topology, fan-in, bitwidths, polynomial
//!   degree, reuse factor), price its FPGA realization with the same device
//!   models [`crate::synth`] uses for KANELE. These reproduce *how each
//!   architecture scales* (LogicNets/PolyLUT exponential in fan-in x bits,
//!   hls4ml DSP-bound, Tran et al. BRAM/DSP-bound) — the property the
//!   paper's comparisons rest on.
//! * **Published rows** ([`published`]) — the exact numbers printed in the
//!   paper for externally-trained systems, reported alongside our model
//!   outputs so every table can show paper-vs-reproduction.

pub mod hls4ml;
pub mod logicnets;
pub mod polylut;
pub mod published;
pub mod tran;

/// Common resource/timing estimate shared by all baseline models.
#[derive(Clone, Debug, Default)]
pub struct BaselineReport {
    pub name: String,
    pub luts: u64,
    pub ffs: u64,
    pub dsps: u64,
    pub brams: u64,
    pub fmax_mhz: f64,
    pub latency_cycles: usize,
    pub latency_ns: f64,
    pub area_delay: f64,
}

impl BaselineReport {
    pub fn finish(mut self) -> Self {
        if self.latency_ns == 0.0 && self.fmax_mhz > 0.0 {
            self.latency_ns = self.latency_cycles as f64 / (self.fmax_mhz / 1000.0);
        }
        self.area_delay = self.luts as f64 * self.latency_ns;
        self
    }
}
