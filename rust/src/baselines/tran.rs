//! Prior KAN-on-FPGA baseline (Tran et al. 2024, CANDARW) — the design the
//! paper reports 2700x latency / 4000x LUT improvements over (Table 4).
//!
//! Tran et al. evaluate splines *arithmetically* on the FPGA: per edge, the
//! B-spline coefficients live in BRAM, a de Boor evaluation pipeline built
//! from DSP multipliers computes phi(x) at runtime, and layers execute
//! sequentially with little pipelining. The cost model below reproduces
//! that architecture's scaling (BRAM ~ edges, DSPs ~ parallel evaluation
//! units, latency ~ edges x recursion depth / parallelism) and is
//! calibrated to land in the magnitude class of their published Table 4
//! rows (e.g. Dry Bean: 1.7M LUTs, 9111 DSPs, 781 BRAMs, 18,960 ns).

use super::BaselineReport;

#[derive(Clone, Debug)]
pub struct TranKanCfg {
    pub name: String,
    pub dims: Vec<usize>,
    pub grid_size: usize,
    pub order: usize,
    /// Evaluation parallelism (edges evaluated concurrently per layer).
    pub parallel: usize,
}

impl TranKanCfg {
    pub fn for_dims(name: &str, dims: &[usize], grid_size: usize, order: usize) -> Self {
        TranKanCfg {
            name: format!("KAN (Tran et al) {name}"),
            dims: dims.to_vec(),
            grid_size,
            order,
            // their designs unroll aggressively per edge
            parallel: dims.windows(2).map(|w| w[0] * w[1]).max().unwrap_or(1),
        }
    }

    pub fn edges(&self) -> u64 {
        self.dims.windows(2).map(|w| (w[0] * w[1]) as u64).sum()
    }

    pub fn estimate(&self) -> BaselineReport {
        let edges = self.edges();
        let s = self.order as u64;
        // de Boor: S levels, each level ~2 mult + 2 add per active basis;
        // fixed-point 32-bit datapath per evaluation unit
        let dsps_per_unit = 2 * s + 2;
        let units = self.parallel as u64;
        let dsps = units * dsps_per_unit / 2; // DSP48 packs mult+acc
        // coefficient storage: (G+S) coeffs x 32b per edge in BRAM
        let coeff_bits = edges * (self.grid_size as u64 + s) * 32;
        let brams = coeff_bits.div_ceil(36 * 1024).max(edges / 8);
        // datapath + interconnect LUTs/FFs per unit (measured class from
        // their tables: ~180 LUTs and ~80 FFs per unrolled edge unit)
        let luts = units * 184 + edges * 12;
        let ffs = units * 81 + edges * 6;
        let fmax_mhz = 100.0; // their designs close ~100 MHz
        // Evaluation is effectively edge-serial despite the unrolled units:
        // coefficient BRAM ports and the de Boor recurrence serialize each
        // edge's S+4-cycle evaluation, and layers execute sequentially
        // (x3 covers their measured memory/framing stalls; calibrated to
        // land in the cycle-count class of their Table 4 rows).
        let mut cycles = 0usize;
        for w in self.dims.windows(2) {
            let layer_edges = w[0] * w[1];
            cycles += layer_edges * (self.order + 4) * 3 + 16;
        }
        BaselineReport {
            name: self.name.clone(),
            luts,
            ffs,
            dsps,
            brams,
            fmax_mhz,
            latency_cycles: cycles,
            latency_ns: 0.0,
            area_delay: 0.0,
        }
        .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drybean() -> TranKanCfg {
        // their Dry Bean network is much larger than ours: [16, 2, 7] with
        // wide parallel spline units; parallelism tuned to land in their class
        let mut c = TranKanCfg::for_dims("drybean", &[16, 64, 7], 5, 3);
        c.parallel = 16 * 64;
        c
    }

    #[test]
    fn uses_bram_and_dsp_heavily() {
        let r = drybean().estimate();
        assert!(r.brams > 50, "brams = {}", r.brams);
        assert!(r.dsps > 1000, "dsps = {}", r.dsps);
        assert!(r.luts > 100_000, "luts = {}", r.luts);
    }

    #[test]
    fn latency_orders_of_magnitude_above_kanele() {
        let r = drybean().estimate();
        // KANELE's Dry Bean latency is ~7 ns; Tran's must be > 1000x that
        assert!(r.latency_ns > 7_000.0, "latency = {} ns", r.latency_ns);
    }

    #[test]
    fn latency_scales_with_edges() {
        let small = TranKanCfg::for_dims("s", &[2, 2, 1], 5, 3).estimate();
        let big = TranKanCfg::for_dims("b", &[16, 64, 7], 5, 3).estimate();
        assert!(big.latency_cycles > small.latency_cycles);
        assert!(big.brams >= small.brams);
    }
}
