//! Published numbers from the paper's tables, reproduced verbatim so every
//! regenerated table prints paper-vs-measured side by side.

/// One row of a hardware-comparison table as printed in the paper.
#[derive(Clone, Copy, Debug)]
pub struct PaperRow {
    pub dataset: &'static str,
    pub model: &'static str,
    pub accuracy: f64,
    pub luts: u64,
    pub ffs: u64,
    pub dsps: u64,
    pub brams: u64,
    pub fmax_mhz: f64,
    pub latency_ns: f64,
    pub area_delay: f64,
}

/// Paper Table 3: KANELE vs LUT-based NN architectures (xcvu9p).
pub const TABLE3: &[PaperRow] = &[
    // JSC CERNBox
    PaperRow { dataset: "jsc_cernbox", model: "KANELE", accuracy: 75.1, luts: 5034, ffs: 1917, dsps: 0, brams: 0, fmax_mhz: 870.0, latency_ns: 8.1, area_delay: 4.1e4 },
    PaperRow { dataset: "jsc_cernbox", model: "NeuraLUT-Assemble", accuracy: 75.0, luts: 8539, ffs: 1332, dsps: 0, brams: 0, fmax_mhz: 352.0, latency_ns: 5.7, area_delay: 4.87e4 },
    PaperRow { dataset: "jsc_cernbox", model: "AmigoLUT-NeuraLUT", accuracy: 74.4, luts: 42742, ffs: 4717, dsps: 0, brams: 0, fmax_mhz: 520.0, latency_ns: 9.6, area_delay: 4.10e5 },
    PaperRow { dataset: "jsc_cernbox", model: "PolyLUT-Add", accuracy: 75.0, luts: 36484, ffs: 1209, dsps: 0, brams: 0, fmax_mhz: 315.0, latency_ns: 16.0, area_delay: 5.84e5 },
    PaperRow { dataset: "jsc_cernbox", model: "NeuraLUT", accuracy: 75.1, luts: 92357, ffs: 4885, dsps: 0, brams: 0, fmax_mhz: 368.0, latency_ns: 14.0, area_delay: 1.29e6 },
    PaperRow { dataset: "jsc_cernbox", model: "PolyLUT", accuracy: 75.0, luts: 246071, ffs: 12384, dsps: 0, brams: 0, fmax_mhz: 203.0, latency_ns: 25.0, area_delay: 6.15e6 },
    PaperRow { dataset: "jsc_cernbox", model: "LogicNets", accuracy: 72.0, luts: 37931, ffs: 810, dsps: 0, brams: 0, fmax_mhz: 427.0, latency_ns: 13.0, area_delay: 4.93e5 },
    // JSC OpenML
    PaperRow { dataset: "jsc_openml", model: "KANELE", accuracy: 76.0, luts: 1232, ffs: 900, dsps: 0, brams: 0, fmax_mhz: 987.0, latency_ns: 7.1, area_delay: 8.7e3 },
    PaperRow { dataset: "jsc_openml", model: "NeuraLUT-Assemble", accuracy: 76.0, luts: 1780, ffs: 540, dsps: 0, brams: 0, fmax_mhz: 941.0, latency_ns: 2.1, area_delay: 3.92e3 },
    PaperRow { dataset: "jsc_openml", model: "TreeLUT", accuracy: 75.6, luts: 2234, ffs: 347, dsps: 0, brams: 0, fmax_mhz: 735.0, latency_ns: 2.7, area_delay: 6.03e3 },
    PaperRow { dataset: "jsc_openml", model: "DWN", accuracy: 76.3, luts: 4972, ffs: 3305, dsps: 0, brams: 0, fmax_mhz: 827.0, latency_ns: 7.3, area_delay: 3.6e4 },
    PaperRow { dataset: "jsc_openml", model: "da4ml", accuracy: 76.9, luts: 12250, ffs: 1502, dsps: 0, brams: 0, fmax_mhz: 212.0, latency_ns: 18.9, area_delay: 2.3e5 },
    PaperRow { dataset: "jsc_openml", model: "hls4ml (Fahim)", accuracy: 76.2, luts: 63251, ffs: 4394, dsps: 38, brams: 0, fmax_mhz: 200.0, latency_ns: 45.0, area_delay: 2.85e6 },
    // MNIST
    PaperRow { dataset: "mnist", model: "KANELE", accuracy: 96.3, luts: 3809, ffs: 4133, dsps: 0, brams: 0, fmax_mhz: 864.0, latency_ns: 9.3, area_delay: 3.5e4 },
    PaperRow { dataset: "mnist", model: "NeuraLUT-Assemble", accuracy: 97.9, luts: 5070, ffs: 725, dsps: 0, brams: 0, fmax_mhz: 863.0, latency_ns: 2.1, area_delay: 1.06e4 },
    PaperRow { dataset: "mnist", model: "TreeLUT", accuracy: 96.6, luts: 4478, ffs: 597, dsps: 0, brams: 0, fmax_mhz: 791.0, latency_ns: 2.5, area_delay: 1.12e4 },
    PaperRow { dataset: "mnist", model: "DWN", accuracy: 97.8, luts: 2092, ffs: 1757, dsps: 0, brams: 0, fmax_mhz: 873.0, latency_ns: 9.2, area_delay: 1.92e4 },
    PaperRow { dataset: "mnist", model: "PolyLUT-Add", accuracy: 96.0, luts: 14810, ffs: 2609, dsps: 0, brams: 0, fmax_mhz: 625.0, latency_ns: 10.0, area_delay: 1.48e5 },
    PaperRow { dataset: "mnist", model: "AmigoLUT-NeuraLUT", accuracy: 95.5, luts: 16081, ffs: 13292, dsps: 0, brams: 0, fmax_mhz: 925.0, latency_ns: 7.6, area_delay: 1.22e5 },
    PaperRow { dataset: "mnist", model: "NeuraLUT", accuracy: 96.0, luts: 54798, ffs: 3757, dsps: 0, brams: 0, fmax_mhz: 431.0, latency_ns: 12.0, area_delay: 6.58e5 },
    PaperRow { dataset: "mnist", model: "PolyLUT", accuracy: 97.5, luts: 75131, ffs: 4668, dsps: 0, brams: 0, fmax_mhz: 353.0, latency_ns: 17.0, area_delay: 1.38e6 },
    PaperRow { dataset: "mnist", model: "FINN", accuracy: 96.0, luts: 91131, ffs: 0, dsps: 0, brams: 5, fmax_mhz: 200.0, latency_ns: 310.0, area_delay: 2.82e7 },
    PaperRow { dataset: "mnist", model: "hls4ml (Ngadiuba)", accuracy: 95.0, luts: 260092, ffs: 165513, dsps: 0, brams: 345, fmax_mhz: 200.0, latency_ns: 190.0, area_delay: 4.94e7 },
];

/// Paper Table 4: prior KAN-FPGA works (xczu7ev).
pub const TABLE4: &[PaperRow] = &[
    PaperRow { dataset: "moons", model: "KANELE", accuracy: 97.0, luts: 67, ffs: 57, dsps: 0, brams: 0, fmax_mhz: 1736.0, latency_ns: 2.9, area_delay: 1.9e2 },
    PaperRow { dataset: "moons", model: "KAN (Tran et al)", accuracy: 97.0, luts: 17877, ffs: 8622, dsps: 120, brams: 10, fmax_mhz: 100.0, latency_ns: 1280.0, area_delay: 2.3e7 },
    PaperRow { dataset: "moons", model: "ChebyUnit", accuracy: 100.0, luts: 9888, ffs: 12150, dsps: 40, brams: 10, fmax_mhz: 100.0, latency_ns: 130.0, area_delay: 1.3e6 },
    PaperRow { dataset: "wine", model: "KANELE", accuracy: 98.0, luts: 534, ffs: 686, dsps: 0, brams: 0, fmax_mhz: 983.0, latency_ns: 6.1, area_delay: 8.8e3 },
    PaperRow { dataset: "wine", model: "KAN (Tran et al)", accuracy: 97.0, luts: 146843, ffs: 74741, dsps: 950, brams: 132, fmax_mhz: 100.0, latency_ns: 6880.0, area_delay: 1.0e9 },
    PaperRow { dataset: "wine", model: "ChebyUnit", accuracy: 95.0, luts: 30154, ffs: 22104, dsps: 324, brams: 132, fmax_mhz: 100.0, latency_ns: 130.0, area_delay: 3.9e6 },
    PaperRow { dataset: "dry_bean", model: "KANELE", accuracy: 92.0, luts: 402, ffs: 471, dsps: 0, brams: 0, fmax_mhz: 842.0, latency_ns: 7.1, area_delay: 3.3e3 },
    PaperRow { dataset: "dry_bean", model: "KAN (Tran et al)", accuracy: 92.0, luts: 1677558, ffs: 734544, dsps: 9111, brams: 781, fmax_mhz: 100.0, latency_ns: 18960.0, area_delay: 3.2e10 },
    PaperRow { dataset: "dry_bean", model: "ChebyUnit", accuracy: 92.0, luts: 27359, ffs: 25198, dsps: 256, brams: 781, fmax_mhz: 100.0, latency_ns: 130.0, area_delay: 3.6e6 },
];

/// Paper Table 5: ToyADMOS on xc7a100t (AUC, throughput, energy).
#[derive(Clone, Copy, Debug)]
pub struct Table5Row {
    pub model: &'static str,
    pub auc: f64,
    pub brams: f64,
    pub dsps: u64,
    pub ffs: u64,
    pub luts: u64,
    pub lutram: u64,
    pub ii: u64,
    pub throughput_inf_s: f64,
    pub latency_us: f64,
    pub energy_uj: f64,
}

pub const TABLE5: &[Table5Row] = &[
    Table5Row { model: "KANELE", auc: 0.83, brams: 0.0, dsps: 0, ffs: 17_643, luts: 29_981, lutram: 0, ii: 1, throughput_inf_s: 228e6, latency_us: 0.07, energy_uj: 0.01 },
    Table5Row { model: "hls4ml (MLPerf Tiny v0.7)", auc: 0.83, brams: 22.5, dsps: 207, ffs: 61_639, luts: 51_429, lutram: 5_780, ii: 144, throughput_inf_s: 694e3, latency_us: 45.0, energy_uj: 98.4 },
];

/// Paper Table 2: accuracy columns (MLP FP / KAN FP / KAN Q&P).
#[derive(Clone, Copy, Debug)]
pub struct Table2Row {
    pub dataset: &'static str,
    pub mlp_fp: f64,
    pub kan_fp: f64,
    pub kan_qp: f64,
}

pub const TABLE2: &[Table2Row] = &[
    Table2Row { dataset: "moons", mlp_fp: 87.2, kan_fp: 97.7, kan_qp: 97.4 },
    Table2Row { dataset: "wine", mlp_fp: 96.3, kan_fp: 98.1, kan_qp: 98.2 },
    Table2Row { dataset: "dry_bean", mlp_fp: 90.9, kan_fp: 92.2, kan_qp: 92.1 },
    Table2Row { dataset: "mnist", mlp_fp: 96.7, kan_fp: 97.9, kan_qp: 96.3 },
    Table2Row { dataset: "jsc_cernbox", mlp_fp: 73.0, kan_fp: 75.1, kan_qp: 75.1 },
    Table2Row { dataset: "jsc_openml", mlp_fp: 76.5, kan_fp: 76.5, kan_qp: 76.0 },
    Table2Row { dataset: "toyadmos", mlp_fp: 0.80, kan_fp: 0.83, kan_qp: 0.83 },
];

/// Paper Table 7: RL actor hardware (xczu7ev).
#[derive(Clone, Copy, Debug)]
pub struct Table7Row {
    pub model: &'static str,
    pub reward: f64,
    pub fmax_mhz: f64,
    pub latency_ns: f64,
    pub brams: u64,
    pub dsps: u64,
    pub ffs: u64,
    pub luts: u64,
    pub area_delay: f64,
}

pub const TABLE7: &[Table7Row] = &[
    Table7Row { model: "KAN 8-bit", reward: 2762.2, fmax_mhz: 884.0, latency_ns: 4.5, brams: 0, dsps: 0, ffs: 2828, luts: 1136, area_delay: 1.3e4 },
    Table7Row { model: "MLP 8-bit hls4ml", reward: 1558.8, fmax_mhz: 500.0, latency_ns: 893.0, brams: 0, dsps: 14346, ffs: 460800, luts: 230400, area_delay: 2.1e8 },
];

pub fn table3_for(dataset: &str) -> Vec<PaperRow> {
    TABLE3.iter().filter(|r| r.dataset == dataset).copied().collect()
}

pub fn table4_for(dataset: &str) -> Vec<PaperRow> {
    TABLE4.iter().filter(|r| r.dataset == dataset).copied().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_nonempty_and_consistent() {
        assert_eq!(TABLE3.iter().filter(|r| r.model == "KANELE").count(), 3);
        assert_eq!(TABLE4.iter().filter(|r| r.model == "KANELE").count(), 3);
        assert_eq!(TABLE2.len(), 7);
        // area_delay column ~ luts * latency for the KANELE rows
        for r in TABLE3.iter().filter(|r| r.model == "KANELE") {
            let ad = r.luts as f64 * r.latency_ns;
            assert!((ad - r.area_delay).abs() / r.area_delay < 0.05, "{}: {ad} vs {}", r.dataset, r.area_delay);
        }
    }

    #[test]
    fn filters() {
        assert_eq!(table3_for("mnist").len(), 10);
        assert_eq!(table4_for("wine").len(), 3);
        assert!(table3_for("nope").is_empty());
    }

    #[test]
    fn headline_ratios_present() {
        // §5.4 headline: >2600x latency, >4000x LUT reduction on Dry Bean
        let rows = table4_for("dry_bean");
        let kanele = rows.iter().find(|r| r.model == "KANELE").unwrap();
        let tran = rows.iter().find(|r| r.model.contains("Tran")).unwrap();
        assert!(tran.latency_ns / kanele.latency_ns > 2600.0);
        assert!(tran.luts as f64 / kanele.luts as f64 > 4000.0);
    }
}
