//! The compiled program representation: structure-of-arrays LUT storage, a
//! preplanned fused op stream, integer requantization plans, and per-layer
//! accumulator lanes.
//!
//! [`CompiledProgram::compile`] lowers a [`Netlist`] once; execution then
//! never touches the netlist object graph again — and, since this PR, never
//! touches floating point either. Layout decisions:
//!
//! * **Packed, narrowed tables** — every truth table is appended to one of
//!   two contiguous arenas; an op addresses its table by `(offset, mask)`
//!   within its layer's arena. A compile-time range analysis
//!   ([`analyze_lane`]) proves, per layer, whether every table entry *and*
//!   every in-order partial accumulator sum fits in i32; if so the layer's
//!   tables live in the i32 arena and its sums run in the i32 scratch lane,
//!   halving hot-loop bandwidth. Layers that could overflow keep the exact
//!   i64 lane. Ops are emitted in `(layer, neuron, lut)` order, so the
//!   executor walks each arena front to back: sequential scans instead of
//!   the interpreter's per-sample pointer chase.
//! * **Fused ops** — one [`LutOp`] is a LUT gather *and* the accumulate
//!   into its neuron's sum; the adder tree is a compile-time fiction here
//!   (in-lane addition is exact by the range analysis, so any summation
//!   order is bit-identical to the pipelined tree the RTL and
//!   [`crate::sim::CycleSim`] implement).
//! * **Requant plans** — the inter-layer quantize/saturate node is lowered
//!   by [`RequantPlan::build`] from the layer's [`Quantizer`] into
//!   integer-only form: a fixed-point multiply/shift/clamp whose constants
//!   are *constructed from* the exact code-boundary thresholds (so it is
//!   bit-exact by construction, not by sampling), falling back to a sorted
//!   threshold table when no linear form fits, and to the float oracle only
//!   for code widths beyond [`PLAN_MAX_BITS`] (never produced by the
//!   paper's flows). Equality with `Quantizer::encode_fixed` is enforced by
//!   exhaustive and property tests below.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::Arc;

use crate::fixed::Quantizer;
use crate::netlist::{LayerNet, Netlist};

use super::optim::{self, OptLevel, OptReport};

/// One fused LUT-gather + accumulate op with fully resolved indices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LutOp {
    /// Start of this op's truth table in its layer's packed arena
    /// (i32 or i64 arena according to [`LayerPlan::lane`]).
    pub table_off: u32,
    /// `table_len - 1`; masking the address reproduces the RTL's
    /// truncation semantics (tables are power-of-two sized).
    pub addr_mask: u32,
    /// Input index within the layer's input vector (address port).
    pub input: u32,
    /// Output neuron index this op accumulates into.
    pub neuron: u32,
    /// Accumulate multiplier: the gathered entry is scaled by this before
    /// the add (`sum += scale * table[code]`). `1` for every op the 1:1
    /// lowering and [`OptLevel::Full`] emit; values != 1 are produced only
    /// by the lossy tier's affine table folding
    /// ([`super::optim::OptLevel::Lossy`]), where a table `t2 ~= a*t1 + b`
    /// is replaced by the representative `t1`, `scale = a`, and `b` folded
    /// into the neuron bias. Every reachable product is proven in-lane by
    /// the compile-time range analysis. (The frozen
    /// [`super::exec::scalar_ref`] predates this field and only ever runs
    /// None/Full programs, where it is always 1.)
    pub scale: i32,
}

/// Accumulator/table lane a layer executes in, chosen at compile time by
/// exact interval analysis (see [`CompiledProgram::compile`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// Tables and partial sums provably fit i32: half the memory traffic.
    I32,
    /// Conservative exact lane (matches the interpreter's i64 accumulator).
    I64,
}

/// Extra accumulate target of a CSE-shared op: after op `op` (an index
/// *within its layer's op slice*) gathers `table[code]`, the same value is
/// also added into `neuron`'s accumulator. Produced only by the optimizer
/// ([`super::optim`]); the 1:1 lowering emits none. Entries of a layer are
/// sorted by `op`, the executor's cursor contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FanOut {
    pub op: u32,
    pub neuron: u32,
}

/// Bytes per packed table entry in the given lane's arena.
pub(super) fn lane_bytes(lane: Lane) -> usize {
    match lane {
        Lane::I32 => std::mem::size_of::<i32>(),
        Lane::I64 => std::mem::size_of::<i64>(),
    }
}

/// Execution plan for one layer: an op-stream slice, the lane, plus the
/// inter-layer requantization plan (None for the output layer).
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub d_in: usize,
    pub d_out: usize,
    /// This layer's slice of [`CompiledProgram::ops`].
    pub ops: Range<usize>,
    /// Offset of this layer's `d_out` bias constants in the bias arena.
    pub bias_off: usize,
    /// Which arena/scratch lane this layer's tables and sums use.
    pub lane: Lane,
    /// This layer's slice of [`CompiledProgram::fanouts`] (CSE-shared
    /// lookups feeding several accumulators; empty for 1:1 lowerings).
    pub fanout: Range<usize>,
    pub requant: Option<RequantPlan>,
}

/// An immutable netlist lowered to flat arrays — cheap to share, cheap to
/// rebuild (hot-swap recompiles in O(total table entries) plus the requant
/// planning, O(code levels · log) per quantized boundary).
#[derive(Clone, Debug)]
pub struct CompiledProgram {
    pub name: String,
    pub frac_bits: u32,
    /// i64 truth tables of wide-lane layers, packed back to back in op order
    /// (hash-consed programs share slots, so offsets may repeat). Behind an
    /// `Arc` so [`intern_tables`] can hand several programs literally the
    /// same arena (cross-tenant sharing) without copying.
    pub(super) tables64: Arc<Vec<i64>>,
    /// i32 truth tables of narrow-lane layers, packed back to back in op order.
    pub(super) tables32: Arc<Vec<i32>>,
    /// The fused op stream, grouped by layer.
    pub(super) ops: Vec<LutOp>,
    /// Per-neuron constant operands (folded biases), grouped by layer.
    pub(super) biases: Vec<i64>,
    pub(super) layers: Vec<LayerPlan>,
    pub(super) d_in: usize,
    pub(super) d_out: usize,
    /// Widest layer interface — the per-feature scratch plane count planned
    /// at compile time (see [`super::exec::Executor`]).
    pub(super) max_width: usize,
    /// Whether any layer runs in the narrow / wide lane (precomputed so the
    /// per-batch scratch sizing never rescans the layer list).
    pub(super) uses_i32: bool,
    pub(super) uses_i64: bool,
    /// CSE fanout entries, grouped by layer (see [`FanOut`]); empty for 1:1
    /// lowerings.
    pub(super) fanouts: Vec<FanOut>,
    /// When the optimizer eliminated dead *external* inputs: the live
    /// external feature index for each internal plane slot. `None` means
    /// the identity packing (every request feature has a slot).
    pub(super) input_map: Option<Vec<u32>>,
    /// What the pass pipeline did (None for plain [`CompiledProgram::compile`]).
    pub(super) opt: Option<OptReport>,
}

impl CompiledProgram {
    /// Lower a netlist at the given [`OptLevel`]. [`OptLevel::Full`] runs
    /// the pass pipeline of [`super::optim`] (fold constants, eliminate
    /// dead inputs, hash-cons tables, CSE duplicate lookups, re-run the
    /// lane analysis); [`OptLevel::None`] is [`CompiledProgram::compile`]
    /// plus an identity [`OptReport`]. Both are bit-exact with
    /// [`crate::sim::eval`] on the source netlist.
    pub fn compile_opt(net: &Netlist, level: OptLevel) -> CompiledProgram {
        optim::compile_with(net, level)
    }

    /// Lower a netlist into the flat feature-major program, 1:1 — one op
    /// and one arena slot per netlist L-LUT (no optimization passes).
    pub fn compile(net: &Netlist) -> CompiledProgram {
        let mut tables64 = Vec::new();
        let mut tables32 = Vec::new();
        let mut ops = Vec::new();
        let mut biases = Vec::new();
        let mut layers = Vec::with_capacity(net.layers.len());
        let mut max_width = 1usize;
        for layer in &net.layers {
            let ops_start = ops.len();
            let bias_off = biases.len();
            let lane = analyze_lane(layer);
            for (q, neuron) in layer.neurons.iter().enumerate() {
                biases.push(neuron.bias);
                for lut in &neuron.luts {
                    debug_assert!(lut.table.len().is_power_of_two());
                    debug_assert!(lut.input < layer.d_in);
                    let off = match lane {
                        Lane::I64 => {
                            let off = tables64.len();
                            tables64.extend_from_slice(&lut.table);
                            off
                        }
                        Lane::I32 => {
                            let off = tables32.len();
                            // lossless: analyze_lane proved every entry fits
                            tables32.extend(lut.table.iter().map(|&v| v as i32));
                            off
                        }
                    };
                    ops.push(LutOp {
                        table_off: off as u32,
                        addr_mask: (lut.table.len() - 1) as u32,
                        input: lut.input as u32,
                        neuron: q as u32,
                        scale: 1,
                    });
                }
            }
            max_width = max_width.max(layer.d_in).max(layer.d_out);
            layers.push(LayerPlan {
                d_in: layer.d_in,
                d_out: layer.d_out,
                ops: ops_start..ops.len(),
                bias_off,
                lane,
                fanout: 0..0,
                requant: layer.requant.map(|q| RequantPlan::build(q, net.frac_bits)),
            });
        }
        assert!(
            tables64.len() <= u32::MAX as usize && tables32.len() <= u32::MAX as usize,
            "table arena exceeds u32 addressing"
        );
        CompiledProgram {
            name: net.name.clone(),
            frac_bits: net.frac_bits,
            tables64: Arc::new(tables64),
            tables32: Arc::new(tables32),
            ops,
            biases,
            d_in: net.input_width(),
            d_out: net.layers.last().map(|l| l.d_out).unwrap_or(0),
            max_width,
            uses_i32: layers.iter().any(|l| l.lane == Lane::I32),
            uses_i64: layers.iter().any(|l| l.lane == Lane::I64),
            layers,
            fanouts: Vec::new(),
            input_map: None,
            opt: None,
        }
    }

    /// Input width (codes per sample).
    pub fn d_in(&self) -> usize {
        self.d_in
    }

    /// Output width (sums per sample).
    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Widest layer interface (scratch planes per sample).
    pub fn max_width(&self) -> usize {
        self.max_width
    }

    /// Total fused ops: one per netlist L-LUT for 1:1 lowerings, fewer
    /// after the optimizer folds/CSEs (see [`OptReport::ops_before`]).
    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Total packed table entries across both arenas. Hash-consed programs
    /// count each unique content once — this is resident footprint, not
    /// reference count.
    pub fn table_words(&self) -> usize {
        self.tables64.len() + self.tables32.len()
    }

    /// Bytes of packed table storage (the bandwidth the narrowing saves is
    /// visible here: all-narrow programs cost half the all-wide bytes).
    pub fn table_bytes(&self) -> usize {
        self.tables64.len() * std::mem::size_of::<i64>()
            + self.tables32.len() * std::mem::size_of::<i32>()
    }

    pub fn layers(&self) -> &[LayerPlan] {
        &self.layers
    }

    pub fn ops(&self) -> &[LutOp] {
        &self.ops
    }

    /// Wide-lane table arena (layers with `lane == Lane::I64`).
    pub fn tables64(&self) -> &[i64] {
        &self.tables64
    }

    /// Narrow-lane table arena (layers with `lane == Lane::I32`).
    pub fn tables32(&self) -> &[i32] {
        &self.tables32
    }

    /// True iff some layer runs in the narrow (i32) lane.
    pub fn uses_i32(&self) -> bool {
        self.uses_i32
    }

    /// True iff some layer runs in the wide (i64) lane.
    pub fn uses_i64(&self) -> bool {
        self.uses_i64
    }

    pub fn biases(&self) -> &[i64] {
        &self.biases
    }

    /// CSE fanout entries (see [`FanOut`]); empty unless the optimizer ran.
    pub fn fanouts(&self) -> &[FanOut] {
        &self.fanouts
    }

    /// Live external feature per internal plane slot, when the optimizer
    /// compacted dead inputs out of the code plane; `None` = identity.
    pub fn input_map(&self) -> Option<&[u32]> {
        self.input_map.as_deref()
    }

    /// What the pass pipeline did to this program (`None` when it was
    /// lowered by plain [`CompiledProgram::compile`]).
    pub fn opt_report(&self) -> Option<&OptReport> {
        self.opt.as_ref()
    }
}

// ---------------------------------------------------------------------------
// Cross-program table-arena interning
// ---------------------------------------------------------------------------

/// What [`intern_tables`] did across a set of programs. `bytes_shared +
/// bytes_private == bytes_interned`, and `bytes_interned <= bytes_flat`
/// (equality when no two programs — and no two ops — share a table).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InternStats {
    /// Programs interned together.
    pub programs: usize,
    /// Unique `(lane, content)` tables in the merged arena pair.
    pub unique_tables: usize,
    /// Sum of the source programs' individual `table_bytes()` — what N
    /// independently materialized arenas would cost.
    pub bytes_flat: usize,
    /// Bytes of the merged arena pair actually resident after interning.
    pub bytes_interned: usize,
    /// Portion of `bytes_interned` referenced by two or more programs
    /// (the cross-tenant sharing win).
    pub bytes_shared: usize,
    /// Portion referenced by exactly one program.
    pub bytes_private: usize,
}

/// Intern N compiled programs into one shared table-arena pair: identical
/// table contents (per lane) across programs — common between fine-tuned
/// variants of one checkpoint — are materialized once, and every output
/// program's ops are rewritten to address the merged arenas. All outputs
/// share the same two `Arc` arenas, so each program's `table_bytes()`
/// reports the *shared* resident footprint; the flat-vs-interned split is
/// in the returned [`InternStats`].
///
/// Outputs are bit-exact with their inputs (same ops modulo `table_off`,
/// same biases/plans/lanes); offsets are no longer monotone per lane —
/// the executor addresses tables absolutely, exactly as it already does
/// for hash-consed single-program arenas.
pub fn intern_tables(progs: &[&CompiledProgram]) -> (Vec<CompiledProgram>, InternStats) {
    intern_tables_with(progs, 0)
}

/// [`intern_tables`] under an error budget: on an exact-content miss, a
/// table may also land on an already-interned slot of the same lane and
/// length whose elementwise max delta fits `budget` (fixed-point LSBs) —
/// the cross-tenant form of the lossy tier's ε-clustering
/// ([`super::optim::OptLevel::Lossy`]). Only `scale == 1` ops ε-match
/// (a scaled op's delta would be amplified by `|scale|`, busting the
/// per-table budget); scaled ops intern exactly. `budget == 0` is
/// byte-identical to [`intern_tables`]. Each program's compile-time
/// `worst_case_bound` is *not* recomputed here — ε-sharing respects the
/// same per-table budget, so per-table deltas stay within the level the
/// registry pinned, but the composed end-to-end figure in a program's
/// [`super::optim::LossyReport`] describes its pre-intern arena.
pub fn intern_tables_lossy(
    progs: &[&CompiledProgram],
    budget: u32,
) -> (Vec<CompiledProgram>, InternStats) {
    intern_tables_with(progs, budget)
}

fn intern_tables_with(progs: &[&CompiledProgram], budget: u32) -> (Vec<CompiledProgram>, InternStats) {
    let mut arena64: Vec<i64> = Vec::new();
    let mut arena32: Vec<i32> = Vec::new();
    let mut slot64: HashMap<Vec<i64>, u32> = HashMap::new();
    let mut slot32: HashMap<Vec<i32>, u32> = HashMap::new();
    // ε-scan index: interned slots by table length, per lane (only the
    // canonical, first-interned slots are listed — ε-matches memoize into
    // the slot maps but never become match targets themselves, so every
    // table lands within `budget` of a *representative*, not of a chain)
    let mut by_len64: HashMap<usize, Vec<u32>> = HashMap::new();
    let mut by_len32: HashMap<usize, Vec<u32>> = HashMap::new();
    // per unique merged slot: (bytes, first referencing program, multi-program?)
    let mut owners: HashMap<(Lane, u32), (usize, usize, bool)> = HashMap::new();
    let mut stats = InternStats { programs: progs.len(), ..Default::default() };
    let mut rewritten: Vec<Vec<LutOp>> = Vec::with_capacity(progs.len());
    for (pi, prog) in progs.iter().enumerate() {
        stats.bytes_flat += prog.table_bytes();
        let mut ops = prog.ops.clone();
        for layer in &prog.layers {
            for op in &mut ops[layer.ops.clone()] {
                let start = op.table_off as usize;
                let len = op.addr_mask as usize + 1;
                let eps_ok = budget > 0 && op.scale == 1;
                let new_off = match layer.lane {
                    Lane::I64 => {
                        let content = prog.tables64[start..start + len].to_vec();
                        match slot64.get(&content) {
                            Some(&off) => off,
                            None => {
                                let near = eps_ok
                                    .then(|| by_len64.get(&len))
                                    .flatten()
                                    .and_then(|offs| {
                                        offs.iter().copied().find(|&off| {
                                            let s = off as usize;
                                            arena64[s..s + len].iter().zip(&content).all(
                                                |(&a, &b)| {
                                                    (a as i128 - b as i128).unsigned_abs()
                                                        <= budget as u128
                                                },
                                            )
                                        })
                                    });
                                let off = near.unwrap_or_else(|| {
                                    let off = arena64.len() as u32;
                                    arena64.extend_from_slice(&content);
                                    by_len64.entry(len).or_default().push(off);
                                    off
                                });
                                slot64.insert(content, off);
                                off
                            }
                        }
                    }
                    Lane::I32 => {
                        let content = prog.tables32[start..start + len].to_vec();
                        match slot32.get(&content) {
                            Some(&off) => off,
                            None => {
                                let near = eps_ok
                                    .then(|| by_len32.get(&len))
                                    .flatten()
                                    .and_then(|offs| {
                                        offs.iter().copied().find(|&off| {
                                            let s = off as usize;
                                            arena32[s..s + len].iter().zip(&content).all(
                                                |(&a, &b)| {
                                                    (a as i64 - b as i64).unsigned_abs()
                                                        <= budget as u64
                                                },
                                            )
                                        })
                                    });
                                let off = near.unwrap_or_else(|| {
                                    let off = arena32.len() as u32;
                                    arena32.extend(&content);
                                    by_len32.entry(len).or_default().push(off);
                                    off
                                });
                                slot32.insert(content, off);
                                off
                            }
                        }
                    }
                };
                let owner = owners
                    .entry((layer.lane, new_off))
                    .or_insert((len * lane_bytes(layer.lane), pi, false));
                if owner.1 != pi {
                    owner.2 = true;
                }
                op.table_off = new_off;
            }
        }
        rewritten.push(ops);
    }
    assert!(
        arena64.len() <= u32::MAX as usize && arena32.len() <= u32::MAX as usize,
        "interned table arena exceeds u32 addressing"
    );
    stats.unique_tables = owners.len();
    stats.bytes_interned = arena64.len() * std::mem::size_of::<i64>()
        + arena32.len() * std::mem::size_of::<i32>();
    for (bytes, _, multi) in owners.values() {
        if *multi {
            stats.bytes_shared += bytes;
        } else {
            stats.bytes_private += bytes;
        }
    }
    let arena64 = Arc::new(arena64);
    let arena32 = Arc::new(arena32);
    let out = progs
        .iter()
        .zip(rewritten)
        .map(|(prog, ops)| CompiledProgram {
            name: prog.name.clone(),
            frac_bits: prog.frac_bits,
            tables64: Arc::clone(&arena64),
            tables32: Arc::clone(&arena32),
            ops,
            biases: prog.biases.clone(),
            layers: prog.layers.clone(),
            d_in: prog.d_in,
            d_out: prog.d_out,
            max_width: prog.max_width,
            uses_i32: prog.uses_i32,
            uses_i64: prog.uses_i64,
            fanouts: prog.fanouts.clone(),
            input_map: prog.input_map.clone(),
            opt: prog.opt.clone(),
        })
        .collect();
    (out, stats)
}

/// Exact interval analysis over one layer, in the executor's op order:
/// the layer may run in the narrow lane iff every table entry and every
/// in-order partial accumulator value provably fits i32. The reachable
/// accumulator set after k tables is contained in
/// `[bias + Σ min_i, bias + Σ max_i]` over the first k tables, and the
/// executor adds in exactly this order, so checking every prefix interval
/// is sound. Saturating adds keep pathological i64-scale tables from
/// wrapping the analysis itself (saturation can only widen the interval,
/// which conservatively selects the wide lane).
pub(super) fn analyze_lane(layer: &LayerNet) -> Lane {
    const LO: i64 = i32::MIN as i64;
    const HI: i64 = i32::MAX as i64;
    for neuron in &layer.neurons {
        let (mut lo, mut hi) = (neuron.bias, neuron.bias);
        if lo < LO || hi > HI {
            return Lane::I64;
        }
        for lut in &neuron.luts {
            let (tlo, thi) = lut
                .table
                .iter()
                .fold((i64::MAX, i64::MIN), |(a, b), &v| (a.min(v), b.max(v)));
            if tlo > thi {
                continue; // empty table: contributes nothing
            }
            if tlo < LO || thi > HI {
                return Lane::I64;
            }
            lo = lo.saturating_add(tlo);
            hi = hi.saturating_add(thi);
            if lo < LO || hi > HI {
                return Lane::I64;
            }
        }
    }
    Lane::I32
}

// ---------------------------------------------------------------------------
// Integer requantization plans
// ---------------------------------------------------------------------------

/// Largest code width lowered to a fully integer plan. The paper's flows
/// never exceed 8-bit codes; 16 leaves generous headroom while keeping the
/// threshold construction (one bisection per code boundary) cheap. Wider
/// quantizers fall back to the float oracle — still bit-exact, just not
/// arithmetic-free.
pub const PLAN_MAX_BITS: u32 = 16;

/// Fixed-point fraction bits of the linear plan's multiplier.
const LINEAR_SHIFT: u32 = 48;

/// A [`Quantizer`] lowered to integer-only form for the inter-layer flip:
/// `encode_sum(sum)` == `Quantizer::encode_fixed(sum, frac_bits)` for every
/// i64 `sum`, bit for bit.
///
/// Lowering strategy (see [`RequantPlan::build`]):
/// 1. Find the exact i64 *boundary* of every code level by monotone
///    bisection against the float oracle (`thresholds[c-1]` = smallest sum
///    the oracle maps to a code >= c).
/// 2. Try to fit `code = clamp((sum * mul + add) >> 48, 0, max)`: the
///    feasible interval for `add` is intersected over *every* boundary
///    constraint, so a returned linear plan is exact by construction — no
///    sampling, no "close enough".
/// 3. Otherwise keep the sorted thresholds and binary-search them
///    (`partition_point`), which is exact for any monotone step function.
#[derive(Clone, Debug)]
pub struct RequantPlan {
    q: Quantizer,
    frac_bits: u32,
    kind: PlanKind,
}

#[derive(Clone, Debug)]
enum PlanKind {
    /// `code = clamp((clamp(sum, rail_lo, rail_hi) * mul + add) >> LINEAR_SHIFT, 0, max_code)`.
    Linear { mul: i128, add: i128, rail_lo: i64, rail_hi: i64, max_code: u32 },
    /// Sorted code boundaries; `code = #thresholds <= sum`.
    Thresholds(Vec<i64>),
    /// Code width beyond [`PLAN_MAX_BITS`]: float oracle fallback.
    Float,
}

impl RequantPlan {
    /// Lower a quantizer (at a given accumulator `frac_bits`) to its
    /// integer plan. Infallible: the threshold form always exists for
    /// `bits <= PLAN_MAX_BITS`, and wider quantizers get the oracle.
    pub fn build(q: Quantizer, frac_bits: u32) -> RequantPlan {
        let kind = if q.bits <= PLAN_MAX_BITS {
            match boundaries(&q, frac_bits) {
                Some(thresholds) => match try_linear(&thresholds) {
                    Some(linear) => linear,
                    None => PlanKind::Thresholds(thresholds),
                },
                // degenerate quantizer (e.g. non-finite scale from a
                // domain like [-f64::MAX, f64::MAX]): the oracle never
                // reaches some codes, so no boundary exists — keep the
                // oracle itself rather than spin or mis-plan
                None => PlanKind::Float,
            }
        } else {
            PlanKind::Float
        };
        RequantPlan { q, frac_bits, kind }
    }

    /// The source quantizer this plan was lowered from.
    pub fn quantizer(&self) -> &Quantizer {
        &self.q
    }

    /// True unless this plan fell back to the float oracle (bits >
    /// [`PLAN_MAX_BITS`]); the serving hot path is float-free iff every
    /// layer plan is integer.
    pub fn is_integer(&self) -> bool {
        !matches!(self.kind, PlanKind::Float)
    }

    /// Which lowering was chosen (bench/stats reporting).
    pub fn kind_name(&self) -> &'static str {
        match self.kind {
            PlanKind::Linear { .. } => "linear",
            PlanKind::Thresholds(_) => "thresholds",
            PlanKind::Float => "float",
        }
    }

    /// Requantize one accumulator sum. Bit-exact with
    /// `self.quantizer().encode_fixed(sum, frac_bits)`.
    #[inline]
    pub fn encode_sum(&self, sum: i64) -> u32 {
        match &self.kind {
            PlanKind::Linear { mul, add, rail_lo, rail_hi, max_code } => {
                let s = sum.clamp(*rail_lo, *rail_hi) as i128;
                // arithmetic shift == floor division by 2^LINEAR_SHIFT,
                // which is exactly the comparison form the boundary
                // constraints were solved in
                let c = (s * mul + add) >> LINEAR_SHIFT;
                c.clamp(0, *max_code as i128) as u32
            }
            PlanKind::Thresholds(t) => t.partition_point(|&b| b <= sum) as u32,
            PlanKind::Float => self.q.encode_fixed(sum, self.frac_bits),
        }
    }

    /// Requantize a whole feature-major sum plane:
    /// `out[i] = encode_sum(sums[i])` element for element, with the
    /// plan-kind dispatch hoisted out of the loop. The linear form keeps
    /// its scalar i128 multiply/shift (a [`LINEAR_SHIFT`]-bit fixed-point
    /// product does not fit a SIMD lane) but runs it in
    /// [`super::kernels::CHUNK`]-element chunks so the clamp/shift chain
    /// unrolls and its bounds checks hoist; the threshold and float forms
    /// are inherently per-element (binary search / oracle call).
    pub fn encode_plane<T: Copy + Into<i64>>(&self, sums: &[T], out: &mut [u32]) {
        assert_eq!(sums.len(), out.len(), "requant plane length mismatch");
        match &self.kind {
            PlanKind::Linear { mul, add, rail_lo, rail_hi, max_code } => {
                let (mul, add) = (*mul, *add);
                let (lo, hi, max) = (*rail_lo, *rail_hi, *max_code as i128);
                let enc = |s: T| {
                    let s = s.into().clamp(lo, hi) as i128;
                    ((s * mul + add) >> LINEAR_SHIFT).clamp(0, max) as u32
                };
                let mut oc = out.chunks_exact_mut(super::kernels::CHUNK);
                let mut sc = sums.chunks_exact(super::kernels::CHUNK);
                for (o, s) in (&mut oc).zip(&mut sc) {
                    for (o, &s) in o.iter_mut().zip(s) {
                        *o = enc(s);
                    }
                }
                for (o, &s) in oc.into_remainder().iter_mut().zip(sc.remainder()) {
                    *o = enc(s);
                }
            }
            PlanKind::Thresholds(t) => {
                for (o, &s) in out.iter_mut().zip(sums) {
                    let s: i64 = s.into();
                    *o = t.partition_point(|&b| b <= s) as u32;
                }
            }
            PlanKind::Float => {
                for (o, &s) in out.iter_mut().zip(sums) {
                    *o = self.q.encode_fixed(s.into(), self.frac_bits);
                }
            }
        }
    }
}

/// Exact code boundaries: `out[c-1]` is the smallest i64 sum that the float
/// oracle maps to a code >= c. Sorted nondecreasing by construction
/// (oracle monotonicity). None when some code is unreachable (degenerate
/// quantizer whose scale over/underflowed f64): no integer plan exists.
pub(super) fn boundaries(q: &Quantizer, frac_bits: u32) -> Option<Vec<i64>> {
    let max_code = (q.levels() - 1) as u32;
    let fixed_one = (1i64 << frac_bits) as f64;
    let mut out = Vec::with_capacity(max_code as usize);
    for c in 1..=max_code {
        // float estimate of where the oracle crosses c, to seed the bracket
        let est = (q.lo + (c as f64 - 0.5) * q.scale()) * fixed_one;
        let est = if est.is_finite() {
            est.clamp(i64::MIN as f64, i64::MAX as f64) as i64
        } else {
            0
        };
        out.push(boundary_search(q, frac_bits, c, est)?);
    }
    Some(out)
}

/// Smallest `sum` with `q.encode_fixed(sum, frac_bits) >= c`, for c >= 1.
/// Sound because the oracle is monotone nondecreasing in `sum`. For every
/// well-formed quantizer the oracle is 0 at i64::MIN (clamped to `q.lo`)
/// and `max_code` at i64::MAX (clamped to `q.hi`), so the boundary exists;
/// a degenerate oracle that never reaches `c` (non-finite scale) yields
/// None instead of a spin. Galloping from the float estimate keeps the
/// typical search to a handful of oracle calls.
fn boundary_search(q: &Quantizer, frac_bits: u32, c: u32, est: i64) -> Option<i64> {
    let p = |s: i64| q.encode_fixed(s, frac_bits) >= c;
    // establish a bracket: p(lo) == false, p(hi) == true
    let (mut lo, mut hi);
    if p(est) {
        if est == i64::MIN {
            return Some(est);
        }
        hi = est;
        lo = i64::MIN;
        let mut step = 1i64;
        loop {
            let cand = est.saturating_sub(step);
            if !p(cand) {
                lo = cand;
                break;
            }
            hi = cand;
            if cand == i64::MIN {
                // oracle true everywhere below est: boundary is i64::MIN
                return Some(i64::MIN);
            }
            step = step.saturating_mul(2);
        }
    } else {
        lo = est;
        hi = i64::MAX;
        let mut step = 1i64;
        loop {
            let cand = est.saturating_add(step);
            if p(cand) {
                hi = cand;
                break;
            }
            if cand == i64::MAX {
                // oracle never reaches c: code c has no boundary
                return None;
            }
            lo = cand;
            step = step.saturating_mul(2);
        }
    }
    while (hi as i128) - (lo as i128) > 1 {
        let mid = ((lo as i128 + hi as i128) / 2) as i64;
        if p(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// Try to express the boundary step function as one multiply/shift. The
/// feasible interval for `add` is the intersection of, for every code c
/// (1-based) with boundary t_c:
///
/// ```text
///   t_c * mul + add      >= c << SHIFT       (sum at the boundary reaches c)
///   (t_c - 1) * mul + add <  c << SHIFT      (one below stays at c - 1)
/// ```
///
/// A nonempty intersection proves, constructively, that the linear form
/// agrees with the oracle at every boundary — and two monotone step
/// functions that share all boundaries are equal everywhere. Returns None
/// (caller keeps the threshold table) when no feasible `add` exists or any
/// constant would overflow the checked i128 arithmetic.
fn try_linear(thresholds: &[i64]) -> Option<PlanKind> {
    let max_code = thresholds.len() as u32;
    let t1 = thresholds[0];
    let tmax = *thresholds.last().unwrap();
    let span = tmax as i128 - t1 as i128;
    let mul: i128 = if max_code == 1 {
        1i128 << LINEAR_SHIFT
    } else if span <= 0 {
        return None; // all boundaries collapsed onto one sum
    } else {
        let spacing = span as f64 / (max_code - 1) as f64;
        let m = ((1u64 << LINEAR_SHIFT) as f64 / spacing).round();
        if !m.is_finite() || m < 1.0 || m >= (1i128 << 62) as f64 {
            return None;
        }
        m as i128
    };
    let mut add_lo = i128::MIN;
    let mut add_hi = i128::MAX;
    for (i, &t) in thresholds.iter().enumerate() {
        let c = (i + 1) as i128;
        let target = c << LINEAR_SHIFT;
        let tm = (t as i128).checked_mul(mul)?;
        let tm1 = (t as i128 - 1).checked_mul(mul)?;
        add_lo = add_lo.max(target.checked_sub(tm)?);
        add_hi = add_hi.min((target - 1).checked_sub(tm1)?);
    }
    if add_lo > add_hi {
        return None;
    }
    let add = add_lo;
    let rail_lo = t1.saturating_sub(1);
    let rail_hi = tmax;
    // runtime products are bounded by the two rails (monotone in sum):
    // prove neither overflows i128 once, here, instead of checking per call
    (rail_lo as i128).checked_mul(mul)?.checked_add(add)?;
    (rail_hi as i128).checked_mul(mul)?.checked_add(add)?;
    Some(PlanKind::Linear { mul, add, rail_lo, rail_hi, max_code })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::fixed::to_fixed;
    use crate::lut;
    use crate::netlist::{LutInst, Netlist, NeuronNet};
    use crate::util::prop;

    fn compiled(dims: &[usize], bits: &[u32], seed: u64) -> (Netlist, CompiledProgram) {
        let ck = synthetic(dims, bits, seed);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        let prog = CompiledProgram::compile(&net);
        (net, prog)
    }

    #[test]
    fn opt_none_is_byte_identical_to_compile() {
        // the A/B baseline contract: OptLevel::None must preserve the 1:1
        // lowering exactly — same arenas, ops, biases, plans — differing
        // only in carrying an identity report
        for seed in [11u64, 31, 77] {
            let ck = synthetic(&[6, 5, 4, 2], &[3, 4, 4, 6], seed);
            let tables = lut::from_checkpoint(&ck);
            let net = Netlist::build(&ck, &tables, 2);
            let plain = CompiledProgram::compile(&net);
            let none = CompiledProgram::compile_opt(&net, OptLevel::None);
            assert_eq!(plain.tables32(), none.tables32());
            assert_eq!(plain.tables64(), none.tables64());
            assert_eq!(plain.ops(), none.ops());
            assert_eq!(plain.biases(), none.biases());
            assert_eq!(plain.d_in(), none.d_in());
            assert_eq!(plain.d_out(), none.d_out());
            assert_eq!(plain.max_width(), none.max_width());
            assert!(none.fanouts().is_empty() && plain.fanouts().is_empty());
            assert!(none.input_map().is_none() && plain.input_map().is_none());
            assert_eq!(plain.layers().len(), none.layers().len());
            for (a, b) in plain.layers().iter().zip(none.layers()) {
                assert_eq!(a.d_in, b.d_in);
                assert_eq!(a.d_out, b.d_out);
                assert_eq!(a.ops, b.ops);
                assert_eq!(a.bias_off, b.bias_off);
                assert_eq!(a.lane, b.lane);
                assert_eq!(a.fanout, b.fanout);
                assert_eq!(a.requant.is_some(), b.requant.is_some());
            }
            assert!(plain.opt_report().is_none());
            assert_eq!(none.opt_report().unwrap().level, OptLevel::None);
        }
    }

    #[test]
    fn op_count_matches_netlist() {
        let (net, prog) = compiled(&[4, 3, 2], &[4, 5, 6], 11);
        assert_eq!(prog.n_ops(), net.n_luts());
        assert_eq!(prog.layers().len(), net.layers.len());
        assert_eq!(prog.d_in(), 4);
        assert_eq!(prog.d_out(), 2);
        let entries: usize = net
            .layers
            .iter()
            .flat_map(|l| l.neurons.iter())
            .flat_map(|n| n.luts.iter())
            .map(|l| l.table.len())
            .sum();
        assert_eq!(prog.table_words(), entries);
    }

    #[test]
    fn ops_scan_tables_sequentially_per_lane() {
        // table offsets must be monotone in op order within each arena —
        // that is the whole point of the packed layout (sequential scans)
        let (_, prog) = compiled(&[5, 4, 3], &[4, 4, 5], 23);
        let (mut expect32, mut expect64) = (0u32, 0u32);
        for plan in prog.layers() {
            let expect = match plan.lane {
                Lane::I32 => &mut expect32,
                Lane::I64 => &mut expect64,
            };
            for op in &prog.ops()[plan.ops.clone()] {
                assert_eq!(op.table_off, *expect);
                *expect += op.addr_mask + 1;
            }
        }
        assert_eq!(expect32 as usize, prog.tables32().len());
        assert_eq!(expect64 as usize, prog.tables64().len());
        assert_eq!((expect32 + expect64) as usize, prog.table_words());
    }

    #[test]
    fn layer_plans_partition_the_op_stream() {
        let (net, prog) = compiled(&[6, 5, 4, 2], &[3, 4, 4, 6], 31);
        let mut next = 0usize;
        for (plan, layer) in prog.layers().iter().zip(&net.layers) {
            assert_eq!(plan.ops.start, next);
            next = plan.ops.end;
            assert_eq!(plan.d_in, layer.d_in);
            assert_eq!(plan.d_out, layer.d_out);
            assert_eq!(plan.requant.is_some(), layer.requant.is_some());
            for op in &prog.ops()[plan.ops.clone()] {
                assert!((op.input as usize) < plan.d_in);
                assert!((op.neuron as usize) < plan.d_out);
            }
        }
        assert_eq!(next, prog.n_ops());
        assert_eq!(prog.biases().len(), net.layers.iter().map(|l| l.d_out).sum::<usize>());
    }

    #[test]
    fn scratch_stride_covers_every_interface() {
        let (net, prog) = compiled(&[2, 7, 1, 5], &[3, 3, 3, 4], 7);
        for l in &net.layers {
            assert!(prog.max_width() >= l.d_in);
            assert!(prog.max_width() >= l.d_out);
        }
    }

    // -- narrowed-arena range analysis ----------------------------------

    /// Single-layer netlist built directly from tables (frac_bits 12,
    /// 3-bit input codes, no requant) for lane-analysis cases.
    fn manual_net(neuron_tables: Vec<Vec<Vec<i64>>>, d_in: usize) -> Netlist {
        let neurons: Vec<NeuronNet> = neuron_tables
            .into_iter()
            .map(|tables| {
                let luts: Vec<LutInst> = tables
                    .into_iter()
                    .enumerate()
                    .map(|(p, table)| {
                        assert!(table.len().is_power_of_two());
                        LutInst { input: p % d_in, table, out_width: 32 }
                    })
                    .collect();
                let depth = crate::netlist::adder_depth(luts.len(), 2);
                NeuronNet { luts, bias: 0, depth, sum_width: 48 }
            })
            .collect();
        let d_out = neurons.len();
        let depth = neurons.iter().map(|n| n.depth).max().unwrap_or(0);
        Netlist {
            name: "manual".into(),
            layers: vec![crate::netlist::LayerNet {
                d_in,
                d_out,
                in_bits: 3,
                out_bits: 8,
                neurons,
                requant: None,
                depth,
            }],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        }
    }

    #[test]
    fn synthetic_layers_all_narrow() {
        // synthetic tables are |v| <= ~1.5 * 2^12 over <= 7-wide fan-in:
        // comfortably i32, so every layer must pick the narrow lane
        let (_, prog) = compiled(&[6, 5, 4, 2], &[3, 4, 4, 6], 31);
        for plan in prog.layers() {
            assert_eq!(plan.lane, Lane::I32);
        }
        assert!(prog.tables64().is_empty());
        assert_eq!(prog.tables32().len(), prog.table_words());
    }

    #[test]
    fn huge_entries_force_wide_lane() {
        let big = 1i64 << 40;
        let net = manual_net(vec![vec![vec![big; 8], vec![-big; 8]]], 2);
        let prog = CompiledProgram::compile(&net);
        assert_eq!(prog.layers()[0].lane, Lane::I64);
        assert_eq!(prog.tables64().len(), 16);
        assert!(prog.tables32().is_empty());
    }

    #[test]
    fn accumulator_overflow_forces_wide_lane_even_when_entries_fit() {
        // each entry fits i32, but three of them sum past i32::MAX: the
        // prefix-interval analysis must reject the narrow lane
        let e = 1_000_000_000i64; // < i32::MAX
        let net = manual_net(vec![vec![vec![e; 8], vec![e; 8], vec![e; 8]]], 3);
        let prog = CompiledProgram::compile(&net);
        assert_eq!(prog.layers()[0].lane, Lane::I64);
        // two of them stay within i32: narrow is kept
        let net2 = manual_net(vec![vec![vec![e; 8], vec![e; 8]]], 2);
        assert_eq!(CompiledProgram::compile(&net2).layers()[0].lane, Lane::I32);
    }

    #[test]
    fn transient_overflow_on_mixed_signs_forces_wide_lane() {
        // every entry fits i32 and the FINAL sum (1.2e9) fits i32, but the
        // in-order partial after two tables is 2.4e9: prefix intervals
        // catch what a final-sum-only bound would miss
        let e = 1_200_000_000i64; // e < i32::MAX < 2e
        let net = manual_net(vec![vec![vec![e; 8], vec![e; 8], vec![-e; 8]]], 3);
        let prog = CompiledProgram::compile(&net);
        assert_eq!(prog.layers()[0].lane, Lane::I64);
    }

    // -- requant plans ---------------------------------------------------

    fn assert_plan_matches(q: Quantizer, frac: u32, sums: &[i64]) {
        let plan = RequantPlan::build(q, frac);
        for &s in sums {
            assert_eq!(
                plan.encode_sum(s),
                q.encode_fixed(s, frac),
                "plan ({}) diverges at sum {s} (bits {}, domain [{}, {}], frac {frac})",
                plan.kind_name(),
                q.bits,
                q.lo,
                q.hi
            );
        }
    }

    #[test]
    fn requant_plan_exact_at_every_boundary_all_bits() {
        // all bits 1..=16: the plan must agree with the float oracle at
        // every code boundary and its neighbors — the only sums where a
        // lowering can possibly diverge — plus the clamp rails and i64
        // extremes. Exhaustive over code levels (every level's boundary is
        // visited), varied domains/frac for the small widths.
        for bits in 1..=16u32 {
            let combos: &[((f64, f64), u32)] = if bits <= 10 {
                &[
                    ((-4.0, 4.0), 12),
                    ((0.0, 1.0), 8),
                    ((-0.001, 0.0035), 20),
                    ((-1000.0, 250.0), 0),
                ]
            } else {
                &[((-4.0, 4.0), 12)]
            };
            for &((lo, hi), frac) in combos {
                let q = Quantizer::new(bits, lo, hi);
                let plan = RequantPlan::build(q, frac);
                assert!(plan.is_integer(), "bits {bits} must get an integer plan");
                let mut sums = vec![i64::MIN, i64::MIN + 1, -1, 0, 1, i64::MAX - 1, i64::MAX];
                for &t in &boundaries(&q, frac).expect("well-formed quantizer has boundaries") {
                    sums.extend([t.saturating_sub(2), t.saturating_sub(1), t, t.saturating_add(1)]);
                }
                assert_plan_matches(q, frac, &sums);
            }
        }
    }

    #[test]
    fn requant_plan_exhaustive_small_range() {
        // small domain at frac_bits 4: the clamp rails sit at ~±128, so a
        // ±1000 window covers every distinguishable sum — compare all of them
        let q = Quantizer::new(5, -8.0, 8.0);
        let sums: Vec<i64> = (-1000..=1000).collect();
        assert_plan_matches(q, 4, &sums);
        // 1-bit quantizer, the degenerate two-level case
        let q1 = Quantizer::new(1, -8.0, 8.0);
        assert_plan_matches(q1, 4, &sums);
    }

    #[test]
    fn encode_plane_matches_encode_sum_for_every_plan_kind() {
        // the plane pass is the per-element encode hoisted over a chunked
        // loop: pin it element-for-element against encode_sum for all three
        // lowerings, both input lanes, and tail lengths around CHUNK
        use super::super::kernels::CHUNK;
        let q = Quantizer::new(5, -8.0, 8.0);
        let forced_thresholds = RequantPlan {
            q,
            frac_bits: 4,
            kind: PlanKind::Thresholds(boundaries(&q, 4).unwrap()),
        };
        let plans = [
            RequantPlan::build(q, 4), // paper-scale build (linear fast path)
            forced_thresholds,        // partition_point lowering
            RequantPlan::build(Quantizer::new(24, -4.0, 4.0), 12), // float oracle
        ];
        for plan in &plans {
            for n in [0usize, 1, CHUNK - 1, CHUNK, CHUNK + 1, 3 * CHUNK + 5] {
                let sums64: Vec<i64> = (0..n as i64).map(|i| i * 37 - 600).collect();
                let mut out = vec![u32::MAX; n];
                plan.encode_plane(&sums64, &mut out);
                let want: Vec<u32> = sums64.iter().map(|&s| plan.encode_sum(s)).collect();
                assert_eq!(out, want, "i64 plane, plan {} n={n}", plan.kind_name());

                let sums32: Vec<i32> = sums64.iter().map(|&s| s as i32).collect();
                plan.encode_plane(&sums32, &mut out);
                assert_eq!(out, want, "i32 plane, plan {} n={n}", plan.kind_name());
            }
        }
    }

    #[test]
    fn degenerate_domain_falls_back_to_oracle_instead_of_spinning() {
        // hi - lo overflows f64 -> scale() is inf -> the oracle returns 0
        // for every sum, so codes >= 1 have no boundary. build() must
        // terminate (regression: the upward gallop used to spin at
        // i64::MAX in release builds) and stay bit-exact via the oracle.
        let q = Quantizer::new(8, -f64::MAX, f64::MAX);
        let plan = RequantPlan::build(q, 12);
        assert!(!plan.is_integer());
        for s in [i64::MIN, -1, 0, 1, i64::MAX] {
            assert_eq!(plan.encode_sum(s), q.encode_fixed(s, 12));
        }
    }

    #[test]
    fn requant_plan_wide_bits_fall_back_to_oracle() {
        let q = Quantizer::new(24, -4.0, 4.0);
        let plan = RequantPlan::build(q, 12);
        assert!(!plan.is_integer());
        assert_eq!(plan.kind_name(), "float");
        for s in [i64::MIN, -(1 << 50), -5, 0, 9, 1 << 50, i64::MAX] {
            assert_eq!(plan.encode_sum(s), q.encode_fixed(s, 12));
        }
    }

    #[test]
    fn requant_boundaries_sorted_and_complete() {
        let q = Quantizer::new(6, -4.0, 4.0);
        let b = boundaries(&q, 12).unwrap();
        assert_eq!(b.len(), q.levels() as usize - 1);
        for w in b.windows(2) {
            assert!(w[0] <= w[1], "boundaries must be nondecreasing");
        }
        // each boundary really is the smallest sum reaching its code
        for (i, &t) in b.iter().enumerate() {
            let c = (i + 1) as u32;
            assert!(q.encode_fixed(t, 12) >= c);
            assert!(q.encode_fixed(t - 1, 12) < c);
        }
    }

    #[test]
    fn prop_requant_plan_equals_oracle() {
        // random quantizers (bits 1..=10 to keep plan construction cheap),
        // random domains and frac_bits; full-range random sums, sums on the
        // quantization grid, and sums straddling the clamp rails
        prop::check("requant-plan-equals-oracle", 150, |g| {
            let bits = g.usize_in(1, 10) as u32;
            let lo = g.f64_in(-100.0, 0.0);
            let hi = lo + g.f64_in(1e-3, 200.0);
            let frac = g.usize_in(0, 24) as u32;
            let q = Quantizer::new(bits, lo, hi);
            let plan = RequantPlan::build(q, frac);
            let probe = |s: i64| -> Result<(), String> {
                let (got, want) = (plan.encode_sum(s), q.encode_fixed(s, frac));
                if got != want {
                    return Err(format!(
                        "plan ({}) {got} != oracle {want} at sum {s} (bits {bits}, [{lo}, {hi}], frac {frac})",
                        plan.kind_name()
                    ));
                }
                Ok(())
            };
            for _ in 0..48 {
                probe(g.rng().next_u64() as i64)?;
            }
            for _ in 0..24 {
                let c = g.i64_in(0, (q.levels() - 1) as i64) as u32;
                let s = to_fixed(q.decode(c), frac);
                for d in -2..=2i64 {
                    probe(s.saturating_add(d))?;
                }
            }
            for s in [i64::MIN, i64::MAX, to_fixed(lo, frac), to_fixed(hi, frac)] {
                probe(s)?;
            }
            Ok(())
        });
    }

    #[test]
    fn threshold_lowering_matches_oracle_even_when_linear_fits() {
        // force the threshold form (bypassing try_linear) so the
        // partition_point path is covered no matter which lowering build()
        // happens to pick for these quantizers
        for (bits, frac) in [(1u32, 0u32), (4, 12), (8, 6), (12, 12)] {
            let q = Quantizer::new(bits, -4.0, 4.0);
            let plan = RequantPlan {
                q,
                frac_bits: frac,
                kind: PlanKind::Thresholds(boundaries(&q, frac).unwrap()),
            };
            let mut sums = vec![i64::MIN, -1, 0, 1, i64::MAX];
            for &t in &boundaries(&q, frac).unwrap() {
                sums.extend([t - 1, t, t + 1]);
            }
            for s in sums {
                assert_eq!(plan.encode_sum(s), q.encode_fixed(s, frac), "bits {bits} sum {s}");
            }
        }
    }

    #[test]
    fn plan_reports_its_lowering() {
        // paper-scale quantizers should get the linear fast path; whatever
        // is chosen, the names must be stable for the bench/stats surface
        let plan = RequantPlan::build(Quantizer::new(6, -4.0, 4.0), 12);
        assert!(plan.is_integer());
        assert!(matches!(plan.kind_name(), "linear" | "thresholds"));
        assert_eq!(plan.quantizer().bits, 6);
    }

    // -- cross-program table interning -----------------------------------

    #[test]
    fn intern_identical_programs_share_one_arena() {
        let (_, a) = compiled(&[5, 4, 3], &[4, 4, 5], 23);
        let (_, b) = compiled(&[5, 4, 3], &[4, 4, 5], 23);
        let flat = a.table_bytes();
        let (out, st) = intern_tables(&[&a, &b]);
        assert_eq!(st.programs, 2);
        assert_eq!(st.bytes_flat, 2 * flat);
        assert!(st.bytes_interned <= flat, "{st:?}");
        assert_eq!(st.bytes_private, 0, "every table appears in both programs: {st:?}");
        assert_eq!(st.bytes_shared, st.bytes_interned);
        // literally one arena pair: both outputs hold the same Arcs
        assert!(Arc::ptr_eq(&out[0].tables32, &out[1].tables32));
        assert!(Arc::ptr_eq(&out[0].tables64, &out[1].tables64));
        assert_eq!(out[0].table_bytes(), st.bytes_interned);
    }

    #[test]
    fn intern_outputs_stay_bit_exact() {
        // two lowerings of one netlist (the Full one's offsets already
        // repeat from hash-consing) plus an unrelated variant: interning
        // must preserve every program's outputs exactly
        let ck = synthetic(&[5, 4, 3], &[4, 4, 5], 23);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        let a = CompiledProgram::compile(&net);
        let b = CompiledProgram::compile_opt(&net, OptLevel::Full);
        let ck2 = synthetic(&[5, 4, 3], &[4, 4, 5], 24);
        let tables2 = lut::from_checkpoint(&ck2);
        let net2 = Netlist::build(&ck2, &tables2, 2);
        let c = CompiledProgram::compile(&net2);
        let (out, st) = intern_tables(&[&a, &b, &c]);
        assert_eq!(st.bytes_shared + st.bytes_private, st.bytes_interned);
        let mut rng = crate::util::Rng::new(5);
        let rows: Vec<Vec<u32>> =
            (0..32).map(|_| (0..5).map(|_| rng.below(16) as u32).collect()).collect();
        for (orig, interned) in [&a, &b, &c].into_iter().zip(&out) {
            assert_eq!(orig.n_ops(), interned.n_ops());
            assert_eq!(
                crate::engine::run_batch(orig, &rows),
                crate::engine::run_batch(interned, &rows),
                "interning changed outputs"
            );
        }
        // a and b lower the same netlist, so their table contents overlap:
        // the merged arena must beat the flat sum
        assert!(st.bytes_interned < st.bytes_flat, "{st:?}");
    }

    #[test]
    fn intern_splits_shared_from_private_bytes() {
        // two single-layer nets sharing exactly one 8-entry narrow table
        let shared = vec![7i64; 8];
        let net1 = manual_net(vec![vec![shared.clone(), vec![11; 8]]], 2);
        let net2 = manual_net(vec![vec![shared, vec![-3; 8]]], 2);
        let p1 = CompiledProgram::compile(&net1);
        let p2 = CompiledProgram::compile(&net2);
        let (out, st) = intern_tables(&[&p1, &p2]);
        let entry = std::mem::size_of::<i32>(); // small entries: narrow lane
        assert_eq!(st.unique_tables, 3);
        assert_eq!(st.bytes_flat, 4 * 8 * entry);
        assert_eq!(st.bytes_interned, 3 * 8 * entry);
        assert_eq!(st.bytes_shared, 8 * entry);
        assert_eq!(st.bytes_private, 2 * 8 * entry);
        let rows: Vec<Vec<u32>> = (0..8).map(|i| vec![i as u32, (7 - i) as u32]).collect();
        for (orig, interned) in [&p1, &p2].into_iter().zip(&out) {
            assert_eq!(
                crate::engine::run_batch(orig, &rows),
                crate::engine::run_batch(interned, &rows)
            );
        }
    }

    #[test]
    fn lossy_intern_merges_near_tables_within_budget_only() {
        // two nets whose tables differ elementwise by exactly 5: budget 4
        // must keep them apart (bit-identical to exact interning), budget 5
        // must merge them, and outputs under the merge stay within d_in *
        // budget of the originals (two 8-entry tables per neuron)
        let base: Vec<i64> = (0..8).map(|i| 100 + 13 * i).collect();
        let near: Vec<i64> = base.iter().map(|v| v + 5).collect();
        let net1 = manual_net(vec![vec![base.clone(), base.clone()]], 2);
        let net2 = manual_net(vec![vec![near.clone(), near]], 2);
        let p1 = CompiledProgram::compile(&net1);
        let p2 = CompiledProgram::compile(&net2);

        let (exact_out, exact) = intern_tables(&[&p1, &p2]);
        let (tight_out, tight) = intern_tables_lossy(&[&p1, &p2], 4);
        assert_eq!(tight, exact, "sub-threshold budget must change nothing");
        assert_eq!(tight_out[0].ops(), exact_out[0].ops());
        assert_eq!(tight_out[1].ops(), exact_out[1].ops());

        let (merged_out, merged) = intern_tables_lossy(&[&p1, &p2], 5);
        assert_eq!(merged.unique_tables, 1, "{merged:?}");
        assert!(merged.bytes_interned < exact.bytes_interned, "{merged:?}");
        assert_eq!(merged.bytes_shared, merged.bytes_interned);
        let rows: Vec<Vec<u32>> = (0..8).map(|i| vec![i as u32, (7 - i) as u32]).collect();
        for (orig, interned) in [&p1, &p2].into_iter().zip(&merged_out) {
            let want = crate::engine::run_batch(orig, &rows);
            let got = crate::engine::run_batch(interned, &rows);
            for (w, g) in want.iter().flatten().zip(got.iter().flatten()) {
                assert!((w - g).abs() <= 2 * 5, "merged delta {w} vs {g}");
            }
        }
        // budget 0 through the lossy entry point is the exact path
        let (_, zero) = intern_tables_lossy(&[&p1, &p2], 0);
        assert_eq!(zero, exact);
    }
}
