//! The compiled program representation: structure-of-arrays LUT storage and
//! a preplanned, fused op stream.
//!
//! [`CompiledProgram::compile`] lowers a [`Netlist`] once; execution then
//! never touches the netlist object graph again. Layout decisions:
//!
//! * **Packed tables** — every truth table is appended to one contiguous
//!   `Vec<i64>`; an op addresses its table by `(offset, mask)`. Ops are
//!   emitted in `(layer, neuron, lut)` order, so a batch-major executor
//!   walks the table arena front to back: sequential scans instead of the
//!   interpreter's per-sample pointer chase.
//! * **Fused ops** — one [`LutOp`] is a LUT gather *and* the accumulate
//!   into its neuron's sum; the adder tree is a compile-time fiction here
//!   (i64 addition is exact, so any summation order is bit-identical to
//!   the pipelined tree the RTL and [`crate::sim::CycleSim`] implement).
//! * **Requant plans** — the inter-layer quantize/saturate node is carried
//!   as the layer's [`Quantizer`] copy, applied when flipping the
//!   double-buffered scratch (see [`super::exec`]).

use std::ops::Range;

use crate::fixed::Quantizer;
use crate::netlist::Netlist;

/// One fused LUT-gather + accumulate op with fully resolved indices.
#[derive(Clone, Copy, Debug)]
pub struct LutOp {
    /// Start of this op's truth table in the packed arena.
    pub table_off: u32,
    /// `table_len - 1`; masking the address reproduces the RTL's
    /// truncation semantics (tables are power-of-two sized).
    pub addr_mask: u32,
    /// Input index within the layer's input vector (address port).
    pub input: u32,
    /// Output neuron index this op accumulates into.
    pub neuron: u32,
}

/// Execution plan for one layer: an op-stream slice plus the inter-layer
/// requantization (None for the output layer).
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub d_in: usize,
    pub d_out: usize,
    /// This layer's slice of [`CompiledProgram::ops`].
    pub ops: Range<usize>,
    /// Offset of this layer's `d_out` bias constants in the bias arena.
    pub bias_off: usize,
    pub requant: Option<Quantizer>,
}

/// An immutable netlist lowered to flat arrays — cheap to share, cheap to
/// rebuild (hot-swap recompiles in O(total table entries)).
#[derive(Clone, Debug)]
pub struct CompiledProgram {
    pub name: String,
    pub frac_bits: u32,
    /// All truth tables, packed back to back in op order.
    tables: Vec<i64>,
    /// The fused op stream, grouped by layer.
    ops: Vec<LutOp>,
    /// Per-neuron constant operands (folded biases), grouped by layer.
    biases: Vec<i64>,
    layers: Vec<LayerPlan>,
    d_in: usize,
    d_out: usize,
    /// Widest layer interface — the per-sample scratch stride planned at
    /// compile time (see [`super::exec::Executor`]).
    max_width: usize,
}

impl CompiledProgram {
    /// Lower a netlist into the flat batch-major program.
    pub fn compile(net: &Netlist) -> CompiledProgram {
        let mut tables = Vec::new();
        let mut ops = Vec::new();
        let mut biases = Vec::new();
        let mut layers = Vec::with_capacity(net.layers.len());
        let mut max_width = 1usize;
        for layer in &net.layers {
            let ops_start = ops.len();
            let bias_off = biases.len();
            for (q, neuron) in layer.neurons.iter().enumerate() {
                biases.push(neuron.bias);
                for lut in &neuron.luts {
                    debug_assert!(lut.table.len().is_power_of_two());
                    debug_assert!(lut.input < layer.d_in);
                    let off = tables.len();
                    tables.extend_from_slice(&lut.table);
                    ops.push(LutOp {
                        table_off: off as u32,
                        addr_mask: (lut.table.len() - 1) as u32,
                        input: lut.input as u32,
                        neuron: q as u32,
                    });
                }
            }
            max_width = max_width.max(layer.d_in).max(layer.d_out);
            layers.push(LayerPlan {
                d_in: layer.d_in,
                d_out: layer.d_out,
                ops: ops_start..ops.len(),
                bias_off,
                requant: layer.requant,
            });
        }
        assert!(tables.len() <= u32::MAX as usize, "table arena exceeds u32 addressing");
        CompiledProgram {
            name: net.name.clone(),
            frac_bits: net.frac_bits,
            tables,
            ops,
            biases,
            d_in: net.input_width(),
            d_out: net.layers.last().map(|l| l.d_out).unwrap_or(0),
            max_width,
            layers,
        }
    }

    /// Input width (codes per sample).
    pub fn d_in(&self) -> usize {
        self.d_in
    }

    /// Output width (sums per sample).
    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Per-sample scratch stride (widest layer interface).
    pub fn max_width(&self) -> usize {
        self.max_width
    }

    /// Total fused ops (== L-LUT instances of the source netlist).
    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Total packed table entries.
    pub fn table_words(&self) -> usize {
        self.tables.len()
    }

    pub fn layers(&self) -> &[LayerPlan] {
        &self.layers
    }

    pub fn ops(&self) -> &[LutOp] {
        &self.ops
    }

    pub fn tables(&self) -> &[i64] {
        &self.tables
    }

    pub fn biases(&self) -> &[i64] {
        &self.biases
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::lut;
    use crate::netlist::Netlist;

    fn compiled(dims: &[usize], bits: &[u32], seed: u64) -> (Netlist, CompiledProgram) {
        let ck = synthetic(dims, bits, seed);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        let prog = CompiledProgram::compile(&net);
        (net, prog)
    }

    #[test]
    fn op_count_matches_netlist() {
        let (net, prog) = compiled(&[4, 3, 2], &[4, 5, 6], 11);
        assert_eq!(prog.n_ops(), net.n_luts());
        assert_eq!(prog.layers().len(), net.layers.len());
        assert_eq!(prog.d_in(), 4);
        assert_eq!(prog.d_out(), 2);
        let entries: usize = net
            .layers
            .iter()
            .flat_map(|l| l.neurons.iter())
            .flat_map(|n| n.luts.iter())
            .map(|l| l.table.len())
            .sum();
        assert_eq!(prog.table_words(), entries);
    }

    #[test]
    fn ops_scan_tables_sequentially() {
        // table offsets must be monotone in op order — that is the whole
        // point of the packed layout (sequential arena scans)
        let (_, prog) = compiled(&[5, 4, 3], &[4, 4, 5], 23);
        let mut expect_off = 0u32;
        for op in prog.ops() {
            assert_eq!(op.table_off, expect_off);
            expect_off += op.addr_mask + 1;
        }
        assert_eq!(expect_off as usize, prog.table_words());
    }

    #[test]
    fn layer_plans_partition_the_op_stream() {
        let (net, prog) = compiled(&[6, 5, 4, 2], &[3, 4, 4, 6], 31);
        let mut next = 0usize;
        for (plan, layer) in prog.layers().iter().zip(&net.layers) {
            assert_eq!(plan.ops.start, next);
            next = plan.ops.end;
            assert_eq!(plan.d_in, layer.d_in);
            assert_eq!(plan.d_out, layer.d_out);
            assert_eq!(plan.requant.is_some(), layer.requant.is_some());
            for op in &prog.ops()[plan.ops.clone()] {
                assert!((op.input as usize) < plan.d_in);
                assert!((op.neuron as usize) < plan.d_out);
            }
        }
        assert_eq!(next, prog.n_ops());
        assert_eq!(prog.biases().len(), net.layers.iter().map(|l| l.d_out).sum::<usize>());
    }

    #[test]
    fn scratch_stride_covers_every_interface() {
        let (net, prog) = compiled(&[2, 7, 1, 5], &[3, 3, 3, 4], 7);
        for l in &net.layers {
            assert!(prog.max_width() >= l.d_in);
            assert!(prog.max_width() >= l.d_out);
        }
    }
}
