//! Fixed-width chunked kernels for the engine's width-`n` passes.
//!
//! Every hot loop in [`super::exec`] is an elementwise pass over
//! feature-major runs of `n` words: the table gather
//! (`codes -> table[code & mask]`), the k-way [`super::program::FanOut`]
//! accumulate, and the integer [`super::program::RequantPlan`] flip. This
//! module factors those passes into explicit [`CHUNK`]-lane kernels with a
//! scalar tail, monomorphized over the two accumulator lanes
//! ([`super::program::Lane`]) through the [`LaneKernel`] trait:
//!
//! * **Default build (stable rustc):** the chunk bodies gather into a
//!   `[T; CHUNK]` stack temporary and then add it into the destination
//!   run as a separate pass. Splitting the fused load->add loop this way
//!   breaks the per-element load-use dependence, hoists the table
//!   bounds checks out of the chunk, and leaves the add/store half as a
//!   straight-line fixed-trip loop that stable rustc reliably
//!   autovectorizes.
//! * **`--features simd` (nightly `portable_simd`):** the same trait
//!   methods are implemented with `std::simd` — hardware gathers where
//!   the target has them, explicit vector adds everywhere. Same chunk
//!   width, same scalar tail, same results.
//!
//! Both implementations are bit-exact with the one-element-at-a-time
//! reference loop by construction: chunking only regroups *which samples*
//! are processed together, never the per-sample order of adds (integer
//! adds are exact, and each destination element receives exactly the same
//! operands in the same op order). The unit tests below pin every kernel
//! against the reference on every tail shape (`n = 0, 1, CHUNK-1, CHUNK,
//! CHUNK+1, ...`) in both lanes; `exec::tests` pins the full executor
//! against the frozen scalar loops and against [`crate::sim`].

use std::ops::AddAssign;

/// Samples processed per chunk. 16 words is 512 bits in the i32 lane (one
/// AVX-512 / two AVX2 / four NEON registers) and gives LLVM enough
/// straight-line work to unroll profitably in the i64 lane; the tail
/// (`n % CHUNK` samples) always runs the scalar reference loop.
pub const CHUNK: usize = 16;

/// The two accumulator widths the per-layer loops are monomorphized over,
/// as chunked kernels (see the module docs for the two implementations).
///
/// Contract shared by all methods: `table.len() == mask as usize + 1`
/// (tables are power-of-two sized, masking reproduces the RTL address
/// truncation), and paired run arguments have equal lengths.
pub(super) trait LaneKernel: Copy + PartialEq + AddAssign {
    const ZERO: Self;

    /// Narrowing conversion from the i64 build-side value. Lossless by the
    /// compile-time range analysis ([`super::program::Lane`]).
    fn from_i64(v: i64) -> Self;

    /// `dst[..] = v` (bias seeding of a neuron run).
    fn fill_run(dst: &mut [Self], v: i64);

    /// `dst[i] = table[codes[i] & mask]` (pure gather; the fan-out path
    /// gathers once per chunk and re-adds the temporary k times).
    fn gather(table: &[Self], mask: u32, codes: &[u32], dst: &mut [Self]);

    /// `dst[i] += table[codes[i] & mask]` (the 1:1 hot path).
    fn gather_add(table: &[Self], mask: u32, codes: &[u32], dst: &mut [Self]);

    /// `dst[i] += a * table[codes[i] & mask]` — the affine-folded op of the
    /// optimizer's lossy tier ([`super::optim::OptLevel::Lossy`]): a table
    /// expressed as `a * rep + b` gathers the representative and scales on
    /// accumulate (`b` was folded into the destination bias at compile
    /// time). Every reachable product is proven in-lane by the compile-time
    /// range analysis, so the multiply cannot overflow.
    fn gather_mul_add(table: &[Self], mask: u32, codes: &[u32], dst: &mut [Self], a: Self);

    /// `dst[i] *= a` (scale a gathered chunk once before fan-out
    /// re-accumulation feeds it to several destinations).
    fn scale_run(dst: &mut [Self], a: Self);

    /// `dst[i] += src[i]` (fan-out re-accumulation of a gathered chunk).
    fn add_run(dst: &mut [Self], src: &[Self]);
}

macro_rules! lane_kernel {
    ($t:ty) => {
        impl LaneKernel for $t {
            const ZERO: $t = 0;

            #[inline(always)]
            // the cast is the identity in the i64 instantiation
            #[allow(clippy::unnecessary_cast)]
            fn from_i64(v: i64) -> $t {
                debug_assert!(<$t>::try_from(v).is_ok(), "narrow-lane value out of range");
                v as $t
            }

            #[inline]
            fn fill_run(dst: &mut [Self], v: i64) {
                dst.fill(Self::from_i64(v));
            }

            #[inline]
            fn gather(table: &[Self], mask: u32, codes: &[u32], dst: &mut [Self]) {
                debug_assert_eq!(codes.len(), dst.len());
                debug_assert_eq!(table.len(), mask as usize + 1);
                #[cfg(feature = "simd")]
                {
                    use std::simd::prelude::*;
                    let mut dc = dst.chunks_exact_mut(CHUNK);
                    let mut cc = codes.chunks_exact(CHUNK);
                    for (d, c) in (&mut dc).zip(&mut cc) {
                        let idx =
                            (Simd::<u32, CHUNK>::from_slice(c) & Simd::splat(mask)).cast::<usize>();
                        Simd::<$t, CHUNK>::gather_or_default(table, idx).copy_to_slice(d);
                    }
                    for (d, &c) in dc.into_remainder().iter_mut().zip(cc.remainder()) {
                        *d = table[(c & mask) as usize];
                    }
                }
                #[cfg(not(feature = "simd"))]
                for (d, &c) in dst.iter_mut().zip(codes) {
                    *d = table[(c & mask) as usize];
                }
            }

            #[inline]
            fn gather_add(table: &[Self], mask: u32, codes: &[u32], dst: &mut [Self]) {
                debug_assert_eq!(codes.len(), dst.len());
                debug_assert_eq!(table.len(), mask as usize + 1);
                #[cfg(feature = "simd")]
                {
                    use std::simd::prelude::*;
                    let mut dc = dst.chunks_exact_mut(CHUNK);
                    let mut cc = codes.chunks_exact(CHUNK);
                    for (d, c) in (&mut dc).zip(&mut cc) {
                        let idx =
                            (Simd::<u32, CHUNK>::from_slice(c) & Simd::splat(mask)).cast::<usize>();
                        let v = Simd::<$t, CHUNK>::gather_or_default(table, idx)
                            + Simd::from_slice(d);
                        v.copy_to_slice(d);
                    }
                    for (d, &c) in dc.into_remainder().iter_mut().zip(cc.remainder()) {
                        *d += table[(c & mask) as usize];
                    }
                }
                #[cfg(not(feature = "simd"))]
                {
                    let mut dc = dst.chunks_exact_mut(CHUNK);
                    let mut cc = codes.chunks_exact(CHUNK);
                    for (d, c) in (&mut dc).zip(&mut cc) {
                        // gather into a stack temporary first: the add/store
                        // half below is then a dependence-free fixed-trip
                        // loop LLVM turns into vector adds
                        let mut g = [Self::ZERO; CHUNK];
                        for (g, &c) in g.iter_mut().zip(c) {
                            *g = table[(c & mask) as usize];
                        }
                        for (d, &g) in d.iter_mut().zip(&g) {
                            *d += g;
                        }
                    }
                    for (d, &c) in dc.into_remainder().iter_mut().zip(cc.remainder()) {
                        *d += table[(c & mask) as usize];
                    }
                }
            }

            #[inline]
            fn gather_mul_add(table: &[Self], mask: u32, codes: &[u32], dst: &mut [Self], a: Self) {
                debug_assert_eq!(codes.len(), dst.len());
                debug_assert_eq!(table.len(), mask as usize + 1);
                #[cfg(feature = "simd")]
                {
                    use std::simd::prelude::*;
                    let mut dc = dst.chunks_exact_mut(CHUNK);
                    let mut cc = codes.chunks_exact(CHUNK);
                    for (d, c) in (&mut dc).zip(&mut cc) {
                        let idx =
                            (Simd::<u32, CHUNK>::from_slice(c) & Simd::splat(mask)).cast::<usize>();
                        let v = Simd::<$t, CHUNK>::gather_or_default(table, idx)
                            * Simd::splat(a)
                            + Simd::from_slice(d);
                        v.copy_to_slice(d);
                    }
                    for (d, &c) in dc.into_remainder().iter_mut().zip(cc.remainder()) {
                        *d += a * table[(c & mask) as usize];
                    }
                }
                #[cfg(not(feature = "simd"))]
                {
                    let mut dc = dst.chunks_exact_mut(CHUNK);
                    let mut cc = codes.chunks_exact(CHUNK);
                    for (d, c) in (&mut dc).zip(&mut cc) {
                        // same split as gather_add: gather first, then a
                        // dependence-free fixed-trip multiply-add loop
                        let mut g = [Self::ZERO; CHUNK];
                        for (g, &c) in g.iter_mut().zip(c) {
                            *g = table[(c & mask) as usize];
                        }
                        for (d, &g) in d.iter_mut().zip(&g) {
                            *d += a * g;
                        }
                    }
                    for (d, &c) in dc.into_remainder().iter_mut().zip(cc.remainder()) {
                        *d += a * table[(c & mask) as usize];
                    }
                }
            }

            #[inline]
            fn scale_run(dst: &mut [Self], a: Self) {
                #[cfg(feature = "simd")]
                {
                    use std::simd::prelude::*;
                    let mut dc = dst.chunks_exact_mut(CHUNK);
                    for d in &mut dc {
                        let v = Simd::<$t, CHUNK>::from_slice(d) * Simd::splat(a);
                        v.copy_to_slice(d);
                    }
                    for d in dc.into_remainder() {
                        *d *= a;
                    }
                }
                // an in-place elementwise multiply is a shape stable rustc
                // vectorizes unaided, like add_run
                #[cfg(not(feature = "simd"))]
                for d in dst.iter_mut() {
                    *d *= a;
                }
            }

            #[inline]
            fn add_run(dst: &mut [Self], src: &[Self]) {
                debug_assert_eq!(dst.len(), src.len());
                #[cfg(feature = "simd")]
                {
                    use std::simd::prelude::*;
                    let mut dc = dst.chunks_exact_mut(CHUNK);
                    let mut sc = src.chunks_exact(CHUNK);
                    for (d, s) in (&mut dc).zip(&mut sc) {
                        let v = Simd::<$t, CHUNK>::from_slice(d) + Simd::from_slice(s);
                        v.copy_to_slice(d);
                    }
                    for (d, &s) in dc.into_remainder().iter_mut().zip(sc.remainder()) {
                        *d += s;
                    }
                }
                // an equal-length elementwise add is the one shape stable
                // rustc already vectorizes unaided
                #[cfg(not(feature = "simd"))]
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            }
        }
    };
}

lane_kernel!(i32);
lane_kernel!(i64);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Every kernel against the one-element reference loop, on every tail
    /// shape: empty, single sample, one-short-of-a-chunk, exact chunks,
    /// chunk-plus-one, and long runs with tails.
    fn check_lane<T>(seed: u64, spread: i64)
    where
        T: LaneKernel + std::fmt::Debug + std::ops::Mul<Output = T>,
    {
        let mut rng = Rng::new(seed);
        let bits = 6u32;
        let mask = (1u32 << bits) - 1;
        let mut table = Vec::new();
        for i in 0..=mask as i64 {
            table.push(T::from_i64((i * 37 - 11) % spread));
        }
        for n in [0usize, 1, CHUNK - 1, CHUNK, CHUNK + 1, 3 * CHUNK + 5, 257] {
            let codes: Vec<u32> = (0..n).map(|_| rng.below(1 << bits) as u32).collect();

            let mut got = vec![T::ZERO; n];
            T::gather(&table, mask, &codes, &mut got);
            let want: Vec<T> = codes.iter().map(|&c| table[(c & mask) as usize]).collect();
            assert_eq!(got, want, "gather n={n}");

            let mut acc: Vec<T> = (0..n as i64).map(|i| T::from_i64(i - 7)).collect();
            let mut want_acc = acc.clone();
            T::gather_add(&table, mask, &codes, &mut acc);
            for (w, &c) in want_acc.iter_mut().zip(&codes) {
                *w += table[(c & mask) as usize];
            }
            assert_eq!(acc, want_acc, "gather_add n={n}");

            // affine-folded op: scaled gather-accumulate, negative and
            // positive scales (products stay in-lane for these spreads,
            // matching the compile-time proof the executor relies on)
            for a in [-2i64, 3] {
                let mut acc: Vec<T> = (0..n as i64).map(|i| T::from_i64(i + 9)).collect();
                let mut want_acc = acc.clone();
                T::gather_mul_add(&table, mask, &codes, &mut acc, T::from_i64(a));
                for (w, &c) in want_acc.iter_mut().zip(&codes) {
                    *w += T::from_i64(a) * table[(c & mask) as usize];
                }
                assert_eq!(acc, want_acc, "gather_mul_add n={n} a={a}");

                let mut scaled: Vec<T> =
                    codes.iter().map(|&c| table[(c & mask) as usize]).collect();
                let want_scaled: Vec<T> =
                    scaled.iter().map(|&v| T::from_i64(a) * v).collect();
                T::scale_run(&mut scaled, T::from_i64(a));
                assert_eq!(scaled, want_scaled, "scale_run n={n} a={a}");
            }

            let src: Vec<T> = (0..n as i64).map(|i| T::from_i64(i * 3 - 5)).collect();
            let mut dst = acc.clone();
            let mut want_dst = dst.clone();
            T::add_run(&mut dst, &src);
            for (d, &s) in want_dst.iter_mut().zip(&src) {
                *d += s;
            }
            assert_eq!(dst, want_dst, "add_run n={n}");

            let mut filled = vec![T::ZERO; n];
            T::fill_run(&mut filled, 42);
            assert!(filled.iter().all(|&v| v == T::from_i64(42)), "fill_run n={n}");
        }
    }

    #[test]
    fn i32_kernels_match_reference_on_all_tail_shapes() {
        check_lane::<i32>(1, 1 << 20);
    }

    #[test]
    fn i64_kernels_match_reference_on_all_tail_shapes() {
        check_lane::<i64>(2, 1 << 40);
    }
}
