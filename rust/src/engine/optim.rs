//! Optimizing pass pipeline between [`Netlist`] and the executable
//! [`CompiledProgram`].
//!
//! KANELE's training co-optimizes quantization with *pruning*, so real
//! checkpoints arrive full of edges whose truth table collapsed to a single
//! constant, duplicate spline tables, and inputs nothing reads — and the
//! 1:1 lowering of [`CompiledProgram::compile`] pays table bandwidth and
//! fused-op work for all of them on every batch. This module removes that
//! work at compile time, keeping the program bit-exact with [`crate::sim`]
//! on the *original* netlist:
//!
//! 1. **Constant folding** ([`crate::netlist::opt::optimize`] on a working
//!    clone) — an edge whose table is one repeated value contributes
//!    `table[code] == v` for every code, so the edge is deleted and `v`
//!    folded into the destination neuron's bias operand. The sum is
//!    unchanged term for term, so this is exact across requant clamp rails
//!    and for any accumulator width.
//! 2. **Dead-code elimination** ([`Netlist::dead_inputs`] is the entry
//!    point) — an input read by no surviving LUT needs neither a plane slot
//!    nor, for interior layers, its producer neuron. One backward sweep
//!    deletes unused producers (never output-layer neurons), renumbers the
//!    consumer layer's input indices, and shrinks the requant/feature
//!    planes; dead *external* features are compacted out of the code plane
//!    via [`CompiledProgram::input_map`] while the program's public
//!    `d_in()` keeps the checkpoint's request width.
//! 3. **Table hash-consing** — identical table *contents* are interned once
//!    (hash + exact compare) and materialized at most once per arena
//!    ([`Lane`]), so `table_bytes()` prices unique content, not edge count.
//! 4. **Common-subexpression elimination** — two lookups in one layer with
//!    the same `(input, table)` pair read the same value, so one [`LutOp`]
//!    is emitted and every additional consumer becomes a
//!    [`FanOut`] entry on the layer: the executor gathers the code run once
//!    and feeds k accumulators (within-neuron duplicates fan out to the
//!    same accumulator twice, which is exactly the duplicated sum).
//! 5. **Lane re-analysis + arena compaction** — the prefix-interval range
//!    analysis reruns over the *optimized* op order (folding tightens
//!    ranges, e.g. opposite-sign constants cancel into a small bias), so
//!    layers that previously needed the i64 lane can narrow to i32.
//! 6. **Error-budgeted lossy tier** ([`OptLevel::Lossy`], off by default) —
//!    three passes that trade a *bounded* per-table output error for arena
//!    bytes, gated on a budget of fixed-point LSBs:
//!    * *ε-clustered sharing* — a table lands on an earlier canonical
//!      representative when the exact elementwise max delta fits the
//!      budget (never estimated; representatives never chain, so every
//!      table is within one budget of what it executes).
//!    * *affine folding* — `t2[c] ≈ a*t1[c] + b` within budget replaces
//!      `t2` with the representative `t1`, `scale = a` on the op's
//!      accumulate ([`LutOp::scale`], a fused kernel variant), and `b`
//!      folded into the destination bias.
//!    * *requant-aware range tightening* — the previous layer's requant
//!      emits codes `< levels`, so the lane analysis only prices the
//!      reachable prefix of each table; entries beyond it can't force the
//!      wide lane.
//!    Budget `0` disables all three and is byte-identical to `Full`. A
//!    [`LossyReport`] composes a sound worst-case end-to-end bound: per
//!    layer, each lookup contributes `eps + |scale| * mod_rep(k)` (`mod` =
//!    max entry delta over `k` input-code steps, `k` = the code slack the
//!    previous requant can add under the incoming sum delta, counted
//!    exactly on its boundary table); the output layer's max per-neuron
//!    sum is the bound.
//!
//! Every pass at [`OptLevel::Full`] or below preserves the functional
//! invariant `optimized(net) == sim::eval(net)` bit for bit;
//! [`OptLevel::None`] keeps the untouched 1:1 lowering for A/B comparison,
//! and [`OptLevel::Lossy`] stays within its composed bound instead. An
//! [`OptReport`] with before/after op, table and lane statistics rides on
//! the program and is surfaced through
//! [`crate::coordinator::ServiceStats`] and the `kanele compile` / `kanele
//! serve` CLI.

use std::collections::HashMap;

use crate::fixed::Quantizer;
use crate::netlist::{opt as netopt, Netlist};

use super::program::{
    analyze_lane, boundaries, lane_bytes, CompiledProgram, FanOut, Lane, LayerPlan, LutOp,
    RequantPlan, PLAN_MAX_BITS,
};

/// How much optimization runs between the netlist and the executable
/// program. [`OptLevel::Full`] is the serving default; [`OptLevel::None`]
/// preserves the 1:1 lowering byte for byte (the A/B baseline).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OptLevel {
    /// 1:1 lowering — one `LutOp` per netlist L-LUT, one arena slot per
    /// edge. Byte-identical to [`CompiledProgram::compile`].
    None,
    /// Fold constants, eliminate dead inputs/producers, hash-cons tables,
    /// CSE duplicate lookups, re-run the lane analysis.
    #[default]
    Full,
    /// Everything `Full` does, plus the error-budgeted lossy passes
    /// (ε-clustered table sharing, affine folding, requant-aware range
    /// tightening). The budget is the max elementwise output delta any
    /// single table substitution may introduce, in fixed-point LSBs of the
    /// accumulator (`2^-frac_bits` units); the composed end-to-end
    /// worst-case bound is reported in [`LossyReport`]. `Lossy(0)` is
    /// byte-identical to `Full`.
    Lossy(u32),
}

impl OptLevel {
    /// Parse a CLI level: `none`/`off`, `full`/`on`, or `lossy:<budget>`
    /// (budget = nonnegative LSB count). Anything else — including a
    /// malformed or missing budget — is `None`; the CLI turns that into a
    /// usage error instead of silently defaulting.
    pub fn parse(s: &str) -> Option<OptLevel> {
        match s {
            "none" | "off" => Some(OptLevel::None),
            "full" | "on" => Some(OptLevel::Full),
            _ => s
                .strip_prefix("lossy:")
                .and_then(|b| b.parse::<u32>().ok())
                .map(OptLevel::Lossy),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptLevel::None => "none",
            OptLevel::Full => "full",
            OptLevel::Lossy(_) => "lossy",
        }
    }
}

/// What the pass pipeline did to one program: before/after geometry plus
/// per-pass counters. Attached to the [`CompiledProgram`] it describes and
/// surfaced through `ServiceStats` and the CLI.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OptReport {
    pub level: OptLevel,
    /// Fused ops of the 1:1 lowering (== netlist L-LUT instances).
    pub ops_before: usize,
    /// Fused ops actually emitted after folding + DCE + CSE.
    pub ops_after: usize,
    /// Constant-table edges folded into destination biases.
    pub folded_edges: usize,
    /// External input features compacted out of the code plane.
    pub dead_inputs: usize,
    /// Interior producer neurons deleted (their outputs fed nothing).
    pub dead_neurons: usize,
    /// Lookups served through a [`FanOut`] instead of their own op.
    pub cse_fanouts: usize,
    /// Table references surviving folding + DCE (before sharing).
    pub tables_total: usize,
    /// Unique arena slots after hash-consing (per [`Lane`]).
    pub tables_unique: usize,
    /// Packed arena bytes of the 1:1 lowering (lane-analyzed per layer).
    pub table_bytes_before: usize,
    /// Packed arena bytes of the optimized program.
    pub table_bytes_after: usize,
    /// Layers the range analysis narrowed to i32, before optimization.
    pub i32_layers_before: usize,
    /// ... and after. Folding usually tightens (cancelling constants can
    /// narrow a layer); in principle moving a large folded constant to the
    /// bias — the *front* of the prefix-sum order — can also cost a layer
    /// the narrow lane near the i32 rails. Either way the chosen lane is
    /// proven safe for the order actually executed.
    pub i32_layers_after: usize,
    pub layers: usize,
    /// What the lossy tier did; `Some` iff the level was
    /// [`OptLevel::Lossy`] (present even at budget 0, where every counter
    /// is zero and the program is byte-identical to `Full`).
    pub lossy: Option<LossyReport>,
}

/// What the error-budgeted lossy passes did to one program — counters per
/// pass, the bytes the budget bought vs a `Full` compile of the same
/// netlist, and the composed sound worst-case bound on any output sum.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LossyReport {
    /// The per-table budget (fixed-point LSBs) the level was pinned at.
    pub budget: u32,
    /// Tables retargeted to an ε-close representative (pure shares).
    pub shared_tables: usize,
    /// Largest elementwise delta any pure share actually spent (<= budget).
    pub shared_eps: i64,
    /// Tables replaced by `scale * rep + offset` (affine folds).
    pub affine_folds: usize,
    /// Largest residual any affine fold actually spent (<= budget).
    pub affine_eps: i64,
    /// Layers the requant-aware reachability analysis narrowed to the i32
    /// lane that the plain (whole-table) analysis would have kept wide.
    pub tightened_layers: usize,
    /// `table_bytes()` of the same netlist compiled at [`OptLevel::Full`].
    pub table_bytes_full: usize,
    /// `table_bytes()` of this lossy program.
    pub table_bytes_lossy: usize,
    /// Sound bound on `|lossy output - exact output|` for any input, in
    /// fixed-point LSBs: per-table residuals plus requant code slack,
    /// composed layer by layer (see the module docs). 0 at budget 0.
    pub worst_case_bound: i64,
}

impl LossyReport {
    /// Arena-byte reduction the budget bought over [`OptLevel::Full`]
    /// (0.0 until [`compile_with`] fills in the A/B bytes).
    pub fn byte_reduction_vs_full(&self) -> f64 {
        if self.table_bytes_full == 0 {
            0.0
        } else {
            1.0 - self.table_bytes_lossy as f64 / self.table_bytes_full as f64
        }
    }
}

impl OptReport {
    /// Fused-op reduction as a fraction of the 1:1 lowering (0.0 when the
    /// pipeline found nothing, or at [`OptLevel::None`]).
    pub fn op_reduction(&self) -> f64 {
        if self.ops_before == 0 {
            0.0
        } else {
            1.0 - self.ops_after as f64 / self.ops_before as f64
        }
    }

    /// Table-byte reduction as a fraction of the 1:1 arenas.
    pub fn byte_reduction(&self) -> f64 {
        if self.table_bytes_before == 0 {
            0.0
        } else {
            1.0 - self.table_bytes_after as f64 / self.table_bytes_before as f64
        }
    }

    /// One-line summary for `kanele compile` / `kanele serve` / benches.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "level {}: ops {} -> {} (-{:.1}%), tables {} refs -> {} unique, bytes {} -> {} (-{:.1}%), folded {}, dead inputs {}, dead neurons {}, cse {}, i32 lanes {}/{} -> {}/{}",
            self.level.name(),
            self.ops_before,
            self.ops_after,
            100.0 * self.op_reduction(),
            self.tables_total,
            self.tables_unique,
            self.table_bytes_before,
            self.table_bytes_after,
            100.0 * self.byte_reduction(),
            self.folded_edges,
            self.dead_inputs,
            self.dead_neurons,
            self.cse_fanouts,
            self.i32_layers_before,
            self.layers,
            self.i32_layers_after,
            self.layers,
        );
        if let Some(l) = &self.lossy {
            s.push_str(&format!(
                ", lossy[budget {} lsb: shared {} (eps <= {}), affine {} (eps <= {}), tightened {}, bytes {} -> {} (-{:.1}%), worst-case bound {} lsb]",
                l.budget,
                l.shared_tables,
                l.shared_eps,
                l.affine_folds,
                l.affine_eps,
                l.tightened_layers,
                l.table_bytes_full,
                l.table_bytes_lossy,
                100.0 * l.byte_reduction_vs_full(),
                l.worst_case_bound,
            ));
        }
        s
    }
}

/// Lower `net` at the requested level. `Full` runs the pass pipeline on a
/// working clone (the source netlist — e.g. a hot-swap cell's snapshot —
/// is never mutated); `None` is the legacy lowering plus an identity
/// report.
pub(super) fn compile_with(net: &Netlist, level: OptLevel) -> CompiledProgram {
    match level {
        OptLevel::None => {
            let mut prog = CompiledProgram::compile(net);
            prog.opt = Some(identity_report(&prog));
            prog
        }
        OptLevel::Full => compile_pipeline(net, None),
        OptLevel::Lossy(budget) => {
            // the A/B baseline in the report is exact, not estimated: price
            // the same netlist at Full (cheap — compilation is O(table
            // entries)) and record both arenas side by side
            let full_bytes = compile_pipeline(net, None).table_bytes();
            let mut prog = compile_pipeline(net, Some(budget));
            let lossy_bytes = prog.table_bytes();
            if let Some(l) = prog.opt.as_mut().and_then(|r| r.lossy.as_mut()) {
                l.table_bytes_full = full_bytes;
                l.table_bytes_lossy = lossy_bytes;
            }
            prog
        }
    }
}

/// The report of a program the pipeline never touched: before == after.
fn identity_report(prog: &CompiledProgram) -> OptReport {
    let i32_layers = prog.layers().iter().filter(|l| l.lane == Lane::I32).count();
    OptReport {
        level: OptLevel::None,
        ops_before: prog.n_ops(),
        ops_after: prog.n_ops(),
        tables_total: prog.n_ops(),
        tables_unique: prog.n_ops(),
        table_bytes_before: prog.table_bytes(),
        table_bytes_after: prog.table_bytes(),
        i32_layers_before: i32_layers,
        i32_layers_after: i32_layers,
        layers: prog.layers().len(),
        ..OptReport::default()
    }
}

/// One CSE group: every surviving lookup of a layer that reads the same
/// input through the same table content at the same accumulate scale. The
/// first destination gets the [`LutOp`]; the rest become [`FanOut`]
/// entries.
struct Group {
    input: u32,
    /// Intern id into the table pool (content identity — under the lossy
    /// tier, the *representative's* id).
    table: u32,
    /// Accumulate multiplier ([`LutOp::scale`]); 1 except for the lossy
    /// tier's affine folds.
    scale: i32,
    /// Accumulator targets in occurrence order; a neuron appearing twice
    /// receives the gathered value twice (within-neuron duplicate).
    dsts: Vec<u32>,
}

/// The lossy tier's verdict on one interned table content: execute
/// `scale * pool[rep][c]` and fold `offset` into the destination bias,
/// introducing at most `eps` LSBs of output delta per lookup. The identity
/// substitution (`rep` = own id, scale 1, offset 0, eps 0) is what `Full`
/// and every out-of-budget table get.
#[derive(Clone, Copy)]
struct Subst {
    rep: u32,
    scale: i64,
    offset: i64,
    eps: i64,
}

/// Affine-fold slope cap: keeps `scale` comfortably inside [`LutOp::scale`]
/// (i32) and the overflow guards' headroom. Real near-affine spline pairs
/// have small slopes; anything larger is noise fitting.
const MAX_AFFINE_SCALE: i64 = 1 << 20;

/// Runtime headroom guard for scaled gathers in the wide lane: every
/// `|scale * rep[c]|` and `|scale * rep[c] + offset|` accepted by the fold
/// stays below this, so the executor's i64 multiply-accumulate cannot wrap
/// even before the lane analysis prices the sums.
const AFFINE_ABS_CAP: i64 = i64::MAX / 4;

/// Exact elementwise max |a - b| when it fits the budget, else None.
fn max_abs_delta(a: &[i64], b: &[i64], budget: i64) -> Option<i64> {
    debug_assert_eq!(a.len(), b.len());
    let mut worst = 0i64;
    for (&x, &y) in a.iter().zip(b) {
        let d = (x as i128 - y as i128).unsigned_abs();
        if d > budget as u128 {
            return None;
        }
        worst = worst.max(d as i64);
    }
    Some(worst)
}

/// Greedy canonical-representative clustering for one freshly interned
/// table: try a pure ε-share against every same-length representative
/// first (cheapest at runtime — plain gather), then an affine fold. Reps
/// never chain (ε-matched tables don't become reps), so every accepted
/// substitution is within one `budget` of the content it executes.
fn lossy_subst(t: &[i64], id: u32, pool: &[Vec<i64>], reps: &[u32], budget: i64) -> Subst {
    if budget > 0 {
        for &r in reps {
            let rt = &pool[r as usize];
            if rt.len() != t.len() {
                continue;
            }
            if let Some(eps) = max_abs_delta(t, rt, budget) {
                return Subst { rep: r, scale: 1, offset: 0, eps };
            }
        }
        for &r in reps {
            let rt = &pool[r as usize];
            if rt.len() == t.len() {
                if let Some(sub) = affine_fit(t, rt, r, budget) {
                    return sub;
                }
            }
        }
    }
    Subst { rep: id, scale: 1, offset: 0, eps: 0 }
}

/// Fit `t[c] ≈ a * r[c] + b` within `budget`: least-squares slope rounded
/// to the nearest integers (±1), optimal intercept `b = (dmax + dmin) / 2`
/// over the residuals `d[c] = t[c] - a*r[c]`, exact worst-case residual
/// `eps = ceil((dmax - dmin) / 2)`. All candidate arithmetic runs in i128;
/// acceptance additionally proves every runtime product/sum stays under
/// [`AFFINE_ABS_CAP`], so the executor cannot overflow on *any* address —
/// reachable or not.
fn affine_fit(t: &[i64], r: &[i64], rep: u32, budget: i64) -> Option<Subst> {
    let n = t.len() as i128;
    if n == 0 {
        return None;
    }
    let (mut sr, mut st, mut srr, mut srt) = (0i128, 0i128, 0i128, 0i128);
    for (&x, &y) in r.iter().zip(t) {
        sr += x as i128;
        st += y as i128;
        srr += (x as i128) * (x as i128);
        srt += (x as i128) * (y as i128);
    }
    let den = n * srr - sr * sr;
    if den == 0 {
        return None; // constant representative: nothing to scale against
    }
    let num = n * srt - sr * st;
    // round-to-nearest integer slope, plus its neighbors: the integer
    // optimum is within 1 of the real-valued LS slope for the minmax
    // objective too often enough to be worth the two extra exact checks
    let a0 = {
        let (q, rem) = (num / den, num % den);
        if rem.abs() * 2 >= den.abs() {
            q + if (num < 0) != (den < 0) { -1 } else { 1 }
        } else {
            q
        }
    };
    for a in [a0, a0 - 1, a0 + 1] {
        // a == 1 with offset is a valid shift fold; a == 0 would mean a
        // constant table, which constant folding already owns
        if a == 0 || a.unsigned_abs() > MAX_AFFINE_SCALE as u128 {
            continue;
        }
        let (mut dmin, mut dmax) = (i128::MAX, i128::MIN);
        let mut prod_ok = true;
        for (&x, &y) in r.iter().zip(t) {
            let p = a * x as i128;
            if p.unsigned_abs() > AFFINE_ABS_CAP as u128 {
                prod_ok = false;
                break;
            }
            let d = y as i128 - p;
            dmin = dmin.min(d);
            dmax = dmax.max(d);
        }
        if !prod_ok {
            continue;
        }
        let b = (dmax + dmin) >> 1; // floor((dmax+dmin)/2): eps below is exact
        let eps = (dmax - b).max(b - dmin);
        if eps > budget as i128 || b.unsigned_abs() > AFFINE_ABS_CAP as u128 {
            continue;
        }
        return Some(Subst {
            rep,
            scale: a as i64,
            offset: b as i64,
            eps: eps as i64,
        });
    }
    None
}

/// Max |t[i] - t[j]| over |i - j| <= k: how much a table can amplify `k`
/// codes of upstream slack. Exact O(len * k) for small k; the global
/// spread (still sound, possibly loose) caps the cost for large k.
fn table_mod(t: &[i64], k: usize) -> i64 {
    if k == 0 || t.len() < 2 {
        return 0;
    }
    let k = k.min(t.len() - 1);
    if k > 64 {
        let (lo, hi) =
            t.iter().fold((i64::MAX, i64::MIN), |(a, b), &v| (a.min(v), b.max(v)));
        return hi.saturating_sub(lo);
    }
    let mut m = 0u128;
    for i in 0..t.len() {
        for j in i + 1..=(i + k).min(t.len() - 1) {
            m = m.max((t[i] as i128 - t[j] as i128).unsigned_abs());
        }
    }
    m.min(i64::MAX as u128) as i64
}

/// How many codes a requantized sum can move when the sum itself is off by
/// at most `delta` LSBs: the max number of code boundaries inside any
/// window of width `2 * delta` (a perturbed sum stays within `±delta` of
/// the true one, and the code difference is the boundary count between
/// them). Exact via the plan's boundary table; quantizers too wide for an
/// integer plan get the trivial `levels - 1` bound.
fn requant_slack(q: &Quantizer, frac_bits: u32, delta: i64) -> usize {
    if delta == 0 {
        return 0;
    }
    let trivial = (q.levels() as usize).saturating_sub(1);
    if q.bits > PLAN_MAX_BITS {
        return trivial;
    }
    match boundaries(q, frac_bits) {
        Some(b) => {
            let window = 2 * delta as i128;
            let (mut best, mut i) = (0usize, 0usize);
            for j in 0..b.len() {
                while (b[j] as i128 - b[i] as i128) > window {
                    i += 1;
                }
                best = best.max(j - i + 1);
            }
            best.min(trivial)
        }
        None => trivial,
    }
}

fn compile_pipeline(net: &Netlist, lossy: Option<u32>) -> CompiledProgram {
    // "before" geometry: what the 1:1 lowering would have cost, priced with
    // the same per-layer lane analysis it would have run
    let ops_before = net.n_luts();
    let mut table_bytes_before = 0usize;
    let mut i32_layers_before = 0usize;
    for layer in &net.layers {
        let lane = analyze_lane(layer);
        let words: usize =
            layer.neurons.iter().flat_map(|n| &n.luts).map(|l| l.table.len()).sum();
        table_bytes_before += words * lane_bytes(lane);
        if lane == Lane::I32 {
            i32_layers_before += 1;
        }
    }

    // passes 1 + 2 rewrite a working clone
    let mut work = net.clone();
    let folded_edges = netopt::optimize(&mut work).constant_tables_folded;
    let (dead_inputs, dead_neurons, input_map) = eliminate_dead(&mut work);

    // passes 3 + 4 + 5 (+ 6 under a lossy budget) happen at lowering:
    // intern table contents, cluster each new content onto an ε- or
    // affine-close representative when the budget allows, group
    // same-(input, table, scale) lookups, re-analyze lanes in the op order
    // the executor will actually run (pricing only requant-reachable
    // entries under the lossy tier), and materialize each representative
    // at most once per arena
    let budget = lossy.unwrap_or(0) as i64;
    let mut pool: Vec<Vec<i64>> = Vec::new();
    let mut intern: HashMap<Vec<i64>, u32> = HashMap::new();
    // per intern id: what to execute instead (identity outside the budget)
    let mut subst: Vec<Subst> = Vec::new();
    // canonical representatives, in pool order (never ε-matched contents)
    let mut reps: Vec<u32> = Vec::new();
    let mut lossy_report = lossy.map(|b| LossyReport { budget: b, ..Default::default() });
    let mut tables32: Vec<i32> = Vec::new();
    let mut tables64: Vec<i64> = Vec::new();
    let mut slot32: HashMap<u32, u32> = HashMap::new();
    let mut slot64: HashMap<u32, u32> = HashMap::new();
    let mut ops: Vec<LutOp> = Vec::new();
    let mut fanouts: Vec<FanOut> = Vec::new();
    let mut biases: Vec<i64> = Vec::new();
    let mut layers: Vec<LayerPlan> = Vec::with_capacity(work.layers.len());
    let mut max_width = 1usize;
    let (mut tables_total, mut cse_fanouts) = (0usize, 0usize);
    // worst-case bound composition (budget > 0 only): codes entering the
    // current layer may be off by `slack_in` steps, sums leaving the last
    // processed layer by `layer_delta` LSBs
    let mut slack_in = 0usize;
    let mut prev_levels: Option<usize> = None;
    let mut layer_delta = 0i64;

    for layer in &work.layers {
        let ops_start = ops.len();
        let fan_start = fanouts.len();
        let bias_off = biases.len();
        let mut groups: Vec<Group> = Vec::new();
        let mut by_key: HashMap<(u32, u32, i32), usize> = HashMap::new();
        let mut eps_sum: Vec<i64> = vec![0; layer.d_out];
        // per-rep amplification of the incoming code slack, cached (the
        // slack is fixed for the whole layer)
        let mut mod_cache: HashMap<u32, i64> = HashMap::new();
        for (q, neuron) in layer.neurons.iter().enumerate() {
            biases.push(neuron.bias);
            for lut in &neuron.luts {
                debug_assert!(lut.table.len().is_power_of_two());
                debug_assert!(lut.input < layer.d_in);
                tables_total += 1;
                let id = match intern.get(lut.table.as_slice()) {
                    Some(&id) => id,
                    None => {
                        let id = pool.len() as u32;
                        pool.push(lut.table.clone());
                        intern.insert(lut.table.clone(), id);
                        let sub = lossy_subst(&lut.table, id, &pool, &reps, budget);
                        if sub.rep == id {
                            reps.push(id);
                        } else if let Some(l) = lossy_report.as_mut() {
                            if sub.scale == 1 && sub.offset == 0 {
                                l.shared_tables += 1;
                                l.shared_eps = l.shared_eps.max(sub.eps);
                            } else {
                                l.affine_folds += 1;
                                l.affine_eps = l.affine_eps.max(sub.eps);
                            }
                        }
                        subst.push(sub);
                        id
                    }
                };
                let sub = subst[id as usize];
                if sub.offset != 0 {
                    // the affine fold's intercept is one more constant
                    // operand of the destination neuron
                    biases[bias_off + q] += sub.offset;
                }
                if budget > 0 {
                    // this lookup's worst-case contribution to neuron q:
                    // its own residual plus the (scaled) amplification of
                    // the incoming code slack through the executed table
                    let amp = *mod_cache.entry(sub.rep).or_insert_with(|| {
                        let t = &pool[sub.rep as usize];
                        let reach = prev_levels.unwrap_or(t.len()).min(t.len());
                        table_mod(&t[..reach], slack_in)
                    });
                    let a = sub.scale.unsigned_abs().min(i64::MAX as u64) as i64;
                    eps_sum[q] = eps_sum[q]
                        .saturating_add(sub.eps)
                        .saturating_add(amp.saturating_mul(a));
                }
                let key = (lut.input as u32, sub.rep, sub.scale as i32);
                match by_key.get(&key) {
                    Some(&g) => groups[g].dsts.push(q as u32),
                    None => {
                        by_key.insert(key, groups.len());
                        groups.push(Group {
                            input: lut.input as u32,
                            table: sub.rep,
                            scale: sub.scale as i32,
                            dsts: vec![q as u32],
                        });
                    }
                }
            }
        }
        cse_fanouts += groups.iter().map(|g| g.dsts.len() - 1).sum::<usize>();
        // requant-aware range tightening: codes produced by the previous
        // layer's requant are < its level count, so entries past that
        // prefix are unreachable and must not force the wide lane. Sound
        // only for interior layers (external codes are arbitrary); gated
        // on budget > 0 so Lossy(0) stays byte-identical to Full.
        let reach = if budget > 0 { prev_levels } else { None };
        let lane = analyze_lane_groups(&biases[bias_off..], &groups, &pool, reach);
        if reach.is_some()
            && lane == Lane::I32
            && analyze_lane_groups(&biases[bias_off..], &groups, &pool, None) == Lane::I64
        {
            if let Some(l) = lossy_report.as_mut() {
                l.tightened_layers += 1;
            }
        }
        for g in &groups {
            let t = &pool[g.table as usize];
            let off = match lane {
                Lane::I32 => *slot32.entry(g.table).or_insert_with(|| {
                    let off = tables32.len() as u32;
                    // lossless for every reachable entry: the group
                    // analysis proved it fits. Under range tightening an
                    // *unreachable* entry may wrap here — it is never
                    // gathered, and any layer that could reach it fails
                    // its own analysis and reads the exact i64 slot
                    tables32.extend(t.iter().map(|&v| v as i32));
                    off
                }),
                Lane::I64 => *slot64.entry(g.table).or_insert_with(|| {
                    let off = tables64.len() as u32;
                    tables64.extend_from_slice(t);
                    off
                }),
            };
            let op_local = (ops.len() - ops_start) as u32;
            ops.push(LutOp {
                table_off: off,
                addr_mask: (t.len() - 1) as u32,
                input: g.input,
                neuron: g.dsts[0],
                scale: g.scale,
            });
            for &q in &g.dsts[1..] {
                fanouts.push(FanOut { op: op_local, neuron: q });
            }
        }
        max_width = max_width.max(layer.d_in).max(layer.d_out);
        layers.push(LayerPlan {
            d_in: layer.d_in,
            d_out: layer.d_out,
            ops: ops_start..ops.len(),
            bias_off,
            lane,
            fanout: fan_start..fanouts.len(),
            requant: layer.requant.map(|q| RequantPlan::build(q, work.frac_bits)),
        });
        // propagate the bound: this layer's worst per-neuron sum delta,
        // then (through its requant, if any) the code slack the next
        // layer's tables will see
        layer_delta = eps_sum.iter().copied().max().unwrap_or(0);
        match &layer.requant {
            Some(q) => {
                slack_in = if budget > 0 {
                    requant_slack(q, work.frac_bits, layer_delta)
                } else {
                    0
                };
                prev_levels = Some(q.levels() as usize);
            }
            None => {
                slack_in = 0;
                prev_levels = None;
            }
        }
    }
    assert!(
        tables64.len() <= u32::MAX as usize && tables32.len() <= u32::MAX as usize,
        "table arena exceeds u32 addressing"
    );

    let table_bytes_after = tables32.len() * std::mem::size_of::<i32>()
        + tables64.len() * std::mem::size_of::<i64>();
    if let Some(l) = lossy_report.as_mut() {
        // the output layer has no requant, so its sum delta IS the
        // end-to-end bound; compile_with fills in the Full-compile bytes
        l.worst_case_bound = layer_delta;
        l.table_bytes_lossy = table_bytes_after;
    }
    let report = OptReport {
        level: match lossy {
            Some(b) => OptLevel::Lossy(b),
            None => OptLevel::Full,
        },
        ops_before,
        ops_after: ops.len(),
        folded_edges,
        dead_inputs,
        dead_neurons,
        cse_fanouts,
        tables_total,
        tables_unique: slot32.len() + slot64.len(),
        table_bytes_before,
        table_bytes_after,
        i32_layers_before,
        i32_layers_after: layers.iter().filter(|l| l.lane == Lane::I32).count(),
        layers: layers.len(),
        lossy: lossy_report,
    };
    CompiledProgram {
        name: work.name.clone(),
        frac_bits: work.frac_bits,
        tables64: std::sync::Arc::new(tables64),
        tables32: std::sync::Arc::new(tables32),
        ops,
        biases,
        // the public request width stays the checkpoint's: dead external
        // features are accepted and ignored (compacted out by `input_map`)
        d_in: net.input_width(),
        d_out: work.layers.last().map(|l| l.d_out).unwrap_or(0),
        max_width,
        uses_i32: layers.iter().any(|l| l.lane == Lane::I32),
        uses_i64: layers.iter().any(|l| l.lane == Lane::I64),
        layers,
        fanouts,
        input_map,
        opt: Some(report),
    }
}

/// Dead-code elimination on the working clone. [`Netlist::dead_inputs`] is
/// the oracle: for every interior layer (back to front, so deadness
/// cascades in one sweep) an unread input's producer neuron in the previous
/// layer is deleted — ops, bias and plane slot — and the consumer layer's
/// input indices are renumbered. Output-layer neurons are never deleted
/// (they are the program's result). Dead inputs of layer 0 are *external*
/// features: they stay in the request width but are compacted out of the
/// feature plane by the returned `input_map` (live external index per
/// internal plane slot).
///
/// Returns `(dead external inputs, deleted interior neurons, input_map)`.
fn eliminate_dead(net: &mut Netlist) -> (usize, usize, Option<Vec<u32>>) {
    if net.layers.is_empty() {
        return (0, 0, None);
    }
    let mut dead_neurons = 0usize;
    for l in (1..net.layers.len()).rev() {
        let dead = net.dead_inputs(l);
        if dead.is_empty() {
            continue;
        }
        let (is_dead, remap, live) = dead_mask(net.layers[l].d_in, &dead);
        // delete the producers nothing reads
        let prev = &mut net.layers[l - 1];
        let mut q = 0usize;
        prev.neurons.retain(|_| {
            let keep = !is_dead[q];
            q += 1;
            keep
        });
        prev.d_out = prev.neurons.len();
        prev.depth = prev.neurons.iter().map(|n| n.depth).max().unwrap_or(0);
        dead_neurons += dead.len();
        // renumber the consumer layer's reads
        renumber_inputs(&mut net.layers[l], live.len(), &remap);
    }
    let dead0 = net.dead_inputs(0);
    if dead0.is_empty() {
        return (0, dead_neurons, None);
    }
    let (_, remap, live) = dead_mask(net.layers[0].d_in, &dead0);
    renumber_inputs(&mut net.layers[0], live.len(), &remap);
    (dead0.len(), dead_neurons, Some(live))
}

/// Dense renumbering of a layer interface with `dead` input indices
/// removed: the `is_dead` mask, an old→new `remap` (dead slots keep
/// `u32::MAX`, which would trap on use), and the surviving old indices in
/// order. Shared by the interior and external halves of [`eliminate_dead`]
/// so the two renumberings cannot drift apart.
fn dead_mask(d_in: usize, dead: &[usize]) -> (Vec<bool>, Vec<u32>, Vec<u32>) {
    let mut is_dead = vec![false; d_in];
    for &p in dead {
        is_dead[p] = true;
    }
    let mut remap = vec![u32::MAX; d_in];
    let mut live = Vec::with_capacity(d_in - dead.len());
    for (p, &gone) in is_dead.iter().enumerate() {
        if !gone {
            remap[p] = live.len() as u32;
            live.push(p as u32);
        }
    }
    (is_dead, remap, live)
}

/// Point a layer's LUT reads at the renumbered (compacted) inputs.
fn renumber_inputs(layer: &mut crate::netlist::LayerNet, new_d_in: usize, remap: &[u32]) {
    layer.d_in = new_d_in;
    for n in &mut layer.neurons {
        for lut in &mut n.luts {
            lut.input = remap[lut.input] as usize;
        }
    }
}

/// The prefix-interval lane analysis of [`analyze_lane`], rerun over the
/// *optimized* op order: groups execute front to back, each feeding every
/// destination (fanout included) at its position in the stream, so the
/// interval walked here is exactly the partial-sum sequence the executor
/// produces. Sound for the same reason as the 1:1 analysis — the reachable
/// accumulator after k contributions lies in `[bias + Σ min, bias + Σ max]`
/// over the first k contributions in this exact order.
///
/// `reach` (the lossy tier's requant-aware tightening) restricts the
/// priced entries to each table's first `reach` — the only addresses the
/// previous layer's requant can emit. Group scales multiply the interval
/// endpoints (every per-entry product then provably fits the chosen lane,
/// so the executor's in-lane multiply cannot wrap); saturating i64
/// arithmetic can only widen intervals, conservatively selecting i64.
fn analyze_lane_groups(
    biases: &[i64],
    groups: &[Group],
    pool: &[Vec<i64>],
    reach: Option<usize>,
) -> Lane {
    const LO: i64 = i32::MIN as i64;
    const HI: i64 = i32::MAX as i64;
    if biases.iter().any(|&b| b < LO || b > HI) {
        return Lane::I64;
    }
    let mut lo = biases.to_vec();
    let mut hi = biases.to_vec();
    for g in groups {
        let t = &pool[g.table as usize];
        let t = match reach {
            Some(r) => &t[..r.min(t.len())],
            None => &t[..],
        };
        let (tlo, thi) =
            t.iter().fold((i64::MAX, i64::MIN), |(a, b), &v| (a.min(v), b.max(v)));
        if tlo > thi {
            continue; // empty table: contributes nothing
        }
        let a = g.scale as i64;
        let (slo, shi) = if a >= 0 {
            (tlo.saturating_mul(a), thi.saturating_mul(a))
        } else {
            (thi.saturating_mul(a), tlo.saturating_mul(a))
        };
        if slo < LO || shi > HI {
            return Lane::I64;
        }
        for &q in &g.dsts {
            let q = q as usize;
            lo[q] = lo[q].saturating_add(slo);
            hi[q] = hi[q].saturating_add(shi);
            if lo[q] < LO || hi[q] > HI {
                return Lane::I64;
            }
        }
    }
    Lane::I32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::{nearify, prunify, synthetic};
    use crate::checkpoint::Checkpoint;
    use crate::engine::{self, Executor};
    use crate::fixed::Quantizer;
    use crate::lut;
    use crate::netlist::{adder_depth, LayerNet, LutInst, NeuronNet};
    use crate::sim;
    use crate::util::{prop, Rng};

    fn net_of(ck: &Checkpoint) -> Netlist {
        let tables = lut::from_checkpoint(ck);
        Netlist::build(ck, &tables, 2)
    }

    fn random_batch(rng: &mut Rng, n: usize, d: usize, bits: u32) -> Vec<Vec<u32>> {
        (0..n)
            .map(|_| (0..d).map(|_| rng.below(1 << bits) as u32).collect())
            .collect()
    }

    /// Optimized and unoptimized lowerings of the same netlist must agree
    /// with the interpreter bit for bit on `batch`; returns the Full report.
    fn assert_bit_exact(net: &Netlist, batch: &[Vec<u32>]) -> OptReport {
        let p_none = compile_with(net, OptLevel::None);
        let p_full = compile_with(net, OptLevel::Full);
        let want = sim::eval_batch(net, batch);
        assert_eq!(engine::run_batch(&p_none, batch), want, "OptLevel::None != sim");
        assert_eq!(engine::run_batch(&p_full, batch), want, "OptLevel::Full != sim");
        // the reused-executor flat path agrees too (fanout + input_map run
        // through the same run_layer, but cover both entry points)
        let mut ex = Executor::new();
        let mut flat = Vec::new();
        ex.run_batch_into(&p_full, batch, &mut flat);
        let want_flat: Vec<i64> = want.iter().flatten().copied().collect();
        assert_eq!(flat, want_flat, "flat outputs diverge on the optimized program");
        p_full.opt_report().unwrap().clone()
    }

    // -- acceptance: the paper-shaped pruned net -------------------------

    #[test]
    fn pruned_synthetic_hits_the_reduction_bars() {
        // >= 30% constant edges and >= 20% duplicate tables must yield
        // >= 25% fused-op reduction and >= 30% table-byte reduction
        let mut ck = synthetic(&[32, 16, 16, 5], &[6, 5, 5, 6], 0xACCE55);
        prunify(&mut ck, 40, 30, 7);
        let net = net_of(&ck);
        let mut rng = Rng::new(3);
        let report = assert_bit_exact(&net, &random_batch(&mut rng, 96, 32, 6));
        assert!(
            report.folded_edges as f64 >= 0.30 * report.ops_before as f64,
            "construction should fold >= 30% of edges: {report:?}"
        );
        assert!(
            (report.tables_total - report.tables_unique) as f64
                >= 0.20 * report.tables_total as f64,
            "construction should dedup >= 20% of surviving tables: {report:?}"
        );
        assert!(
            report.op_reduction() >= 0.25,
            "op reduction {:.3} < 0.25: {report:?}",
            report.op_reduction()
        );
        assert!(
            report.byte_reduction() >= 0.30,
            "byte reduction {:.3} < 0.30: {report:?}",
            report.byte_reduction()
        );
        assert_eq!(report.level, OptLevel::Full);
    }

    // -- property: optimized == unoptimized == sim ------------------------

    #[test]
    fn prop_optimized_equals_unoptimized_equals_sim() {
        // random shapes, random pruning mixes (including 0%), random
        // streams: the three executions are one function
        prop::check("optimized-equals-sim", 30, |g| {
            let n_layers = g.usize_in(1, 3);
            let mut dims = vec![g.usize_in(1, 6)];
            let mut bits = vec![g.usize_in(2, 5) as u32];
            for _ in 0..n_layers {
                dims.push(g.usize_in(1, 6));
                bits.push(g.usize_in(2, 6) as u32);
            }
            let seed = g.rng().next_u64();
            let mut ck = synthetic(&dims, &bits, seed);
            let const_pct = g.usize_in(0, 60);
            let dup_pct = g.usize_in(0, 40);
            prunify(&mut ck, const_pct, dup_pct, seed ^ 0xD1CE);
            let net = net_of(&ck);
            let p_none = compile_with(&net, OptLevel::None);
            let p_full = compile_with(&net, OptLevel::Full);
            let n = g.usize_in(1, 24);
            let batch: Vec<Vec<u32>> = (0..n)
                .map(|_| {
                    (0..dims[0]).map(|_| g.rng().below(1u64 << bits[0]) as u32).collect()
                })
                .collect();
            let want = sim::eval_batch(&net, &batch);
            if engine::run_batch(&p_none, &batch) != want {
                return Err(format!("None != sim (dims {dims:?} seed {seed})"));
            }
            if engine::run_batch(&p_full, &batch) != want {
                return Err(format!(
                    "Full != sim (dims {dims:?} seed {seed}, const {const_pct}% dup {dup_pct}%, report {:?})",
                    p_full.opt_report()
                ));
            }
            let r = p_full.opt_report().unwrap();
            if r.ops_after > r.ops_before {
                return Err(format!("optimizer grew the program: {r:?}"));
            }
            if r.table_bytes_after > r.table_bytes_before {
                return Err(format!("optimizer grew the arenas: {r:?}"));
            }
            // lane widening is only possible near the i32 rails (a large
            // folded bias moves to the FRONT of the prefix order); this
            // generator's tables and constants are < 2^13, so any widening
            // here would be an analysis bug, not the known edge case
            if r.i32_layers_after < r.i32_layers_before {
                return Err(format!("optimizer widened a lane on small tables: {r:?}"));
            }
            Ok(())
        });
    }

    // -- targeted: bias folding across clamp rails ------------------------

    /// Two-layer netlist with constant edges of magnitude `c` on the first
    /// layer (plus one varying edge) feeding a requantizer: the folded bias
    /// pushes sums across the clamp rails, where an off-by-one in folding
    /// would flip codes.
    fn clamp_rail_net(c: i64) -> Netlist {
        let varying: Vec<i64> = (0..8).map(|i| (i * 577) % 2000 - 1000).collect();
        let l0 = vec![
            NeuronNet {
                luts: vec![
                    LutInst { input: 0, table: vec![c; 8], out_width: 48 },
                    LutInst { input: 1, table: varying.clone(), out_width: 12 },
                ],
                bias: 0,
                depth: adder_depth(2, 2),
                sum_width: 50,
            },
            NeuronNet {
                luts: vec![
                    LutInst { input: 0, table: vec![-c; 8], out_width: 48 },
                    LutInst { input: 1, table: vec![c; 8], out_width: 48 },
                    LutInst { input: 0, table: varying.clone(), out_width: 12 },
                ],
                bias: 0,
                depth: adder_depth(3, 2),
                sum_width: 50,
            },
        ];
        let l1 = vec![NeuronNet {
            luts: vec![
                LutInst { input: 0, table: varying.clone(), out_width: 12 },
                LutInst { input: 1, table: varying, out_width: 12 },
            ],
            bias: 0,
            depth: adder_depth(2, 2),
            sum_width: 14,
        }];
        Netlist {
            name: "clamp-rails".into(),
            layers: vec![
                LayerNet {
                    d_in: 2,
                    d_out: 2,
                    in_bits: 3,
                    out_bits: 3,
                    neurons: l0,
                    requant: Some(Quantizer::new(3, -4.0, 4.0)),
                    depth: 2,
                },
                LayerNet {
                    d_in: 2,
                    d_out: 1,
                    in_bits: 3,
                    out_bits: 8,
                    neurons: l1,
                    requant: None,
                    depth: 1,
                },
            ],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        }
    }

    #[test]
    fn bias_folding_exact_across_clamp_rails() {
        // c = 2^40 slams neuron 0 of layer 0 into the hi rail and leaves
        // neuron 1 (whose two constants cancel) on the varying edge alone:
        // every (code0, code1) combination must match sim exactly
        let net = clamp_rail_net(1 << 40);
        let batch: Vec<Vec<u32>> =
            (0..64).map(|i| vec![(i % 8) as u32, (i / 8) as u32]).collect();
        let report = assert_bit_exact(&net, &batch);
        assert_eq!(report.folded_edges, 3, "{report:?}");
        // moderate constants too (rails approached from inside the domain)
        let net = clamp_rail_net(10_000);
        assert_bit_exact(&net, &batch);
    }

    #[test]
    fn folding_cancelling_constants_narrows_the_lane() {
        // before folding, |2^40| entries force the wide lane; the two
        // constants cancel into bias 0, so the optimized layer must narrow
        let net = clamp_rail_net(1 << 40);
        let p_none = compile_with(&net, OptLevel::None);
        let p_full = compile_with(&net, OptLevel::Full);
        assert_eq!(p_none.layers()[0].lane, Lane::I64);
        // neuron 0 keeps a folded bias of 2^40, which still needs i64 —
        // so check the report on a net where everything cancels instead
        assert_eq!(p_full.layers()[0].lane, Lane::I64, "bias 2^40 still needs the wide lane");
        let mut cancelling = clamp_rail_net(1 << 40);
        // make neuron 0's constant cancel too (add an opposite edge)
        cancelling.layers[0].neurons[0].luts.push(LutInst {
            input: 1,
            table: vec![-(1i64 << 40); 8],
            out_width: 48,
        });
        let batch: Vec<Vec<u32>> =
            (0..64).map(|i| vec![(i % 8) as u32, (i / 8) as u32]).collect();
        let report = assert_bit_exact(&cancelling, &batch);
        let p = compile_with(&cancelling, OptLevel::Full);
        assert_eq!(p.layers()[0].lane, Lane::I32, "cancelled constants must narrow");
        assert!(report.i32_layers_after > report.i32_layers_before, "{report:?}");
        assert!(p.tables64().is_empty());
    }

    // -- targeted: hash-consing across lanes ------------------------------

    #[test]
    fn dedup_is_per_lane_and_shared_across_layers() {
        // the same table content appears 3x in a wide layer (accumulator
        // overflow forces i64) and 2x in a narrow layer: one slot per arena
        let t: Vec<i64> = (0..8).map(|i| 1_000_000_000 + i).collect(); // fits i32
        let wide = vec![NeuronNet {
            luts: (0..3)
                .map(|p| LutInst { input: p % 2, table: t.clone(), out_width: 31 })
                .collect(),
            bias: 0,
            depth: adder_depth(3, 2),
            sum_width: 33,
        }];
        let narrow = vec![
            NeuronNet {
                luts: vec![LutInst { input: 0, table: t.clone(), out_width: 31 }],
                bias: 0,
                depth: 0,
                sum_width: 31,
            },
        ];
        let net = Netlist {
            name: "cross-lane-dedup".into(),
            layers: vec![
                LayerNet {
                    d_in: 2,
                    d_out: 1,
                    in_bits: 3,
                    out_bits: 3,
                    neurons: wide,
                    requant: Some(Quantizer::new(3, -4.0, 4.0)),
                    depth: 2,
                },
                LayerNet {
                    d_in: 1,
                    d_out: 1,
                    in_bits: 3,
                    out_bits: 8,
                    neurons: narrow,
                    requant: None,
                    depth: 0,
                },
            ],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let batch: Vec<Vec<u32>> = (0..16).map(|i| vec![(i % 8) as u32, (i / 2) as u32]).collect();
        let report = assert_bit_exact(&net, &batch);
        let p = compile_with(&net, OptLevel::Full);
        assert_eq!(p.layers()[0].lane, Lane::I64, "3 x 1e9 overflows i32");
        assert_eq!(p.layers()[1].lane, Lane::I32);
        // one materialization per lane, not per reference
        assert_eq!(p.tables64().len(), t.len(), "wide arena must hold one copy");
        assert_eq!(p.tables32().len(), t.len(), "narrow arena must hold one copy");
        assert_eq!(report.tables_total, 4);
        assert_eq!(report.tables_unique, 2, "one slot per lane: {report:?}");
    }

    // -- targeted: CSE fanout -------------------------------------------

    #[test]
    fn cse_fanout_ordering_and_within_neuron_duplicates() {
        // layer reading input 0 through the same table from three neurons,
        // twice within neuron 0: one op + three fanouts, in op order
        let t: Vec<i64> = (0..8).map(|i| i * 321 - 900).collect();
        let u: Vec<i64> = (0..8).map(|i| 40 - i * 17).collect();
        let neurons = vec![
            NeuronNet {
                luts: vec![
                    LutInst { input: 0, table: t.clone(), out_width: 12 },
                    LutInst { input: 0, table: t.clone(), out_width: 12 },
                ],
                bias: 5,
                depth: adder_depth(2, 2),
                sum_width: 14,
            },
            NeuronNet {
                luts: vec![
                    LutInst { input: 0, table: t.clone(), out_width: 12 },
                    LutInst { input: 1, table: u.clone(), out_width: 12 },
                ],
                bias: -3,
                depth: adder_depth(2, 2),
                sum_width: 14,
            },
            NeuronNet {
                luts: vec![LutInst { input: 0, table: t.clone(), out_width: 12 }],
                bias: 0,
                depth: 0,
                sum_width: 13,
            },
        ];
        let net = Netlist {
            name: "cse-fanout".into(),
            layers: vec![LayerNet {
                d_in: 2,
                d_out: 3,
                in_bits: 3,
                out_bits: 8,
                neurons,
                requant: None,
                depth: 1,
            }],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let batch: Vec<Vec<u32>> = (0..64).map(|i| vec![(i % 8) as u32, (i / 8) as u32]).collect();
        let report = assert_bit_exact(&net, &batch);
        let p = compile_with(&net, OptLevel::Full);
        assert_eq!(p.n_ops(), 2, "5 lookups share 2 (input, table) pairs");
        assert_eq!(report.cse_fanouts, 3);
        assert_eq!(report.tables_unique, 2);
        // fanout entries are sorted by op and in-range, the executor's
        // cursor contract; neuron 0 appears as the shared op's own target
        // AND a fanout (within-neuron duplicate = the value added twice)
        let fans = p.fanouts();
        assert_eq!(fans.len(), 3);
        assert!(fans.windows(2).all(|w| w[0].op <= w[1].op), "{fans:?}");
        let plan = &p.layers()[0];
        assert_eq!(plan.fanout, 0..3);
        let shared = &p.ops()[plan.ops.clone()][fans[0].op as usize];
        assert_eq!(shared.neuron, 0, "first occurrence owns the op");
        assert_eq!(fans.iter().map(|f| f.neuron).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    // -- targeted: dead inputs end to end ---------------------------------

    #[test]
    fn dead_external_inputs_are_compacted_not_rejected() {
        // input 1 of 3 feeds nothing: requests keep width 3, the plane
        // packs 2, the map names the live features
        let t: Vec<i64> = (0..8).map(|i| i * 100 - 350).collect();
        let neurons = vec![NeuronNet {
            luts: vec![
                LutInst { input: 0, table: t.clone(), out_width: 12 },
                LutInst { input: 2, table: t.clone(), out_width: 12 },
            ],
            bias: 0,
            depth: adder_depth(2, 2),
            sum_width: 14,
        }];
        let net = Netlist {
            name: "dead-external".into(),
            layers: vec![LayerNet {
                d_in: 3,
                d_out: 1,
                in_bits: 3,
                out_bits: 8,
                neurons,
                requant: None,
                depth: 1,
            }],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let batch: Vec<Vec<u32>> =
            (0..32).map(|i| vec![(i % 8) as u32, 7 - (i % 8) as u32, (i / 4) as u32]).collect();
        let report = assert_bit_exact(&net, &batch);
        let p = compile_with(&net, OptLevel::Full);
        assert_eq!(p.d_in(), 3, "request width must stay the checkpoint's");
        assert_eq!(p.input_map(), Some(&[0u32, 2][..]));
        assert_eq!(p.layers()[0].d_in, 2, "plane width shrinks to live inputs");
        assert_eq!(report.dead_inputs, 1);
        // the dead feature's value genuinely does not matter
        let a = engine::run_batch(&p, &[vec![3u32, 0, 5]]);
        let b = engine::run_batch(&p, &[vec![3u32, 7, 5]]);
        assert_eq!(a, b);
    }

    #[test]
    fn dead_interior_producer_is_deleted_and_cascades() {
        // layer 1 neuron 1 is read by a constant edge only: folding kills
        // the edge, the sweep deletes the producer, and the producer's own
        // exclusive input column in layer 0 dies with it
        let t: Vec<i64> = (0..8).map(|i| i * 55 - 200).collect();
        let l0 = vec![
            NeuronNet {
                luts: vec![LutInst { input: 0, table: t.clone(), out_width: 12 }],
                bias: 0,
                depth: 0,
                sum_width: 13,
            },
            NeuronNet {
                luts: vec![LutInst { input: 1, table: t.clone(), out_width: 12 }],
                bias: 0,
                depth: 0,
                sum_width: 13,
            },
        ];
        let l1 = vec![NeuronNet {
            luts: vec![
                LutInst { input: 0, table: t.clone(), out_width: 12 },
                LutInst { input: 1, table: vec![77; 8], out_width: 8 }, // constant
            ],
            bias: 0,
            depth: adder_depth(2, 2),
            sum_width: 14,
        }];
        let net = Netlist {
            name: "dead-cascade".into(),
            layers: vec![
                LayerNet {
                    d_in: 2,
                    d_out: 2,
                    in_bits: 3,
                    out_bits: 3,
                    neurons: l0,
                    requant: Some(Quantizer::new(3, -4.0, 4.0)),
                    depth: 0,
                },
                LayerNet {
                    d_in: 2,
                    d_out: 1,
                    in_bits: 3,
                    out_bits: 8,
                    neurons: l1,
                    requant: None,
                    depth: 1,
                },
            ],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let batch: Vec<Vec<u32>> = (0..64).map(|i| vec![(i % 8) as u32, (i / 8) as u32]).collect();
        let report = assert_bit_exact(&net, &batch);
        assert_eq!(report.folded_edges, 1);
        assert_eq!(report.dead_neurons, 1, "layer-0 neuron 1 fed only the folded edge");
        assert_eq!(report.dead_inputs, 1, "external input 1 fed only the dead producer");
        let p = compile_with(&net, OptLevel::Full);
        assert_eq!(p.layers()[0].d_out, 1);
        assert_eq!(p.layers()[1].d_in, 1);
        assert_eq!(p.input_map(), Some(&[0u32][..]));
        assert_eq!(p.n_ops(), 2);
    }

    #[test]
    fn fully_folded_layer_keeps_bias_only_outputs() {
        // every edge of the output layer is constant: the program runs on
        // biases alone and still matches sim
        let l0 = vec![NeuronNet {
            luts: vec![LutInst {
                input: 0,
                table: (0..8).map(|i| i * 9 - 31).collect(),
                out_width: 8,
            }],
            bias: 0,
            depth: 0,
            sum_width: 9,
        }];
        let l1 = vec![
            NeuronNet {
                luts: vec![LutInst { input: 0, table: vec![123; 8], out_width: 8 }],
                bias: 0,
                depth: 0,
                sum_width: 9,
            },
            NeuronNet {
                luts: vec![LutInst { input: 0, table: vec![-45; 8], out_width: 7 }],
                bias: 0,
                depth: 0,
                sum_width: 7,
            },
        ];
        let net = Netlist {
            name: "bias-only".into(),
            layers: vec![
                LayerNet {
                    d_in: 1,
                    d_out: 1,
                    in_bits: 3,
                    out_bits: 3,
                    neurons: l0,
                    requant: Some(Quantizer::new(3, -4.0, 4.0)),
                    depth: 0,
                },
                LayerNet {
                    d_in: 1,
                    d_out: 2,
                    in_bits: 3,
                    out_bits: 8,
                    neurons: l1,
                    requant: None,
                    depth: 0,
                },
            ],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let batch: Vec<Vec<u32>> = (0..8).map(|i| vec![i as u32]).collect();
        let report = assert_bit_exact(&net, &batch);
        assert_eq!(report.folded_edges, 2);
        let p = compile_with(&net, OptLevel::Full);
        assert_eq!(engine::run_batch(&p, &batch), sim::eval_batch(&net, &batch));
        assert_eq!(p.ops().len(), 0, "nothing left to look up");
    }

    // -- report plumbing --------------------------------------------------

    #[test]
    fn none_level_report_is_identity() {
        let ck = synthetic(&[4, 3, 2], &[4, 5, 6], 11);
        let net = net_of(&ck);
        let p = compile_with(&net, OptLevel::None);
        let r = p.opt_report().unwrap();
        assert_eq!(r.level, OptLevel::None);
        assert_eq!(r.ops_before, r.ops_after);
        assert_eq!(r.ops_before, net.n_luts());
        assert_eq!(r.table_bytes_before, r.table_bytes_after);
        assert_eq!(r.op_reduction(), 0.0);
        assert_eq!(r.byte_reduction(), 0.0);
        assert!(!r.summary().is_empty());
    }

    #[test]
    fn optimize_is_idempotent_on_clean_nets() {
        // a net with nothing to optimize compiles to the same geometry at
        // both levels (CSE/dedup may still fire on accidental duplicates,
        // so assert on a handcrafted all-distinct net)
        let t = |s: i64| -> Vec<i64> { (0..8).map(|i| i * 31 + s).collect() };
        let neurons = vec![
            NeuronNet {
                luts: vec![
                    LutInst { input: 0, table: t(1), out_width: 12 },
                    LutInst { input: 1, table: t(2), out_width: 12 },
                ],
                bias: 0,
                depth: adder_depth(2, 2),
                sum_width: 14,
            },
            NeuronNet {
                luts: vec![LutInst { input: 1, table: t(3), out_width: 12 }],
                bias: 0,
                depth: 0,
                sum_width: 13,
            },
        ];
        let net = Netlist {
            name: "clean".into(),
            layers: vec![LayerNet {
                d_in: 2,
                d_out: 2,
                in_bits: 3,
                out_bits: 8,
                neurons,
                requant: None,
                depth: 1,
            }],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let p_none = compile_with(&net, OptLevel::None);
        let p_full = compile_with(&net, OptLevel::Full);
        assert_eq!(p_full.n_ops(), p_none.n_ops());
        assert_eq!(p_full.table_bytes(), p_none.table_bytes());
        assert!(p_full.fanouts().is_empty());
        assert!(p_full.input_map().is_none());
        let r = p_full.opt_report().unwrap();
        assert_eq!(r.folded_edges + r.dead_inputs + r.dead_neurons + r.cse_fanouts, 0);
    }

    // -- lossy tier -------------------------------------------------------

    #[test]
    fn opt_level_parse_accepts_lossy_budgets_and_rejects_malformed() {
        assert_eq!(OptLevel::parse("full"), Some(OptLevel::Full));
        assert_eq!(OptLevel::parse("none"), Some(OptLevel::None));
        assert_eq!(OptLevel::parse("lossy:0"), Some(OptLevel::Lossy(0)));
        assert_eq!(OptLevel::parse("lossy:16"), Some(OptLevel::Lossy(16)));
        for bad in ["lossy", "lossy:", "lossy:x", "lossy:-1", "lossy:1.5", "medium", ""] {
            assert_eq!(OptLevel::parse(bad), None, "{bad:?} must be rejected");
        }
        assert_eq!(OptLevel::Lossy(7).name(), "lossy");
    }

    #[test]
    fn lossy_zero_is_byte_identical_to_full() {
        // the acceptance contract: budget 0 disables every lossy pass, so
        // the program must match a Full compile in every byte of geometry —
        // arenas, ops (scales included), biases, fanouts, lanes, maps
        for seed in [0xACCE55u64, 42, 7] {
            let mut ck = synthetic(&[12, 8, 6, 4], &[5, 4, 4, 6], seed);
            prunify(&mut ck, 35, 25, seed ^ 0xF00);
            nearify(&mut ck, 30, 8, seed ^ 0xBEE);
            let net = net_of(&ck);
            let full = compile_with(&net, OptLevel::Full);
            let zero = compile_with(&net, OptLevel::Lossy(0));
            assert_eq!(full.tables32(), zero.tables32());
            assert_eq!(full.tables64(), zero.tables64());
            assert_eq!(full.ops(), zero.ops());
            assert_eq!(full.biases(), zero.biases());
            assert_eq!(full.fanouts(), zero.fanouts());
            assert_eq!(full.input_map(), zero.input_map());
            assert_eq!(full.d_in(), zero.d_in());
            assert_eq!(full.d_out(), zero.d_out());
            assert_eq!(full.max_width(), zero.max_width());
            assert_eq!(full.layers().len(), zero.layers().len());
            for (a, b) in full.layers().iter().zip(zero.layers()) {
                assert_eq!(a.d_in, b.d_in);
                assert_eq!(a.d_out, b.d_out);
                assert_eq!(a.ops, b.ops);
                assert_eq!(a.bias_off, b.bias_off);
                assert_eq!(a.lane, b.lane);
                assert_eq!(a.fanout, b.fanout);
                assert_eq!(a.requant.is_some(), b.requant.is_some());
            }
            let l = zero.opt_report().unwrap().lossy.as_ref().unwrap();
            assert_eq!(l.budget, 0);
            assert_eq!(l.shared_tables + l.affine_folds + l.tightened_layers, 0);
            assert_eq!(l.worst_case_bound, 0);
            assert_eq!(l.table_bytes_full, l.table_bytes_lossy);
            assert_eq!(l.byte_reduction_vs_full(), 0.0);
        }
    }

    #[test]
    fn epsilon_clustering_shares_near_tables_within_budget() {
        // two tables differing elementwise by <= 6: budget 6 shares one
        // representative (one arena slot), budget 5 must not; the measured
        // output delta never exceeds the reported bound
        let base: Vec<i64> = (0..8).map(|i| i * 400 - 1500).collect();
        let jit = [3i64, -6, 5, 0, 2, -1, 6, -4];
        let near: Vec<i64> = base.iter().zip(jit).map(|(v, j)| v + j).collect();
        let neurons = vec![NeuronNet {
            luts: vec![
                LutInst { input: 0, table: base.clone(), out_width: 12 },
                LutInst { input: 1, table: near.clone(), out_width: 12 },
            ],
            bias: 0,
            depth: adder_depth(2, 2),
            sum_width: 14,
        }];
        let net = Netlist {
            name: "eps-cluster".into(),
            layers: vec![LayerNet {
                d_in: 2,
                d_out: 1,
                in_bits: 3,
                out_bits: 8,
                neurons,
                requant: None,
                depth: 1,
            }],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let full = compile_with(&net, OptLevel::Full);
        let shared = compile_with(&net, OptLevel::Lossy(6));
        assert!(shared.table_bytes() < full.table_bytes());
        let l = shared.opt_report().unwrap().lossy.clone().unwrap();
        assert_eq!(l.shared_tables, 1, "{l:?}");
        assert_eq!(l.shared_eps, 6, "exact max elementwise delta");
        assert_eq!(l.affine_folds, 0);
        assert_eq!(l.worst_case_bound, 6, "one substituted lookup, slack 0");
        assert_eq!(l.table_bytes_full, full.table_bytes());
        assert_eq!(l.table_bytes_lossy, shared.table_bytes());
        // one LSB under the required budget: nothing may merge
        let apart = compile_with(&net, OptLevel::Lossy(5));
        assert_eq!(apart.table_bytes(), full.table_bytes());
        assert_eq!(apart.opt_report().unwrap().lossy.as_ref().unwrap().shared_tables, 0);
        // measured end-to-end delta within the bound
        let batch: Vec<Vec<u32>> =
            (0..64).map(|i| vec![(i % 8) as u32, (i / 8) as u32]).collect();
        let want = engine::run_batch(&full, &batch);
        let got = engine::run_batch(&shared, &batch);
        let worst = want
            .iter()
            .flatten()
            .zip(got.iter().flatten())
            .map(|(a, b)| (a - b).abs())
            .max()
            .unwrap();
        assert!(worst <= l.worst_case_bound, "measured {worst} > bound {}", l.worst_case_bound);
    }

    #[test]
    fn affine_folding_replaces_scaled_tables_exactly() {
        // t2 = 3*t1 + 7 exactly: even budget 1 folds it (residual 0) —
        // scale 3 on the op, +7 into the bias, outputs bit-exact with sim
        let t1: Vec<i64> = (0..8).map(|i| i * 123 - 400).collect();
        let t2: Vec<i64> = t1.iter().map(|v| 3 * v + 7).collect();
        let neurons = vec![
            NeuronNet {
                luts: vec![LutInst { input: 0, table: t1.clone(), out_width: 12 }],
                bias: 1,
                depth: 0,
                sum_width: 13,
            },
            NeuronNet {
                luts: vec![LutInst { input: 1, table: t2.clone(), out_width: 13 }],
                bias: -2,
                depth: 0,
                sum_width: 14,
            },
        ];
        let net = Netlist {
            name: "affine-fold".into(),
            layers: vec![LayerNet {
                d_in: 2,
                d_out: 2,
                in_bits: 3,
                out_bits: 8,
                neurons,
                requant: None,
                depth: 1,
            }],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let full = compile_with(&net, OptLevel::Full);
        let lossy = compile_with(&net, OptLevel::Lossy(1));
        let l = lossy.opt_report().unwrap().lossy.clone().unwrap();
        assert_eq!(l.affine_folds, 1, "{l:?}");
        assert_eq!(l.affine_eps, 0, "the pair is exactly affine");
        assert_eq!(l.worst_case_bound, 0);
        assert!(lossy.table_bytes() < full.table_bytes());
        assert!(lossy.ops().iter().any(|o| o.scale == 3), "{:?}", lossy.ops());
        assert_eq!(lossy.biases()[1], -2 + 7, "intercept folds into the bias");
        let batch: Vec<Vec<u32>> =
            (0..64).map(|i| vec![(i % 8) as u32, (i / 8) as u32]).collect();
        assert_eq!(engine::run_batch(&lossy, &batch), sim::eval_batch(&net, &batch));
        assert_eq!(engine::run_batch(&full, &batch), sim::eval_batch(&net, &batch));
    }

    #[test]
    fn requant_tightening_narrows_unreachable_wide_entries() {
        // layer 0 requants to 2-bit codes (4 levels); layer 1's 8-entry
        // table hides a 2^40 entry at address 5 — unreachable. Full prices
        // the whole table and keeps i64; Lossy(1) prices codes < 4 only
        // and narrows, staying bit-exact (no substitution fires)
        let l0 = vec![NeuronNet {
            luts: vec![LutInst {
                input: 0,
                table: (0..8).map(|i| i * 9 - 31).collect(),
                out_width: 8,
            }],
            bias: 0,
            depth: 0,
            sum_width: 9,
        }];
        let mut wild: Vec<i64> = (0..8).map(|i| i * 100 - 350).collect();
        wild[5] = 1 << 40;
        let l1 = vec![NeuronNet {
            luts: vec![LutInst { input: 0, table: wild, out_width: 42 }],
            bias: 0,
            depth: 0,
            sum_width: 43,
        }];
        let net = Netlist {
            name: "tighten".into(),
            layers: vec![
                LayerNet {
                    d_in: 1,
                    d_out: 1,
                    in_bits: 3,
                    out_bits: 2,
                    neurons: l0,
                    requant: Some(Quantizer::new(2, -4.0, 4.0)),
                    depth: 0,
                },
                LayerNet {
                    d_in: 1,
                    d_out: 1,
                    in_bits: 3,
                    out_bits: 8,
                    neurons: l1,
                    requant: None,
                    depth: 0,
                },
            ],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let full = compile_with(&net, OptLevel::Full);
        let lossy = compile_with(&net, OptLevel::Lossy(1));
        assert_eq!(full.layers()[1].lane, Lane::I64);
        assert_eq!(lossy.layers()[1].lane, Lane::I32, "unreachable entry must not widen");
        let l = lossy.opt_report().unwrap().lossy.clone().unwrap();
        assert_eq!(l.tightened_layers, 1, "{l:?}");
        assert_eq!(l.worst_case_bound, 0, "tightening is exact");
        assert!(lossy.table_bytes() < full.table_bytes());
        let batch: Vec<Vec<u32>> = (0..8).map(|i| vec![i as u32]).collect();
        let want = sim::eval_batch(&net, &batch);
        assert_eq!(engine::run_batch(&full, &batch), want);
        assert_eq!(engine::run_batch(&lossy, &batch), want);
    }

    #[test]
    fn prop_lossy_budgets_monotone_and_within_bound() {
        // random prunified + nearified checkpoints, budgets 0 < b1 < b2:
        // bytes never grow with the budget, Lossy(0) == Full on outputs,
        // and the measured end-to-end delta respects the composed bound
        prop::check("lossy-budget-sound", 20, |g| {
            let n_layers = g.usize_in(1, 3);
            let mut dims = vec![g.usize_in(2, 6)];
            let mut bits = vec![g.usize_in(2, 5) as u32];
            for _ in 0..n_layers {
                dims.push(g.usize_in(1, 6));
                bits.push(g.usize_in(2, 6) as u32);
            }
            let seed = g.rng().next_u64();
            let mut ck = synthetic(&dims, &bits, seed);
            prunify(&mut ck, g.usize_in(0, 40), g.usize_in(0, 30), seed ^ 0xD1CE);
            nearify(&mut ck, g.usize_in(0, 70), g.usize_in(1, 12) as i64, seed ^ 0xA11);
            let net = net_of(&ck);
            let full = compile_with(&net, OptLevel::Full);
            let n = g.usize_in(1, 24);
            let batch: Vec<Vec<u32>> = (0..n)
                .map(|_| {
                    (0..dims[0]).map(|_| g.rng().below(1u64 << bits[0]) as u32).collect()
                })
                .collect();
            let want = engine::run_batch(&full, &batch);
            let budgets =
                [0u32, g.usize_in(1, 8) as u32, g.usize_in(16, 48) as u32];
            let mut prev_bytes = usize::MAX;
            for &b in &budgets {
                let p = compile_with(&net, OptLevel::Lossy(b));
                if p.table_bytes() > full.table_bytes() {
                    return Err(format!("budget {b} grew the arena (dims {dims:?} seed {seed})"));
                }
                if p.table_bytes() > prev_bytes {
                    return Err(format!(
                        "bytes not monotone at budget {b} (dims {dims:?} seed {seed})"
                    ));
                }
                prev_bytes = p.table_bytes();
                let l = p.opt_report().unwrap().lossy.clone().unwrap();
                let got = engine::run_batch(&p, &batch);
                if b == 0 && got != want {
                    return Err(format!("Lossy(0) != Full (dims {dims:?} seed {seed})"));
                }
                let worst = want
                    .iter()
                    .flatten()
                    .zip(got.iter().flatten())
                    .map(|(x, y)| (x - y).abs())
                    .max()
                    .unwrap_or(0);
                if worst > l.worst_case_bound {
                    return Err(format!(
                        "measured delta {worst} > bound {} at budget {b} (dims {dims:?} seed {seed})",
                        l.worst_case_bound
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn eliminate_dead_uses_dead_inputs_oracle() {
        // the pass's result agrees with Netlist::dead_inputs before/after:
        // afterwards no layer reports any dead input
        let mut ck = synthetic(&[6, 5, 4, 2], &[3, 4, 4, 6], 77);
        prunify(&mut ck, 50, 0, 5);
        let net = net_of(&ck);
        let mut work = net.clone();
        netopt::optimize(&mut work);
        let before: usize = (0..work.layers.len()).map(|l| work.dead_inputs(l).len()).sum();
        let (dead_ext, dead_neurons, map) = eliminate_dead(&mut work);
        for l in 0..work.layers.len() {
            assert!(work.dead_inputs(l).is_empty(), "layer {l} still has dead inputs");
        }
        // interface consistency after renumbering
        for w in work.layers.windows(2) {
            assert_eq!(w[0].d_out, w[1].d_in);
        }
        if let Some(map) = &map {
            assert_eq!(map.len(), work.layers[0].d_in);
            assert!(map.windows(2).all(|w| w[0] < w[1]), "map must stay sorted");
        }
        assert!(
            dead_ext + dead_neurons >= before.min(1),
            "a net with dead inputs must report elimination work"
        );
    }
}
