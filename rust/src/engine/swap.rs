//! Hot-swap bridge: keep a compiled program in lockstep with a swappable
//! netlist.
//!
//! [`crate::netlist::hotswap::NetlistCell`] stays the single source of
//! truth for online updates (edge-table swaps, whole-model replacement);
//! the [`ProgramCell`] layers a compiled-program cache on top. Readers get
//! a *consistent* `(netlist, program)` snapshot pair; the first reader
//! after a swap pays the recompile — O(total table entries) for the arena
//! repack plus the per-layer range analysis, and one bisection per code
//! boundary to rebuild the integer requant plans; still well under a
//! millisecond for paper-scale netlists — and publishes it atomically for
//! everyone else.

use std::sync::{Arc, RwLock};

use crate::netlist::hotswap::NetlistCell;
use crate::netlist::Netlist;

use super::program::CompiledProgram;

/// Swappable compiled-program handle, derived from a [`NetlistCell`].
pub struct ProgramCell {
    source: Arc<NetlistCell>,
    /// The netlist snapshot the cached program was compiled from, plus the
    /// program itself. Pointer equality against `source.load()` detects
    /// staleness exactly (every swap publishes a fresh `Arc`). RwLock so
    /// the steady state (no swap) is a shared read, same as the netlist
    /// cell itself.
    cached: RwLock<(Arc<Netlist>, Arc<CompiledProgram>)>,
}

impl ProgramCell {
    /// Wrap a netlist cell, compiling its current snapshot eagerly.
    pub fn new(source: Arc<NetlistCell>) -> ProgramCell {
        let net = source.load();
        let prog = Arc::new(CompiledProgram::compile(&net));
        ProgramCell { source, cached: RwLock::new((net, prog)) }
    }

    /// The underlying swappable netlist handle.
    pub fn source(&self) -> &Arc<NetlistCell> {
        &self.source
    }

    /// Consistent `(netlist, program)` snapshot; recompiles if and only if
    /// the netlist changed since the last load. In-flight batches keep the
    /// pair they loaded — exactly the PR-region semantics of the netlist
    /// cell itself.
    pub fn load(&self) -> (Arc<Netlist>, Arc<CompiledProgram>) {
        let net = self.source.load();
        {
            let cached = self.cached.read().unwrap();
            if Arc::ptr_eq(&cached.0, &net) {
                return (net, Arc::clone(&cached.1));
            }
        }
        let mut cached = self.cached.write().unwrap();
        // Re-check under the write lock against the *current* source
        // snapshot: another thread may have recompiled already, and a
        // concurrent swap may have superseded the `net` we read above —
        // never regress the cache to an older snapshot.
        let net = self.source.load();
        if !Arc::ptr_eq(&cached.0, &net) {
            *cached = (Arc::clone(&net), Arc::new(CompiledProgram::compile(&net)));
        }
        (Arc::clone(&cached.0), Arc::clone(&cached.1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::engine;
    use crate::lut;
    use crate::sim;

    fn cell(seed: u64) -> (u32, Arc<NetlistCell>) {
        let ck = synthetic(&[3, 2], &[3, 6], seed);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        (ck.bits[0], Arc::new(NetlistCell::new(Arc::new(net))))
    }

    #[test]
    fn load_is_cached_until_swap() {
        let (_, nc) = cell(5);
        let pc = ProgramCell::new(Arc::clone(&nc));
        let (n1, p1) = pc.load();
        let (n2, p2) = pc.load();
        assert!(Arc::ptr_eq(&n1, &n2));
        assert!(Arc::ptr_eq(&p1, &p2));
    }

    #[test]
    fn swap_recompiles_and_matches_new_netlist() {
        let (bits, nc) = cell(6);
        let pc = ProgramCell::new(Arc::clone(&nc));
        let (_, before) = pc.load();
        // first neuron that still has an active edge (synthetic pruning is
        // random, so neuron 0 may have none)
        let (q, p) = nc.load().layers[0]
            .neurons
            .iter()
            .enumerate()
            .find_map(|(q, n)| n.luts.first().map(|l| (q, l.input)))
            .expect("at least one active edge");
        nc.swap_edge(0, q, p, vec![424_242; 1usize << bits]).unwrap();
        let (net_after, after) = pc.load();
        let codes = vec![vec![0u32, 1, 2]];
        let want = sim::eval_batch(&net_after, &codes);
        assert_eq!(engine::run_batch(&after, &codes), want);
        // old program still reflects the old tables (snapshot semantics)
        assert_ne!(engine::run_batch(&before, &codes), want);
    }

    #[test]
    fn whole_model_replace_recompiles() {
        let (_, nc) = cell(7);
        let pc = ProgramCell::new(Arc::clone(&nc));
        let (_, p1) = pc.load();
        let ck2 = synthetic(&[3, 4, 2], &[3, 4, 6], 99);
        let tables2 = lut::from_checkpoint(&ck2);
        let net2 = Arc::new(Netlist::build(&ck2, &tables2, 2));
        nc.replace(Arc::clone(&net2));
        let (nl, p2) = pc.load();
        assert!(Arc::ptr_eq(&nl, &net2));
        assert!(!Arc::ptr_eq(&p1, &p2));
        assert_eq!(p2.layers().len(), 2);
        let inputs = vec![vec![1u32, 2, 3], vec![0, 0, 0]];
        assert_eq!(engine::run_batch(&p2, &inputs), sim::eval_batch(&net2, &inputs));
    }
}
