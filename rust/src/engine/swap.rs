//! Hot-swap bridge: keep a compiled program in lockstep with a swappable
//! netlist.
//!
//! [`crate::netlist::hotswap::NetlistCell`] stays the single source of
//! truth for online updates (edge-table swaps, whole-model replacement);
//! the [`ProgramCell`] layers a compiled-program cache on top. Readers get
//! a *consistent* `(netlist, program)` snapshot pair; the first reader
//! after a swap pays the recompile — O(total table entries) for the arena
//! repack plus the per-layer range analysis, and one bisection per code
//! boundary to rebuild the integer requant plans; still well under a
//! millisecond for paper-scale netlists — and publishes it atomically for
//! everyone else.

use std::sync::{Arc, RwLock};

use crate::netlist::hotswap::NetlistCell;
use crate::netlist::Netlist;

use super::optim::OptLevel;
use super::program::CompiledProgram;

/// Swappable compiled-program handle, derived from a [`NetlistCell`].
pub struct ProgramCell {
    source: Arc<NetlistCell>,
    /// Pass-pipeline level every (re)compile runs at — fixed at
    /// construction so a hot-swap can never silently change the lowering
    /// an A/B measurement depends on.
    level: OptLevel,
    /// The netlist snapshot the cached program was compiled from, plus the
    /// program itself. Pointer equality against `source.load()` detects
    /// staleness exactly (every swap publishes a fresh `Arc`). RwLock so
    /// the steady state (no swap) is a shared read, same as the netlist
    /// cell itself.
    cached: RwLock<(Arc<Netlist>, Arc<CompiledProgram>)>,
}

impl ProgramCell {
    /// Wrap a netlist cell, compiling its current snapshot eagerly at the
    /// default (optimizing) level.
    pub fn new(source: Arc<NetlistCell>) -> ProgramCell {
        Self::with_level(source, OptLevel::default())
    }

    /// Wrap a netlist cell at an explicit [`OptLevel`] (recompiles after
    /// hot-swaps stay at this level).
    pub fn with_level(source: Arc<NetlistCell>, level: OptLevel) -> ProgramCell {
        let net = source.load();
        let prog = Arc::new(CompiledProgram::compile_opt(&net, level));
        ProgramCell { source, level, cached: RwLock::new((net, prog)) }
    }

    /// The pass-pipeline level this cell compiles at.
    pub fn level(&self) -> OptLevel {
        self.level
    }

    /// The underlying swappable netlist handle.
    pub fn source(&self) -> &Arc<NetlistCell> {
        &self.source
    }

    /// Consistent `(netlist, program)` snapshot; recompiles if and only if
    /// the netlist changed since the last load. In-flight batches keep the
    /// pair they loaded — exactly the PR-region semantics of the netlist
    /// cell itself.
    pub fn load(&self) -> (Arc<Netlist>, Arc<CompiledProgram>) {
        let net = self.source.load();
        {
            let cached = self.cached.read().unwrap();
            if Arc::ptr_eq(&cached.0, &net) {
                return (net, Arc::clone(&cached.1));
            }
        }
        let mut cached = self.cached.write().unwrap();
        // Re-check under the write lock against the *current* source
        // snapshot: another thread may have recompiled already, and a
        // concurrent swap may have superseded the `net` we read above —
        // never regress the cache to an older snapshot.
        let net = self.source.load();
        if !Arc::ptr_eq(&cached.0, &net) {
            *cached =
                (Arc::clone(&net), Arc::new(CompiledProgram::compile_opt(&net, self.level)));
        }
        (Arc::clone(&cached.0), Arc::clone(&cached.1))
    }

    /// Publish an externally prepared `(netlist, program)` pair — the model
    /// registry's cross-tenant interning pass rewrites programs to address
    /// a shared arena and installs them here. Caller's contract: `prog` is
    /// bit-exact with `net` at this cell's level (interning only relocates
    /// tables). Staleness detection is unaffected: if `net` is not the
    /// source's current snapshot (a swap raced the install), the next
    /// [`ProgramCell::load`] recompiles privately as usual.
    pub fn install(&self, net: Arc<Netlist>, prog: Arc<CompiledProgram>) {
        *self.cached.write().unwrap() = (net, prog);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::engine;
    use crate::lut;
    use crate::sim;

    fn cell(seed: u64) -> (u32, Arc<NetlistCell>) {
        let ck = synthetic(&[3, 2], &[3, 6], seed);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        (ck.bits[0], Arc::new(NetlistCell::new(Arc::new(net))))
    }

    #[test]
    fn load_is_cached_until_swap() {
        let (_, nc) = cell(5);
        let pc = ProgramCell::new(Arc::clone(&nc));
        let (n1, p1) = pc.load();
        let (n2, p2) = pc.load();
        assert!(Arc::ptr_eq(&n1, &n2));
        assert!(Arc::ptr_eq(&p1, &p2));
    }

    #[test]
    fn swap_recompiles_and_matches_new_netlist() {
        let (bits, nc) = cell(6);
        let pc = ProgramCell::new(Arc::clone(&nc));
        let (_, before) = pc.load();
        // first neuron that still has an active edge (synthetic pruning is
        // random, so neuron 0 may have none)
        let (q, p) = nc.load().layers[0]
            .neurons
            .iter()
            .enumerate()
            .find_map(|(q, n)| n.luts.first().map(|l| (q, l.input)))
            .expect("at least one active edge");
        nc.swap_edge(0, q, p, vec![424_242; 1usize << bits]).unwrap();
        let (net_after, after) = pc.load();
        let codes = vec![vec![0u32, 1, 2]];
        let want = sim::eval_batch(&net_after, &codes);
        assert_eq!(engine::run_batch(&after, &codes), want);
        // old program still reflects the old tables (snapshot semantics)
        assert_ne!(engine::run_batch(&before, &codes), want);
    }

    #[test]
    fn recompile_after_swap_keeps_the_cell_level() {
        use crate::engine::OptLevel;
        let (bits, nc) = cell(8);
        let full = ProgramCell::new(Arc::clone(&nc));
        let none = ProgramCell::with_level(Arc::clone(&nc), OptLevel::None);
        assert_eq!(full.level(), OptLevel::Full);
        assert_eq!(none.level(), OptLevel::None);
        assert_eq!(full.load().1.opt_report().unwrap().level, OptLevel::Full);
        assert_eq!(none.load().1.opt_report().unwrap().level, OptLevel::None);
        // a hot-swap to a CONSTANT table: the Full cell folds it away, the
        // None cell keeps it — and both still match the swapped netlist
        let (q, p) = nc.load().layers[0]
            .neurons
            .iter()
            .enumerate()
            .find_map(|(q, n)| n.luts.first().map(|l| (q, l.input)))
            .expect("at least one active edge");
        nc.swap_edge(0, q, p, vec![31_415; 1usize << bits]).unwrap();
        let (net_f, pf) = full.load();
        let (_, pn) = none.load();
        assert_eq!(pf.opt_report().unwrap().level, OptLevel::Full);
        assert!(pf.opt_report().unwrap().folded_edges >= 1, "constant swap must fold");
        assert_eq!(pn.opt_report().unwrap().folded_edges, 0);
        assert!(pf.n_ops() < pn.n_ops());
        let codes = vec![vec![0u32, 1, 2], vec![2, 0, 1]];
        let want = sim::eval_batch(&net_f, &codes);
        assert_eq!(engine::run_batch(&pf, &codes), want);
        assert_eq!(engine::run_batch(&pn, &codes), want);
    }

    #[test]
    fn install_publishes_until_next_swap() {
        let (bits, nc) = cell(9);
        let pc = ProgramCell::new(Arc::clone(&nc));
        let (net, prog) = pc.load();
        let (interned, _) = engine::intern_tables(&[&prog]);
        let interned = Arc::new(interned.into_iter().next().unwrap());
        pc.install(Arc::clone(&net), Arc::clone(&interned));
        assert!(Arc::ptr_eq(&pc.load().1, &interned), "install published the pair");
        // a later swap supersedes the installed program: load recompiles
        let (q, p) = nc.load().layers[0]
            .neurons
            .iter()
            .enumerate()
            .find_map(|(q, n)| n.luts.first().map(|l| (q, l.input)))
            .expect("at least one active edge");
        nc.swap_edge(0, q, p, vec![123_456; 1usize << bits]).unwrap();
        let (net2, p2) = pc.load();
        assert!(!Arc::ptr_eq(&p2, &interned));
        let codes = vec![vec![0u32, 1, 2]];
        assert_eq!(engine::run_batch(&p2, &codes), sim::eval_batch(&net2, &codes));
    }

    #[test]
    fn lossy_cells_survive_a_swap_storm_at_their_pinned_level() {
        // a storm of edge swaps against cells pinned at Lossy budgets:
        // every recompile stays at the pinned level, carries a LossyReport,
        // honors its own worst-case bound vs an exact lowering of the SAME
        // snapshot, and the zero-budget cell stays byte-identical to Full
        use crate::checkpoint::testutil::{nearify, prunify};
        use crate::engine::OptLevel;
        let mut ck = synthetic(&[4, 3, 2], &[3, 4, 6], 10);
        prunify(&mut ck, 30, 20, 0xBAD);
        nearify(&mut ck, 50, 4, 0x5EED);
        let bits = ck.bits[0];
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        let nc = Arc::new(NetlistCell::new(Arc::new(net)));
        let lossy = ProgramCell::with_level(Arc::clone(&nc), OptLevel::Lossy(8));
        let zero = ProgramCell::with_level(Arc::clone(&nc), OptLevel::Lossy(0));
        assert_eq!(lossy.level(), OptLevel::Lossy(8));
        let codes: Vec<Vec<u32>> = (0..16u32)
            .map(|i| vec![i % 8, (i * 3) % 8, (i * 5 + 1) % 8, (i * 7 + 2) % 8])
            .collect();
        for round in 0..6i64 {
            let (q, p) = nc.load().layers[0]
                .neurons
                .iter()
                .enumerate()
                .find_map(|(q, n)| n.luts.first().map(|l| (q, l.input)))
                .expect("at least one active edge");
            let fresh: Vec<i64> =
                (0..1i64 << bits).map(|c| c * 31 + round * 17 - 99).collect();
            nc.swap_edge(0, q, p, fresh).unwrap();
            let (net_now, pl) = lossy.load();
            let rep = pl.opt_report().unwrap();
            assert_eq!(rep.level, OptLevel::Lossy(8), "pinned level must survive the swap");
            let l = rep.lossy.as_ref().expect("lossy report rides the recompile");
            let exact = engine::compile_with(&net_now, OptLevel::Full);
            let want = engine::run_batch(&exact, &codes);
            let got = engine::run_batch(&pl, &codes);
            let worst = want
                .iter()
                .flatten()
                .zip(got.iter().flatten())
                .map(|(a, b)| (a - b).abs())
                .max()
                .unwrap();
            assert!(
                worst <= l.worst_case_bound,
                "round {round}: measured {worst} > bound {}",
                l.worst_case_bound
            );
            let (_, pz) = zero.load();
            assert_eq!(pz.opt_report().unwrap().level, OptLevel::Lossy(0));
            assert_eq!(pz.tables32(), exact.tables32());
            assert_eq!(pz.tables64(), exact.tables64());
            assert_eq!(pz.ops(), exact.ops());
            assert_eq!(engine::run_batch(&pz, &codes), want);
        }
    }

    #[test]
    fn whole_model_replace_recompiles() {
        let (_, nc) = cell(7);
        let pc = ProgramCell::new(Arc::clone(&nc));
        let (_, p1) = pc.load();
        let ck2 = synthetic(&[3, 4, 2], &[3, 4, 6], 99);
        let tables2 = lut::from_checkpoint(&ck2);
        let net2 = Arc::new(Netlist::build(&ck2, &tables2, 2));
        nc.replace(Arc::clone(&net2));
        let (nl, p2) = pc.load();
        assert!(Arc::ptr_eq(&nl, &net2));
        assert!(!Arc::ptr_eq(&p1, &p2));
        assert_eq!(p2.layers().len(), 2);
        let inputs = vec![vec![1u32, 2, 3], vec![0, 0, 0]];
        assert_eq!(engine::run_batch(&p2, &inputs), sim::eval_batch(&net2, &inputs));
    }
}
