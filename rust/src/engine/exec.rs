//! Feature-major, integer-only batch execution of a [`CompiledProgram`].
//!
//! The interpreter ([`crate::sim::Evaluator`]) advances one *sample* at a
//! time, re-walking the whole structure per request. The executor inverts
//! the loops: every fused op runs across all N samples of the batch before
//! the next op is touched, so each truth table is streamed through exactly
//! once per batch and the per-op bookkeeping (offset, mask, indices)
//! amortizes over N samples.
//!
//! **Layout contract (feature-major planes).** Scratch planes are stored
//! transposed: `plane[feature * n + sample]`, where `n` is the current
//! batch size. An op reading input `i` and accumulating into neuron `q`
//! therefore touches exactly two *contiguous* runs of `n` words — the old
//! sample-major layout (`plane[sample * width + feature]`) strided both
//! accesses by the layer width, defeating prefetch and auto-vectorization.
//! Requests are transposed in at batch entry and the final sums transposed
//! out at batch exit; everything in between is sequential.
//!
//! **Chunked kernels.** Every width-`n` pass runs through the fixed-width
//! kernels of [`super::kernels`]: runs are processed in
//! [`super::kernels::CHUNK`]-sample chunks with a scalar tail, and the
//! per-layer loop is monomorphized over the two accumulator lanes via the
//! `LaneKernel` trait (plain chunked loops that stable rustc
//! autovectorizes by default; `std::simd` bodies behind the nightly-only
//! `simd` cargo feature). Chunking regroups samples, never the per-sample
//! order of adds, so the output is bit-identical to the one-element
//! reference loop — which survives verbatim as [`scalar_ref`], the frozen
//! A/B baseline and test oracle. The same sample independence makes the
//! planes *sample-sliceable*: the coordinator fans grain-sized sample
//! ranges of one large batch across its executor pool and stitches the
//! per-slice planes back in order (`ServiceCfg::parallel_grain`), again
//! byte-for-byte equal to the single-thread run.
//!
//! **Integer requant contract.** The inter-layer flip applies the layer's
//! [`RequantPlan`] over the whole sum plane
//! ([`RequantPlan::encode_plane`]: fixed-point multiply/shift or threshold
//! search, with the plan-kind dispatch hoisted out of the loop), which is
//! bit-exact with the float oracle `Quantizer::encode_fixed` by
//! construction — so the hot path performs no floating-point arithmetic
//! for any paper-scale program (code widths `<=`
//! [`super::program::PLAN_MAX_BITS`]).
//!
//! **Lanes.** Each layer runs in the scratch lane its compile-time range
//! analysis proved safe: i32 planes and tables where no partial sum can
//! overflow, i64 otherwise ([`super::program::Lane`]).
//!
//! **Optimized programs.** Programs lowered at
//! [`super::optim::OptLevel::Full`] additionally carry CSE fanout lists
//! (one gather feeding several accumulators — see [`FanOut`]) and an
//! optional input map (dead external features are accepted in the request
//! row but never packed into the plane). Both are handled here; 1:1
//! programs pay one cursor compare per op and an identity pack. Programs
//! lowered at `OptLevel::Lossy` may further carry affine-folded ops
//! (`LutOp::scale != 1`): the gather multiplies by the compile-time scale
//! before accumulating (`gather_mul_add` / `scale_run` kernels), with the
//! intercept already folded into the bias and the products proven in-lane
//! by the compiler's range analysis.
//!
//! **Scratch growth.** Planes are grown (never shrunk) to
//! `batch x max_width` on demand: the first batch of a new largest size
//! allocates, every later batch of any smaller size reuses the same
//! capacity, so the serving hot path settles to zero allocation. The
//! current footprint is observable via [`Executor::scratch_bytes`] (the
//! `kanele serve` stats line reports the max across executors).

use super::kernels::{LaneKernel, CHUNK};
use super::program::{CompiledProgram, FanOut, Lane, LutOp};

/// Reusable batch executor: owns the feature-major scratch planes.
///
/// Independent of any particular program (scratch grows to the largest
/// `batch x max_width` seen and never shrinks), so one executor per worker
/// thread serves across hot-swaps.
#[derive(Default)]
pub struct Executor {
    /// Code plane, feature-major (`codes[f * n + s]` = feature `f` of
    /// sample `s`): the current layer's inputs.
    codes: Vec<u32>,
    /// Narrow accumulator plane (layers whose range analysis fits i32).
    sums32: Vec<i32>,
    /// Wide accumulator plane (exact fallback lane).
    sums64: Vec<i64>,
}

/// One layer over the whole batch: seed biases, then stream the op slice.
/// Every op reads `codes[input*n..][..n]` and writes `sums[neuron*n..][..n]`
/// — two contiguous runs; the table gather stays in cache (tables are
/// `2^bits` entries). Both runs go through the chunked [`LaneKernel`]
/// bodies ([`CHUNK`]-sample chunks, scalar tail).
///
/// `fanouts` is the layer's CSE fanout slice, sorted by op index: an op
/// with fanout entries gathers each chunk of its code run **once** into a
/// stack temporary and adds it to its own accumulator plus every extra
/// destination — k chunk-adds per gather instead of k gathers (a
/// within-neuron duplicate simply adds twice). Per (sample, neuron) pair
/// the gathered value lands in the same op order as the scalar loop, so
/// the integer sums are bit-identical. The 1:1 lowering has no fanouts,
/// and its hot loop's only extra cost is one cursor compare per op.
fn run_layer<T: LaneKernel>(
    ops: &[LutOp],
    fanouts: &[FanOut],
    tables: &[T],
    biases: &[i64],
    codes: &[u32],
    sums: &mut [T],
    n: usize,
) {
    for (q, &bias) in biases.iter().enumerate() {
        T::fill_run(&mut sums[q * n..(q + 1) * n], bias);
    }
    let mut fi = 0usize;
    for (i, op) in ops.iter().enumerate() {
        let off = op.table_off as usize;
        let mask = op.addr_mask;
        let table = &tables[off..off + mask as usize + 1];
        let src = &codes[op.input as usize * n..][..n];
        let start = fi;
        while fi < fanouts.len() && fanouts[fi].op as usize == i {
            fi += 1;
        }
        if start == fi {
            // hot path: single destination, two contiguous runs. Lossy
            // affine-folded ops (scale != 1, see `LutOp::scale`) take the
            // multiply-accumulate kernel; the compiler proved the products
            // fit the layer's lane, so the in-lane multiply cannot wrap.
            let dst = &mut sums[op.neuron as usize * n..][..n];
            if op.scale == 1 {
                T::gather_add(table, mask, src, dst);
            } else {
                T::gather_mul_add(table, mask, src, dst, T::from_i64(op.scale as i64));
            }
        } else {
            // CSE fanout: gather each chunk once (scaling in place for
            // affine-folded ops — every destination of a group shares one
            // scale by construction), then re-add the temporary into the
            // op's own run and every extra destination
            let extra = &fanouts[start..fi];
            let own = op.neuron as usize * n;
            let mut g = [T::ZERO; CHUNK];
            let mut at = 0usize;
            while at < n {
                let len = CHUNK.min(n - at);
                let g = &mut g[..len];
                T::gather(table, mask, &src[at..at + len], g);
                if op.scale != 1 {
                    T::scale_run(g, T::from_i64(op.scale as i64));
                }
                T::add_run(&mut sums[own + at..own + at + len], g);
                for f in extra {
                    let base = f.neuron as usize * n + at;
                    T::add_run(&mut sums[base..base + len], g);
                }
                at += len;
            }
        }
    }
    debug_assert_eq!(fi, fanouts.len(), "fanout entries must map onto layer ops in order");
}

impl Executor {
    pub fn new() -> Executor {
        Executor::default()
    }

    /// Preallocate scratch for batches up to `batch` samples of `prog`
    /// (only the lanes `prog` actually uses).
    pub fn with_capacity(prog: &CompiledProgram, batch: usize) -> Executor {
        let mut ex = Executor::default();
        let words = batch * prog.max_width();
        ex.codes.reserve(words);
        if prog.uses_i32() {
            ex.sums32.reserve(words);
        }
        if prog.uses_i64() {
            ex.sums64.reserve(words);
        }
        ex
    }

    /// Current scratch footprint in bytes (plane capacities). Monotone
    /// nondecreasing across the executor's life: planes grow to the largest
    /// `batch x max_width` seen and are never shrunk, so this number
    /// stabilizes after the first largest batch — the serving hot path
    /// allocates nothing after that point.
    pub fn scratch_bytes(&self) -> usize {
        self.codes.capacity() * std::mem::size_of::<u32>()
            + self.sums32.capacity() * std::mem::size_of::<i32>()
            + self.sums64.capacity() * std::mem::size_of::<i64>()
    }

    /// Run every sample of `batch` through the program, writing the flat
    /// sample-major output plane (`out[s * d_out + q]`) into the
    /// caller-owned buffer: `out` is cleared and refilled, so a reused
    /// buffer makes the whole call allocation-free at steady state.
    /// Bit-exact with [`crate::sim::eval`] per sample.
    ///
    /// Every row must be exactly `prog.d_in()` codes wide (panics
    /// otherwise — in a feature-major plane a wrong-width row would shift
    /// every later sample; the coordinator validates widths at admission).
    pub fn run_batch_into<S: AsRef<[u32]>>(
        &mut self,
        prog: &CompiledProgram,
        batch: &[S],
        out: &mut Vec<i64>,
    ) {
        out.clear();
        let n = batch.len();
        let d_out = prog.d_out();
        if n == 0 || d_out == 0 {
            return;
        }
        // grow-only scratch: planes keep the largest length ever needed, so
        // a new largest batch pays one grow and every other batch pays
        // nothing — no per-batch zeroing (every word the layer loop reads
        // is written first: packed inputs, bias-seeded sums, requant codes)
        let words = n * prog.max_width();
        if self.codes.len() < words {
            self.codes.resize(words, 0);
        }
        if prog.uses_i32() && self.sums32.len() < words {
            self.sums32.resize(words, 0);
        }
        if prog.uses_i64() && self.sums64.len() < words {
            self.sums64.resize(words, 0);
        }

        // pack: transpose request rows into the feature-major code plane
        // (the only strided writes of the whole batch). Optimized programs
        // may carry an input map: dead external features stay in the
        // request width but get no plane slot.
        let d0 = prog.d_in();
        match prog.input_map() {
            None => {
                for (s, row) in batch.iter().enumerate() {
                    let row = row.as_ref();
                    assert_eq!(row.len(), d0, "batch row width != program d_in");
                    for (f, &code) in row.iter().enumerate() {
                        self.codes[f * n + s] = code;
                    }
                }
            }
            Some(map) => {
                for (s, row) in batch.iter().enumerate() {
                    let row = row.as_ref();
                    assert_eq!(row.len(), d0, "batch row width != program d_in");
                    for (i, &f) in map.iter().enumerate() {
                        self.codes[i * n + s] = row[f as usize];
                    }
                }
            }
        }

        let ops = prog.ops();
        let fanouts = prog.fanouts();
        for plan in prog.layers() {
            let biases = &prog.biases()[plan.bias_off..plan.bias_off + plan.d_out];
            let layer_ops = &ops[plan.ops.clone()];
            let layer_fan = &fanouts[plan.fanout.clone()];
            match plan.lane {
                Lane::I32 => run_layer(
                    layer_ops,
                    layer_fan,
                    prog.tables32(),
                    biases,
                    &self.codes,
                    &mut self.sums32,
                    n,
                ),
                Lane::I64 => run_layer(
                    layer_ops,
                    layer_fan,
                    prog.tables64(),
                    biases,
                    &self.codes,
                    &mut self.sums64,
                    n,
                ),
            }
            // requant boundary: integer flip of the sum plane back into the
            // code plane — same feature-major layout on both sides, so this
            // is one contiguous plane pass (and float-free for integer
            // plans), with the plan-kind dispatch hoisted out of the loop
            if let Some(rq) = &plan.requant {
                let m = n * plan.d_out;
                match plan.lane {
                    Lane::I32 => rq.encode_plane(&self.sums32[..m], &mut self.codes[..m]),
                    Lane::I64 => rq.encode_plane(&self.sums64[..m], &mut self.codes[..m]),
                }
            }
        }

        // unpack: transpose the final feature-major sum plane into the flat
        // sample-major output. Appending (instead of zero-resizing and
        // index-writing) keeps the write stream sequential and skips a
        // whole-plane memset that would be overwritten anyway.
        out.reserve(n * d_out);
        let last = prog.layers().last().expect("d_out > 0 implies layers");
        match last.lane {
            Lane::I32 => {
                let sums = &self.sums32[..n * d_out];
                for s in 0..n {
                    out.extend((0..d_out).map(|q| sums[q * n + s] as i64));
                }
            }
            Lane::I64 => {
                let sums = &self.sums64[..n * d_out];
                for s in 0..n {
                    out.extend((0..d_out).map(|q| sums[q * n + s]));
                }
            }
        }
    }

    /// Per-sample convenience over [`Executor::run_batch_into`]: returns
    /// one sum vector per sample. This allocates a `Vec` per sample —
    /// anything that runs more than once should call
    /// [`Executor::run_batch_into`] (or [`run_batch_flat`]) and slice the
    /// flat plane instead.
    pub fn run_batch<S: AsRef<[u32]>>(
        &mut self,
        prog: &CompiledProgram,
        batch: &[S],
    ) -> Vec<Vec<i64>> {
        let n = batch.len();
        let d_out = prog.d_out();
        if n == 0 || d_out == 0 {
            return vec![Vec::new(); n];
        }
        let mut flat = Vec::with_capacity(n * d_out);
        self.run_batch_into(prog, batch, &mut flat);
        flat.chunks(d_out).map(|c| c.to_vec()).collect()
    }
}

/// One-shot convenience over a fresh [`Executor`] sized for this batch
/// (allocates once up front; the serving path holds a per-worker executor
/// plus a reused flat output buffer instead).
pub fn run_batch<S: AsRef<[u32]>>(prog: &CompiledProgram, batch: &[S]) -> Vec<Vec<i64>> {
    Executor::with_capacity(prog, batch.len()).run_batch(prog, batch)
}

/// One-shot flat-plane variant of [`run_batch`]: fills the caller-owned
/// sample-major plane (`out[s * d_out + q]`) with no per-sample `Vec`
/// allocations — the shape examples and benches should use when they
/// compare whole batches.
pub fn run_batch_flat<S: AsRef<[u32]>>(prog: &CompiledProgram, batch: &[S], out: &mut Vec<i64>) {
    Executor::with_capacity(prog, batch.len()).run_batch_into(prog, batch, out);
}

/// The PR-3 one-element-at-a-time executor loops, frozen verbatim.
///
/// Two consumers keep this alive: `benches/engine.rs` A/Bs the chunked
/// kernels against it (the "frozen scalar kernels" baseline the speedup
/// gate is defined against), and the tests in this module use it as the
/// bit-exactness oracle alongside [`crate::sim`]. It is not part of the
/// public API surface and carries no optimizations on purpose — do not
/// "improve" it, its value is that it never changes. It predates the lossy
/// tier and ignores `LutOp::scale`, so it must only run programs compiled
/// at `OptLevel::None` or `Full` (where every scale is 1) — exactly what
/// its two consumers do.
#[doc(hidden)]
pub mod scalar_ref {
    use super::super::program::{CompiledProgram, FanOut, Lane, LutOp};

    trait LaneWord: Copy + std::ops::AddAssign {
        fn from_i64(v: i64) -> Self;
    }

    impl LaneWord for i64 {
        #[inline(always)]
        fn from_i64(v: i64) -> i64 {
            v
        }
    }

    impl LaneWord for i32 {
        #[inline(always)]
        fn from_i64(v: i64) -> i32 {
            debug_assert!(i32::try_from(v).is_ok(), "narrow-lane value out of range");
            v as i32
        }
    }

    fn run_layer<T: LaneWord>(
        ops: &[LutOp],
        fanouts: &[FanOut],
        tables: &[T],
        biases: &[i64],
        codes: &[u32],
        sums: &mut [T],
        n: usize,
    ) {
        for (q, &bias) in biases.iter().enumerate() {
            sums[q * n..(q + 1) * n].fill(T::from_i64(bias));
        }
        let mut fi = 0usize;
        for (i, op) in ops.iter().enumerate() {
            let off = op.table_off as usize;
            let mask = op.addr_mask as usize;
            let table = &tables[off..off + mask + 1];
            let src_off = op.input as usize * n;
            let start = fi;
            while fi < fanouts.len() && fanouts[fi].op as usize == i {
                fi += 1;
            }
            if start == fi {
                let src = &codes[src_off..src_off + n];
                let dst = &mut sums[op.neuron as usize * n..op.neuron as usize * n + n];
                for (acc, &code) in dst.iter_mut().zip(src) {
                    *acc += table[code as usize & mask];
                }
            } else {
                let extra = &fanouts[start..fi];
                let own = op.neuron as usize * n;
                for (s, &code) in codes[src_off..src_off + n].iter().enumerate() {
                    let v = table[code as usize & mask];
                    sums[own + s] += v;
                    for f in extra {
                        sums[f.neuron as usize * n + s] += v;
                    }
                }
            }
        }
        debug_assert_eq!(fi, fanouts.len(), "fanout entries must map onto layer ops in order");
    }

    /// Frozen scalar twin of [`super::Executor`]: same scratch layout and
    /// growth policy, per-element loops and per-element `encode_sum`
    /// requant.
    #[derive(Default)]
    pub struct ScalarExecutor {
        codes: Vec<u32>,
        sums32: Vec<i32>,
        sums64: Vec<i64>,
    }

    impl ScalarExecutor {
        pub fn new() -> ScalarExecutor {
            ScalarExecutor::default()
        }

        /// Frozen twin of [`super::Executor::run_batch_into`]; identical
        /// contract, per-element inner loops.
        pub fn run_batch_into<S: AsRef<[u32]>>(
            &mut self,
            prog: &CompiledProgram,
            batch: &[S],
            out: &mut Vec<i64>,
        ) {
            out.clear();
            let n = batch.len();
            let d_out = prog.d_out();
            if n == 0 || d_out == 0 {
                return;
            }
            let words = n * prog.max_width();
            if self.codes.len() < words {
                self.codes.resize(words, 0);
            }
            if prog.uses_i32() && self.sums32.len() < words {
                self.sums32.resize(words, 0);
            }
            if prog.uses_i64() && self.sums64.len() < words {
                self.sums64.resize(words, 0);
            }

            let d0 = prog.d_in();
            match prog.input_map() {
                None => {
                    for (s, row) in batch.iter().enumerate() {
                        let row = row.as_ref();
                        assert_eq!(row.len(), d0, "batch row width != program d_in");
                        for (f, &code) in row.iter().enumerate() {
                            self.codes[f * n + s] = code;
                        }
                    }
                }
                Some(map) => {
                    for (s, row) in batch.iter().enumerate() {
                        let row = row.as_ref();
                        assert_eq!(row.len(), d0, "batch row width != program d_in");
                        for (i, &f) in map.iter().enumerate() {
                            self.codes[i * n + s] = row[f as usize];
                        }
                    }
                }
            }

            let ops = prog.ops();
            let fanouts = prog.fanouts();
            for plan in prog.layers() {
                let biases = &prog.biases()[plan.bias_off..plan.bias_off + plan.d_out];
                let layer_ops = &ops[plan.ops.clone()];
                let layer_fan = &fanouts[plan.fanout.clone()];
                match plan.lane {
                    Lane::I32 => run_layer(
                        layer_ops,
                        layer_fan,
                        prog.tables32(),
                        biases,
                        &self.codes,
                        &mut self.sums32,
                        n,
                    ),
                    Lane::I64 => run_layer(
                        layer_ops,
                        layer_fan,
                        prog.tables64(),
                        biases,
                        &self.codes,
                        &mut self.sums64,
                        n,
                    ),
                }
                if let Some(rq) = &plan.requant {
                    let m = n * plan.d_out;
                    match plan.lane {
                        Lane::I32 => {
                            for (code, &sum) in self.codes[..m].iter_mut().zip(&self.sums32[..m]) {
                                *code = rq.encode_sum(sum as i64);
                            }
                        }
                        Lane::I64 => {
                            for (code, &sum) in self.codes[..m].iter_mut().zip(&self.sums64[..m]) {
                                *code = rq.encode_sum(sum);
                            }
                        }
                    }
                }
            }

            out.reserve(n * d_out);
            let last = prog.layers().last().expect("d_out > 0 implies layers");
            match last.lane {
                Lane::I32 => {
                    let sums = &self.sums32[..n * d_out];
                    for s in 0..n {
                        out.extend((0..d_out).map(|q| sums[q * n + s] as i64));
                    }
                }
                Lane::I64 => {
                    let sums = &self.sums64[..n * d_out];
                    for s in 0..n {
                        out.extend((0..d_out).map(|q| sums[q * n + s]));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::fixed::Quantizer;
    use crate::lut;
    use crate::netlist::{adder_depth, LayerNet, LutInst, Netlist, NeuronNet};
    use crate::sim;
    use crate::util::Rng;

    fn net_for(dims: &[usize], bits: &[u32], seed: u64) -> Netlist {
        let ck = synthetic(dims, bits, seed);
        let tables = lut::from_checkpoint(&ck);
        Netlist::build(&ck, &tables, 2)
    }

    fn random_batch(rng: &mut Rng, n: usize, d: usize, bits: u32) -> Vec<Vec<u32>> {
        (0..n)
            .map(|_| (0..d).map(|_| rng.below(1 << bits) as u32).collect())
            .collect()
    }

    #[test]
    fn run_batch_into_matches_run_batch_and_sim() {
        let net = net_for(&[4, 3, 2], &[4, 5, 6], 301);
        let prog = CompiledProgram::compile(&net);
        let mut rng = Rng::new(8);
        let mut ex = Executor::new();
        let mut flat = Vec::new();
        for n in [1usize, 5, 64, 2] {
            let batch = random_batch(&mut rng, n, 4, 4);
            ex.run_batch_into(&prog, &batch, &mut flat);
            let want = sim::eval_batch(&net, &batch);
            assert_eq!(flat.len(), n * prog.d_out());
            let want_flat: Vec<i64> = want.iter().flatten().copied().collect();
            assert_eq!(flat, want_flat);
            assert_eq!(ex.run_batch(&prog, &batch), want);
        }
    }

    #[test]
    fn chunked_kernels_match_frozen_scalar_and_sim_on_tail_batches() {
        // the tentpole gate, in miniature: chunked kernels == frozen PR-3
        // scalar loops == sim, for batch sizes straddling every tail shape
        // (1, CHUNK-1, CHUNK, CHUNK+1, ...), both opt levels
        use crate::engine::OptLevel;
        let cases = [
            (net_for(&[4, 3, 2], &[4, 5, 6], 901), 4u32),
            (net_for(&[6, 5, 4, 2], &[3, 4, 4, 6], 902), 3u32),
        ];
        let mut rng = Rng::new(77);
        for (net, in_bits) in &cases {
            for level in [OptLevel::None, OptLevel::Full] {
                let prog = CompiledProgram::compile_opt(net, level);
                let mut ex = Executor::new();
                let mut sc = scalar_ref::ScalarExecutor::new();
                let (mut flat, mut want) = (Vec::new(), Vec::new());
                for n in [1usize, CHUNK - 1, CHUNK, CHUNK + 1, 2 * CHUNK + 3, 64] {
                    let batch = random_batch(&mut rng, n, prog.d_in(), *in_bits);
                    ex.run_batch_into(&prog, &batch, &mut flat);
                    sc.run_batch_into(&prog, &batch, &mut want);
                    assert_eq!(flat, want, "kernels != scalar_ref at n={n} level={level:?}");
                    let sim_flat: Vec<i64> =
                        sim::eval_batch(net, &batch).iter().flatten().copied().collect();
                    assert_eq!(flat, sim_flat, "kernels != sim at n={n} level={level:?}");
                }
            }
        }
    }

    #[test]
    fn flat_buffer_is_reused_and_scratch_never_shrinks() {
        let net = net_for(&[5, 4, 3], &[4, 4, 5], 77);
        let prog = CompiledProgram::compile(&net);
        let mut rng = Rng::new(3);
        let mut ex = Executor::with_capacity(&prog, 8);
        let mut flat = Vec::new();

        let big = random_batch(&mut rng, 256, 5, 4);
        ex.run_batch_into(&prog, &big, &mut flat);
        let peak = ex.scratch_bytes();
        let flat_cap = flat.capacity();
        assert!(peak >= 256 * prog.max_width() * std::mem::size_of::<u32>());

        // smaller batches must not shrink scratch or reallocate the buffer
        for n in [1usize, 31, 256] {
            let batch = random_batch(&mut rng, n, 5, 4);
            ex.run_batch_into(&prog, &batch, &mut flat);
            assert_eq!(ex.scratch_bytes(), peak, "planes must never shrink");
            assert_eq!(flat.capacity(), flat_cap, "flat buffer must be reused");
            let want: Vec<i64> =
                sim::eval_batch(&net, &batch).iter().flatten().copied().collect();
            assert_eq!(flat, want);
        }
    }

    #[test]
    fn empty_batch_clears_out() {
        let net = net_for(&[3, 2], &[3, 6], 5);
        let prog = CompiledProgram::compile(&net);
        let mut ex = Executor::new();
        let mut flat = vec![1, 2, 3];
        let empty: Vec<Vec<u32>> = Vec::new();
        ex.run_batch_into(&prog, &empty, &mut flat);
        assert!(flat.is_empty());
    }

    #[test]
    #[should_panic(expected = "batch row width != program d_in")]
    fn wrong_width_row_panics() {
        let net = net_for(&[3, 2], &[3, 6], 5);
        let prog = CompiledProgram::compile(&net);
        let mut ex = Executor::new();
        ex.run_batch(&prog, &[vec![0u32, 1]]);
    }

    /// Two-layer netlist whose FIRST layer needs the wide lane (one neuron
    /// with ±2^40 entries) while the other neuron stays small enough that
    /// requant produces varied (not rail-clamped) codes, and whose second
    /// layer is narrow: exercises the i64 lane, the wide->requant flip, and
    /// the mixed-lane handoff in one program.
    fn mixed_lane_net() -> Netlist {
        let small = |seed: i64| -> Vec<i64> { (0..8).map(|i| (i * 97 + seed) % 3000 - 1500).collect() };
        let big = 1i64 << 40;
        let l0_neurons = vec![
            NeuronNet {
                luts: vec![
                    LutInst { input: 0, table: small(11), out_width: 12 },
                    LutInst { input: 1, table: small(23), out_width: 12 },
                ],
                bias: 0,
                depth: adder_depth(2, 2),
                sum_width: 14,
            },
            NeuronNet {
                luts: vec![
                    LutInst { input: 0, table: vec![big; 8], out_width: 42 },
                    LutInst { input: 1, table: vec![-big; 8], out_width: 42 },
                ],
                bias: 0,
                depth: adder_depth(2, 2),
                sum_width: 43,
            },
        ];
        let l1_neurons = vec![NeuronNet {
            luts: vec![
                LutInst { input: 0, table: small(5), out_width: 12 },
                LutInst { input: 1, table: small(7), out_width: 12 },
            ],
            bias: 0,
            depth: adder_depth(2, 2),
            sum_width: 14,
        }];
        Netlist {
            name: "mixed-lane".into(),
            layers: vec![
                LayerNet {
                    d_in: 2,
                    d_out: 2,
                    in_bits: 3,
                    out_bits: 3,
                    neurons: l0_neurons,
                    requant: Some(Quantizer::new(3, -4.0, 4.0)),
                    depth: 1,
                },
                LayerNet {
                    d_in: 2,
                    d_out: 1,
                    in_bits: 3,
                    out_bits: 8,
                    neurons: l1_neurons,
                    requant: None,
                    depth: 1,
                },
            ],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        }
    }

    #[test]
    fn mixed_lanes_match_interpreter() {
        let net = mixed_lane_net();
        let prog = CompiledProgram::compile(&net);
        assert_eq!(prog.layers()[0].lane, Lane::I64);
        assert_eq!(prog.layers()[1].lane, Lane::I32);
        let batch: Vec<Vec<u32>> = (0..64).map(|i| vec![i % 8, (i * 5 + 3) % 8]).collect();
        assert_eq!(run_batch(&prog, &batch), sim::eval_batch(&net, &batch));
    }

    #[test]
    fn mixed_lane_tail_batches_match_frozen_scalar() {
        // both lanes and the wide->requant flip, at every tail shape
        let net = mixed_lane_net();
        let prog = CompiledProgram::compile(&net);
        let mut ex = Executor::new();
        let mut sc = scalar_ref::ScalarExecutor::new();
        let (mut flat, mut want) = (Vec::new(), Vec::new());
        for n in [1usize, CHUNK - 1, CHUNK + 1, 2 * CHUNK + 1] {
            let batch: Vec<Vec<u32>> =
                (0..n as u32).map(|i| vec![i % 8, (i * 5 + 3) % 8]).collect();
            ex.run_batch_into(&prog, &batch, &mut flat);
            sc.run_batch_into(&prog, &batch, &mut want);
            assert_eq!(flat, want, "mixed-lane kernels != scalar_ref at n={n}");
            let sim_flat: Vec<i64> =
                sim::eval_batch(&net, &batch).iter().flatten().copied().collect();
            assert_eq!(flat, sim_flat, "mixed-lane kernels != sim at n={n}");
        }
    }

    #[test]
    fn wide_lane_output_layer_unpacks_i64() {
        // wide lane on the LAST layer: the unpack transpose must read the
        // i64 plane (big raw sums survive to the output untouched)
        let big = 1i64 << 40;
        let neurons = vec![NeuronNet {
            luts: vec![LutInst { input: 0, table: vec![big; 8], out_width: 42 }],
            bias: 0,
            depth: 0,
            sum_width: 42,
        }];
        let net = Netlist {
            name: "wide-out".into(),
            layers: vec![LayerNet {
                d_in: 1,
                d_out: 1,
                in_bits: 3,
                out_bits: 8,
                neurons,
                requant: None,
                depth: 0,
            }],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let prog = CompiledProgram::compile(&net);
        assert_eq!(prog.layers()[0].lane, Lane::I64);
        let batch = vec![vec![0u32], vec![7u32]];
        let got = run_batch(&prog, &batch);
        assert_eq!(got, sim::eval_batch(&net, &batch));
        assert_eq!(got[0][0], big);
    }

    #[test]
    fn optimized_program_reuses_executor_across_levels_and_sizes() {
        // one executor serves a 1:1 program and an optimized one (fanouts +
        // input map) interleaved, across batch sizes — the scratch planes
        // and cursor logic must not leak state between programs
        use crate::engine::OptLevel;
        let t: Vec<i64> = (0..8).map(|i| i * 123 - 400).collect();
        let neurons = vec![
            NeuronNet {
                luts: vec![
                    LutInst { input: 0, table: t.clone(), out_width: 12 },
                    LutInst { input: 2, table: t.clone(), out_width: 12 },
                ],
                bias: 9,
                depth: adder_depth(2, 2),
                sum_width: 14,
            },
            NeuronNet {
                luts: vec![LutInst { input: 0, table: t.clone(), out_width: 12 }],
                bias: -2,
                depth: 0,
                sum_width: 13,
            },
        ];
        let net = Netlist {
            name: "opt-exec".into(),
            layers: vec![LayerNet {
                d_in: 3, // input 1 is dead
                d_out: 2,
                in_bits: 3,
                out_bits: 8,
                neurons,
                requant: None,
                depth: 1,
            }],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let p_none = CompiledProgram::compile_opt(&net, OptLevel::None);
        let p_full = CompiledProgram::compile_opt(&net, OptLevel::Full);
        assert!(!p_full.fanouts().is_empty(), "duplicate (input, table) must CSE");
        assert!(p_full.input_map().is_some(), "dead input 1 must be compacted");
        let mut ex = Executor::new();
        let mut rng = Rng::new(4);
        for &nb in &[1usize, 9, CHUNK + 1, 64, 2] {
            let batch = random_batch(&mut rng, nb, 3, 3);
            let want = sim::eval_batch(&net, &batch);
            assert_eq!(ex.run_batch(&p_none, &batch), want);
            assert_eq!(ex.run_batch(&p_full, &batch), want);
        }
    }

    #[test]
    fn lossy_scaled_ops_match_sim_on_tail_batches() {
        // affine-folded programs dispatch gather_mul_add / scale_run: t2 is
        // exactly 3*t1 + 7, so Lossy(1) folds both t2 consumers onto t1's
        // slot (residual 0) and the outputs must stay bit-exact with sim.
        // The two folded consumers share (input, rep, scale), so they CSE
        // into the fanout path — both scaled code paths run here.
        use crate::engine::OptLevel;
        let t1: Vec<i64> = (0..8).map(|i| i * 123 - 400).collect();
        let t2: Vec<i64> = t1.iter().map(|v| 3 * v + 7).collect();
        let neurons = vec![
            NeuronNet {
                luts: vec![LutInst { input: 0, table: t1.clone(), out_width: 12 }],
                bias: 1,
                depth: 0,
                sum_width: 13,
            },
            NeuronNet {
                luts: vec![LutInst { input: 1, table: t2.clone(), out_width: 13 }],
                bias: -2,
                depth: 0,
                sum_width: 14,
            },
            NeuronNet {
                luts: vec![LutInst { input: 1, table: t2.clone(), out_width: 13 }],
                bias: 4,
                depth: 0,
                sum_width: 14,
            },
        ];
        let net = Netlist {
            name: "affine-exec".into(),
            layers: vec![LayerNet {
                d_in: 2,
                d_out: 3,
                in_bits: 3,
                out_bits: 8,
                neurons,
                requant: None,
                depth: 0,
            }],
            n_add: 2,
            frac_bits: 12,
            domain: (-4.0, 4.0),
        };
        let prog = CompiledProgram::compile_opt(&net, OptLevel::Lossy(1));
        assert!(prog.ops().iter().any(|o| o.scale == 3), "{:?}", prog.ops());
        assert!(!prog.fanouts().is_empty(), "shared folded pair must CSE");
        let mut ex = Executor::new();
        let mut flat = Vec::new();
        for n in [1usize, CHUNK - 1, CHUNK, CHUNK + 1, 2 * CHUNK + 3] {
            let batch: Vec<Vec<u32>> =
                (0..n as u32).map(|i| vec![i % 8, (i * 3 + 1) % 8]).collect();
            ex.run_batch_into(&prog, &batch, &mut flat);
            let want: Vec<i64> =
                sim::eval_batch(&net, &batch).iter().flatten().copied().collect();
            assert_eq!(flat, want, "scaled ops != sim at n={n}");
        }
    }

    #[test]
    fn run_batch_flat_matches_nested_convenience() {
        let net = net_for(&[4, 3, 2], &[4, 5, 6], 42);
        let prog = CompiledProgram::compile(&net);
        let mut rng = Rng::new(6);
        let batch = random_batch(&mut rng, 33, 4, 4);
        let mut flat = Vec::new();
        run_batch_flat(&prog, &batch, &mut flat);
        let nested: Vec<i64> = run_batch(&prog, &batch).into_iter().flatten().collect();
        assert_eq!(flat, nested);
    }

    #[test]
    fn one_shot_run_batch_presizes_scratch() {
        // the free-function convenience must size its executor via
        // with_capacity (regression for the old Executor::new() one-shot)
        let net = net_for(&[4, 3, 2], &[4, 5, 6], 17);
        let prog = CompiledProgram::compile(&net);
        let ex = Executor::with_capacity(&prog, 64);
        let words = 64 * prog.max_width();
        assert!(ex.scratch_bytes() >= words * (std::mem::size_of::<u32>() + std::mem::size_of::<i32>()));
        // ... and reserves only the lanes the program uses: this all-narrow
        // program must not have paid for an i64 plane
        assert!(
            ex.scratch_bytes()
                < words
                    * (std::mem::size_of::<u32>()
                        + std::mem::size_of::<i32>()
                        + std::mem::size_of::<i64>())
        );
    }
}
