//! Batch-major execution of a [`CompiledProgram`].
//!
//! The interpreter ([`crate::sim::Evaluator`]) advances one *sample* at a
//! time, re-walking the whole structure per request. The executor inverts
//! the loops: every fused op runs across all N samples of the batch before
//! the next op is touched, so each truth table is streamed through exactly
//! once per batch and the per-op bookkeeping (offset, mask, indices)
//! amortizes over N samples.
//!
//! Scratch is double-buffered and planned at compile time: one `u32` code
//! plane and one `i64` sum plane, each `batch x max_width`, flipped at the
//! requant boundary between layers. No allocation happens on the serving
//! hot path after the first batch of a given size.

use crate::fixed::from_fixed;

use super::program::CompiledProgram;

/// Reusable batch executor: owns the double-buffered scratch planes.
///
/// Independent of any particular program (scratch grows to the largest
/// `batch x max_width` seen), so one executor per worker thread serves
/// across hot-swaps.
#[derive(Default)]
pub struct Executor {
    /// Front buffer: current layer's input codes, batch-major
    /// (`codes[s * d_in + p]` = input `p` of sample `s`).
    codes: Vec<u32>,
    /// Back buffer: current layer's accumulator sums, batch-major.
    sums: Vec<i64>,
}

impl Executor {
    pub fn new() -> Executor {
        Executor::default()
    }

    /// Preallocate scratch for batches up to `batch` samples of `prog`.
    pub fn with_capacity(prog: &CompiledProgram, batch: usize) -> Executor {
        Executor {
            codes: Vec::with_capacity(batch * prog.max_width()),
            sums: Vec::with_capacity(batch * prog.max_width()),
        }
    }

    /// Run every sample of `batch` through the program; returns one sum
    /// vector per sample. Bit-exact with [`crate::sim::eval`] per sample.
    ///
    /// Every row must be exactly `prog.d_in()` codes wide (panics
    /// otherwise — in a batch-major plane a wrong-width row would shift
    /// every later sample; the coordinator validates widths at admission).
    pub fn run_batch<S: AsRef<[u32]>>(
        &mut self,
        prog: &CompiledProgram,
        batch: &[S],
    ) -> Vec<Vec<i64>> {
        let n = batch.len();
        if n == 0 || prog.layers().is_empty() {
            return vec![Vec::new(); n];
        }
        // pack the request rows into the batch-major input plane
        let d0 = prog.d_in();
        self.codes.clear();
        self.codes.reserve(n * prog.max_width());
        for row in batch {
            let row = row.as_ref();
            assert_eq!(row.len(), d0, "batch row width != program d_in");
            self.codes.extend_from_slice(row);
        }

        let ops = prog.ops();
        let tables = prog.tables();
        for plan in prog.layers() {
            let (d_in, d_out) = (plan.d_in, plan.d_out);
            // seed the sum plane with the per-neuron constant operands
            let biases = &prog.biases()[plan.bias_off..plan.bias_off + d_out];
            self.sums.clear();
            self.sums.reserve(n * prog.max_width());
            for _ in 0..n {
                self.sums.extend_from_slice(biases);
            }
            let codes = &self.codes[..n * d_in];
            let sums = &mut self.sums[..n * d_out];
            // fused gather + accumulate, batch-major: one sequential scan
            // of the table arena per batch
            for op in &ops[plan.ops.clone()] {
                let off = op.table_off as usize;
                let mask = op.addr_mask as usize;
                let table = &tables[off..off + mask + 1];
                let (input, neuron) = (op.input as usize, op.neuron as usize);
                for s in 0..n {
                    let addr = codes[s * d_in + input] as usize & mask;
                    sums[s * d_out + neuron] += table[addr];
                }
            }
            // requant boundary: flip sums back into the code plane
            if let Some(q) = &plan.requant {
                self.codes.clear();
                for &sum in self.sums[..n * d_out].iter() {
                    self.codes.push(q.encode(from_fixed(sum, prog.frac_bits)));
                }
            }
        }

        let d_out = prog.d_out();
        (0..n)
            .map(|s| self.sums[s * d_out..(s + 1) * d_out].to_vec())
            .collect()
    }
}

/// One-shot convenience over a fresh [`Executor`] (allocates; the serving
/// path holds a per-worker executor instead).
pub fn run_batch<S: AsRef<[u32]>>(prog: &CompiledProgram, batch: &[S]) -> Vec<Vec<i64>> {
    Executor::new().run_batch(prog, batch)
}
