//! Compiled netlist execution engine — the serving backend.
//!
//! The paper's deployment target is a streaming II=1 accelerator whose
//! whole inference is LUT lookups and integer adds; the software
//! substitute for *correctness* is [`crate::sim`], which walks the
//! `Netlist` object graph (`layers -> neurons -> luts`) per sample. That
//! pointer chase is the wrong shape for the serving hot path, so this
//! module splits execution into **compile once (through an optimizing
//! pass pipeline), run batches** — and the compiled hot path is, like the
//! hardware, integer-only:
//!
//! ```text
//!            ┌────────────── optim (OptLevel::Full, the default) ──────────────┐
//! Netlist ─▶ │ 1 fold constant edges  ─▶ biases     (sum unchanged term-wise)  │
//!            │ 2 eliminate dead code  (Netlist::dead_inputs is the oracle:     │
//!            │   unread producers deleted, external features → input_map)      │
//!            │ 3 hash-cons tables     (one arena slot per content, per Lane)   │
//!            │ 4 CSE duplicate lookups (one op + FanOut list per (input,table))│
//!            │ 5 re-run lane analysis  on the optimized op order (folding      │
//!            │   tightens ranges, so layers can narrow to the i32 lane)        │
//!            │ 6 OptLevel::Lossy(b) only — error-budgeted passes on top:       │
//!            │   ε-cluster near tables (exact max |Δ| <= b) onto one rep,      │
//!            │   fold t2 ≈ a*t1 + c into (scale, bias), tighten next-layer     │
//!            │   ranges to the codes the requant can actually produce.         │
//!            │   Worst-case end-to-end bound composed per layer:               │
//!            │   max_q Σ_lut (eps + |scale|·mod_rep(code slack)), slack =      │
//!            │   requant boundaries crossable by the previous layer's delta    │
//!            └──────────────────────────────────────────────────────────┬─────┘
//!                 OptLevel::None: the 1:1 lowering, byte-identical       │
//!                 to `CompiledProgram::compile` (the A/B baseline)       ▼
//!                                            CompiledProgram (+ OptReport [+ LossyReport])
//! ```
//!
//! Invariants each pass preserves (tested in [`optim`]):
//! **functional** — `optimized(net) == sim::eval(net)` bit for bit, for
//! every input (folding moves exact terms, DCE deletes unobservable work,
//! sharing never changes a gathered value, and the lane analysis re-proves
//! no-overflow in the *new* op order); **interface** — `d_in()`/`d_out()`
//! keep the checkpoint's request/response widths even when internal planes
//! shrink; **reporting** — `table_bytes()` prices unique content and
//! [`OptReport`] carries the before/after geometry. The lossy tier
//! deliberately relaxes only the *functional* invariant, and only by a
//! compile-time-proven amount: `Lossy(0)` is byte-identical to `Full`, and
//! any budget `b` ships a [`LossyReport`] whose `worst_case_bound` is a
//! sound (never estimated) cap on the end-to-end output delta vs the exact
//! program.
//!
//! * [`CompiledProgram`] ([`program`]) — the netlist lowered to flat
//!   arrays: packed table arenas **narrowed to i32 where a per-layer range
//!   analysis proves no partial sum can overflow** ([`Lane`]), a fused
//!   gather+accumulate op stream with resolved indices, **integer requant
//!   plans** ([`RequantPlan`]: fixed-point multiply/shift or threshold
//!   table, bit-exact with the float `Quantizer::encode_fixed` oracle by
//!   construction), and the scratch geometry, all fixed at compile time.
//! * [`optim`] — the pass pipeline above ([`OptLevel`], [`OptReport`]),
//!   run by default everywhere a program is built for serving.
//! * [`Executor`] ([`exec`]) — **feature-major** batch execution: scratch
//!   planes are transposed (`plane[feature * n + sample]`) so each op
//!   reads and writes contiguous runs of `n` words, and each op is applied
//!   to all N samples before the next op — sequential arena scans instead
//!   of the per-sample random walk, with no floats and no allocation on
//!   the steady-state path ([`Executor::run_batch_into`] fills a
//!   caller-owned flat plane). CSE'd ops gather once and feed k
//!   accumulators ([`program::FanOut`]). Bit-exact with
//!   [`crate::sim::eval`] (in-lane accumulation is order-exact by the
//!   range analysis, requant plans are proven equal to the float path).
//! * `kernels` — the width-`n` passes themselves run through
//!   fixed-width chunked kernels ([`CHUNK`]-sample chunks + scalar tail),
//!   monomorphized over the two lanes: plain chunked loops stable rustc
//!   autovectorizes by default, `std::simd` bodies behind the
//!   nightly-only `simd` cargo feature — same trait, same results. The
//!   pre-kernel one-element loops are frozen as `exec::scalar_ref`, the
//!   bench A/B baseline and test oracle. Because every sample's chain is
//!   independent, planes are also *sample-sliceable*: the coordinator can
//!   fan grain-sized sample ranges of one large batch across its executor
//!   pool and stitch the slices back byte-for-byte
//!   (`ServiceCfg::parallel_grain`).
//! * [`ProgramCell`] ([`swap`]) — hot-swap support: recompile (at the
//!   cell's [`OptLevel`]) on netlist change + atomic program publication,
//!   preserving the netlist cell's batch-consistent snapshot semantics.
//!
//! Division of labor: `sim` stays the debugging / cycle-accuracy oracle
//! (and the cross-check that gates every batch in debug builds); `engine`
//! is what the [`crate::coordinator`] workers run in production.

pub mod exec;
mod kernels;
pub mod optim;
pub mod program;
pub mod swap;

pub use exec::{run_batch, run_batch_flat, Executor};
pub use kernels::CHUNK;
pub use optim::{LossyReport, OptLevel, OptReport};
pub use program::{
    intern_tables, intern_tables_lossy, CompiledProgram, FanOut, InternStats, Lane, LayerPlan,
    LutOp, RequantPlan, PLAN_MAX_BITS,
};
pub use swap::ProgramCell;

use crate::netlist::Netlist;

/// Lower a netlist into its flat feature-major program through the default
/// optimizing pipeline ([`OptLevel::Full`]).
pub fn compile(net: &Netlist) -> CompiledProgram {
    CompiledProgram::compile_opt(net, OptLevel::default())
}

/// Lower a netlist at an explicit [`OptLevel`] ([`OptLevel::None`] is the
/// 1:1 lowering — the A/B baseline).
pub fn compile_with(net: &Netlist, level: OptLevel) -> CompiledProgram {
    CompiledProgram::compile_opt(net, level)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::lut;
    use crate::sim;
    use crate::util::{prop, Rng};

    fn net_for(dims: &[usize], bits: &[u32], seed: u64, n_add: usize) -> Netlist {
        let ck = synthetic(dims, bits, seed);
        let tables = lut::from_checkpoint(&ck);
        Netlist::build(&ck, &tables, n_add)
    }

    fn random_batch(rng: &mut Rng, n: usize, d: usize, bits: u32) -> Vec<Vec<u32>> {
        (0..n)
            .map(|_| (0..d).map(|_| rng.below(1 << bits) as u32).collect())
            .collect()
    }

    #[test]
    fn matches_interpreter_on_random_batches() {
        let net = net_for(&[4, 3, 2], &[4, 5, 6], 17, 2);
        let prog = compile(&net);
        let mut rng = Rng::new(3);
        let batch = random_batch(&mut rng, 64, 4, 4);
        assert_eq!(run_batch(&prog, &batch), sim::eval_batch(&net, &batch));
    }

    #[test]
    fn executor_reuse_across_batch_sizes_and_programs() {
        let net_a = net_for(&[4, 3, 2], &[4, 5, 6], 21, 2);
        let net_b = net_for(&[6, 5, 4, 2], &[3, 4, 4, 6], 22, 3);
        let (pa, pb) = (compile(&net_a), compile(&net_b));
        let mut ex = Executor::with_capacity(&pa, 8);
        let mut rng = Rng::new(9);
        for &n in &[1usize, 7, 64, 3, 256, 1] {
            let ba = random_batch(&mut rng, n, 4, 4);
            assert_eq!(ex.run_batch(&pa, &ba), sim::eval_batch(&net_a, &ba));
            let bb = random_batch(&mut rng, n, 6, 3);
            assert_eq!(ex.run_batch(&pb, &bb), sim::eval_batch(&net_b, &bb));
        }
    }

    #[test]
    fn empty_batch_and_slice_inputs() {
        let net = net_for(&[3, 2], &[3, 6], 5, 2);
        let prog = compile(&net);
        let empty: Vec<Vec<u32>> = Vec::new();
        assert!(run_batch(&prog, &empty).is_empty());
        // &[u32] rows work too (the coordinator passes borrowed rows)
        let rows: Vec<&[u32]> = vec![&[0, 1, 2], &[7, 0, 3]];
        let owned: Vec<Vec<u32>> = rows.iter().map(|r| r.to_vec()).collect();
        assert_eq!(run_batch(&prog, &rows), sim::eval_batch(&net, &owned));
    }

    #[test]
    fn pruned_to_empty_fan_in_neuron() {
        // neuron 0 of the first layer loses every incoming edge: its sum
        // must be exactly the folded bias (0 for fresh netlists)
        let mut ck = synthetic(&[3, 2, 2], &[4, 4, 6], 55);
        let l = &mut ck.layers[0];
        for p in 0..l.d_in {
            l.mask[p] = false;
            l.table[p] = None;
        }
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        let prog = compile(&net);
        let batch = vec![vec![0u32, 1, 2], vec![3, 3, 3]];
        assert_eq!(run_batch(&prog, &batch), sim::eval_batch(&net, &batch));
    }

    #[test]
    fn requant_boundary_codes() {
        // extreme accumulator sums must hit the quantizer's clamp rails
        // identically in both engines: drive all-min / all-max codes
        let net = net_for(&[4, 3, 2], &[5, 2, 6], 77, 2);
        let prog = compile(&net);
        let lo = vec![vec![0u32; 4]];
        let hi = vec![vec![31u32; 4]];
        assert_eq!(run_batch(&prog, &lo), sim::eval_batch(&net, &lo));
        assert_eq!(run_batch(&prog, &hi), sim::eval_batch(&net, &hi));
    }

    #[test]
    fn prop_engine_equals_eval_batch_equals_cycle_sim() {
        // the three executors are one function: compiled == interpreted ==
        // cycle-accurate, over random shapes (including 1-neuron layers),
        // arities, seeds and input streams
        prop::check("engine-equals-sim-equals-cyclesim", 40, |g| {
            let n_layers = g.usize_in(1, 3);
            let mut dims = vec![g.usize_in(1, 6)];
            let mut bits = vec![g.usize_in(1, 5) as u32];
            for _ in 0..n_layers {
                dims.push(g.usize_in(1, 6));
                bits.push(g.usize_in(2, 6) as u32);
            }
            let n_add = g.usize_in(2, 4);
            let seed = g.rng().next_u64();
            let net = net_for(&dims, &bits, seed, n_add);
            let prog = compile(&net);
            let n = g.usize_in(1, 24);
            let inputs: Vec<Vec<u32>> = (0..n)
                .map(|_| {
                    (0..dims[0])
                        .map(|_| g.rng().below(1u64 << bits[0]) as u32)
                        .collect()
                })
                .collect();
            let compiled = run_batch(&prog, &inputs);
            let interpreted = sim::eval_batch(&net, &inputs);
            if compiled != interpreted {
                return Err(format!(
                    "engine != eval_batch for dims {dims:?} bits {bits:?} seed {seed}"
                ));
            }
            // chunked kernels == frozen one-element scalar loops, at the
            // same random batch sizes (n in 1..=24 straddles the CHUNK=16
            // tail shapes, including n=1 and n=CHUNK-1)
            let mut scalar = Vec::new();
            exec::scalar_ref::ScalarExecutor::new().run_batch_into(&prog, &inputs, &mut scalar);
            let flat: Vec<i64> = compiled.iter().flatten().copied().collect();
            if scalar != flat {
                return Err(format!(
                    "kernels != scalar_ref for dims {dims:?} bits {bits:?} seed {seed} n {n}"
                ));
            }
            // the default (optimized) lowering and the 1:1 baseline are one
            // function too
            let unopt = run_batch(&compile_with(&net, OptLevel::None), &inputs);
            if unopt != interpreted {
                return Err(format!(
                    "OptLevel::None != eval_batch for dims {dims:?} bits {bits:?} seed {seed}"
                ));
            }
            let mut cyc = sim::CycleSim::new(&net);
            let completions = cyc.run_stream(&inputs);
            if completions.len() != inputs.len() {
                return Err(format!("{} of {} completed", completions.len(), inputs.len()));
            }
            for c in &completions {
                if c.sums != compiled[c.id as usize] {
                    return Err(format!(
                        "cycle-sim sample {} diverges for dims {dims:?} seed {seed}",
                        c.id
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn compile_is_deterministic() {
        let net = net_for(&[5, 4, 3], &[4, 4, 5], 31, 2);
        let (a, b) = (compile(&net), compile(&net));
        assert_eq!(a.n_ops(), b.n_ops());
        assert_eq!(a.table_words(), b.table_words());
        assert_eq!(a.tables32(), b.tables32());
        assert_eq!(a.tables64(), b.tables64());
        assert_eq!(a.biases(), b.biases());
        for (pa, pb) in a.layers().iter().zip(b.layers()) {
            assert_eq!(pa.lane, pb.lane);
        }
    }

    #[test]
    fn serving_hot_path_is_float_free_for_paper_scale_programs() {
        // every requant plan of a paper-scale (<= 8-bit codes) program must
        // lower to integer form — the engine's core claim
        let net = net_for(&[6, 5, 4, 2], &[3, 4, 4, 6], 91, 2);
        let prog = compile(&net);
        for plan in prog.layers() {
            if let Some(rq) = &plan.requant {
                assert!(rq.is_integer(), "float fallback on a {}-bit quantizer", rq.quantizer().bits);
            }
        }
    }
}
