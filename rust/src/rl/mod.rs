//! CheetahLite environment (Rust port of `python/compile/rl/cheetah.py`)
//! for the closed-loop control example: the quantized KAN policy runs as a
//! *netlist* (bit-exact hardware semantics) inside the control loop,
//! demonstrating the paper's §5.7 deployment story end to end without
//! Python anywhere near the loop.

use crate::checkpoint::Checkpoint;
use crate::fixed::from_fixed;
use crate::netlist::Netlist;
use crate::sim;
use crate::util::Rng;

pub const OBS_DIM: usize = 17;
pub const ACT_DIM: usize = 6;
pub const EPISODE_LEN: usize = 1000;

const PHI: [f64; 6] = [0.0, 2.094, 4.189, 1.047, 3.142, 5.236];
const COUPLE: [f64; 6] = [1.0, 0.8, 0.6, -1.0, -0.8, -0.6];

/// Single CheetahLite environment (f64 state, f32 observations).
pub struct CheetahLite {
    rng: Rng,
    pub dt: f64,
    q: [f64; 6],
    qd: [f64; 6],
    vx: f64,
    vz: f64,
    height: f64,
    pitch: f64,
    pitch_rate: f64,
    t: usize,
}

impl CheetahLite {
    pub fn new(seed: u64) -> Self {
        let mut env = CheetahLite {
            rng: Rng::new(seed),
            dt: 0.05,
            q: [0.0; 6],
            qd: [0.0; 6],
            vx: 0.0,
            vz: 0.0,
            height: 0.7,
            pitch: 0.0,
            pitch_rate: 0.0,
            t: 0,
        };
        env.reset();
        env
    }

    pub fn reset(&mut self) -> [f32; OBS_DIM] {
        for i in 0..6 {
            self.q[i] = self.rng.normal() * 0.1;
            self.qd[i] = self.rng.normal() * 0.1;
        }
        self.vx = 0.0;
        self.vz = 0.0;
        self.height = 0.7 + self.rng.normal() * 0.02;
        self.pitch = self.rng.normal() * 0.05;
        self.pitch_rate = 0.0;
        self.t = 0;
        self.obs()
    }

    pub fn obs(&self) -> [f32; OBS_DIM] {
        let mut o = [0f32; OBS_DIM];
        o[0] = self.height as f32;
        o[1] = self.pitch as f32;
        for i in 0..6 {
            o[2 + i] = self.q[i] as f32;
        }
        o[8] = self.vx as f32;
        o[9] = self.vz as f32;
        o[10] = self.pitch_rate as f32;
        for i in 0..6 {
            o[11 + i] = self.qd[i] as f32;
        }
        o
    }

    /// Step with actions in [-1, 1]; returns (obs, reward, done).
    pub fn step(&mut self, action: &[f64; ACT_DIM]) -> ([f32; OBS_DIM], f64, bool) {
        let mut thrust = 0.0;
        for i in 0..6 {
            let a = action[i].clamp(-1.0, 1.0);
            let spring = self.q[i].clamp(-1.3, 1.3).powi(3);
            let qdd = 18.0 * a - 1.2 * self.qd[i] - 4.0 * spring;
            self.qd[i] = (self.qd[i] + self.dt * qdd).clamp(-12.0, 12.0);
            self.q[i] = (self.q[i] + self.dt * self.qd[i]).clamp(-2.0, 2.0);
        }
        for i in 0..6 {
            thrust += self.qd[i] * (self.q[i] + PHI[i]).sin() * COUPLE[i];
        }
        thrust *= 0.12;
        let stability = (-2.0 * self.pitch * self.pitch).exp();
        self.vx += self.dt * (4.0 * thrust * stability - 0.8 * self.vx);

        let asym: f64 = (0..3).map(|i| self.qd[i] - self.qd[i + 3]).sum::<f64>() * 0.01;
        self.vz = 0.9 * self.vz + asym;
        self.height = (self.height + self.dt * self.vz).clamp(0.3, 1.1);
        self.pitch_rate = 0.9 * self.pitch_rate + 0.02 * asym + 0.004 * self.rng.normal();
        self.pitch = (self.pitch + self.dt * self.pitch_rate).clamp(-1.0, 1.0);

        let ctrl_cost: f64 = action.iter().map(|a| a * a).sum::<f64>() * 0.1;
        let reward = self.vx - ctrl_cost;
        self.t += 1;
        let done = self.t >= EPISODE_LEN;
        (self.obs(), reward, done)
    }
}

/// Observation -> input codes, per the exported checkpoint's contract
/// (preproc, then the layer-0 quantizer). Split out of [`NetlistPolicy`]
/// so remote controllers can encode locally and evaluate over the wire —
/// codes are the wire currency of `kanele serve`, and encode/eval/decode
/// composed through any transport stays bit-exact with the in-process
/// policy.
pub fn encode_obs(ck: &Checkpoint, obs: &[f32; OBS_DIM]) -> Vec<u32> {
    let q = ck.quantizer(0);
    let raw: Vec<f64> = obs.iter().map(|&v| v as f64).collect();
    let pre = ck.preproc.apply(&raw);
    pre.iter().map(|&v| q.encode(v)).collect()
}

/// Netlist output sums -> actions in [-1, 1] (fixed-point decode + tanh),
/// the inverse half of the policy contract. See [`encode_obs`].
pub fn decode_action(ck: &Checkpoint, sums: &[i64]) -> [f64; ACT_DIM] {
    let mut a = [0f64; ACT_DIM];
    for i in 0..ACT_DIM {
        a[i] = from_fixed(sums[i], ck.frac_bits).tanh();
    }
    a
}

/// Hardware-in-the-loop policy: observation -> input codes -> netlist sums
/// -> tanh(action). Mirrors the exported checkpoint's contract exactly.
pub struct NetlistPolicy<'a> {
    pub ck: &'a Checkpoint,
    pub net: &'a Netlist,
}

impl<'a> NetlistPolicy<'a> {
    pub fn act(&self, obs: &[f32; OBS_DIM]) -> [f64; ACT_DIM] {
        let codes = encode_obs(self.ck, obs);
        let sums = sim::eval(self.net, &codes);
        decode_action(self.ck, &sums)
    }
}

/// Roll one episode of the netlist policy; returns total reward.
pub fn rollout(policy: &NetlistPolicy, seed: u64) -> f64 {
    let mut env = CheetahLite::new(seed);
    let mut obs = env.reset();
    let mut total = 0.0;
    loop {
        let act = policy.act(&obs);
        let (o, r, done) = env.step(&act);
        obs = o;
        total += r;
        if done {
            return total;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_deterministic_per_seed() {
        let mut a = CheetahLite::new(3);
        let mut b = CheetahLite::new(3);
        let act = [0.5, -0.5, 0.2, -0.2, 1.0, -1.0];
        for _ in 0..50 {
            let (oa, ra, _) = a.step(&act);
            let (ob, rb, _) = b.step(&act);
            assert_eq!(oa, ob);
            assert_eq!(ra, rb);
        }
    }

    #[test]
    fn episode_terminates() {
        let mut env = CheetahLite::new(1);
        let act = [0.0; 6];
        let mut steps = 0;
        loop {
            let (_, _, done) = env.step(&act);
            steps += 1;
            if done {
                break;
            }
            assert!(steps <= EPISODE_LEN);
        }
        assert_eq!(steps, EPISODE_LEN);
    }

    #[test]
    fn zero_policy_low_reward_oscillation_higher() {
        // a coordinated oscillating gait must beat doing nothing
        let mut env0 = CheetahLite::new(7);
        env0.reset();
        let mut r0 = 0.0;
        for _ in 0..400 {
            r0 += env0.step(&[0.0; 6]).1;
        }
        // feedback gait: drive each joint's velocity into phase with its
        // thrust term (qd_i ~ sin(q_i + phi_i) * couple_i maximizes thrust)
        let mut env1 = CheetahLite::new(7);
        let mut obs = env1.reset();
        let mut r1 = 0.0;
        for _ in 0..400 {
            let mut act = [0.0; 6];
            for i in 0..6 {
                let q = obs[2 + i] as f64;
                act[i] = ((q + PHI[i]).sin() * COUPLE[i]).clamp(-1.0, 1.0);
            }
            let (o, r, _) = env1.step(&act);
            obs = o;
            r1 += r;
        }
        assert!(r1 > r0 + 10.0, "gait {r1} vs idle {r0}");
    }

    #[test]
    fn obs_layout_matches_python() {
        let mut env = CheetahLite::new(11);
        env.q = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6];
        env.qd = [-0.1, -0.2, -0.3, -0.4, -0.5, -0.6];
        env.height = 0.8;
        env.pitch = 0.05;
        env.vx = 1.5;
        env.vz = -0.2;
        env.pitch_rate = 0.01;
        let o = env.obs();
        assert_eq!(o[0], 0.8);
        assert_eq!(o[2], 0.1f32);
        assert_eq!(o[7], 0.6f32);
        assert_eq!(o[8], 1.5);
        assert_eq!(o[11], -0.1f32);
        assert_eq!(o[16], -0.6f32);
    }
}
