//! PJRT-backed [`Engine`] (compiled only with `--features xla`).
//!
//! `python/compile/aot.py` lowers the quantized KAN inference function
//! (fake-quant JAX graph calling the Pallas kernel) to HLO text; here we
//! parse it with `HloModuleProto::from_text_file`, compile on the PJRT CPU
//! client, and execute from the request path.
//!
//! Text — NOT serialized protos — is the interchange format: jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects.

use std::path::Path;

use anyhow::{Context, Result};

/// A compiled HLO artifact ready to execute.
pub struct Engine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    /// Expected (batch, features) of the single input parameter.
    pub batch: usize,
    pub features: usize,
}

impl Engine {
    /// Load and compile `<name>.hlo.txt`.
    ///
    /// `batch`/`features` must match the shapes baked at lowering time
    /// (jax.jit AOT artifacts are shape-monomorphic).
    pub fn load(path: &Path, batch: usize, features: usize) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling HLO")?;
        Ok(Engine { client, exe, batch, features })
    }

    /// Execute on a full batch of `batch x features` f32 inputs.
    /// Returns the flattened f32 outputs of the first tuple element plus
    /// the number of output columns.
    pub fn run(&self, input: &[f32]) -> Result<(Vec<f32>, usize)> {
        anyhow::ensure!(
            input.len() == self.batch * self.features,
            "input length {} != {} x {}",
            input.len(),
            self.batch,
            self.features
        );
        let lit = xla::Literal::vec1(input).reshape(&[self.batch as i64, self.features as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let out = result.to_tuple1()?;
        let shape = out.array_shape()?;
        let dims = shape.dims();
        anyhow::ensure!(dims.len() == 2, "expected rank-2 output, got {dims:?}");
        let cols = dims[1] as usize;
        Ok((out.to_vec::<f32>()?, cols))
    }

    /// Run a sub-batch, padding up to the compiled batch size.
    pub fn run_padded(&self, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(rows.len() <= self.batch, "sub-batch too large");
        let mut flat = vec![0f32; self.batch * self.features];
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(r.len() == self.features, "row {} has wrong width", i);
            flat[i * self.features..(i + 1) * self.features].copy_from_slice(r);
        }
        let (out, cols) = self.run(&flat)?;
        Ok(rows
            .iter()
            .enumerate()
            .map(|(i, _)| out[i * cols..(i + 1) * cols].to_vec())
            .collect())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Raw executable access (multi-parameter artifacts like the demo).
    pub fn executable(&self) -> &xla::PjRtLoadedExecutable {
        &self.exe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifact(name: &str) -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name);
        p.exists().then_some(p)
    }

    #[test]
    fn demo_artifact_roundtrip() {
        // artifacts/model.hlo.txt is the 2x2 matmul demo from aot.py
        let Some(path) = artifact("model.hlo.txt") else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let eng = Engine::load(&path, 2, 2).unwrap();
        // demo fn(x, y) takes TWO params; use the raw executable
        let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2]).unwrap();
        let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2]).unwrap();
        let res = eng.executable().execute::<xla::Literal>(&[x, y]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        let vals = res.to_tuple1().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(vals, vec![5., 5., 9., 9.]);
    }

    #[test]
    fn kan_artifact_executes() {
        let Some(path) = artifact("moons.hlo.txt") else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let eng = Engine::load(&path, 256, 2).unwrap();
        let input = vec![0.25f32; 256 * 2];
        let (out, cols) = eng.run(&input).unwrap();
        assert_eq!(cols, 1); // moons has a single-logit head
        assert_eq!(out.len(), 256);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
