//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the float-reference path of the stack: the netlist simulator and
//! the compiled [`crate::engine`] are cross-checked against this path
//! (argmax agreement) and against the Python integer oracle (bit-exact).
//!
//! The real implementation needs the `xla` crate, which the offline build
//! image cannot fetch from a registry; it is therefore gated behind the
//! `xla` cargo feature (`pjrt` module). The default build ships an
//! API-identical stub whose [`Engine::load`] fails with an explanatory
//! error, so every caller (the e2e example, the CLI) compiles and degrades
//! gracefully to "no HLO cross-check available".

#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::Engine;

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use anyhow::{bail, Result};

    /// Stub of the PJRT engine: same surface as `pjrt::Engine`, but
    /// [`Engine::load`] always fails (build with `--features xla` and a
    /// vendored xla crate for the real thing).
    pub struct Engine {
        /// Expected (batch, features) of the single input parameter.
        pub batch: usize,
        pub features: usize,
    }

    impl Engine {
        /// Always fails: PJRT support is not compiled in.
        pub fn load(path: &Path, _batch: usize, _features: usize) -> Result<Engine> {
            bail!(
                "PJRT runtime disabled (crate built without the `xla` feature); \
                 cannot load {}",
                path.display()
            );
        }

        /// Unreachable in the stub (no `Engine` can be constructed).
        pub fn run(&self, _input: &[f32]) -> Result<(Vec<f32>, usize)> {
            bail!("PJRT runtime disabled (`xla` feature off)");
        }

        /// Unreachable in the stub (no `Engine` can be constructed).
        pub fn run_padded(&self, _rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            bail!("PJRT runtime disabled (`xla` feature off)");
        }

        pub fn platform(&self) -> String {
            "disabled".to_string()
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::Engine;
