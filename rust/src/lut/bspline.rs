//! f64 Cox-de Boor B-spline basis — exact mirror of
//! `python/compile/kan/bspline.py::bspline_basis_np` (same operation order,
//! same domain clamping, same closed right edge), so L-LUT regeneration
//! agrees with the Python oracle to the last bit modulo libm `exp`.

/// Uniform extended knot vector: G intervals over [a, b], extended by
/// `order` knots each side. Length G + 2*order + 1.
pub fn make_knots(grid_size: usize, domain: (f64, f64), order: usize) -> Vec<f64> {
    let (a, b) = domain;
    assert!(b > a, "domain must satisfy b > a");
    assert!(grid_size >= 1);
    let h = (b - a) / grid_size as f64;
    (0..grid_size + 2 * order + 1)
        .map(|i| a + (i as f64 - order as f64) * h)
        .collect()
}

/// silu(x) = x / (1 + e^-x), the Eq. 2 base activation.
pub fn silu(x: f64) -> f64 {
    x / (1.0 + (-x).exp())
}

/// Evaluate all G + S basis functions at x. Input outside the domain is
/// clamped (hardware clip). Returns a vector of length `knots.len() - 1 - order`.
pub fn bspline_basis(x: f64, knots: &[f64], order: usize) -> Vec<f64> {
    let n_knots = knots.len();
    let a = knots[order];
    let b = knots[n_knots - 1 - order];
    let x = x.clamp(a, b);

    // degree 0: half-open indicators, right edge of the domain closed
    let mut basis: Vec<f64> = (0..n_knots - 1)
        .map(|i| {
            if x >= knots[i] && x < knots[i + 1] {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    let domain_last = n_knots - 2 - order;
    if x >= b {
        // x == b belongs to the (closed) last domain interval, not to the
        // extension interval [b, b + h) that the half-open rule would pick
        // (the extension interval only exists for order >= 1).
        basis[domain_last] = 1.0;
        if order > 0 {
            basis[domain_last + 1] = 0.0;
        }
    }

    for k in 1..=order {
        let m = n_knots - k - 1;
        let mut next = vec![0.0f64; m];
        for i in 0..m {
            let ti = knots[i];
            let tik = knots[i + k];
            let ti1 = knots[i + 1];
            let tik1 = knots[i + k + 1];
            let d0 = if tik - ti > 0.0 { tik - ti } else { 1.0 };
            let d1 = if tik1 - ti1 > 0.0 { tik1 - ti1 } else { 1.0 };
            // same expression shape as the numpy twin:
            // (x - ti)/d0 * B_i + (tik1 - x)/d1 * B_{i+1}
            next[i] = (x - ti) / d0 * basis[i] + (tik1 - x) / d1 * basis[i + 1];
        }
        basis = next;
    }
    basis
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn knot_vector_shape_and_spacing() {
        let k = make_knots(6, (-8.0, 8.0), 3);
        assert_eq!(k.len(), 6 + 2 * 3 + 1);
        let h = (k[1] - k[0]).abs();
        for w in k.windows(2) {
            assert!((w[1] - w[0] - h).abs() < 1e-12);
        }
        assert!((k[3] - -8.0).abs() < 1e-12);
        assert!((k[k.len() - 4] - 8.0).abs() < 1e-12);
    }

    #[test]
    fn partition_of_unity_on_domain() {
        for (g, s) in [(4, 2), (6, 3), (30, 10)] {
            let knots = make_knots(g, (-2.0, 2.0), s);
            for i in 0..=100 {
                let x = -2.0 + 4.0 * i as f64 / 100.0;
                let b = bspline_basis(x, &knots, s);
                assert_eq!(b.len(), g + s);
                let sum: f64 = b.iter().sum();
                assert!((sum - 1.0).abs() < 1e-9, "sum {sum} at x={x} (G={g},S={s})");
            }
        }
    }

    #[test]
    fn clamps_outside_domain() {
        let knots = make_knots(6, (-8.0, 8.0), 3);
        let inside = bspline_basis(8.0, &knots, 3);
        let outside = bspline_basis(100.0, &knots, 3);
        assert_eq!(inside, outside);
    }

    #[test]
    fn basis_nonnegative() {
        prop::check("basis-nonneg", 100, |g| {
            let order = g.usize_in(0, 5);
            let grid = g.usize_in(1, 12);
            let knots = make_knots(grid, (-3.0, 3.0), order);
            let x = g.f64_in(-4.0, 4.0);
            for (i, v) in bspline_basis(x, &knots, order).iter().enumerate() {
                if *v < -1e-12 {
                    return Err(format!("basis[{i}] = {v} < 0 at x={x}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn locality_support() {
        // each basis function has support on at most order+1 intervals
        let (g, s) = (8, 3);
        let knots = make_knots(g, (0.0, 8.0), s);
        let b = bspline_basis(0.5, &knots, s); // x in interval 0
        // only the first s+1 bases can be nonzero there
        for (i, v) in b.iter().enumerate() {
            if i > s {
                assert_eq!(*v, 0.0, "basis {i} should vanish at x=0.5");
            }
        }
    }

    #[test]
    fn silu_known_values() {
        assert_eq!(silu(0.0), 0.0);
        assert!((silu(100.0) - 100.0).abs() < 1e-9);
        assert!(silu(-100.0).abs() < 1e-9);
        assert!((silu(1.0) - 1.0 / (1.0 + (-1.0f64).exp())).abs() < 1e-15);
    }
}
