//! KAN -> Logical-LUT conversion (paper §4.1.2).
//!
//! For every surviving edge the quantized input state space is enumerated
//! and the edge's pre-activation response (Eq. 2) is evaluated in f64 and
//! converted to accumulator fixed point. The operation order mirrors
//! `python/compile/export.py::edge_phi_np` exactly; the only cross-language
//! wiggle is libm `exp` in the silu term, so the extraction test tolerates
//! <=1 LSB against the checkpoint's exported tables while the *netlist*
//! always consumes whichever table set the caller selects.

pub mod bspline;

use crate::checkpoint::Checkpoint;
use crate::fixed::{self, Quantizer};

pub use bspline::{bspline_basis, make_knots, silu};

/// Truth tables for one layer: `tables[q][p]`, None for pruned edges.
#[derive(Clone, Debug)]
pub struct LayerTables {
    pub d_in: usize,
    pub d_out: usize,
    pub in_bits: u32,
    pub tables: Vec<Option<Vec<i64>>>,
}

impl LayerTables {
    pub fn at(&self, q: usize, p: usize) -> Option<&Vec<i64>> {
        self.tables[q * self.d_in + p].as_ref()
    }

    /// Min/max entry over all tables (drives adder-tree width sizing).
    pub fn entry_range(&self) -> (i64, i64) {
        let mut lo = 0i64;
        let mut hi = 0i64;
        for t in self.tables.iter().flatten() {
            for &v in t {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
        (lo, hi)
    }
}

/// Evaluate one edge's phi at `x` (Eq. 2), f64, Python-mirrored op order:
/// spline contributions accumulated in ascending k, base term added last.
pub fn edge_phi(
    x: f64,
    w_spline: &[f64],
    w_base: f64,
    knots: &[f64],
    order: usize,
) -> f64 {
    let basis = bspline_basis(x, knots, order);
    debug_assert_eq!(basis.len(), w_spline.len());
    let mut acc = 0.0f64;
    for (k, b) in basis.iter().enumerate() {
        acc += w_spline[k] * b;
    }
    acc + w_base * silu(x)
}

/// Regenerate the L-LUT truth tables of one layer from spline parameters
/// (the paper's conversion step).
pub fn extract_layer(ck: &Checkpoint, l: usize) -> LayerTables {
    let layer = &ck.layers[l];
    let in_q = Quantizer::new(layer.in_bits, ck.domain.0, ck.domain.1);
    let knots = make_knots(ck.grid_size, ck.domain, ck.order);
    let n_codes = in_q.levels() as usize;
    // precompute dequantized input values once per layer
    let xs: Vec<f64> = (0..n_codes).map(|c| in_q.decode(c as u32)).collect();
    // basis values are shared by every edge of the layer: (n_codes, n_basis)
    let basis: Vec<Vec<f64>> = xs.iter().map(|&x| bspline_basis(x, &knots, ck.order)).collect();
    let silus: Vec<f64> = xs.iter().map(|&x| silu(x)).collect();

    let mut tables = Vec::with_capacity(layer.d_out * layer.d_in);
    for q in 0..layer.d_out {
        for p in 0..layer.d_in {
            if !layer.mask_at(q, p) {
                tables.push(None);
                continue;
            }
            let ws = layer.w_spline_at(q, p);
            let wb = layer.w_base_at(q, p);
            let t: Vec<i64> = (0..n_codes)
                .map(|c| {
                    let mut acc = 0.0f64;
                    for (k, b) in basis[c].iter().enumerate() {
                        acc += ws[k] * b;
                    }
                    fixed::to_fixed(acc + wb * silus[c], ck.frac_bits)
                })
                .collect();
            tables.push(Some(t));
        }
    }
    LayerTables {
        d_in: layer.d_in,
        d_out: layer.d_out,
        in_bits: layer.in_bits,
        tables,
    }
}

/// Extract every layer.
pub fn extract_all(ck: &Checkpoint) -> Vec<LayerTables> {
    (0..ck.n_layers()).map(|l| extract_layer(ck, l)).collect()
}

/// Use the checkpoint's exported (authoritative) tables instead of
/// regenerating — bit-identical to the Python oracle by construction.
pub fn from_checkpoint(ck: &Checkpoint) -> Vec<LayerTables> {
    ck.layers
        .iter()
        .map(|layer| LayerTables {
            d_in: layer.d_in,
            d_out: layer.d_out,
            in_bits: layer.in_bits,
            tables: layer.table.clone(),
        })
        .collect()
}

/// Compare regenerated tables against the checkpoint's exported ones.
/// Returns (n_entries, n_mismatched, max_abs_diff).
pub fn compare_with_exported(ck: &Checkpoint) -> (usize, usize, i64) {
    let mut total = 0usize;
    let mut mismatched = 0usize;
    let mut max_diff = 0i64;
    for l in 0..ck.n_layers() {
        let regen = extract_layer(ck, l);
        let layer = &ck.layers[l];
        for (i, t) in regen.tables.iter().enumerate() {
            match (t, &layer.table[i]) {
                (Some(a), Some(b)) => {
                    for (x, y) in a.iter().zip(b) {
                        total += 1;
                        let d = (x - y).abs();
                        if d != 0 {
                            mismatched += 1;
                            max_diff = max_diff.max(d);
                        }
                    }
                }
                (None, None) => {}
                _ => {
                    mismatched += usize::MAX / 2; // structural mismatch: fail loudly
                }
            }
        }
    }
    (total, mismatched, max_diff)
}

/// Table statistics used by the synthesis reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct TableStats {
    pub n_tables: usize,
    pub n_constant: usize,
    pub n_entries: usize,
    pub out_width_max: u32,
}

pub fn stats(layers: &[LayerTables]) -> TableStats {
    let mut s = TableStats::default();
    for lt in layers {
        for t in lt.tables.iter().flatten() {
            s.n_tables += 1;
            s.n_entries += t.len();
            let (lo, hi) = t.iter().fold((i64::MAX, i64::MIN), |(a, b), &v| (a.min(v), b.max(v)));
            if lo == hi {
                s.n_constant += 1;
            }
            s.out_width_max = s.out_width_max.max(fixed::signed_width_range(lo, hi));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::util::prop;

    #[test]
    fn from_checkpoint_matches_layer_shapes() {
        let ck = synthetic(&[4, 3, 2], &[4, 5, 6], 3);
        let ts = from_checkpoint(&ck);
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].tables.len(), 12);
        for (i, t) in ts[0].tables.iter().enumerate() {
            assert_eq!(t.is_some(), ck.layers[0].mask[i]);
        }
    }

    #[test]
    fn extract_layer_covers_all_codes() {
        let ck = synthetic(&[3, 2], &[5, 8], 7);
        let lt = extract_layer(&ck, 0);
        for t in lt.tables.iter().flatten() {
            assert_eq!(t.len(), 32);
        }
    }

    #[test]
    fn edge_phi_zero_weights_is_zero() {
        let knots = make_knots(4, (-2.0, 2.0), 2);
        let ws = vec![0.0; 6];
        for x in [-2.0, -0.5, 0.0, 1.7, 2.0] {
            assert_eq!(edge_phi(x, &ws, 0.0, &knots, 2), 0.0);
        }
    }

    #[test]
    fn edge_phi_pure_base_is_silu() {
        let knots = make_knots(4, (-2.0, 2.0), 2);
        let ws = vec![0.0; 6];
        for x in [-1.0, 0.0, 0.5] {
            let y = edge_phi(x, &ws, 2.0, &knots, 2);
            assert!((y - 2.0 * silu(x)).abs() < 1e-15);
        }
    }

    #[test]
    fn prop_table_entries_bounded_by_weight_scale() {
        // |phi| <= sum|w_spline| * max basis (=1, partition of unity) + |w_base| * max|silu| on domain
        prop::check("lut-bounded", 50, |g| {
            let order = g.usize_in(1, 3);
            let grid = g.usize_in(2, 8);
            let knots = make_knots(grid, (-4.0, 4.0), order);
            let nb = grid + order;
            let ws: Vec<f64> = (0..nb).map(|_| g.f64_in(-2.0, 2.0)).collect();
            let wb = g.f64_in(-2.0, 2.0);
            let x = g.f64_in(-4.0, 4.0);
            let y = edge_phi(x, &ws, wb, &knots, order);
            let bound = ws.iter().map(|w| w.abs()).sum::<f64>() + wb.abs() * 4.0;
            if y.abs() > bound + 1e-9 {
                return Err(format!("phi({x}) = {y} exceeds bound {bound}"));
            }
            Ok(())
        });
    }

    #[test]
    fn stats_counts_tables() {
        let ck = synthetic(&[4, 3], &[4, 8], 11);
        let ts = from_checkpoint(&ck);
        let s = stats(&ts);
        assert_eq!(s.n_tables, ck.active_edges());
        assert_eq!(s.n_entries, ck.active_edges() * 16);
        assert!(s.out_width_max >= 1);
    }
}
