//! Table/figure renderers: regenerate every table and figure of the paper's
//! evaluation with our measured numbers printed next to the published rows.

use std::path::Path;

use anyhow::{Context, Result};

use crate::baselines::published;
use crate::checkpoint::{Checkpoint, TestSet};
use crate::config;
use crate::json;
use crate::lut;
use crate::netlist::Netlist;
use crate::sim;
use crate::synth::{self, SynthReport};
use crate::util::stats::auc;

/// Measured row for one of our builds.
#[derive(Clone, Debug)]
pub struct Measured {
    pub name: String,
    pub metric: f64, // accuracy % or AUC
    pub synth: SynthReport,
    pub edges: usize,
}

/// Build netlist + synth + accuracy for a checkpoint on its paper device.
pub fn measure(ck: &Checkpoint, device: &str, n_add: usize) -> Result<Measured> {
    let tables = lut::from_checkpoint(ck);
    let net = Netlist::build(ck, &tables, n_add);
    let dev = synth::device_by_name(device)
        .with_context(|| format!("unknown device {device}"))?;
    let report = synth::synthesize(&net, &dev);
    let metric = eval_metric(ck, &net)?;
    Ok(Measured {
        name: ck.name.clone(),
        metric,
        synth: report,
        edges: ck.active_edges(),
    })
}

/// Task-appropriate quality metric of the bit-exact netlist on the test set.
pub fn eval_metric(ck: &Checkpoint, net: &Netlist) -> Result<f64> {
    let ts_path = config::testset_path(&ck.name);
    if !ts_path.exists() {
        // fall back to embedded oracle vectors (no labels -> NaN metric)
        return Ok(f64::NAN);
    }
    let ts = TestSet::load(&ts_path)?;
    match ck.task.as_str() {
        "classify" => Ok(100.0 * sim::accuracy(net, &ts.input_codes, &ts.labels, false)),
        "binary" => Ok(100.0 * sim::accuracy(net, &ts.input_codes, &ts.labels, true)),
        "regress" => {
            // autoencoder: AUC of reconstruction error vs anomaly label
            let q_in = ck.quantizer(0);
            let mut scores = Vec::with_capacity(ts.input_codes.len());
            let mut labels = Vec::with_capacity(ts.labels.len());
            for (codes, &label) in ts.input_codes.iter().zip(&ts.labels) {
                let sums = sim::eval(net, codes);
                let mut err = 0.0;
                for (s, &c) in sums.iter().zip(codes) {
                    let rec = crate::fixed::from_fixed(*s, ck.frac_bits);
                    let inp = q_in.decode(c);
                    err += (rec - inp) * (rec - inp);
                }
                scores.push(err / sums.len() as f64);
                labels.push(label != 0);
            }
            Ok(auc(&scores, &labels))
        }
        other => anyhow::bail!("unknown task {other}"),
    }
}

fn fmt_row(
    model: &str,
    acc: f64,
    luts: u64,
    ffs: u64,
    dsps: u64,
    brams: u64,
    fmax: f64,
    lat_ns: f64,
    ad: f64,
) -> String {
    format!(
        "{model:<28} {acc:>8.1} {luts:>9} {ffs:>8} {dsps:>5} {brams:>5} {fmax:>8.0} {lat_ns:>9.1} {ad:>12.2e}"
    )
}

fn table_header(title: &str) -> String {
    format!(
        "\n=== {title} ===\n{:<28} {:>8} {:>9} {:>8} {:>5} {:>5} {:>8} {:>9} {:>12}\n{}",
        "model", "acc", "LUT", "FF", "DSP", "BRAM", "Fmax", "lat(ns)", "AreaxDelay",
        "-".repeat(100)
    )
}

/// Table 3: KANELE vs LUT-NN architectures on the three shared datasets.
pub fn table3(n_add: usize) -> Result<String> {
    let mut out = String::new();
    for ds in ["jsc_cernbox", "jsc_openml", "mnist"] {
        out.push_str(&table_header(&format!("Table 3 — {ds} (xcvu9p)")));
        out.push('\n');
        let path = config::ckpt_path(ds);
        if path.exists() {
            let ck = Checkpoint::load(&path)?;
            let m = measure(&ck, "xcvu9p", n_add)?;
            out.push_str(&fmt_row(
                "KANELE (ours, measured)",
                m.metric,
                m.synth.luts,
                m.synth.ffs,
                m.synth.dsps,
                m.synth.brams,
                m.synth.fmax_mhz,
                m.synth.latency_ns,
                m.synth.area_delay,
            ));
            out.push('\n');
        } else {
            out.push_str(&format!("(missing checkpoint {}; run `make artifacts-all`)\n", path.display()));
        }
        for r in published::table3_for(ds) {
            out.push_str(&fmt_row(
                &format!("{} (paper)", r.model),
                r.accuracy,
                r.luts,
                r.ffs,
                r.dsps,
                r.brams,
                r.fmax_mhz,
                r.latency_ns,
                r.area_delay,
            ));
            out.push('\n');
        }
        // structural baseline models (our implementations)
        use crate::baselines::{logicnets::LogicNetsCfg, polylut::PolyLutCfg};
        if ds != "mnist" {
            for rep in [
                LogicNetsCfg::jsc_l().estimate(),
                PolyLutCfg::jsc(2).estimate(),
                PolyLutCfg::jsc_add(2, 2).estimate(),
            ] {
                out.push_str(&fmt_row(
                    &format!("{} (our model)", rep.name),
                    f64::NAN,
                    rep.luts,
                    rep.ffs,
                    rep.dsps,
                    rep.brams,
                    rep.fmax_mhz,
                    rep.latency_ns,
                    rep.area_delay,
                ));
                out.push('\n');
            }
        }
    }
    Ok(out)
}

/// Table 4: vs prior KAN-FPGA works (xczu7ev).
pub fn table4(n_add: usize) -> Result<String> {
    let mut out = String::new();
    for ds in ["moons", "wine", "dry_bean"] {
        out.push_str(&table_header(&format!("Table 4 — {ds} (xczu7ev)")));
        out.push('\n');
        let path = config::ckpt_path(ds);
        if path.exists() {
            let ck = Checkpoint::load(&path)?;
            let m = measure(&ck, "xczu7ev", n_add)?;
            out.push_str(&fmt_row(
                "KANELE (ours, measured)",
                m.metric,
                m.synth.luts,
                m.synth.ffs,
                m.synth.dsps,
                m.synth.brams,
                m.synth.fmax_mhz,
                m.synth.latency_ns,
                m.synth.area_delay,
            ));
            out.push_str(&format!("  latency: {} cycles\n", m.synth.latency_cycles));
            // our Tran-et-al model for the same task
            let exp = config::experiment(ds).unwrap();
            let tran = crate::baselines::tran::TranKanCfg::for_dims(
                ds,
                &exp.dims.iter().map(|&d| d.max(2) * 4).collect::<Vec<_>>(),
                5,
                3,
            )
            .estimate();
            out.push_str(&fmt_row(
                &format!("{} (our model)", tran.name),
                f64::NAN,
                tran.luts,
                tran.ffs,
                tran.dsps,
                tran.brams,
                tran.fmax_mhz,
                tran.latency_ns,
                tran.area_delay,
            ));
            out.push('\n');
        } else {
            out.push_str(&format!("(missing checkpoint {})\n", path.display()));
        }
        for r in published::table4_for(ds) {
            out.push_str(&fmt_row(
                &format!("{} (paper)", r.model),
                r.accuracy,
                r.luts,
                r.ffs,
                r.dsps,
                r.brams,
                r.fmax_mhz,
                r.latency_ns,
                r.area_delay,
            ));
            out.push('\n');
        }
    }
    // headline ratios (§5.4)
    if config::ckpt_path("dry_bean").exists() {
        let ck = Checkpoint::load(&config::ckpt_path("dry_bean"))?;
        let m = measure(&ck, "xczu7ev", n_add)?;
        let tran = published::table4_for("dry_bean")
            .into_iter()
            .find(|r| r.model.contains("Tran"))
            .unwrap();
        out.push_str(&format!(
            "\nheadline (dry_bean): latency speedup vs Tran = {:.0}x (paper: 2670x), LUT reduction = {:.0}x (paper: 4173x)\n",
            tran.latency_ns / m.synth.latency_ns,
            tran.luts as f64 / m.synth.luts as f64
        ));
    }
    Ok(out)
}

/// Table 5: ToyADMOS vs hls4ml on xc7a100t.
pub fn table5(n_add: usize) -> Result<String> {
    let mut out = String::new();
    out.push_str("\n=== Table 5 — ToyADMOS anomaly detection (xc7a100t) ===\n");
    out.push_str(&format!(
        "{:<28} {:>6} {:>6} {:>5} {:>8} {:>8} {:>4} {:>14} {:>10} {:>10}\n{}\n",
        "model", "AUC", "BRAM", "DSP", "FF", "LUT", "II", "thrpt(inf/s)", "lat(us)", "E/inf(uJ)",
        "-".repeat(108)
    ));
    let path = config::ckpt_path("toyadmos");
    if path.exists() {
        let ck = Checkpoint::load(&path)?;
        let m = measure(&ck, "xc7a100t", n_add)?;
        out.push_str(&format!(
            "{:<28} {:>6.2} {:>6} {:>5} {:>8} {:>8} {:>4} {:>14.3e} {:>10.3} {:>10.3}\n",
            "KANELE (ours, measured)",
            m.metric,
            m.synth.brams,
            m.synth.dsps,
            m.synth.ffs,
            m.synth.luts,
            1,
            m.synth.throughput_inf_s,
            m.synth.latency_ns / 1000.0,
            m.synth.energy_per_inf_uj,
        ));
    } else {
        out.push_str("(missing toyadmos checkpoint)\n");
    }
    for r in published::TABLE5 {
        out.push_str(&format!(
            "{:<28} {:>6.2} {:>6} {:>5} {:>8} {:>8} {:>4} {:>14.3e} {:>10.3} {:>10.3}\n",
            format!("{} (paper)", r.model),
            r.auc,
            r.brams,
            r.dsps,
            r.ffs,
            r.luts,
            r.ii,
            r.throughput_inf_s,
            r.latency_us,
            r.energy_uj,
        ));
    }
    // our hls4ml model of the same AE
    let ae = crate::baselines::hls4ml::Hls4mlCfg {
        name: "hls4ml AE (our model)".into(),
        dims: vec![64, 128, 128, 128, 8, 128, 128, 128, 64],
        bits: 16,
        reuse: 16,
        resource_strategy: true,
    }
    .estimate();
    out.push_str(&format!(
        "{:<28} {:>6} {:>6} {:>5} {:>8} {:>8} {:>4} {:>14.3e} {:>10.3} {:>10}\n",
        ae.name, "-", ae.brams, ae.dsps, ae.ffs, ae.luts, 16,
        ae.fmax_mhz * 1e6 / 16.0, ae.latency_ns / 1000.0, "-",
    ));
    Ok(out)
}

/// Table 2: accuracy columns, ours vs paper.
pub fn table2() -> Result<String> {
    let mut out = String::new();
    out.push_str("\n=== Table 2 — accuracy (ours vs paper) ===\n");
    out.push_str(&format!(
        "{:<14} {:>10} {:>10} {:>12} {:>14} | {:>8} {:>8} {:>8}\n{}\n",
        "dataset", "MLP FP", "KAN FP", "KAN Q&P", "HW (netlist)", "p:MLP", "p:KAN", "p:Q&P",
        "-".repeat(96)
    ));
    let t2path = config::artifacts_dir().join("table2.json");
    let trained = t2path.exists().then(|| json::from_file(&t2path)).transpose()?;
    for row in published::TABLE2 {
        let (mlp, kanfp, kanqp, hw) = match &trained {
            Some(doc) => {
                let m = doc.get(row.dataset);
                let g = |k: &str| -> f64 {
                    m.and_then(|v| v.get(k)).and_then(|v| v.as_f64()).unwrap_or(f64::NAN)
                };
                let scale = if row.dataset == "toyadmos" { 1.0 } else { 100.0 };
                (
                    g("mlp_fp_val") * scale,
                    g("kan_fp_val") * scale,
                    g("kan_qp_val") * scale,
                    g("hw_int_metric") * scale,
                )
            }
            None => (f64::NAN, f64::NAN, f64::NAN, f64::NAN),
        };
        out.push_str(&format!(
            "{:<14} {:>10.1} {:>10.1} {:>12.1} {:>14.1} | {:>8.1} {:>8.1} {:>8.1}\n",
            row.dataset, mlp, kanfp, kanqp, hw, row.mlp_fp, row.kan_fp, row.kan_qp
        ));
    }
    if trained.is_none() {
        out.push_str("(train with `python -m compile.experiments table2` to fill the left columns)\n");
    }
    Ok(out)
}

/// Figure 6: ablation series (uses fig6_*.ckpt.json sweeps).
pub fn fig6(n_add: usize) -> Result<String> {
    let mut out = String::new();
    let dir = config::artifacts_dir();
    let fig6_meta = dir.join("fig6.json");
    out.push_str("\n=== Figure 6 — JSC OpenML ablations ===\n");
    if !fig6_meta.exists() {
        out.push_str("(run `python -m compile.experiments fig6` first)\n");
        return Ok(out);
    }
    let meta = json::from_file(&fig6_meta)?;
    out.push_str(&format!(
        "{:<16} {:>8} {:>7} {:>9} {:>9} {:>9}\n{}\n",
        "variant", "acc(%)", "edges", "LUT", "FF", "AxD",
        "-".repeat(64)
    ));
    for rec in meta.as_array().context("fig6.json not an array")? {
        let tag = rec.req_str("tag")?;
        let path = dir.join(format!("fig6_{tag}.ckpt.json"));
        if !path.exists() {
            continue;
        }
        let ck = Checkpoint::load(&path)?;
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, n_add);
        let dev = synth::device_by_name("xcvu9p").unwrap();
        let r = synth::synthesize(&net, &dev);
        out.push_str(&format!(
            "{:<16} {:>8.1} {:>7} {:>9} {:>9} {:>9.2e}\n",
            tag,
            rec.req_f64("val_acc")? * 100.0,
            ck.active_edges(),
            r.luts,
            r.ffs,
            r.area_delay,
        ));
    }
    out.push_str(
        "\nseries: (a) acc vs LUT/FF - prune_* rows | (b) edges vs LUT/FF - all rows\n\
         (c) width_* rows: LUT/FF linear in width | (d) bits_* rows: LUT exponential in bits\n",
    );
    Ok(out)
}

/// Figure 7 + Tables 6/7: RL results.
pub fn table7(n_add: usize) -> Result<String> {
    let mut out = String::new();
    out.push_str("\n=== Table 6 — actor/critic architectures ===\n");
    out.push_str("MLP Actor  [17, 64, 64, 6]   5,702 params (paper: 5,383)\n");
    out.push_str("MLP Critic [17, 64, 64, 1]   5,377 params\n");
    out.push_str("KAN Actor  [17, 6] G=6 S=3   1,020 params (paper: 1,020)\n");

    let fig7 = config::artifacts_dir().join("fig7.json");
    if fig7.exists() {
        let doc = json::from_file(&fig7)?;
        out.push_str("\n=== Figure 7 — PPO on CheetahLite (final returns, mean over seeds) ===\n");
        let mut by_kind: std::collections::BTreeMap<String, Vec<f64>> = Default::default();
        for run in doc.as_array().context("fig7.json")? {
            by_kind
                .entry(run.req_str("kind")?.to_string())
                .or_default()
                .push(run.req_f64("final_return")?);
        }
        for (kind, vals) in &by_kind {
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            let best = vals.iter().cloned().fold(f64::MIN, f64::max);
            out.push_str(&format!(
                "{kind:<8} seeds={} mean={mean:9.1} best={best:9.1}\n",
                vals.len()
            ));
        }
    } else {
        out.push_str("\n(run `python -m compile.experiments fig7` for learning curves)\n");
    }

    out.push_str("\n=== Table 7 — actor hardware on xczu7ev ===\n");
    let path = config::ckpt_path("rl_kan_actor");
    if path.exists() {
        let ck = Checkpoint::load(&path)?;
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, n_add);
        let dev = synth::device_by_name("xczu7ev").unwrap();
        let r = synth::synthesize(&net, &dev);
        out.push_str(&format!(
            "KAN 8-bit (ours):  Fmax {:.0} MHz | latency {:.1} ns ({} cyc) | LUT {} FF {} DSP {} BRAM {} | AxD {:.2e}\n",
            r.fmax_mhz, r.latency_ns, r.latency_cycles, r.luts, r.ffs, r.dsps, r.brams, r.area_delay
        ));
    } else {
        out.push_str("(run `python -m compile.experiments rl_export` for the KAN actor checkpoint)\n");
    }
    let mlp = crate::baselines::hls4ml::Hls4mlCfg {
        name: "MLP 8-bit hls4ml (our model)".into(),
        dims: vec![17, 64, 64, 6],
        bits: 8,
        reuse: 1,
        resource_strategy: true,
    }
    .estimate();
    out.push_str(&format!(
        "{}: Fmax {:.0} MHz | latency {:.1} ns | LUT {} FF {} DSP {} | AxD {:.2e}\n",
        mlp.name, mlp.fmax_mhz, mlp.latency_ns, mlp.luts, mlp.ffs, mlp.dsps, mlp.area_delay
    ));
    for r in published::TABLE7 {
        out.push_str(&format!(
            "{} (paper): reward {:.1} | Fmax {:.0} MHz | latency {:.1} ns | LUT {} FF {} DSP {} | AxD {:.2e}\n",
            r.model, r.reward, r.fmax_mhz, r.latency_ns, r.luts, r.ffs, r.dsps, r.area_delay
        ));
    }
    Ok(out)
}

/// Write a rendered report next to the artifacts.
pub fn save(name: &str, contents: &str) -> Result<std::path::PathBuf> {
    let dir = config::artifacts_dir().join("reports");
    std::fs::create_dir_all(&dir)?;
    let p = dir.join(format!("{name}.txt"));
    std::fs::write(&p, contents)?;
    Ok(p)
}

/// Render everything that has artifacts available.
pub fn all(n_add: usize) -> Result<String> {
    let mut out = String::new();
    out.push_str(&table2()?);
    out.push_str(&table3(n_add)?);
    out.push_str(&table4(n_add)?);
    out.push_str(&table5(n_add)?);
    out.push_str(&fig6(n_add)?);
    out.push_str(&table7(n_add)?);
    Ok(out)
}

#[allow(unused)]
fn _path_is_send(_: &Path) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headers_format() {
        let h = table_header("x");
        assert!(h.contains("model"));
        assert!(h.contains("AreaxDelay"));
    }

    #[test]
    fn tables_render_without_artifacts() {
        // with or without artifacts present, rendering must not error
        assert!(table2().is_ok());
        assert!(table3(2).is_ok());
        assert!(table4(2).is_ok());
        assert!(table5(2).is_ok());
        assert!(fig6(2).is_ok());
        assert!(table7(2).is_ok());
    }
}
