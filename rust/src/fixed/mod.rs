//! Fixed-point arithmetic helpers shared by the L-LUT extractor, netlist
//! simulator and synthesis estimator.
//!
//! Hardware contract (mirrors `python/compile/export.py`):
//! * accumulator values are i64 with `frac_bits` fractional bits,
//! * quantizer codes are unsigned `bits`-wide integers over a fixed domain
//!   `[lo, hi]` with scale `s = (hi - lo) / (2^bits - 1)`,
//! * rounding is floor(v + 0.5) on the non-negative shifted value (codes)
//!   and round-half-away-from-zero (table entries).

/// A uniform quantizer over a fixed domain (paper Eq. 7/8, frozen form).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quantizer {
    pub bits: u32,
    pub lo: f64,
    pub hi: f64,
}

impl Quantizer {
    pub fn new(bits: u32, lo: f64, hi: f64) -> Self {
        assert!(bits >= 1 && bits <= 32, "bits out of range: {bits}");
        assert!(hi > lo, "domain must satisfy hi > lo");
        Quantizer { bits, lo, hi }
    }

    /// Number of code levels, 2^bits.
    pub fn levels(&self) -> u64 {
        1u64 << self.bits
    }

    /// Code scale s = (hi - lo) / (2^bits - 1).
    pub fn scale(&self) -> f64 {
        (self.hi - self.lo) / (self.levels() - 1) as f64
    }

    /// Value -> code: clamp(floor((clip(v) - lo)/s + 0.5), 0, 2^bits - 1).
    pub fn encode(&self, v: f64) -> u32 {
        let c = v.clamp(self.lo, self.hi);
        let raw = ((c - self.lo) / self.scale() + 0.5).floor();
        (raw.max(0.0) as u64).min(self.levels() - 1) as u32
    }

    /// Code -> dequantized value lo + c*s.
    pub fn decode(&self, code: u32) -> f64 {
        self.lo + code as f64 * self.scale()
    }

    /// Encode an i64 accumulator value directly: the float requantization
    /// path `encode(from_fixed(sum, frac_bits))` as one call. This is the
    /// reference ORACLE that [`crate::engine::RequantPlan`] must reproduce
    /// bit-exactly with integer-only arithmetic; it is monotone
    /// nondecreasing in `sum` (`sum as f64` and [`Quantizer::encode`] both
    /// are), which is what makes the plan's exact threshold search sound.
    pub fn encode_fixed(&self, sum: i64, frac_bits: u32) -> u32 {
        self.encode(from_fixed(sum, frac_bits))
    }
}

/// Round-half-away-from-zero, the table-entry rounding rule
/// (matches Python's `round_half_away_np` and rust f64::round()).
pub fn round_half_away(v: f64) -> i64 {
    v.round() as i64
}

/// Convert a real value to the i64 accumulator fixed-point representation.
pub fn to_fixed(v: f64, frac_bits: u32) -> i64 {
    round_half_away(v * (1i64 << frac_bits) as f64)
}

/// Convert an i64 accumulator value back to a real value.
pub fn from_fixed(v: i64, frac_bits: u32) -> f64 {
    v as f64 / (1i64 << frac_bits) as f64
}

/// Minimum signed bit width that can represent `v` (two's complement).
pub fn signed_width(v: i64) -> u32 {
    if v == 0 {
        return 1;
    }
    if v > 0 {
        64 - v.leading_zeros() + 1
    } else {
        64 - (!v).leading_zeros() + 1
    }
}

/// Minimum signed width covering an inclusive range.
pub fn signed_width_range(lo: i64, hi: i64) -> u32 {
    signed_width(lo).max(signed_width(hi))
}

/// Saturating add clamped to a given signed width (hardware adder semantics
/// when the RTL config narrows the accumulator).
pub fn sat_add(a: i64, b: i64, width: u32) -> i64 {
    let hi = (1i64 << (width - 1)) - 1;
    let lo = -(1i64 << (width - 1));
    (a.saturating_add(b)).clamp(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn quantizer_roundtrip_codes() {
        let q = Quantizer::new(6, -8.0, 8.0);
        for code in 0..q.levels() as u32 {
            assert_eq!(q.encode(q.decode(code)), code);
        }
    }

    #[test]
    fn encode_clamps() {
        let q = Quantizer::new(4, -2.0, 2.0);
        assert_eq!(q.encode(-100.0), 0);
        assert_eq!(q.encode(100.0), 15);
        assert_eq!(q.encode(-2.0), 0);
        assert_eq!(q.encode(2.0), 15);
    }

    #[test]
    fn one_bit_quantizer() {
        let q = Quantizer::new(1, -8.0, 8.0);
        assert_eq!(q.levels(), 2);
        assert_eq!(q.encode(-8.0), 0);
        assert_eq!(q.encode(8.0), 1);
        assert_eq!(q.encode(0.1), 1); // midpoint rounds up
    }

    #[test]
    fn rounding_matches_python_rule() {
        assert_eq!(round_half_away(0.5), 1);
        assert_eq!(round_half_away(-0.5), -1);
        assert_eq!(round_half_away(1.5), 2);
        assert_eq!(round_half_away(-1.5), -2);
        assert_eq!(round_half_away(2.4), 2);
    }

    #[test]
    fn fixed_roundtrip() {
        for v in [-3.75, 0.0, 1.0 / 3.0, 100.125] {
            let f = to_fixed(v, 14);
            assert!((from_fixed(f, 14) - v).abs() <= 0.5 / (1 << 14) as f64 + 1e-12);
        }
    }

    #[test]
    fn widths() {
        assert_eq!(signed_width(0), 1);
        assert_eq!(signed_width(1), 2);
        assert_eq!(signed_width(-1), 1);
        assert_eq!(signed_width(127), 8);
        assert_eq!(signed_width(-128), 8);
        assert_eq!(signed_width(128), 9);
        assert_eq!(signed_width_range(-128, 127), 8);
        assert_eq!(signed_width_range(-129, 0), 9);
    }

    #[test]
    fn sat_add_saturates() {
        assert_eq!(sat_add(100, 100, 8), 127);
        assert_eq!(sat_add(-100, -100, 8), -128);
        assert_eq!(sat_add(3, 4, 8), 7);
    }

    #[test]
    fn prop_encode_monotone() {
        prop::check("quantizer-monotone", 200, |g| {
            let bits = g.usize_in(1, 10) as u32;
            let lo = g.f64_in(-10.0, 0.0);
            let hi = lo + g.f64_in(0.5, 20.0);
            let q = Quantizer::new(bits, lo, hi);
            let a = g.f64_in(lo - 2.0, hi + 2.0);
            let b = g.f64_in(lo - 2.0, hi + 2.0);
            let (a, b) = if a <= b { (a, b) } else { (b, a) };
            if q.encode(a) > q.encode(b) {
                return Err(format!("encode not monotone: {a} -> {}, {b} -> {}", q.encode(a), q.encode(b)));
            }
            Ok(())
        });
    }

    #[test]
    fn encode_fixed_is_encode_of_from_fixed() {
        let q = Quantizer::new(5, -4.0, 4.0);
        for frac in [0u32, 4, 12, 20] {
            for sum in [i64::MIN, -(1 << 40), -129, -1, 0, 1, 77, 1 << 40, i64::MAX] {
                assert_eq!(q.encode_fixed(sum, frac), q.encode(from_fixed(sum, frac)));
            }
        }
    }

    #[test]
    fn prop_encode_fixed_monotone_in_sum() {
        // the property RequantPlan's bisection relies on
        prop::check("encode-fixed-monotone", 200, |g| {
            let bits = g.usize_in(1, 12) as u32;
            let lo = g.f64_in(-50.0, 0.0);
            let hi = lo + g.f64_in(0.01, 100.0);
            let frac = g.usize_in(0, 24) as u32;
            let q = Quantizer::new(bits, lo, hi);
            let a = g.i64_in(-(1 << 40), 1 << 40);
            let b = g.i64_in(-(1 << 40), 1 << 40);
            let (a, b) = if a <= b { (a, b) } else { (b, a) };
            if q.encode_fixed(a, frac) > q.encode_fixed(b, frac) {
                return Err(format!("not monotone: {a} -> {}, {b} -> {}",
                    q.encode_fixed(a, frac), q.encode_fixed(b, frac)));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_decode_in_domain() {
        prop::check("decode-in-domain", 100, |g| {
            let bits = g.usize_in(1, 12) as u32;
            let q = Quantizer::new(bits, -4.0, 4.0);
            let c = g.i64_in(0, q.levels() as i64 - 1) as u32;
            let v = q.decode(c);
            if v < q.lo - 1e-12 || v > q.hi + 1e-12 {
                return Err(format!("decode({c}) = {v} outside domain"));
            }
            Ok(())
        });
    }
}
