//! Hot-swappable netlists (paper §6 future work: "hot-swapping edge tables
//! via partial reconfiguration or LUT updates, enabling lightweight online
//! learning with minimal latency").
//!
//! On a real FPGA this is a partial-reconfiguration write to one LUT ROM;
//! here it is an atomic pointer swap: readers (`load`) grab the current
//! `Arc<Netlist>` per batch and are never torn, writers build the updated
//! netlist and publish it. In-flight batches finish on the old tables —
//! exactly the semantics of a PR region swap between inferences.

use std::sync::{Arc, RwLock};

use anyhow::{bail, Result};

use super::Netlist;
use crate::fixed::signed_width_range;

/// Shared, swappable handle to a netlist.
pub struct NetlistCell {
    inner: RwLock<Arc<Netlist>>,
    swaps: std::sync::atomic::AtomicU64,
}

impl NetlistCell {
    pub fn new(net: Arc<Netlist>) -> Self {
        NetlistCell {
            inner: RwLock::new(net),
            swaps: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Current netlist snapshot (cheap: one Arc clone).
    pub fn load(&self) -> Arc<Netlist> {
        self.inner.read().unwrap().clone()
    }

    /// Number of successful swaps so far.
    pub fn swap_count(&self) -> u64 {
        self.swaps.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Input width of the current snapshot (request admission validation;
    /// avoids the `Arc` clone of a full [`load`](Self::load)).
    pub fn input_width(&self) -> usize {
        self.inner.read().unwrap().input_width()
    }

    /// Replace the whole netlist (e.g. a freshly retrained checkpoint).
    pub fn replace(&self, net: Arc<Netlist>) {
        *self.inner.write().unwrap() = net;
        self.swaps.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// Swap one edge's truth table: layer `l`, output neuron `q`, input `p`.
    /// The new table must have exactly `2^in_bits` entries. Sum widths and
    /// adder metadata are recomputed for the affected neuron.
    pub fn swap_edge(&self, l: usize, q: usize, p: usize, table: Vec<i64>) -> Result<()> {
        let current = self.load();
        if l >= current.layers.len() {
            bail!("layer {l} out of range");
        }
        let layer = &current.layers[l];
        if q >= layer.neurons.len() {
            bail!("neuron {q} out of range in layer {l}");
        }
        let expect = 1usize << layer.in_bits;
        if table.len() != expect {
            bail!("table must have {expect} entries, got {}", table.len());
        }
        let mut net = (*current).clone();
        let neuron = &mut net.layers[l].neurons[q];
        let Some(lut) = neuron.luts.iter_mut().find(|lt| lt.input == p) else {
            bail!("neuron {q} of layer {l} has no active edge from input {p} (pruned edges cannot be hot-added without re-synthesis)");
        };
        let (lo, hi) = table
            .iter()
            .fold((i64::MAX, i64::MIN), |(a, b), &v| (a.min(v), b.max(v)));
        lut.out_width = signed_width_range(lo.min(0), hi.max(0));
        lut.table = table;
        // recompute the neuron's sum width (exact per-table extremes + bias)
        let (sum_lo, sum_hi) = neuron.luts.iter().fold((neuron.bias, neuron.bias), |(a, b), lt| {
            let (l2, h2) = lt
                .table
                .iter()
                .fold((i64::MAX, i64::MIN), |(x, y), &v| (x.min(v), y.max(v)));
            (a + l2, b + h2)
        });
        neuron.sum_width = signed_width_range(sum_lo.min(0), sum_hi.max(0));
        self.replace(Arc::new(net));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::lut;
    use crate::sim;

    fn cell(seed: u64) -> (crate::checkpoint::Checkpoint, NetlistCell) {
        let ck = synthetic(&[3, 2], &[3, 6], seed);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        (ck, NetlistCell::new(Arc::new(net)))
    }

    #[test]
    fn swap_changes_function_only_through_that_edge() {
        let (_, cell) = cell(1);
        let before = cell.load();
        // find an active edge on neuron 0
        let p = before.layers[0].neurons[0].luts[0].input;
        let n_codes = 1usize << before.layers[0].in_bits;
        let new_table = vec![12345i64; n_codes];
        cell.swap_edge(0, 0, p, new_table.clone()).unwrap();
        let after = cell.load();
        assert_eq!(cell.swap_count(), 1);
        let codes = vec![0u32; 3];
        let a = sim::eval(&before, &codes);
        let b = sim::eval(&after, &codes);
        assert_ne!(a[0], b[0]);
        // old snapshot unchanged (in-flight batches safe)
        assert_eq!(sim::eval(&before, &codes), a);
    }

    #[test]
    fn swap_validates_shape_and_indices() {
        let (_, cell) = cell(2);
        assert!(cell.swap_edge(9, 0, 0, vec![0; 8]).is_err());
        assert!(cell.swap_edge(0, 9, 0, vec![0; 8]).is_err());
        assert!(cell.swap_edge(0, 0, 0, vec![0; 3]).is_err());
    }

    #[test]
    fn swap_updates_widths() {
        let (_, cell) = cell(3);
        let p = cell.load().layers[0].neurons[0].luts[0].input;
        let n_codes = 1usize << cell.load().layers[0].in_bits;
        cell.swap_edge(0, 0, p, vec![1i64 << 40; n_codes]).unwrap();
        let after = cell.load();
        let neuron = &after.layers[0].neurons[0];
        assert!(neuron.sum_width >= 42, "width {}", neuron.sum_width);
    }

    #[test]
    fn concurrent_readers_never_torn() {
        let (ck, cell) = cell(4);
        let cell = Arc::new(cell);
        let n_codes = 1usize << ck.bits[0];
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let cell = Arc::clone(&cell);
            handles.push(std::thread::spawn(move || {
                for i in 0..200 {
                    if t == 0 && i % 10 == 0 {
                        let net = cell.load();
                        let p = net.layers[0].neurons[0].luts[0].input;
                        cell.swap_edge(0, 0, p, vec![i as i64; n_codes]).unwrap();
                    } else {
                        let net = cell.load();
                        let out = sim::eval(&net, &[0, 1, 2]);
                        assert_eq!(out.len(), 2);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(cell.swap_count() >= 20);
    }
}
