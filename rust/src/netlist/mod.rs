//! Hardware netlist IR (paper §4.1.3 + §4.2).
//!
//! Structure generated from the L-LUT graph:
//!
//! * per output neuron: the surviving edge LUTs feeding it, a **balanced
//!   pipelined adder tree** combining up to `n_add` operands per stage
//!   (registers after every stage), and
//! * between layers: the requantize/saturate node + pipeline register.
//!
//! Latency (cycles) = 1 input register + sum over layers of
//! (1 LUT-read stage + adder-tree depth), with depth
//! `ceil(log_{n_add} max(fan_in, 1))`. The cycle-accurate simulator in
//! [`crate::sim`] executes exactly this schedule; [`crate::synth`] prices it.

pub mod hotswap;
pub mod opt;

use crate::checkpoint::Checkpoint;
use crate::fixed::{signed_width_range, Quantizer};
use crate::lut::LayerTables;

/// One instantiated edge LUT.
#[derive(Clone, Debug)]
pub struct LutInst {
    /// Index of the input neuron this LUT reads (its address port).
    pub input: usize,
    /// 2^in_bits truth-table entries (accumulator fixed point).
    pub table: Vec<i64>,
    /// Minimum signed width of the table's entries.
    pub out_width: u32,
}

/// One output neuron: LUTs + balanced adder tree.
#[derive(Clone, Debug)]
pub struct NeuronNet {
    pub luts: Vec<LutInst>,
    /// Compile-time constant operand (introduced by constant-table folding
    /// in [`opt`]; 0 for freshly built netlists).
    pub bias: i64,
    /// Adder tree depth at this neuron (0 when <= 1 operand).
    pub depth: usize,
    /// Signed width of the final sum.
    pub sum_width: u32,
}

/// One layer of the netlist.
#[derive(Clone, Debug)]
pub struct LayerNet {
    pub d_in: usize,
    pub d_out: usize,
    pub in_bits: u32,
    pub out_bits: u32,
    pub neurons: Vec<NeuronNet>,
    /// Requantizer to the next layer's input codes; None for the output layer.
    pub requant: Option<Quantizer>,
    /// Max adder depth across neurons = the layer's pipeline depth.
    pub depth: usize,
}

/// Full netlist.
#[derive(Clone, Debug)]
pub struct Netlist {
    pub name: String,
    pub layers: Vec<LayerNet>,
    pub n_add: usize,
    pub frac_bits: u32,
    pub domain: (f64, f64),
}

/// Adder tree depth for `n` operands combining up to `n_add` per stage.
pub fn adder_depth(n: usize, n_add: usize) -> usize {
    assert!(n_add >= 2);
    if n <= 1 {
        return 0;
    }
    let mut ops = n;
    let mut d = 0;
    while ops > 1 {
        ops = ops.div_ceil(n_add);
        d += 1;
    }
    d
}

impl Netlist {
    /// Build from extracted tables + checkpoint metadata.
    pub fn build(ck: &Checkpoint, tables: &[LayerTables], n_add: usize) -> Netlist {
        assert_eq!(tables.len(), ck.n_layers());
        assert!(n_add >= 2, "adder tree needs n_add >= 2");
        let mut layers = Vec::with_capacity(ck.n_layers());
        for (l, lt) in tables.iter().enumerate() {
            let mut neurons = Vec::with_capacity(lt.d_out);
            for q in 0..lt.d_out {
                let mut luts = Vec::new();
                for p in 0..lt.d_in {
                    if let Some(t) = lt.at(q, p) {
                        let (lo, hi) = t.iter().fold((i64::MAX, i64::MIN), |(a, b), &v| {
                            (a.min(v), b.max(v))
                        });
                        luts.push(LutInst {
                            input: p,
                            table: t.clone(),
                            out_width: if lo > hi { 1 } else { signed_width_range(lo, hi) },
                        });
                    }
                }
                // sum range: sum of per-table extremes (exact bound)
                let (sum_lo, sum_hi) = luts.iter().fold((0i64, 0i64), |(a, b), lut| {
                    let (lo, hi) = lut
                        .table
                        .iter()
                        .fold((i64::MAX, i64::MIN), |(x, y), &v| (x.min(v), y.max(v)));
                    (a + lo, b + hi)
                });
                let depth = adder_depth(luts.len(), n_add);
                neurons.push(NeuronNet {
                    bias: 0,
                    depth,
                    sum_width: signed_width_range(sum_lo.min(0), sum_hi.max(0)),
                    luts,
                });
            }
            let depth = neurons.iter().map(|n| n.depth).max().unwrap_or(0);
            layers.push(LayerNet {
                d_in: lt.d_in,
                d_out: lt.d_out,
                in_bits: lt.in_bits,
                out_bits: ck.bits[l + 1],
                neurons,
                requant: if l + 1 < ck.n_layers() {
                    Some(ck.quantizer(l + 1))
                } else {
                    None
                },
                depth,
            });
        }
        Netlist {
            name: ck.name.clone(),
            layers,
            n_add,
            frac_bits: ck.frac_bits,
            domain: ck.domain,
        }
    }

    /// Pipeline latency in cycles: input register + per-layer LUT stage +
    /// adder stages (balanced across neurons: every neuron is padded to the
    /// layer's max depth by the register insertion pass).
    pub fn latency_cycles(&self) -> usize {
        1 + self
            .layers
            .iter()
            .map(|l| 1 + l.depth)
            .sum::<usize>()
    }

    /// Input width (codes per sample); 0 for an empty netlist.
    pub fn input_width(&self) -> usize {
        self.layers.first().map(|l| l.d_in).unwrap_or(0)
    }

    /// Total L-LUT instances.
    pub fn n_luts(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.neurons.iter().map(|n| n.luts.len()).sum::<usize>())
            .sum()
    }

    /// Total adder count (nodes of every reduction tree).
    pub fn n_adders(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| l.neurons.iter())
            .map(|n| {
                // a tree over k operands with arity n_add has ceil((k-1)/(n_add-1)) nodes
                if n.luts.len() <= 1 {
                    0
                } else {
                    (n.luts.len() - 1).div_ceil(self.n_add - 1)
                }
            })
            .sum()
    }

    /// Dead-input detection: inputs of layer `l` read by no LUT (feed
    /// nothing). For `l == 0` these are external features; for interior
    /// layers they are unread producer neurons of layer `l - 1`. This is
    /// the entry point of the engine's dead-code-elimination pass
    /// ([`crate::engine::optim`]) and of the register-saving count in
    /// [`opt::optimize`].
    pub fn dead_inputs(&self, l: usize) -> Vec<usize> {
        let layer = &self.layers[l];
        let mut used = vec![false; layer.d_in];
        for n in &layer.neurons {
            for lut in &n.luts {
                used[lut.input] = true;
            }
        }
        (0..layer.d_in).filter(|&p| !used[p]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::lut;
    use crate::util::prop;

    #[test]
    fn adder_depth_cases() {
        assert_eq!(adder_depth(0, 2), 0);
        assert_eq!(adder_depth(1, 2), 0);
        assert_eq!(adder_depth(2, 2), 1);
        assert_eq!(adder_depth(3, 2), 2);
        assert_eq!(adder_depth(8, 2), 3);
        assert_eq!(adder_depth(9, 2), 4);
        assert_eq!(adder_depth(16, 4), 2);
        assert_eq!(adder_depth(17, 4), 3);
    }

    #[test]
    fn build_from_synthetic() {
        let ck = synthetic(&[4, 3, 2], &[4, 5, 6], 9);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        assert_eq!(net.layers.len(), 2);
        assert_eq!(net.n_luts(), ck.active_edges());
        assert!(net.latency_cycles() >= 3);
        // requant only between layers
        assert!(net.layers[0].requant.is_some());
        assert!(net.layers[1].requant.is_none());
    }

    #[test]
    fn sum_width_covers_extremes() {
        let ck = synthetic(&[5, 2], &[4, 8], 21);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        for neuron in &net.layers[0].neurons {
            let worst_pos: i64 = neuron
                .luts
                .iter()
                .map(|l| *l.table.iter().max().unwrap())
                .sum();
            let worst_neg: i64 = neuron
                .luts
                .iter()
                .map(|l| *l.table.iter().min().unwrap())
                .sum();
            let w = neuron.sum_width;
            let hi = (1i64 << (w - 1)) - 1;
            let lo = -(1i64 << (w - 1));
            assert!(worst_pos <= hi, "{worst_pos} > {hi}");
            assert!(worst_neg >= lo, "{worst_neg} < {lo}");
        }
    }

    #[test]
    fn prop_adder_nodes_and_depth_consistent() {
        prop::check("adder-tree", 200, |g| {
            let n = g.usize_in(0, 64);
            let n_add = g.usize_in(2, 6);
            let d = adder_depth(n, n_add);
            // depth property: n_add^d >= n for n >= 1
            if n >= 1 && n_add.pow(d as u32) < n {
                return Err(format!("depth {d} too small for {n} ops arity {n_add}"));
            }
            if n >= 2 && n_add.pow((d - 1) as u32) >= n {
                return Err(format!("depth {d} not minimal for {n} ops arity {n_add}"));
            }
            Ok(())
        });
    }

    #[test]
    fn dead_inputs_on_fresh_synthetic() {
        // a fully connected column is never dead; a fully pruned column is
        let mut ck = synthetic(&[4, 3, 2], &[4, 5, 6], 123);
        // prune every edge reading input 2 of layer 0
        let l = &mut ck.layers[0];
        for q in 0..l.d_out {
            l.mask[q * l.d_in + 2] = false;
            l.table[q * l.d_in + 2] = None;
        }
        // and make input 0 fully connected
        let n_codes = 1usize << ck.bits[0];
        for q in 0..l.d_out {
            l.mask[q * l.d_in] = true;
            l.table[q * l.d_in] = Some(vec![q as i64 + 1; n_codes]);
        }
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        let dead = net.dead_inputs(0);
        assert!(dead.contains(&2), "{dead:?}");
        assert!(!dead.contains(&0), "{dead:?}");
        // every reported index really has no reader
        for &p in &dead {
            for n in &net.layers[0].neurons {
                assert!(n.luts.iter().all(|l| l.input != p));
            }
        }
        // ... and every unreported index has at least one
        for p in 0..net.layers[0].d_in {
            if !dead.contains(&p) {
                assert!(net.layers[0]
                    .neurons
                    .iter()
                    .any(|n| n.luts.iter().any(|l| l.input == p)));
            }
        }
    }

    #[test]
    fn dead_inputs_interior_layer_and_bounds() {
        let mut ck = synthetic(&[3, 4, 2], &[3, 4, 6], 321);
        // prune layer 1's reads of its input 1 (= layer-0 neuron 1)
        let l = &mut ck.layers[1];
        for q in 0..l.d_out {
            l.mask[q * l.d_in + 1] = false;
            l.table[q * l.d_in + 1] = None;
        }
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        assert!(net.dead_inputs(1).contains(&1));
        // a layer with every edge alive reports nothing
        let mut full = synthetic(&[2, 2], &[3, 6], 5);
        let n_codes = 1usize << full.bits[0];
        let l = &mut full.layers[0];
        for i in 0..l.mask.len() {
            l.mask[i] = true;
            l.table[i] = Some(vec![i as i64; n_codes]);
        }
        let tables = lut::from_checkpoint(&full);
        let net = Netlist::build(&full, &tables, 2);
        assert!(net.dead_inputs(0).is_empty());
        // results are sorted and in-range (callers build remap tables)
        let dead = net.dead_inputs(0);
        assert!(dead.windows(2).all(|w| w[0] < w[1]));
        assert!(dead.iter().all(|&p| p < net.layers[0].d_in));
    }

    #[test]
    fn latency_grows_with_narrower_adders() {
        let ck = synthetic(&[16, 4, 2], &[4, 5, 6], 33);
        let tables = lut::from_checkpoint(&ck);
        let wide = Netlist::build(&ck, &tables, 6).latency_cycles();
        let narrow = Netlist::build(&ck, &tables, 2).latency_cycles();
        assert!(narrow >= wide);
    }
}
