//! Netlist optimization passes (design-choice ablations in DESIGN.md):
//!
//! * **constant-table folding** — a pruned/QAT'd edge whose truth table is
//!   a single constant contributes a compile-time bias, not a LUT: fold it
//!   into the neuron's bias operand and delete the LUT + its register.
//! * **duplicate-table sharing** — identical (input, table) pairs within a
//!   neuron collapse to one LUT with a x2 weight... which for tables means
//!   doubling entries; within a *layer* across neurons, identical pairs
//!   can share one physical LUT when the device allows multi-fanout reads
//!   (always true for LUTROMs). We count shareable duplicates and expose
//!   the saving; the builder keeps them separate for timing fidelity, so
//!   sharing is reported as an optimization option (`SharingReport`).
//! * **dead-input pruning** — inputs read by no LUT need no input register.
//!
//! All passes preserve bit-exactness: `sim::eval` results are identical
//! before and after (tested below).

use std::collections::HashMap;

use super::{LutInst, Netlist};

/// Result of running [`optimize`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OptReport {
    pub constant_tables_folded: usize,
    pub dead_inputs: usize,
    pub shareable_duplicates: usize,
}

/// Per-neuron constant bias introduced by folding (added to the adder tree
/// as a compile-time operand; the simulator adds it after the LUT gather).
pub fn optimize(net: &mut Netlist) -> OptReport {
    let mut report = OptReport::default();
    for layer in &mut net.layers {
        // share-detection across the layer: (input, table) -> count
        let mut seen: HashMap<(usize, &[i64]), usize> = HashMap::new();
        for neuron in &layer.neurons {
            for lut in &neuron.luts {
                *seen.entry((lut.input, lut.table.as_slice())).or_default() += 1;
            }
        }
        report.shareable_duplicates += seen.values().filter(|&&c| c > 1).map(|c| c - 1).sum::<usize>();

        for neuron in &mut layer.neurons {
            let (constants, kept): (Vec<LutInst>, Vec<LutInst>) = neuron
                .luts
                .drain(..)
                .partition(|l| l.table.iter().all(|&v| v == l.table[0]));
            let bias: i64 = constants.iter().map(|l| l.table[0]).sum();
            report.constant_tables_folded += constants.len();
            neuron.luts = kept;
            neuron.bias = neuron.bias + bias;
            // depth may shrink with fewer operands
            neuron.depth = super::adder_depth(
                neuron.luts.len() + usize::from(neuron.bias != 0),
                net.n_add,
            );
        }
        layer.depth = layer.neurons.iter().map(|n| n.depth).max().unwrap_or(0);
    }
    // dead inputs (after folding)
    for l in 0..net.layers.len() {
        report.dead_inputs += net.dead_inputs(l).len();
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::lut;
    use crate::sim;
    use crate::util::Rng;

    fn make_net_with_constants(seed: u64) -> (crate::checkpoint::Checkpoint, Netlist) {
        let mut ck = synthetic(&[4, 3, 2], &[4, 5, 6], seed);
        // force two constant tables in layer 0
        let n_codes = 1usize << ck.bits[0];
        ck.layers[0].table[0] = Some(vec![42; n_codes]);
        ck.layers[0].table[1] = Some(vec![-7; n_codes]);
        ck.layers[0].mask[0] = true;
        ck.layers[0].mask[1] = true;
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        (ck, net)
    }

    #[test]
    fn folding_preserves_function() {
        let (ck, net) = make_net_with_constants(3);
        let mut optimized = net.clone();
        let report = optimize(&mut optimized);
        assert!(report.constant_tables_folded >= 2);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(1 << ck.bits[0]) as u32).collect();
            assert_eq!(sim::eval(&net, &codes), sim::eval(&optimized, &codes));
        }
    }

    #[test]
    fn folding_reduces_resources() {
        let (_, net) = make_net_with_constants(5);
        let mut optimized = net.clone();
        optimize(&mut optimized);
        assert!(optimized.n_luts() < net.n_luts());
        let dev = crate::synth::XCVU9P;
        let before = crate::synth::synthesize(&net, &dev);
        let after = crate::synth::synthesize(&optimized, &dev);
        assert!(after.luts < before.luts, "{} !< {}", after.luts, before.luts);
    }

    #[test]
    fn idempotent() {
        let (_, net) = make_net_with_constants(7);
        let mut a = net.clone();
        optimize(&mut a);
        let mut b = a.clone();
        let r2 = optimize(&mut b);
        assert_eq!(r2.constant_tables_folded, 0);
        assert_eq!(a.n_luts(), b.n_luts());
    }

    #[test]
    fn duplicate_detection() {
        let mut ck = synthetic(&[2, 3], &[3, 6], 11);
        let t = vec![1i64, 2, 3, 4, 5, 6, 7, 8];
        for q in 0..3 {
            ck.layers[0].table[q * 2] = Some(t.clone());
            ck.layers[0].mask[q * 2] = true;
        }
        let tables = lut::from_checkpoint(&ck);
        let mut net = Netlist::build(&ck, &tables, 2);
        let report = optimize(&mut net);
        assert!(report.shareable_duplicates >= 2, "{report:?}");
    }

    #[test]
    fn cycle_sim_still_matches_after_opt() {
        let (ck, net) = make_net_with_constants(13);
        let mut optimized = net.clone();
        optimize(&mut optimized);
        let mut rng = Rng::new(2);
        let inputs: Vec<Vec<u32>> = (0..20)
            .map(|_| (0..4).map(|_| rng.below(1 << ck.bits[0]) as u32).collect())
            .collect();
        let mut cs = sim::CycleSim::new(&optimized);
        for c in cs.run_stream(&inputs) {
            assert_eq!(c.sums, sim::eval(&net, &inputs[c.id as usize]));
        }
    }
}
