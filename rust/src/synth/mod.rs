//! Synthesis + place-and-route estimator — the Vivado 2024.1 substitute
//! (DESIGN.md §3).
//!
//! The paper's hardware metrics (P-LUT count, FF count, Fmax, latency,
//! Area x Delay, power) are *structural* functions of the L-LUT netlist.
//! This module implements the same arithmetic Vivado applies to ROM-style
//! logic on UltraScale+/7-series fabrics:
//!
//! * **Technology mapping** — an A-address-bit, W-output-bit logical LUT
//!   maps to fracturable 6-input physical LUTs: `ceil(W/2)` for A <= 5
//!   (LUT6_2, two 5-input functions sharing inputs), `W` for A = 6, and
//!   `W * 2^(A-6)` for 6 < A <= 9 (free F7/F8/F9 muxes), beyond that extra
//!   mux LUTs.
//! * **Adders** — one LUT per result bit per 2-operand add (carry chain);
//!   an `n_add`-ary stage over m operands costs `(m-1) * width` LUTs.
//! * **FFs** — every pipeline register bit (codes, adder stages, requant).
//! * **Timing** — per-stage delay model (logic + net + clocking overhead),
//!   Fmax = min(1 / critical_stage, device clock ceiling).
//! * **Power** — dynamic power proportional to toggling LUT/FF count and
//!   clock, calibrated against the paper's Table 5 (xc7a100t).
//!
//! Calibration quality is reported in EXPERIMENTS.md (paper-vs-model); the
//! comparisons the paper draws (who wins, by what factor) depend on netlist
//! structure, which is exact.

use crate::fixed::signed_width_range;
use crate::netlist::Netlist;

/// FPGA device description.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub name: &'static str,
    pub luts: u64,
    pub ffs: u64,
    pub brams: u64,
    pub dsps: u64,
    /// Fabric speed scale (1.0 = UltraScale+ -2; 7-series is slower).
    pub delay_scale: f64,
    /// Global clock ceiling in GHz.
    pub fmax_ceiling_ghz: f64,
    /// Dynamic power coefficients, W per (resource * GHz).
    pub p_lut_w_per_ghz: f64,
    pub p_ff_w_per_ghz: f64,
}

/// xcvu9p-flgb2104-2-i — the LUT-NN benchmarking part (paper Table 3).
pub const XCVU9P: Device = Device {
    name: "xcvu9p-flgb2104-2-i",
    luts: 1_182_240,
    ffs: 2_364_480,
    brams: 2_160,
    dsps: 6_840,
    delay_scale: 1.0,
    fmax_ceiling_ghz: 1.85,
    p_lut_w_per_ghz: 0.22e-3,
    p_ff_w_per_ghz: 0.08e-3,
};

/// xczu7ev-ffvc1156-2-e — prior-KAN-work comparison part (paper Table 4/7).
pub const XCZU7EV: Device = Device {
    name: "xczu7ev-ffvc1156-2-e",
    luts: 230_400,
    ffs: 460_800,
    brams: 312,
    dsps: 1_728,
    delay_scale: 1.0,
    fmax_ceiling_ghz: 1.80,
    p_lut_w_per_ghz: 0.22e-3,
    p_ff_w_per_ghz: 0.08e-3,
};

/// xc7a100t-1csg324 — MLPerf-Tiny part (paper Table 5; Artix-7, slower).
pub const XC7A100T: Device = Device {
    name: "xc7a100t-1csg324",
    luts: 63_400,
    ffs: 126_800,
    brams: 135,
    dsps: 240,
    delay_scale: 2.4,
    fmax_ceiling_ghz: 0.65,
    p_lut_w_per_ghz: 0.30e-3,
    p_ff_w_per_ghz: 0.10e-3,
};

pub fn device_by_name(name: &str) -> Option<Device> {
    match name {
        "xcvu9p" | "xcvu9p-flgb2104-2-i" => Some(XCVU9P),
        "xczu7ev" | "xczu7ev-ffvc1156-2-e" => Some(XCZU7EV),
        "xc7a100t" | "xc7a100t-1csg324" => Some(XC7A100T),
        _ => None,
    }
}

/// Physical LUT cost of one logical LUT: A address bits -> W output bits.
pub fn plut_cost(addr_bits: u32, out_bits: u32) -> u64 {
    let w = out_bits as u64;
    match addr_bits {
        0 => 0, // constant: folded into downstream logic
        1..=5 => w.div_ceil(2),
        6 => w,
        7..=9 => w << (addr_bits - 6),
        // beyond F9: mux tree in fabric LUTs (3 leaves per extra LUT3 level)
        a => {
            let base = w << 3; // 2^(9-6) per bit at the F9 boundary
            let extra_factor = 1u64 << (a - 9);
            base * extra_factor + w * (extra_factor - 1)
        }
    }
}

/// Full resource/timing/power report (one paper-table row).
#[derive(Clone, Debug)]
pub struct SynthReport {
    pub device: &'static str,
    pub luts: u64,
    pub ffs: u64,
    pub brams: u64,
    pub dsps: u64,
    pub fmax_mhz: f64,
    pub latency_cycles: usize,
    pub latency_ns: f64,
    pub area_delay: f64,
    /// Dynamic power at Fmax, watts.
    pub dyn_power_w: f64,
    /// Energy per inference at II=1, microjoules.
    pub energy_per_inf_uj: f64,
    /// Throughput at II=1, inferences/second.
    pub throughput_inf_s: f64,
    pub fits: bool,
}

/// Per-stage delay model (nanoseconds, UltraScale+ -2 baseline).
mod delay {
    /// LUT-read stage: logic + local route; extra mux levels past 6 inputs.
    pub fn lut_stage(addr_bits: u32) -> f64 {
        let mux_levels = addr_bits.saturating_sub(6) as f64;
        0.29 + 0.10 * mux_levels
    }

    /// Carry-chain adder delay for one stage at the given result width,
    /// combining up to n_add operands (n_add-1 chained adds worst case
    /// within a stage is avoided by the tree, so one add + mux margin).
    pub fn adder_stage(width: u32, n_add: usize) -> f64 {
        0.24 + 0.011 * width as f64 + 0.05 * (n_add as f64 - 2.0)
    }

    /// Requantize/saturate: compare + shift + round before the register.
    pub fn requant_stage(sum_width: u32) -> f64 {
        0.22 + 0.009 * sum_width as f64
    }

    /// Fixed clocking overhead (clk->q, setup, skew).
    pub const CLOCK_OVERHEAD: f64 = 0.12;
}

/// Estimate resources + timing for a netlist on a device.
pub fn synthesize(net: &Netlist, dev: &Device) -> SynthReport {
    let mut luts = 0u64;
    let mut ffs = 0u64;
    let mut critical = 0.0f64;

    // input register: one FF per input code bit
    ffs += net.layers[0]
        .neurons
        .first()
        .map(|_| (net.layers[0].d_in as u64) * net.layers[0].in_bits as u64)
        .unwrap_or(0);

    for layer in &net.layers {
        let mut layer_critical = delay::lut_stage(layer.in_bits);
        for neuron in &layer.neurons {
            // LUT-read stage: each edge L-LUT becomes P-LUTs + its output reg
            let mut operand_widths: Vec<u32> = Vec::with_capacity(neuron.luts.len());
            for lut in &neuron.luts {
                luts += plut_cost(layer.in_bits, lut.out_width);
                ffs += lut.out_width as u64;
                operand_widths.push(lut.out_width);
            }
            // adder tree stages: widths grow toward the final sum width
            let mut widths = operand_widths;
            while widths.len() > 1 {
                let mut next = Vec::with_capacity(widths.len().div_ceil(net.n_add));
                for chunk in widths.chunks(net.n_add) {
                    let w = (chunk.iter().copied().max().unwrap_or(1)
                        + (chunk.len() as u32).next_power_of_two().trailing_zeros())
                    .min(neuron.sum_width);
                    // (k-1) adds of width w cost (k-1)*w LUTs; register w FFs
                    luts += (chunk.len() as u64 - 1) * w as u64;
                    ffs += w as u64;
                    next.push(w);
                    layer_critical = layer_critical.max(delay::adder_stage(w, net.n_add));
                }
                widths = next;
            }
            // requant / output capture
            match &layer.requant {
                Some(_) => {
                    // clip+shift+round logic ~ sum_width LUTs, out_bits FFs
                    luts += neuron.sum_width as u64;
                    ffs += layer.out_bits as u64;
                    layer_critical = layer_critical.max(delay::requant_stage(neuron.sum_width));
                }
                None => {
                    ffs += neuron.sum_width as u64;
                }
            }
        }
        critical = critical.max(layer_critical);
    }

    let period_ns = (critical + delay::CLOCK_OVERHEAD) * dev.delay_scale;
    let fmax_ghz = (1.0 / period_ns).min(dev.fmax_ceiling_ghz);
    let fmax_mhz = fmax_ghz * 1000.0;
    let cycles = net.latency_cycles();
    let latency_ns = cycles as f64 / fmax_ghz;
    let dyn_power_w = fmax_ghz * (luts as f64 * dev.p_lut_w_per_ghz + ffs as f64 * dev.p_ff_w_per_ghz);
    let throughput = fmax_ghz * 1e9; // II = 1
    SynthReport {
        device: dev.name,
        luts,
        ffs,
        brams: 0, // LUT-native design: no BRAM
        dsps: 0,  // and no DSP (paper contribution #1)
        fmax_mhz,
        latency_cycles: cycles,
        latency_ns,
        area_delay: luts as f64 * latency_ns,
        dyn_power_w,
        energy_per_inf_uj: dyn_power_w / throughput * 1e6,
        throughput_inf_s: throughput,
        fits: luts <= dev.luts && ffs <= dev.ffs,
    }
}

/// Width helper exposed for baseline models.
pub fn width_for_range(lo: i64, hi: i64) -> u32 {
    signed_width_range(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::lut;
    use crate::netlist::Netlist;
    use crate::util::prop;

    #[test]
    fn plut_costs() {
        assert_eq!(plut_cost(4, 16), 8); // fracturable
        assert_eq!(plut_cost(5, 15), 8);
        assert_eq!(plut_cost(6, 16), 16);
        assert_eq!(plut_cost(7, 16), 32);
        assert_eq!(plut_cost(8, 16), 64);
        assert_eq!(plut_cost(9, 1), 8);
        assert_eq!(plut_cost(0, 16), 0);
        assert!(plut_cost(10, 1) > plut_cost(9, 1) * 2 - 1);
    }

    fn report_for(dims: &[usize], bits: &[u32], seed: u64) -> SynthReport {
        let ck = synthetic(dims, bits, seed);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        synthesize(&net, &XCVU9P)
    }

    #[test]
    fn no_bram_no_dsp() {
        let r = report_for(&[4, 3, 2], &[4, 5, 6], 2);
        assert_eq!(r.brams, 0);
        assert_eq!(r.dsps, 0);
        assert!(r.fits);
    }

    #[test]
    fn bigger_nets_cost_more() {
        let small = report_for(&[4, 3, 2], &[4, 4, 6], 3);
        let big = report_for(&[16, 12, 5], &[4, 4, 6], 3);
        assert!(big.luts > small.luts);
        assert!(big.ffs > small.ffs);
    }

    #[test]
    fn higher_bitwidth_costs_exponentially_more_luts() {
        // Fig. 6d: LUT usage vs activation bitwidth
        let b4 = report_for(&[8, 4, 3], &[4, 4, 6], 5);
        let b6 = report_for(&[8, 4, 3], &[6, 6, 6], 5);
        let b8 = report_for(&[8, 4, 3], &[8, 8, 6], 5);
        assert!(b6.luts > b4.luts);
        assert!(b8.luts as f64 > b6.luts as f64 * 2.0, "{} vs {}", b8.luts, b6.luts);
    }

    #[test]
    fn fmax_bounded_by_ceiling() {
        let r = report_for(&[2, 1], &[2, 4], 8);
        assert!(r.fmax_mhz <= XCVU9P.fmax_ceiling_ghz * 1000.0 + 1e-9);
        assert!(r.fmax_mhz > 400.0, "tiny design should clock fast, got {}", r.fmax_mhz);
    }

    #[test]
    fn latency_consistent() {
        let ck = synthetic(&[6, 4, 2], &[4, 5, 6], 13);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        let r = synthesize(&net, &XCVU9P);
        assert_eq!(r.latency_cycles, net.latency_cycles());
        let expect_ns = r.latency_cycles as f64 / (r.fmax_mhz / 1000.0);
        assert!((r.latency_ns - expect_ns).abs() < 1e-9);
        assert!((r.area_delay - r.luts as f64 * r.latency_ns).abs() < 1e-6);
    }

    #[test]
    fn artix_slower_than_ultrascale() {
        let ck = synthetic(&[8, 4, 2], &[6, 6, 6], 17);
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        let us = synthesize(&net, &XCVU9P);
        let a7 = synthesize(&net, &XC7A100T);
        assert!(a7.fmax_mhz < us.fmax_mhz);
        assert_eq!(a7.luts, us.luts); // same mapping, different timing
    }

    #[test]
    fn prop_resources_monotone_in_edges() {
        prop::check("synth-monotone", 20, |g| {
            let d = g.usize_in(2, 8);
            let seed = g.rng().next_u64();
            let ck_full = synthetic(&[d, d], &[4, 6], seed);
            // pruned variant: drop half the edges
            let mut ck_pruned = ck_full.clone();
            {
                let l = &mut ck_pruned.layers[0];
                let mut dropped = 0;
                for i in 0..l.mask.len() {
                    if l.mask[i] && dropped < l.mask.len() / 2 {
                        l.mask[i] = false;
                        l.table[i] = None;
                        dropped += 1;
                    }
                }
            }
            let rf = synthesize(&Netlist::build(&ck_full, &lut::from_checkpoint(&ck_full), 2), &XCVU9P);
            let rp = synthesize(&Netlist::build(&ck_pruned, &lut::from_checkpoint(&ck_pruned), 2), &XCVU9P);
            if rp.luts > rf.luts {
                return Err(format!("pruning increased LUTs: {} > {}", rp.luts, rf.luts));
            }
            Ok(())
        });
    }

    #[test]
    fn device_lookup() {
        assert!(device_by_name("xcvu9p").is_some());
        assert!(device_by_name("xczu7ev-ffvc1156-2-e").is_some());
        assert!(device_by_name("nope").is_none());
    }
}
