//! Experiment configurations — the Rust mirror of `python/compile/configs.py`
//! (one entry per paper Table 2 row) plus artifact path resolution.

use std::path::{Path, PathBuf};

/// Task type of a benchmark.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Classify,
    Binary,
    Regress,
}

impl Task {
    pub fn parse(s: &str) -> Option<Task> {
        match s {
            "classify" => Some(Task::Classify),
            "binary" => Some(Task::Binary),
            "regress" => Some(Task::Regress),
            _ => None,
        }
    }
}

/// One benchmark row (Table 2 hyperparameters).
#[derive(Clone, Debug)]
pub struct Experiment {
    pub name: &'static str,
    pub task: Task,
    pub dims: &'static [usize],
    pub bits: &'static [u32],
    pub grid_size: usize,
    pub order: usize,
    pub domain: (f64, f64),
    pub prune_threshold: f64,
    /// Device used for the paper's hardware table containing this row.
    pub device: &'static str,
}

/// All Table 2 rows.
pub const EXPERIMENTS: &[Experiment] = &[
    Experiment { name: "moons", task: Task::Binary, dims: &[2, 2, 1], bits: &[6, 5, 8], grid_size: 6, order: 3, domain: (-8.0, 8.0), prune_threshold: 0.0, device: "xczu7ev" },
    Experiment { name: "wine", task: Task::Classify, dims: &[13, 4, 3], bits: &[6, 7, 8], grid_size: 6, order: 3, domain: (-8.0, 8.0), prune_threshold: 0.0, device: "xczu7ev" },
    Experiment { name: "dry_bean", task: Task::Classify, dims: &[16, 2, 7], bits: &[6, 6, 8], grid_size: 6, order: 3, domain: (-8.0, 8.0), prune_threshold: 0.0, device: "xczu7ev" },
    Experiment { name: "jsc_cernbox", task: Task::Classify, dims: &[16, 12, 5], bits: &[8, 8, 6], grid_size: 30, order: 10, domain: (-2.0, 2.0), prune_threshold: 0.14, device: "xcvu9p" },
    Experiment { name: "jsc_openml", task: Task::Classify, dims: &[16, 8, 5], bits: &[6, 7, 6], grid_size: 40, order: 10, domain: (-2.0, 2.0), prune_threshold: 0.9, device: "xcvu9p" },
    Experiment { name: "mnist", task: Task::Classify, dims: &[784, 62, 10], bits: &[1, 6, 6], grid_size: 30, order: 3, domain: (-8.0, 8.0), prune_threshold: 1.0, device: "xcvu9p" },
    Experiment { name: "toyadmos", task: Task::Regress, dims: &[64, 16, 8, 16, 64], bits: &[7, 8, 8, 7, 8], grid_size: 30, order: 10, domain: (-2.0, 2.0), prune_threshold: 0.9, device: "xc7a100t" },
];

pub fn experiment(name: &str) -> Option<&'static Experiment> {
    EXPERIMENTS.iter().find(|e| e.name == name)
}

/// Artifact directory: $KANELE_ARTIFACTS or `<repo>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("KANELE_ARTIFACTS") {
        return PathBuf::from(p);
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Checkpoint / testset / HLO paths for a benchmark name.
pub fn ckpt_path(name: &str) -> PathBuf {
    artifacts_dir().join(format!("{name}.ckpt.json"))
}

pub fn testset_path(name: &str) -> PathBuf {
    artifacts_dir().join(format!("{name}.testset.json"))
}

pub fn hlo_path(name: &str) -> PathBuf {
    artifacts_dir().join(format!("{name}.hlo.txt"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_rows_present_and_consistent() {
        assert_eq!(EXPERIMENTS.len(), 7);
        for e in EXPERIMENTS {
            assert_eq!(e.bits.len(), e.dims.len(), "{}", e.name);
            assert!(e.domain.1 > e.domain.0);
            assert!(crate::synth::device_by_name(e.device).is_some(), "{}", e.device);
        }
    }

    #[test]
    fn lookup() {
        assert!(experiment("moons").is_some());
        assert_eq!(experiment("mnist").unwrap().dims, &[784, 62, 10]);
        assert!(experiment("nope").is_none());
    }

    #[test]
    fn task_parse() {
        assert_eq!(Task::parse("classify"), Some(Task::Classify));
        assert_eq!(Task::parse("binary"), Some(Task::Binary));
        assert_eq!(Task::parse("regress"), Some(Task::Regress));
        assert_eq!(Task::parse("x"), None);
    }

    #[test]
    fn paths_shaped() {
        assert!(ckpt_path("moons").to_string_lossy().ends_with("moons.ckpt.json"));
        assert!(hlo_path("moons").to_string_lossy().ends_with("moons.hlo.txt"));
    }
}
