// The `simd` cargo feature swaps the engine's chunked lane kernels from
// stable-autovectorized loops to std::simd bodies; portable_simd is a
// nightly-only std feature, so the gate lives here (see engine::kernels).
#![cfg_attr(feature = "simd", feature(portable_simd))]

//! KANELE: Kolmogorov-Arnold Networks for Efficient LUT-based Evaluation.
//!
//! Full-system reproduction of the FPGA '26 paper. The library is organised
//! around the paper's toolflow (Fig. 4), plus a compile→execute split on
//! the serving side:
//!
//! 1. A quantization-aware-trained, pruned KAN checkpoint (produced by the
//!    build-time JAX/Pallas stack in `python/`) is loaded by [`checkpoint`].
//! 2. [`lut`] enumerates every surviving edge's quantized input state space
//!    and evaluates the spline fixed-point response -> Logical-LUT truth
//!    tables.
//! 3. [`netlist`] assembles L-LUTs, balanced pipelined adder trees and
//!    inter-layer registers into a hardware graph; [`vhdl`] emits RTL.
//! 4. [`sim`] executes the netlist bit- and cycle-accurately (the FPGA
//!    substrate substitute), and [`synth`] estimates P-LUT/FF/Fmax/power the
//!    way Vivado out-of-context synthesis would.
//! 5. [`engine`] **compiles** the netlist into a flat feature-major program
//!    through an optimizing pass pipeline (constant-folding pruned edges
//!    into biases, dead-input elimination, table hash-consing, CSE — see
//!    [`engine::optim`]; packed table arenas narrowed to i32 where range
//!    analysis allows, fused op stream, integer requant plans) and executes
//!    request batches as contiguous integer-only table scans into
//!    caller-owned flat outputs — bit-exact with [`sim`], several times
//!    faster, hot-swappable.
//! 6. [`runtime`] cross-checks everything against the AOT-compiled XLA
//!    artifact via PJRT (behind the `xla` feature), and [`coordinator`]
//!    serves batched inference on the compiled engine by default.
//! 7. [`net`] puts the serving plane on a socket: a framed TCP front end
//!    (`kanele serve --listen`), a blocking client, and a closed-loop load
//!    generator (`kanele loadgen`) — wire sessions map onto admission
//!    shards with typed backpressure, never hangs.
//!
//! Choosing an executor: [`sim::eval`] for debugging and oracle
//! equivalence, [`sim::CycleSim`] when cycle/latency behaviour matters,
//! [`engine::run_batch`] (or a reused [`engine::Executor`]) on every
//! serving hot path.
//!
//! Baselines from the paper's evaluation (LogicNets, PolyLUT, hls4ml-style
//! dense MLP, Tran et al.'s direct-spline KAN) live in [`baselines`].

pub mod baselines;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod fixed;
pub mod json;
pub mod lut;
pub mod net;
pub mod netlist;
pub mod report;
pub mod rl;
pub mod runtime;
pub mod sim;
pub mod synth;
pub mod util;
pub mod vhdl;
