//! kanele — command-line driver for the KANELE toolflow.
//!
//! Subcommands mirror the paper's flow (Fig. 4): checkpoints produced by
//! the Python build path are compiled to netlists, simulated bit-exactly,
//! synthesized (estimator), emitted as VHDL, served, and reported as the
//! paper's tables. Run `kanele help` for usage.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use kanele::checkpoint::{testutil, Checkpoint, TestSet};
use kanele::config;
use kanele::coordinator::{Backend, FaultPlan, ModelRegistry, Service, ServiceCfg, SubmitError};
use kanele::engine::{self, OptLevel};
use kanele::net::{self, LoadGenCfg, NetCfg, NetServer, WireFaults};
use kanele::netlist::Netlist;
use kanele::report;
use kanele::sim;
use kanele::synth;
use kanele::vhdl;
use kanele::{data, lut};

const USAGE: &str = "\
kanele — Kolmogorov-Arnold Networks for Efficient LUT-based Evaluation

USAGE: kanele <command> [args]

COMMANDS:
  compile <name|path> [--n-add N] [--device D] [--vhdl DIR]
          [--opt full|none|lossy:<budget>]
      checkpoint -> L-LUTs -> netlist; print synthesis report plus the
      serving engine's optimizer report (constant folding, dead-input
      elimination, table dedup/CSE; --opt lossy:<budget> adds the
      error-budgeted passes — epsilon-clustered table sharing, affine
      folding, requant-aware range tightening — and reports the bytes
      saved plus the worst-case output bound); optionally emit the VHDL
      bundle.
  verify <name|path> [--n-add N]
      bit-exact equivalence: netlist sim vs the checkpoint's Python oracle
      vectors, plus L-LUT regeneration vs exported tables.
  eval <name> [--n-add N]
      run the netlist on the exported test set; print the task metric.
  serve <name> [--requests N] [--workers W] [--shards S] [--steal on|off]
        [--batch B] [--wait-us U] [--queue-depth Q]
        [--parallel-batch auto|off|G]
        [--backend compiled|interpreted] [--opt full|none|lossy:<budget>]
        [--listen ADDR] [--duration-s N] [--auth-token TOK]
        [--model NAME=CKPT ...] [--canary T=CKPT:PCT]
        [--read-idle-ms N] [--fault-panic-every N] [--fault-panic-budget N]
        [--fault-seed S] [--fault-torn-every N] [--fault-stall-every N]
        [--fault-stall-us U] [--fault-disconnect-after N]
      batched inference service through the sharded dispatcher/executor
      plane: S admission shards (client-affine round-robin, each with its
      own dispatcher forming batches — fill to --batch or flush --wait-us
      after the oldest request's submission) feed a work-stealing pool of
      W executors (idle executors steal the oldest queued batch from other
      shards unless --steal off). Default backend: the compiled batch-major
      engine lowered through the full optimizer pipeline (--opt none keeps
      the 1:1 lowering for A/B; --opt lossy:<budget> adds error-budgeted
      table sharing/folding/tightening — responses may deviate from the
      exact model by at most the budget-derived bound the stats report
      carries); `interpreted` selects the netlist simulator.
      --parallel-batch arms intra-batch data-parallelism: a compiled batch
      with at least 2*G valid samples is split into up to W grain-G sample
      slices fanned across the executor pool and stitched back bit-exactly
      (auto, the default, derives G from observed per-row execution time —
      ~0.5 ms per slice, clamped to [256, 8192]; off disables; an explicit
      G is fixed; small batches always keep the single-executor path).
      Without --listen this self-drives a --requests benchmark;
      with --listen ADDR it runs the framed TCP front end (port 0 picks a
      free port; prints `listening on <addr>`) until a client sends the
      `shutdown` op or --duration-s elapses. Falls back to a synthetic
      checkpoint twin when the artifact is missing and <name> is a known
      experiment. Repeatable --model NAME=CKPT flags (require --listen)
      load a multi-tenant registry instead of <name>: requests carrying
      `model` route to that tenant, table arenas are interned across
      tenants, and --canary T=CKPT:PCT shadows PCT percent of T's rows
      with a second checkpoint, tracking live argmax agreement (PCT in
      0..=100).
      --auth-token gates every connection behind a shared-secret hello.
      --read-idle-ms bounds how long an idle connection may sit before the
      slow-loris guard closes it (default 60000; 0 disables). The
      --fault-* flags arm deterministic fault injection for chaos runs:
      panic every Nth executed batch (budgeted by --fault-panic-budget,
      phase-shifted by --fault-seed), tear every Nth response frame
      mid-payload, stall every Nth response --fault-stall-us, or sever
      each connection after N inbound frames. All default to 0 = off;
      production serves never arm them.
  loadgen <addr> [--connections N] [--requests N] [--rate R]
          [--tail-every K] [--tail-batch B] [--seed S] [--shutdown]
          [--model-mix a:3,b:1] [--auth-token TOK] [--deadline-us D]
      closed-loop load generator against a running `serve --listen` server:
      N connections split --requests total single-sample inferences (--rate
      is a per-connection target in req/s, 0 = max; every K-th request is
      an infer_batch of B rows for heavy-tail runs). Learns the request
      shape from the server's stats op, retries backpressure frames, and
      reports completed/rps plus wire-latency p50/p90/p99. --model-mix
      weights requests across named tenants (per-tenant widths come from
      the stats frame); --auth-token sends the hello handshake first.
      --deadline-us stamps every inference with a relative deadline (the
      server sheds requests still unbatched past it with typed `expired`
      frames, which are counted, not retried). Transport faults trigger a
      reconnect with capped exponential backoff; `failed` frames (server
      batch panics) are retried on the same connection.
      --shutdown sends the server a shutdown op at the end.
  table2|table3|table4|table5|fig6|table7|report-all [--n-add N]
      regenerate the paper's tables/figures (report-all renders everything
      and saves to artifacts/reports/).
  devices
      list device models.
  help
      this text.
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: `--key value` pairs after positional args.
struct Flags<'a> {
    args: &'a [String],
}

impl<'a> Flags<'a> {
    fn get(&self, key: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == key)
            .and_then(|i| self.args.get(i + 1))
            .map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("bad {key}: {v}")),
            None => Ok(default),
        }
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("bad {key}: {v}")),
            None => Ok(default),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("bad {key}: {v}")),
            None => Ok(default),
        }
    }

    /// Every value of a repeatable flag (`--model a=x --model b=y`).
    fn get_all(&self, key: &str) -> Vec<&'a str> {
        self.args
            .iter()
            .enumerate()
            .filter(|(_, a)| *a == key)
            .filter_map(|(i, _)| self.args.get(i + 1))
            .map(|s| s.as_str())
            .collect()
    }

    /// Presence flag with no value (`--shutdown`).
    fn has(&self, key: &str) -> bool {
        self.args.iter().any(|a| a == key)
    }
}

/// Parse `--opt` (shared by `compile` and `serve`): exact levels by name
/// plus the error-budgeted `lossy:<budget>` form. Unknown levels get the
/// usage list; a recognized-but-malformed lossy budget gets its own
/// message, since "lossy:8.5" failing as "unknown level" is a dead end.
fn opt_level_flag(flags: &Flags) -> Result<OptLevel> {
    match flags.get("--opt") {
        None => Ok(OptLevel::default()),
        Some(s) => match OptLevel::parse(s) {
            Some(l) => Ok(l),
            None if s == "lossy" || s.starts_with("lossy:") => bail!(
                "bad --opt {s:?}: lossy needs an unsigned integer error budget in output LSBs (e.g. --opt lossy:8)"
            ),
            None => bail!("bad --opt {s:?} (full|none|lossy:<budget>)"),
        },
    }
}

/// Parse `--parallel-batch` (see `ServiceCfg::parallel_grain`): `auto`
/// (the default) derives the slice grain from observed per-row time,
/// `off` (or the legacy `0`) disables intra-batch slicing, an explicit
/// sample count is a fixed grain.
fn parallel_grain_flag(flags: &Flags) -> Result<usize> {
    match flags.get("--parallel-batch") {
        None | Some("auto") => Ok(0),
        Some("off" | "0") => Ok(kanele::coordinator::GRAIN_OFF),
        Some(v) => v.parse().with_context(|| format!("bad --parallel-batch {v:?} (auto|off|G)")),
    }
}

fn load_checkpoint(name_or_path: &str) -> Result<Checkpoint> {
    let p = PathBuf::from(name_or_path);
    let path = if p.exists() { p } else { config::ckpt_path(name_or_path) };
    if !path.exists() {
        bail!(
            "no checkpoint at {} — train it first (cd python && python -m compile.trainer {name_or_path})",
            path.display()
        );
    }
    Checkpoint::load(&path)
}

/// [`load_checkpoint`], but a known experiment whose artifact has not been
/// trained falls back to a synthetic twin with the experiment's dims/bits —
/// the same quickstart path the benches use, so `kanele serve --listen`
/// works in artifact-less environments (CI, fresh clones).
fn load_checkpoint_or_synthetic(name_or_path: &str) -> Result<Checkpoint> {
    let p = PathBuf::from(name_or_path);
    if !p.exists() && !config::ckpt_path(name_or_path).exists() {
        if let Some(exp) = config::experiment(name_or_path) {
            eprintln!(
                "note: no checkpoint artifact for {name_or_path}; serving a synthetic twin (dims {:?}, bits {:?})",
                exp.dims, exp.bits
            );
            return Ok(testutil::synthetic(exp.dims, exp.bits, 0xB5EED));
        }
    }
    load_checkpoint(name_or_path)
}

/// Wire-serving loop shared by `serve --listen`'s single-model and
/// multi-tenant paths: bind, print `listening on <addr>`, run until a
/// client's `shutdown` op or the duration budget elapses, then drain and
/// print the plane's report (per-tenant lines when a registry serves more
/// than one model).
fn serve_wire(svc: &Arc<Service>, addr: &str, net_cfg: NetCfg, duration_s: u64) -> Result<()> {
    let listener = std::net::TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let mut server = NetServer::start(Arc::clone(svc), listener, net_cfg)?;
    println!("listening on {}", server.local_addr());
    let t0 = Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(100));
        if server.shutdown_requested() {
            println!("serve: shutdown requested by client");
            break;
        }
        if duration_s > 0 && t0.elapsed().as_secs() >= duration_s {
            println!("serve: duration budget elapsed");
            break;
        }
    }
    server.shutdown();
    let ns = server.stats();
    let stats = svc.stats();
    println!(
        "wire            : {} conns, {} frames in / {} out, {} parse errors, {} completions, {} idle kills, {} injected wire faults",
        ns.accepted,
        ns.frames_in,
        ns.frames_out,
        ns.parse_errors,
        ns.wire_completed,
        ns.idle_kills,
        ns.faults_injected
    );
    println!(
        "served          : {} samples ({:.0} samples/s; rejected {}, dropped {})",
        stats.completed, stats.throughput_rps, stats.rejected, stats.dropped
    );
    // one greppable line for the CI chaos smoke: every fault-path counter
    println!(
        "faults          : exec_panics={} respawns={} failed={} shed_expired={} quarantine_drops={} injected={}",
        stats.exec_panics,
        stats.respawns,
        stats.failed,
        stats.shed_expired,
        stats.quarantine_drops,
        stats.faults_injected
    );
    println!(
        "latency p50/p90/p99 : {:.1} / {:.1} / {:.1} us",
        stats.latency_p50_us, stats.latency_p90_us, stats.latency_p99_us
    );
    println!("mean batch      : {:.1} (batches: {})", stats.mean_batch, stats.batches);
    if stats.per_tenant.len() > 1 {
        for t in &stats.per_tenant {
            let mark = if t.retired { " (retired)" } else { "" };
            println!(
                "  model {:<10}: {} completed, {} batches (mean {:.1}), p99 {:.1} us, quota drops {}{mark}",
                t.name, t.completed, t.batches, t.mean_batch, t.latency_p99_us, t.quota_drops
            );
            if t.canary_rows > 0 {
                println!(
                    "    canary      : {} rows, argmax agreement {:.4} ({} agreed)",
                    t.canary_rows, t.canary_agreement, t.canary_agree
                );
            }
        }
    }
    svc.shutdown();
    println!("serve: clean shutdown");
    Ok(())
}

fn run(args: &[String]) -> Result<()> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let flags = Flags { args: rest };
    match cmd {
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        "devices" => {
            for d in [synth::XCVU9P, synth::XCZU7EV, synth::XC7A100T] {
                println!(
                    "{:<28} LUT {:>9}  FF {:>9}  BRAM {:>5}  DSP {:>5}  ceiling {:.2} GHz",
                    d.name, d.luts, d.ffs, d.brams, d.dsps, d.fmax_ceiling_ghz
                );
            }
            Ok(())
        }
        "compile" => {
            let name = rest.first().context("compile <name>")?;
            let n_add = flags.get_usize("--n-add", 2)?;
            let ck = load_checkpoint(name)?;
            let device = flags
                .get("--device")
                .map(String::from)
                .or_else(|| config::experiment(&ck.name).map(|e| e.device.to_string()))
                .unwrap_or_else(|| "xcvu9p".into());
            let t0 = Instant::now();
            let tables = lut::extract_all(&ck);
            let t_extract = t0.elapsed();
            let net = Netlist::build(&ck, &tables, n_add);
            let dev = synth::device_by_name(&device).with_context(|| format!("device {device}"))?;
            let r = synth::synthesize(&net, &dev);
            println!("model          : {}", ck.name);
            println!("dims / bits    : {:?} / {:?}", ck.dims, ck.bits);
            println!(
                "active edges   : {} (of {})",
                ck.active_edges(),
                ck.dims.windows(2).map(|w| w[0] * w[1]).sum::<usize>()
            );
            println!("L-LUT extract  : {:.1} ms", t_extract.as_secs_f64() * 1e3);
            println!("device         : {}", r.device);
            println!("P-LUTs         : {}", r.luts);
            println!("FFs            : {}", r.ffs);
            println!("BRAM / DSP     : {} / {}", r.brams, r.dsps);
            println!("Fmax           : {:.0} MHz", r.fmax_mhz);
            println!("latency        : {} cycles = {:.1} ns", r.latency_cycles, r.latency_ns);
            println!("Area x Delay   : {:.2e} LUT*ns", r.area_delay);
            println!(
                "dyn power      : {:.3} W  ({:.4} uJ/inf @ II=1)",
                r.dyn_power_w, r.energy_per_inf_uj
            );
            println!("fits device    : {}", r.fits);
            // the serving engine's view of the same netlist: what the
            // compile-time pass pipeline folds, dedups and CSEs away —
            // and, at --opt lossy:<budget>, what the error-budgeted
            // passes additionally share/fold within their bound
            let opt_level = opt_level_flag(&flags)?;
            let prog = engine::compile_with(&net, opt_level);
            if let Some(opt) = prog.opt_report() {
                println!("engine opt     : {}", opt.summary());
            }
            println!(
                "engine program : {} fused ops, {} unique table words, {} B arenas",
                prog.n_ops(),
                prog.table_words(),
                prog.table_bytes()
            );
            if let Some(dir) = flags.get("--vhdl") {
                let oracle_in = &ck.test_vectors.input_codes;
                let oracle_out = &ck.test_vectors.output_sums;
                vhdl::write_bundle(
                    &net,
                    &PathBuf::from(dir),
                    (!oracle_in.is_empty()).then_some((oracle_in.as_slice(), oracle_out.as_slice())),
                )?;
                println!("VHDL bundle    : {dir}");
            }
            Ok(())
        }
        "verify" => {
            let name = rest.first().context("verify <name>")?;
            let n_add = flags.get_usize("--n-add", 2)?;
            let ck = load_checkpoint(name)?;
            // 1. L-LUT regeneration vs exported tables
            let (total, mismatched, maxdiff) = lut::compare_with_exported(&ck);
            println!(
                "L-LUT regeneration: {total} entries, {mismatched} mismatched (max |diff| {maxdiff} LSB)"
            );
            if maxdiff > 1 {
                bail!("regenerated tables deviate by more than 1 LSB");
            }
            // 2. netlist (exported tables) vs Python oracle vectors
            let tables = lut::from_checkpoint(&ck);
            let net = Netlist::build(&ck, &tables, n_add);
            let tv = &ck.test_vectors;
            let mut bad = 0usize;
            for (codes, want) in tv.input_codes.iter().zip(&tv.output_sums) {
                if &sim::eval(&net, codes) != want {
                    bad += 1;
                }
            }
            println!(
                "netlist vs oracle : {}/{} vectors bit-exact",
                tv.input_codes.len() - bad,
                tv.input_codes.len()
            );
            if bad > 0 {
                bail!("{bad} oracle vectors mismatched");
            }
            // 3. cycle-accurate simulator vs functional eval
            let mut cyc = sim::CycleSim::new(&net);
            let completions = cyc.run_stream(&tv.input_codes);
            let ok = completions
                .iter()
                .all(|c| c.sums == tv.output_sums[c.id as usize]);
            println!(
                "cycle-sim (II=1)  : {} vectors in {} cycles (latency {}), match = {ok}",
                completions.len(),
                cyc.cycle(),
                net.latency_cycles()
            );
            if !ok {
                bail!("cycle-accurate simulation mismatched");
            }
            // 4. compiled engine (the serving backend) vs oracle — through
            // the flat plane, the allocation-free path serving actually uses
            let prog = engine::compile(&net);
            let mut flat = Vec::new();
            engine::run_batch_flat(&prog, &tv.input_codes, &mut flat);
            let d_out = prog.d_out();
            let bad = flat
                .chunks(d_out)
                .zip(&tv.output_sums)
                .filter(|(got, want)| *got != want.as_slice())
                .count();
            println!(
                "compiled engine   : {}/{} vectors bit-exact ({} ops, {} table words)",
                tv.input_codes.len() - bad,
                tv.input_codes.len(),
                prog.n_ops(),
                prog.table_words()
            );
            if bad > 0 {
                bail!("{bad} vectors mismatched on the compiled engine");
            }
            println!("VERIFY OK");
            Ok(())
        }
        "eval" => {
            let name = rest.first().context("eval <name>")?;
            let n_add = flags.get_usize("--n-add", 2)?;
            let ck = load_checkpoint(name)?;
            let tables = lut::from_checkpoint(&ck);
            let net = Netlist::build(&ck, &tables, n_add);
            let metric = report::eval_metric(&ck, &net)?;
            let unit = if ck.task == "regress" { "AUC" } else { "% accuracy" };
            println!("{name}: {metric:.2} {unit} (bit-exact netlist, full exported test set)");
            Ok(())
        }
        "serve" => {
            let name = rest.first().context("serve <name>")?;
            let n_requests = flags.get_usize("--requests", 100_000)?;
            let workers = flags.get_usize("--workers", 2)?;
            let shards = flags.get_usize("--shards", 1)?;
            let steal = match flags.get("--steal") {
                Some("on") | None => true,
                Some("off") => false,
                Some(s) => bail!("bad --steal {s:?} (on|off)"),
            };
            let batch = flags.get_usize("--batch", 64)?;
            let wait_us = flags.get_usize("--wait-us", 100)?;
            let queue_depth = flags.get_usize("--queue-depth", 1 << 14)?;
            let parallel_grain = parallel_grain_flag(&flags)?;
            let backend = match flags.get("--backend") {
                Some(s) => Backend::parse(s)
                    .with_context(|| format!("bad --backend {s:?} (compiled|interpreted)"))?,
                None => Backend::Compiled,
            };
            let opt = opt_level_flag(&flags)?;
            let listen = flags.get("--listen").map(String::from);
            let auth_token = flags.get("--auth-token").map(String::from);
            let read_idle_ms = flags.get_u64("--read-idle-ms", 60_000)?;
            let read_idle = (read_idle_ms > 0).then(|| Duration::from_millis(read_idle_ms));
            let faults = FaultPlan {
                seed: flags.get_u64("--fault-seed", 0)?,
                panic_every: flags.get_usize("--fault-panic-every", 0)?,
                panic_budget: flags.get_usize("--fault-panic-budget", 0)?,
                panic_model: None,
            };
            let wire_faults = WireFaults {
                torn_every: flags.get_usize("--fault-torn-every", 0)?,
                stall_every: flags.get_usize("--fault-stall-every", 0)?,
                stall: Duration::from_micros(flags.get_u64("--fault-stall-us", 0)?),
                disconnect_after: flags.get_usize("--fault-disconnect-after", 0)?,
            };
            if faults.armed() {
                println!(
                    "fault plan      : panic every {} batch(es), budget {}, seed {}",
                    faults.panic_every, faults.panic_budget, faults.seed
                );
            }
            if wire_faults.armed() {
                println!(
                    "wire faults     : torn_every={} stall_every={} stall_us={} disconnect_after={}",
                    wire_faults.torn_every,
                    wire_faults.stall_every,
                    wire_faults.stall.as_micros(),
                    wire_faults.disconnect_after
                );
            }
            let svc_cfg = ServiceCfg {
                workers,
                shards,
                steal,
                max_batch: batch,
                max_wait: Duration::from_micros(wait_us as u64),
                queue_depth,
                backend,
                opt,
                faults,
                parallel_grain,
                ..Default::default()
            };
            let model_specs = flags.get_all("--model");
            if !model_specs.is_empty() {
                // multi-tenant registry path: every tenant comes from a
                // --model flag; the positional <name> is not loaded
                let addr = listen.context("--model requires --listen ADDR")?;
                let duration_s = flags.get_u64("--duration-s", 0)?;
                let reg = Arc::new(ModelRegistry::new(opt));
                let mut levels = 0u64;
                for spec in &model_specs {
                    let (tenant, path) = spec
                        .split_once('=')
                        .with_context(|| format!("bad --model {spec:?} (want NAME=CHECKPOINT)"))?;
                    let ck = load_checkpoint_or_synthetic(path)?;
                    if levels == 0 {
                        levels = ck.quantizer(0).levels();
                    }
                    let tables = lut::from_checkpoint(&ck);
                    let net = Arc::new(Netlist::build(&ck, &tables, 2));
                    let id =
                        reg.load(tenant, net).with_context(|| format!("loading tenant {tenant}"))?;
                    println!("model           : {tenant} (id {}) <- {path}", id.raw());
                }
                if let Some(spec) = flags.get("--canary") {
                    let bad = || format!("bad --canary {spec:?} (want TENANT=CHECKPOINT:PCT)");
                    let (tenant, rest) = spec.split_once('=').with_context(bad)?;
                    let (path, pct) = rest.rsplit_once(':').with_context(bad)?;
                    let pct: u32 = pct.parse().with_context(bad)?;
                    let ck = load_checkpoint_or_synthetic(path)?;
                    let tables = lut::from_checkpoint(&ck);
                    let net = Arc::new(Netlist::build(&ck, &tables, 2));
                    reg.set_canary(tenant, net, pct)
                        .with_context(|| format!("canarying tenant {tenant}"))?;
                    println!("canary          : {tenant} shadows {pct}% of rows with {path}");
                }
                // one shared arena across all tenants (and canaries)
                let arena = reg.reintern();
                println!(
                    "arena           : {} programs, {} unique tables; {} B interned ({} B shared) vs {} B flat",
                    arena.programs,
                    arena.unique_tables,
                    arena.bytes_interned,
                    arena.bytes_shared,
                    arena.bytes_flat
                );
                let svc = Arc::new(Service::start_registry(reg, svc_cfg));
                let eff_shards = svc.cfg().shards; // effective (clamped to workers)
                println!("backend         : {backend:?}");
                println!(
                    "plane           : {eff_shards} admission shard(s) + {workers} executors (steal {}, queue depth {queue_depth} total)",
                    if steal { "on" } else { "off" }
                );
                let net_cfg = NetCfg {
                    levels,
                    auth_token,
                    read_idle,
                    faults: wire_faults,
                    ..NetCfg::default()
                };
                return serve_wire(&svc, &addr, net_cfg, duration_s);
            }
            if flags.get("--canary").is_some() {
                bail!("--canary requires --model (the tenant it shadows)");
            }
            let ck = if listen.is_some() {
                load_checkpoint_or_synthetic(name)?
            } else {
                load_checkpoint(name)?
            };
            let tables = lut::from_checkpoint(&ck);
            let net = Arc::new(Netlist::build(&ck, &tables, 2));
            let svc = Arc::new(Service::start(Arc::clone(&net), svc_cfg));
            let shards = svc.cfg().shards; // effective (clamped to workers)
            println!("backend         : {backend:?}");
            println!(
                "plane           : {shards} admission shard(s) + {workers} executors (steal {}, queue depth {queue_depth} total)",
                if steal { "on" } else { "off" }
            );
            if let Some(addr) = listen {
                // network front end: serve the wire until a client asks for
                // shutdown or the duration budget elapses
                let duration_s = flags.get_u64("--duration-s", 0)?;
                let levels = ck.quantizer(0).levels();
                let net_cfg = NetCfg {
                    levels,
                    auth_token,
                    read_idle,
                    faults: wire_faults,
                    ..NetCfg::default()
                };
                return serve_wire(&svc, &addr, net_cfg, duration_s);
            }
            let ts_path = config::testset_path(&ck.name);
            let stream = if ts_path.exists() {
                data::replay_stream(&TestSet::load(&ts_path)?, n_requests)
            } else {
                data::random_code_stream(&ck, n_requests, 7)
            };
            let t0 = Instant::now();
            let mut receivers = Vec::with_capacity(1024);
            let mut done = 0usize;
            for codes in stream {
                loop {
                    match svc.submit(codes.clone()) {
                        Ok(rx) => {
                            receivers.push(rx);
                            break;
                        }
                        Err(SubmitError::Backpressure) => {
                            // retryable: drain pending completions first
                            for rx in receivers.drain(..) {
                                let _ = rx.recv();
                                done += 1;
                            }
                        }
                        Err(e) => return Err(e.into()),
                    }
                }
            }
            for rx in receivers {
                let _ = rx.recv();
                done += 1;
            }
            let wall = t0.elapsed().as_secs_f64();
            let stats = svc.stats();
            println!("served          : {done} requests in {wall:.3} s");
            println!("throughput      : {:.0} req/s", done as f64 / wall);
            if let Some(opt) = &stats.opt {
                println!("optimizer       : {}", opt.summary());
            }
            println!(
                "ops throughput  : {:.3e} fused ops/s ({:.0} samples/s, {} ops/sample)",
                stats.throughput_ops,
                stats.throughput_rps,
                stats.opt.as_ref().map(|o| o.ops_after).unwrap_or_else(|| net.n_luts())
            );
            println!(
                "latency p50/p99 : {:.1} / {:.1} us",
                stats.latency_p50_us, stats.latency_p99_us
            );
            println!("mean batch      : {:.1} (batches: {})", stats.mean_batch, stats.batches);
            for (i, s) in stats.per_shard.iter().enumerate() {
                println!(
                    "  shard {i}       : {} admitted, {} batches (mean {:.1}; {} full / {} timeout)",
                    s.admitted, s.batches, s.mean_batch, s.flush_full, s.flush_timeout
                );
            }
            println!(
                "executor pops   : {} local, {} stolen ({:.1}% steals)",
                stats.local_pops,
                stats.steals,
                100.0 * stats.steals as f64 / (stats.local_pops + stats.steals).max(1) as f64
            );
            // only the compiled engine owns feature-major scratch planes;
            // the interpreter reports nothing here
            if backend == Backend::Compiled {
                println!(
                    "exec scratch    : {} B max/executor (feature-major planes, grow-only)",
                    stats.scratch_bytes
                );
            }
            println!("rejected (bp)   : {} (dropped mid-swap: {})", stats.rejected, stats.dropped);
            svc.shutdown();
            Ok(())
        }
        "loadgen" => {
            let addr = rest.first().context("loadgen <addr>")?;
            // --model-mix a:3,b:1 — weighted tenant mix, `name` alone = weight 1
            let mut model_mix = Vec::new();
            if let Some(mix) = flags.get("--model-mix") {
                for part in mix.split(',').filter(|p| !p.is_empty()) {
                    let (tenant, weight) = match part.split_once(':') {
                        Some((t, w)) => {
                            (t, w.parse().with_context(|| format!("bad --model-mix weight {w:?}"))?)
                        }
                        None => (part, 1u64),
                    };
                    model_mix.push((tenant.to_string(), weight));
                }
            }
            let cfg = LoadGenCfg {
                connections: flags.get_usize("--connections", 4)?,
                requests: flags.get_u64("--requests", 10_000)?,
                rate_rps: flags.get_f64("--rate", 0.0)?,
                tail_every: flags.get_u64("--tail-every", 0)?,
                tail_batch: flags.get_usize("--tail-batch", 32)?,
                seed: flags.get_u64("--seed", 7)?,
                model_mix,
                auth: flags.get("--auth-token").map(String::from),
                deadline_us: flags.get_u64("--deadline-us", 0)?,
            };
            println!(
                "loadgen         : {} conns x {} reqs @ {} (tail: every {} -> batch {})",
                cfg.connections,
                cfg.requests,
                if cfg.rate_rps > 0.0 { format!("{} req/s", cfg.rate_rps) } else { "max rate".into() },
                cfg.tail_every,
                cfg.tail_batch
            );
            if !cfg.model_mix.is_empty() {
                let mix: Vec<String> =
                    cfg.model_mix.iter().map(|(t, w)| format!("{t}:{w}")).collect();
                println!("model mix       : {}", mix.join(", "));
            }
            let auth = cfg.auth.clone();
            let r = net::loadgen(addr, cfg)?;
            println!(
                "completed       : {} samples in {:.3} s ({:.0} samples/s)",
                r.completed, r.wall_s, r.rps
            );
            println!(
                "retries/errors  : {} backpressure, {} dropped, {} terminal",
                r.backpressure_retries, r.dropped, r.errors
            );
            println!(
                "resilience      : {} expired, {} failed retries, {} reconnects",
                r.expired, r.failed_retries, r.reconnects
            );
            println!(
                "wire latency    : mean {:.1} us, p50/p90/p99 {:.1} / {:.1} / {:.1} us",
                r.mean_us, r.p50_us, r.p90_us, r.p99_us
            );
            if flags.has("--shutdown") {
                let mut c = net::Client::connect(addr).context("connecting for shutdown")?;
                if let Some(tok) = auth.as_deref() {
                    c.hello(Some(tok)).map_err(|e| anyhow::anyhow!("hello op failed: {e}"))?;
                }
                c.shutdown_server().map_err(|e| anyhow::anyhow!("shutdown op failed: {e}"))?;
                println!("loadgen         : server shutdown requested");
            }
            if r.completed == 0 {
                bail!("no requests completed");
            }
            Ok(())
        }
        "table2" => {
            print!("{}", report::table2()?);
            Ok(())
        }
        "table3" => {
            print!("{}", report::table3(flags.get_usize("--n-add", 2)?)?);
            Ok(())
        }
        "table4" => {
            print!("{}", report::table4(flags.get_usize("--n-add", 2)?)?);
            Ok(())
        }
        "table5" => {
            print!("{}", report::table5(flags.get_usize("--n-add", 2)?)?);
            Ok(())
        }
        "fig6" => {
            print!("{}", report::fig6(flags.get_usize("--n-add", 2)?)?);
            Ok(())
        }
        "table7" => {
            print!("{}", report::table7(flags.get_usize("--n-add", 2)?)?);
            Ok(())
        }
        "report-all" => {
            let out = report::all(flags.get_usize("--n-add", 2)?)?;
            print!("{out}");
            let p = report::save("all", &out)?;
            eprintln!("(saved to {})", p.display());
            Ok(())
        }
        other => bail!("unknown command {other:?}; run `kanele help`"),
    }
}
