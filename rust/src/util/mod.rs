//! Small self-contained utilities: PRNG, statistics, timing, and a
//! property-testing micro-framework (the offline registry has no `rand`,
//! `proptest` or `criterion`, so these substrates are built here and tested
//! like everything else).

pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

pub use prop::Gen;
pub use rng::Rng;
pub use stats::{Reservoir, Summary};
pub use timer::Timer;
