//! Streaming statistics used by the bench harness and the coordinator's
//! latency tracking.

/// Online summary (Welford) + retained samples for exact quantiles.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let d = x - self.mean;
        self.mean += d / n;
        self.m2 += d * (x - self.mean);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            self.m2 / (self.samples.len() - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact quantile by sorting retained samples; q in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        s[idx]
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    /// Median absolute deviation — robust spread for the bench harness.
    pub fn mad(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let med = self.median();
        let mut devs: Vec<f64> = self.samples.iter().map(|x| (x - med).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        devs[(devs.len() - 1) / 2]
    }
}

/// Area under the ROC curve from (score, label) pairs — used by the
/// ToyADMOS anomaly-detection harness (paper Table 5's AUC column).
pub fn auc(scores: &[f64], labels: &[bool]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    // rank-sum (Mann-Whitney U), with tie handling via average ranks
    let n = scores.len();
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = avg_rank;
        }
        i = j + 1;
    }
    let n_pos = labels.iter().filter(|&&l| l).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return f64::NAN;
    }
    let rank_sum_pos: f64 = labels
        .iter()
        .zip(&ranks)
        .filter(|(l, _)| **l)
        .map(|(_, r)| *r)
        .sum();
    (rank_sum_pos - n_pos as f64 * (n_pos as f64 + 1.0) / 2.0) / (n_pos as f64 * n_neg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_var() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn quantiles() {
        let mut s = Summary::new();
        for i in 0..101 {
            s.push(i as f64);
        }
        assert_eq!(s.median(), 50.0);
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(1.0), 100.0);
    }

    #[test]
    fn auc_perfect_and_random() {
        let scores = [0.1, 0.2, 0.3, 0.8, 0.9, 1.0];
        let labels = [false, false, false, true, true, true];
        assert!((auc(&scores, &labels) - 1.0).abs() < 1e-12);
        let labels_rev = [true, true, true, false, false, false];
        assert!(auc(&scores, &labels_rev).abs() < 1e-12);
    }

    #[test]
    fn auc_with_ties() {
        let scores = [0.5, 0.5, 0.5, 0.5];
        let labels = [true, false, true, false];
        assert!((auc(&scores, &labels) - 0.5).abs() < 1e-12);
    }
}
