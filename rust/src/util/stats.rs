//! Streaming statistics used by the bench harness and the coordinator's
//! latency tracking.
//!
//! [`Summary`] retains every sample for exact quantiles — right for
//! benches with a known, bounded sample count. Long-running services use
//! [`Reservoir`] instead: O(cap) memory forever, exact mean, approximate
//! quantiles.

use super::rng::Rng;

/// Online summary (Welford) + retained samples for exact quantiles.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let d = x - self.mean;
        self.mean += d / n;
        self.m2 += d * (x - self.mean);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            self.m2 / (self.samples.len() - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact quantile by sorting retained samples; q in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        s[idx]
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    /// Median absolute deviation — robust spread for the bench harness.
    pub fn mad(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let med = self.median();
        let mut devs: Vec<f64> = self.samples.iter().map(|x| (x - med).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        devs[(devs.len() - 1) / 2]
    }
}

/// Bounded-memory sample summary: exact streaming mean plus a fixed-size
/// uniform reservoir (Vitter's Algorithm R) for approximate quantiles.
///
/// Unlike [`Summary`], pushing forever never grows memory and `quantile()`
/// sorts at most `cap` samples — the right trade for a service tracking
/// latencies under sustained load. Quantiles are exact until `cap` samples
/// have been seen and an unbiased uniform subsample estimate after.
/// Deterministic: the replacement PRNG is seeded from `cap`.
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    /// Total samples observed (not retained).
    seen: u64,
    samples: Vec<f64>,
    mean: f64,
    rng: Rng,
}

impl Reservoir {
    pub fn new(cap: usize) -> Reservoir {
        assert!(cap > 0, "reservoir capacity must be positive");
        Reservoir {
            cap,
            seen: 0,
            samples: Vec::new(),
            mean: 0.0,
            rng: Rng::new(0xC0FFEE ^ cap as u64),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        self.mean += (x - self.mean) / self.seen as f64;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // Algorithm R: after this step every one of the `seen` samples
            // is retained with equal probability cap/seen
            let j = self.rng.below(self.seen) as usize;
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }

    /// Total samples observed (not the retained count — see [`Reservoir::retained`]).
    pub fn len(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Samples currently retained (bounded by the construction capacity).
    pub fn retained(&self) -> usize {
        self.samples.len()
    }

    /// Exact mean over everything observed.
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Quantile over the retained reservoir; q in [0, 1]. Exact while
    /// fewer than `cap` samples have been seen, approximate after.
    pub fn quantile(&self, q: f64) -> f64 {
        self.quantiles(&[q])[0]
    }

    /// The serving-plane quantile set — p50/p90/p99 from one sort. The
    /// coordinator's stats snapshot, `kanele serve`'s final report and the
    /// loadgen client all print exactly these three.
    pub fn p50_p90_p99(&self) -> [f64; 3] {
        let q = self.quantiles(&[0.5, 0.9, 0.99]);
        [q[0], q[1], q[2]]
    }

    /// Several quantiles from one sort of the retained samples — cheaper
    /// than repeated [`Reservoir::quantile`] calls for stats scrapes.
    pub fn quantiles(&self, qs: &[f64]) -> Vec<f64> {
        if self.samples.is_empty() {
            return vec![f64::NAN; qs.len()];
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        qs.iter()
            .map(|q| s[((s.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize])
            .collect()
    }
}

/// Area under the ROC curve from (score, label) pairs — used by the
/// ToyADMOS anomaly-detection harness (paper Table 5's AUC column).
pub fn auc(scores: &[f64], labels: &[bool]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    // rank-sum (Mann-Whitney U), with tie handling via average ranks
    let n = scores.len();
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = avg_rank;
        }
        i = j + 1;
    }
    let n_pos = labels.iter().filter(|&&l| l).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return f64::NAN;
    }
    let rank_sum_pos: f64 = labels
        .iter()
        .zip(&ranks)
        .filter(|(l, _)| **l)
        .map(|(_, r)| *r)
        .sum();
    (rank_sum_pos - n_pos as f64 * (n_pos as f64 + 1.0) / 2.0) / (n_pos as f64 * n_neg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_var() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn quantiles() {
        let mut s = Summary::new();
        for i in 0..101 {
            s.push(i as f64);
        }
        assert_eq!(s.median(), 50.0);
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(1.0), 100.0);
    }

    #[test]
    fn reservoir_exact_below_capacity() {
        // under cap, Reservoir and Summary agree exactly
        let mut r = Reservoir::new(256);
        let mut s = Summary::new();
        for i in 0..100 {
            let x = (i * 37 % 100) as f64;
            r.push(x);
            s.push(x);
        }
        assert_eq!(r.len(), 100);
        assert_eq!(r.retained(), 100);
        assert!((r.mean() - s.mean()).abs() < 1e-12);
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(r.quantile(q), s.quantile(q));
        }
        // the p50/p90/p99 helper is the same three quantiles in one call
        let [p50, p90, p99] = r.p50_p90_p99();
        assert_eq!(p50, s.quantile(0.5));
        assert_eq!(p90, s.quantile(0.9));
        assert_eq!(p99, s.quantile(0.99));
        assert!(p50 <= p90 && p90 <= p99);
    }

    #[test]
    fn reservoir_memory_is_bounded() {
        let mut r = Reservoir::new(512);
        for i in 0..100_000 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 100_000);
        assert_eq!(r.retained(), 512);
        // the mean is exact even though only 512 samples are retained
        assert!((r.mean() - 49_999.5).abs() < 1e-6, "mean {}", r.mean());
    }

    #[test]
    fn reservoir_quantiles_approximately_correct_under_load() {
        // uniform stream in [0, 1): quantile(q) must land near q. The
        // deterministic PRNG makes the tolerances safe (binomial std for
        // p50 at cap 4096 is ~0.008).
        let mut r = Reservoir::new(4096);
        let mut rng = Rng::new(2026);
        for _ in 0..200_000 {
            r.push(rng.f64());
        }
        assert!((r.quantile(0.5) - 0.5).abs() < 0.05, "p50 {}", r.quantile(0.5));
        assert!((r.quantile(0.99) - 0.99).abs() < 0.02, "p99 {}", r.quantile(0.99));
        assert!((r.mean() - 0.5).abs() < 0.01);
    }

    #[test]
    fn reservoir_empty_is_nan() {
        let r = Reservoir::new(8);
        assert!(r.is_empty());
        assert!(r.quantile(0.5).is_nan());
        assert_eq!(r.mean(), 0.0);
    }

    #[test]
    fn auc_perfect_and_random() {
        let scores = [0.1, 0.2, 0.3, 0.8, 0.9, 1.0];
        let labels = [false, false, false, true, true, true];
        assert!((auc(&scores, &labels) - 1.0).abs() < 1e-12);
        let labels_rev = [true, true, true, false, false, false];
        assert!(auc(&scores, &labels_rev).abs() < 1e-12);
    }

    #[test]
    fn auc_with_ties() {
        let scores = [0.5, 0.5, 0.5, 0.5];
        let labels = [true, false, true, false];
        assert!((auc(&scores, &labels) - 0.5).abs() < 1e-12);
    }
}
