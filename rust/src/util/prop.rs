//! Property-testing micro-framework (proptest is unavailable offline).
//!
//! A [`Gen`] wraps the deterministic [`Rng`](super::Rng) with value
//! generators; [`check`] runs a property over many generated cases and, on
//! failure, reports the seed + case index so the failure replays exactly.
//! No shrinking — cases are kept small instead.

use super::rng::Rng;

/// Value generator handed to properties.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed) }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_i64(lo as i64, hi as i64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_i64(&mut self, len: usize, lo: i64, hi: i64) -> Vec<i64> {
        (0..len).map(|_| self.i64_in(lo, hi)).collect()
    }
}

/// Run `prop` over `cases` generated cases. Panics with seed + case index on
/// the first failing case (properties signal failure by returning an
/// `Err(String)`).
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_seeded(name, 0xC0FFEE, cases, &mut prop);
}

/// Like [`check`] with an explicit base seed (for replaying failures).
pub fn check_seeded<F>(name: &str, seed: u64, cases: usize, prop: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let mut g = Gen::new(seed.wrapping_add(case as u64));
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (replay: seed {})\n  {msg}",
                seed.wrapping_add(case as u64)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("tautology", 50, |g| {
            n += 1;
            let v = g.i64_in(-5, 5);
            if (-5..=5).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        check("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 200, |g| {
            let lo = g.i64_in(-100, 0);
            let hi = g.i64_in(1, 100);
            let v = g.i64_in(lo, hi);
            if v < lo || v > hi {
                return Err(format!("{v} outside [{lo}, {hi}]"));
            }
            let f = g.f64_in(lo as f64, hi as f64);
            if f < lo as f64 || f >= hi as f64 + 1.0 {
                return Err(format!("float {f} outside range"));
            }
            Ok(())
        });
    }
}
