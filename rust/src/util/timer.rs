//! Wall-clock timing helper for the bench harness and coordinator metrics.

use std::time::Instant;

/// Simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ns(&self) -> f64 {
        self.start.elapsed().as_nanos() as f64
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Time a closure over `n` iterations, returning seconds per iteration.
pub fn time_per_iter<F: FnMut()>(n: usize, mut f: F) -> f64 {
    let t = Timer::start();
    for _ in 0..n {
        f();
    }
    t.elapsed_s() / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_ns();
        let b = t.elapsed_ns();
        assert!(b >= a);
    }

    #[test]
    fn per_iter_positive() {
        let v = time_per_iter(10, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(v >= 0.0);
    }
}
