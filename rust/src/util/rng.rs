//! Deterministic PRNG: splitmix64 seeding + xoshiro256++ core.
//!
//! Used by the property-test framework, workload generators and the
//! coordinator's synthetic request streams. Deterministic across platforms
//! (pure integer arithmetic), which matters for reproducible benches.

/// xoshiro256++ with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 works, including 0.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our needs).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply trick; bias is < 2^-64, irrelevant for tests.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Random boolean with probability `p` of being true.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element reference.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for n in [1u64, 2, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
