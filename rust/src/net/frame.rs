//! Length-prefixed frame codec — the lowest wire layer.
//!
//! A frame is a 4-byte big-endian payload length followed by that many
//! bytes of UTF-8 JSON. The codec is the trust boundary for everything
//! arriving off a socket: lengths above the negotiated cap and EOF
//! mid-frame come back as **typed** [`FrameError`]s — there is no panic
//! path, no unbounded allocation (the payload buffer is only reserved
//! after the length passes the cap check), and a clean EOF at a frame
//! boundary is distinguishable from a truncated one so connection
//! teardown can tell "client hung up" from "client died mid-send".

use std::fmt;
use std::io::{Read, Write};

/// Default frame-size cap. Large enough for an `infer_batch` of a few
/// thousand rows or a full truth-table `swap`; small enough that a hostile
/// length prefix cannot balloon server memory.
pub const MAX_FRAME: usize = 1 << 20;

/// Why reading a frame failed. `Closed` is the *expected* end of a
/// connection; everything else is a protocol or transport fault.
#[derive(Debug)]
pub enum FrameError {
    /// Clean EOF exactly at a frame boundary (client finished and FIN'd).
    Closed,
    /// EOF inside the length prefix or payload (peer died mid-frame).
    Truncated,
    /// Declared length exceeds the cap; the payload was not read.
    Oversized { len: usize, max: usize },
    Io(std::io::Error),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Truncated => write!(f, "connection closed mid-frame"),
            FrameError::Oversized { len, max } => {
                write!(f, "frame of {len} bytes exceeds the {max}-byte cap")
            }
            FrameError::Io(e) => write!(f, "frame i/o error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

/// Read exactly `buf.len()` bytes, looping over short reads (partial
/// frames split across TCP segments are the norm, not the exception).
/// `any_read` distinguishes a clean EOF (nothing of this frame arrived)
/// from a truncated one.
fn read_full(r: &mut impl Read, buf: &mut [u8], mut any_read: bool) -> Result<(), FrameError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(if any_read { FrameError::Truncated } else { FrameError::Closed })
            }
            Ok(n) => {
                filled += n;
                any_read = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(())
}

/// Read one frame's payload. Rejects lengths above `max` *before*
/// allocating, so a hostile prefix costs four bytes, not `len`.
pub fn read_frame(r: &mut impl Read, max: usize) -> Result<Vec<u8>, FrameError> {
    let mut len_buf = [0u8; 4];
    read_full(r, &mut len_buf, false)?;
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > max {
        return Err(FrameError::Oversized { len, max });
    }
    let mut payload = vec![0u8; len];
    read_full(r, &mut payload, true)?;
    Ok(payload)
}

/// Write one frame (length prefix + payload). The same cap applies on the
/// way out so a server can never emit a frame its own clients reject.
pub fn write_frame(w: &mut impl Write, payload: &[u8], max: usize) -> Result<(), FrameError> {
    if payload.len() > max {
        return Err(FrameError::Oversized { len: payload.len(), max });
    }
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::io::Cursor;

    /// Reader that returns at most one byte per `read` call — the
    /// adversarial version of a frame split across many TCP segments.
    struct ByteAtATime<R>(R);

    impl<R: Read> Read for ByteAtATime<R> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.0.read(&mut buf[..buf.len().min(1)])
        }
    }

    fn encode(payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, payload, MAX_FRAME).unwrap();
        out
    }

    #[test]
    fn roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"{\"op\":\"stats\"}", MAX_FRAME).unwrap();
        write_frame(&mut buf, b"", MAX_FRAME).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r, MAX_FRAME).unwrap(), b"{\"op\":\"stats\"}");
        assert_eq!(read_frame(&mut r, MAX_FRAME).unwrap(), b"");
        assert!(matches!(read_frame(&mut r, MAX_FRAME), Err(FrameError::Closed)));
    }

    #[test]
    fn partial_frames_across_reads() {
        // every byte arrives in its own read() — prefix and payload must
        // reassemble identically
        let wire = encode(b"hello frame");
        let mut r = ByteAtATime(Cursor::new(wire));
        assert_eq!(read_frame(&mut r, MAX_FRAME).unwrap(), b"hello frame");
        assert!(matches!(read_frame(&mut r, MAX_FRAME), Err(FrameError::Closed)));
    }

    #[test]
    fn oversized_is_typed_and_cheap() {
        // length prefix claims 2 GiB: typed error, payload never allocated
        let mut wire = (2u32 << 30).to_be_bytes().to_vec();
        wire.extend_from_slice(b"xx");
        match read_frame(&mut Cursor::new(wire), MAX_FRAME) {
            Err(FrameError::Oversized { len, max }) => {
                assert_eq!(len, 2 << 30);
                assert_eq!(max, MAX_FRAME);
            }
            other => panic!("expected Oversized, got {other:?}"),
        }
        // the cap also applies on write
        assert!(matches!(
            write_frame(&mut Vec::new(), &[0u8; 32], 16),
            Err(FrameError::Oversized { .. })
        ));
    }

    #[test]
    fn truncation_is_distinguished_from_close() {
        let wire = encode(b"abcdef");
        // cut inside the payload and inside the prefix
        for cut in [1usize, 3, 5, 8] {
            let mut r = Cursor::new(wire[..cut].to_vec());
            assert!(
                matches!(read_frame(&mut r, MAX_FRAME), Err(FrameError::Truncated)),
                "cut at {cut}"
            );
        }
        // cut exactly at the boundary: clean close
        let mut r = Cursor::new(Vec::<u8>::new());
        assert!(matches!(read_frame(&mut r, MAX_FRAME), Err(FrameError::Closed)));
    }

    #[test]
    fn fuzz_random_bytes_through_decode_then_json() {
        // the satellite's mini-fuzz: arbitrary byte soup through frame
        // decode, and any payload that survives through the json parser —
        // typed errors only, never a panic, never a huge allocation
        prop::check("frame-fuzz", 400, |g| {
            let n = g.usize_in(0, 256);
            let mut bytes: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
            // half the cases: make the length prefix plausible so the
            // payload path is exercised, not just the oversize check
            if g.bool() && bytes.len() >= 4 {
                let body = (bytes.len() - 4).min(g.usize_in(0, 255));
                bytes[..4].copy_from_slice(&(body as u32).to_be_bytes());
            }
            let mut r = Cursor::new(bytes);
            loop {
                match read_frame(&mut r, 1 << 10) {
                    Ok(payload) => {
                        let _ = crate::json::parse(&String::from_utf8_lossy(&payload));
                    }
                    Err(_) => break,
                }
            }
            Ok(())
        });
    }
}
