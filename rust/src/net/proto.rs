//! Wire message model: typed request/response enums and their JSON codec.
//!
//! Every frame payload is one JSON object. Requests carry an `"op"`
//! discriminant and a client-chosen `"id"` echoed verbatim in the matching
//! response, so clients may pipeline requests and match replies out of
//! band. Responses carry `"ok"` — `true` with op-specific fields, `false`
//! with a machine-readable `"error"` kind and a human `"msg"`.
//!
//! Decode is the second trust boundary after the frame codec: every field
//! is range-checked (codes must fit `u32`, ids must be non-negative) and
//! failures are typed [`ProtoError`]s, never panics.

use crate::json::{self, obj, Value};

/// Machine-readable error kinds carried in error frames. The serving-plane
/// kinds mirror [`crate::coordinator::SubmitError`] one-to-one; the rest
/// are wire-layer conditions the serving plane never sees.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// Admission queues full — retry later (maps `SubmitError::Backpressure`).
    Backpressure,
    /// Service shut down — no retry will succeed (maps `SubmitError::Stopped`).
    Stopped,
    /// Malformed request at the serving plane, e.g. wrong input width
    /// (maps `SubmitError::Invalid`).
    Invalid,
    /// The frame payload was not a well-formed request.
    Parse,
    /// Admitted but the reply channel closed (model swap or shutdown
    /// landed mid-flight); the request may or may not have executed.
    Dropped,
    /// Recognized JSON, unrecognized `"op"` — or a `"model"` naming no
    /// loaded tenant (the registry analog of an unknown op: typed, the
    /// connection survives, other models keep working).
    Unsupported,
    /// The server requires a shared-secret `hello` and this connection has
    /// not presented the right token (absent, wrong, or a non-`hello`
    /// first frame). The server closes the connection after sending this.
    Auth,
    /// The request's batch panicked during execution; the request did not
    /// complete and is safe to retry (maps `SubmitError::Failed`).
    Failed,
    /// The request's deadline passed before its batch formed; it never
    /// executed (maps `SubmitError::Expired`).
    Expired,
    /// The target model is quarantined after repeated executor panics;
    /// retry after the quarantine window, or pick another tenant (maps
    /// `SubmitError::Quarantined`).
    Quarantined,
}

impl ErrorKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorKind::Backpressure => "backpressure",
            ErrorKind::Stopped => "stopped",
            ErrorKind::Invalid => "invalid",
            ErrorKind::Parse => "parse",
            ErrorKind::Dropped => "dropped",
            ErrorKind::Unsupported => "unsupported",
            ErrorKind::Auth => "auth",
            ErrorKind::Failed => "failed",
            ErrorKind::Expired => "expired",
            ErrorKind::Quarantined => "quarantined",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorKind> {
        Some(match s {
            "backpressure" => ErrorKind::Backpressure,
            "stopped" => ErrorKind::Stopped,
            "invalid" => ErrorKind::Invalid,
            "parse" => ErrorKind::Parse,
            "dropped" => ErrorKind::Dropped,
            "unsupported" => ErrorKind::Unsupported,
            "auth" => ErrorKind::Auth,
            "failed" => ErrorKind::Failed,
            "expired" => ErrorKind::Expired,
            "quarantined" => ErrorKind::Quarantined,
            _ => return None,
        })
    }
}

impl std::fmt::Display for ErrorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Decode failure: the payload parsed as JSON but is not a valid message
/// (or did not parse at all). Carries a human-readable reason.
#[derive(Debug)]
pub struct ProtoError(pub String);

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "protocol error: {}", self.0)
    }
}

impl std::error::Error for ProtoError {}

fn perr(msg: impl Into<String>) -> ProtoError {
    ProtoError(msg.into())
}

/// Client→server messages.
///
/// Inference and swap ops carry an optional `"model"` tenant name; `None`
/// encodes to no field at all, so a single-tenant client speaking the
/// pre-registry protocol emits byte-identical frames and keeps working
/// against multi-tenant servers (model-less frames route to the default
/// tenant).
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    /// Optional first frame: `{"op":"hello","id":N,"auth":"..."}`. A
    /// server started with a shared-secret token requires this before any
    /// other op (and answers [`ErrorKind::Auth`] otherwise); servers
    /// without a token ack it as a no-op, so clients may always lead with
    /// a hello.
    Hello { id: u64, auth: Option<String> },
    /// One sample:
    /// `{"op":"infer","id":N,"codes":[...],"model":"name"?,"deadline_us":D?}`.
    /// `deadline_us` is a relative budget: if the request has not entered a
    /// batch within `D` microseconds of admission it is shed with a typed
    /// `expired` error instead of executing late. Like `model`, `None`
    /// emits no field at all.
    Infer { id: u64, model: Option<String>, codes: Vec<u32>, deadline_us: Option<u64> },
    /// Several samples in one frame:
    /// `{"op":"infer_batch","id":N,"batch":[[...],...],"model":"name"?,"deadline_us":D?}`.
    /// One response frame carries all rows; the deadline applies to every
    /// row independently.
    InferBatch { id: u64, model: Option<String>, batch: Vec<Vec<u32>>, deadline_us: Option<u64> },
    /// Serving-plane + wire counters snapshot: `{"op":"stats","id":N}`.
    Stats { id: u64 },
    /// Hot-swap one edge's truth table:
    /// `{"op":"swap","id":N,"layer":L,"q":Q,"p":P,"table":[...],"model":"name"?}`.
    Swap { id: u64, model: Option<String>, layer: usize, q: usize, p: usize, table: Vec<i64> },
    /// Ask the server process to begin shutdown: `{"op":"shutdown","id":N}`.
    Shutdown { id: u64 },
}

/// Server→client messages. `id` always echoes the request.
#[derive(Clone, Debug, PartialEq)]
pub enum WireResponse {
    /// `{"id":N,"ok":true,"sums":[...],"latency_us":F}`.
    Sums { id: u64, sums: Vec<i64>, latency_us: f64 },
    /// `{"id":N,"ok":true,"batch":[[...],...]}` — rows in request order.
    Batch { id: u64, batch: Vec<Vec<i64>> },
    /// `{"id":N,"ok":true,"stats":{...}}` — see [`crate::net::server`]
    /// for the field set.
    Stats { id: u64, stats: Value },
    /// `{"id":N,"ok":true}` — ack for `swap` / `shutdown`.
    Ok { id: u64 },
    /// `{"id":N,"ok":false,"error":"<kind>","msg":"..."}`.
    Error { id: u64, kind: ErrorKind, msg: String },
}

impl WireResponse {
    pub fn id(&self) -> u64 {
        match self {
            WireResponse::Sums { id, .. }
            | WireResponse::Batch { id, .. }
            | WireResponse::Stats { id, .. }
            | WireResponse::Ok { id }
            | WireResponse::Error { id, .. } => *id,
        }
    }
}

/// Best-effort id extraction from a payload that failed full decode, so
/// error frames for malformed-but-parseable requests (unknown op, bad
/// codes) still echo the client's id. Unparseable payloads yield `None`
/// and the server falls back to id 0.
pub fn peek_id(payload: &str) -> Option<u64> {
    let v = json::parse(payload).ok()?;
    get_id(&v).ok()
}

fn get_id(v: &Value) -> Result<u64, ProtoError> {
    match v.get("id").and_then(Value::as_i64) {
        Some(id) if id >= 0 => Ok(id as u64),
        Some(_) => Err(perr("\"id\" must be non-negative")),
        None => Err(perr("missing integer \"id\"")),
    }
}

/// Decode a JSON array of non-negative integers into LUT input codes.
/// Codes are *structurally* validated here (integer, fits u32); semantic
/// range checks against the quantizer's level count belong to the model.
fn get_codes(v: &Value, what: &str) -> Result<Vec<u32>, ProtoError> {
    let arr = v.as_array().ok_or_else(|| perr(format!("{what} must be an array")))?;
    arr.iter()
        .map(|x| match x.as_i64() {
            Some(c) if (0..=u32::MAX as i64).contains(&c) => Ok(c as u32),
            _ => Err(perr(format!("{what} entries must be integers in [0, 2^32)"))),
        })
        .collect()
}

fn codes_value(codes: &[u32]) -> Value {
    Value::Array(codes.iter().map(|&c| Value::Int(c as i64)).collect())
}

fn sums_value(sums: &[i64]) -> Value {
    Value::Array(sums.iter().map(|&s| Value::Int(s)).collect())
}

/// Append `("model", name)` when a tenant is named — absent otherwise, so
/// model-less frames stay byte-identical to the pre-registry protocol.
fn push_model(fields: &mut Vec<(&str, Value)>, model: &Option<String>) {
    if let Some(m) = model {
        fields.push(("model", Value::Str(m.clone())));
    }
}

/// Optional string field (`"model"` tenant name, `"auth"` token);
/// present-but-not-a-string is malformed, absent is `None`.
fn get_str_opt(v: &Value, key: &str) -> Result<Option<String>, ProtoError> {
    match v.get(key) {
        None => Ok(None),
        Some(m) => match m.as_str() {
            Some(s) => Ok(Some(s.to_string())),
            None => Err(perr(format!("\"{key}\" must be a string"))),
        },
    }
}

fn get_model(v: &Value) -> Result<Option<String>, ProtoError> {
    get_str_opt(v, "model")
}

/// Append `("deadline_us", D)` when a deadline is set — absent otherwise,
/// same compatibility contract as [`push_model`].
fn push_deadline(fields: &mut Vec<(&str, Value)>, deadline_us: &Option<u64>) {
    if let Some(d) = deadline_us {
        fields.push(("deadline_us", Value::Int(*d as i64)));
    }
}

/// Optional non-negative integer `"deadline_us"`; absent is `None`,
/// present-but-negative (or non-integer) is malformed.
fn get_deadline(v: &Value) -> Result<Option<u64>, ProtoError> {
    match v.get("deadline_us") {
        None => Ok(None),
        Some(d) => match d.as_i64() {
            Some(us) if us >= 0 => Ok(Some(us as u64)),
            _ => Err(perr("\"deadline_us\" must be a non-negative integer")),
        },
    }
}

impl WireRequest {
    pub fn id(&self) -> u64 {
        match self {
            WireRequest::Hello { id, .. }
            | WireRequest::Infer { id, .. }
            | WireRequest::InferBatch { id, .. }
            | WireRequest::Stats { id }
            | WireRequest::Swap { id, .. }
            | WireRequest::Shutdown { id } => *id,
        }
    }

    pub fn encode(&self) -> String {
        let v = match self {
            WireRequest::Hello { id, auth } => {
                let mut fields = vec![
                    ("op", Value::Str("hello".into())),
                    ("id", Value::Int(*id as i64)),
                ];
                if let Some(a) = auth {
                    fields.push(("auth", Value::Str(a.clone())));
                }
                obj(fields)
            }
            WireRequest::Infer { id, model, codes, deadline_us } => {
                let mut fields = vec![
                    ("op", Value::Str("infer".into())),
                    ("id", Value::Int(*id as i64)),
                    ("codes", codes_value(codes)),
                ];
                push_model(&mut fields, model);
                push_deadline(&mut fields, deadline_us);
                obj(fields)
            }
            WireRequest::InferBatch { id, model, batch, deadline_us } => {
                let mut fields = vec![
                    ("op", Value::Str("infer_batch".into())),
                    ("id", Value::Int(*id as i64)),
                    ("batch", Value::Array(batch.iter().map(|row| codes_value(row)).collect())),
                ];
                push_model(&mut fields, model);
                push_deadline(&mut fields, deadline_us);
                obj(fields)
            }
            WireRequest::Stats { id } => obj(vec![
                ("op", Value::Str("stats".into())),
                ("id", Value::Int(*id as i64)),
            ]),
            WireRequest::Swap { id, model, layer, q, p, table } => {
                let mut fields = vec![
                    ("op", Value::Str("swap".into())),
                    ("id", Value::Int(*id as i64)),
                    ("layer", Value::Int(*layer as i64)),
                    ("q", Value::Int(*q as i64)),
                    ("p", Value::Int(*p as i64)),
                    ("table", sums_value(table)),
                ];
                push_model(&mut fields, model);
                obj(fields)
            }
            WireRequest::Shutdown { id } => obj(vec![
                ("op", Value::Str("shutdown".into())),
                ("id", Value::Int(*id as i64)),
            ]),
        };
        json::to_string(&v)
    }

    /// Decode a frame payload. Unknown ops are distinguished from malformed
    /// JSON so the server can answer `Unsupported` with the request's id
    /// instead of tearing the connection down.
    pub fn decode(payload: &str) -> Result<WireRequest, ProtoError> {
        let v = json::parse(payload).map_err(|e| perr(e.to_string()))?;
        let id = get_id(&v)?;
        let op = v.get("op").and_then(Value::as_str).ok_or_else(|| perr("missing \"op\""))?;
        match op {
            "hello" => Ok(WireRequest::Hello { id, auth: get_str_opt(&v, "auth")? }),
            "infer" => {
                let codes = get_codes(v.req("codes").map_err(|e| perr(e.to_string()))?, "codes")?;
                Ok(WireRequest::Infer {
                    id,
                    model: get_model(&v)?,
                    codes,
                    deadline_us: get_deadline(&v)?,
                })
            }
            "infer_batch" => {
                let rows = v.req_array("batch").map_err(|e| perr(e.to_string()))?;
                let batch = rows
                    .iter()
                    .map(|row| get_codes(row, "batch rows"))
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(WireRequest::InferBatch {
                    id,
                    model: get_model(&v)?,
                    batch,
                    deadline_us: get_deadline(&v)?,
                })
            }
            "stats" => Ok(WireRequest::Stats { id }),
            "swap" => {
                let dim = |k: &str| -> Result<usize, ProtoError> {
                    match v.get(k).and_then(Value::as_i64) {
                        Some(x) if x >= 0 => Ok(x as usize),
                        _ => Err(perr(format!("\"{k}\" must be a non-negative integer"))),
                    }
                };
                let table = v
                    .req_array("table")
                    .map_err(|e| perr(e.to_string()))?
                    .iter()
                    .map(|x| x.as_i64().ok_or_else(|| perr("table entries must be integers")))
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(WireRequest::Swap {
                    id,
                    model: get_model(&v)?,
                    layer: dim("layer")?,
                    q: dim("q")?,
                    p: dim("p")?,
                    table,
                })
            }
            "shutdown" => Ok(WireRequest::Shutdown { id }),
            other => Err(perr(format!("unsupported op {other:?}"))),
        }
    }
}

impl WireResponse {
    pub fn encode(&self) -> String {
        let v = match self {
            WireResponse::Sums { id, sums, latency_us } => obj(vec![
                ("id", Value::Int(*id as i64)),
                ("ok", Value::Bool(true)),
                ("sums", sums_value(sums)),
                ("latency_us", Value::Float(*latency_us)),
            ]),
            WireResponse::Batch { id, batch } => obj(vec![
                ("id", Value::Int(*id as i64)),
                ("ok", Value::Bool(true)),
                ("batch", Value::Array(batch.iter().map(|row| sums_value(row)).collect())),
            ]),
            WireResponse::Stats { id, stats } => obj(vec![
                ("id", Value::Int(*id as i64)),
                ("ok", Value::Bool(true)),
                ("stats", stats.clone()),
            ]),
            WireResponse::Ok { id } => {
                obj(vec![("id", Value::Int(*id as i64)), ("ok", Value::Bool(true))])
            }
            WireResponse::Error { id, kind, msg } => obj(vec![
                ("id", Value::Int(*id as i64)),
                ("ok", Value::Bool(false)),
                ("error", Value::Str(kind.as_str().into())),
                ("msg", Value::Str(msg.clone())),
            ]),
        };
        json::to_string(&v)
    }

    pub fn decode(payload: &str) -> Result<WireResponse, ProtoError> {
        let v = json::parse(payload).map_err(|e| perr(e.to_string()))?;
        let id = get_id(&v)?;
        let ok = v.get("ok").and_then(Value::as_bool).ok_or_else(|| perr("missing \"ok\""))?;
        if !ok {
            let kind_s =
                v.get("error").and_then(Value::as_str).ok_or_else(|| perr("missing \"error\""))?;
            let kind = ErrorKind::parse(kind_s)
                .ok_or_else(|| perr(format!("unknown error kind {kind_s:?}")))?;
            let msg = v.get("msg").and_then(Value::as_str).unwrap_or("").to_string();
            return Ok(WireResponse::Error { id, kind, msg });
        }
        if let Some(sums) = v.get("sums") {
            let sums = sums
                .to_i64_vec()
                .map_err(|e| perr(format!("bad sums: {e}")))?;
            let latency_us = v.get("latency_us").and_then(Value::as_f64).unwrap_or(0.0);
            return Ok(WireResponse::Sums { id, sums, latency_us });
        }
        if let Some(batch) = v.get("batch") {
            let rows = batch.as_array().ok_or_else(|| perr("batch must be an array"))?;
            let batch = rows
                .iter()
                .map(|row| row.to_i64_vec().map_err(|e| perr(format!("bad batch row: {e}"))))
                .collect::<Result<Vec<_>, _>>()?;
            return Ok(WireResponse::Batch { id, batch });
        }
        if let Some(stats) = v.get("stats") {
            return Ok(WireResponse::Stats { id, stats: stats.clone() });
        }
        Ok(WireResponse::Ok { id })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: WireRequest) {
        let wire = req.encode();
        assert_eq!(WireRequest::decode(&wire).unwrap(), req, "{wire}");
    }

    fn roundtrip_resp(resp: WireResponse) {
        let wire = resp.encode();
        assert_eq!(WireResponse::decode(&wire).unwrap(), resp, "{wire}");
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(WireRequest::Infer { id: 0, model: None, codes: vec![], deadline_us: None });
        roundtrip_req(WireRequest::Infer {
            id: 7,
            model: None,
            codes: vec![0, 1, u32::MAX],
            deadline_us: None,
        });
        roundtrip_req(WireRequest::Infer {
            id: 7,
            model: Some("jsc-v2".into()),
            codes: vec![0, 1],
            deadline_us: Some(2_500),
        });
        roundtrip_req(WireRequest::InferBatch {
            id: 8,
            model: None,
            batch: vec![vec![1, 2, 3], vec![4, 5, 6]],
            deadline_us: Some(0),
        });
        roundtrip_req(WireRequest::InferBatch {
            id: 8,
            model: Some("b".into()),
            batch: vec![vec![1, 2, 3]],
            deadline_us: None,
        });
        roundtrip_req(WireRequest::Stats { id: 9 });
        roundtrip_req(WireRequest::Swap {
            id: 10,
            model: None,
            layer: 1,
            q: 2,
            p: 3,
            table: vec![-5, 0, 5, i64::MAX],
        });
        roundtrip_req(WireRequest::Swap {
            id: 10,
            model: Some("canary".into()),
            layer: 0,
            q: 0,
            p: 0,
            table: vec![1],
        });
        roundtrip_req(WireRequest::Shutdown { id: u64::MAX / 2 });
        roundtrip_req(WireRequest::Hello { id: 11, auth: None });
        roundtrip_req(WireRequest::Hello { id: 12, auth: Some("s3cret".into()) });
    }

    #[test]
    fn model_less_frames_keep_the_pre_registry_encoding() {
        // a `model: None` / `deadline_us: None` request must not emit the
        // keys at all: old servers reject unknown fields nowhere, but old
        // *captures* (and the bench baselines) compare frames byte-for-byte
        let plain = WireRequest::Infer { id: 3, model: None, codes: vec![7, 0], deadline_us: None };
        let wire = plain.encode();
        assert!(!wire.contains("model"), "{wire}");
        assert!(!wire.contains("deadline"), "{wire}");
        assert_eq!(wire, "{\"op\":\"infer\",\"id\":3,\"codes\":[7,0]}");
        // and a model-less decode accepts frames from pre-registry clients
        let req = WireRequest::decode("{\"op\":\"infer\",\"id\":3,\"codes\":[7,0]}").unwrap();
        assert_eq!(
            req,
            WireRequest::Infer { id: 3, model: None, codes: vec![7, 0], deadline_us: None }
        );
        // "model" present but not a string is malformed, not ignored
        let bad = "{\"op\":\"infer\",\"id\":1,\"codes\":[],\"model\":7}";
        assert!(WireRequest::decode(bad).is_err());
        assert!(WireRequest::decode("{\"op\":\"hello\",\"id\":1,\"auth\":9}").is_err());
        // same for a bogus deadline: typed rejection, not silent acceptance
        let bad = "{\"op\":\"infer\",\"id\":1,\"codes\":[],\"deadline_us\":-3}";
        assert!(WireRequest::decode(bad).is_err());
    }

    #[test]
    fn responses_roundtrip() {
        roundtrip_resp(WireResponse::Sums { id: 1, sums: vec![-3, 0, 9], latency_us: 12.5 });
        roundtrip_resp(WireResponse::Batch { id: 2, batch: vec![vec![1], vec![-2, 3]] });
        roundtrip_resp(WireResponse::Ok { id: 3 });
        for kind in [
            ErrorKind::Backpressure,
            ErrorKind::Stopped,
            ErrorKind::Invalid,
            ErrorKind::Parse,
            ErrorKind::Dropped,
            ErrorKind::Unsupported,
            ErrorKind::Auth,
            ErrorKind::Failed,
            ErrorKind::Expired,
            ErrorKind::Quarantined,
        ] {
            roundtrip_resp(WireResponse::Error { id: 4, kind, msg: "why".into() });
        }
        let stats = obj(vec![("completed", Value::Int(41))]);
        roundtrip_resp(WireResponse::Stats { id: 5, stats });
    }

    #[test]
    fn decode_rejects_malformed() {
        for bad in [
            "",                                           // not JSON
            "42",                                         // not an object
            "{\"op\":\"infer\"}",                         // missing id
            "{\"op\":\"infer\",\"id\":-1,\"codes\":[]}",  // negative id
            "{\"op\":\"infer\",\"id\":1}",                // missing codes
            "{\"op\":\"infer\",\"id\":1,\"codes\":[-1]}", // negative code
            "{\"op\":\"infer\",\"id\":1,\"codes\":[4294967296]}", // > u32
            "{\"op\":\"infer\",\"id\":1,\"codes\":[1.5]}", // fractional code
            "{\"op\":\"launch\",\"id\":1}",               // unknown op
            "{\"id\":1}",                                 // no op
            "{\"op\":\"swap\",\"id\":1,\"layer\":-2,\"q\":0,\"p\":0,\"table\":[]}",
        ] {
            assert!(WireRequest::decode(bad).is_err(), "should reject {bad:?}");
        }
        assert!(WireResponse::decode("{\"id\":1,\"ok\":false,\"error\":\"martian\"}").is_err());
        assert!(WireResponse::decode("{\"id\":1}").is_err());
    }

    #[test]
    fn unknown_op_error_still_names_the_op() {
        // the server wants to answer Unsupported with the request id, so
        // the decode error for a recognized-JSON/unknown-op frame must be
        // distinguishable by message content
        let err = WireRequest::decode("{\"op\":\"warp\",\"id\":3}").unwrap_err();
        assert!(err.to_string().contains("unsupported op"), "{err}");
    }
}
