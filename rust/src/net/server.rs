//! Framed TCP front end over the serving plane.
//!
//! [`NetServer`] owns an accept loop plus two threads per connection and
//! maps wire sessions onto [`Service`]:
//!
//! - **Shard affinity**: each connection is pinned to one admission shard
//!   (`conn_id % shards`) via [`Service::submit_to`] — a TCP session is a
//!   client in the serving plane's sense, so its requests share a queue
//!   and batch together, same as the in-process benches.
//! - **Backpressure is a frame, never a hang**: [`SubmitError`]s are
//!   written to the socket *immediately from the reader thread*, bypassing
//!   the in-order completion queue. A client that overruns admission gets
//!   its `backpressure` error while earlier responses are still pending.
//! - **Bounded in-flight window**: the reader blocks once `in_flight`
//!   accepted requests await completion, so one connection cannot buffer
//!   unbounded replies server-side; TCP pushback does the rest.
//! - **Disconnect safety**: reply channels are rendezvous-free
//!   (`sync_channel(1)` server-side) and the completion thread keeps
//!   draining them after a write fails, so a vanished client never stalls
//!   an executor or leaks a pending reply.
//! - **Graceful drain**: [`NetServer::shutdown`] closes the read half of
//!   every connection; readers see EOF, completion threads flush what was
//!   already admitted, then FIN. The server never shuts the [`Service`]
//!   down — the caller owns that ordering.
//! - **Multi-tenant routing**: inference and swap frames may name a
//!   `"model"`; the name is resolved against the service's
//!   [`ModelRegistry`](crate::coordinator::ModelRegistry) *per frame* (a
//!   concurrent load/unload/swap takes effect on the very next frame), and
//!   an unknown name answers a typed `unsupported` error while the
//!   connection — and every other tenant on it — keeps working. Model-less
//!   frames route to the default tenant, so pre-registry clients are
//!   wire-compatible without changes.
//! - **Shared-secret auth**: when [`NetCfg::auth_token`] is set, the first
//!   frame of every connection must be a `hello` carrying the token; any
//!   other first frame, or a wrong token, gets a typed `auth` error and
//!   the connection is closed. Without a configured token, `hello` is an
//!   acked no-op so clients may always lead with one.
//! - **Slow-loris guard**: [`NetCfg::read_idle`] bounds how long a reader
//!   blocks waiting for the next byte. A connection that goes quiet (or
//!   trickles a frame slower than the budget) is closed and counted in
//!   `idle_kills` — idle sockets cannot pin reader threads forever.
//! - **Typed failure frames**: the serving plane's fault outcomes surface
//!   as error frames with their own kinds — `failed` (batch panicked,
//!   retryable), `expired` (deadline passed before batch formation),
//!   `quarantined` (tenant circuit breaker open). Requests carry an
//!   optional `deadline_us` that flows through to the DRR batcher.
//! - **Deterministic wire faults**: [`WireFaults`] (off by default) makes
//!   the server misbehave on purpose — torn frames, response stalls,
//!   mid-stream disconnects — on a fixed schedule, so client resilience
//!   and the chaos harness are testable without OS-level packet games.

use std::io::{BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{ModelId, Reply, Service, SubmitError};
use crate::json::{obj, Value};

use super::frame::{read_frame, write_frame, FrameError, MAX_FRAME};
use super::proto::{peek_id, ErrorKind, WireRequest, WireResponse};

/// Deterministic wire-fault injection schedule, all counted per
/// connection. Zero means "never" everywhere, so `Default` is a server
/// that never misbehaves; the chaos harness and `serve --fault-*` arm it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireFaults {
    /// Every `torn_every`-th response frame is torn: the length prefix
    /// claims the full payload, half the bytes follow, then the socket is
    /// severed — the client observes `Truncated` mid-frame.
    pub torn_every: usize,
    /// Every `stall_every`-th response is delayed by [`WireFaults::stall`]
    /// before it is written (a server-side hiccup the client's read
    /// timeout must absorb or surface).
    pub stall_every: usize,
    /// How long a stalled response sits before writing.
    pub stall: Duration,
    /// Sever the connection (both halves) after this many inbound frames,
    /// without answering the last one — a mid-stream crash from the
    /// client's point of view.
    pub disconnect_after: usize,
}

impl WireFaults {
    pub fn armed(&self) -> bool {
        self.torn_every > 0 || self.stall_every > 0 || self.disconnect_after > 0
    }
}

/// Front-end knobs, all per-connection except `levels` and `auth_token`.
#[derive(Clone, Debug)]
pub struct NetCfg {
    /// Frame-size cap in both directions (default [`MAX_FRAME`]).
    pub max_frame: usize,
    /// Requests a connection may have awaiting completion before its
    /// reader blocks (the wire-side analogue of the benches' in-flight
    /// window). Counted in frames: a batch frame occupies one slot.
    pub in_flight: usize,
    /// Quantizer level count advertised in `stats` frames so remote load
    /// generators can synthesize in-range codes; `0` when unknown.
    pub levels: u64,
    /// Shared secret. `Some(token)` requires every connection's first
    /// frame to be a `hello` presenting exactly this token before any
    /// other op is served; `None` (default) disables the gate.
    pub auth_token: Option<String>,
    /// Per-connection read idle budget (the slow-loris guard): if no byte
    /// arrives for this long the connection is closed and counted in
    /// `idle_kills`. `None` disables the guard; the default is 60 s —
    /// far above any sane inter-frame gap, low enough that abandoned
    /// sockets cannot pin reader threads indefinitely.
    pub read_idle: Option<Duration>,
    /// Deterministic wire-fault schedule; `Default` (all zeros) is off.
    pub faults: WireFaults,
}

impl Default for NetCfg {
    fn default() -> Self {
        NetCfg {
            max_frame: MAX_FRAME,
            in_flight: 64,
            levels: 0,
            auth_token: None,
            read_idle: Some(Duration::from_secs(60)),
            faults: WireFaults::default(),
        }
    }
}

/// Wire-layer counters, shared across all connections.
#[derive(Default)]
pub struct NetCounters {
    pub accepted: AtomicU64,
    pub frames_in: AtomicU64,
    pub frames_out: AtomicU64,
    pub parse_errors: AtomicU64,
    /// Response frames carrying successful results.
    pub wire_completed: AtomicU64,
    /// Connections closed by the read-idle (slow-loris) guard.
    pub idle_kills: AtomicU64,
    /// Wire faults deliberately injected per the [`WireFaults`] schedule.
    pub faults_injected: AtomicU64,
}

/// Point-in-time copy of [`NetCounters`].
#[derive(Clone, Copy, Debug, Default)]
pub struct NetStats {
    pub accepted: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub parse_errors: u64,
    pub wire_completed: u64,
    pub idle_kills: u64,
    pub faults_injected: u64,
}

/// What the reader hands the completion thread. The channel is bounded at
/// `in_flight`, which is what bounds per-connection server memory.
enum Out {
    /// Pending replies to collect and write, in admission order.
    Reply { id: u64, rxs: Vec<Receiver<Reply>>, batch: bool },
    /// Replies to drain without writing (a batch that partially failed
    /// admission — the client already got an error frame for the whole
    /// batch, but the admitted rows still execute and must be received).
    Discard(Vec<Receiver<Reply>>),
}

struct Conn {
    /// Kept only so [`NetServer::shutdown`] can close the read half.
    stream: TcpStream,
    reader: JoinHandle<()>,
    completion: JoinHandle<()>,
}

/// The running front end. Dropping it shuts it down (the wrapped
/// [`Service`] is untouched either way).
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    shutdown_requested: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<Conn>>>,
    counters: Arc<NetCounters>,
}

impl NetServer {
    /// Start serving `svc` on `listener`. The listener may be bound to
    /// port 0; [`NetServer::local_addr`] reports the resolved address.
    pub fn start(svc: Arc<Service>, listener: TcpListener, cfg: NetCfg) -> std::io::Result<NetServer> {
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let shutdown_requested = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<Conn>>> = Arc::new(Mutex::new(Vec::new()));
        let counters = Arc::new(NetCounters::default());

        let accept = {
            let stop = Arc::clone(&stop);
            let shutdown_requested = Arc::clone(&shutdown_requested);
            let conns = Arc::clone(&conns);
            let counters = Arc::clone(&counters);
            std::thread::spawn(move || {
                let mut conn_id: u64 = 0;
                loop {
                    if stop.load(Ordering::Acquire) || svc.is_stopped() {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            counters.accepted.fetch_add(1, Ordering::Relaxed);
                            let shard = conn_id as usize % svc.cfg().shards.max(1);
                            conn_id += 1;
                            // a setup error means the peer vanished between
                            // accept and thread spawn; just move on
                            if let Ok(conn) = spawn_conn(
                                Arc::clone(&svc),
                                stream,
                                shard,
                                cfg.clone(),
                                Arc::clone(&counters),
                                Arc::clone(&shutdown_requested),
                            ) {
                                conns.lock().unwrap().push(conn);
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(2)),
                    }
                }
            })
        };

        Ok(NetServer { local_addr, stop, shutdown_requested, accept: Some(accept), conns, counters })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Whether some client sent a `shutdown` op. The embedding process
    /// (e.g. `kanele serve`) polls this and decides when to actually stop.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown_requested.load(Ordering::Acquire)
    }

    /// Wire counters snapshot.
    pub fn stats(&self) -> NetStats {
        NetStats {
            accepted: self.counters.accepted.load(Ordering::Relaxed),
            frames_in: self.counters.frames_in.load(Ordering::Relaxed),
            frames_out: self.counters.frames_out.load(Ordering::Relaxed),
            parse_errors: self.counters.parse_errors.load(Ordering::Relaxed),
            wire_completed: self.counters.wire_completed.load(Ordering::Relaxed),
            idle_kills: self.counters.idle_kills.load(Ordering::Relaxed),
            faults_injected: self.counters.faults_injected.load(Ordering::Relaxed),
        }
    }

    /// Graceful drain: stop accepting, close every connection's read half
    /// (no new requests), let completion threads flush everything already
    /// admitted, FIN, and join. Idempotent. Does not stop the [`Service`].
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for c in &conns {
            // EOF the reader; already-closed sockets are fine
            let _ = c.stream.shutdown(Shutdown::Read);
        }
        for c in conns {
            let _ = c.reader.join();
            let _ = c.completion.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serialize + frame + flush one response under the writer lock. Returns
/// `false` once the socket is dead so callers stop writing (but keep
/// draining).
fn write_response(
    writer: &Mutex<BufWriter<TcpStream>>,
    counters: &NetCounters,
    max_frame: usize,
    resp: &WireResponse,
) -> bool {
    let payload = resp.encode();
    let mut w = writer.lock().unwrap();
    let ok = write_frame(&mut *w, payload.as_bytes(), max_frame).is_ok() && w.flush().is_ok();
    if ok {
        counters.frames_out.fetch_add(1, Ordering::Relaxed);
        if !matches!(resp, WireResponse::Error { .. }) {
            counters.wire_completed.fetch_add(1, Ordering::Relaxed);
        }
    }
    ok
}

fn submit_error(id: u64, e: SubmitError) -> WireResponse {
    let kind = match e {
        SubmitError::Backpressure => ErrorKind::Backpressure,
        SubmitError::Stopped => ErrorKind::Stopped,
        SubmitError::Invalid(_) => ErrorKind::Invalid,
        // the registry analog of an unknown op: typed, non-fatal
        SubmitError::UnknownModel(_) => ErrorKind::Unsupported,
        // fault outcomes: each keeps its own kind so clients can pick the
        // right recovery (retry / respect the deadline / back off tenant)
        SubmitError::Failed => ErrorKind::Failed,
        SubmitError::Expired => ErrorKind::Expired,
        SubmitError::Quarantined(_) => ErrorKind::Quarantined,
    };
    WireResponse::Error { id, kind, msg: e.to_string() }
}

/// A reply channel closed without a verdict: the request raced a model
/// swap or shutdown and may or may not have executed.
fn dropped_error(id: u64) -> WireResponse {
    WireResponse::Error {
        id,
        kind: ErrorKind::Dropped,
        msg: "reply dropped (model swap or shutdown mid-flight)".to_string(),
    }
}

/// Tear a response on purpose: claim the full payload length, emit half
/// the bytes, flush, and sever the socket — the wire analogue of a server
/// dying mid-send. The peer's next read ends in `FrameError::Truncated`.
fn inject_torn_frame(
    writer: &Mutex<BufWriter<TcpStream>>,
    counters: &NetCounters,
    resp: &WireResponse,
) {
    counters.faults_injected.fetch_add(1, Ordering::Relaxed);
    let payload = resp.encode();
    let mut w = writer.lock().unwrap();
    let _ = w.write_all(&(payload.len() as u32).to_be_bytes());
    let _ = w.write_all(&payload.as_bytes()[..payload.len() / 2]);
    let _ = w.flush();
    let _ = w.get_ref().shutdown(Shutdown::Both);
}

/// Resolve an optional wire model name to a tenant id: no name routes to
/// the default tenant, an unknown name is a typed `unsupported` error
/// carrying the name (the connection survives — resolution is per frame).
fn resolve_model(svc: &Service, id: u64, model: Option<&str>) -> Result<ModelId, WireResponse> {
    match model {
        None => Ok(ModelId::DEFAULT),
        Some(name) => svc.registry().get(name).ok_or_else(|| WireResponse::Error {
            id,
            kind: ErrorKind::Unsupported,
            msg: format!("unknown model: {name}"),
        }),
    }
}

/// The `stats` frame body: serving-plane snapshot + model/topology facts a
/// remote client needs to drive load, + wire counters. All floats are
/// NaN-guarded — `json::write_f64` turns NaN into `null`, which strict
/// clients would reject.
fn stats_value(svc: &Service, counters: &NetCounters, levels: u64) -> Value {
    let s = svc.stats();
    let nz = |x: f64| if x.is_finite() { x } else { 0.0 };
    // per-tenant breakdown: live tenants sorted by id, then retired
    // history — remote dashboards and the multi-model loadgen read this
    let models = Value::Array(
        s.per_tenant
            .iter()
            .map(|t| {
                obj(vec![
                    ("name", Value::Str(t.name.clone())),
                    ("id", Value::Int(t.id as i64)),
                    ("input_width", Value::Int(t.input_width as i64)),
                    ("admitted", Value::Int(t.admitted as i64)),
                    ("completed", Value::Int(t.completed as i64)),
                    ("quota_drops", Value::Int(t.quota_drops as i64)),
                    ("batches", Value::Int(t.batches as i64)),
                    ("mean_batch", Value::Float(nz(t.mean_batch))),
                    ("latency_p50_us", Value::Float(nz(t.latency_p50_us))),
                    ("latency_p99_us", Value::Float(nz(t.latency_p99_us))),
                    ("canary_rows", Value::Int(t.canary_rows as i64)),
                    ("canary_agreement", Value::Float(nz(t.canary_agreement))),
                    ("retired", Value::Bool(t.retired)),
                    ("failed", Value::Int(t.failed as i64)),
                    ("shed_expired", Value::Int(t.shed_expired as i64)),
                    ("quarantined", Value::Bool(t.quarantined)),
                ])
            })
            .collect(),
    );
    obj(vec![
        ("completed", Value::Int(s.completed as i64)),
        ("rejected", Value::Int(s.rejected as i64)),
        ("dropped", Value::Int(s.dropped as i64)),
        ("quota_drops", Value::Int(s.quota_drops as i64)),
        ("failed", Value::Int(s.failed as i64)),
        ("shed_expired", Value::Int(s.shed_expired as i64)),
        ("exec_panics", Value::Int(s.exec_panics as i64)),
        ("respawns", Value::Int(s.respawns as i64)),
        ("quarantine_drops", Value::Int(s.quarantine_drops as i64)),
        ("models", models),
        ("batches", Value::Int(s.batches as i64)),
        ("mean_batch", Value::Float(nz(s.mean_batch))),
        ("latency_p50_us", Value::Float(nz(s.latency_p50_us))),
        ("latency_p90_us", Value::Float(nz(s.latency_p90_us))),
        ("latency_p99_us", Value::Float(nz(s.latency_p99_us))),
        ("throughput_rps", Value::Float(nz(s.throughput_rps))),
        ("fused_ops", Value::Int(s.fused_ops as i64)),
        ("input_width", Value::Int(svc.input_width() as i64)),
        ("levels", Value::Int(levels as i64)),
        ("shards", Value::Int(svc.cfg().shards as i64)),
        ("workers", Value::Int(svc.cfg().workers as i64)),
        ("net_accepted", Value::Int(counters.accepted.load(Ordering::Relaxed) as i64)),
        ("net_frames_in", Value::Int(counters.frames_in.load(Ordering::Relaxed) as i64)),
        ("net_frames_out", Value::Int(counters.frames_out.load(Ordering::Relaxed) as i64)),
        ("net_parse_errors", Value::Int(counters.parse_errors.load(Ordering::Relaxed) as i64)),
        ("net_idle_kills", Value::Int(counters.idle_kills.load(Ordering::Relaxed) as i64)),
        (
            "net_faults_injected",
            Value::Int(counters.faults_injected.load(Ordering::Relaxed) as i64),
        ),
    ])
}

fn spawn_conn(
    svc: Arc<Service>,
    stream: TcpStream,
    shard: usize,
    cfg: NetCfg,
    counters: Arc<NetCounters>,
    shutdown_requested: Arc<AtomicBool>,
) -> std::io::Result<Conn> {
    // accepted sockets may inherit the listener's nonblocking flag on some
    // platforms; the per-connection threads want plain blocking reads
    stream.set_nonblocking(false)?;
    let _ = stream.set_nodelay(true);
    let mut rstream = stream.try_clone()?;
    // slow-loris guard: a reader blocked on a silent socket wakes after
    // read_idle and tears the connection down instead of pinning a thread
    rstream.set_read_timeout(cfg.read_idle)?;
    let writer = Arc::new(Mutex::new(BufWriter::new(stream.try_clone()?)));
    let (tx, rx): (SyncSender<Out>, Receiver<Out>) = sync_channel(cfg.in_flight.max(1));
    // NetCfg is not Copy (it carries the token); both per-connection
    // threads want pieces of it, so split the scalars out here
    let NetCfg { max_frame, levels, auth_token, faults, .. } = cfg;

    let reader = {
        let svc = Arc::clone(&svc);
        let writer = Arc::clone(&writer);
        let counters = Arc::clone(&counters);
        std::thread::spawn(move || {
            // no token configured = every connection starts authenticated
            let mut authed = auth_token.is_none();
            let mut frames_seen: usize = 0;
            loop {
                let payload = match read_frame(&mut rstream, max_frame) {
                    Ok(p) => p,
                    Err(FrameError::Oversized { len, max }) => {
                        counters.parse_errors.fetch_add(1, Ordering::Relaxed);
                        let resp = WireResponse::Error {
                            id: 0,
                            kind: ErrorKind::Parse,
                            msg: format!("frame of {len} bytes exceeds the {max}-byte cap"),
                        };
                        write_response(&writer, &counters, max_frame, &resp);
                        break;
                    }
                    // the read-idle budget expired: close the connection
                    // (WouldBlock on unix, TimedOut on windows)
                    Err(FrameError::Io(e))
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) =>
                    {
                        counters.idle_kills.fetch_add(1, Ordering::Relaxed);
                        let _ = rstream.shutdown(Shutdown::Both);
                        break;
                    }
                    // Closed (clean), Truncated, Io: teardown either way
                    Err(_) => break,
                };
                counters.frames_in.fetch_add(1, Ordering::Relaxed);
                frames_seen += 1;
                // injected mid-stream crash: the last frame read is never
                // answered and both socket halves go away under the client
                if faults.disconnect_after > 0 && frames_seen >= faults.disconnect_after {
                    counters.faults_injected.fetch_add(1, Ordering::Relaxed);
                    let _ = rstream.shutdown(Shutdown::Both);
                    break;
                }
                let text = String::from_utf8_lossy(&payload);
                let req = match WireRequest::decode(&text) {
                    Ok(req) => req,
                    Err(e) => {
                        counters.parse_errors.fetch_add(1, Ordering::Relaxed);
                        match peek_id(&text) {
                            // addressable: answer and keep the connection —
                            // the frame boundary is intact
                            Some(id) => {
                                let kind = if e.0.contains("unsupported op") {
                                    ErrorKind::Unsupported
                                } else {
                                    ErrorKind::Parse
                                };
                                let resp =
                                    WireResponse::Error { id, kind, msg: e.to_string() };
                                if !write_response(&writer, &counters, max_frame, &resp) {
                                    break;
                                }
                                continue;
                            }
                            // not even an id to echo: report and hang up
                            None => {
                                let resp = WireResponse::Error {
                                    id: 0,
                                    kind: ErrorKind::Parse,
                                    msg: e.to_string(),
                                };
                                write_response(&writer, &counters, max_frame, &resp);
                                break;
                            }
                        }
                    }
                };
                match req {
                    WireRequest::Hello { id, auth } => {
                        let granted = match &auth_token {
                            // no gate: hello is an acked no-op, so clients
                            // may lead with one unconditionally
                            None => true,
                            Some(tok) => auth.as_deref() == Some(tok.as_str()),
                        };
                        if !granted {
                            let resp = WireResponse::Error {
                                id,
                                kind: ErrorKind::Auth,
                                msg: "bad or missing auth token".to_string(),
                            };
                            write_response(&writer, &counters, max_frame, &resp);
                            break;
                        }
                        authed = true;
                        if !write_response(&writer, &counters, max_frame, &WireResponse::Ok { id })
                        {
                            break;
                        }
                    }
                    // the gate: a token is configured and this connection
                    // has not presented it — nothing but hello is served
                    other if !authed => {
                        let resp = WireResponse::Error {
                            id: other.id(),
                            kind: ErrorKind::Auth,
                            msg: "authentication required: send hello with the token first"
                                .to_string(),
                        };
                        write_response(&writer, &counters, max_frame, &resp);
                        break;
                    }
                    WireRequest::Infer { id, model, codes, deadline_us } => {
                        let mid = match resolve_model(&svc, id, model.as_deref()) {
                            Ok(m) => m,
                            Err(resp) => {
                                if !write_response(&writer, &counters, max_frame, &resp) {
                                    break;
                                }
                                continue;
                            }
                        };
                        match svc.submit_to_model_deadline(shard, mid, codes, deadline_us) {
                            Ok(rx) => {
                                let out = Out::Reply { id, rxs: vec![rx], batch: false };
                                if tx.send(out).is_err() {
                                    break;
                                }
                            }
                            // error frames bypass the completion queue:
                            // written here, immediately — backpressure must
                            // be visible while earlier responses pend
                            Err(e) => {
                                let resp = submit_error(id, e);
                                if !write_response(&writer, &counters, max_frame, &resp) {
                                    break;
                                }
                            }
                        }
                    }
                    WireRequest::InferBatch { id, model, batch, deadline_us } => {
                        let mid = match resolve_model(&svc, id, model.as_deref()) {
                            Ok(m) => m,
                            Err(resp) => {
                                if !write_response(&writer, &counters, max_frame, &resp) {
                                    break;
                                }
                                continue;
                            }
                        };
                        let mut rxs = Vec::with_capacity(batch.len());
                        let mut failed = None;
                        for row in batch {
                            match svc.submit_to_model_deadline(shard, mid, row, deadline_us) {
                                Ok(rx) => rxs.push(rx),
                                Err(e) => {
                                    failed = Some(e);
                                    break;
                                }
                            }
                        }
                        let out = match failed {
                            None => Out::Reply { id, rxs, batch: true },
                            Some(e) => {
                                // whole batch fails atomically from the
                                // client's view; admitted rows still run
                                // and their replies must be drained
                                if !write_response(
                                    &writer,
                                    &counters,
                                    max_frame,
                                    &submit_error(id, e),
                                ) {
                                    break;
                                }
                                Out::Discard(rxs)
                            }
                        };
                        if tx.send(out).is_err() {
                            break;
                        }
                    }
                    WireRequest::Stats { id } => {
                        let resp = WireResponse::Stats {
                            id,
                            stats: stats_value(&svc, &counters, levels),
                        };
                        if !write_response(&writer, &counters, max_frame, &resp) {
                            break;
                        }
                    }
                    WireRequest::Swap { id, model, layer, q, p, table } => {
                        // swaps route by tenant too: the named (or default)
                        // tenant's own netlist cell takes the new table
                        let target = match model.as_deref() {
                            None => svc.registry().resolve(ModelId::DEFAULT),
                            Some(name) => svc.registry().resolve_name(name),
                        };
                        let resp = match target {
                            Some(t) => match t.cell().swap_edge(layer, q, p, table) {
                                Ok(()) => WireResponse::Ok { id },
                                Err(e) => WireResponse::Error {
                                    id,
                                    kind: ErrorKind::Invalid,
                                    msg: e.to_string(),
                                },
                            },
                            None => WireResponse::Error {
                                id,
                                kind: ErrorKind::Unsupported,
                                msg: format!(
                                    "unknown model: {}",
                                    model.as_deref().unwrap_or("<default>")
                                ),
                            },
                        };
                        if !write_response(&writer, &counters, max_frame, &resp) {
                            break;
                        }
                    }
                    WireRequest::Shutdown { id } => {
                        shutdown_requested.store(true, Ordering::Release);
                        if !write_response(
                            &writer,
                            &counters,
                            max_frame,
                            &WireResponse::Ok { id },
                        ) {
                            break;
                        }
                    }
                }
            }
            // dropping tx lets the completion thread drain and FIN
        })
    };

    let completion = {
        let counters = Arc::clone(&counters);
        std::thread::spawn(move || {
            let mut alive = true;
            let mut replies_out: usize = 0;
            for out in rx {
                match out {
                    Out::Reply { id, rxs, batch } => {
                        let resp = if batch {
                            let mut rows = Vec::with_capacity(rxs.len());
                            // the first failure's kind speaks for the whole
                            // frame; the remaining rows are still drained so
                            // no executor blocks on an unread reply
                            let mut failure: Option<WireResponse> = None;
                            for r in rxs {
                                match r.recv() {
                                    Ok(Ok(resp)) => rows.push(resp.sums),
                                    Ok(Err(e)) => {
                                        if failure.is_none() {
                                            failure = Some(submit_error(id, e));
                                        }
                                    }
                                    Err(_) => {
                                        if failure.is_none() {
                                            failure = Some(dropped_error(id));
                                        }
                                    }
                                }
                            }
                            failure.unwrap_or(WireResponse::Batch { id, batch: rows })
                        } else {
                            let r = rxs.into_iter().next().expect("non-batch reply has one rx");
                            match r.recv() {
                                Ok(Ok(resp)) => WireResponse::Sums {
                                    id,
                                    sums: resp.sums,
                                    latency_us: resp.latency.as_secs_f64() * 1e6,
                                },
                                Ok(Err(e)) => submit_error(id, e),
                                Err(_) => dropped_error(id),
                            }
                        };
                        replies_out += 1;
                        // injected stall: hold the finished frame, then
                        // deliver it late (the connection survives)
                        if faults.stall_every > 0 && replies_out % faults.stall_every == 0 {
                            counters.faults_injected.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(faults.stall);
                        }
                        // a dead socket stops writes, not draining: every
                        // queued reply is still received so executors'
                        // results are consumed and the thread terminates
                        if alive {
                            if faults.torn_every > 0 && replies_out % faults.torn_every == 0 {
                                // injected torn frame: sever mid-payload
                                inject_torn_frame(&writer, &counters, &resp);
                                alive = false;
                            } else {
                                alive = write_response(&writer, &counters, max_frame, &resp);
                            }
                        }
                    }
                    Out::Discard(rxs) => {
                        for r in rxs {
                            let _ = r.recv();
                        }
                    }
                }
            }
            // reader gone, queue drained: flush and half-close (FIN) so the
            // client sees EOF after the last in-flight response
            if alive {
                let mut w = writer.lock().unwrap();
                let _ = w.flush();
                let _ = w.get_ref().shutdown(Shutdown::Write);
            }
        })
    };

    Ok(Conn { stream, reader, completion })
}
