//! Blocking wire client + closed-loop load generator.
//!
//! [`Client`] is the minimal correct counterpart to the server: one
//! blocking socket, `send`/`recv_response` split for pipelining, and
//! typed conveniences (`infer`, `infer_batch`, `stats`, ...) that map
//! error frames onto [`NetError::Remote`]. [`loadgen`] drives N such
//! clients from N threads — closed loop with optional rate pacing and a
//! heavy-tail knob (every k-th request is a batch) — and reports
//! p50/p90/p99 wire latency from a [`Reservoir`], the same estimator the
//! serving plane uses internally.
//!
//! The load generator is resilient by design: transport faults (torn
//! frames, resets, mid-stream disconnects) trigger a reconnect with
//! capped exponential backoff plus jitter, the interrupted request is
//! retried on the fresh connection, and the report separates `completed`
//! work from `reconnects`, `failed_retries` (server-side batch panics
//! absorbed by retrying) and `expired` (deadline shed — not retried, the
//! deadline already passed).

use std::collections::HashMap;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::json::Value;
use crate::util::stats::Reservoir;
use crate::util::Rng;

use super::frame::{read_frame, write_frame, FrameError, MAX_FRAME};
use super::proto::{ErrorKind, ProtoError, WireRequest, WireResponse};

/// Client-side failure. `Remote` is the server saying no (typed error
/// frame); the rest are transport or codec faults.
#[derive(Debug)]
pub enum NetError {
    /// The server answered with an error frame.
    Remote { kind: ErrorKind, msg: String },
    Frame(FrameError),
    Proto(ProtoError),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Remote { kind, msg } => write!(f, "server error [{kind}]: {msg}"),
            NetError::Frame(e) => write!(f, "{e}"),
            NetError::Proto(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for NetError {}

impl From<FrameError> for NetError {
    fn from(e: FrameError) -> Self {
        NetError::Frame(e)
    }
}

impl From<ProtoError> for NetError {
    fn from(e: ProtoError) -> Self {
        NetError::Proto(e)
    }
}

/// Blocking connection to a `kanele serve` front end.
pub struct Client {
    pub(crate) stream: TcpStream,
    max_frame: usize,
    next_id: u64,
    deadline_us: Option<u64>,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream, max_frame: MAX_FRAME, next_id: 1, deadline_us: None })
    }

    /// Bound how long `recv_response` may block — tests use this so a
    /// protocol bug shows as a failed assertion, not a hung run.
    pub fn set_read_timeout(&mut self, t: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(t)
    }

    /// Sticky per-request deadline: every subsequent `infer` /
    /// `infer_batch` frame carries this `deadline_us` budget (relative,
    /// microseconds). The server sheds requests still unbatched past the
    /// budget with a typed `expired` error. `None` (the default) emits no
    /// field — byte-identical to the pre-deadline protocol.
    pub fn set_deadline(&mut self, deadline_us: Option<u64>) {
        self.deadline_us = deadline_us;
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Write one request frame. Pairs with [`Client::recv_response`] for
    /// pipelined use; the conveniences below are strict request/response.
    pub fn send(&mut self, req: &WireRequest) -> Result<(), NetError> {
        write_frame(&mut self.stream, req.encode().as_bytes(), self.max_frame)?;
        Ok(())
    }

    /// Read one response frame. Error frames come back as
    /// `Ok(WireResponse::Error { .. })` — pipelining callers match on id
    /// and decide; the conveniences turn them into [`NetError::Remote`].
    pub fn recv_response(&mut self) -> Result<WireResponse, NetError> {
        let payload = read_frame(&mut self.stream, self.max_frame)?;
        Ok(WireResponse::decode(&String::from_utf8_lossy(&payload))?)
    }

    fn call(&mut self, req: WireRequest) -> Result<WireResponse, NetError> {
        let want = req.id();
        self.send(&req)?;
        let resp = self.recv_response()?;
        if resp.id() != want {
            return Err(NetError::Proto(ProtoError(format!(
                "response id {} does not match request id {want}",
                resp.id()
            ))));
        }
        if let WireResponse::Error { kind, msg, .. } = resp {
            return Err(NetError::Remote { kind, msg });
        }
        Ok(resp)
    }

    /// Present the shared-secret token (or just say hello to a server that
    /// requires none). Against a token-gated server this must be the first
    /// call on the connection; a wrong token comes back as
    /// [`NetError::Remote`] with [`ErrorKind::Auth`] and the server closes
    /// the socket.
    pub fn hello(&mut self, auth: Option<&str>) -> Result<(), NetError> {
        let id = self.fresh_id();
        self.call(WireRequest::Hello { id, auth: auth.map(str::to_string) })?;
        Ok(())
    }

    /// One sample; returns the output sums and the server-side latency in
    /// microseconds (queue + batch + execute, as the serving plane saw it).
    pub fn infer(&mut self, codes: Vec<u32>) -> Result<(Vec<i64>, f64), NetError> {
        self.infer_model(None, codes)
    }

    /// [`Client::infer`] routed to a named tenant (`None` = the server's
    /// default model — byte-identical to the pre-registry frame).
    pub fn infer_model(
        &mut self,
        model: Option<&str>,
        codes: Vec<u32>,
    ) -> Result<(Vec<i64>, f64), NetError> {
        let id = self.fresh_id();
        let model = model.map(str::to_string);
        let deadline_us = self.deadline_us;
        match self.call(WireRequest::Infer { id, model, codes, deadline_us })? {
            WireResponse::Sums { sums, latency_us, .. } => Ok((sums, latency_us)),
            other => Err(NetError::Proto(ProtoError(format!("expected sums, got {other:?}")))),
        }
    }

    /// Several samples in one frame; rows come back in request order.
    pub fn infer_batch(&mut self, batch: Vec<Vec<u32>>) -> Result<Vec<Vec<i64>>, NetError> {
        self.infer_batch_model(None, batch)
    }

    /// [`Client::infer_batch`] routed to a named tenant.
    pub fn infer_batch_model(
        &mut self,
        model: Option<&str>,
        batch: Vec<Vec<u32>>,
    ) -> Result<Vec<Vec<i64>>, NetError> {
        let id = self.fresh_id();
        let model = model.map(str::to_string);
        let deadline_us = self.deadline_us;
        match self.call(WireRequest::InferBatch { id, model, batch, deadline_us })? {
            WireResponse::Batch { batch, .. } => Ok(batch),
            other => Err(NetError::Proto(ProtoError(format!("expected batch, got {other:?}")))),
        }
    }

    /// Serving-plane + wire stats snapshot as a JSON object.
    pub fn stats(&mut self) -> Result<Value, NetError> {
        let id = self.fresh_id();
        match self.call(WireRequest::Stats { id })? {
            WireResponse::Stats { stats, .. } => Ok(stats),
            other => Err(NetError::Proto(ProtoError(format!("expected stats, got {other:?}")))),
        }
    }

    /// Hot-swap one edge's truth table on the serving model.
    pub fn swap(&mut self, layer: usize, q: usize, p: usize, table: Vec<i64>) -> Result<(), NetError> {
        self.swap_model(None, layer, q, p, table)
    }

    /// [`Client::swap`] routed to a named tenant.
    pub fn swap_model(
        &mut self,
        model: Option<&str>,
        layer: usize,
        q: usize,
        p: usize,
        table: Vec<i64>,
    ) -> Result<(), NetError> {
        let id = self.fresh_id();
        let model = model.map(str::to_string);
        self.call(WireRequest::Swap { id, model, layer, q, p, table })?;
        Ok(())
    }

    /// Ask the server process to begin shutting down (acked before the
    /// server drains).
    pub fn shutdown_server(&mut self) -> Result<(), NetError> {
        let id = self.fresh_id();
        self.call(WireRequest::Shutdown { id })?;
        Ok(())
    }
}

/// Load-generator shape: `connections` closed loops, `requests` total.
#[derive(Clone, Debug)]
pub struct LoadGenCfg {
    pub connections: usize,
    /// Total single-sample requests across all connections (split evenly;
    /// the remainder goes to the first connections).
    pub requests: u64,
    /// Per-connection target rate in requests/s; `0.0` = as fast as the
    /// closed loop allows.
    pub rate_rps: f64,
    /// Every `tail_every`-th request becomes an `infer_batch` of
    /// `tail_batch` rows — the wire version of the benches' heavy-tail
    /// workload. `0` disables batches.
    pub tail_every: u64,
    pub tail_batch: usize,
    pub seed: u64,
    /// Weighted tenant mix: each request picks a model name with
    /// probability proportional to its weight. Empty = model-less frames
    /// (the server's default tenant), byte-identical to the pre-registry
    /// wire traffic. Per-model input widths are learned from the `models`
    /// array in the server's stats frame.
    pub model_mix: Vec<(String, u64)>,
    /// Shared-secret token sent in a `hello` frame before any other op.
    /// `None` sends no hello at all.
    pub auth: Option<String>,
    /// Relative deadline carried on every inference frame, microseconds;
    /// `0` sends no deadline at all (the pre-deadline wire encoding).
    pub deadline_us: u64,
}

impl Default for LoadGenCfg {
    fn default() -> Self {
        LoadGenCfg {
            connections: 4,
            requests: 10_000,
            rate_rps: 0.0,
            tail_every: 0,
            tail_batch: 32,
            seed: 7,
            model_mix: Vec::new(),
            auth: None,
            deadline_us: 0,
        }
    }
}

/// What [`loadgen`] measured. Latencies are wall-clock round trips seen by
/// the client (includes the wire), in microseconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadGenReport {
    /// Samples with successful responses (batch rows count individually).
    pub completed: u64,
    /// Backpressure error frames absorbed by retrying.
    pub backpressure_retries: u64,
    /// `dropped` error frames (request lost to a swap/shutdown race).
    pub dropped: u64,
    /// Connections that ended early on a terminal error.
    pub errors: u64,
    /// `expired` error frames: the request's deadline passed before its
    /// batch formed. Not retried — the budget is already blown.
    pub expired: u64,
    /// `failed` / `quarantined` error frames absorbed by retrying (the
    /// server's executor panicked under that request, or its tenant was
    /// briefly quarantined).
    pub failed_retries: u64,
    /// Successful reconnects after a transport fault; the interrupted
    /// request was retried on the fresh connection.
    pub reconnects: u64,
    pub wall_s: f64,
    /// Completed samples per second over the whole run.
    pub rps: f64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
}

/// Reconnect policy after a transport fault: up to this many attempts
/// with exponential backoff (base below, doubling, capped at 32x) plus
/// uniform jitter so a fleet of clients does not reconnect in lockstep.
const RECONNECT_ATTEMPTS: usize = 6;
const RECONNECT_BASE_MS: u64 = 10;

/// Per-tenant input widths from the stats frame's `models` array. Retired
/// tenants advertise width 0 and are skipped; servers predating the
/// registry have no `models` array and yield an empty map (callers fall
/// back to the top-level `input_width`).
fn tenant_widths(stats: &Value) -> HashMap<String, usize> {
    let mut out = HashMap::new();
    for m in stats.get("models").and_then(Value::as_array).unwrap_or(&[]) {
        let w = m.get("input_width").and_then(Value::as_i64).unwrap_or(0);
        if let Some(name) = m.get("name").and_then(Value::as_str) {
            if w > 0 {
                out.insert(name.to_string(), w as usize);
            }
        }
    }
    out
}

/// Run a closed-loop load test against a running server. Each connection
/// first sends `hello` if an auth token is configured, then issues a
/// `stats` op to learn input width and level count (per tenant, via the
/// `models` array, when a model mix is set), so the generator needs no
/// local checkpoint. Backpressure frames are retried (and counted);
/// terminal errors end that connection.
pub fn loadgen(addr: &str, cfg: LoadGenCfg) -> anyhow::Result<LoadGenReport> {
    let conns = cfg.connections.max(1);
    let completed = Arc::new(AtomicU64::new(0));
    let backpressure = Arc::new(AtomicU64::new(0));
    let dropped = Arc::new(AtomicU64::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    let expired = Arc::new(AtomicU64::new(0));
    let failed_retries = Arc::new(AtomicU64::new(0));
    let reconnects = Arc::new(AtomicU64::new(0));
    let lat = Arc::new(Mutex::new(Reservoir::new(4096)));

    let start = Instant::now();
    let mut handles = Vec::with_capacity(conns);
    for c in 0..conns {
        let quota = cfg.requests / conns as u64 + u64::from((c as u64) < cfg.requests % conns as u64);
        let addr = addr.to_string();
        let cfg = cfg.clone();
        let completed = Arc::clone(&completed);
        let backpressure = Arc::clone(&backpressure);
        let dropped = Arc::clone(&dropped);
        let errors = Arc::clone(&errors);
        let expired = Arc::clone(&expired);
        let failed_retries = Arc::clone(&failed_retries);
        let reconnects = Arc::clone(&reconnects);
        let lat = Arc::clone(&lat);
        handles.push(std::thread::spawn(move || {
            let deadline = if cfg.deadline_us > 0 { Some(cfg.deadline_us) } else { None };
            let mut client = match Client::connect(&addr) {
                Ok(c) => c,
                Err(_) => {
                    errors.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            };
            client.set_deadline(deadline);
            if let Some(token) = cfg.auth.as_deref() {
                if client.hello(Some(token)).is_err() {
                    errors.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            }
            // learn the request shape from the server
            let (width, levels, tenant_widths) = match client.stats() {
                Ok(s) => {
                    let w = s.get("input_width").and_then(Value::as_i64).unwrap_or(0).max(0);
                    let l = s.get("levels").and_then(Value::as_i64).unwrap_or(0).max(0);
                    (w as usize, if l > 0 { l as u64 } else { 64 }, tenant_widths(&s))
                }
                Err(_) => {
                    errors.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            };
            let total_weight: u64 = cfg.model_mix.iter().map(|(_, w)| *w).sum();
            let mut rng = Rng::new(cfg.seed ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(c as u64 + 1)));
            let row = |rng: &mut Rng, width: usize| -> Vec<u32> {
                (0..width).map(|_| rng.below(levels) as u32).collect()
            };
            let t0 = Instant::now();
            for k in 0..quota {
                if cfg.rate_rps > 0.0 {
                    // open-loop pacing against the schedule, closed-loop
                    // execution: late requests fire immediately
                    let due = Duration::from_secs_f64(k as f64 / cfg.rate_rps);
                    let elapsed = t0.elapsed();
                    if due > elapsed {
                        std::thread::sleep(due - elapsed);
                    }
                }
                // weighted tenant pick, fixed before the retry loop so a
                // backpressured request lands on the same model
                let model: Option<&str> = if total_weight > 0 {
                    let mut pick = rng.below(total_weight);
                    let mut chosen = None;
                    for (name, weight) in &cfg.model_mix {
                        if pick < *weight {
                            chosen = Some(name.as_str());
                            break;
                        }
                        pick -= *weight;
                    }
                    chosen
                } else {
                    None
                };
                let w = model.and_then(|m| tenant_widths.get(m)).copied().unwrap_or(width);
                let is_tail = cfg.tail_every > 0 && (k + 1) % cfg.tail_every == 0;
                loop {
                    let req_start = Instant::now();
                    let outcome = if is_tail {
                        let batch: Vec<Vec<u32>> =
                            (0..cfg.tail_batch.max(1)).map(|_| row(&mut rng, w)).collect();
                        client.infer_batch_model(model, batch).map(|rows| rows.len() as u64)
                    } else {
                        client.infer_model(model, row(&mut rng, w)).map(|_| 1u64)
                    };
                    match outcome {
                        Ok(n) => {
                            lat.lock()
                                .unwrap()
                                .push(req_start.elapsed().as_secs_f64() * 1e6);
                            completed.fetch_add(n, Ordering::Relaxed);
                            break;
                        }
                        Err(NetError::Remote { kind: ErrorKind::Backpressure, .. }) => {
                            backpressure.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        Err(NetError::Remote { kind: ErrorKind::Dropped, .. }) => {
                            dropped.fetch_add(1, Ordering::Relaxed);
                            break;
                        }
                        // the deadline already passed server-side; retrying
                        // a blown budget only wastes capacity
                        Err(NetError::Remote { kind: ErrorKind::Expired, .. }) => {
                            expired.fetch_add(1, Ordering::Relaxed);
                            break;
                        }
                        // the batch panicked under this request: safe and
                        // worthwhile to retry on the same connection
                        Err(NetError::Remote { kind: ErrorKind::Failed, .. }) => {
                            failed_retries.fetch_add(1, Ordering::Relaxed);
                        }
                        // quarantined tenants half-open after a window;
                        // retry gently rather than hammering the breaker
                        Err(NetError::Remote { kind: ErrorKind::Quarantined, .. }) => {
                            failed_retries.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        // transport fault (torn frame / reset / mid-stream
                        // disconnect): reconnect with capped exponential
                        // backoff + jitter, re-hello, retry this request
                        Err(NetError::Frame(_)) => {
                            let mut fresh = None;
                            for attempt in 0..RECONNECT_ATTEMPTS {
                                let base_ms = RECONNECT_BASE_MS << attempt.min(5);
                                let jitter_ms = rng.below(base_ms / 2 + 1);
                                std::thread::sleep(Duration::from_millis(base_ms + jitter_ms));
                                if let Ok(mut c) = Client::connect(&addr) {
                                    let authed = match cfg.auth.as_deref() {
                                        None => true,
                                        Some(tok) => c.hello(Some(tok)).is_ok(),
                                    };
                                    if authed {
                                        c.set_deadline(deadline);
                                        fresh = Some(c);
                                        break;
                                    }
                                }
                            }
                            match fresh {
                                Some(c) => {
                                    client = c;
                                    reconnects.fetch_add(1, Ordering::Relaxed);
                                }
                                None => {
                                    errors.fetch_add(1, Ordering::Relaxed);
                                    return;
                                }
                            }
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                            return;
                        }
                    }
                }
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    let wall_s = start.elapsed().as_secs_f64();

    let lat = lat.lock().unwrap();
    let [p50, p90, p99] = lat.p50_p90_p99();
    let nz = |x: f64| if x.is_finite() { x } else { 0.0 };
    let completed = completed.load(Ordering::Relaxed);
    Ok(LoadGenReport {
        completed,
        backpressure_retries: backpressure.load(Ordering::Relaxed),
        dropped: dropped.load(Ordering::Relaxed),
        errors: errors.load(Ordering::Relaxed),
        expired: expired.load(Ordering::Relaxed),
        failed_retries: failed_retries.load(Ordering::Relaxed),
        reconnects: reconnects.load(Ordering::Relaxed),
        wall_s,
        rps: if wall_s > 0.0 { completed as f64 / wall_s } else { 0.0 },
        mean_us: nz(lat.mean()),
        p50_us: nz(p50),
        p90_us: nz(p90),
        p99_us: nz(p99),
    })
}
