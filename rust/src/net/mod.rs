//! Network front end: framed TCP serving for the KANELÉ plane.
//!
//! Everything in-repo so far drove the serving plane in-process; this
//! module puts it on a socket. It is dependency-free (std networking, the
//! in-repo [`crate::json`] codec) and deliberately small: framing, a typed
//! message model, a server that maps connections onto the sharded
//! [`crate::coordinator::Service`], and a client + load generator.
//!
//! # Frame protocol
//!
//! Byte layout, both directions:
//!
//! ```text
//! +----------------+---------------------------+
//! | len: u32 (BE)  | payload: `len` bytes JSON |
//! +----------------+---------------------------+
//! ```
//!
//! One JSON object per frame, capped at [`frame::MAX_FRAME`] bytes.
//! Requests carry `"op"` and a client-chosen `"id"`; responses echo the
//! id, so clients may pipeline and match out of band:
//!
//! | op            | request fields                              | success response            |
//! |---------------|----------------------------------------------|-----------------------------|
//! | `hello`       | `auth?: str`                                 | bare ack                    |
//! | `infer`       | `codes: [u32], model?: str, deadline_us?`    | `sums: [i64], latency_us`   |
//! | `infer_batch` | `batch: [[u32]], model?: str, deadline_us?`  | `batch: [[i64]]`            |
//! | `stats`       | —                                            | `stats: {..}` (+ `models`)  |
//! | `swap`        | `layer, q, p, table: [i64], model?`          | bare ack                    |
//! | `shutdown`    | —                                            | bare ack                    |
//!
//! Fields marked `?` are optional and omitted when absent, so a frame
//! without them is byte-identical to the pre-registry protocol: old
//! clients keep working and land on the default tenant.
//!
//! Failures are `{"id":N,"ok":false,"error":"<kind>","msg":"..."}` with
//! kind one of `backpressure` / `stopped` / `invalid` / `failed` /
//! `expired` / `quarantined` (the serving plane's
//! [`crate::coordinator::SubmitError`] verbatim) or `parse` / `dropped` /
//! `unsupported` / `auth` (wire-layer; an unknown `model` name is
//! `unsupported`). Error frames are written from the reader thread, ahead
//! of pending completions — an overloaded server answers `backpressure`
//! immediately; it never leaves a client hanging.
//!
//! # What happens when things break
//!
//! Every failure mode has a typed outcome and a recovery path; none of
//! them hangs a client or wedges a server thread:
//!
//! | failure                        | client sees                   | recovery                                    |
//! |--------------------------------|-------------------------------|---------------------------------------------|
//! | admission queue full           | `backpressure` frame          | retry with backoff (loadgen does)           |
//! | executor panic under the batch | `failed` frame                | retry; request never half-executes          |
//! | deadline passed before batch   | `expired` frame               | don't retry — the budget is blown           |
//! | tenant breaker open            | `quarantined` frame           | other tenants unaffected; retry after window|
//! | swap/shutdown race             | `dropped` frame               | retry if idempotent                         |
//! | server dies mid-send           | `Truncated` read              | reconnect + retry ([`client::loadgen`] does)|
//! | client goes silent             | — (connection closed)         | server `read_idle` guard frees the thread   |
//! | oversized / malformed frame    | `parse` frame, then close     | fix the client                              |
//!
//! The serving-plane rows are exercised deterministically by the chaos
//! harness (`benches/chaos.rs`) via [`crate::coordinator::FaultPlan`] and
//! [`server::WireFaults`] — seeded fault schedules, not OS packet games.
//!
//! # Wire topology (multi-tenant)
//!
//! ```text
//!  client conns          NetServer                    Service + ModelRegistry
//!  ───────────           ─────────                    ───────────────────────
//!  conn 0 ──TCP──▶ reader ─submit_to(0, model)─▶ [shard 0 queue]─▶ DRR ─┐
//!         ◀─TCP── writer ◀── completion ◀─ reply rxs        dispatcher  │ work
//!  conn 1 ──TCP──▶ reader ─submit_to(1, model)─▶ [shard 1 queue]─▶ DRR ─┤ pool
//!         ◀─TCP── writer ◀── completion ◀─ reply rxs        dispatcher  │ (steal)
//!  conn k ──TCP──▶ reader ─submit_to(k%S, ...)─▶ [shard k%S ...]        ┘
//!                   │                                   │
//!                   └─ name → ModelId (registry) ───────┴─▶ tenant cells
//!                                                           (shared arena)
//! ```
//!
//! The reader resolves the optional `model` name to a [`ModelId`] once per
//! frame; admission, deficit-round-robin batch formation, and execution
//! all run on ids. Requests from different tenants share shards and the
//! work pool but never share a batch.
//!
//! Each connection pins to one admission shard (connection = client, same
//! affinity the in-process plane assumes), runs a reader thread (frames →
//! decode → submit) and a completion thread (reply channels → frames), and
//! bounds its in-flight window with a `sync_channel` between them.
//! Teardown order is always: reader EOF → completion drains what was
//! admitted → flush → FIN. [`NetServer::shutdown`] forces exactly that
//! path on every connection by closing read halves, so in-flight responses
//! are flushed, never abandoned.
//!
//! [`ModelId`]: crate::coordinator::ModelId
//!
//! Entry points: `kanele serve --listen <addr>` wraps [`NetServer`];
//! `kanele loadgen <addr>` wraps [`client::loadgen`].

pub mod client;
pub mod frame;
pub mod proto;
pub mod server;

pub use client::{loadgen, Client, LoadGenCfg, LoadGenReport, NetError};
pub use frame::{FrameError, MAX_FRAME};
pub use proto::{ErrorKind, ProtoError, WireRequest, WireResponse};
pub use server::{NetCfg, NetServer, NetStats, WireFaults};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil;
    use crate::coordinator::{Service, ServiceCfg};
    use crate::lut;
    use crate::netlist::Netlist;
    use std::net::TcpListener;
    use std::sync::Arc;
    use std::time::Duration;

    fn loopback(workers: usize) -> (Arc<Service>, NetServer) {
        let ck = testutil::synthetic(&[6, 4, 3], &[4, 4, 4], 99);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Arc::new(Service::start(
            net,
            ServiceCfg { workers, shards: 2, ..ServiceCfg::default() },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server =
            NetServer::start(Arc::clone(&svc), listener, NetCfg { levels: 16, ..NetCfg::default() })
                .unwrap();
        (svc, server)
    }

    #[test]
    fn loopback_infer_roundtrip() {
        let (svc, mut server) = loopback(2);
        let mut client = Client::connect(server.local_addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

        let codes = vec![1u32, 2, 3, 4, 5, 6];
        let (wire_sums, latency_us) = client.infer(codes.clone()).unwrap();
        let direct = svc.submit_blocking(codes).unwrap();
        assert_eq!(wire_sums, direct.sums);
        assert!(latency_us >= 0.0);

        // stats advertises the request shape loadgen relies on
        let stats = client.stats().unwrap();
        assert_eq!(stats.get("input_width").and_then(|v| v.as_i64()), Some(6));
        assert_eq!(stats.get("levels").and_then(|v| v.as_i64()), Some(16));

        drop(client);
        server.shutdown();
        let net_stats = server.stats();
        assert_eq!(net_stats.accepted, 1);
        assert!(net_stats.wire_completed >= 2);
        svc.shutdown();
    }

    #[test]
    fn loopback_wrong_width_is_invalid_frame_and_connection_survives() {
        let (svc, mut server) = loopback(2);
        let mut client = Client::connect(server.local_addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

        match client.infer(vec![1, 2, 3]) {
            Err(NetError::Remote { kind: ErrorKind::Invalid, .. }) => {}
            other => panic!("expected Invalid error frame, got {other:?}"),
        }
        // same connection still serves well-formed requests
        let (sums, _) = client.infer(vec![0; 6]).unwrap();
        assert_eq!(sums.len(), 3);

        server.shutdown();
        svc.shutdown();
    }

    #[test]
    fn loopback_malformed_json_is_parse_frame() {
        let (svc, mut server) = loopback(2);
        let mut client = Client::connect(server.local_addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

        // hand-rolled garbage frame: valid framing, invalid payload
        let req = WireRequest::Stats { id: 1 };
        let garbage = "{not json";
        {
            use std::io::Write as _;
            let mut raw = client_stream(&client);
            raw.write_all(&(garbage.len() as u32).to_be_bytes()).unwrap();
            raw.write_all(garbage.as_bytes()).unwrap();
        }
        match client.recv_response().unwrap() {
            WireResponse::Error { kind: ErrorKind::Parse, .. } => {}
            other => panic!("expected Parse error frame, got {other:?}"),
        }
        // unaddressable payload closes the connection
        assert!(client.send(&req).is_err() || client.recv_response().is_err());

        server.shutdown();
        svc.shutdown();
    }

    /// Tests poke raw bytes through the client's socket.
    fn client_stream(c: &Client) -> &std::net::TcpStream {
        &c.stream
    }

    #[test]
    fn error_kind_wire_strings_are_stable_across_protocol_growth() {
        // clients from earlier protocol revisions hard-code these strings;
        // growing the set must never rename an existing kind, and every
        // kind (old and new) must survive an encode/decode roundtrip
        let fixed = [
            (ErrorKind::Backpressure, "backpressure"),
            (ErrorKind::Stopped, "stopped"),
            (ErrorKind::Invalid, "invalid"),
            (ErrorKind::Parse, "parse"),
            (ErrorKind::Dropped, "dropped"),
            (ErrorKind::Unsupported, "unsupported"),
            (ErrorKind::Auth, "auth"),
            (ErrorKind::Failed, "failed"),
            (ErrorKind::Expired, "expired"),
            (ErrorKind::Quarantined, "quarantined"),
        ];
        for (kind, s) in fixed {
            assert_eq!(kind.as_str(), s);
            assert_eq!(ErrorKind::parse(s), Some(kind));
        }
        // a pre-fault-tolerance capture decodes unchanged...
        let old = "{\"id\":4,\"ok\":false,\"error\":\"backpressure\",\"msg\":\"queue full\"}";
        match WireResponse::decode(old).unwrap() {
            WireResponse::Error { id: 4, kind: ErrorKind::Backpressure, .. } => {}
            other => panic!("old capture misdecoded: {other:?}"),
        }
        // ...and the grown kinds come back typed, not as protocol errors
        for s in ["failed", "expired", "quarantined"] {
            let frame = format!("{{\"id\":9,\"ok\":false,\"error\":\"{s}\",\"msg\":\"m\"}}");
            match WireResponse::decode(&frame).unwrap() {
                WireResponse::Error { id: 9, kind, .. } => {
                    assert_eq!(kind.as_str(), s);
                }
                other => panic!("expected error frame for {s}, got {other:?}"),
            }
        }
    }

    #[test]
    fn loopback_deadline_expiry_is_typed_and_generous_deadline_completes() {
        let ck = testutil::synthetic(&[6, 4, 3], &[4, 4, 4], 99);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        // one worker, wide batches, 50 ms formation wait: a microsecond
        // deadline is deterministically stale by the time the batch forms
        let svc = Arc::new(Service::start(
            net,
            ServiceCfg {
                workers: 1,
                shards: 1,
                max_batch: 64,
                max_wait: Duration::from_millis(50),
                ..ServiceCfg::default()
            },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut server =
            NetServer::start(Arc::clone(&svc), listener, NetCfg { levels: 16, ..NetCfg::default() })
                .unwrap();
        let mut client = Client::connect(server.local_addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

        client.set_deadline(Some(1));
        match client.infer(vec![1, 2, 3, 4, 5, 6]) {
            Err(NetError::Remote { kind: ErrorKind::Expired, .. }) => {}
            other => panic!("expected Expired error frame, got {other:?}"),
        }
        // the connection survives, and a generous budget completes
        client.set_deadline(Some(5_000_000));
        let (sums, _) = client.infer(vec![1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(sums.len(), 3);
        let direct = svc.submit_blocking(vec![1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(sums, direct.sums);

        server.shutdown();
        let st = svc.stats();
        assert_eq!(st.shed_expired, 1, "exactly the stale request was shed");
        assert_eq!(st.completed, 2, "wire + direct requests completed");
        svc.shutdown();
    }

    #[test]
    fn loadgen_reconnects_through_injected_torn_frames() {
        let ck = testutil::synthetic(&[6, 4, 3], &[4, 4, 4], 99);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Arc::new(Service::start(
            net,
            ServiceCfg { workers: 2, shards: 2, ..ServiceCfg::default() },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        // tear every 3rd inference reply mid-payload: the client must
        // observe Truncated, reconnect, and retry to finish its quota
        let cfg = NetCfg {
            levels: 16,
            faults: WireFaults { torn_every: 3, ..WireFaults::default() },
            ..NetCfg::default()
        };
        let mut server = NetServer::start(Arc::clone(&svc), listener, cfg).unwrap();

        let report = loadgen(
            &server.local_addr().to_string(),
            LoadGenCfg { connections: 1, requests: 10, ..LoadGenCfg::default() },
        )
        .unwrap();
        assert_eq!(report.errors, 0, "torn frames must be absorbed, not terminal");
        assert_eq!(report.completed, 10, "every request completes after retries");
        assert!(report.reconnects >= 1, "at least one torn frame forced a reconnect");
        assert!(server.stats().faults_injected >= 1);

        server.shutdown();
        svc.shutdown();
    }

    #[test]
    fn idle_connection_is_killed_by_the_slow_loris_guard() {
        let (svc, mut server) = loopback(1);
        // rebind with a tight idle budget: loopback() uses the default
        // 60 s guard, far too slow for a test
        server.shutdown();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let cfg =
            NetCfg { levels: 16, read_idle: Some(Duration::from_millis(50)), ..NetCfg::default() };
        let mut server = NetServer::start(Arc::clone(&svc), listener, cfg).unwrap();

        let mut client = Client::connect(server.local_addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // a healthy request first: the guard must not fire between frames
        // that arrive within budget
        let (sums, _) = client.infer(vec![0; 6]).unwrap();
        assert_eq!(sums.len(), 3);
        // now go silent and let the budget lapse
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while server.stats().idle_kills == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(server.stats().idle_kills, 1, "silent connection must be reaped");
        // the reaped socket is dead from the client's side too
        assert!(client.infer(vec![0; 6]).is_err());

        server.shutdown();
        svc.shutdown();
    }
}
