//! Network front end: framed TCP serving for the KANELÉ plane.
//!
//! Everything in-repo so far drove the serving plane in-process; this
//! module puts it on a socket. It is dependency-free (std networking, the
//! in-repo [`crate::json`] codec) and deliberately small: framing, a typed
//! message model, a server that maps connections onto the sharded
//! [`crate::coordinator::Service`], and a client + load generator.
//!
//! # Frame protocol
//!
//! Byte layout, both directions:
//!
//! ```text
//! +----------------+---------------------------+
//! | len: u32 (BE)  | payload: `len` bytes JSON |
//! +----------------+---------------------------+
//! ```
//!
//! One JSON object per frame, capped at [`frame::MAX_FRAME`] bytes.
//! Requests carry `"op"` and a client-chosen `"id"`; responses echo the
//! id, so clients may pipeline and match out of band:
//!
//! | op            | request fields                      | success response            |
//! |---------------|-------------------------------------|-----------------------------|
//! | `hello`       | `auth?: str`                        | bare ack                    |
//! | `infer`       | `codes: [u32], model?: str`         | `sums: [i64], latency_us`   |
//! | `infer_batch` | `batch: [[u32]], model?: str`       | `batch: [[i64]]`            |
//! | `stats`       | —                                   | `stats: {..}` (+ `models`)  |
//! | `swap`        | `layer, q, p, table: [i64], model?` | bare ack                    |
//! | `shutdown`    | —                                   | bare ack                    |
//!
//! Fields marked `?` are optional and omitted when absent, so a frame
//! without them is byte-identical to the pre-registry protocol: old
//! clients keep working and land on the default tenant.
//!
//! Failures are `{"id":N,"ok":false,"error":"<kind>","msg":"..."}` with
//! kind one of `backpressure` / `stopped` / `invalid` (the serving plane's
//! [`crate::coordinator::SubmitError`] verbatim) or `parse` / `dropped` /
//! `unsupported` / `auth` (wire-layer; an unknown `model` name is
//! `unsupported`). Error frames are written from the reader thread, ahead
//! of pending completions — an overloaded server answers `backpressure`
//! immediately; it never leaves a client hanging.
//!
//! # Wire topology (multi-tenant)
//!
//! ```text
//!  client conns          NetServer                    Service + ModelRegistry
//!  ───────────           ─────────                    ───────────────────────
//!  conn 0 ──TCP──▶ reader ─submit_to(0, model)─▶ [shard 0 queue]─▶ DRR ─┐
//!         ◀─TCP── writer ◀── completion ◀─ reply rxs        dispatcher  │ work
//!  conn 1 ──TCP──▶ reader ─submit_to(1, model)─▶ [shard 1 queue]─▶ DRR ─┤ pool
//!         ◀─TCP── writer ◀── completion ◀─ reply rxs        dispatcher  │ (steal)
//!  conn k ──TCP──▶ reader ─submit_to(k%S, ...)─▶ [shard k%S ...]        ┘
//!                   │                                   │
//!                   └─ name → ModelId (registry) ───────┴─▶ tenant cells
//!                                                           (shared arena)
//! ```
//!
//! The reader resolves the optional `model` name to a [`ModelId`] once per
//! frame; admission, deficit-round-robin batch formation, and execution
//! all run on ids. Requests from different tenants share shards and the
//! work pool but never share a batch.
//!
//! Each connection pins to one admission shard (connection = client, same
//! affinity the in-process plane assumes), runs a reader thread (frames →
//! decode → submit) and a completion thread (reply channels → frames), and
//! bounds its in-flight window with a `sync_channel` between them.
//! Teardown order is always: reader EOF → completion drains what was
//! admitted → flush → FIN. [`NetServer::shutdown`] forces exactly that
//! path on every connection by closing read halves, so in-flight responses
//! are flushed, never abandoned.
//!
//! [`ModelId`]: crate::coordinator::ModelId
//!
//! Entry points: `kanele serve --listen <addr>` wraps [`NetServer`];
//! `kanele loadgen <addr>` wraps [`client::loadgen`].

pub mod client;
pub mod frame;
pub mod proto;
pub mod server;

pub use client::{loadgen, Client, LoadGenCfg, LoadGenReport, NetError};
pub use frame::{FrameError, MAX_FRAME};
pub use proto::{ErrorKind, ProtoError, WireRequest, WireResponse};
pub use server::{NetCfg, NetServer, NetStats};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil;
    use crate::coordinator::{Service, ServiceCfg};
    use crate::lut;
    use crate::netlist::Netlist;
    use std::net::TcpListener;
    use std::sync::Arc;
    use std::time::Duration;

    fn loopback(workers: usize) -> (Arc<Service>, NetServer) {
        let ck = testutil::synthetic(&[6, 4, 3], &[4, 4, 4], 99);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Arc::new(Service::start(
            net,
            ServiceCfg { workers, shards: 2, ..ServiceCfg::default() },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server =
            NetServer::start(Arc::clone(&svc), listener, NetCfg { levels: 16, ..NetCfg::default() })
                .unwrap();
        (svc, server)
    }

    #[test]
    fn loopback_infer_roundtrip() {
        let (svc, mut server) = loopback(2);
        let mut client = Client::connect(server.local_addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

        let codes = vec![1u32, 2, 3, 4, 5, 6];
        let (wire_sums, latency_us) = client.infer(codes.clone()).unwrap();
        let direct = svc.submit_blocking(codes).unwrap();
        assert_eq!(wire_sums, direct.sums);
        assert!(latency_us >= 0.0);

        // stats advertises the request shape loadgen relies on
        let stats = client.stats().unwrap();
        assert_eq!(stats.get("input_width").and_then(|v| v.as_i64()), Some(6));
        assert_eq!(stats.get("levels").and_then(|v| v.as_i64()), Some(16));

        drop(client);
        server.shutdown();
        let net_stats = server.stats();
        assert_eq!(net_stats.accepted, 1);
        assert!(net_stats.wire_completed >= 2);
        svc.shutdown();
    }

    #[test]
    fn loopback_wrong_width_is_invalid_frame_and_connection_survives() {
        let (svc, mut server) = loopback(2);
        let mut client = Client::connect(server.local_addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

        match client.infer(vec![1, 2, 3]) {
            Err(NetError::Remote { kind: ErrorKind::Invalid, .. }) => {}
            other => panic!("expected Invalid error frame, got {other:?}"),
        }
        // same connection still serves well-formed requests
        let (sums, _) = client.infer(vec![0; 6]).unwrap();
        assert_eq!(sums.len(), 3);

        server.shutdown();
        svc.shutdown();
    }

    #[test]
    fn loopback_malformed_json_is_parse_frame() {
        let (svc, mut server) = loopback(2);
        let mut client = Client::connect(server.local_addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

        // hand-rolled garbage frame: valid framing, invalid payload
        let req = WireRequest::Stats { id: 1 };
        let garbage = "{not json";
        {
            use std::io::Write as _;
            let mut raw = client_stream(&client);
            raw.write_all(&(garbage.len() as u32).to_be_bytes()).unwrap();
            raw.write_all(garbage.as_bytes()).unwrap();
        }
        match client.recv_response().unwrap() {
            WireResponse::Error { kind: ErrorKind::Parse, .. } => {}
            other => panic!("expected Parse error frame, got {other:?}"),
        }
        // unaddressable payload closes the connection
        assert!(client.send(&req).is_err() || client.recv_response().is_err());

        server.shutdown();
        svc.shutdown();
    }

    /// Tests poke raw bytes through the client's socket.
    fn client_stream(c: &Client) -> &std::net::TcpStream {
        &c.stream
    }
}
