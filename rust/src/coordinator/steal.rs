//! Work-stealing run-queue pool between the per-shard dispatchers and the
//! executor pool.
//!
//! PR 2/3's single bounded work channel was the serving plane's last
//! single-owner handoff: every executor popped from one `Mutex<Receiver>`,
//! and one heavy-tailed batch could not be rebalanced once the FIFO had
//! assigned it. This pool gives each admission shard its **own bounded
//! deque**: the shard's dispatcher pushes formed batches locally, each
//! executor pops from its *home* deque first, and — when stealing is
//! enabled — an idle executor scans the other shards and steals their
//! oldest queued item, so heavy-tailed batch costs spread across the whole
//! executor pool instead of convoying behind one shard.
//!
//! The implementation is deliberately mutex-sharded rather than a lock-free
//! Chase-Lev deque: no new dependencies (the registry is offline), and the
//! items are *formed batches* (microseconds to milliseconds of work each),
//! so a short per-shard critical section is far below the noise floor while
//! staying obviously correct. Both owner and thief pop from the **front**
//! (oldest first): for a serving queue, LIFO stealing would invert
//! latencies, and request-age-relative `max_wait` semantics want the oldest
//! batch executed first regardless of which executor runs it.
//!
//! Blocking uses an eventcount-lite gate: a generation counter + condvar
//! guarded by one mutex that is only touched by *idle* poppers, *blocked*
//! pushers, and the push/pop that wakes them (fast paths check the atomic
//! sleeper counts and skip the gate entirely). A defensive wait timeout
//! bounds any missed-wakeup bug to one poll interval; correctness does not
//! rely on it (see the ordering argument on [`WorkPool::push`]).
//!
//! **Supervision invariant** (PR 8): the coordinator's executors and
//! dispatchers catch panics *in-thread* and restart their loops in place,
//! so the pool's fixed producer/consumer accounting — `close_producer`
//! once per dispatcher thread, the RAII consumer guard once per executor
//! thread — is untouched by a contained panic. `consumers` only reaches
//! zero when a supervisor genuinely gives up (restart budget exhausted),
//! which is exactly when `push` must start failing fast again.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Counters for the pool's pop paths. `pushed == local + stolen` once the
/// pool has been fully drained.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Items pushed across all shards.
    pub pushed: u64,
    /// Pops served from the popper's home shard.
    pub local: u64,
    /// Pops served by stealing from another shard.
    pub stolen: u64,
}

struct Gate {
    /// Bumped on every event a waiter could be waiting for (item pushed,
    /// space freed, producer closed); waiters sleep on "seq unchanged".
    seq: u64,
    /// Open producers; at zero, poppers that find nothing return `None`.
    producers: usize,
}

/// Mutex-sharded, bounded, work-stealing run queues (see module docs).
pub struct WorkPool<T> {
    queues: Vec<Mutex<VecDeque<T>>>,
    gate: Mutex<Gate>,
    cond: Condvar,
    /// Per-shard queue bound (backpressure towards the dispatcher).
    cap: usize,
    steal: bool,
    /// Poppers idle (or about to re-check) on the gate; pushers skip the
    /// gate lock entirely while this is zero.
    sleepers: AtomicUsize,
    /// Pushers blocked on a full shard; poppers skip the wakeup while zero.
    full_waiters: AtomicUsize,
    /// Live consumers. Purely a fail-safe: when it hits zero (every
    /// executor died — panics included, via the coordinator's RAII guard),
    /// `push` fails instead of blocking forever on a full deque, matching
    /// the old work channel whose `send` errored once its receivers were
    /// gone.
    consumers: AtomicUsize,
    pushed: AtomicU64,
    local: AtomicU64,
    stolen: AtomicU64,
}

impl<T> WorkPool<T> {
    /// Defensive re-check interval for gate waits; correctness never
    /// depends on it (lost wakeups are excluded by the seq protocol), it
    /// only bounds the damage of a future regression.
    const POLL: Duration = Duration::from_millis(1);

    /// `shards` bounded deques of capacity `cap` each, fed by `producers`
    /// producers and drained by `consumers` consumers. With `steal` off, a
    /// popper only ever sees its home shard, so every shard must have at
    /// least one home popper or its items strand (the coordinator
    /// guarantees this by clamping shards to the executor count).
    pub fn new(
        shards: usize,
        cap: usize,
        steal: bool,
        producers: usize,
        consumers: usize,
    ) -> WorkPool<T> {
        assert!(shards > 0 && cap > 0 && producers > 0 && consumers > 0);
        WorkPool {
            queues: (0..shards).map(|_| Mutex::new(VecDeque::new())).collect(),
            gate: Mutex::new(Gate { seq: 0, producers }),
            cond: Condvar::new(),
            cap,
            steal,
            sleepers: AtomicUsize::new(0),
            full_waiters: AtomicUsize::new(0),
            consumers: AtomicUsize::new(consumers),
            pushed: AtomicU64::new(0),
            local: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
        }
    }

    pub fn shards(&self) -> usize {
        self.queues.len()
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            pushed: self.pushed.load(Ordering::Relaxed),
            local: self.local.load(Ordering::Relaxed),
            stolen: self.stolen.load(Ordering::Relaxed),
        }
    }

    /// Bump the gate generation and wake every waiter (work- and
    /// space-waiters share the condvar; both re-check their condition).
    fn bump(&self) {
        let mut g = self.gate.lock().unwrap();
        g.seq += 1;
        self.cond.notify_all();
    }

    /// One pop attempt: home shard first, then (with stealing) the victims
    /// in round-robin order from `home`. Front pops everywhere — oldest
    /// batch first, whoever runs it.
    fn try_pop(&self, home: usize) -> Option<(usize, T)> {
        if let Some(t) = self.queues[home].lock().unwrap().pop_front() {
            self.local.fetch_add(1, Ordering::Relaxed);
            return Some((home, t));
        }
        if self.steal {
            let n = self.queues.len();
            for i in 1..n {
                let victim = (home + i) % n;
                if let Some(t) = self.queues[victim].lock().unwrap().pop_front() {
                    self.stolen.fetch_add(1, Ordering::Relaxed);
                    return Some((victim, t));
                }
            }
        }
        None
    }

    /// Push `item` onto `shard`'s deque, blocking while the shard is at
    /// capacity (bounded handoff = backpressure into the admission queue,
    /// exactly like the old bounded work channel). Returns `false` —
    /// dropping the item — once every consumer has closed (executor pool
    /// died), so a producer can never block forever on a deque nothing
    /// will drain; the old work channel's erroring `send` behaved the same.
    ///
    /// No lost wakeups: a popper registers in `sleepers` *before* its final
    /// re-scan, and this push enqueues *before* loading `sleepers` (both
    /// SeqCst, and the queue mutex orders enqueue vs scan) — so either the
    /// popper's re-scan observes the item, or this push observes the
    /// sleeper and bumps the gate. Symmetrically for full pushers vs pops.
    #[must_use]
    pub fn push(&self, shard: usize, item: T) -> bool {
        let mut item = item;
        loop {
            if self.consumers.load(Ordering::SeqCst) == 0 {
                return false;
            }
            {
                let mut q = self.queues[shard].lock().unwrap();
                if q.len() < self.cap {
                    q.push_back(item);
                    self.pushed.fetch_add(1, Ordering::Relaxed);
                    break;
                }
            }
            // shard full: wait for a pop. Register, then re-check under the
            // gate so a concurrent pop either sees us or we see its space.
            let mut g = self.gate.lock().unwrap();
            self.full_waiters.fetch_add(1, Ordering::SeqCst);
            let full = self.queues[shard].lock().unwrap().len() >= self.cap;
            if !full {
                self.full_waiters.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            let seen = g.seq;
            while g.seq == seen {
                let (g2, timeout) = self.cond.wait_timeout(g, Self::POLL).unwrap();
                g = g2;
                if timeout.timed_out() {
                    break;
                }
            }
            self.full_waiters.fetch_sub(1, Ordering::SeqCst);
        }
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            self.bump();
        }
        true
    }

    /// Non-blocking push: enqueue onto `shard` if it is below capacity,
    /// returning the item back on a full deque (or a dead consumer pool)
    /// so the caller can run it inline. The intra-batch slicer's
    /// opportunistic fan-out depends on this shape for deadlock freedom:
    /// an executor that is itself mid-batch must never *block* on deque
    /// space it is responsible for draining.
    pub fn try_push(&self, shard: usize, item: T) -> Result<(), T> {
        if self.consumers.load(Ordering::SeqCst) == 0 {
            return Err(item);
        }
        {
            let mut q = self.queues[shard].lock().unwrap();
            if q.len() >= self.cap {
                return Err(item);
            }
            q.push_back(item);
            self.pushed.fetch_add(1, Ordering::Relaxed);
        }
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            self.bump();
        }
        Ok(())
    }

    /// Non-blocking, filtered pop: remove and return the oldest queued
    /// item matching `pred`, scanning the home shard first and then (with
    /// stealing enabled) the victims in round-robin order. The intra-batch
    /// slicer uses this from an executor that is *joining* its own sliced
    /// batch: it keeps draining slice work — and only slice work, the
    /// predicate never admits a whole batch, which would recurse — so a
    /// pool full of joining originators still makes progress.
    pub fn try_pop_where<F: FnMut(&T) -> bool>(
        &self,
        home: usize,
        mut pred: F,
    ) -> Option<(usize, T)> {
        let n = self.queues.len();
        let visible = if self.steal { n } else { 1 };
        for i in 0..visible {
            let shard = (home + i) % n;
            let item = {
                let mut q = self.queues[shard].lock().unwrap();
                match q.iter().position(&mut pred) {
                    Some(at) => q.remove(at),
                    None => None,
                }
            };
            if let Some(item) = item {
                if shard == home {
                    self.local.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.stolen.fetch_add(1, Ordering::Relaxed);
                }
                if self.full_waiters.load(Ordering::SeqCst) > 0 {
                    self.bump();
                }
                return Some((shard, item));
            }
        }
        None
    }

    /// Pop the next item for a popper whose home shard is `home`; returns
    /// the *source* shard alongside the item (a `(victim, item)` result is
    /// a steal). Blocks while the visible shards are empty; returns `None`
    /// once every producer has closed and the visible shards are drained.
    pub fn pop(&self, home: usize) -> Option<(usize, T)> {
        loop {
            if let Some(r) = self.try_pop(home) {
                if self.full_waiters.load(Ordering::SeqCst) > 0 {
                    self.bump();
                }
                return Some(r);
            }
            let mut g = self.gate.lock().unwrap();
            self.sleepers.fetch_add(1, Ordering::SeqCst);
            // re-scan with the registration visible: any push that missed
            // our sleeper flag happened before it, so this scan sees it
            if let Some(r) = self.try_pop(home) {
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                g.seq += 1; // a slot just freed; wake space-waiters inline
                self.cond.notify_all();
                return Some(r);
            }
            if g.producers == 0 {
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                return None;
            }
            let seen = g.seq;
            while g.seq == seen {
                let (g2, timeout) = self.cond.wait_timeout(g, Self::POLL).unwrap();
                g = g2;
                if timeout.timed_out() {
                    break;
                }
            }
            self.sleepers.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// A producer will push no more. When the last one closes, blocked
    /// poppers drain what is queued and then return `None`.
    pub fn close_producer(&self) {
        let mut g = self.gate.lock().unwrap();
        assert!(g.producers > 0, "close_producer called more times than producers");
        g.producers -= 1;
        g.seq += 1;
        self.cond.notify_all();
    }

    /// A consumer will pop no more (normal wind-down or panic unwind; the
    /// coordinator calls this from an RAII guard). When the last one
    /// closes, blocked pushers wake and fail instead of waiting forever.
    pub fn close_consumer(&self) {
        let left = self.consumers.fetch_sub(1, Ordering::SeqCst);
        assert!(left > 0, "close_consumer called more times than consumers");
        if left == 1 {
            self.bump();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn fifo_order_single_shard() {
        let pool: WorkPool<u32> = WorkPool::new(1, 16, true, 1, 1);
        for i in 0..10 {
            assert!(pool.push(0, i));
        }
        for i in 0..10 {
            assert_eq!(pool.pop(0), Some((0, i)));
        }
        let st = pool.stats();
        assert_eq!(st, PoolStats { pushed: 10, local: 10, stolen: 0 });
        pool.close_producer();
        assert_eq!(pool.pop(0), None);
    }

    #[test]
    fn drain_after_close_then_none() {
        let pool: WorkPool<u32> = WorkPool::new(2, 8, true, 1, 1);
        assert!(pool.push(0, 1));
        assert!(pool.push(1, 2));
        pool.close_producer();
        // both items still come out (shutdown drains admitted work) ...
        let mut got: Vec<u32> = (0..2).map(|_| pool.pop(0).unwrap().1).collect();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
        // ... and only then does the pool report exhaustion
        assert_eq!(pool.pop(0), None);
        assert_eq!(pool.pop(1), None);
    }

    #[test]
    fn steal_disabled_isolates_shards() {
        let pool: WorkPool<u32> = WorkPool::new(2, 8, false, 1, 2);
        assert!(pool.push(0, 7));
        pool.close_producer();
        // home-1 popper never looks at shard 0
        assert_eq!(pool.pop(1), None);
        assert_eq!(pool.pop(0), Some((0, 7)));
        assert_eq!(pool.stats().stolen, 0);
    }

    #[test]
    fn idle_popper_steals_from_victim() {
        let pool: WorkPool<u32> = WorkPool::new(2, 8, true, 1, 2);
        assert!(pool.push(0, 1));
        assert!(pool.push(0, 2));
        // home-1 popper finds its shard empty and steals the OLDEST from 0
        assert_eq!(pool.pop(1), Some((0, 1)));
        assert_eq!(pool.pop(0), Some((0, 2)));
        let st = pool.stats();
        assert_eq!(st.stolen, 1);
        assert_eq!(st.local, 1);
        pool.close_producer();
    }

    #[test]
    fn bounded_push_blocks_until_popped() {
        // cap 1: a producer pushing 64 items can only make progress as fast
        // as the consumer pops — liveness under sustained fullness
        let pool: Arc<WorkPool<u32>> = Arc::new(WorkPool::new(1, 1, true, 1, 1));
        let p = Arc::clone(&pool);
        let producer = std::thread::spawn(move || {
            for i in 0..64 {
                assert!(p.push(0, i));
            }
            p.close_producer();
        });
        let mut got = Vec::new();
        while let Some((_, v)) = pool.pop(0) {
            got.push(v);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..64).collect::<Vec<_>>());
        assert_eq!(pool.stats().pushed, 64);
    }

    #[test]
    fn push_fails_once_all_consumers_close() {
        // executor-pool death fail-safe: a producer facing a full deque
        // with no consumers left must fail, not block forever
        let pool: Arc<WorkPool<u32>> = Arc::new(WorkPool::new(1, 1, true, 1, 1));
        assert!(pool.push(0, 1)); // fills the deque
        let p = Arc::clone(&pool);
        let blocked = std::thread::spawn(move || p.push(0, 2));
        std::thread::sleep(Duration::from_millis(20)); // let it block on full
        pool.close_consumer();
        assert!(!blocked.join().unwrap(), "push must fail after the last consumer closes");
        // and new pushes fail immediately
        assert!(!pool.push(0, 3));
    }

    #[test]
    fn try_push_is_nonblocking_and_reports_full() {
        let pool: WorkPool<u32> = WorkPool::new(1, 2, true, 1, 1);
        assert_eq!(pool.try_push(0, 1), Ok(()));
        assert_eq!(pool.try_push(0, 2), Ok(()));
        // full deque: the item comes straight back, no blocking
        assert_eq!(pool.try_push(0, 3), Err(3));
        assert_eq!(pool.pop(0), Some((0, 1)));
        assert_eq!(pool.try_push(0, 3), Ok(()));
        // dead consumer pool: fail fast like push()
        pool.close_consumer();
        assert_eq!(pool.try_push(0, 4), Err(4));
        assert_eq!(pool.stats().pushed, 3);
    }

    #[test]
    fn try_pop_where_picks_oldest_match_and_skips_others() {
        let pool: WorkPool<u32> = WorkPool::new(1, 8, true, 1, 1);
        for v in [10u32, 3, 12, 5] {
            assert!(pool.push(0, v));
        }
        // oldest odd-ish (< 10) item is 3, from the middle of the deque
        assert_eq!(pool.try_pop_where(0, |&v| v < 10), Some((0, 3)));
        assert_eq!(pool.try_pop_where(0, |&v| v < 10), Some((0, 5)));
        assert_eq!(pool.try_pop_where(0, |&v| v < 10), None);
        // FIFO order of the unmatched items is preserved
        assert_eq!(pool.pop(0), Some((0, 10)));
        assert_eq!(pool.pop(0), Some((0, 12)));
        pool.close_producer();
        let st = pool.stats();
        assert_eq!(st.local + st.stolen, st.pushed);
    }

    #[test]
    fn try_pop_where_steals_only_when_enabled() {
        let isolated: WorkPool<u32> = WorkPool::new(2, 8, false, 1, 2);
        assert!(isolated.push(0, 7));
        assert_eq!(isolated.try_pop_where(1, |_| true), None, "steal off must isolate");
        assert_eq!(isolated.try_pop_where(0, |_| true), Some((0, 7)));

        let stealing: WorkPool<u32> = WorkPool::new(2, 8, true, 1, 2);
        assert!(stealing.push(0, 9));
        assert_eq!(stealing.try_pop_where(1, |_| true), Some((0, 9)));
        assert_eq!(stealing.stats().stolen, 1);
    }

    #[test]
    fn try_pop_where_frees_space_for_blocked_pusher() {
        let pool: Arc<WorkPool<u32>> = Arc::new(WorkPool::new(1, 1, true, 1, 1));
        assert!(pool.push(0, 1));
        let p = Arc::clone(&pool);
        let blocked = std::thread::spawn(move || p.push(0, 2));
        std::thread::sleep(Duration::from_millis(20)); // let it block on full
        assert_eq!(pool.try_pop_where(0, |_| true), Some((0, 1)));
        assert!(blocked.join().unwrap(), "filtered pop must wake a space-waiter");
        assert_eq!(pool.pop(0), Some((0, 2)));
    }

    #[test]
    fn close_wakes_blocked_popper() {
        let pool: Arc<WorkPool<u32>> = Arc::new(WorkPool::new(1, 4, true, 1, 1));
        let p = Arc::clone(&pool);
        let popper = std::thread::spawn(move || p.pop(0));
        std::thread::sleep(Duration::from_millis(20));
        pool.close_producer();
        assert_eq!(popper.join().unwrap(), None);
    }

    #[test]
    fn two_workers_split_one_hot_shard() {
        // everything lands on shard 0; a home-1 worker must steal roughly
        // half of it so the wall clock is ~half the serial cost
        const ITEM_MS: u64 = 10;
        const ITEMS: u64 = 8;
        let pool: Arc<WorkPool<u64>> = Arc::new(WorkPool::new(2, 8, true, 1, 2));
        let t0 = Instant::now();
        let workers: Vec<_> = (0..2)
            .map(|home| {
                let p = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    while let Some((_, ms)) = p.pop(home) {
                        std::thread::sleep(Duration::from_millis(ms));
                        n += 1;
                    }
                    n
                })
            })
            .collect();
        for _ in 0..ITEMS {
            assert!(pool.push(0, ITEM_MS));
        }
        pool.close_producer();
        let done: u64 = workers.into_iter().map(|w| w.join().unwrap()).sum();
        let wall = t0.elapsed();
        assert_eq!(done, ITEMS);
        let st = pool.stats();
        assert!(st.stolen >= 1, "idle worker never stole: {st:?}");
        assert_eq!(st.local + st.stolen, ITEMS);
        // serial cost is 80 ms; two workers with stealing should land well
        // under it even on a loaded CI box
        assert!(
            wall < Duration::from_millis(ITEM_MS * ITEMS - ITEM_MS),
            "stealing failed to parallelize the hot shard ({wall:?})"
        );
    }

    #[test]
    fn multi_producer_multi_consumer_drains_exactly() {
        let pool: Arc<WorkPool<u64>> = Arc::new(WorkPool::new(4, 2, true, 4, 3));
        let producers: Vec<_> = (0..4u64)
            .map(|s| {
                let p = Arc::clone(&pool);
                std::thread::spawn(move || {
                    for i in 0..100 {
                        assert!(p.push(s as usize, s * 1000 + i));
                    }
                    p.close_producer();
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|home| {
                let p = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some((_, v)) = p.pop(home) {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let mut want: Vec<u64> =
            (0..4u64).flat_map(|s| (0..100).map(move |i| s * 1000 + i)).collect();
        want.sort_unstable();
        assert_eq!(all, want, "every pushed item popped exactly once");
        let st = pool.stats();
        assert_eq!(st.pushed, 400);
        assert_eq!(st.local + st.stolen, 400);
    }
}
