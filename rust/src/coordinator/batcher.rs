//! Standalone dynamic-batching policy, extracted so the policy itself can
//! be unit-tested and swept by the ablation benches (batch-size vs latency
//! trade-off) without spinning up threads.

use std::time::Duration;

/// Decision state for one forming batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decision {
    /// Keep waiting for more requests.
    Wait(Duration),
    /// Dispatch now.
    Dispatch,
}

/// Dispatch policy: fill to `max_batch` or flush after `max_wait`.
#[derive(Clone, Copy, Debug)]
pub struct Policy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Policy {
    /// Given the current batch fill and the age of its oldest request,
    /// decide whether to dispatch.
    pub fn decide(&self, fill: usize, oldest_age: Duration) -> Decision {
        if fill >= self.max_batch {
            return Decision::Dispatch;
        }
        if fill > 0 && oldest_age >= self.max_wait {
            return Decision::Dispatch;
        }
        Decision::Wait(self.max_wait.saturating_sub(oldest_age))
    }

    /// Expected batching latency added to a request arriving at a Poisson
    /// rate `lambda_rps` (analytic model used by the tuning bench): the
    /// batch dispatches after min(time to fill, max_wait).
    pub fn expected_added_latency_us(&self, lambda_rps: f64) -> f64 {
        if lambda_rps <= 0.0 {
            return self.max_wait.as_secs_f64() * 1e6;
        }
        let fill_time = (self.max_batch as f64 - 1.0) / lambda_rps;
        fill_time.min(self.max_wait.as_secs_f64()) * 0.5 * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatches_when_full() {
        let p = Policy { max_batch: 8, max_wait: Duration::from_micros(100) };
        assert_eq!(p.decide(8, Duration::ZERO), Decision::Dispatch);
        assert_eq!(p.decide(9, Duration::ZERO), Decision::Dispatch);
    }

    #[test]
    fn dispatches_on_timeout() {
        let p = Policy { max_batch: 8, max_wait: Duration::from_micros(100) };
        assert_eq!(p.decide(3, Duration::from_micros(100)), Decision::Dispatch);
        assert_eq!(p.decide(3, Duration::from_micros(150)), Decision::Dispatch);
    }

    #[test]
    fn waits_otherwise() {
        let p = Policy { max_batch: 8, max_wait: Duration::from_micros(100) };
        match p.decide(3, Duration::from_micros(40)) {
            Decision::Wait(d) => assert_eq!(d, Duration::from_micros(60)),
            other => panic!("expected Wait, got {other:?}"),
        }
        // empty batch: full wait budget
        match p.decide(0, Duration::ZERO) {
            Decision::Wait(d) => assert_eq!(d, Duration::from_micros(100)),
            other => panic!("expected Wait, got {other:?}"),
        }
    }

    #[test]
    fn expected_latency_monotone_in_batch() {
        let lam = 1e6; // 1M rps
        let small = Policy { max_batch: 4, max_wait: Duration::from_micros(200) };
        let big = Policy { max_batch: 256, max_wait: Duration::from_micros(200) };
        assert!(small.expected_added_latency_us(lam) <= big.expected_added_latency_us(lam));
    }
}
