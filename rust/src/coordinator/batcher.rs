//! Dynamic-batching policy **and** the dispatcher's batch-collection loop.
//!
//! [`Policy::decide`] is the single source of dispatch decisions (fill to
//! `max_batch`, flush once the *oldest request* has waited `max_wait`);
//! [`collect_with`] is the loop each of the coordinator's per-shard
//! dispatcher threads runs to turn its admission channel into [`Batch`]es,
//! consulting `decide` before every wait and recording per-shard policy
//! state into a [`CollectStats`] (how many batches, how many dispatched
//! full vs flushed on timeout — the observable a shard's batching health
//! is judged by). Both are thread-free and unit-testable: `collect_with`
//! only needs a channel of [`Timestamped`] items, so the policy/dispatcher
//! equivalence is asserted directly in tests instead of being an emergent
//! property of the worker pool.
//!
//! Age is always measured from each request's *submission* time, never
//! from when collection started: a request that queued behind a busy
//! service is dispatched as soon as the dispatcher sees it has already
//! spent its `max_wait` budget, instead of waiting a second full window.
//!
//! Multi-tenant dispatchers use [`DrrCollector`] instead of `collect_with`:
//! items carry a routing key ([`Keyed`]) and are parked in per-key queues
//! served deficit-round-robin, so one batch never mixes tenants and a
//! heavy tenant's backlog cannot starve a light one. With a single key the
//! collector degenerates to `collect_with` exactly (same batch lengths,
//! same flush reasons, same [`CollectStats`]) — asserted by test.
//!
//! Items may also carry an absolute **deadline** ([`Timestamped::deadline`],
//! default `None`). The DRR collector sheds already-expired items at batch
//! formation time — they never enter a batch; instead they are handed to
//! the caller's `on_shed` sink (the dispatcher replies with a typed
//! `Expired` error) and counted in [`CollectStats::shed_expired`]. Under
//! overload the plane degrades to answering fresh requests on time instead
//! of answering everything late. Deadline-free traffic takes none of these
//! paths: per-queue `has_deadlines` keeps the shedding scan entirely off
//! the deadline-less hot path.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Decision state for one forming batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decision {
    /// Keep waiting for more requests.
    Wait(Duration),
    /// Dispatch now.
    Dispatch,
}

/// Dispatch policy: fill to `max_batch` or flush after `max_wait`; across
/// tenants, serve per-key queues deficit-round-robin.
#[derive(Clone, Copy, Debug)]
pub struct Policy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Deficit-round-robin quantum: how many items a tenant's queue earns
    /// per rotation visit in [`DrrCollector`]. `0` (the default) means
    /// "use `max_batch`" — round-robin of full batches. Smaller values
    /// interleave tenants at sub-batch granularity under saturation.
    /// Ignored by the single-queue [`collect_with`].
    pub drr_quantum: usize,
}

impl Default for Policy {
    fn default() -> Self {
        Policy { max_batch: 64, max_wait: Duration::from_micros(200), drr_quantum: 0 }
    }
}

impl Policy {
    /// Given the current batch fill and the age of its oldest request,
    /// decide whether to dispatch.
    pub fn decide(&self, fill: usize, oldest_age: Duration) -> Decision {
        if fill >= self.max_batch {
            return Decision::Dispatch;
        }
        if fill > 0 && oldest_age >= self.max_wait {
            return Decision::Dispatch;
        }
        Decision::Wait(self.max_wait.saturating_sub(oldest_age))
    }

    /// Expected batching latency added to a request arriving at a Poisson
    /// rate `lambda_rps` (analytic model used by the tuning bench): the
    /// batch dispatches after min(time to fill, max_wait).
    pub fn expected_added_latency_us(&self, lambda_rps: f64) -> f64 {
        if lambda_rps <= 0.0 {
            return self.max_wait.as_secs_f64() * 1e6;
        }
        let fill_time = (self.max_batch as f64 - 1.0) / lambda_rps;
        fill_time.min(self.max_wait.as_secs_f64()) * 0.5 * 1e6
    }

    /// Effective DRR quantum: `drr_quantum` defaulted to `max_batch` and
    /// clamped into `[1, max_batch]` so every rotation visit makes progress
    /// and no single visit exceeds one batch.
    fn quantum(&self) -> usize {
        let cap = self.max_batch.max(1);
        let q = if self.drr_quantum == 0 { cap } else { self.drr_quantum };
        q.clamp(1, cap)
    }
}

/// Anything carrying a submission timestamp can be collected into batches.
pub trait Timestamped {
    fn submitted(&self) -> Instant;

    /// Absolute deadline, if the item carries one. Items whose deadline has
    /// passed are shed at batch formation by [`DrrCollector`] instead of
    /// entering a batch. The default (`None`) opts out entirely.
    fn deadline(&self) -> Option<Instant> {
        None
    }
}

/// Bare timestamps batch as themselves (tests and simulations).
impl Timestamped for Instant {
    fn submitted(&self) -> Instant {
        *self
    }
}

/// Items carrying a tenant routing key can be collected per key by
/// [`DrrCollector`]: one batch never mixes keys (executors resolve the
/// program per batch).
pub trait Keyed {
    fn key(&self) -> u32;
}

/// Bare timestamps are single-tenant (tests and simulations).
impl Keyed for Instant {
    fn key(&self) -> u32 {
        0
    }
}

/// One formed batch: the unit of work handed from the dispatcher to the
/// executor pool.
#[derive(Debug)]
pub struct Batch<T> {
    pub items: Vec<T>,
    /// Earliest submission time across `items`.
    pub oldest: Instant,
}

impl<T: Timestamped> Batch<T> {
    /// Wrap a non-empty item list, computing the oldest submission time.
    /// Convenience for tests and external producers; [`collect`] builds
    /// batches directly from its incrementally-tracked oldest timestamp.
    pub fn new(items: Vec<T>) -> Batch<T> {
        let oldest = items
            .iter()
            .map(|t| t.submitted())
            .min()
            .expect("batch must be non-empty");
        Batch { items, oldest }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Why [`collect_with`] dispatched a batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// Filled to `max_batch`.
    Full,
    /// Oldest request exhausted its `max_wait` budget.
    Timeout,
    /// Admission disconnected (shutdown) with a partial batch in hand.
    Disconnect,
}

/// Per-shard collection state: each dispatcher owns one and publishes it
/// into its shard's service statistics, so a shard whose batches always
/// flush on timeout (underfed) is distinguishable from one dispatching
/// full (saturated).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CollectStats {
    pub batches: u64,
    pub items: u64,
    pub flush_full: u64,
    pub flush_timeout: u64,
    pub flush_disconnect: u64,
    /// Items shed at batch formation because their deadline had already
    /// expired (never entered a batch; `items` does not include them).
    pub shed_expired: u64,
}

impl CollectStats {
    fn record<T>(&mut self, reason: FlushReason, batch: Batch<T>) -> Batch<T> {
        self.batches += 1;
        self.items += batch.len() as u64;
        match reason {
            FlushReason::Full => self.flush_full += 1,
            FlushReason::Timeout => self.flush_timeout += 1,
            FlushReason::Disconnect => self.flush_disconnect += 1,
        }
        batch
    }
}

/// Collect the next batch from `rx`, consulting [`Policy::decide`] before
/// every wait and recording the dispatch into `stats`. Returns `None` once
/// the channel is disconnected and fully drained (service shutdown); a
/// partial batch in hand at disconnection is still dispatched so admitted
/// requests always complete.
///
/// A backlog is drained greedily first: requests already queued fill the
/// batch to `max_batch` without any waiting, so sustained load produces
/// full batches regardless of how old the queue head is.
pub fn collect_with<T: Timestamped>(
    rx: &Receiver<T>,
    policy: &Policy,
    stats: &mut CollectStats,
) -> Option<Batch<T>> {
    let first = rx.recv().ok()?;
    let mut oldest = first.submitted();
    let mut items = vec![first];
    loop {
        // greedy drain: whatever is already queued joins for free
        while items.len() < policy.max_batch {
            match rx.try_recv() {
                Ok(t) => {
                    oldest = oldest.min(t.submitted());
                    items.push(t);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    return Some(stats.record(FlushReason::Disconnect, Batch { items, oldest }))
                }
            }
        }
        match policy.decide(items.len(), oldest.elapsed()) {
            Decision::Dispatch => {
                let reason = if items.len() >= policy.max_batch {
                    FlushReason::Full
                } else {
                    FlushReason::Timeout
                };
                return Some(stats.record(reason, Batch { items, oldest }));
            }
            Decision::Wait(d) => match rx.recv_timeout(d) {
                Ok(t) => {
                    oldest = oldest.min(t.submitted());
                    items.push(t);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Some(stats.record(FlushReason::Timeout, Batch { items, oldest }))
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Some(stats.record(FlushReason::Disconnect, Batch { items, oldest }))
                }
            },
        }
    }
}

/// [`collect_with`] without the per-shard bookkeeping (tests, simulations,
/// embedders that track their own).
pub fn collect<T: Timestamped>(rx: &Receiver<T>, policy: &Policy) -> Option<Batch<T>> {
    collect_with(rx, policy, &mut CollectStats::default())
}

/// One tenant's parked items inside a [`DrrCollector`], plus its carried
/// deficit. Queues are kept non-empty (removed when drained) and live in
/// rotation order.
struct KeyQueue<T> {
    key: u32,
    items: VecDeque<T>,
    deficit: usize,
    /// Any parked item carries a deadline — gates the shedding scan so
    /// deadline-free tenants never pay for it.
    has_deadlines: bool,
}

/// The item's deadline has passed.
fn is_expired<T: Timestamped>(item: &T, now: Instant) -> bool {
    item.deadline().is_some_and(|d| d <= now)
}

/// Per-tenant deficit-round-robin batch collection — the multi-tenant
/// dispatcher loop. Admitted items are parked into per-key queues; each
/// call to [`DrrCollector::next`] dispatches from the first queue in
/// rotation order that is ready (filled to `max_batch`, or its oldest item
/// aged past `max_wait`), taking at most `min(max_batch, deficit)` items
/// where the deficit grows by [`Policy::drr_quantum`] per visit. The
/// dispatched queue rotates to the back, so a tenant with 25 queued
/// batches yields the rotation after every dispatch instead of draining
/// first.
///
/// Degeneration contract: with every item on one key, the sequence of
/// batch lengths, flush reasons and [`CollectStats`] is identical to
/// [`collect_with`] (asserted by test) — the PR-6 single-tenant pipeline
/// is this collector with one queue. One behavioral note: the greedy drain
/// parks the *whole* channel backlog internally (collect_with leaves
/// anything past `max_batch` in the channel), so under saturation the
/// effective admission capacity is the bounded channel plus the parked
/// backlog; [`DrrCollector::backlog`] exposes the parked count.
pub struct DrrCollector<T> {
    queues: VecDeque<KeyQueue<T>>,
    policy: Policy,
    disconnected: bool,
}

impl<T: Timestamped + Keyed> DrrCollector<T> {
    pub fn new(policy: Policy) -> DrrCollector<T> {
        DrrCollector { queues: VecDeque::new(), policy, disconnected: false }
    }

    /// Items parked in per-key queues (admitted but not yet dispatched).
    pub fn backlog(&self) -> usize {
        self.queues.iter().map(|q| q.items.len()).sum()
    }

    /// Collect the next single-tenant batch. Returns `None` once admission
    /// is disconnected and every queue is drained; partial queues at
    /// disconnection are still flushed (admitted requests always complete).
    ///
    /// Expired items are silently dropped on this path (their reply channel
    /// closes); callers whose items carry deadlines should use
    /// [`DrrCollector::next_with`] and reply typed `Expired` from the sink.
    pub fn next(&mut self, rx: &Receiver<T>, stats: &mut CollectStats) -> Option<Batch<T>> {
        self.next_with(rx, stats, &mut |_| {})
    }

    /// [`DrrCollector::next`] with a shed sink: every item shed for an
    /// expired deadline is handed to `on_shed` the moment the collector
    /// notices it (admission drain or batch formation), so typed `Expired`
    /// replies go out promptly even when no batch is ready.
    pub fn next_with<F: FnMut(T)>(
        &mut self,
        rx: &Receiver<T>,
        stats: &mut CollectStats,
        on_shed: &mut F,
    ) -> Option<Batch<T>> {
        loop {
            self.drain(rx, stats, on_shed);
            if let Some(b) = self.dispatch(stats, false, on_shed) {
                return Some(b);
            }
            if self.disconnected {
                return self.dispatch(stats, true, on_shed);
            }
            match self.earliest_oldest() {
                // nothing parked: block for the first item
                None => match rx.recv() {
                    Ok(item) => self.enqueue(item, stats, on_shed),
                    Err(_) => self.disconnected = true,
                },
                // wait until the earliest queue head exhausts its budget
                Some(oldest) => {
                    let wait = self.policy.max_wait.saturating_sub(oldest.elapsed());
                    match rx.recv_timeout(wait) {
                        Ok(item) => self.enqueue(item, stats, on_shed),
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => self.disconnected = true,
                    }
                }
            }
        }
    }

    /// Park everything currently admitted (greedy, like `collect_with`'s
    /// drain — queued requests join batches without waiting).
    fn drain<F: FnMut(T)>(&mut self, rx: &Receiver<T>, stats: &mut CollectStats, on_shed: &mut F) {
        loop {
            match rx.try_recv() {
                Ok(item) => self.enqueue(item, stats, on_shed),
                Err(TryRecvError::Empty) => return,
                Err(TryRecvError::Disconnected) => {
                    self.disconnected = true;
                    return;
                }
            }
        }
    }

    /// Linear scan over *active* keys (tenants with parked work) — small by
    /// construction; the registry may hold many tenants but only those with
    /// a backlog on this shard appear here. Items already past their
    /// deadline go straight to the shed sink instead of parking.
    fn enqueue<F: FnMut(T)>(&mut self, item: T, stats: &mut CollectStats, on_shed: &mut F) {
        let has_deadline = item.deadline().is_some();
        if has_deadline && is_expired(&item, Instant::now()) {
            stats.shed_expired += 1;
            on_shed(item);
            return;
        }
        let key = item.key();
        match self.queues.iter_mut().find(|q| q.key == key) {
            Some(q) => {
                q.has_deadlines |= has_deadline;
                q.items.push_back(item);
            }
            None => {
                let mut items = VecDeque::new();
                items.push_back(item);
                self.queues.push_back(KeyQueue {
                    key,
                    items,
                    deficit: 0,
                    has_deadlines: has_deadline,
                });
            }
        }
    }

    fn earliest_oldest(&self) -> Option<Instant> {
        self.queues.iter().filter_map(|q| q.items.front().map(Timestamped::submitted)).min()
    }

    /// Dispatch from the first ready queue in rotation order. `flush`
    /// overrides readiness (shutdown: everything parked must complete).
    /// Expired items are shed to `on_shed` before the batch forms; a queue
    /// whose entire backlog expired yields to the next ready queue.
    fn dispatch<F: FnMut(T)>(
        &mut self,
        stats: &mut CollectStats,
        flush: bool,
        on_shed: &mut F,
    ) -> Option<Batch<T>> {
        let cap = self.policy.max_batch.max(1);
        loop {
            let idx = self.queues.iter().position(|q| {
                flush
                    || q.items.len() >= cap
                    || q.items
                        .front()
                        .is_some_and(|t| t.submitted().elapsed() >= self.policy.max_wait)
            })?;
            let mut q = self.queues.remove(idx).expect("position is in range");
            // deadline shedding at formation time: expired items never
            // enter a batch (deadline-free queues skip the scan entirely)
            if q.has_deadlines {
                let now = Instant::now();
                let before = q.items.len();
                let mut kept = VecDeque::with_capacity(before);
                for item in q.items.drain(..) {
                    if is_expired(&item, now) {
                        on_shed(item);
                    } else {
                        kept.push_back(item);
                    }
                }
                stats.shed_expired += (before - kept.len()) as u64;
                q.items = kept;
                if q.items.is_empty() {
                    continue; // whole backlog expired; try the next queue
                }
            }
            let quantum = self.policy.quantum();
            // deficit is capped at one batch: a queue skipped while not
            // ready must not accumulate an unbounded burst allowance
            q.deficit = (q.deficit + quantum).min(cap);
            let fill = q.items.len();
            let take = fill.min(cap).min(q.deficit);
            q.deficit -= take;
            let items: Vec<T> = q.items.drain(..take).collect();
            let reason = if flush {
                FlushReason::Disconnect
            } else if fill >= cap {
                FlushReason::Full
            } else {
                FlushReason::Timeout
            };
            if q.items.is_empty() {
                q.deficit = 0; // a drained tenant starts fresh next backlog
            } else {
                self.queues.push_back(q);
            }
            return Some(stats.record(reason, Batch::new(items)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn dispatches_when_full() {
        let p = Policy { max_batch: 8, max_wait: Duration::from_micros(100), ..Default::default() };
        assert_eq!(p.decide(8, Duration::ZERO), Decision::Dispatch);
        assert_eq!(p.decide(9, Duration::ZERO), Decision::Dispatch);
    }

    #[test]
    fn dispatches_on_timeout() {
        let p = Policy { max_batch: 8, max_wait: Duration::from_micros(100), ..Default::default() };
        assert_eq!(p.decide(3, Duration::from_micros(100)), Decision::Dispatch);
        assert_eq!(p.decide(3, Duration::from_micros(150)), Decision::Dispatch);
    }

    #[test]
    fn waits_otherwise() {
        let p = Policy { max_batch: 8, max_wait: Duration::from_micros(100), ..Default::default() };
        match p.decide(3, Duration::from_micros(40)) {
            Decision::Wait(d) => assert_eq!(d, Duration::from_micros(60)),
            other => panic!("expected Wait, got {other:?}"),
        }
        // empty batch: full wait budget
        match p.decide(0, Duration::ZERO) {
            Decision::Wait(d) => assert_eq!(d, Duration::from_micros(100)),
            other => panic!("expected Wait, got {other:?}"),
        }
    }

    #[test]
    fn expected_latency_monotone_in_batch() {
        let lam = 1e6; // 1M rps
        let wait = Duration::from_micros(200);
        let small = Policy { max_batch: 4, max_wait: wait, ..Default::default() };
        let big = Policy { max_batch: 256, max_wait: wait, ..Default::default() };
        assert!(small.expected_added_latency_us(lam) <= big.expected_added_latency_us(lam));
    }

    #[test]
    fn batch_tracks_oldest_submission() {
        let now = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        let later = Instant::now();
        let b = Batch::new(vec![later, now, later]);
        assert_eq!(b.oldest, now);
        assert_eq!(b.len(), 3);
        assert!(!b.is_empty());
    }

    #[test]
    fn collect_honors_max_wait_from_submission_not_collection_start() {
        // a request that aged past max_wait while queued dispatches
        // immediately — the dispatcher must NOT grant it a fresh window
        // (generous margins: correct behavior returns in microseconds, the
        // old bug waits the full 400 ms)
        let p = Policy { max_batch: 8, max_wait: Duration::from_millis(400), ..Default::default() };
        let (tx, rx) = sync_channel::<Instant>(8);
        let submitted = Instant::now();
        std::thread::sleep(Duration::from_millis(450)); // ages in "the queue"
        tx.send(submitted).unwrap();
        let t = Instant::now();
        let batch = collect(&rx, &p).expect("one batch");
        assert_eq!(batch.len(), 1);
        assert!(
            t.elapsed() < Duration::from_millis(200),
            "collect waited a fresh max_wait window ({:?}) for an already-expired request",
            t.elapsed()
        );
    }

    #[test]
    fn collect_fills_full_batches_from_backlog() {
        // 20 queued requests, max_batch 8: two immediate full batches, then
        // a timeout-flushed remainder of 4 (generous margins for loaded
        // CI runners: immediate means microseconds, the timeout is 400 ms)
        let p = Policy { max_batch: 8, max_wait: Duration::from_millis(400), ..Default::default() };
        let (tx, rx) = sync_channel::<Instant>(32);
        let t = Instant::now();
        for _ in 0..20 {
            tx.send(Instant::now()).unwrap();
        }
        assert_eq!(collect(&rx, &p).unwrap().len(), 8);
        assert_eq!(collect(&rx, &p).unwrap().len(), 8);
        assert!(
            t.elapsed() < Duration::from_millis(200),
            "full batches from a backlog must not wait ({:?})",
            t.elapsed()
        );
        let rest = collect(&rx, &p).unwrap();
        assert_eq!(rest.len(), 4);
        assert!(t.elapsed() >= Duration::from_millis(400), "partial batch flushes on timeout");
        drop(tx);
        assert!(collect(&rx, &p).is_none(), "drained + disconnected ends collection");
    }

    #[test]
    fn collect_dispatches_partial_batch_at_disconnect() {
        let p = Policy { max_batch: 8, max_wait: Duration::from_secs(5), ..Default::default() };
        let (tx, rx) = sync_channel::<Instant>(8);
        tx.send(Instant::now()).unwrap();
        tx.send(Instant::now()).unwrap();
        drop(tx);
        // would otherwise wait 5 s: disconnection flushes what was admitted
        let t = Instant::now();
        let mut cs = CollectStats::default();
        let b = collect_with(&rx, &p, &mut cs).expect("partial batch");
        assert_eq!(b.len(), 2);
        assert!(t.elapsed() < Duration::from_secs(1));
        assert!(collect_with(&rx, &p, &mut cs).is_none());
        assert_eq!(cs.batches, 1);
        assert_eq!(cs.items, 2);
        assert_eq!(cs.flush_disconnect, 1, "shutdown flush recorded as such: {cs:?}");
    }

    #[test]
    fn collect_agrees_with_decide_at_every_dispatch() {
        // scripted arrivals; every batch collect() emits must be one that
        // Policy::decide marks Dispatch at the moment of dispatch — the
        // dispatcher loop adds no decision logic of its own
        let p = Policy { max_batch: 4, max_wait: Duration::from_millis(200), ..Default::default() };
        let (tx, rx) = sync_channel::<Instant>(64);
        let producer = std::thread::spawn(move || {
            for _ in 0..3 {
                tx.send(Instant::now()).unwrap();
            }
            std::thread::sleep(Duration::from_millis(50));
            tx.send(Instant::now()).unwrap(); // fills batch 1 to max_batch
            std::thread::sleep(Duration::from_millis(250));
            for _ in 0..5 {
                tx.send(Instant::now()).unwrap(); // batch 2 (full) + batch 3 (1, flushes on timeout)
            }
            std::thread::sleep(Duration::from_millis(500));
            // tx drops here: channel already drained, collect returns None
        });
        let mut lens = Vec::new();
        let mut cs = CollectStats::default();
        while let Some(b) = collect_with(&rx, &p, &mut cs) {
            let age_at_dispatch = b.oldest.elapsed();
            assert_eq!(
                p.decide(b.len(), age_at_dispatch),
                Decision::Dispatch,
                "collect dispatched a batch (len {}, age {age_at_dispatch:?}) the policy would hold",
                b.len()
            );
            lens.push(b.len());
        }
        producer.join().unwrap();
        assert_eq!(lens, vec![4, 4, 1]);
        // per-shard policy state: two full dispatches, one timeout flush
        assert_eq!(cs.batches, 3);
        assert_eq!(cs.items, 9);
        assert_eq!(cs.flush_full, 2, "{cs:?}");
        assert_eq!(cs.flush_timeout, 1, "{cs:?}");
        assert_eq!(cs.flush_disconnect, 0, "{cs:?}");
    }

    // -- deficit-round-robin collection ----------------------------------

    /// Test item: explicit tenant key + submission time.
    #[derive(Clone, Copy, Debug)]
    struct K(u32, Instant);
    impl Timestamped for K {
        fn submitted(&self) -> Instant {
            self.1
        }
    }
    impl Keyed for K {
        fn key(&self) -> u32 {
            self.0
        }
    }

    #[test]
    fn drr_single_key_matches_collect_with_exactly() {
        // the PR-6 degeneration contract: one key => identical batch
        // lengths, flush reasons, and CollectStats as collect_with, for
        // backlogs around and across the max_batch boundary
        let p = Policy { max_batch: 4, max_wait: Duration::from_secs(5), ..Default::default() };
        let fill = |n: usize| {
            let (tx, rx) = sync_channel::<Instant>(64);
            for _ in 0..n {
                tx.send(Instant::now()).unwrap();
            }
            rx // tx drops here: disconnected once drained
        };
        for n in [1usize, 3, 4, 8, 9, 13] {
            let rx = fill(n);
            let mut cs_a = CollectStats::default();
            let mut lens_a = Vec::new();
            while let Some(b) = collect_with(&rx, &p, &mut cs_a) {
                lens_a.push(b.len());
            }
            let rx = fill(n);
            let mut cs_b = CollectStats::default();
            let mut drr = DrrCollector::new(p);
            let mut lens_b = Vec::new();
            while let Some(b) = drr.next(&rx, &mut cs_b) {
                lens_b.push(b.len());
            }
            assert_eq!(lens_a, lens_b, "n={n}");
            assert_eq!(cs_a, cs_b, "n={n}");
            assert_eq!(drr.backlog(), 0, "n={n}");
        }
    }

    #[test]
    fn drr_prevents_heavy_key_starving_light() {
        // 100 heavy requests queued ahead of 4 light ones: the light
        // tenant's batch goes out on the second rotation visit, not behind
        // the heavy tenant's 25 batches — and batches never mix keys
        let p = Policy { max_batch: 4, max_wait: Duration::from_secs(5), ..Default::default() };
        let (tx, rx) = sync_channel::<K>(256);
        let now = Instant::now();
        for _ in 0..100 {
            tx.send(K(0, now)).unwrap();
        }
        for _ in 0..4 {
            tx.send(K(1, now)).unwrap();
        }
        drop(tx);
        let mut cs = CollectStats::default();
        let mut drr = DrrCollector::new(p);
        let mut order = Vec::new();
        while let Some(b) = drr.next(&rx, &mut cs) {
            let key = b.items[0].0;
            assert!(b.items.iter().all(|k| k.0 == key), "batch mixes tenants");
            order.push((key, b.len()));
        }
        let light_pos = order.iter().position(|&(k, _)| k == 1).expect("light dispatched");
        assert!(light_pos <= 1, "light tenant starved behind the heavy backlog: {order:?}");
        let sum =
            |key: u32| order.iter().filter(|&&(k, _)| k == key).map(|&(_, n)| n).sum::<usize>();
        assert_eq!(sum(0), 100);
        assert_eq!(sum(1), 4);
        assert_eq!(cs.items, 104);
        assert_eq!(cs.batches, order.len() as u64);
    }

    /// Test item: key 0, explicit submission time + optional deadline.
    #[derive(Clone, Copy, Debug)]
    struct D(Instant, Option<Instant>);
    impl Timestamped for D {
        fn submitted(&self) -> Instant {
            self.0
        }
        fn deadline(&self) -> Option<Instant> {
            self.1
        }
    }
    impl Keyed for D {
        fn key(&self) -> u32 {
            0
        }
    }

    #[test]
    fn drr_sheds_already_expired_items_and_batches_the_rest() {
        // items expired before admission are shed at the drain (handed to
        // the sink, counted in shed_expired, never parked); live items —
        // with or without a future deadline — batch normally
        let p = Policy { max_batch: 4, max_wait: Duration::from_secs(5), ..Default::default() };
        let (tx, rx) = sync_channel::<D>(64);
        let now = Instant::now();
        let expired = Some(now - Duration::from_millis(5));
        let live = Some(now + Duration::from_secs(10));
        tx.send(D(now, expired)).unwrap();
        tx.send(D(now, live)).unwrap();
        tx.send(D(now, None)).unwrap();
        tx.send(D(now, expired)).unwrap();
        tx.send(D(now, live)).unwrap();
        tx.send(D(now, live)).unwrap();
        drop(tx);
        let mut cs = CollectStats::default();
        let mut drr = DrrCollector::new(p);
        let mut shed = Vec::new();
        let b = drr.next_with(&rx, &mut cs, &mut |it| shed.push(it)).expect("one full batch");
        assert_eq!(b.len(), 4, "the four live items form one full batch");
        assert_eq!(shed.len(), 2);
        assert_eq!(cs.shed_expired, 2);
        assert_eq!(cs.items, 4, "shed items are not counted as batched items");
        assert!(drr.next_with(&rx, &mut cs, &mut |it| shed.push(it)).is_none());
        assert_eq!(shed.len(), 2);
    }

    #[test]
    fn drr_sheds_items_that_expire_while_parked_at_formation_time() {
        // items live at admission but expired by the time their queue is
        // ready never enter a batch: the formation-time scan sheds them
        // (deadline 25 ms, formation gated by max_wait 50 ms)
        let p = Policy { max_batch: 4, max_wait: Duration::from_millis(50), ..Default::default() };
        let (tx, rx) = sync_channel::<D>(8);
        let now = Instant::now();
        let deadline = Some(now + Duration::from_millis(25));
        let producer = std::thread::spawn(move || {
            tx.send(D(now, deadline)).unwrap();
            tx.send(D(now, deadline)).unwrap();
            std::thread::sleep(Duration::from_millis(150));
            // tx drops here: queues already shed, collection ends
        });
        let mut cs = CollectStats::default();
        let mut drr = DrrCollector::new(p);
        let mut shed = Vec::new();
        let got = drr.next_with(&rx, &mut cs, &mut |it| shed.push(it));
        producer.join().unwrap();
        assert!(got.is_none(), "every item expired; no batch may form");
        assert_eq!(shed.len(), 2);
        assert_eq!(cs.shed_expired, 2);
        assert_eq!(cs.batches, 0);
        assert_eq!(drr.backlog(), 0);
    }

    #[test]
    fn drr_custom_quantum_interleaves_below_batch_size() {
        // quantum 2 under saturation: tenants alternate in 2-item grants
        // even though both could fill 4-item batches
        let p = Policy { max_batch: 4, max_wait: Duration::ZERO, drr_quantum: 2 };
        let (tx, rx) = sync_channel::<K>(64);
        let now = Instant::now();
        for _ in 0..8 {
            tx.send(K(0, now)).unwrap();
        }
        for _ in 0..8 {
            tx.send(K(1, now)).unwrap();
        }
        drop(tx);
        let mut cs = CollectStats::default();
        let mut drr = DrrCollector::new(p);
        let mut order = Vec::new();
        while let Some(b) = drr.next(&rx, &mut cs) {
            order.push((b.items[0].0, b.len()));
        }
        assert_eq!(
            order,
            vec![(0, 2), (1, 2), (0, 2), (1, 2), (0, 2), (1, 2), (0, 2), (1, 2)],
            "quantum-sized grants must alternate tenants"
        );
    }
}
