//! Dynamic-batching policy **and** the dispatcher's batch-collection loop.
//!
//! [`Policy::decide`] is the single source of dispatch decisions (fill to
//! `max_batch`, flush once the *oldest request* has waited `max_wait`);
//! [`collect_with`] is the loop each of the coordinator's per-shard
//! dispatcher threads runs to turn its admission channel into [`Batch`]es,
//! consulting `decide` before every wait and recording per-shard policy
//! state into a [`CollectStats`] (how many batches, how many dispatched
//! full vs flushed on timeout — the observable a shard's batching health
//! is judged by). Both are thread-free and unit-testable: `collect_with`
//! only needs a channel of [`Timestamped`] items, so the policy/dispatcher
//! equivalence is asserted directly in tests instead of being an emergent
//! property of the worker pool.
//!
//! Age is always measured from each request's *submission* time, never
//! from when collection started: a request that queued behind a busy
//! service is dispatched as soon as the dispatcher sees it has already
//! spent its `max_wait` budget, instead of waiting a second full window.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Decision state for one forming batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decision {
    /// Keep waiting for more requests.
    Wait(Duration),
    /// Dispatch now.
    Dispatch,
}

/// Dispatch policy: fill to `max_batch` or flush after `max_wait`.
#[derive(Clone, Copy, Debug)]
pub struct Policy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Policy {
    /// Given the current batch fill and the age of its oldest request,
    /// decide whether to dispatch.
    pub fn decide(&self, fill: usize, oldest_age: Duration) -> Decision {
        if fill >= self.max_batch {
            return Decision::Dispatch;
        }
        if fill > 0 && oldest_age >= self.max_wait {
            return Decision::Dispatch;
        }
        Decision::Wait(self.max_wait.saturating_sub(oldest_age))
    }

    /// Expected batching latency added to a request arriving at a Poisson
    /// rate `lambda_rps` (analytic model used by the tuning bench): the
    /// batch dispatches after min(time to fill, max_wait).
    pub fn expected_added_latency_us(&self, lambda_rps: f64) -> f64 {
        if lambda_rps <= 0.0 {
            return self.max_wait.as_secs_f64() * 1e6;
        }
        let fill_time = (self.max_batch as f64 - 1.0) / lambda_rps;
        fill_time.min(self.max_wait.as_secs_f64()) * 0.5 * 1e6
    }
}

/// Anything carrying a submission timestamp can be collected into batches.
pub trait Timestamped {
    fn submitted(&self) -> Instant;
}

/// Bare timestamps batch as themselves (tests and simulations).
impl Timestamped for Instant {
    fn submitted(&self) -> Instant {
        *self
    }
}

/// One formed batch: the unit of work handed from the dispatcher to the
/// executor pool.
#[derive(Debug)]
pub struct Batch<T> {
    pub items: Vec<T>,
    /// Earliest submission time across `items`.
    pub oldest: Instant,
}

impl<T: Timestamped> Batch<T> {
    /// Wrap a non-empty item list, computing the oldest submission time.
    /// Convenience for tests and external producers; [`collect`] builds
    /// batches directly from its incrementally-tracked oldest timestamp.
    pub fn new(items: Vec<T>) -> Batch<T> {
        let oldest = items
            .iter()
            .map(|t| t.submitted())
            .min()
            .expect("batch must be non-empty");
        Batch { items, oldest }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Why [`collect_with`] dispatched a batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// Filled to `max_batch`.
    Full,
    /// Oldest request exhausted its `max_wait` budget.
    Timeout,
    /// Admission disconnected (shutdown) with a partial batch in hand.
    Disconnect,
}

/// Per-shard collection state: each dispatcher owns one and publishes it
/// into its shard's service statistics, so a shard whose batches always
/// flush on timeout (underfed) is distinguishable from one dispatching
/// full (saturated).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CollectStats {
    pub batches: u64,
    pub items: u64,
    pub flush_full: u64,
    pub flush_timeout: u64,
    pub flush_disconnect: u64,
}

impl CollectStats {
    fn record<T>(&mut self, reason: FlushReason, batch: Batch<T>) -> Batch<T> {
        self.batches += 1;
        self.items += batch.len() as u64;
        match reason {
            FlushReason::Full => self.flush_full += 1,
            FlushReason::Timeout => self.flush_timeout += 1,
            FlushReason::Disconnect => self.flush_disconnect += 1,
        }
        batch
    }
}

/// Collect the next batch from `rx`, consulting [`Policy::decide`] before
/// every wait and recording the dispatch into `stats`. Returns `None` once
/// the channel is disconnected and fully drained (service shutdown); a
/// partial batch in hand at disconnection is still dispatched so admitted
/// requests always complete.
///
/// A backlog is drained greedily first: requests already queued fill the
/// batch to `max_batch` without any waiting, so sustained load produces
/// full batches regardless of how old the queue head is.
pub fn collect_with<T: Timestamped>(
    rx: &Receiver<T>,
    policy: &Policy,
    stats: &mut CollectStats,
) -> Option<Batch<T>> {
    let first = rx.recv().ok()?;
    let mut oldest = first.submitted();
    let mut items = vec![first];
    loop {
        // greedy drain: whatever is already queued joins for free
        while items.len() < policy.max_batch {
            match rx.try_recv() {
                Ok(t) => {
                    oldest = oldest.min(t.submitted());
                    items.push(t);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    return Some(stats.record(FlushReason::Disconnect, Batch { items, oldest }))
                }
            }
        }
        match policy.decide(items.len(), oldest.elapsed()) {
            Decision::Dispatch => {
                let reason = if items.len() >= policy.max_batch {
                    FlushReason::Full
                } else {
                    FlushReason::Timeout
                };
                return Some(stats.record(reason, Batch { items, oldest }));
            }
            Decision::Wait(d) => match rx.recv_timeout(d) {
                Ok(t) => {
                    oldest = oldest.min(t.submitted());
                    items.push(t);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Some(stats.record(FlushReason::Timeout, Batch { items, oldest }))
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Some(stats.record(FlushReason::Disconnect, Batch { items, oldest }))
                }
            },
        }
    }
}

/// [`collect_with`] without the per-shard bookkeeping (tests, simulations,
/// embedders that track their own).
pub fn collect<T: Timestamped>(rx: &Receiver<T>, policy: &Policy) -> Option<Batch<T>> {
    collect_with(rx, policy, &mut CollectStats::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn dispatches_when_full() {
        let p = Policy { max_batch: 8, max_wait: Duration::from_micros(100) };
        assert_eq!(p.decide(8, Duration::ZERO), Decision::Dispatch);
        assert_eq!(p.decide(9, Duration::ZERO), Decision::Dispatch);
    }

    #[test]
    fn dispatches_on_timeout() {
        let p = Policy { max_batch: 8, max_wait: Duration::from_micros(100) };
        assert_eq!(p.decide(3, Duration::from_micros(100)), Decision::Dispatch);
        assert_eq!(p.decide(3, Duration::from_micros(150)), Decision::Dispatch);
    }

    #[test]
    fn waits_otherwise() {
        let p = Policy { max_batch: 8, max_wait: Duration::from_micros(100) };
        match p.decide(3, Duration::from_micros(40)) {
            Decision::Wait(d) => assert_eq!(d, Duration::from_micros(60)),
            other => panic!("expected Wait, got {other:?}"),
        }
        // empty batch: full wait budget
        match p.decide(0, Duration::ZERO) {
            Decision::Wait(d) => assert_eq!(d, Duration::from_micros(100)),
            other => panic!("expected Wait, got {other:?}"),
        }
    }

    #[test]
    fn expected_latency_monotone_in_batch() {
        let lam = 1e6; // 1M rps
        let small = Policy { max_batch: 4, max_wait: Duration::from_micros(200) };
        let big = Policy { max_batch: 256, max_wait: Duration::from_micros(200) };
        assert!(small.expected_added_latency_us(lam) <= big.expected_added_latency_us(lam));
    }

    #[test]
    fn batch_tracks_oldest_submission() {
        let now = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        let later = Instant::now();
        let b = Batch::new(vec![later, now, later]);
        assert_eq!(b.oldest, now);
        assert_eq!(b.len(), 3);
        assert!(!b.is_empty());
    }

    #[test]
    fn collect_honors_max_wait_from_submission_not_collection_start() {
        // a request that aged past max_wait while queued dispatches
        // immediately — the dispatcher must NOT grant it a fresh window
        // (generous margins: correct behavior returns in microseconds, the
        // old bug waits the full 400 ms)
        let p = Policy { max_batch: 8, max_wait: Duration::from_millis(400) };
        let (tx, rx) = sync_channel::<Instant>(8);
        let submitted = Instant::now();
        std::thread::sleep(Duration::from_millis(450)); // ages in "the queue"
        tx.send(submitted).unwrap();
        let t = Instant::now();
        let batch = collect(&rx, &p).expect("one batch");
        assert_eq!(batch.len(), 1);
        assert!(
            t.elapsed() < Duration::from_millis(200),
            "collect waited a fresh max_wait window ({:?}) for an already-expired request",
            t.elapsed()
        );
    }

    #[test]
    fn collect_fills_full_batches_from_backlog() {
        // 20 queued requests, max_batch 8: two immediate full batches, then
        // a timeout-flushed remainder of 4 (generous margins for loaded
        // CI runners: immediate means microseconds, the timeout is 400 ms)
        let p = Policy { max_batch: 8, max_wait: Duration::from_millis(400) };
        let (tx, rx) = sync_channel::<Instant>(32);
        let t = Instant::now();
        for _ in 0..20 {
            tx.send(Instant::now()).unwrap();
        }
        assert_eq!(collect(&rx, &p).unwrap().len(), 8);
        assert_eq!(collect(&rx, &p).unwrap().len(), 8);
        assert!(
            t.elapsed() < Duration::from_millis(200),
            "full batches from a backlog must not wait ({:?})",
            t.elapsed()
        );
        let rest = collect(&rx, &p).unwrap();
        assert_eq!(rest.len(), 4);
        assert!(t.elapsed() >= Duration::from_millis(400), "partial batch flushes on timeout");
        drop(tx);
        assert!(collect(&rx, &p).is_none(), "drained + disconnected ends collection");
    }

    #[test]
    fn collect_dispatches_partial_batch_at_disconnect() {
        let p = Policy { max_batch: 8, max_wait: Duration::from_secs(5) };
        let (tx, rx) = sync_channel::<Instant>(8);
        tx.send(Instant::now()).unwrap();
        tx.send(Instant::now()).unwrap();
        drop(tx);
        // would otherwise wait 5 s: disconnection flushes what was admitted
        let t = Instant::now();
        let mut cs = CollectStats::default();
        let b = collect_with(&rx, &p, &mut cs).expect("partial batch");
        assert_eq!(b.len(), 2);
        assert!(t.elapsed() < Duration::from_secs(1));
        assert!(collect_with(&rx, &p, &mut cs).is_none());
        assert_eq!(cs.batches, 1);
        assert_eq!(cs.items, 2);
        assert_eq!(cs.flush_disconnect, 1, "shutdown flush recorded as such: {cs:?}");
    }

    #[test]
    fn collect_agrees_with_decide_at_every_dispatch() {
        // scripted arrivals; every batch collect() emits must be one that
        // Policy::decide marks Dispatch at the moment of dispatch — the
        // dispatcher loop adds no decision logic of its own
        let p = Policy { max_batch: 4, max_wait: Duration::from_millis(200) };
        let (tx, rx) = sync_channel::<Instant>(64);
        let producer = std::thread::spawn(move || {
            for _ in 0..3 {
                tx.send(Instant::now()).unwrap();
            }
            std::thread::sleep(Duration::from_millis(50));
            tx.send(Instant::now()).unwrap(); // fills batch 1 to max_batch
            std::thread::sleep(Duration::from_millis(250));
            for _ in 0..5 {
                tx.send(Instant::now()).unwrap(); // batch 2 (full) + batch 3 (1, flushes on timeout)
            }
            std::thread::sleep(Duration::from_millis(500));
            // tx drops here: channel already drained, collect returns None
        });
        let mut lens = Vec::new();
        let mut cs = CollectStats::default();
        while let Some(b) = collect_with(&rx, &p, &mut cs) {
            let age_at_dispatch = b.oldest.elapsed();
            assert_eq!(
                p.decide(b.len(), age_at_dispatch),
                Decision::Dispatch,
                "collect dispatched a batch (len {}, age {age_at_dispatch:?}) the policy would hold",
                b.len()
            );
            lens.push(b.len());
        }
        producer.join().unwrap();
        assert_eq!(lens, vec![4, 4, 1]);
        // per-shard policy state: two full dispatches, one timeout flush
        assert_eq!(cs.batches, 3);
        assert_eq!(cs.items, 9);
        assert_eq!(cs.flush_full, 2, "{cs:?}");
        assert_eq!(cs.flush_timeout, 1, "{cs:?}");
        assert_eq!(cs.flush_disconnect, 0, "{cs:?}");
    }
}
