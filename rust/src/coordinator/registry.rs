//! Multi-tenant model registry: N independently loaded checkpoints served
//! by ONE coordinator plane.
//!
//! ```text
//!                 ModelRegistry (name -> ModelId -> Tenant)
//!   "default" (id 0) ─ Tenant { NetlistCell ─ ProgramCell @ level, quota,
//!   "ft-a"    (id 1) ─ Tenant {   per-tenant counters (survive unload),
//!   "ft-b"    (id 2) ─ Tenant {   optional Canary: 2nd checkpoint, x% of
//!        ...                      rows, live argmax agreement }
//!          │
//!          └── reintern(): cross-tenant table interning — identical tables
//!              across fine-tuned variants materialize ONCE in a shared
//!              arena ([`InternStats`]: shared vs private bytes), programs
//!              republished in place via [`ProgramCell::install`]
//! ```
//!
//! Each tenant owns its swappable netlist ([`NetlistCell`]) and compiled
//! program cache ([`ProgramCell`]) **pinned at the tenant's own
//! [`OptLevel`]** — a registry can serve one tenant at `Full` next to an
//! A/B twin at `None`. Tenants are resolved once at admission into an
//! `Arc<Tenant>` carried by the request, so executors never touch the
//! registry lock and an unloaded tenant's snapshot stays alive exactly
//! until its in-flight work drains.
//!
//! Counters are `Arc`-shared with the [`Tenant`] and moved to a retired
//! list on unload, so a stats snapshot taken after `unload` still accounts
//! for every request the plane ever completed (totals stay consistent).
//!
//! Each tenant also carries a lock-free **panic circuit breaker**: when
//! [`QUARANTINE_TRIP`] consecutive batches of a tenant poison an executor
//! (a bad canary or hot-swapped checkpoint), the tenant is quarantined —
//! its admissions come back as typed `Quarantined` rejections so
//! co-tenants keep serving — until the [`QUARANTINE_WINDOW`] elapses
//! (timed half-open re-probe) or an operator calls [`Tenant::probe`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::engine::{
    intern_tables, intern_tables_lossy, CompiledProgram, InternStats, OptLevel, ProgramCell,
};
use crate::netlist::hotswap::NetlistCell;
use crate::netlist::Netlist;
use crate::util::Reservoir;

use super::LATENCY_RESERVOIR;

/// Dense tenant identifier, assigned at load time in load order. Threads
/// through [`super::Request`] and the batcher's fairness key; the wire
/// layer maps names to ids once per frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId(u32);

impl ModelId {
    /// The first tenant loaded into a registry. Single-tenant services
    /// (and wire frames without a `model` field) route here, which is what
    /// makes the N=1 registry degenerate to the pre-registry plane.
    pub const DEFAULT: ModelId = ModelId(0);

    pub fn raw(self) -> u32 {
        self.0
    }

    /// Construct from a raw id (wire plumbing and tests; resolution still
    /// goes through the registry, unknown ids are refused at admission).
    pub fn from_raw(raw: u32) -> ModelId {
        ModelId(raw)
    }
}

impl std::fmt::Display for ModelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Per-tenant counters, `Arc`-shared between the live [`Tenant`] and the
/// registry's retired list so unload never loses accounting. Writers
/// follow one global ordering rule: **tenant counter first, then the
/// service-wide counter** — paired with readers doing the opposite
/// ([`super::Service::stats`] reads globals first), a concurrent snapshot
/// always observes `sum(per-tenant) >= global`, so the self-consistency
/// debug assertion is race-free (exact equality holds quiescent).
pub struct TenantCounters {
    pub admitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub dropped: AtomicU64,
    /// Admissions refused because the tenant's in-flight quota was full
    /// (counted here AND in the service-wide total, never in `rejected`).
    pub quota_drops: AtomicU64,
    /// Requests currently inside the plane (admitted, not yet replied);
    /// maintained by [`InflightGuard`], gates the quota.
    pub inflight: AtomicU64,
    pub batches: AtomicU64,
    pub batch_items: AtomicU64,
    /// Rows routed to the canary checkpoint.
    pub canary_rows: AtomicU64,
    /// Canary rows whose argmax agreed with the primary checkpoint.
    pub canary_agree: AtomicU64,
    /// Requests answered with a typed `Failed` because their batch
    /// poisoned an executor (panic caught by supervision).
    pub failed: AtomicU64,
    /// Requests shed at batch formation because their deadline had
    /// already expired (typed `Expired` reply).
    pub shed_expired: AtomicU64,
    /// Batches of this tenant that panicked inside an executor.
    pub panics: AtomicU64,
    /// Admissions refused while the tenant was quarantined by the panic
    /// circuit breaker (typed `Quarantined` rejection).
    pub quarantine_drops: AtomicU64,
    /// Per-tenant latency reservoir (seconds, like the service-wide one).
    pub latencies: Mutex<Reservoir>,
}

impl TenantCounters {
    fn new() -> TenantCounters {
        TenantCounters {
            admitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            quota_drops: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_items: AtomicU64::new(0),
            canary_rows: AtomicU64::new(0),
            canary_agree: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            shed_expired: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            quarantine_drops: AtomicU64::new(0),
            latencies: Mutex::new(Reservoir::new(LATENCY_RESERVOIR)),
        }
    }

    fn snapshot(&self, name: &str, id: ModelId, retired: bool) -> TenantStats {
        let [p50, p90, p99] = self.latencies.lock().unwrap().p50_p90_p99();
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batch_items.load(Ordering::Relaxed);
        let canary_rows = self.canary_rows.load(Ordering::Relaxed);
        let canary_agree = self.canary_agree.load(Ordering::Relaxed);
        TenantStats {
            name: name.to_string(),
            id: id.raw(),
            admitted: self.admitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            quota_drops: self.quota_drops.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 { 0.0 } else { items as f64 / batches as f64 },
            latency_p50_us: p50 * 1e6,
            latency_p90_us: p90 * 1e6,
            latency_p99_us: p99 * 1e6,
            canary_rows,
            canary_agree,
            canary_agreement: if canary_rows == 0 {
                0.0
            } else {
                canary_agree as f64 / canary_rows as f64
            },
            failed: self.failed.load(Ordering::Relaxed),
            shed_expired: self.shed_expired.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            quarantine_drops: self.quarantine_drops.load(Ordering::Relaxed),
            quarantined: false,
            input_width: 0,
            retired,
        }
    }
}

/// One tenant's statistics snapshot (carried in
/// [`super::ServiceStats::per_tenant`]).
#[derive(Clone, Debug, Default)]
pub struct TenantStats {
    pub name: String,
    pub id: u32,
    pub admitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub dropped: u64,
    pub quota_drops: u64,
    pub inflight: u64,
    /// Single-tenant batches formed for this tenant by the DRR dispatchers.
    pub batches: u64,
    pub mean_batch: f64,
    pub latency_p50_us: f64,
    pub latency_p90_us: f64,
    pub latency_p99_us: f64,
    pub canary_rows: u64,
    pub canary_agree: u64,
    /// Live argmax agreement fraction (`0.0` before any canary row).
    pub canary_agreement: f64,
    /// Requests failed by supervised executor panics (typed `Failed`).
    pub failed: u64,
    /// Requests shed already-expired at batch formation (typed `Expired`).
    pub shed_expired: u64,
    /// Batches of this tenant that poisoned an executor.
    pub panics: u64,
    /// Admissions refused while quarantined (typed `Quarantined`).
    pub quarantine_drops: u64,
    /// Breaker state at snapshot time: the tenant is currently refusing
    /// admissions (its quarantine window has not elapsed).
    pub quarantined: bool,
    /// Current model input width (0 for retired tenants) — advertised on
    /// the wire so multi-model load generators can synthesize rows without
    /// a local checkpoint per tenant.
    pub input_width: u64,
    /// Tenant was unloaded; counters are frozen history.
    pub retired: bool,
}

/// RAII in-flight slot: decrements the tenant's `inflight` gauge when the
/// request leaves the plane — completed, dropped, rejected after a failed
/// spill, or discarded by shutdown. Held inside the queued request itself
/// so every exit path is covered by `Drop`.
pub struct InflightGuard(Arc<TenantCounters>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// A second checkpoint shadowing one tenant: `percent`% of the tenant's
/// rows are answered by this program instead of the primary, and every
/// such row's argmax is compared against the primary's (which still runs
/// for the whole batch) into the tenant's agreement counters.
pub struct Canary {
    cell: Arc<NetlistCell>,
    programs: Arc<ProgramCell>,
    percent: u32,
    /// Global row sequence. Row k is canaried iff `k % 100 < percent`, so
    /// the first N rows contain **exactly** `N * percent / 100` canary
    /// rows (N a multiple of 100) regardless of batching or executor
    /// interleaving — deterministic accounting under concurrency.
    seq: AtomicU64,
}

impl Canary {
    pub fn cell(&self) -> &Arc<NetlistCell> {
        &self.cell
    }

    pub fn programs(&self) -> &Arc<ProgramCell> {
        &self.programs
    }

    pub fn percent(&self) -> u32 {
        self.percent
    }

    /// Claim the next row sequence number and decide canary membership.
    pub fn take_row(&self) -> bool {
        self.seq.fetch_add(1, Ordering::Relaxed) % 100 < self.percent as u64
    }
}

/// Consecutive poisoned batches that trip a tenant's circuit breaker
/// (override per tenant via [`Tenant::quarantine_policy`]).
pub const QUARANTINE_TRIP: u32 = 3;

/// How long a tripped breaker refuses admissions before the timed
/// half-open re-probe lets traffic through again.
pub const QUARANTINE_WINDOW: Duration = Duration::from_millis(250);

/// Per-tenant panic circuit breaker. All-atomic so the healthy admission
/// fast path is a single relaxed load (`until_us == 0`); timestamps are
/// microseconds since the tenant's load instant so they fit an atomic.
struct Breaker {
    epoch: Instant,
    /// Consecutive poisoned batches; any clean batch resets it.
    strikes: AtomicU32,
    /// Refuse admissions until this many µs past `epoch`; `0` = closed
    /// (healthy — the only state a tenant that never panicked ever sees).
    until_us: AtomicU64,
    trip: AtomicU32,
    window_us: AtomicU64,
    /// Times the breaker tripped (monotonic, for stats and tests).
    trips: AtomicU64,
}

impl Breaker {
    fn new() -> Breaker {
        Breaker {
            epoch: Instant::now(),
            strikes: AtomicU32::new(0),
            until_us: AtomicU64::new(0),
            trip: AtomicU32::new(QUARANTINE_TRIP),
            window_us: AtomicU64::new(QUARANTINE_WINDOW.as_micros() as u64),
            trips: AtomicU64::new(0),
        }
    }
}

/// One loaded checkpoint: swappable netlist, compiled-program cache pinned
/// at the tenant's level, quota, counters, optional canary.
pub struct Tenant {
    id: ModelId,
    name: String,
    cell: Arc<NetlistCell>,
    programs: Arc<ProgramCell>,
    level: OptLevel,
    /// Max in-flight requests admitted for this tenant; `0` = unlimited.
    quota: u64,
    canary: RwLock<Option<Arc<Canary>>>,
    counters: Arc<TenantCounters>,
    breaker: Breaker,
}

impl Tenant {
    pub fn id(&self) -> ModelId {
        self.id
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn level(&self) -> OptLevel {
        self.level
    }

    pub fn quota(&self) -> u64 {
        self.quota
    }

    pub fn cell(&self) -> &Arc<NetlistCell> {
        &self.cell
    }

    pub fn programs(&self) -> &Arc<ProgramCell> {
        &self.programs
    }

    pub fn counters(&self) -> &Arc<TenantCounters> {
        &self.counters
    }

    /// Input width of the tenant's current snapshot.
    pub fn input_width(&self) -> usize {
        self.cell.input_width()
    }

    /// The canary active right now (batch-consistent: executors snapshot
    /// once per batch).
    pub fn canary_snapshot(&self) -> Option<Arc<Canary>> {
        self.canary.read().unwrap().clone()
    }

    /// Claim an in-flight slot, refusing when the quota is full. The
    /// increment-then-check shape makes concurrent admits race-free: the
    /// loser of an over-admit race backs its increment out.
    pub fn try_admit(&self) -> Option<InflightGuard> {
        let prev = self.counters.inflight.fetch_add(1, Ordering::Relaxed);
        if self.quota > 0 && prev >= self.quota {
            self.counters.inflight.fetch_sub(1, Ordering::Relaxed);
            return None;
        }
        Some(InflightGuard(Arc::clone(&self.counters)))
    }

    /// Override the breaker's trip threshold / re-probe window (tests, or
    /// operators tightening a tenant's blast radius).
    pub fn quarantine_policy(&self, trip: u32, window: Duration) {
        self.breaker.trip.store(trip.max(1), Ordering::Relaxed);
        self.breaker.window_us.store((window.as_micros() as u64).max(1), Ordering::Relaxed);
    }

    /// The breaker is open right now: admissions come back `Quarantined`.
    pub fn is_quarantined(&self) -> bool {
        let until = self.breaker.until_us.load(Ordering::Relaxed);
        until != 0 && (self.breaker.epoch.elapsed().as_micros() as u64) < until
    }

    /// Times the breaker has tripped since load.
    pub fn quarantine_trips(&self) -> u64 {
        self.breaker.trips.load(Ordering::Relaxed)
    }

    /// Manually re-probe a quarantined tenant: admissions resume
    /// immediately, one strike away from re-tripping (a clean batch closes
    /// the breaker fully). No-op on a healthy tenant.
    pub fn probe(&self) {
        if self.breaker.until_us.swap(0, Ordering::Relaxed) != 0 {
            let trip = self.breaker.trip.load(Ordering::Relaxed);
            self.breaker.strikes.store(trip.saturating_sub(1), Ordering::Relaxed);
        }
    }

    /// Admission-time breaker check. `false` = quarantined (the caller
    /// rejects with the typed `Quarantined` error; the tenant-side drop
    /// counter is bumped here, the service-wide one by the caller — the
    /// tenant-first write ordering the counters contract requires).
    pub(crate) fn breaker_admit(&self) -> bool {
        let until = self.breaker.until_us.load(Ordering::Relaxed);
        if until == 0 {
            return true;
        }
        let now = self.breaker.epoch.elapsed().as_micros() as u64;
        if now < until {
            self.counters.quarantine_drops.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        // timed half-open: the window elapsed, so let traffic probe the
        // tenant again — one strike away from re-tripping, so a single
        // further panic re-opens the breaker immediately while a clean
        // batch closes it fully (racing admits store idempotent values)
        let trip = self.breaker.trip.load(Ordering::Relaxed);
        self.breaker.strikes.store(trip.saturating_sub(1), Ordering::Relaxed);
        self.breaker.until_us.store(0, Ordering::Relaxed);
        true
    }

    /// A batch of this tenant poisoned an executor: strike, and trip the
    /// breaker when the consecutive-panic threshold is reached.
    pub(crate) fn breaker_panic(&self) {
        let strikes = self.breaker.strikes.fetch_add(1, Ordering::Relaxed) + 1;
        if strikes >= self.breaker.trip.load(Ordering::Relaxed) {
            let window = self.breaker.window_us.load(Ordering::Relaxed).max(1);
            let now = self.breaker.epoch.elapsed().as_micros() as u64;
            self.breaker.until_us.store(now + window, Ordering::Relaxed);
            self.breaker.trips.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A batch of this tenant completed cleanly: reset the strike count
    /// (the load-then-store keeps the healthy path write-free).
    pub(crate) fn breaker_ok(&self) {
        if self.breaker.strikes.load(Ordering::Relaxed) != 0 {
            self.breaker.strikes.store(0, Ordering::Relaxed);
        }
    }
}

struct Retired {
    name: String,
    id: ModelId,
    counters: Arc<TenantCounters>,
}

struct Inner {
    by_id: HashMap<u32, Arc<Tenant>>,
    by_name: HashMap<String, u32>,
    retired: Vec<Retired>,
    next_id: u32,
    /// Result of the last [`ModelRegistry::reintern`] pass; invalidated by
    /// load/unload/swap/canary changes (the arena composition changed).
    arena: Option<InternStats>,
}

/// The registry: name/id → [`Tenant`], load/unload/swap at runtime, plus
/// the cross-tenant arena interning pass.
pub struct ModelRegistry {
    level: OptLevel,
    inner: RwLock<Inner>,
}

impl ModelRegistry {
    /// Empty registry; tenants loaded later compile at `level` unless
    /// loaded with an explicit override.
    pub fn new(level: OptLevel) -> ModelRegistry {
        ModelRegistry {
            level,
            inner: RwLock::new(Inner {
                by_id: HashMap::new(),
                by_name: HashMap::new(),
                retired: Vec::new(),
                next_id: 0,
                arena: None,
            }),
        }
    }

    /// Single-tenant registry over an existing swappable cell — the
    /// compatibility constructor [`super::Service::start_swappable`] uses;
    /// the one tenant is named `"default"` and gets [`ModelId::DEFAULT`].
    pub fn single(cell: Arc<NetlistCell>, level: OptLevel) -> ModelRegistry {
        let reg = ModelRegistry::new(level);
        reg.load_cell("default", cell, 0).expect("fresh registry accepts the first tenant");
        reg
    }

    /// The level tenants compile at by default.
    pub fn level(&self) -> OptLevel {
        self.level
    }

    /// Load a checkpoint as a new tenant (unlimited quota).
    pub fn load(&self, name: &str, net: Arc<Netlist>) -> Result<ModelId> {
        self.load_cell(name, Arc::new(NetlistCell::new(net)), 0)
    }

    /// Load with an in-flight quota (`0` = unlimited).
    pub fn load_with_quota(&self, name: &str, net: Arc<Netlist>, quota: u64) -> Result<ModelId> {
        self.load_cell(name, Arc::new(NetlistCell::new(net)), quota)
    }

    /// Load over a caller-owned swappable cell.
    pub fn load_cell(&self, name: &str, cell: Arc<NetlistCell>, quota: u64) -> Result<ModelId> {
        if name.is_empty() {
            bail!("tenant name must be non-empty");
        }
        // compile OUTSIDE the registry lock: loads must not stall the
        // admission hot path behind a fresh tenant's first compile
        let programs = Arc::new(ProgramCell::with_level(Arc::clone(&cell), self.level));
        let mut inner = self.inner.write().unwrap();
        if inner.by_name.contains_key(name) {
            bail!("tenant '{name}' is already loaded");
        }
        let id = ModelId(inner.next_id);
        inner.next_id += 1;
        let tenant = Arc::new(Tenant {
            id,
            name: name.to_string(),
            cell,
            programs,
            level: self.level,
            quota,
            canary: RwLock::new(None),
            counters: Arc::new(TenantCounters::new()),
            breaker: Breaker::new(),
        });
        inner.by_name.insert(name.to_string(), id.raw());
        inner.by_id.insert(id.raw(), tenant);
        inner.arena = None;
        Ok(id)
    }

    /// Unload a tenant. Its counters move to the retired list (history
    /// stays in stats); in-flight requests finish on the `Arc<Tenant>`
    /// they were admitted with.
    pub fn unload(&self, name: &str) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        let Some(id) = inner.by_name.remove(name) else {
            bail!("tenant '{name}' is not loaded");
        };
        let tenant = inner.by_id.remove(&id).expect("by_name and by_id agree");
        inner.retired.push(Retired {
            name: tenant.name.clone(),
            id: tenant.id,
            counters: Arc::clone(&tenant.counters),
        });
        inner.arena = None;
        Ok(())
    }

    /// Swap a tenant's whole checkpoint while serving (in-flight batches
    /// keep their snapshot — the netlist cell's PR-region semantics).
    pub fn swap(&self, name: &str, net: Arc<Netlist>) -> Result<()> {
        let t = self.resolve_name(name).ok_or_else(|| {
            anyhow::anyhow!("tenant '{name}' is not loaded")
        })?;
        t.cell.replace(net);
        self.inner.write().unwrap().arena = None;
        Ok(())
    }

    /// Route `percent`% of `name`'s traffic to a second checkpoint,
    /// tracking live argmax agreement. The canary must match the primary's
    /// request/response geometry (rows are shared verbatim).
    pub fn set_canary(&self, name: &str, net: Arc<Netlist>, percent: u32) -> Result<()> {
        if percent > 100 {
            bail!("canary percent {percent} out of range (0..=100)");
        }
        let t = self.resolve_name(name).ok_or_else(|| {
            anyhow::anyhow!("tenant '{name}' is not loaded")
        })?;
        let cell = Arc::new(NetlistCell::new(net));
        let programs = Arc::new(ProgramCell::with_level(Arc::clone(&cell), t.level));
        let (d_in, d_out) = {
            let p = programs.load().1;
            (p.d_in(), p.d_out())
        };
        let primary = t.programs.load().1;
        if d_in != primary.d_in() || d_out != primary.d_out() {
            bail!(
                "canary geometry {}x{} != tenant '{name}' geometry {}x{}",
                d_in,
                d_out,
                primary.d_in(),
                primary.d_out()
            );
        }
        *t.canary.write().unwrap() =
            Some(Arc::new(Canary { cell, programs, percent, seq: AtomicU64::new(0) }));
        self.inner.write().unwrap().arena = None;
        Ok(())
    }

    /// Stop canarying `name`'s traffic.
    pub fn clear_canary(&self, name: &str) -> Result<()> {
        let t = self.resolve_name(name).ok_or_else(|| {
            anyhow::anyhow!("tenant '{name}' is not loaded")
        })?;
        *t.canary.write().unwrap() = None;
        Ok(())
    }

    /// Resolve by id — the admission hot path (one shared read lock + one
    /// hash lookup; executors never call this, they carry the `Arc`).
    pub fn resolve(&self, id: ModelId) -> Option<Arc<Tenant>> {
        self.inner.read().unwrap().by_id.get(&id.raw()).cloned()
    }

    /// Resolve by name — the wire front end's per-frame lookup.
    pub fn resolve_name(&self, name: &str) -> Option<Arc<Tenant>> {
        let inner = self.inner.read().unwrap();
        inner.by_name.get(name).and_then(|id| inner.by_id.get(id)).cloned()
    }

    /// Name → id without cloning the tenant.
    pub fn get(&self, name: &str) -> Option<ModelId> {
        self.inner.read().unwrap().by_name.get(name).copied().map(ModelId)
    }

    /// Live tenants, sorted by id (stable stats ordering).
    pub fn tenants(&self) -> Vec<Arc<Tenant>> {
        let inner = self.inner.read().unwrap();
        let mut out: Vec<Arc<Tenant>> = inner.by_id.values().cloned().collect();
        out.sort_by_key(|t| t.id);
        out
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().by_id.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cross-tenant table interning: hash-cons every table across ALL live
    /// tenants' programs (primaries AND canaries) into one shared arena
    /// and republish each program in place ([`ProgramCell::install`]).
    /// Identical tables across fine-tuned variants of one checkpoint are
    /// materialized once; the returned [`InternStats`] split shared vs
    /// private bytes. Bit-exact for exact levels: interning only relocates
    /// table content. A registry built at [`OptLevel::Lossy`] additionally
    /// ε-clusters *near*-identical tables across tenants under the same
    /// per-table budget (`Lossy(0)` degenerates to the exact pass) — each
    /// substituted lookup moves by at most the budget, the same contract
    /// every tenant already accepted by compiling at that level. A swap
    /// racing the install is benign — the next `load()` on that cell
    /// recompiles privately, and a later `reintern` re-shares it.
    pub fn reintern(&self) -> InternStats {
        // snapshot the program set under the read lock, intern outside any
        // lock (the pass is O(total table bytes)), publish lock-free via
        // the per-cell install, then record the stats
        let mut cells: Vec<Arc<ProgramCell>> = Vec::new();
        {
            let inner = self.inner.read().unwrap();
            let mut tenants: Vec<&Arc<Tenant>> = inner.by_id.values().collect();
            tenants.sort_by_key(|t| t.id);
            for t in tenants {
                cells.push(Arc::clone(&t.programs));
                if let Some(c) = t.canary.read().unwrap().as_ref() {
                    cells.push(Arc::clone(&c.programs));
                }
            }
        }
        let pairs: Vec<(Arc<Netlist>, Arc<CompiledProgram>)> =
            cells.iter().map(|c| c.load()).collect();
        let progs: Vec<&CompiledProgram> = pairs.iter().map(|(_, p)| p.as_ref()).collect();
        let (interned, stats) = match self.level {
            OptLevel::Lossy(budget) => intern_tables_lossy(&progs, budget),
            _ => intern_tables(&progs),
        };
        for (cell, ((net, _), prog)) in cells.iter().zip(pairs.iter().zip(interned)) {
            cell.install(Arc::clone(net), Arc::new(prog));
        }
        self.inner.write().unwrap().arena = Some(stats);
        stats
    }

    /// Stats of the last [`ModelRegistry::reintern`] pass, `None` when the
    /// registry changed since (or never interned).
    pub fn arena_stats(&self) -> Option<InternStats> {
        self.inner.read().unwrap().arena
    }

    /// Per-tenant stats snapshots: live tenants sorted by id, then retired
    /// tenants (frozen history), so totals summed over the returned list
    /// account for every request the registry ever served.
    pub fn tenant_stats(&self) -> Vec<TenantStats> {
        let inner = self.inner.read().unwrap();
        let mut live: Vec<&Arc<Tenant>> = inner.by_id.values().collect();
        live.sort_by_key(|t| t.id);
        let mut out: Vec<TenantStats> = live
            .iter()
            .map(|t| {
                let mut st = t.counters.snapshot(&t.name, t.id, false);
                st.input_width = t.input_width() as u64;
                st.quarantined = t.is_quarantined();
                st
            })
            .collect();
        out.extend(inner.retired.iter().map(|r| r.counters.snapshot(&r.name, r.id, true)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::engine;
    use crate::lut;
    use crate::sim;

    fn net(dims: &[usize], bits: &[u32], seed: u64) -> Arc<Netlist> {
        let ck = synthetic(dims, bits, seed);
        let tables = lut::from_checkpoint(&ck);
        Arc::new(Netlist::build(&ck, &tables, 2))
    }

    #[test]
    fn load_resolve_unload_lifecycle() {
        let reg = ModelRegistry::new(OptLevel::default());
        assert!(reg.is_empty());
        let a = reg.load("a", net(&[3, 2], &[3, 6], 1)).unwrap();
        let b = reg.load("b", net(&[4, 2], &[4, 6], 2)).unwrap();
        assert_eq!(a, ModelId::DEFAULT, "first tenant is the default route");
        assert_ne!(a, b);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.get("a"), Some(a));
        assert_eq!(reg.resolve(b).unwrap().name(), "b");
        assert_eq!(reg.resolve_name("b").unwrap().input_width(), 4);
        // duplicate names are a load-time error, not a silent replace
        assert!(reg.load("a", net(&[3, 2], &[3, 6], 3)).is_err());
        // unload retires the tenant but keeps its counters in stats
        reg.resolve(a).unwrap().counters().completed.fetch_add(7, Ordering::Relaxed);
        reg.unload("a").unwrap();
        assert!(reg.resolve(a).is_none());
        assert!(reg.get("a").is_none());
        assert!(reg.unload("a").is_err());
        let stats = reg.tenant_stats();
        assert_eq!(stats.len(), 2);
        let ra = stats.iter().find(|s| s.name == "a").unwrap();
        assert!(ra.retired);
        assert_eq!(ra.completed, 7);
        assert!(!stats.iter().find(|s| s.name == "b").unwrap().retired);
        // the name is free again after unload
        let a2 = reg.load("a", net(&[3, 2], &[3, 6], 4)).unwrap();
        assert_ne!(a2, a, "reloaded tenants get fresh ids");
    }

    #[test]
    fn reintern_shares_tables_across_variants_bit_exactly() {
        // two tenants loaded from the SAME checkpoint (fine-tune twins):
        // after reintern, one arena backs both and nothing shifts a bit
        let reg = ModelRegistry::new(OptLevel::default());
        let base = net(&[4, 3, 2], &[4, 5, 6], 77);
        reg.load("base", Arc::clone(&base)).unwrap();
        reg.load("twin", Arc::clone(&base)).unwrap();
        assert!(reg.arena_stats().is_none());
        let codes: Vec<Vec<u32>> = vec![vec![1, 2, 3, 0], vec![15, 0, 7, 9]];
        let want = sim::eval_batch(&base, &codes);
        let st = reg.reintern();
        assert_eq!(st.programs, 2);
        assert!(st.bytes_interned < st.bytes_flat, "{st:?}");
        assert_eq!(st.bytes_private, 0, "identical twins share every table: {st:?}");
        assert_eq!(reg.arena_stats().unwrap(), st);
        for t in reg.tenants() {
            let (_, p) = t.programs().load();
            assert_eq!(engine::run_batch(&p, &codes), want, "{}", t.name());
        }
        // the interned programs literally share the arena allocation
        let pa = reg.resolve_name("base").unwrap().programs().load().1;
        let pb = reg.resolve_name("twin").unwrap().programs().load().1;
        assert_eq!(pa.tables64(), pb.tables64());
        assert_eq!(pa.tables32(), pb.tables32());
        // a later load invalidates the recorded arena stats
        reg.load("c", net(&[3, 2], &[3, 6], 5)).unwrap();
        assert!(reg.arena_stats().is_none());
    }

    #[test]
    fn lossy_reintern_clusters_near_twins_across_tenants() {
        // fine-tune twins whose tables differ by a few LSBs in every entry:
        // the exact pass shares nothing, a Lossy registry's reintern
        // clusters them under the same per-table budget its tenants
        // compiled with, and Lossy(0) degenerates to the exact pass
        use crate::netlist::{adder_depth, LayerNet, LutInst, NeuronNet};
        let mk = |jit: i64| -> Arc<Netlist> {
            let t1: Vec<i64> = (0..8).map(|i| i * 300 - 1000 + jit).collect();
            let t2: Vec<i64> = (0..8).map(|i| -i * 200 + 500 - jit).collect();
            let neurons = vec![NeuronNet {
                luts: vec![
                    LutInst { input: 0, table: t1, out_width: 12 },
                    LutInst { input: 1, table: t2, out_width: 12 },
                ],
                bias: 0,
                depth: adder_depth(2, 2),
                sum_width: 14,
            }];
            Arc::new(Netlist {
                name: format!("twin{jit}"),
                layers: vec![LayerNet {
                    d_in: 2,
                    d_out: 1,
                    in_bits: 3,
                    out_bits: 8,
                    neurons,
                    requant: None,
                    depth: 1,
                }],
                n_add: 2,
                frac_bits: 12,
                domain: (-4.0, 4.0),
            })
        };
        let codes: Vec<Vec<u32>> = (0..64).map(|i| vec![i % 8, (i / 8) % 8]).collect();

        let exact = ModelRegistry::new(OptLevel::Full);
        exact.load("a", mk(0)).unwrap();
        exact.load("b", mk(3)).unwrap();
        let st_exact = exact.reintern();
        assert_eq!(
            st_exact.bytes_private, st_exact.bytes_interned,
            "twins share no exact duplicates: {st_exact:?}"
        );

        let reg = ModelRegistry::new(OptLevel::Lossy(6));
        reg.load("a", mk(0)).unwrap();
        reg.load("b", mk(3)).unwrap();
        let before: Vec<_> = reg
            .tenants()
            .iter()
            .map(|t| engine::run_batch(&t.programs().load().1, &codes))
            .collect();
        let st = reg.reintern();
        assert!(
            st.bytes_interned < st_exact.bytes_interned,
            "budget 6 must cluster the |delta| = 3 twins: {st:?} vs {st_exact:?}"
        );
        assert!(st.bytes_shared > 0, "{st:?}");
        // each substituted lookup moved by <= the budget; 2 lookups feed
        // every output neuron, so 2 * budget caps the per-output drift
        for (t, want) in reg.tenants().iter().zip(&before) {
            let got = engine::run_batch(&t.programs().load().1, &codes);
            let worst = want
                .iter()
                .flatten()
                .zip(got.iter().flatten())
                .map(|(a, b)| (a - b).abs())
                .max()
                .unwrap();
            assert!(worst <= 2 * 6, "tenant {}: drift {worst} > 12", t.name());
        }

        let zero = ModelRegistry::new(OptLevel::Lossy(0));
        zero.load("a", mk(0)).unwrap();
        zero.load("b", mk(3)).unwrap();
        let st0 = zero.reintern();
        assert_eq!(st0.bytes_interned, st_exact.bytes_interned, "Lossy(0) interns exactly");
        assert_eq!(st0.bytes_private, st0.bytes_interned);
    }

    #[test]
    fn canary_split_is_exact_and_geometry_checked() {
        let reg = ModelRegistry::new(OptLevel::default());
        reg.load("m", net(&[4, 3, 2], &[4, 5, 6], 10)).unwrap();
        // wrong-shape canary rejected up front
        assert!(reg.set_canary("m", net(&[3, 2], &[3, 6], 11), 25).is_err());
        assert!(reg.set_canary("m", net(&[4, 3, 2], &[4, 5, 6], 11), 101).is_err());
        assert!(reg.set_canary("missing", net(&[4, 3, 2], &[4, 5, 6], 11), 25).is_err());
        reg.set_canary("m", net(&[4, 3, 2], &[4, 5, 6], 11), 25).unwrap();
        let c = reg.resolve_name("m").unwrap().canary_snapshot().unwrap();
        assert_eq!(c.percent(), 25);
        // exactly 25 of every 100 consecutive rows are canaried
        let taken = (0..300).filter(|_| c.take_row()).count();
        assert_eq!(taken, 75);
        reg.clear_canary("m").unwrap();
        assert!(reg.resolve_name("m").unwrap().canary_snapshot().is_none());
    }

    #[test]
    fn quota_admits_up_to_limit_and_guard_frees() {
        let reg = ModelRegistry::new(OptLevel::default());
        reg.load_with_quota("q", net(&[3, 2], &[3, 6], 20), 2).unwrap();
        let t = reg.resolve_name("q").unwrap();
        let g1 = t.try_admit().expect("slot 1");
        let _g2 = t.try_admit().expect("slot 2");
        assert!(t.try_admit().is_none(), "quota 2 refuses the 3rd in-flight");
        assert_eq!(t.counters().inflight.load(Ordering::Relaxed), 2);
        drop(g1);
        assert!(t.try_admit().is_some(), "freed slot admits again");
        // unlimited quota never refuses
        reg.load("free", net(&[3, 2], &[3, 6], 21)).unwrap();
        let f = reg.resolve_name("free").unwrap();
        let guards: Vec<_> = (0..64).map(|_| f.try_admit().expect("unlimited")).collect();
        assert_eq!(guards.len(), 64);
    }

    #[test]
    fn quarantine_breaker_trips_half_opens_and_recovers() {
        let reg = ModelRegistry::new(OptLevel::default());
        reg.load("m", net(&[3, 2], &[3, 6], 40)).unwrap();
        let t = reg.resolve_name("m").unwrap();
        t.quarantine_policy(2, Duration::from_millis(30));
        assert!(t.breaker_admit());
        t.breaker_panic();
        assert!(!t.is_quarantined(), "one strike below the trip threshold");
        t.breaker_ok();
        t.breaker_panic();
        assert!(!t.is_quarantined(), "a clean batch resets the strike count");
        t.breaker_panic();
        assert!(t.is_quarantined(), "2 consecutive poisoned batches trip");
        assert!(!t.breaker_admit());
        assert_eq!(t.counters().quarantine_drops.load(Ordering::Relaxed), 1);
        assert_eq!(t.quarantine_trips(), 1);
        // timed half-open: after the window, traffic probes again...
        std::thread::sleep(Duration::from_millis(45));
        assert!(!t.is_quarantined());
        assert!(t.breaker_admit());
        // ...and a single further panic re-trips immediately
        t.breaker_panic();
        assert!(t.is_quarantined());
        assert_eq!(t.quarantine_trips(), 2);
        // manual probe reopens admission without waiting out the window
        t.probe();
        assert!(t.breaker_admit());
        t.breaker_ok();
        t.breaker_panic();
        assert!(!t.is_quarantined(), "recovered: the clean batch closed the breaker");
        // snapshot carries the breaker-facing counters
        let st = reg.tenant_stats();
        let m = st.iter().find(|s| s.name == "m").unwrap();
        assert_eq!(m.quarantine_drops, 1);
        assert!(!m.quarantined);
    }

    #[test]
    fn swap_replaces_checkpoint_in_place() {
        let reg = ModelRegistry::new(OptLevel::default());
        reg.load("m", net(&[3, 2], &[3, 6], 30)).unwrap();
        let other = net(&[3, 4, 2], &[3, 4, 6], 31);
        reg.swap("m", Arc::clone(&other)).unwrap();
        let t = reg.resolve_name("m").unwrap();
        let (n, p) = t.programs().load();
        assert!(Arc::ptr_eq(&n, &other));
        let codes = vec![vec![0u32, 1, 2]];
        assert_eq!(engine::run_batch(&p, &codes), sim::eval_batch(&other, &codes));
        assert!(reg.swap("missing", other).is_err());
    }
}
