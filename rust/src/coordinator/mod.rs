//! L3 coordinator: threaded batched-inference service over the netlist.
//!
//! The paper's deployment story is a streaming accelerator core (II = 1)
//! fed by a host; this module is that host-side system: a request router
//! with a **dynamic batcher** (dispatch on `max_batch` or `max_wait`,
//! whichever first), a worker pool executing batches, bounded queues for
//! backpressure, and end-to-end latency/throughput accounting. Tokio is
//! not available offline; the implementation uses std threads + channels,
//! which for this workload (CPU-bound microsecond batches) is the right
//! tool anyway.
//!
//! Workers execute on a [`Backend`]: the default is the compiled flat
//! program of [`crate::engine`] (batch-major, hot-swap aware via
//! [`ProgramCell`], cross-checked against [`crate::sim`] in debug builds);
//! the netlist-walking interpreter remains selectable for debugging and
//! A/B benchmarking.

pub mod batcher;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{Executor, ProgramCell};
use crate::netlist::hotswap::NetlistCell;
use crate::netlist::Netlist;
use crate::sim;
use crate::util::Summary;

/// One inference request (input codes).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub codes: Vec<u32>,
    pub submitted: Instant,
}

/// Completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub sums: Vec<i64>,
    /// Queue + batch + execute time.
    pub latency: Duration,
}

struct Pending {
    req: Request,
    reply: SyncSender<Response>,
}

/// Which executor the worker pool runs batches on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// Flat compiled program ([`crate::engine`]): batch-major table scans.
    /// The serving default.
    #[default]
    Compiled,
    /// Netlist-graph interpreter ([`crate::sim::Evaluator`]): per-sample
    /// walk. Kept for debugging and as the A/B baseline.
    Interpreted,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "compiled" | "engine" => Some(Backend::Compiled),
            "interpreted" | "sim" => Some(Backend::Interpreted),
            _ => None,
        }
    }
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceCfg {
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Bounded admission queue (backpressure).
    pub queue_depth: usize,
    pub backend: Backend,
}

impl Default for ServiceCfg {
    fn default() -> Self {
        ServiceCfg {
            workers: 4,
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_depth: 4096,
            backend: Backend::Compiled,
        }
    }
}

/// Aggregated service statistics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub completed: u64,
    pub rejected: u64,
    /// Admitted but never executed: the request's width stopped matching
    /// the model snapshot (admission raced a `replace_model`). The client
    /// observes a closed reply channel.
    pub dropped: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub throughput_rps: f64,
}

struct Shared {
    latencies: Mutex<Summary>,
    batch_sizes: Mutex<Summary>,
    completed: AtomicU64,
    rejected: AtomicU64,
    dropped: AtomicU64,
    batches: AtomicU64,
}

/// Batched inference service over a netlist.
pub struct Service {
    tx: SyncSender<Pending>,
    /// Kept so the queue survives even with zero workers (tests/backpressure).
    rx_keepalive: Arc<Mutex<Receiver<Pending>>>,
    /// Hot-swappable model handle (paper §6: online LUT updates).
    cell: Arc<NetlistCell>,
    shared: Arc<Shared>,
    next_id: AtomicU64,
    started: Instant,
    workers: Vec<std::thread::JoinHandle<()>>,
    cfg: ServiceCfg,
}

impl Service {
    pub fn start(net: Arc<Netlist>, cfg: ServiceCfg) -> Service {
        Self::start_swappable(Arc::new(NetlistCell::new(net)), cfg)
    }

    /// Start over a swappable cell: edge tables (or the whole model) can be
    /// replaced while serving; in-flight batches finish on their snapshot.
    pub fn start_swappable(cell: Arc<NetlistCell>, cfg: ServiceCfg) -> Service {
        let (tx, rx) = sync_channel::<Pending>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            latencies: Mutex::new(Summary::new()),
            batch_sizes: Mutex::new(Summary::new()),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            batches: AtomicU64::new(0),
        });
        // backend resources: the compiled path shares one program cache
        // (compiled once here, recompiled lazily after hot-swaps); the
        // interpreted path never pays for compilation
        let exec_backend = match cfg.backend {
            Backend::Compiled => {
                WorkerBackend::Compiled(Arc::new(ProgramCell::new(Arc::clone(&cell))))
            }
            Backend::Interpreted => WorkerBackend::Interpreted(Arc::clone(&cell)),
        };
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let rx = Arc::clone(&rx);
            let backend = exec_backend.clone();
            let shared = Arc::clone(&shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("kanele-worker-{w}"))
                    .spawn(move || worker_loop(rx, backend, shared, cfg))
                    .expect("spawn worker"),
            );
        }
        Service {
            tx,
            rx_keepalive: rx,
            cell,
            shared,
            next_id: AtomicU64::new(0),
            started: Instant::now(),
            workers,
            cfg,
        }
    }

    /// Hot-swap one edge table while serving (paper §6 future work).
    pub fn swap_edge(&self, layer: usize, q: usize, p: usize, table: Vec<i64>) -> Result<()> {
        self.cell.swap_edge(layer, q, p, table)
    }

    /// Replace the whole model while serving.
    pub fn replace_model(&self, net: Arc<Netlist>) {
        self.cell.replace(net);
    }

    /// Reject malformed requests at admission: a wrong-width row inside a
    /// compiled batch would otherwise shift every later sample in the
    /// batch-major input plane (cross-request corruption).
    fn check_width(&self, codes: &[u32]) -> Result<()> {
        let want = self.cell.input_width();
        anyhow::ensure!(
            codes.len() == want,
            "request width {} != model input width {want}",
            codes.len()
        );
        Ok(())
    }

    /// Submit a request; the returned receiver yields the response.
    /// Errors immediately on a wrong-width request or when the admission
    /// queue is full (backpressure).
    pub fn submit(&self, codes: Vec<u32>) -> Result<Receiver<Response>> {
        self.check_width(&codes)?;
        let (reply_tx, reply_rx) = sync_channel(1);
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            codes,
            submitted: Instant::now(),
        };
        match self.tx.try_send(Pending { req, reply: reply_tx }) {
            Ok(()) => Ok(reply_rx),
            Err(TrySendError::Full(_)) => {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("admission queue full (backpressure)")
            }
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("service stopped"),
        }
    }

    /// Submit with blocking retry (used by the closed-loop example).
    /// Malformed requests fail immediately; only backpressure retries.
    pub fn submit_blocking(&self, codes: Vec<u32>) -> Result<Response> {
        loop {
            // re-validate every attempt: a width error must never be
            // retried as if it were backpressure (a concurrent
            // replace_model can change the expected width)
            self.check_width(&codes)?;
            match self.submit(codes.clone()) {
                Ok(rx) => return Ok(rx.recv()?),
                Err(_) => std::thread::sleep(Duration::from_micros(20)),
            }
        }
    }

    pub fn stats(&self) -> ServiceStats {
        let lat = self.shared.latencies.lock().unwrap();
        let bs = self.shared.batch_sizes.lock().unwrap();
        let completed = self.shared.completed.load(Ordering::Relaxed);
        ServiceStats {
            completed,
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            dropped: self.shared.dropped.load(Ordering::Relaxed),
            batches: self.shared.batches.load(Ordering::Relaxed),
            mean_batch: bs.mean(),
            latency_p50_us: lat.quantile(0.5) * 1e6,
            latency_p99_us: lat.quantile(0.99) * 1e6,
            throughput_rps: completed as f64 / self.started.elapsed().as_secs_f64(),
        }
    }

    pub fn cfg(&self) -> ServiceCfg {
        self.cfg
    }

    /// Stop workers and join them.
    pub fn shutdown(self) {
        drop(self.tx);
        drop(self.rx_keepalive);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Per-worker execution resources, fixed at service start.
#[derive(Clone)]
enum WorkerBackend {
    Compiled(Arc<ProgramCell>),
    Interpreted(Arc<NetlistCell>),
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Pending>>>,
    backend: WorkerBackend,
    shared: Arc<Shared>,
    cfg: ServiceCfg,
) {
    // per-worker scratch, reused across batches and hot-swaps
    let mut exec = Executor::new();
    loop {
        // dynamic batch collection: block for the first item, then fill the
        // batch until max_batch or max_wait
        let mut batch: Vec<Pending> = Vec::with_capacity(cfg.max_batch);
        {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(p) => batch.push(p),
                Err(_) => return, // service dropped
            }
            let deadline = Instant::now() + cfg.max_wait;
            while batch.len() < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match guard.recv_timeout(deadline - now) {
                    Ok(p) => batch.push(p),
                    Err(_) => break,
                }
            }
        } // release the receiver so other workers can batch concurrently

        shared.batches.fetch_add(1, Ordering::Relaxed);
        {
            let mut bs = shared.batch_sizes.lock().unwrap();
            bs.push(batch.len() as f64);
        }
        // batch-consistent snapshot: a concurrent hot-swap applies to the
        // NEXT batch, never mid-batch (PR-region semantics). Requests whose
        // width no longer matches the snapshot (admission raced a
        // whole-model replace) yield None: their reply channel is dropped
        // instead of corrupting co-batched samples.
        let outputs: Vec<Option<Vec<i64>>> = match &backend {
            WorkerBackend::Compiled(programs) => {
                let (net, prog) = programs.load();
                let d_in = prog.d_in();
                let rows: Vec<&[u32]> = batch
                    .iter()
                    .map(|p| p.req.codes.as_slice())
                    .filter(|r| r.len() == d_in)
                    .collect();
                let outs = exec.run_batch(&prog, &rows);
                // checked invariant: the compiled program IS the netlist
                if cfg!(debug_assertions) {
                    let mut ev = sim::Evaluator::new(&net);
                    for (row, out) in rows.iter().zip(&outs) {
                        debug_assert_eq!(ev.eval(row), out.as_slice(), "engine/sim divergence");
                    }
                }
                let mut outs = outs.into_iter();
                batch
                    .iter()
                    .map(|p| {
                        (p.req.codes.len() == d_in)
                            .then(|| outs.next().expect("one output per valid row"))
                    })
                    .collect()
            }
            WorkerBackend::Interpreted(cell) => {
                let net = cell.load();
                let d_in = net.input_width();
                let mut ev = sim::Evaluator::new(&net);
                batch
                    .iter()
                    .map(|p| {
                        (p.req.codes.len() == d_in).then(|| ev.eval(&p.req.codes).to_vec())
                    })
                    .collect()
            }
        };
        for (p, sums) in batch.into_iter().zip(outputs) {
            let Some(sums) = sums else {
                // client sees RecvError on its reply channel
                shared.dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            };
            let latency = p.req.submitted.elapsed();
            {
                let mut lat = shared.latencies.lock().unwrap();
                lat.push(latency.as_secs_f64());
            }
            shared.completed.fetch_add(1, Ordering::Relaxed);
            let _ = p.reply.send(Response { id: p.req.id, sums, latency });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::lut;
    use crate::util::Rng;

    fn service(cfg: ServiceCfg) -> (Arc<Netlist>, Service) {
        let ck = synthetic(&[4, 3, 2], &[4, 5, 6], 2024);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Service::start(Arc::clone(&net), cfg);
        (net, svc)
    }

    #[test]
    fn both_backends_match_direct_eval() {
        for backend in [Backend::Compiled, Backend::Interpreted] {
            let (net, svc) = service(ServiceCfg { backend, ..Default::default() });
            let mut rng = Rng::new(42);
            let mut pending = Vec::new();
            let mut want = Vec::new();
            for _ in 0..100 {
                let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
                want.push(sim::eval(&net, &codes));
                pending.push(svc.submit(codes).unwrap());
            }
            for (rx, w) in pending.into_iter().zip(want) {
                assert_eq!(rx.recv().unwrap().sums, w, "{backend:?}");
            }
            svc.shutdown();
        }
    }

    #[test]
    fn responses_match_direct_eval() {
        let (net, svc) = service(ServiceCfg::default());
        let mut rng = Rng::new(1);
        let mut pending = Vec::new();
        let mut want = Vec::new();
        for _ in 0..200 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            want.push(sim::eval(&net, &codes));
            pending.push(svc.submit(codes).unwrap());
        }
        for (rx, w) in pending.into_iter().zip(want) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.sums, w);
        }
        let stats = svc.stats();
        assert_eq!(stats.completed, 200);
        assert!(stats.batches >= 1);
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let (net, svc) = service(ServiceCfg { workers: 4, ..Default::default() });
        let svc = Arc::new(svc);
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let svc = Arc::clone(&svc);
            let net = Arc::clone(&net);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..50 {
                    let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
                    let want = sim::eval(&net, &codes);
                    let got = svc.submit_blocking(codes).unwrap();
                    assert_eq!(got.sums, want);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(Arc::try_unwrap(svc).ok().unwrap().stats().completed, 400);
    }

    #[test]
    fn wrong_width_request_rejected_at_admission() {
        let (net, svc) = service(ServiceCfg::default());
        assert!(svc.submit(vec![1, 2, 3]).is_err()); // model wants 4 codes
        assert!(svc.submit(vec![1, 2, 3, 0, 0]).is_err());
        assert!(svc.submit_blocking(vec![0; 9]).is_err());
        // a well-formed neighbor is unaffected
        let codes = vec![1u32, 2, 3, 0];
        let resp = svc.submit_blocking(codes.clone()).unwrap();
        assert_eq!(resp.sums, sim::eval(&net, &codes));
        assert_eq!(svc.stats().completed, 1);
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // zero workers can't drain; queue_depth 4 must reject the 5th+
        let ck = synthetic(&[2, 2], &[3, 6], 7);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Service::start(
            net,
            ServiceCfg { workers: 0, queue_depth: 4, ..Default::default() },
        );
        let mut oks = 0;
        let mut errs = 0;
        let mut rxs = Vec::new();
        for _ in 0..10 {
            match svc.submit(vec![0, 1]) {
                Ok(rx) => {
                    oks += 1;
                    rxs.push(rx);
                }
                Err(_) => errs += 1,
            }
        }
        assert_eq!(oks, 4);
        assert_eq!(errs, 6);
        assert_eq!(svc.stats().rejected, 6);
    }

    #[test]
    fn hot_swap_while_serving() {
        // paper §6: LUT updates during operation; in-flight batches keep
        // their snapshot, later requests see the new table
        let ck = synthetic(&[3, 2], &[3, 6], 99);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Service::start(Arc::clone(&net), ServiceCfg::default());
        let codes = vec![1u32, 2, 3];
        let before = svc.submit_blocking(codes.clone()).unwrap().sums;
        assert_eq!(before, sim::eval(&net, &codes));
        // swap neuron 0's first active edge to a constant table
        let p = net.layers[0].neurons[0].luts[0].input;
        let n_codes = 1usize << ck.bits[0];
        svc.swap_edge(0, 0, p, vec![999_999; n_codes]).unwrap();
        let after = svc.submit_blocking(codes.clone()).unwrap().sums;
        assert_ne!(before[0], after[0]);
        // invalid swaps rejected while serving
        assert!(svc.swap_edge(7, 0, 0, vec![0; n_codes]).is_err());
        svc.shutdown();
    }

    #[test]
    fn batching_aggregates() {
        let (_, svc) = service(ServiceCfg {
            workers: 1,
            max_batch: 32,
            max_wait: Duration::from_millis(5),
            queue_depth: 1024,
            ..Default::default()
        });
        let rxs: Vec<_> = (0..64).map(|_| svc.submit(vec![1, 2, 3, 0]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = svc.stats();
        assert!(stats.mean_batch > 1.5, "mean batch {}", stats.mean_batch);
        svc.shutdown();
    }
}
